"""Execute every fenced ``python`` code block in the given markdown files.

The CI docs job runs this over ``README.md`` and ``docs/*.md`` so the
documentation suite can never silently rot: a doc example that stops
working fails the build, exactly like a test.

Contract:

* Only blocks whose info string is exactly ``python`` run.  Blocks
  tagged ``python no-run`` (for illustrative fragments — pseudo-code,
  output samples) and blocks in any other language (``bash``, plain
  fences) are skipped.
* All blocks of ONE file execute top-to-bottom in ONE fresh subprocess
  and share a namespace — later blocks may use names defined by earlier
  ones, so examples can build on each other the way a reader reads them.
* Files are independent processes: no cross-file leakage, and a failure
  pinpoints the file (and the block, via the ``# block N`` markers in
  the traceback's line numbers).

Usage::

    PYTHONPATH=src python tools/run_doc_blocks.py README.md docs/*.md
"""
from __future__ import annotations

import argparse
import re
import subprocess
import sys
from pathlib import Path

FENCE = re.compile(r"^```(\S*)[ \t]*(.*)$")


def extract_blocks(text: str):
    """-> list of (start_line, code) for blocks tagged exactly ``python``."""
    blocks, cur, lang, start = [], None, None, 0
    for i, line in enumerate(text.splitlines(), 1):
        m = FENCE.match(line.strip())
        if cur is None:
            if m and m.group(1):
                lang = (m.group(1), m.group(2).strip())
                cur, start = [], i + 1
            continue
        if m and not m.group(1):           # closing fence
            if lang == ("python", ""):
                blocks.append((start, "\n".join(cur)))
            cur, lang = None, None
            continue
        cur.append(line)
    return blocks


def run_file(path: Path, *, timeout: int) -> bool:
    blocks = extract_blocks(path.read_text())
    if not blocks:
        print(f"{path}: no python blocks")
        return True
    # pad each block with blank lines so traceback line numbers map
    # straight back into the markdown file
    script, emitted = [], 0
    for start, code in blocks:
        script.append("\n" * max(0, start - emitted - 1))
        emitted = start - 1
        script.append(code + "\n")
        emitted += code.count("\n") + 1
    proc = subprocess.run(
        [sys.executable, "-c", "".join(script)],
        capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        print(f"{path}: FAILED ({len(blocks)} blocks) — traceback line "
              "numbers match the markdown source")
        print(proc.stdout[-2000:])
        print(proc.stderr[-4000:])
        return False
    print(f"{path}: {len(blocks)} python blocks OK")
    return True


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("files", nargs="+", type=Path)
    ap.add_argument("--timeout", type=int, default=600,
                    help="per-file subprocess timeout (seconds)")
    args = ap.parse_args(argv)
    failed = [str(p) for p in args.files
              if not run_file(p, timeout=args.timeout)]
    if failed:
        print(f"\nFAIL: doc blocks broken in: {', '.join(failed)}")
        return 1
    print(f"\nOK: {len(args.files)} files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
