"""CI smoke test for ``python -m repro.serve``: start the server, POST one
request, assert 200 + finite logabsdet, and assert zero request-time
traces via the /stats endpoint.

Spawns the real entrypoint as a subprocess (``--port 0``), waits for the
``serving on http://...`` ready line, then exercises the public HTTP
surface exactly the way the docs/serving.md walkthrough does.

Usage::

    PYTHONPATH=src python tools/serve_smoke.py
"""
from __future__ import annotations

import json
import math
import os
import re
import subprocess
import sys
import time
import urllib.request
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")
READY = re.compile(r"serving on http://([\d.]+):(\d+)")


def main() -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.serve", "serve", "--port", "0",
         "--buckets", "16,32", "--max-batch", "2"],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True)
    try:
        deadline = time.monotonic() + 300
        host = port = None
        for line in proc.stdout:
            print("server:", line.rstrip())
            m = READY.search(line)
            if m:
                host, port = m.group(1), int(m.group(2))
                break
            if time.monotonic() > deadline:
                raise TimeoutError("server never printed the ready line")
        if port is None:
            raise RuntimeError(
                f"server exited (rc={proc.wait()}) before becoming ready")

        base = f"http://{host}:{port}"
        matrix = [[4.0, 1.0, 0.0], [1.0, 3.0, 1.0], [0.0, 1.0, 5.0]]
        req = urllib.request.Request(
            f"{base}/v1/logdet",
            data=json.dumps({"matrix": matrix}).encode(),
            headers={"Content-Type": "application/json"})
        with urllib.request.urlopen(req, timeout=120) as resp:
            assert resp.status == 200, resp.status
            body = json.load(resp)
        print("response:", body)
        assert math.isfinite(body["logabsdet"]), body
        assert abs(body["logabsdet"] - math.log(51.0)) < 1e-6, body

        with urllib.request.urlopen(f"{base}/stats", timeout=30) as resp:
            stats = json.load(resp)
        warm = stats["trace_count"]
        with urllib.request.urlopen(req, timeout=120) as resp:
            assert resp.status == 200
        with urllib.request.urlopen(f"{base}/stats", timeout=30) as resp:
            stats = json.load(resp)
        assert stats["trace_count"] == warm, (
            f"request-time trace: {warm} -> {stats['trace_count']}")
        print(f"serve smoke OK (warm traces: {warm}, request-time: 0)")
        return 0
    finally:
        proc.terminate()
        try:
            proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            proc.kill()


if __name__ == "__main__":
    sys.exit(main())
