"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parent.parent / "bench_out"
SRC = str(Path(__file__).resolve().parent.parent / "src")


def timeit(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall seconds of fn(*args) with block_until_ready."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def run_with_devices(code: str, n_devices: int, *, timeout: int = 1200,
                     x64: bool = True) -> str:
    """Run a snippet under --xla_force_host_platform_device_count=N."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    prelude = "import jax\n"
    if x64:
        prelude += 'jax.config.update("jax_enable_x64", True)\n'
    proc = subprocess.run([sys.executable, "-c", prelude + code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    return proc.stdout


def write_csv(name: str, header: list[str], rows: list[list]):
    OUT_DIR.mkdir(exist_ok=True)
    path = OUT_DIR / name
    with path.open("w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path
