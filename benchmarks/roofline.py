"""Roofline tooling: the measurement pass that calibrates the method
selector, plus the §Roofline report over launch/dryrun.py results.

Calibration (``--calibrate``)
-----------------------------
Measures the four terms `repro.core.calibration` prices routes with, on
THIS machine:

  gemm_flops        median-timed f32 GEMM (the MXU/BLAS roofline that
                    prices panel updates and estimator matvec slabs)
  stream_bytes      median-timed fused rank-1 update (read + write the
                    buffer once: the streaming-bandwidth roofline of the
                    faithful condensation step)
  collective_lat /  a shard_map psum loop over 8 host devices at two
  collective_bytes  payload sizes; the (latency, bandwidth) line is fit
                    from the two timings

and persists them to ``bench_out/roofline_calibration.json`` — the table
``select_method`` / ``select_route`` load (see repro.core.calibration for
the search order).  Re-run after moving to new hardware:

    PYTHONPATH=src python -m benchmarks.roofline --calibrate

Report (default)
----------------
Reads launch/dryrun.py results (dryrun_results.jsonl) and renders the
§Roofline table (one row per arch x shape on the single-pod mesh): three
terms in seconds, dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and a
one-line lever per row.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from benchmarks._common import OUT_DIR, run_with_devices, timeit, write_csv

LEVERS = {
    "compute_s": "raise MXU utilization: larger per-chip tiles / fewer remat "
                 "recomputes (useful-FLOP fraction is the lever)",
    "memory_s": "cut HBM traffic: fuse bandwidth-bound stages, bf16 "
                "intermediates, larger arithmetic-intensity blocks",
    "collective_s": "cut wire bytes: reshard to kill duplicate all-gathers, "
                    "overlap collectives with compute, bf16 grad all-reduce",
}


def load(path: Path):
    recs = []
    for line in path.read_text().splitlines():
        try:
            recs.append(json.loads(line))
        except json.JSONDecodeError:
            pass
    return recs


def render(recs, mesh: str = "16x16"):
    rows = []
    seen = set()
    for r in recs:
        if r.get("mesh") != mesh or r.get("skipped"):
            continue
        key = (r["arch"], r["shape"])
        if key in seen:
            continue
        seen.add(key)
        frac = r.get("useful_flops_frac", 0.0)
        rows.append([
            r["arch"], r["shape"], r["kind"],
            f"{r['compute_s']:.4g}", f"{r['memory_s']:.4g}",
            f"{r['collective_s']:.4g}", r["bottleneck"].replace("_s", ""),
            f"{r['model_flops']:.3e}", f"{r['hlo_flops_global']:.3e}",
            f"{min(frac, 1.0):.2f}",
            f"{r['memory']['peak_bytes_per_device'] / 2**30:.2f}",
        ])
    skips = [[r["arch"], r["shape"], "SKIP", r.get("reason", "")]
             for r in recs if r.get("skipped") and r.get("mesh") == mesh]
    return rows, skips


# ---------------------------------------------------------------- calibrate

_COLLECTIVE_CHILD = """
import json, time
import numpy as np
import jax, jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec
from repro._compat import make_mesh, shard_map, pvary

P = jax.device_count()
mesh = make_mesh((P,), ("rows",))
STEPS = {steps}
out = {{}}
for payload in {payloads}:
    def kernel(x):
        def body(i, acc):
            # one data-dependent psum per step: a pivot-row broadcast
            return lax.psum(x[0] + acc * 1e-9, "rows")
        acc = pvary(jnp.zeros(x.shape[1:], x.dtype), "rows")
        return lax.fori_loop(0, STEPS, body, acc).reshape(1, -1)
    f = shard_map(kernel, mesh=mesh,
                  in_specs=(PartitionSpec("rows", None),),
                  out_specs=PartitionSpec("rows", None))
    jf = jax.jit(f)
    x = jnp.zeros((P, payload), jnp.float32)
    jax.block_until_ready(jf(x))
    ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        jax.block_until_ready(jf(x))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    out[str(payload)] = ts[len(ts) // 2] / STEPS
print(json.dumps(out))
"""


def _measure_collectives(devices: int = 8, steps: int = 200,
                         payloads=(256, 65536)):
    """(latency_s, bytes_per_s) fit from a two-payload psum loop."""
    raw = json.loads(run_with_devices(
        _COLLECTIVE_CHILD.format(steps=steps, payloads=list(payloads)),
        devices, x64=False).strip().splitlines()[-1])
    b1, b2 = (4 * p for p in payloads)          # f32 payload bytes
    t1, t2 = raw[str(payloads[0])], raw[str(payloads[1])]
    if t2 <= t1:                                # noise floor: all latency
        return max(t1, t2), 1e12, raw
    bw = (b2 - b1) / (t2 - t1)
    lat = max(t1 - b1 / bw, 1e-9)
    return lat, bw, raw


def calibrate(out_path: Path, *, gemm_n: int = 1536, stream_n: int = 4096,
              devices: int = 8):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ref

    rng = np.random.default_rng(0)

    a = jnp.asarray(rng.standard_normal((gemm_n, gemm_n)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((gemm_n, gemm_n)), jnp.float32)
    t_gemm = timeit(jax.jit(jnp.dot), a, b, iters=5)
    gemm_flops = 2.0 * gemm_n ** 3 / t_gemm

    s = jnp.asarray(rng.standard_normal((stream_n, stream_n)), jnp.float32)
    pc = jnp.asarray(rng.standard_normal((stream_n,)), jnp.float32)
    pr = jnp.asarray(rng.standard_normal((stream_n,)), jnp.float32)
    t_r1 = timeit(jax.jit(ref.rank1_update_ref), s, pc, pr, iters=5)
    # read + write the buffer, stream the two vectors: ~3 x n^2 x 4 bytes
    stream_bytes = 3.0 * stream_n * stream_n * 4 / t_r1

    lat, coll_bw, raw = _measure_collectives(devices)

    table = {
        "gemm_flops": gemm_flops,
        "stream_bytes": stream_bytes,
        "collective_lat": lat,
        "collective_bytes": coll_bw,
        "source": f"measured:{jax.default_backend()}",
        "meta": {
            "gemm_n": gemm_n, "gemm_seconds": t_gemm,
            "stream_n": stream_n, "rank1_seconds": t_r1,
            "collective_devices": devices,
            "collective_raw_s_per_step": raw,
            "jax": jax.__version__,
            "unix_time": time.time(),
        },
    }
    out_path.parent.mkdir(exist_ok=True)
    out_path.write_text(json.dumps(table, indent=2) + "\n")
    print(f"calibration -> {out_path}")
    for k in ("gemm_flops", "stream_bytes", "collective_lat",
              "collective_bytes"):
        print(f"  {k:18s} {table[k]:.4g}")
    return table


# ------------------------------------------------------------------- report

def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="dryrun_results.jsonl")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--calibrate", action="store_true",
                    help="measure the device roofline and write the "
                         "selector's calibration table")
    ap.add_argument("--out", default=str(OUT_DIR / "roofline_calibration.json"))
    args = ap.parse_args(argv)
    if args.calibrate:
        return calibrate(Path(args.out))
    path = Path(args.results)
    if not path.exists():
        print(f"roofline: {path} not found — run "
              f"`python -m repro.launch.dryrun` first")
        return []
    recs = load(path)
    rows, skips = render(recs, args.mesh)
    header = ["arch", "shape", "kind", "compute_s", "memory_s",
              "collective_s", "bottleneck", "model_flops", "hlo_flops",
              "useful_frac", "peak_GiB_per_dev"]
    out = write_csv("roofline.csv", header, rows)
    print(f"roofline -> {out}")
    for r in rows:
        print("roofline", *r, sep=",")
    for s in skips:
        print("roofline_skip", *s[:3], sep=",")
    return rows


if __name__ == "__main__":
    main()
