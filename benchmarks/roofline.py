"""Roofline report: reads launch/dryrun.py results (dryrun_results.jsonl)
and renders the §Roofline table (one row per arch x shape on the single-pod
mesh): three terms in seconds, dominant bottleneck, MODEL_FLOPS/HLO_FLOPs,
and a one-line lever per row.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks._common import OUT_DIR, write_csv

LEVERS = {
    "compute_s": "raise MXU utilization: larger per-chip tiles / fewer remat "
                 "recomputes (useful-FLOP fraction is the lever)",
    "memory_s": "cut HBM traffic: fuse bandwidth-bound stages, bf16 "
                "intermediates, larger arithmetic-intensity blocks",
    "collective_s": "cut wire bytes: reshard to kill duplicate all-gathers, "
                    "overlap collectives with compute, bf16 grad all-reduce",
}


def load(path: Path):
    recs = []
    for line in path.read_text().splitlines():
        try:
            recs.append(json.loads(line))
        except json.JSONDecodeError:
            pass
    return recs


def render(recs, mesh: str = "16x16"):
    rows = []
    seen = set()
    for r in recs:
        if r.get("mesh") != mesh or r.get("skipped"):
            continue
        key = (r["arch"], r["shape"])
        if key in seen:
            continue
        seen.add(key)
        frac = r.get("useful_flops_frac", 0.0)
        rows.append([
            r["arch"], r["shape"], r["kind"],
            f"{r['compute_s']:.4g}", f"{r['memory_s']:.4g}",
            f"{r['collective_s']:.4g}", r["bottleneck"].replace("_s", ""),
            f"{r['model_flops']:.3e}", f"{r['hlo_flops_global']:.3e}",
            f"{min(frac, 1.0):.2f}",
            f"{r['memory']['peak_bytes_per_device'] / 2**30:.2f}",
        ])
    skips = [[r["arch"], r["shape"], "SKIP", r.get("reason", "")]
             for r in recs if r.get("skipped") and r.get("mesh") == mesh]
    return rows, skips


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="dryrun_results.jsonl")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args(argv)
    path = Path(args.results)
    if not path.exists():
        print(f"roofline: {path} not found — run "
              f"`python -m repro.launch.dryrun` first")
        return []
    recs = load(path)
    rows, skips = render(recs, args.mesh)
    header = ["arch", "shape", "kind", "compute_s", "memory_s",
              "collective_s", "bottleneck", "model_flops", "hlo_flops",
              "useful_frac", "peak_GiB_per_dev"]
    out = write_csv("roofline.csv", header, rows)
    print(f"roofline -> {out}")
    for r in rows:
        print("roofline", *r, sep=",")
    for s in skips:
        print("roofline_skip", *s[:3], sep=",")
    return rows


if __name__ == "__main__":
    main()
