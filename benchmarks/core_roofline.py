"""Roofline analysis of the logdet CORE on the production mesh (§Perf P0).

Terms are ANALYTIC — the kernels are simple enough to count exactly (the
rank-1 step is literally one fused outer-product subtract over the (L, N)
local block; the panel GEMM is one (L,k)x(k,N) matmul) — and the STRUCTURE
(collectives per loop body) is machine-verified against the compiled HLO of
each variant at a reduced N (a fori_loop body is costed/parsed exactly once,
so body collective counts are per-step counts).

Variants:
  pmc              paper-faithful rank-1, full static width     (baseline)
  pmc_staged       + geometric shape staging (live-area ~1/3)   (It1)
  pmc_blocked_k    + rank-K panels (GEMM trailing update)       (It2)
  pmc_blocked_k*   k = sqrt(N/P): napkin-optimal panel width
  pmc_blocked_vmem + Pallas VMEM-resident panel factorization   (It3)
  pge              parallel GE (cyclic, global pivoting)        (comparison)

Run:  python -m benchmarks.core_roofline --n 65536 --procs 256
"""
from __future__ import annotations

import argparse
import json
import math

from benchmarks._common import run_with_devices, write_csv

# v5e, f32
MXU = 99e12        # f32 matmul peak
VPU = 4.9e12       # f32 vector peak (rank-1 updates)
HBM = 819e9
ICI = 49.5e9
LAT = 1e-6

CHILD = """
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.core.parallel import parallel_slogdet_mc
from repro.core.blocked import parallel_slogdet_mc_blocked
from repro.core.gaussian import parallel_slogdet_ge
from repro.launch.mesh import make_rows_mesh
from repro.launch.hlo_analysis import collective_bytes

P = jax.device_count()
mesh = make_rows_mesh(P)
spec = jax.ShapeDtypeStruct(({n_lower}, {n_lower}), jnp.float32)
out = {{}}
for name, f in [("pmc", parallel_slogdet_mc(mesh)),
                ("pge", parallel_slogdet_ge(mesh)),
                ("pmc_blocked", parallel_slogdet_mc_blocked(mesh, k=16))]:
    st = collective_bytes(f.lower(spec).compile().as_text())
    out[name] = st.counts
print(json.dumps(out))
"""


def terms(name, n, p, *, k=None, staged=False, vmem=False):
    """Per-device roofline terms in seconds."""
    L = n // p
    area = 1.0 / 3.0 if staged else 1.0      # live-area fraction of updates
    if k is None:                             # rank-1 variants
        steps = n - p
        compute = 2 * L * n * steps * area / VPU
        memory = 8 * L * n * steps * area / HBM
        payload = (4 * n * area if staged else 4 * n)
        collective = steps * (2 * payload / ICI + LAT)
    else:
        n_panels = n / k
        gemm_f = 2 * L * n * k * n_panels * area          # = 2LN^2
        gemm_b = (8 * L * n + 8 * k * n) * n_panels * area
        fact_f = 2 * k * k * n * n_panels                 # redundant, VPU
        fact_b = (8 * k * n * n_panels if vmem            # one VMEM pass
                  else 8 * k * k * n * n_panels)          # k HBM passes
        compute = gemm_f / MXU + fact_f / VPU
        memory = (gemm_b + fact_b) / HBM
        collective = n_panels * (2 * 4 * k * n / ICI + LAT)
    if name == "pge":
        steps = n
        compute = 2 * L * n * steps / VPU
        memory = 8 * L * n * steps / HBM
        collective = steps * (2 * 2 * 4 * n / ICI + 3 * LAT)  # 2-row psum+argmax
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=65536)
    ap.add_argument("--procs", type=int, default=256)
    ap.add_argument("--lower-n", type=int, default=2048)
    ap.add_argument("--verify-hlo", action="store_true",
                    help="compile at lower-n and check per-step collective "
                         "counts (slow: spawns a 256-device subprocess)")
    args = ap.parse_args(argv)
    n, p = args.n, args.procs
    kstar = int(math.sqrt(n / p))

    variants = [
        ("pmc", dict()),
        ("pmc_staged", dict(staged=True)),
        ("pge", dict()),
        ("pmc_blocked_16", dict(k=16)),
        ("pmc_blocked_64", dict(k=64)),
        (f"pmc_blocked_k*={kstar}", dict(k=kstar)),
        (f"pmc_blocked_vmem_k32", dict(k=32, vmem=True, staged=True)),
    ]
    useful_s = (2 * n ** 3 / 3 / p) / MXU
    rows = []
    for name, kw in variants:
        t = terms(name, n, p, **kw)
        dom = max(t, key=t.get)
        bound = t[dom]
        rows.append([name, n, p, f"{t['compute_s']:.3f}", f"{t['memory_s']:.3f}",
                     f"{t['collective_s']:.3f}", dom.replace("_s", ""),
                     f"{useful_s / bound:.4f}"])
        print(f"core_roofline,{name},N={n},P={p},"
              f"compute={t['compute_s']:.3f}s,memory={t['memory_s']:.3f}s,"
              f"collective={t['collective_s']:.3f}s,"
              f"bottleneck={dom},roofline_frac={useful_s / bound:.4f}")

    if args.verify_hlo:
        counts = json.loads(run_with_devices(
            CHILD.format(n_lower=args.lower_n), args.procs, timeout=3000,
            x64=False))
        print("hlo per-body collective counts:", json.dumps(counts))
        assert counts["pmc"].get("all-reduce", 0) <= 4     # 1/step + tail
        assert counts["pge"].get("all-gather", 0) >= 2     # pivot search

    path = write_csv("core_roofline.csv",
                     ["variant", "N", "P", "compute_s", "memory_s",
                      "collective_s", "bottleneck", "roofline_frac"], rows)
    print(f"core_roofline -> {path}")
    return rows


if __name__ == "__main__":
    main()
