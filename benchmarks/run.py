"""Benchmark aggregator: one module per paper table/figure.

  table3        Table 3  — MC vs GE vs ScaLAPACK(bs=1) wall times
  fig7_8        Fig 7/8  — speedups (measured + cluster-modeled)
  fig9_comm     Fig 9    — distribution time + collective traffic
  kernels       (ours)   — kernel roofline projections
  estimators    (ours)   — exact vs stochastic logdet: time + rel error by N
  roofline      (ours)   — 40-cell dry-run roofline table (if results exist)

``python -m benchmarks.run [--quick|--full]`` prints CSV lines per bench.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smallest sizes (CI)")
    ap.add_argument("--full", action="store_true",
                    help="paper-size grid (hours on 1 core)")
    ap.add_argument("--only", default="",
                    help="comma list: table3,fig7_8,fig9,kernels,"
                         "estimators,roofline")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    failures = []

    if want("table3"):
        try:
            from benchmarks import table3
            if args.full:
                table3.main(["--full"])
            elif args.quick:
                table3.main(["--sizes", "128,256", "--procs", "1,2"])
            else:
                table3.main([])
        except Exception:
            failures.append("table3")
            traceback.print_exc()

    if want("fig7_8"):
        try:
            from benchmarks import fig7_8
            fig7_8.main([])
        except Exception:
            failures.append("fig7_8")
            traceback.print_exc()

    if want("fig9"):
        try:
            from benchmarks import fig9_comm
            fig9_comm.main(["--n", "128" if args.quick else "256",
                            "--procs", "2,4" if args.quick else "4,8"])
        except Exception:
            failures.append("fig9")
            traceback.print_exc()

    if want("kernels"):
        try:
            from benchmarks import kernels_bench
            kernels_bench.main(["--m", "512" if args.quick else "1024"])
        except Exception:
            failures.append("kernels")
            traceback.print_exc()

    if want("estimators"):
        try:
            from benchmarks import estimators_bench
            if args.full:
                estimators_bench.main(["--full"])
            elif args.quick:
                estimators_bench.main(["--sizes", "256,512", "--iters", "2"])
            else:
                estimators_bench.main([])
        except Exception:
            failures.append("estimators")
            traceback.print_exc()

    if want("roofline"):
        try:
            from benchmarks import roofline
            roofline.main([])
        except Exception:
            failures.append("roofline")
            traceback.print_exc()

    if failures:
        print(f"\nbenchmark FAILURES: {failures}")
        sys.exit(1)
    print("\nall benchmarks completed")


if __name__ == "__main__":
    main()
