"""Kernel-level benchmark: the condensation hot loop, unfused vs fused.

Two legs:

**Roofline micro-bench** (``bench_out/kernels.csv``) — the raw update
kernels.  On CPU the Pallas kernels run in interpret mode (correctness,
not speed), so speed here is (a) the XLA-fused jnp path wall-time, and
(b) the TPU projection from the kernel's exact byte/FLOP counts at v5e
roofline:
    rank-1:  (2*M*N flops, ~3*M*N*dtype bytes)  -> HBM-bound
    rank-K:  (2*M*N*K flops, ~(2*M*N + M*K + K*N)*dtype bytes) -> MXU-bound

**Fused-variant records** (``bench_out/kernels.json``) — the fused
kernels through their real call sites: the condensation engine (fused
one-pass steps vs the pivot/swap/update sequence, plus the bf16
mixed-precision route), the dense Chebyshev recurrence, and the dense
CG solve.  Each record is

    {"n": ..., "kernel": "condense|cheb|cg",
     "variant": "unfused|fused|fused_bf16",
     "seconds": ..., "rel_err": ...}

``rel_err`` is against the unfused full-precision leg of the SAME fresh
run: f32 fused variants must report 0.0 (bit-identical — the tests
assert it, this file records it); the bf16 variant must stay under the
documented error-model ceiling.  ``benchmarks.check_regression
--kernels`` gates these records (fused throughput floor vs the unfused
leg — a within-run ratio, no machine calibration — bf16 rel_err
ceiling, and absolute seconds vs the committed
``bench_out/kernels_baseline.json`` with unfused rows as the
runner-speed probe).  Refresh after a legitimate perf change:

    PYTHONPATH=src python -m benchmarks.kernels_bench
    cp bench_out/kernels.json bench_out/kernels_baseline.json
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from benchmarks._common import OUT_DIR, timeit, write_csv

HBM = 819e9
PEAK = 197e12

DEFAULT_SIZES = (256, 512)
CHEB_DEGREE = 32
CHEB_PROBES = 16
CG_RHS = 16


def _rel(got: float, want: float) -> float:
    return abs(got - want) / max(abs(want), 1e-30)


def roofline(m: int):
    """The original micro-bench: raw update kernels + TPU projections."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref

    n = m
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    pc = jnp.asarray(rng.standard_normal((m,)), jnp.float32)
    pr = jnp.asarray(rng.standard_normal((n,)), jnp.float32)

    rows = []
    f1 = jax.jit(ref.rank1_update_ref)
    t = timeit(f1, a, pc, pr)
    proj = 3 * m * n * 4 / HBM
    rows.append(["rank1_update", m, n, 0, f"{t*1e6:.0f}",
                 f"{proj*1e6:.1f}", f"{2*m*n/proj/1e12:.2f}"])

    for k in (32, 128, 256):
        c = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        r = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        fk = jax.jit(ref.panel_update_ref)
        t = timeit(fk, a, c, r)
        flops = 2 * m * n * k
        bytes_ = (2 * m * n + m * k + k * n) * 4
        proj = max(flops / PEAK, bytes_ / HBM)
        rows.append(["panel_update", m, n, k, f"{t*1e6:.0f}",
                     f"{proj*1e6:.1f}", f"{flops/proj/1e12:.2f}"])

    path = write_csv("kernels.csv",
                     ["kernel", "M", "N", "K", "cpu_us", "tpu_proj_us",
                      "tpu_proj_tflops"], rows)
    for r in rows:
        print("kernel", *r, sep=",")
    print(f"kernels roofline -> {path}")
    return rows


def fused_records(sizes, iters: int, panel_k: int):
    """Fused-vs-unfused timings through the production call sites."""
    import jax
    import jax.numpy as jnp

    from repro.core import pad_to_multiple
    from repro.core.engine import EngineConfig, build_serial
    from repro.estimators.chebyshev import logdet_chebyshev
    from repro.estimators.operators import DenseOperator, cg_solve

    # a thin operator wrapper that dodges the DenseOperator isinstance
    # gate — the estimators' unfused loop bodies, same matvec cost
    class _Unfused:
        def __init__(self, a):
            self.a, self.shape, self.dtype = a, a.shape, a.dtype

        def mm(self, v):
            return self.a @ v

        mv = mm

        def diag(self):
            return jnp.diagonal(self.a)

        def trace_hint(self):
            return jnp.trace(self.a)

    rng = np.random.default_rng(0)
    records = []
    for n in sizes:
        a_np = rng.standard_normal((n, n))
        spd_np = (a_np @ a_np.T / n + 2.0 * np.eye(n)).astype(np.float32)

        # ---- condensation engine: unfused vs fused vs fused+bf16 ----
        a = pad_to_multiple(jnp.asarray(a_np, jnp.float64), panel_k)
        legs = [
            ("unfused", EngineConfig(schedule="staged", update="panel",
                                     panel_k=panel_k)),
            ("fused", EngineConfig(schedule="staged", update="panel",
                                   panel_k=panel_k, fused=True)),
            ("fused_bf16", EngineConfig(schedule="staged", update="panel",
                                        panel_k=panel_k, fused=True,
                                        precision="bf16")),
        ]
        base_ld = None
        for variant, cfg in legs:
            fn = build_serial(cfg)
            t = timeit(fn, a, iters=iters)
            ld = float(fn(a)[1])
            if base_ld is None:
                base_ld = ld
            records.append({"n": n, "kernel": "condense",
                            "variant": variant, "seconds": t,
                            "rel_err": _rel(ld, base_ld)})
            print(f"kernels n={n:5d} condense/{variant:10s} {t:8.4f}s "
                  f"rel_err={_rel(ld, base_ld):.2e}")

        # ---- Chebyshev three-term recurrence: fused vs operator path ----
        spd = jnp.asarray(spd_np)
        kw = dict(degree=CHEB_DEGREE, num_probes=CHEB_PROBES, seed=1)
        base_est = None
        for variant in ("unfused", "fused"):
            op = _Unfused(spd) if variant == "unfused" else spd

            def fn(x, o=op):
                return logdet_chebyshev(o, **kw).est
            t = timeit(fn, spd, iters=iters)
            est = float(fn(spd))
            if base_est is None:
                base_est = est
            records.append({"n": n, "kernel": "cheb", "variant": variant,
                            "seconds": t,
                            "rel_err": _rel(est, base_est)})
            print(f"kernels n={n:5d} cheb/{variant:14s} {t:8.4f}s "
                  f"rel_err={_rel(est, base_est):.2e}")

        # ---- CG matvec+axpy+dot chain: fused vs operator path ----
        b = jnp.asarray(rng.standard_normal((n, CG_RHS)), jnp.float32)
        base_x = None
        for variant in ("unfused", "fused"):
            op = _Unfused(spd) if variant == "unfused" \
                else DenseOperator(spd)

            def fn(bb, o=op):
                return cg_solve(o, bb, tol=1e-6).x
            t = timeit(fn, b, iters=iters)
            x = np.asarray(fn(b))
            if base_x is None:
                base_x = x
            rel = float(np.abs(x - base_x).max()
                        / max(np.abs(base_x).max(), 1e-30))
            records.append({"n": n, "kernel": "cg", "variant": variant,
                            "seconds": t, "rel_err": rel})
            print(f"kernels n={n:5d} cg/{variant:16s} {t:8.4f}s "
                  f"rel_err={rel:.2e}")
    return records


def main(argv=None):
    import jax
    jax.config.update("jax_enable_x64", True)

    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=2048,
                    help="roofline micro-bench square size")
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--sizes", default=",".join(map(str, DEFAULT_SIZES)),
                    help="fused-variant record sizes")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--out", default=str(OUT_DIR / "kernels.json"))
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args(argv)

    rows = [] if args.skip_roofline else roofline(args.m)

    sizes = tuple(int(s) for s in args.sizes.split(","))
    records = fused_records(sizes, args.iters, args.k)
    OUT_DIR.mkdir(exist_ok=True)
    out = Path(args.out)
    out.write_text(json.dumps(records, indent=1) + "\n")
    print(f"kernels fused records -> {out}")
    return rows + records


if __name__ == "__main__":
    main()
