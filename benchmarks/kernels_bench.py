"""Kernel-level benchmark: the condensation hot loop.

On CPU the Pallas kernels run in interpret mode (correctness, not speed), so
speed here is (a) the XLA-fused jnp path wall-time, and (b) the TPU
projection from the kernel's exact byte/FLOP counts at v5e roofline:
    rank-1:  (2*M*N flops, ~3*M*N*dtype bytes)  -> HBM-bound
    rank-K:  (2*M*N*K flops, ~(2*M*N + M*K + K*N)*dtype bytes) -> MXU-bound
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks._common import timeit, write_csv

HBM = 819e9
PEAK = 197e12


def main(argv=None):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref

    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=2048)
    ap.add_argument("--n", type=int, default=2048)
    args = ap.parse_args(argv)
    m = n = args.m
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    pc = jnp.asarray(rng.standard_normal((m,)), jnp.float32)
    pr = jnp.asarray(rng.standard_normal((n,)), jnp.float32)

    rows = []
    f1 = jax.jit(ref.rank1_update_ref)
    t = timeit(f1, a, pc, pr)
    proj = 3 * m * n * 4 / HBM
    rows.append(["rank1_update", m, n, 0, f"{t*1e6:.0f}",
                 f"{proj*1e6:.1f}", f"{2*m*n/proj/1e12:.2f}"])

    for k in (32, 128, 256):
        c = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        r = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        fk = jax.jit(ref.panel_update_ref)
        t = timeit(fk, a, c, r)
        flops = 2 * m * n * k
        bytes_ = (2 * m * n + m * k + k * n) * 4
        proj = max(flops / PEAK, bytes_ / HBM)
        rows.append(["panel_update", m, n, k, f"{t*1e6:.0f}",
                     f"{proj*1e6:.1f}", f"{flops/proj/1e12:.2f}"])

    path = write_csv("kernels.csv",
                     ["kernel", "M", "N", "K", "cpu_us", "tpu_proj_us",
                      "tpu_proj_tflops"], rows)
    for r in rows:
        print("kernel", *r, sep=",")
    print(f"kernels -> {path}")
    return rows


if __name__ == "__main__":
    main()
