"""Serving-path benchmark: warm bucketed batching vs one-request-at-a-time.

Drives one synthetic mixed-size workload (``--requests`` square matrices
with N drawn uniformly from ``--n-lo``..``--n-hi``; nearly every request
is a novel shape, as real mixed traffic is) through three serving modes:

  naive     what a user gets today: ``repro.plan((n, n))`` per request,
            one at a time.  The process-level plan cache is on (repeat
            shapes are free), but every *novel* shape pays its trace +
            compile inside the timed region — that is the cost the
            serving path exists to remove.
  bucketed  `LogdetService` with ``max_batch=1``: pad-to-bucket through
            warm executables, no batching.  Isolates what bucketing
            alone buys.
  batched   the full service: pad-to-bucket + continuous batching
            (``--max-batch``).  All requests are submitted open-loop and
            drained through the warm batch executables.

Service warmup (compiling the bucket x batch ladder) happens *before*
the timed region and is reported separately as ``warmup_s`` — a serving
process pays it once at startup, or never when ``--plan-dir`` points at
AOT artifacts from ``python -m repro.serve export``.

Per mode, the record carries throughput (requests/s), p50/p99 request
latency (submit -> result, saturated open-loop for the service modes),
max relative error vs ``numpy.linalg.slogdet``, and ``request_traces`` —
executable traces that happened during the timed region (the service
modes must report 0; `check_regression` fails otherwise and also gates
``batched >= 3x naive`` throughput, ratio-based so any machine can run
it).

JSON schema (``bench_out/serve.json``): a list of records, one per mode,
with the shared workload fields inlined::

    {"bench": "serve", "mode": "batched", "requests": 40,
     "n_lo": 64, "n_hi": 512, "unique_shapes": 38, "method": "exact",
     "seconds": ..., "throughput_rps": ..., "p50_ms": ..., "p99_ms": ...,
     "warmup_s": ..., "request_traces": 0, "rel_err_max": ...}

Refresh the committed baseline after a legitimate serving-path change::

    PYTHONPATH=src python -m benchmarks.serve_bench
    cp bench_out/serve.json bench_out/serve_baseline.json
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks._common import OUT_DIR


def make_workload(requests: int, n_lo: int, n_hi: int, seed: int):
    """(matrices, reference logabsdets) — well-conditioned mixed sizes."""
    rng = np.random.default_rng(seed)
    sizes = rng.integers(n_lo, n_hi + 1, requests)
    mats, refs = [], []
    for n in sizes:
        # diagonally dominant: safely nonsingular at every size
        a = rng.standard_normal((n, n)) + np.eye(n) * (2.0 * np.sqrt(n))
        mats.append(a)
        refs.append(np.linalg.slogdet(a)[1])
    return mats, np.asarray(refs)


def _quantile_ms(lat_s, q: float) -> float:
    return float(np.quantile(np.asarray(lat_s), q) * 1e3)


def run_naive(mats, refs, method: str) -> dict:
    """One plan call per request, sequential — today's baseline path."""
    import repro
    from repro.core.plan import clear_plan_cache

    clear_plan_cache()
    lat, errs = [], []
    t0 = time.perf_counter()
    for a, ref in zip(mats, refs):
        t1 = time.perf_counter()
        p = repro.plan(a.shape, method=method, precision="float64",
                       validate=False)
        r = p(a)
        ld = float(r.logabsdet)
        lat.append(time.perf_counter() - t1)
        errs.append(abs(ld - ref) / max(abs(ref), 1.0))
    seconds = time.perf_counter() - t0
    return {"mode": "naive", "seconds": seconds,
            "throughput_rps": len(mats) / seconds,
            "p50_ms": _quantile_ms(lat, 0.5),
            "p99_ms": _quantile_ms(lat, 0.99),
            "warmup_s": 0.0, "request_traces": None,
            "rel_err_max": float(max(errs))}


def run_service(mats, refs, method: str, *, mode: str, buckets,
                max_batch: int, plan_dir=None) -> dict:
    """Submit the whole workload open-loop through a LogdetService."""
    from repro.serve import LogdetService, ServeConfig

    cfg = ServeConfig(buckets=buckets, max_batch=max_batch,
                      max_wait_ms=2.0, cache_capacity=128,
                      plan_dir=plan_dir, default_method=method)
    with LogdetService(cfg) as svc:
        warmup_s = svc.warmup()
        traces0 = svc.trace_count()
        t0 = time.perf_counter()
        futs = [svc.submit(a) for a in mats]
        done = [(f.result(timeout=600), time.perf_counter())
                for f in futs]
        seconds = time.perf_counter() - t0
        traces = svc.trace_count() - traces0
        errs = [abs(float(r.logabsdet) - ref) / max(abs(ref), 1.0)
                for (r, _), ref in zip(done, refs)]
        lat = [t_done - t0 for _, t_done in done]
    return {"mode": mode, "seconds": seconds,
            "throughput_rps": len(mats) / seconds,
            "p50_ms": _quantile_ms(lat, 0.5),
            "p99_ms": _quantile_ms(lat, 0.99),
            "warmup_s": warmup_s, "request_traces": traces,
            "rel_err_max": float(max(errs))}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--n-lo", type=int, default=64)
    ap.add_argument("--n-hi", type=int, default=512)
    ap.add_argument("--method", default="exact")
    ap.add_argument("--buckets", default="64,128,192,256,384,512",
                    help="service bucket ladder (comma-separated)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--plan-dir", default=None,
                    help="AOT artifact dir (python -m repro.serve export)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--modes", default="naive,bucketed,batched")
    ap.add_argument("--out", default=str(OUT_DIR / "serve.json"))
    args = ap.parse_args(argv)

    import jax
    jax.config.update("jax_enable_x64", True)

    buckets = tuple(int(b) for b in args.buckets.split(","))
    if max(b for b in buckets) < args.n_hi:
        ap.error(f"bucket ladder tops out at {max(buckets)} < "
                 f"--n-hi {args.n_hi}")
    mats, refs = make_workload(args.requests, args.n_lo, args.n_hi,
                               args.seed)
    shared = {"bench": "serve", "requests": args.requests,
              "n_lo": args.n_lo, "n_hi": args.n_hi,
              "unique_shapes": len({a.shape for a in mats}),
              "method": args.method, "max_batch": args.max_batch}
    print(f"workload: {args.requests} requests, "
          f"{shared['unique_shapes']} unique shapes in "
          f"[{args.n_lo}, {args.n_hi}], method={args.method}")

    records = []
    for mode in args.modes.split(","):
        if mode == "naive":
            rec = run_naive(mats, refs, args.method)
        elif mode == "bucketed":
            rec = run_service(mats, refs, args.method, mode="bucketed",
                              buckets=buckets, max_batch=1,
                              plan_dir=args.plan_dir)
        elif mode == "batched":
            rec = run_service(mats, refs, args.method, mode="batched",
                              buckets=buckets, max_batch=args.max_batch,
                              plan_dir=args.plan_dir)
        else:
            ap.error(f"unknown mode {mode!r}")
        rec = {**shared, **rec}
        records.append(rec)
        print(f"{mode:9s} {rec['throughput_rps']:8.2f} req/s  "
              f"p50={rec['p50_ms']:8.1f}ms p99={rec['p99_ms']:8.1f}ms  "
              f"warmup={rec['warmup_s']:5.1f}s  "
              f"traces={rec['request_traces']}  "
              f"rel_err={rec['rel_err_max']:.2e}")

    by_mode = {r["mode"]: r for r in records}
    if "naive" in by_mode and "batched" in by_mode:
        speedup = (by_mode["batched"]["throughput_rps"]
                   / by_mode["naive"]["throughput_rps"])
        print(f"batched vs naive throughput: x{speedup:.1f}")

    OUT_DIR.mkdir(exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(records, f, indent=1)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
