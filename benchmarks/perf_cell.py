"""§Perf iteration driver for one (arch x shape) cell.

Re-lowers the cell with a named set of optimization knobs and prints the
three roofline terms — the measure step of the hypothesis -> change ->
measure -> validate loop.  Runs in-process (set XLA_FLAGS for 512 devices
before calling) or via the __main__ subprocess path.

Knobs (comma list in --variant):
  base            paper of record for the cell (what the dry-run ran)
  gradcomp        bf16 gradient compression before the all-reduce
  bf16params      cast 2D+ params to bf16 before use (bf16 FSDP gathers)
  nosp            disable sequence-parallel residual (ablation)
  adafactor       switch optimizer
"""
import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                               + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def lower_variant(arch: str, shape_name: str, variant: str, *,
                  multi_pod: bool = False, scan_layers: bool = False):
    from repro.configs.registry import batch_specs, get_config
    from repro.configs.shapes import SHAPES
    from repro.launch.dryrun import analyze_compiled
    from repro.launch.mesh import make_production_mesh
    from repro.models import model as M
    from repro.optim.optimizers import OptConfig
    from repro.sharding import hints
    from repro.sharding.rules import batch_spec as batch_pspec, param_shardings
    from repro.train.step import TrainConfig, init_train_state, make_train_step

    knobs = set(variant.split(","))
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    cfg = get_config(arch).replace(scan_layers=scan_layers)
    if "padheads" in knobs:
        # pad head counts up to the model-axis size so attention shards
        # (qwen1.5: 20 heads replicated 16-way -> 32 heads, 2/device)
        msize = 16
        pad = lambda h: -(-h // msize) * msize
        cfg = cfg.replace(n_heads=pad(cfg.n_heads),
                          n_kv_heads=pad(cfg.n_kv_heads))
    shape = SHAPES[shape_name]
    assert shape.kind == "train", "perf_cell drives train cells"

    tcfg = TrainConfig(
        opt=OptConfig(name="adafactor" if ("adafactor" in knobs
                                           or cfg.n_experts >= 64) else "adamw"),
        microbatches=1,
        grad_compression="gradcomp" in knobs,
        cast_params_bf16="bf16params" in knobs,
        logdet_reg=0.05 if "logdetreg" in knobs else 0.0,
    )
    hints.configure(cfg, None if "nosp" in knobs else mesh)
    if "nosp" in knobs:
        hints.configure(cfg.replace(family="ssm"), mesh)  # ssm => no seq-SP

    specs = batch_specs(cfg, shape.global_batch, shape.seq_len, kind="train")
    bspecs = batch_pspec(cfg, mesh, kind="train", batch=shape.global_batch)
    bshard = {k: NamedSharding(mesh, bspecs[k]) for k in specs}
    key = jax.random.PRNGKey(0)
    state_shapes = jax.eval_shape(lambda k: init_train_state(k, cfg, tcfg), key)
    state_shardings = {
        "params": param_shardings(state_shapes["params"], cfg, mesh),
        "opt": param_shardings(state_shapes["opt"], cfg, mesh),
        "step": NamedSharding(mesh, P()),
    }
    jitted = jax.jit(make_train_step(cfg, tcfg),
                     in_shardings=(state_shardings, bshard),
                     out_shardings=(state_shardings, None),
                     donate_argnums=(0,))
    t0 = time.time()
    with mesh:
        compiled = jitted.lower(state_shapes, specs).compile()
    n_active = M.count_params(cfg, active_only=True)
    rec = analyze_compiled(None, compiled, chips=chips, cfg=cfg, shape=shape,
                           n_active=n_active)
    rec["variant"] = variant
    rec["compile_s"] = round(time.time() - t0, 1)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--scan", action="store_true")
    args = ap.parse_args(argv)
    rec = lower_variant(args.arch, args.shape, args.variant,
                        scan_layers=args.scan)
    slim = {k: rec[k] for k in
            ("variant", "compute_s", "memory_s", "collective_s", "bottleneck",
             "hlo_flops_global", "useful_flops_frac", "wire_bytes_per_chip",
             "collective_bytes_by_op", "collective_counts", "compile_s")}
    slim["temp_gib"] = rec["memory"]["temp_bytes_per_device"] / 2 ** 30
    print(json.dumps(slim))


if __name__ == "__main__":
    main()
