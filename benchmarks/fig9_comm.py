"""Figure 9: data-distribution time + communication volume, MC vs GE vs LU.

Two measurements:
  1. *Distribution time* (measured): host->devices scatter of the matrix
     under block layout (MC) vs cyclic layout (GE/ScaLAPACK).  The cyclic
     layout pays an extra permutation copy — the paper's Fig. 9 (left).
  2. *Communication per run* (exact, from HLO): per-algorithm collective op
     counts and wire bytes parsed from the compiled module — the paper's
     Fig. 9 (right) re-expressed for ICI rings (no MPI wall-clock here).
     MC: 1 psum per eliminated row; GE: all-gather pivot search + 2-row
     psum per row; LU adds panel gathers.
"""
from __future__ import annotations

import argparse
import json

from benchmarks._common import run_with_devices, write_csv

CHILD = """
import json, time
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.parallel import parallel_slogdet_mc
from repro.core.blocked import parallel_slogdet_mc_blocked
from repro.core.gaussian import parallel_slogdet_ge, cyclic_perm
from repro.core.scalapack import parallel_slogdet_lu
from repro.launch.mesh import make_rows_mesh
from repro.launch.hlo_analysis import collective_bytes
from repro.data.synthetic import random_matrix

N = {N}
n = jax.device_count()
mesh = make_rows_mesh(n)
a = random_matrix(N, kind="normal", seed=0)
sh = NamedSharding(mesh, P("rows", None))
out = {{}}

for name, prep in [("block", lambda: a), ("cyclic", lambda: a[cyclic_perm(N, n)])]:
    fn = lambda: jax.device_put(prep(), sh)
    jax.block_until_ready(fn())
    ts = []
    for _ in range(5):
        t0 = time.perf_counter(); jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    out[name + "_dist_s"] = sorted(ts)[2]

spec = jax.ShapeDtypeStruct((N, N), jnp.float64)
for name, f in [("pmc", parallel_slogdet_mc(mesh)),
                ("pmc_blocked", parallel_slogdet_mc_blocked(mesh, k=16)),
                ("pge", parallel_slogdet_ge(mesh)),
                ("plu", parallel_slogdet_lu(mesh, nb=1))]:
    txt = f.lower(spec).compile().as_text()
    st = collective_bytes(txt)
    out[name] = {{"counts": st.counts, "wire_bytes": st.wire_bytes}}
print(json.dumps(out))
"""


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--procs", default="4,8")
    args = ap.parse_args(argv)
    rows = []
    for p in [int(x) for x in args.procs.split(",")]:
        out = json.loads(run_with_devices(CHILD.format(N=args.n), p))
        print(f"fig9_dist,N={args.n},procs={p},"
              f"block={out['block_dist_s']:.4f}s,"
              f"cyclic={out['cyclic_dist_s']:.4f}s,"
              f"cyclic/block={out['cyclic_dist_s']/out['block_dist_s']:.2f}x")
        row = [args.n, p, out["block_dist_s"], out["cyclic_dist_s"]]
        for alg in ("pmc", "pmc_blocked", "pge", "plu"):
            st = out.get(alg)
            n_ops = sum(st["counts"].values())
            print(f"fig9_comm,{alg},procs={p},collective_ops={n_ops},"
                  f"wire_bytes={st['wire_bytes']:.3e}")
            row += [n_ops, st["wire_bytes"]]
        rows.append(row)
    path = write_csv(
        "fig9.csv",
        ["N", "procs", "block_dist_s", "cyclic_dist_s",
         "pmc_ops", "pmc_bytes", "pmcb_ops", "pmcb_bytes",
         "pge_ops", "pge_bytes", "plu_ops", "plu_bytes"], rows)
    print(f"fig9 -> {path}")
    return rows


if __name__ == "__main__":
    main()
