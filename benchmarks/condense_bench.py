"""Exact-path timing: the condensation engine's route matrix by N.

Times every serial engine route (schedule x update), the fused one-pass
variants of the staged routes, and the GE baseline at the gated sizes,
recording median wall seconds and the relative error against
``numpy.linalg.slogdet``.  Records go to ``bench_out/condense.json`` as

    {"n": ..., "route": "staged|rank1", "seconds": ..., "rel_err": ...,
     "pass": "fwd"}

(fused routes spell as ``staged|panel|fused``) and are gated by
``benchmarks.check_regression`` against the committed
``bench_out/condense_baseline.json`` exactly like the estimator records
(2x time + slack, 3x rel_err + floor; the GE rows are the runner-speed
probe), plus the headline fused acceptance — at N=1024 the fused
staged|panel route must beat the committed unfused staged|panel
baseline by >= 1.3x.  rank-1 routes are skipped above N=512 (O(n)
dispatches per step; they gate nothing the staged rows don't).
Refresh after a legitimate perf change:

    PYTHONPATH=src python -m benchmarks.condense_bench --sizes 256,512,1024
    cp bench_out/condense.json bench_out/condense_baseline.json
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from benchmarks._common import OUT_DIR, timeit, write_csv

DEFAULT_SIZES = (256, 512, 1024)
SERIAL_ROUTES = [("serial", "rank1"), ("serial", "panel"),
                 ("staged", "rank1"), ("staged", "panel")]
# fused one-pass variants of the staged routes (the production fused
# path); check_regression enforces the staged|panel|fused speedup floor
# against the committed staged|panel baseline at N=1024
FUSED_ROUTES = [("staged", "rank1"), ("staged", "panel")]
# rank-1 serial routes are O(n) separate device dispatches per step:
# past this size they dominate bench wall time without gating anything
# the staged rows don't already cover
SLOW_ROUTE_MAX_N = 512


def route_name(schedule: str, update: str, fused: bool = False) -> str:
    return f"{schedule}|{update}" + ("|fused" if fused else "")


def main(argv=None):
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp

    from repro.core import pad_to_multiple, slogdet_ge
    from repro.core.engine import EngineConfig, build_serial

    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default=",".join(map(str, DEFAULT_SIZES)))
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--out", default=str(OUT_DIR / "condense.json"))
    args = ap.parse_args(argv)
    sizes = tuple(int(s) for s in args.sizes.split(","))

    rng = np.random.default_rng(0)
    records = []
    for n in sizes:
        a_np = rng.standard_normal((n, n))
        ref = np.linalg.slogdet(a_np)[1]
        a = jnp.asarray(a_np)
        runs = []
        for schedule, update in SERIAL_ROUTES:
            if update == "rank1" and n > SLOW_ROUTE_MAX_N:
                continue
            cfg = EngineConfig(schedule=schedule, update=update,
                               panel_k=args.k)
            fn = build_serial(cfg)
            x = pad_to_multiple(a, args.k) if update == "panel" else a
            runs.append((route_name(schedule, update), fn, x))
        for schedule, update in FUSED_ROUTES:
            if update == "rank1" and n > SLOW_ROUTE_MAX_N:
                continue
            cfg = EngineConfig(schedule=schedule, update=update,
                               panel_k=args.k, fused=True)
            fn = build_serial(cfg)
            x = pad_to_multiple(a, args.k) if update == "panel" else a
            runs.append((route_name(schedule, update, fused=True), fn, x))
        runs.append(("ge", slogdet_ge, a))
        for name, fn, x in runs:
            t = timeit(fn, x, iters=args.iters)
            ld = float(fn(x)[1])
            rel = abs(ld - ref) / max(abs(ref), 1e-30)
            records.append({"n": n, "route": name, "seconds": t,
                            "rel_err": rel, "pass": "fwd"})
            print(f"condense n={n:5d} {name:14s} {t:8.4f}s "
                  f"rel_err={rel:.2e}")

    OUT_DIR.mkdir(exist_ok=True)
    out = Path(args.out)
    out.write_text(json.dumps(records, indent=1) + "\n")
    write_csv("condense.csv", ["n", "route", "seconds", "rel_err"],
              [[r["n"], r["route"], f"{r['seconds']:.5f}",
                f"{r['rel_err']:.3e}"] for r in records])
    print(f"condense -> {out}")
    return records


if __name__ == "__main__":
    main()
