"""Figures 7 & 8: speedup curves per matrix size + average speedup.

Three views:
  * modeled: the paper-cluster model.  Per eliminated row,
      MC:  compute 2*N*m/P flops + 1 broadcast of m doubles
      GE:  compute + argmax allreduce + 2 broadcasts of m doubles
    with the paper's constants (640 GFLOP/s nodes, ~5 GB/s IB, ~1.5 us
    latency), producing the speedup shape the paper measured (MC > GE, both
    degrading past ~16-32 procs at small N);
  * measured (table3): T_s / T_p from table3.csv (on this 1-core container
    these show partitioning overhead, not parallelism — documented);
  * ``--measured``: the engine scaling bench — runs the mesh engine on
    1/2/4/8 fake devices across (update in {rank1, panel}) x (lookahead
    on/off), records wall seconds, speedup vs the P=1 run of the same
    update, and a bit-identity check of lookahead vs plain.  Written to
    ``bench_out/scaling.json`` + ``scaling.csv``; gated by
    ``benchmarks.check_regression --scaling`` against the committed
    ``scaling_baseline.json``.
"""
from __future__ import annotations

import argparse
import csv
import json
from collections import defaultdict
from pathlib import Path

from benchmarks._common import OUT_DIR, run_with_devices, write_csv

# paper-era cluster constants (Table 2: dual Xeon E5-2650v3 nodes, IB)
FLOPS = 640e9 / 20     # per MPI rank (20 ranks/node)
BW = 5e9               # bytes/s effective per link (FDR IB, shared)
LAT = 3e-6             # per-message latency, seconds


def model_time(N: int, P: int, alg: str) -> float:
    """Total modeled seconds for N x N on P ranks."""
    t = 0.0
    # distributed phase: N - P rows (MC) / N rows (GE); live width shrinks
    comp = 0.0
    comm = 0.0
    rows = N - P if alg == "mc" else N
    for i in range(rows):
        m = N - i
        comp += 2.0 * m * max(N - i, 1) / P / FLOPS      # rank-1 update share
        if P > 1:
            if alg == "mc":
                comm += LAT + 8.0 * m / BW                # 1 bcast
            else:
                comm += 3 * LAT + 2 * 8.0 * m / BW + 8.0 * P / BW  # argmax+2
    t = comp + comm
    if alg == "mc" and P > 1:
        t += 2.0 * P * P * P / 3 / FLOPS + LAT + 8.0 * P * P / BW  # tail
    return t


def modeled_speedups(sizes, procs):
    rows = []
    for N in sizes:
        t1 = model_time(N, 1, "mc")
        for P in procs:
            for alg in ("mc", "ge"):
                rows.append([N, P, alg, t1 / model_time(N, P, alg)])
    return rows


def measured_speedups(table3_csv: Path):
    by = {}
    with table3_csv.open() as f:
        for row in csv.DictReader(f):
            by[(int(row["N"]), int(row["procs"]), row["method"])] = \
                float(row["seconds"])
    sizes = sorted({k[0] for k in by})
    procs = sorted({k[1] for k in by if k[1] > 1} | {1})
    rows = []
    for N in sizes:
        serials = [v for (n, p, m), v in by.items()
                   if n == N and p == 1 and m in ("mc", "ge")]
        if not serials:
            continue
        ts = min(serials)
        for P in procs:
            for m in ("pmc", "pge", "plu"):
                if (N, P, m) in by:
                    rows.append([N, P, m, ts / by[(N, P, m)]])
    return rows


# ---------------------------------------------------------------------------
# --measured: the engine scaling bench (fake-device subprocesses)
# ---------------------------------------------------------------------------

# subprocess body: one device count per process (the device count is fixed
# at jax init).  Times every (update, lookahead) engine instantiation and
# checks lookahead bit-identity within the same process.
_SCALING_CODE = """
import json, time
import numpy as np
from repro._compat import make_mesh
from repro.core.engine import EngineConfig, build_mesh

P, N, iters = {P}, {N}, {iters}
mesh = make_mesh((P,), ("rows",))
rng = np.random.default_rng(0)
a = rng.standard_normal((N, N))
out = []
for update in ("rank1", "panel"):
    got = {{}}
    for la in (False, True):
        fn = build_mesh(EngineConfig(schedule="mesh", update=update,
                                     lookahead=la), mesh)
        s, l = fn(a)                       # compile outside the timing
        s, l = float(s), float(l)
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            r = fn(a)
            r[1].block_until_ready()
            ts.append(time.perf_counter() - t0)
        ts.sort()
        got[la] = (s, l, ts[len(ts) // 2])
    bit = got[True][:2] == got[False][:2]
    for la in (False, True):
        s, l, sec = got[la]
        out.append(dict(bench="scaling", n=N, procs=P, update=update,
                        lookahead=la, seconds=sec, sign=s, logabsdet=l,
                        bit_identical=bit))
print(json.dumps(out))
"""


def measured_scaling(n: int, procs, iters: int = 3) -> list[dict]:
    """Run the engine scaling grid; returns the scaling.json records.

    ``speedup`` is against the same update's (P=1, lookahead=off) run, so
    the curves are comparable to the paper's Fig. 7 T_s/T_p convention;
    ``throughput`` (1/seconds) is what `gate_scaling` ratios within one
    run — no machine calibration needed.
    """
    records: list[dict] = []
    for P in procs:
        out = run_with_devices(
            _SCALING_CODE.format(P=P, N=n, iters=iters), P)
        records.extend(json.loads(out.strip().splitlines()[-1]))
    base = {r["update"]: r["seconds"] for r in records
            if r["procs"] == 1 and not r["lookahead"]}
    for r in records:
        t1 = base.get(r["update"])
        r["speedup"] = (t1 / r["seconds"]) if t1 else None
        r["throughput"] = 1.0 / r["seconds"]
    return records


def run_measured(n: int, procs, iters: int) -> list[dict]:
    records = measured_scaling(n, procs, iters=iters)
    OUT_DIR.mkdir(exist_ok=True)
    path = OUT_DIR / "scaling.json"
    path.write_text(json.dumps(records, indent=1))
    print(f"scaling -> {path}")
    write_csv("scaling.csv",
              ["n", "procs", "update", "lookahead", "seconds", "speedup"],
              [[r["n"], r["procs"], r["update"], int(r["lookahead"]),
                f"{r['seconds']:.6f}", f"{r['speedup']:.4f}"]
               for r in records])
    for r in records:
        print(f"scaling,n={r['n']},P={r['procs']},{r['update']},"
              f"lookahead={int(r['lookahead'])},{r['seconds']:.4f}s,"
              f"speedup={r['speedup']:.3f},bit={r['bit_identical']}")
    return records


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="1000,2000,4000,8000")
    ap.add_argument("--procs", default="1,2,4,8,16,32,64,128")
    ap.add_argument("--measured", action="store_true",
                    help="run the engine scaling bench on fake devices "
                         "(writes bench_out/scaling.json + scaling.csv)")
    ap.add_argument("--measured-n", type=int, default=1024,
                    help="matrix size of the --measured grid")
    ap.add_argument("--measured-procs", default="1,2,4,8")
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args(argv)
    sizes = [int(x) for x in args.sizes.split(",")]
    procs = [int(x) for x in args.procs.split(",")]

    if args.measured:
        return run_measured(args.measured_n,
                            [int(x) for x in args.measured_procs.split(",")],
                            args.iters)

    rows = modeled_speedups(sizes, procs)
    path = write_csv("fig7_modeled.csv", ["N", "procs", "alg", "speedup"], rows)
    print(f"fig7 modeled -> {path}")
    # fig8: average across sizes
    agg = defaultdict(list)
    for N, P, alg, s in rows:
        agg[(P, alg)].append(s)
    avg_rows = [[P, alg, sum(v) / len(v)] for (P, alg), v in sorted(agg.items())]
    path8 = write_csv("fig8_modeled.csv", ["procs", "alg", "avg_speedup"], avg_rows)
    for P, alg, s in avg_rows:
        print(f"fig8_modeled,{alg},procs={P},avg_speedup={s:.2f}")

    t3 = OUT_DIR / "table3.csv"
    if t3.exists():
        mrows = measured_speedups(t3)
        write_csv("fig7_measured.csv", ["N", "procs", "alg", "speedup"], mrows)
        for r in mrows:
            print("fig7_measured", *r, sep=",")
    return avg_rows


if __name__ == "__main__":
    main()
