"""Figures 7 & 8: speedup curves per matrix size + average speedup.

Two views:
  * measured: T_s / T_p from table3.csv (on this 1-core container these show
    partitioning overhead, not parallelism — documented);
  * modeled: the paper-cluster model.  Per eliminated row,
      MC:  compute 2*N*m/P flops + 1 broadcast of m doubles
      GE:  compute + argmax allreduce + 2 broadcasts of m doubles
    with the paper's constants (640 GFLOP/s nodes, ~5 GB/s IB, ~1.5 us
    latency), producing the speedup shape the paper measured (MC > GE, both
    degrading past ~16-32 procs at small N).
"""
from __future__ import annotations

import argparse
import csv
from collections import defaultdict
from pathlib import Path

from benchmarks._common import OUT_DIR, write_csv

# paper-era cluster constants (Table 2: dual Xeon E5-2650v3 nodes, IB)
FLOPS = 640e9 / 20     # per MPI rank (20 ranks/node)
BW = 5e9               # bytes/s effective per link (FDR IB, shared)
LAT = 3e-6             # per-message latency, seconds


def model_time(N: int, P: int, alg: str) -> float:
    """Total modeled seconds for N x N on P ranks."""
    t = 0.0
    # distributed phase: N - P rows (MC) / N rows (GE); live width shrinks
    comp = 0.0
    comm = 0.0
    rows = N - P if alg == "mc" else N
    for i in range(rows):
        m = N - i
        comp += 2.0 * m * max(N - i, 1) / P / FLOPS      # rank-1 update share
        if P > 1:
            if alg == "mc":
                comm += LAT + 8.0 * m / BW                # 1 bcast
            else:
                comm += 3 * LAT + 2 * 8.0 * m / BW + 8.0 * P / BW  # argmax+2
    t = comp + comm
    if alg == "mc" and P > 1:
        t += 2.0 * P * P * P / 3 / FLOPS + LAT + 8.0 * P * P / BW  # tail
    return t


def modeled_speedups(sizes, procs):
    rows = []
    for N in sizes:
        t1 = model_time(N, 1, "mc")
        for P in procs:
            for alg in ("mc", "ge"):
                rows.append([N, P, alg, t1 / model_time(N, P, alg)])
    return rows


def measured_speedups(table3_csv: Path):
    by = {}
    with table3_csv.open() as f:
        for row in csv.DictReader(f):
            by[(int(row["N"]), int(row["procs"]), row["method"])] = \
                float(row["seconds"])
    sizes = sorted({k[0] for k in by})
    procs = sorted({k[1] for k in by if k[1] > 1} | {1})
    rows = []
    for N in sizes:
        serials = [v for (n, p, m), v in by.items()
                   if n == N and p == 1 and m in ("mc", "ge")]
        if not serials:
            continue
        ts = min(serials)
        for P in procs:
            for m in ("pmc", "pge", "plu"):
                if (N, P, m) in by:
                    rows.append([N, P, m, ts / by[(N, P, m)]])
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="1000,2000,4000,8000")
    ap.add_argument("--procs", default="1,2,4,8,16,32,64,128")
    args = ap.parse_args(argv)
    sizes = [int(x) for x in args.sizes.split(",")]
    procs = [int(x) for x in args.procs.split(",")]

    rows = modeled_speedups(sizes, procs)
    path = write_csv("fig7_modeled.csv", ["N", "procs", "alg", "speedup"], rows)
    print(f"fig7 modeled -> {path}")
    # fig8: average across sizes
    agg = defaultdict(list)
    for N, P, alg, s in rows:
        agg[(P, alg)].append(s)
    avg_rows = [[P, alg, sum(v) / len(v)] for (P, alg), v in sorted(agg.items())]
    path8 = write_csv("fig8_modeled.csv", ["procs", "alg", "avg_speedup"], avg_rows)
    for P, alg, s in avg_rows:
        print(f"fig8_modeled,{alg},procs={P},avg_speedup={s:.2f}")

    t3 = OUT_DIR / "table3.csv"
    if t3.exists():
        mrows = measured_speedups(t3)
        write_csv("fig7_measured.csv", ["N", "procs", "alg", "speedup"], mrows)
        for r in mrows:
            print("fig7_measured", *r, sep=",")
    return avg_rows


if __name__ == "__main__":
    main()
