"""Render the §Roofline markdown table from dryrun_results.jsonl and patch
it into EXPERIMENTS.md (replaces the <!-- ROOFLINE_TABLE --> marker)."""
from __future__ import annotations

import argparse
import json
from pathlib import Path

LEVER = {
    "compute": "more useful-FLOP fraction (less remat/mask waste)",
    "memory": "fuse bandwidth-bound stages / bigger tiles",
    "collective": "reshard or overlap collectives",
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="dryrun_results.jsonl")
    ap.add_argument("--exp", default="EXPERIMENTS.md")
    args = ap.parse_args(argv)

    recs = {}
    skips = []
    for line in Path(args.results).read_text().splitlines():
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        if r.get("mesh") != "16x16":
            continue
        if r.get("skipped"):
            skips.append(r)
        else:
            recs[(r["arch"], r["shape"])] = r

    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck |"
        " MODEL/HLO fl | peak GiB/dev | lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), r in sorted(recs.items()):
        bn = r["bottleneck"].replace("_s", "")
        frac = min(r.get("useful_flops_frac", 0.0), 1.0)
        lines.append(
            f"| {arch} | {shape} | {r['compute_s']:.3g} | {r['memory_s']:.3g} "
            f"| {r['collective_s']:.3g} | **{bn}** | {frac:.2f} "
            f"| {r['memory']['peak_bytes_per_device']/2**30:.1f} "
            f"| {LEVER[bn]} |")
    for r in sorted(skips, key=lambda x: (x["arch"], x["shape"])):
        lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP "
                     f"| — | — | full attention: no sub-quadratic path |")
    table = "\n".join(lines)

    exp = Path(args.exp)
    txt = exp.read_text()
    marker = "<!-- ROOFLINE_TABLE -->"
    if marker in txt:
        txt = txt.replace(marker, "\n\n" + table + "\n")
        exp.write_text(txt)
        print(f"patched {exp} with {len(recs)} rows + {len(skips)} skips")
    else:
        print(table)


if __name__ == "__main__":
    main()
