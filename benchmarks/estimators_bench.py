"""Exact vs stochastic log-determinant: wall time and relative error by N.

For each size the harness builds a seeded well-conditioned SPD matrix,
computes the f64 LAPACK reference once, then times every requested method
(median of --iters after a compile warm-up) and records the relative error.
Results go to bench_out/estimators.json as a list of records

    {"n": ..., "method": ..., "seconds": ..., "logdet": ...,
     "rel_err": ..., "sem": ...}

plus a CSV twin for the roofline tooling.  Defaults stay CPU-friendly
(N up to 2048); --full sweeps the paper-scale range N in {512..8192} where
the O(N^3)-vs-O(N^2 * probes) crossover is unmistakable.

    PYTHONPATH=src python -m benchmarks.estimators_bench
    PYTHONPATH=src python -m benchmarks.estimators_bench --full \
        --methods mc_staged,chebyshev,slq
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks._common import OUT_DIR, timeit, write_csv

DEFAULT_SIZES = (512, 1024, 2048)
FULL_SIZES = (512, 1024, 2048, 4096, 8192)
EXACT = {"mc", "mc_staged", "mc_blocked", "ge"}


def make_spd(n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, 2 * n))
    return x @ x.T / (2 * n) + 2.0 * np.eye(n)


def main(argv=None):
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp

    from repro.core import slogdet

    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=str, default="")
    ap.add_argument("--full", action="store_true",
                    help="sweep the paper-scale range 512..8192")
    ap.add_argument("--methods", type=str,
                    default="mc_staged,chebyshev,slq")
    ap.add_argument("--num-probes", type=int, default=32)
    ap.add_argument("--degree", type=int, default=64)
    ap.add_argument("--num-steps", type=int, default=25)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.sizes:
        sizes = tuple(int(s) for s in args.sizes.split(","))
    else:
        sizes = FULL_SIZES if args.full else DEFAULT_SIZES
    methods = args.methods.split(",")

    records = []
    for n in sizes:
        a_np = make_spd(n, args.seed)
        _, ld_ref = np.linalg.slogdet(a_np)
        a = jnp.asarray(a_np)

        for method in methods:
            kw = {}
            if method == "chebyshev":
                kw = dict(num_probes=args.num_probes, degree=args.degree,
                          seed=args.seed)
            elif method == "slq":
                kw = dict(num_probes=args.num_probes,
                          num_steps=args.num_steps, seed=args.seed)

            def run(x):
                return slogdet(x, method=method, **kw)

            t = timeit(run, a, warmup=1, iters=args.iters)
            rec = {"n": n, "method": method, "seconds": t,
                   "logdet_ref": float(ld_ref)}
            if method in EXACT:
                _, ld = run(a)
            else:
                # one estimator pass yields both value and standard error
                from repro.estimators import estimate_logdet
                res = estimate_logdet(a, method=method, **kw)
                ld = res.est
                rec["sem"] = float(res.sem)
            rec["logdet"] = float(ld)
            rec["rel_err"] = abs(float(ld) - ld_ref) / abs(ld_ref)
            records.append(rec)
            print(f"n={n:5d} {method:>10s}: {t*1e3:9.1f} ms  "
                  f"rel_err={rec['rel_err']:.2e}")

    OUT_DIR.mkdir(exist_ok=True)
    out = OUT_DIR / "estimators.json"
    out.write_text(json.dumps(records, indent=2))
    write_csv("estimators.csv",
              ["n", "method", "seconds", "logdet", "rel_err"],
              [[r["n"], r["method"], f"{r['seconds']:.6f}",
                f"{r['logdet']:.6f}", f"{r['rel_err']:.3e}"]
               for r in records])
    print(f"estimators -> {out}")
    return records


if __name__ == "__main__":
    main()
