"""Exact vs stochastic log-determinant: wall time and relative error by N,
across operator structures.

For each size the harness builds a seeded well-conditioned SPD input —
dense, or one of the structured implicit backends — computes an exact
reference logdet, then times every requested method (median of --iters
after a compile warm-up) and records the relative error.  Structures:

  dense      in-memory (n, n) matrix; all methods apply
  kron       KroneckerOperator(A, B) with nA ~ nB ~ sqrt(n); reference is
             the exact identity nB*logdet(A) + nA*logdet(B)
  toeplitz   SPD ToeplitzOperator from a geometrically decaying symbol
  stencil    1-D Laplacian-style StencilOperator (offsets -1/0/+1)

Exact condensation methods need a materialized matrix, so structured runs
cover the estimator methods only (others are skipped with a note).
Results go to bench_out/estimators.json as a list of records

    {"n": ..., "method": ..., "operator": ..., "pass": "fwd"|"grad",
     "seconds": ..., "logdet": ..., "rel_err": ..., "sem": ...}

plus a CSV twin for the roofline tooling.  Defaults stay CPU-friendly
(N up to 2048); --full sweeps the paper-scale range N in {512..8192} where
the O(N^3)-vs-O(N^2 * probes) crossover is unmistakable.

``--grad`` adds a forward+backward axis: each method is re-timed as
``jit(value_and_grad(logdet))`` — exact methods pay one dense inverse in
the backward pass, estimator methods one batched CG solve on the forward's
probes, and structured operators differentiate with respect to their own
parameters (Kronecker factors / Toeplitz symbol / stencil bands).  The
``pass`` field keys the regression gate (benchmarks/check_regression.py)
so backward-pass time is gated exactly like forward.

``--methods`` may include ``auto``: those rows go through ``repro.plan(a,
method="auto")`` and carry a ``method_used`` field recording what the
cost model picked — sweep ``--full --methods auto,mc_staged,slq`` to see
the selector flip from exact condensation to estimators at the dense
crossover (n ~ 2400 per device at default budgets) and stay on
estimators for every structured operator.

    PYTHONPATH=src python -m benchmarks.estimators_bench
    PYTHONPATH=src python -m benchmarks.estimators_bench --operator kron \
        --methods chebyshev,slq
    PYTHONPATH=src python -m benchmarks.estimators_bench --full \
        --methods auto,mc_staged,chebyshev,slq
    PYTHONPATH=src python -m benchmarks.estimators_bench --grad
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks._common import OUT_DIR, timeit, write_csv

DEFAULT_SIZES = (512, 1024, 2048)
FULL_SIZES = (512, 1024, 2048, 4096, 8192)
EXACT = {"mc", "mc_staged", "mc_blocked", "ge"}
OPERATORS = ("dense", "kron", "toeplitz", "stencil")


def make_spd(n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, 2 * n))
    return x @ x.T / (2 * n) + 2.0 * np.eye(n)


def make_operator(structure: str, n: int, seed: int):
    """(operator_or_matrix, exact_reference_logdet, actual_n)."""
    import jax.numpy as jnp

    from repro.estimators import (
        KroneckerOperator, StencilOperator, ToeplitzOperator,
    )

    if structure == "dense":
        a = make_spd(n, seed)
        return jnp.asarray(a), float(np.linalg.slogdet(a)[1]), n
    if structure == "kron":
        na = max(int(round(np.sqrt(n))), 1)
        a, b = make_spd(na, seed), make_spd(na, seed + 1)
        # logdet(A (x) B) = nB logdet(A) + nA logdet(B): exact, no n x n
        ref = na * float(np.linalg.slogdet(a)[1]) \
            + na * float(np.linalg.slogdet(b)[1])
        return KroneckerOperator(jnp.asarray(a), jnp.asarray(b)), ref, na * na
    if structure == "toeplitz":
        c = 0.5 ** np.arange(n, dtype=np.float64)
        c[0] = 2.5                       # diagonally dominant -> SPD
        i = np.arange(n)
        dense = c[np.abs(i[:, None] - i[None, :])]
        ref = float(np.linalg.slogdet(dense)[1])
        return ToeplitzOperator(jnp.asarray(c)), ref, n
    if structure == "stencil":
        # 1-D Laplacian + shift: SPD tridiagonal
        dense = 2.5 * np.eye(n) - np.eye(n, k=1) - np.eye(n, k=-1)
        ref = float(np.linalg.slogdet(dense)[1])
        op = StencilOperator((-1, 0, 1),
                             jnp.asarray([-1.0, 2.5, -1.0]), n=n)
        return op, ref, n
    raise ValueError(f"unknown operator structure {structure!r}; "
                     f"choose from {OPERATORS}")


def grad_target(structure, a, plan_):
    """(scalar_fn, params) for jax.value_and_grad on this structure.

    Dense inputs differentiate with respect to the matrix entries;
    structured operators with respect to their own parameters, rebuilt
    inside the traced function so the structured pullback engages.  The
    plan is compiled once outside the traced function — only execution is
    timed/traced.
    """
    from repro.estimators import (
        KroneckerOperator, StencilOperator, ToeplitzOperator,
    )

    if structure == "dense":
        return (lambda p: plan_.logdet(p)), a
    if structure == "kron":
        return (lambda p: plan_.logdet(KroneckerOperator(p[0], p[1]))), \
            (a.a, a.b)
    if structure == "toeplitz":
        return (lambda p: plan_.logdet(ToeplitzOperator(p))), a.c
    if structure == "stencil":
        offsets = a.offsets
        return (lambda p: plan_.logdet(StencilOperator(offsets, p))), a.bands
    raise ValueError(structure)


def _bench_auto(a, ld_ref, n_actual, structure, args):
    """Time the auto-selector's pick for this (n, structure) cell.

    The interesting number is WHERE the cost model flips from exact
    condensation to estimators (dense: near n ~ 2400 per device at default
    budgets; structured operators: estimators at any n) — the emitted rows
    carry ``method_used`` so the crossover is visible in the JSON/CSV, and
    the plan is built once so the timings measure execution only.
    """
    import jax
    import repro

    p = repro.plan(a, method="auto", validate=False)
    res = p(a)
    t = timeit(lambda x: p.slogdet(x)[1], a, warmup=1, iters=args.iters)
    rec = {"n": n_actual, "method": "auto", "method_used": p.method,
           "operator": structure, "pass": "fwd", "seconds": t,
           "logdet_ref": ld_ref, "logdet": float(res.logabsdet),
           "rel_err": abs(float(res.logabsdet) - ld_ref) / abs(ld_ref)}
    if p.method in ("chebyshev", "slq"):
        rec["probes"] = int(p.config.num_probes)
    if p.compiled:
        # warm plan after the timed loop: anything beyond the first trace
        # is a retrace (gated to 0 by check_regression)
        rec["retraces"] = p.trace_count - 1
    if res.sem is not None and float(res.sem) > 0:
        rec["sem"] = float(res.sem)
    out = [rec]
    print(f"n={n_actual:5d} {structure:>8s} {'auto':>10s} "
          f" fwd: {t*1e3:9.1f} ms  rel_err={rec['rel_err']:.2e}  "
          f"-> {p.method}")
    if args.grad:
        tg = timeit(lambda x: jax.block_until_ready(p.value_and_grad(x)[1]),
                    a, warmup=1, iters=args.iters)
        resg, _ = p.value_and_grad(a)
        out.append({"n": n_actual, "method": "auto", "method_used": p.method,
                    "operator": structure, "pass": "grad", "seconds": tg,
                    "logdet_ref": ld_ref, "logdet": float(resg.logabsdet),
                    "rel_err": abs(float(resg.logabsdet) - ld_ref)
                    / abs(ld_ref)})
        print(f"n={n_actual:5d} {structure:>8s} {'auto':>10s} "
              f"grad: {tg*1e3:9.1f} ms  rel_err={out[-1]['rel_err']:.2e}  "
              f"-> {p.method}")
    return out


def main(argv=None):
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp  # noqa: F401  (x64 must be set before use)

    import repro

    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=str, default="")
    ap.add_argument("--full", action="store_true",
                    help="sweep the paper-scale range 512..8192")
    ap.add_argument("--methods", type=str,
                    default="mc_staged,chebyshev,slq")
    ap.add_argument("--operator", type=str, default="dense",
                    help="comma list of input structures: "
                         "dense|kron|toeplitz|stencil (or 'all')")
    ap.add_argument("--num-probes", type=int, default=32)
    ap.add_argument("--degree", type=int, default=64)
    ap.add_argument("--num-steps", type=int, default=25)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--grad", action="store_true",
                    help="also time forward+backward via "
                         "jit(value_and_grad(logdet)) per method")
    args = ap.parse_args(argv)

    if args.sizes:
        sizes = tuple(int(s) for s in args.sizes.split(","))
    else:
        sizes = FULL_SIZES if args.full else DEFAULT_SIZES
    methods = args.methods.split(",")
    structures = (OPERATORS if args.operator == "all"
                  else tuple(args.operator.split(",")))

    records = []
    for structure in structures:
        for n in sizes:
            a, ld_ref, n_actual = make_operator(structure, n, args.seed)

            for method in methods:
                if method == "auto":
                    records.extend(_bench_auto(a, ld_ref, n_actual,
                                               structure, args))
                    continue
                if structure != "dense" and method not in ("chebyshev",
                                                           "slq"):
                    print(f"n={n:5d} {method:>10s}: skipped (needs a "
                          f"materialized matrix, operator={structure})")
                    continue
                kw = {}
                if method == "chebyshev":
                    kw = dict(num_probes=args.num_probes, degree=args.degree,
                              seed=args.seed)
                elif method == "slq":
                    kw = dict(num_probes=args.num_probes,
                              num_steps=args.num_steps, seed=args.seed)

                # compile once; the timed loop executes the plan only
                p_method = repro.plan(a, method=method, validate=False, **kw)

                def run(x):
                    return p_method.slogdet(x)

                t = timeit(run, a, warmup=1, iters=args.iters)
                rec = {"n": n_actual, "method": method,
                       "operator": structure, "pass": "fwd", "seconds": t,
                       "logdet_ref": ld_ref}
                if method in EXACT:
                    _, ld = run(a)
                else:
                    # one unified-result pass yields value + standard error
                    res = p_method(a)
                    ld = res.logabsdet
                    rec["sem"] = float(res.sem)
                    rec["probes"] = int(p_method.config.num_probes)
                if p_method.compiled:
                    # warm plan: retraces beyond the first compile are a
                    # regression (check_regression gates this at 0)
                    rec["retraces"] = p_method.trace_count - 1
                rec["logdet"] = float(ld)
                rec["rel_err"] = abs(float(ld) - ld_ref) / abs(ld_ref)
                records.append(rec)
                print(f"n={n_actual:5d} {structure:>8s} {method:>10s} "
                      f" fwd: {t*1e3:9.1f} ms  rel_err={rec['rel_err']:.2e}")

                if not args.grad:
                    continue
                fn, params = grad_target(structure, a, p_method)
                vg = jax.jit(jax.value_and_grad(fn))
                tg = timeit(vg, params, warmup=1, iters=args.iters)
                val, _ = vg(params)
                grec = {"n": n_actual, "method": method,
                        "operator": structure, "pass": "grad",
                        "seconds": tg, "logdet_ref": ld_ref,
                        "logdet": float(val),
                        "rel_err": abs(float(val) - ld_ref) / abs(ld_ref)}
                records.append(grec)
                # NOTE: grad rows are jit(value_and_grad) end to end, while
                # fwd rows time the public eager call — grad can come out
                # FASTER at small N where eager dispatch dominates; compare
                # grad rows against grad rows (the gate keys on `pass`).
                print(f"n={n_actual:5d} {structure:>8s} {method:>10s} "
                      f"grad: {tg*1e3:9.1f} ms  rel_err={grec['rel_err']:.2e}")

    OUT_DIR.mkdir(exist_ok=True)
    out = OUT_DIR / "estimators.json"
    out.write_text(json.dumps(records, indent=2))
    write_csv("estimators.csv",
              ["n", "method", "method_used", "operator", "pass", "seconds",
               "logdet", "rel_err"],
              [[r["n"], r["method"], r.get("method_used", r["method"]),
                r["operator"], r["pass"],
                f"{r['seconds']:.6f}", f"{r['logdet']:.6f}",
                f"{r['rel_err']:.3e}"]
               for r in records])
    print(f"estimators -> {out}")
    return records


if __name__ == "__main__":
    main()
