"""Table 3: execution time of MC vs GE vs ScaLAPACK-style LU across matrix
sizes and processor counts.

This container has ONE physical core, so wall-clock across fake devices
measures algorithmic + partitioning overhead, not parallel speedup; the
MODELED speedup (fig7_8.py) uses per-step communication counts from the HLO
and the paper's cluster constants.  ``--full`` runs the paper's real grid
(1000..8000 x 1..128) — hours on this box, minutes on a pod.
"""
from __future__ import annotations

import argparse
import json

from benchmarks._common import run_with_devices, write_csv

CHILD = """
import json, time
import numpy as np
import jax
import repro
from repro.launch.mesh import make_rows_mesh
from repro.data.synthetic import random_matrix

sizes = {sizes}
methods = {methods}
n = jax.device_count()
mesh = make_rows_mesh(n)
out = []
for N in sizes:
    a = random_matrix(N, kind="normal", seed=N)
    ref = np.linalg.slogdet(a)[1]
    for m in methods:
        kw = dict(mesh=mesh) if m.startswith("p") else {{}}
        if m == "plu":
            kw["nb"] = 1      # the paper's ScaLAPACK setting (blocksize 1)
        plan = repro.plan(a, method=m, **kw)   # compile once, time execution
        f = lambda: plan.slogdet(a)
        ld = float(f()[1])            # warmup + correctness
        assert abs(ld - ref) < 1e-6 * max(1.0, abs(ref)), (m, N, ld, ref)
        ts = []
        for _ in range(3):
            t0 = time.perf_counter(); jax.block_until_ready(f()[1])
            ts.append(time.perf_counter() - t0)
        out.append((N, n, m, sorted(ts)[1]))
print(json.dumps(out))
"""


def run(sizes, procs, methods=("pmc", "pge", "plu"), serial=("mc", "ge")):
    rows = []
    # serial reference (paper: T_s = fastest serial among all algorithms)
    out = run_with_devices(
        CHILD.format(sizes=list(sizes), methods=list(serial)), 1)
    rows += [list(r) for r in json.loads(out)]
    for p in procs:
        out = run_with_devices(
            CHILD.format(sizes=list(sizes), methods=list(methods)), p)
        rows += [list(r) for r in json.loads(out)]
    path = write_csv("table3.csv", ["N", "procs", "method", "seconds"], rows)
    return rows, path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper grid: 1000..8000 x 1..128 (slow on 1 core)")
    ap.add_argument("--sizes", default="")
    ap.add_argument("--procs", default="")
    args = ap.parse_args(argv)
    if args.full:
        sizes = [1000, 2000, 3000, 4000, 5000, 6000, 7000, 8000]
        procs = [1, 2, 4, 8, 16, 32, 64, 128]
    else:
        sizes = [int(x) for x in args.sizes.split(",")] if args.sizes else [256, 512]
        procs = [int(x) for x in args.procs.split(",")] if args.procs else [1, 2, 4]
    rows, path = run(sizes, procs)
    print(f"table3 -> {path}")
    for r in rows:
        print("table3", *r, sep=",")
    return rows


if __name__ == "__main__":
    main()
