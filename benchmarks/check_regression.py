"""Benchmark-regression gate: fresh estimator bench vs committed baseline.

CI runs ``benchmarks.estimators_bench --sizes 256,512 --grad`` and then
this check, which compares ``bench_out/estimators.json`` against the
committed ``bench_out/estimators_baseline.json`` record-by-record (keyed
on (n, method, operator, pass) — ``pass`` distinguishes forward-only from
forward+backward rows, so backward-pass regressions are gated exactly
like forward ones) and FAILS on

  time    > 2x baseline * speed + 0.25 s slack
  rel_err > 3x baseline + 1e-8 floor     (floor keeps exact methods from
                                          tripping on roundoff noise)

``speed`` calibrates the gate to the machine running it: the baseline was
timed on one box, CI re-times on a shared runner that may simply be
slower.  The deterministic exact-method records (mc_staged etc.) act as
the runner-speed probe — speed = median(fresh/baseline seconds) over
them, clamped to >= 1 so a fast runner never loosens the gate.  The
absolute slack absorbs jitter on sub-second runs.

at the gated sizes N in {256, 512, 529}.  529 = 23^2 is the Kronecker
record for the 512 request (nA = nB = 23).  Baseline records with no
fresh counterpart are reported but do not fail the gate (method sets may
shrink deliberately); a fresh run missing EVERY gated record fails.

Refresh the baseline after a legitimate perf/accuracy change:

    PYTHONPATH=src python -m benchmarks.estimators_bench \
        --sizes 256,512 --operator all --iters 3 --grad
    cp bench_out/estimators.json bench_out/estimators_baseline.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

BENCH_DIR = Path(__file__).resolve().parent.parent / "bench_out"
GATED_N = (256, 512, 529)
TIME_FACTOR = 2.0
TIME_SLACK = 0.25
ERR_FACTOR = 3.0
ERR_FLOOR = 1e-8
EXACT = {"mc", "mc_staged", "mc_blocked", "ge"}


def speed_ratio(baseline: dict, fresh: dict) -> float:
    """Runner-speed calibration from deterministic exact-method records."""
    ratios = sorted(
        fresh[k]["seconds"] / base["seconds"]
        for k, base in baseline.items()
        if k[1] in EXACT and k in fresh and base["seconds"] > 0)
    if not ratios:
        return 1.0
    return max(1.0, ratios[len(ratios) // 2])


def key(rec):
    return (rec["n"], rec["method"], rec.get("operator", "dense"),
            rec.get("pass", "fwd"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", type=Path,
                    default=BENCH_DIR / "estimators.json")
    ap.add_argument("--baseline", type=Path,
                    default=BENCH_DIR / "estimators_baseline.json")
    args = ap.parse_args(argv)

    baseline = {key(r): r for r in json.loads(args.baseline.read_text())
                if r["n"] in GATED_N}
    fresh = {key(r): r for r in json.loads(args.fresh.read_text())
             if r["n"] in GATED_N}
    if not baseline:
        print(f"FAIL: no gated records (N in {GATED_N}) in {args.baseline}")
        return 1

    speed = speed_ratio(baseline, fresh)
    print(f"runner speed calibration: x{speed:.2f} vs baseline machine")

    failures, compared = [], 0
    for k, base in sorted(baseline.items()):
        got = fresh.get(k)
        if got is None:
            print(f"note: baseline record {k} missing from fresh run")
            continue
        compared += 1
        t_lim = TIME_FACTOR * base["seconds"] * speed + TIME_SLACK
        e_lim = ERR_FACTOR * base["rel_err"] + ERR_FLOOR
        flags = []
        if got["seconds"] > t_lim:
            flags.append("TIME REGRESSION")
            failures.append(
                f"{k}: {got['seconds']:.3f}s > limit {t_lim:.3f}s "
                f"(baseline {base['seconds']:.3f}s)")
        if got["rel_err"] > e_lim:
            flags.append("ERROR REGRESSION")
            failures.append(
                f"{k}: rel_err {got['rel_err']:.3e} > limit {e_lim:.3e} "
                f"(baseline {base['rel_err']:.3e})")
        print(f"{str(k):56s} t={got['seconds']:.3f}s/{t_lim:.3f}s "
              f"err={got['rel_err']:.2e}/{e_lim:.2e}  "
              f"{', '.join(flags) or 'ok'}")

    if compared == 0:
        print("FAIL: fresh run has none of the gated baseline records")
        return 1
    if failures:
        print(f"\nFAIL: {len(failures)} regression(s):")
        for f in failures:
            print(" -", f)
        return 1
    print(f"\nOK: {compared} records within gates")
    return 0


if __name__ == "__main__":
    sys.exit(main())
