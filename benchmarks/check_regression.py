"""Benchmark-regression gate: fresh estimator bench vs committed baseline.

CI runs ``benchmarks.estimators_bench --sizes 256,512 --grad`` and then
this check, which compares ``bench_out/estimators.json`` against the
committed ``bench_out/estimators_baseline.json`` record-by-record (keyed
on (n, method, operator, pass) — ``pass`` distinguishes forward-only from
forward+backward rows, so backward-pass regressions are gated exactly
like forward ones) and FAILS on

  time    > 2x baseline * speed + 0.25 s slack
  rel_err > 3x baseline + 1e-8 floor     (floor keeps exact methods from
                                          tripping on roundoff noise)

``speed`` calibrates the gate to the machine running it: the baseline was
timed on one box, CI re-times on a shared runner that may simply be
slower.  The deterministic exact-method records (mc_staged etc.) act as
the runner-speed probe — speed = median(fresh/baseline seconds) over
them, clamped to >= 1 so a fast runner never loosens the gate.  The
absolute slack absorbs jitter on sub-second runs.

at the gated sizes N in {256, 512, 529}.  529 = 23^2 is the Kronecker
record for the 512 request (nA = nB = 23).  Baseline records with no
fresh counterpart are reported but do not fail the gate (method sets may
shrink deliberately); a fresh run missing EVERY gated record fails.

The exact-path records from ``benchmarks.condense_bench`` (keyed on
(n, route, "dense", pass)) are gated the same way against
``bench_out/condense_baseline.json`` whenever that baseline is committed;
being deterministic, they also sharpen the runner-speed probe.

Beyond the baseline comparison, the fresh records are gated on their own
observability fields (`gate_metrics`): a warm compiled plan reporting
``retraces != 0`` fails (spec-stable executions must reuse one compiled
executable), and estimator forward rows missing the ``probes`` field
fail (accuracy comparisons must never be probe-blind).

The serving-path records from ``benchmarks.serve_bench`` are gated by
`gate_serve` whenever ``bench_out/serve_baseline.json`` is committed:
batched-service throughput must stay >= 3x the one-request-at-a-time
naive path (a ratio inside one fresh run, so no machine calibration),
the service modes must report zero executable traces during the timed
region, and absolute throughput is floored against the baseline with the
naive mode as the runner-speed probe.

The engine scaling records from ``benchmarks.fig7_8 --measured`` are
gated by `gate_scaling` (the multi-device CI leg runs it standalone via
``--scaling``): lookahead/plain bit-identity, the lookahead throughput
ratio at P >= 4 (``--strict`` requires >= 1.0 on real-interconnect
runners), and absolute wall time vs ``scaling_baseline.json`` with the
plain records as the runner-speed probe.

The static-audit records from ``python -m repro.analysis --all --json``
are gated by `gate_audit` (``--audit``, a standalone mode like
``--scaling``): any error-severity finding fails outright, any finding
ident absent from the committed ``bench_out/audit_baseline.json`` fails
(new waivers must be re-baselined deliberately, not silently absorbed),
and a checker pass present in the baseline but missing from the fresh
run fails (a dropped pass would otherwise pass vacuously).

The fused-kernel records from ``benchmarks.kernels_bench`` are gated by
`gate_kernels` (``--kernels``, a standalone mode like ``--scaling``):
fused legs must keep the unfused leg's throughput (within-run ratio),
f32 fused legs must be bit-identical (rel_err exactly 0), the bf16
route is ceilinged at its documented error model, and absolute seconds
are floored against ``bench_out/kernels_baseline.json``.  The condense
gate additionally enforces the headline fused acceptance: at N=1024 the
fresh ``staged|panel|fused`` route must beat the committed unfused
``staged|panel`` baseline by >= 1.3x (GE-probe calibrated).

Refresh the baselines after a legitimate perf/accuracy change:

    PYTHONPATH=src python -m benchmarks.estimators_bench \
        --sizes 256,512 --operator all --iters 3 --grad
    cp bench_out/estimators.json bench_out/estimators_baseline.json
    PYTHONPATH=src python -m benchmarks.condense_bench --sizes 256,512,1024
    cp bench_out/condense.json bench_out/condense_baseline.json
    PYTHONPATH=src python -m benchmarks.kernels_bench
    cp bench_out/kernels.json bench_out/kernels_baseline.json
    PYTHONPATH=src python -m benchmarks.serve_bench
    cp bench_out/serve.json bench_out/serve_baseline.json
    PYTHONPATH=src:. python -m benchmarks.fig7_8 --measured
    cp bench_out/scaling.json bench_out/scaling_baseline.json
    PYTHONPATH=src python -m repro.analysis --all \
        --json bench_out/audit_baseline.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

BENCH_DIR = Path(__file__).resolve().parent.parent / "bench_out"
GATED_N = (256, 512, 529, 1024)
TIME_FACTOR = 2.0
TIME_SLACK = 0.25
ERR_FACTOR = 3.0
ERR_FLOOR = 1e-8
EXACT = {"mc", "mc_staged", "mc_blocked", "ge"}

# fused-kernel gate (benchmarks.kernels_bench --kernels mode): within one
# fresh run the fused leg must retain this fraction of the unfused leg's
# throughput (machine-independent ratio; the fused condensation path is
# several x faster, the estimator kernels at worst break even on CPU
# where dispatch falls back to the identical jnp reference), f32 fused
# legs must be BIT-identical to unfused (rel_err exactly 0 — fusion that
# changes results is a correctness bug, not a perf trade), and the bf16
# route's rel_err is ceilinged at the documented error model
KERNEL_FUSED_MIN = 0.8
KERNEL_BF16_ERR_MAX = 5e-3

# the headline acceptance ratio: at this N the fused staged|panel engine
# route must beat the committed unfused staged|panel baseline by this
# factor (runner-speed calibrated through the GE probe)
CONDENSE_FUSED_N = 1024
CONDENSE_FUSED_SPEEDUP_MIN = 1.3

# serving gate (benchmarks.serve_bench): the batched service must beat
# the one-request-at-a-time path by this factor — a *ratio within one
# fresh run*, so it needs no machine calibration — and the service modes
# must report zero executable traces inside the timed region
SERVE_SPEEDUP_MIN = 3.0
SERVE_ERR_MAX = 1e-8

# scaling gate (benchmarks.fig7_8 --measured): every record must report
# lookahead bit-identity, and at P >= SCALING_GATE_P the lookahead route
# must retain this fraction of the plain route's throughput (a ratio
# within one fresh run).  On hardware with a real interconnect the
# pipelined broadcast overlaps compute and the ratio is >= 1 — pass
# --strict there.  CI's fake devices share ONE core: there is no
# broadcast latency to hide, so the default thresholds bound the
# pipelining *overhead* (the early apply + extra per-step ops) instead.
# rank1 pays proportionally more: its early apply adds a handful of
# dynamic-index ops per step against a tiny (L x N)/P bulk update.
SCALING_GATE_P = 4
SCALING_LOOKAHEAD_MIN = {"panel": 0.85, "rank1": 0.70}
SCALING_LOOKAHEAD_STRICT = 1.0


def speed_ratio(baseline: dict, fresh: dict) -> float:
    """Runner-speed calibration from deterministic exact-method records."""
    ratios = sorted(
        fresh[k]["seconds"] / base["seconds"]
        for k, base in baseline.items()
        if k[1] in EXACT and k in fresh and base["seconds"] > 0)
    if not ratios:
        return 1.0
    return max(1.0, ratios[len(ratios) // 2])


def key(rec):
    return (rec["n"], rec.get("method", rec.get("route")),
            rec.get("operator", "dense"), rec.get("pass", "fwd"))


def gate(baseline: dict, fresh: dict, speed: float, failures: list) -> int:
    """Compare one record set; append failures; return #compared."""
    compared = 0
    for k, base in sorted(baseline.items()):
        got = fresh.get(k)
        if got is None:
            print(f"note: baseline record {k} missing from fresh run")
            continue
        compared += 1
        t_lim = TIME_FACTOR * base["seconds"] * speed + TIME_SLACK
        e_lim = ERR_FACTOR * base["rel_err"] + ERR_FLOOR
        flags = []
        if got["seconds"] > t_lim:
            flags.append("TIME REGRESSION")
            failures.append(
                f"{k}: {got['seconds']:.3f}s > limit {t_lim:.3f}s "
                f"(baseline {base['seconds']:.3f}s)")
        if got["rel_err"] > e_lim:
            flags.append("ERROR REGRESSION")
            failures.append(
                f"{k}: rel_err {got['rel_err']:.3e} > limit {e_lim:.3e} "
                f"(baseline {base['rel_err']:.3e})")
        print(f"{str(k):56s} t={got['seconds']:.3f}s/{t_lim:.3f}s "
              f"err={got['rel_err']:.2e}/{e_lim:.2e}  "
              f"{', '.join(flags) or 'ok'}")
    return compared


def _load(path: Path, gated_only: bool = True) -> dict:
    recs = json.loads(path.read_text())
    return {key(r): r for r in recs
            if not gated_only or r["n"] in GATED_N}


ESTIMATORS = {"chebyshev", "slq"}


def gate_metrics(fresh: dict, failures: list) -> int:
    """Observability gates on the fresh records themselves (no baseline).

    Warm compiled plans must not retrace (``retraces`` must be 0 — the
    bench reports the plan's trace count after its timed loop), and every
    estimator forward row must report the probe budget it ran
    (``probes`` > 0) so accuracy comparisons are never probe-blind.
    Returns the number of records checked.
    """
    checked = 0
    for k, rec in sorted(fresh.items()):
        flags = []
        retraces = rec.get("retraces")
        if retraces is not None:
            checked += 1
            if retraces != 0:
                flags.append("RETRACE")
                failures.append(
                    f"{k}: warm plan retraced {retraces}x — spec-stable "
                    "executions must reuse one compiled executable")
        method = rec.get("method_used", rec.get("method"))
        if method in ESTIMATORS and rec.get("pass", "fwd") == "fwd":
            checked += 1
            if not rec.get("probes"):
                flags.append("NO PROBES FIELD")
                failures.append(
                    f"{k}: estimator row reports no 'probes' — rerun "
                    "benchmarks.estimators_bench (it records probes used)")
        if flags:
            print(f"{str(k):56s} metrics: {', '.join(flags)}")
    return checked


def gate_serve(fresh_path: Path, baseline_path: Path,
               failures: list) -> int:
    """Gate the serving-path records (benchmarks.serve_bench).

    Three checks: (1) batched >= SERVE_SPEEDUP_MIN x naive throughput
    within the fresh run (ratio-based — machine independent); (2) the
    service modes ran with zero executable traces in the timed region
    (the whole point of warm bucketed plans); (3) throughput hasn't
    collapsed vs the committed baseline, calibrated by the naive mode
    as the runner-speed probe (naive shares no serving code, so a
    serving regression cannot normalize itself away).
    """
    fresh = {r["mode"]: r for r in json.loads(fresh_path.read_text())}
    base = {r["mode"]: r for r in json.loads(baseline_path.read_text())}
    checked = 0

    naive, batched = fresh.get("naive"), fresh.get("batched")
    if naive is None or batched is None:
        failures.append("serve: fresh run must include the naive and "
                        "batched modes")
        return 0
    checked += 1
    speedup = batched["throughput_rps"] / naive["throughput_rps"]
    flag = "ok" if speedup >= SERVE_SPEEDUP_MIN else "SPEEDUP REGRESSION"
    print(f"{'serve: batched vs naive':56s} x{speedup:.1f} "
          f"(need >= x{SERVE_SPEEDUP_MIN:.0f})  {flag}")
    if speedup < SERVE_SPEEDUP_MIN:
        failures.append(
            f"serve: batched throughput only x{speedup:.2f} the naive "
            f"path (gate: >= x{SERVE_SPEEDUP_MIN})")

    speed = 1.0
    if "naive" in base and base["naive"]["throughput_rps"] > 0:
        speed = max(1.0, base["naive"]["throughput_rps"]
                    / naive["throughput_rps"])
        print(f"serve runner speed (naive probe): x{speed:.2f} "
              "vs baseline machine")

    for mode, rec in sorted(fresh.items()):
        checked += 1
        flags = []
        if mode != "naive" and rec.get("request_traces") != 0:
            flags.append("REQUEST-TIME TRACE")
            failures.append(
                f"serve {mode}: {rec.get('request_traces')} executable "
                "trace(s) during the timed region — the service must "
                "only ever run warm plans")
        if rec["rel_err_max"] > SERVE_ERR_MAX:
            flags.append("ERROR REGRESSION")
            failures.append(
                f"serve {mode}: rel_err_max {rec['rel_err_max']:.2e} > "
                f"{SERVE_ERR_MAX:.0e}")
        b = base.get(mode)
        if b is not None and b["throughput_rps"] > 0:
            floor = b["throughput_rps"] / (TIME_FACTOR * speed)
            if rec["throughput_rps"] < floor:
                flags.append("THROUGHPUT REGRESSION")
                failures.append(
                    f"serve {mode}: {rec['throughput_rps']:.2f} req/s < "
                    f"floor {floor:.2f} (baseline "
                    f"{b['throughput_rps']:.2f})")
        print(f"{'serve: ' + mode:56s} "
              f"{rec['throughput_rps']:8.2f} req/s  "
              f"traces={rec.get('request_traces')}  "
              f"{', '.join(flags) or 'ok'}")
    return checked


def gate_scaling(fresh_path: Path, baseline_path: Path, failures: list,
                 strict: bool = False) -> int:
    """Gate the engine scaling records (benchmarks.fig7_8 --measured).

    Three checks: (1) every record reports lookahead/plain bit-identity
    (``bit_identical`` — the correctness half of the lookahead claim);
    (2) at P >= SCALING_GATE_P, lookahead throughput >= threshold x the
    plain route's within the same fresh run (machine-independent ratio;
    ``strict`` raises the threshold to 1.0 for runners with a real
    interconnect); (3) wall seconds floored against the committed
    baseline, runner speed calibrated on the plain (lookahead=off)
    records — code the lookahead kernels do not share, so a uniform
    lookahead regression cannot normalize itself away.
    """
    fresh = {(r["procs"], r["update"], bool(r["lookahead"])): r
             for r in json.loads(fresh_path.read_text())}
    base = {(r["procs"], r["update"], bool(r["lookahead"])): r
            for r in json.loads(baseline_path.read_text())}
    checked = 0

    # (1) bit identity everywhere it was measured
    for k, rec in sorted(fresh.items()):
        checked += 1
        if not rec.get("bit_identical"):
            failures.append(
                f"scaling {k}: lookahead (sign, logabsdet) differs from "
                "the plain schedule — pipelining must be bit-identical")
            print(f"{'scaling: ' + str(k):56s} BIT-IDENTITY BROKEN")

    # (2) lookahead-vs-plain throughput ratio at gated device counts
    pairs = sorted({(p, u) for (p, u, _la) in fresh
                    if p >= SCALING_GATE_P})
    for p, u in pairs:
        plain, la = fresh.get((p, u, False)), fresh.get((p, u, True))
        if plain is None or la is None:
            continue
        checked += 1
        ratio = la["throughput"] / plain["throughput"]
        need = SCALING_LOOKAHEAD_STRICT if strict \
            else SCALING_LOOKAHEAD_MIN[u]
        flag = "ok" if ratio >= need else "LOOKAHEAD REGRESSION"
        print(f"{f'scaling: P={p} {u} lookahead/plain':56s} "
              f"x{ratio:.3f} (need >= x{need:.2f})  {flag}")
        if ratio < need:
            failures.append(
                f"scaling P={p} {u}: lookahead throughput only "
                f"x{ratio:.3f} of the plain schedule (gate: >= "
                f"x{need:.2f})")

    # (3) absolute wall time vs baseline, plain records as speed probe
    ratios = sorted(fresh[k]["seconds"] / b["seconds"]
                    for k, b in base.items()
                    if not k[2] and k in fresh and b["seconds"] > 0)
    speed = max(1.0, ratios[len(ratios) // 2]) if ratios else 1.0
    print(f"scaling runner speed (plain probe): x{speed:.2f} "
          "vs baseline machine")
    for k, b in sorted(base.items()):
        got = fresh.get(k)
        if got is None:
            print(f"note: scaling baseline record {k} missing from "
                  "fresh run")
            continue
        checked += 1
        t_lim = TIME_FACTOR * b["seconds"] * speed + TIME_SLACK
        flag = "ok" if got["seconds"] <= t_lim else "TIME REGRESSION"
        if got["seconds"] > t_lim:
            failures.append(
                f"scaling {k}: {got['seconds']:.3f}s > limit "
                f"{t_lim:.3f}s (baseline {b['seconds']:.3f}s)")
        print(f"{'scaling: ' + str(k):56s} t={got['seconds']:.3f}s"
              f"/{t_lim:.3f}s  {flag}")
    return checked


def gate_audit(fresh_path: Path, baseline_path: Path,
               failures: list) -> int:
    """Gate the static-audit findings (python -m repro.analysis --json).

    Both files are `repro.analysis.AuditReport` JSON; findings carry a
    line-number-stable ``ident`` (pass::context::file), so the diff
    below survives unrelated edits.  Three checks: (1) no fresh finding
    may be error-severity — errors never belong in a baseline; (2) every
    fresh ident must already exist in the baseline — a NEW finding, even
    an allowlist-waived one, fails until the baseline is refreshed
    deliberately; (3) every checker pass recorded in the baseline must
    have run fresh — a silently dropped pass would pass vacuously.
    """
    fresh = json.loads(fresh_path.read_text())
    base = json.loads(baseline_path.read_text())
    base_idents = {f["ident"] for f in base.get("findings", [])}
    checked = 0

    for f in fresh.get("findings", []):
        checked += 1
        flags = []
        if f["severity"] == "error":
            flags.append("AUDIT ERROR")
            failures.append(
                f"audit {f['ident']}: [{f['pass_id']}] {f['message']}")
        elif f["ident"] not in base_idents:
            flags.append("NEW FINDING")
            failures.append(
                f"audit {f['ident']}: finding not in the committed "
                "baseline — fix it, or re-baseline deliberately "
                "(check_regression docstring, 'Refresh the baselines')")
        print(f"{f['ident']:72s} [{f['severity']}"
              f"{'/waived' if f.get('waived') else ''}]  "
              f"{', '.join(flags) or 'ok'}")

    fresh_idents = {f["ident"] for f in fresh.get("findings", [])}
    for ident in sorted(base_idents - fresh_idents):
        print(f"note: baseline audit finding {ident} resolved in fresh "
              "run — refresh the baseline to lock the improvement in")

    fresh_passes = set(fresh.get("passes_run", []))
    for p in sorted(set(base.get("passes_run", [])) - fresh_passes):
        checked += 1
        failures.append(
            f"audit: pass {p!r} ran in the baseline but not fresh — a "
            "dropped pass gates nothing")
    checked += 1     # the error-free / coverage sweep itself
    return checked


def gate_kernels(fresh_path: Path, baseline_path: Path,
                 failures: list) -> int:
    """Gate the fused-kernel records (benchmarks.kernels_bench).

    Three checks per (n, kernel) group in the fresh run: (1) the fused
    leg keeps >= KERNEL_FUSED_MIN of the unfused leg's throughput — a
    ratio within one fresh run, so no machine calibration; (2) every f32
    fused leg reports rel_err exactly 0 against its unfused leg (fusion
    must be bit-identical — a nonzero value is a correctness bug, never
    a perf trade); (3) the bf16 route's rel_err stays under the
    documented KERNEL_BF16_ERR_MAX error model.  Absolute seconds are
    then floored against the committed baseline with the unfused rows
    as the runner-speed probe (code the fused kernels do not share, so
    a uniform fused regression cannot normalize itself away).
    """
    fresh = {(r["n"], r["kernel"], r["variant"]): r
             for r in json.loads(fresh_path.read_text())}
    base = {(r["n"], r["kernel"], r["variant"]): r
            for r in json.loads(baseline_path.read_text())}
    checked = 0

    groups = sorted({(n, kern) for (n, kern, _v) in fresh})
    for n, kern in groups:
        unf = fresh.get((n, kern, "unfused"))
        for variant in ("fused", "fused_bf16"):
            rec = fresh.get((n, kern, variant))
            if rec is None:
                continue
            flags = []
            checked += 1
            if unf is not None and rec["seconds"] > 0:
                ratio = unf["seconds"] / rec["seconds"]
                if ratio < KERNEL_FUSED_MIN:
                    flags.append("FUSED THROUGHPUT REGRESSION")
                    failures.append(
                        f"kernels ({n}, {kern}, {variant}): only "
                        f"x{ratio:.2f} the unfused leg's throughput "
                        f"(gate: >= x{KERNEL_FUSED_MIN})")
            else:
                ratio = float("nan")
            if variant == "fused" and rec["rel_err"] != 0.0:
                flags.append("FUSION CHANGED RESULTS")
                failures.append(
                    f"kernels ({n}, {kern}, fused): rel_err "
                    f"{rec['rel_err']:.3e} != 0 — f32 fusion must be "
                    "bit-identical to the unfused leg")
            if variant == "fused_bf16" \
                    and rec["rel_err"] > KERNEL_BF16_ERR_MAX:
                flags.append("BF16 ERROR MODEL EXCEEDED")
                failures.append(
                    f"kernels ({n}, {kern}, fused_bf16): rel_err "
                    f"{rec['rel_err']:.3e} > ceiling "
                    f"{KERNEL_BF16_ERR_MAX:.0e}")
            print(f"{f'kernels: ({n}, {kern}, {variant})':56s} "
                  f"x{ratio:.2f} vs unfused  "
                  f"err={rec['rel_err']:.2e}  "
                  f"{', '.join(flags) or 'ok'}")

    # absolute wall time vs baseline, unfused rows as the speed probe
    ratios = sorted(fresh[k]["seconds"] / b["seconds"]
                    for k, b in base.items()
                    if k[2] == "unfused" and k in fresh
                    and b["seconds"] > 0)
    speed = max(1.0, ratios[len(ratios) // 2]) if ratios else 1.0
    print(f"kernels runner speed (unfused probe): x{speed:.2f} "
          "vs baseline machine")
    for k, b in sorted(base.items()):
        got = fresh.get(k)
        if got is None:
            print(f"note: kernels baseline record {k} missing from "
                  "fresh run")
            continue
        checked += 1
        t_lim = TIME_FACTOR * b["seconds"] * speed + TIME_SLACK
        flag = "ok" if got["seconds"] <= t_lim else "TIME REGRESSION"
        if got["seconds"] > t_lim:
            failures.append(
                f"kernels {k}: {got['seconds']:.3f}s > limit "
                f"{t_lim:.3f}s (baseline {b['seconds']:.3f}s)")
        print(f"{'kernels: ' + str(k):56s} t={got['seconds']:.3f}s"
              f"/{t_lim:.3f}s  {flag}")
    return checked


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", type=Path,
                    default=BENCH_DIR / "estimators.json")
    ap.add_argument("--baseline", type=Path,
                    default=BENCH_DIR / "estimators_baseline.json")
    ap.add_argument("--condense-fresh", type=Path,
                    default=BENCH_DIR / "condense.json")
    ap.add_argument("--condense-baseline", type=Path,
                    default=BENCH_DIR / "condense_baseline.json")
    ap.add_argument("--skip-condense", action="store_true",
                    help="gate the estimator records only")
    ap.add_argument("--serve-fresh", type=Path,
                    default=BENCH_DIR / "serve.json")
    ap.add_argument("--serve-baseline", type=Path,
                    default=BENCH_DIR / "serve_baseline.json")
    ap.add_argument("--skip-serve", action="store_true",
                    help="skip the serving-path gate")
    ap.add_argument("--scaling", action="store_true",
                    help="gate ONLY the engine scaling records "
                         "(benchmarks.fig7_8 --measured) — the "
                         "multi-device CI leg's mode")
    ap.add_argument("--scaling-fresh", type=Path,
                    default=BENCH_DIR / "scaling.json")
    ap.add_argument("--scaling-baseline", type=Path,
                    default=BENCH_DIR / "scaling_baseline.json")
    ap.add_argument("--strict", action="store_true",
                    help="require lookahead >= plain throughput at "
                         "P >= 4 (real-interconnect runners; CI's "
                         "single-core fake devices use the overhead "
                         "thresholds)")
    ap.add_argument("--kernels", action="store_true",
                    help="gate ONLY the fused-kernel records "
                         "(benchmarks.kernels_bench) against the "
                         "committed kernels baseline")
    ap.add_argument("--kernels-fresh", type=Path,
                    default=BENCH_DIR / "kernels.json")
    ap.add_argument("--kernels-baseline", type=Path,
                    default=BENCH_DIR / "kernels_baseline.json")
    ap.add_argument("--audit", action="store_true",
                    help="gate ONLY the static-audit findings "
                         "(python -m repro.analysis --all --json) against "
                         "the committed audit baseline")
    ap.add_argument("--audit-fresh", type=Path,
                    default=BENCH_DIR / "audit.json")
    ap.add_argument("--audit-baseline", type=Path,
                    default=BENCH_DIR / "audit_baseline.json")
    args = ap.parse_args(argv)

    if args.audit:
        if not args.audit_fresh.exists():
            print(f"FAIL: {args.audit_fresh} missing — run "
                  "python -m repro.analysis --all --json "
                  f"{args.audit_fresh} before the gate")
            return 1
        if not args.audit_baseline.exists():
            print(f"FAIL: {args.audit_baseline} missing — commit a "
                  "baseline (check_regression docstring, 'Refresh the "
                  "baselines')")
            return 1
        failures = []
        checked = gate_audit(args.audit_fresh, args.audit_baseline,
                             failures)
        if failures:
            print(f"\nFAIL: {len(failures)} audit regression(s):")
            for f in failures:
                print(" -", f)
            return 1
        print(f"\nOK: {checked} audit checks within gates")
        return 0

    if args.kernels:
        if not args.kernels_fresh.exists():
            print(f"FAIL: {args.kernels_fresh} missing — run "
                  "benchmarks.kernels_bench before the gate")
            return 1
        if not args.kernels_baseline.exists():
            print(f"FAIL: {args.kernels_baseline} missing — commit a "
                  "baseline (check_regression docstring, 'Refresh the "
                  "baselines')")
            return 1
        failures = []
        checked = gate_kernels(args.kernels_fresh, args.kernels_baseline,
                               failures)
        if checked == 0:
            print("FAIL: fresh kernels run has none of the gated records")
            return 1
        if failures:
            print(f"\nFAIL: {len(failures)} kernel regression(s):")
            for f in failures:
                print(" -", f)
            return 1
        print(f"\nOK: {checked} kernel checks within gates")
        return 0

    if args.scaling:
        if not args.scaling_fresh.exists():
            print(f"FAIL: {args.scaling_fresh} missing — run "
                  "benchmarks.fig7_8 --measured before the gate")
            return 1
        if not args.scaling_baseline.exists():
            print(f"FAIL: {args.scaling_baseline} missing — commit a "
                  "baseline (docs/benchmarks.md, 'Re-baselining')")
            return 1
        failures: list = []
        checked = gate_scaling(args.scaling_fresh, args.scaling_baseline,
                               failures, strict=args.strict)
        if checked == 0:
            print("FAIL: fresh scaling run has none of the gated records")
            return 1
        if failures:
            print(f"\nFAIL: {len(failures)} regression(s):")
            for f in failures:
                print(" -", f)
            return 1
        print(f"\nOK: {checked} scaling checks within gates")
        return 0

    baseline = _load(args.baseline)
    fresh = _load(args.fresh)
    if not baseline:
        print(f"FAIL: no gated records (N in {GATED_N}) in {args.baseline}")
        return 1

    speed = speed_ratio(baseline, fresh)
    print(f"runner speed calibration: x{speed:.2f} vs baseline machine")

    failures: list = []
    compared = gate(baseline, fresh, speed, failures)
    checked = gate_metrics(fresh, failures)
    print(f"metrics gate: {checked} checks over fresh records")

    # ---- exact condensation routes (benchmarks.condense_bench) ----------
    if not args.skip_condense and args.condense_baseline.exists():
        if not args.condense_fresh.exists():
            print(f"FAIL: {args.condense_fresh} missing — run "
                  "benchmarks.condense_bench before the gate")
            return 1
        cond_base = _load(args.condense_baseline)
        cond_fresh = _load(args.condense_fresh)
        # runner-speed probe: ONLY the GE baseline rows.  GE shares no
        # code with the engine routes being gated, so a uniform engine
        # regression cannot normalize itself away (it would if cspeed
        # came from the median of the gated routes themselves).
        ratios = sorted(cond_fresh[k]["seconds"] / b["seconds"]
                        for k, b in cond_base.items()
                        if k[1] == "ge" and k in cond_fresh
                        and b["seconds"] > 0)
        cspeed = max(1.0, ratios[len(ratios) // 2]) if ratios else speed
        print(f"condense runner speed (ge probe): x{cspeed:.2f} "
              "vs baseline machine")
        compared += gate(cond_base, cond_fresh, cspeed, failures)

        # headline fused acceptance: the fused staged|panel route must
        # beat the committed UNFUSED staged|panel baseline by the
        # speedup floor at the large gated size (cspeed calibrates the
        # baseline's machine to this runner through the GE probe)
        kb = (CONDENSE_FUSED_N, "staged|panel", "dense", "fwd")
        kf = (CONDENSE_FUSED_N, "staged|panel|fused", "dense", "fwd")
        if kb in cond_base and kf in cond_fresh:
            compared += 1
            speedup = (cond_base[kb]["seconds"] * cspeed
                       / cond_fresh[kf]["seconds"])
            flag = ("ok" if speedup >= CONDENSE_FUSED_SPEEDUP_MIN
                    else "FUSED SPEEDUP REGRESSION")
            print(f"{f'condense: N={CONDENSE_FUSED_N} fused speedup':56s}"
                  f" x{speedup:.2f} "
                  f"(need >= x{CONDENSE_FUSED_SPEEDUP_MIN})  {flag}")
            if speedup < CONDENSE_FUSED_SPEEDUP_MIN:
                failures.append(
                    f"condense N={CONDENSE_FUSED_N}: fused staged|panel "
                    f"only x{speedup:.2f} the committed unfused baseline "
                    f"(gate: >= x{CONDENSE_FUSED_SPEEDUP_MIN})")

    # ---- serving path (benchmarks.serve_bench) --------------------------
    if not args.skip_serve and args.serve_baseline.exists():
        if not args.serve_fresh.exists():
            print(f"FAIL: {args.serve_fresh} missing — run "
                  "benchmarks.serve_bench before the gate")
            return 1
        compared += gate_serve(args.serve_fresh, args.serve_baseline,
                               failures)

    if compared == 0:
        print("FAIL: fresh run has none of the gated baseline records")
        return 1
    if failures:
        print(f"\nFAIL: {len(failures)} regression(s):")
        for f in failures:
            print(" -", f)
        return 1
    print(f"\nOK: {compared} records within gates")
    return 0


if __name__ == "__main__":
    sys.exit(main())
