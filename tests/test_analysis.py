"""repro.analysis — IR parser, checker passes, lint, audit drivers, CLI.

Three layers of proof:

  * **round-trip**: the committed HLO fixtures (serial / mesh / compiled
    lookahead lowerings) parse to byte-identical golden instruction
    tables, so a parser change that silently re-reads shapes or scopes
    shows up as a golden diff;
  * **mutation**: every registered pass FAILS on a deliberately broken
    program (an un-sliced tail all-gather, a callback left in obs-off
    HLO, a phantom lookahead stage, ...) and stays clean on the real
    lowering — a pass that cannot fail proves nothing;
  * **integration**: `LogdetPlan.audit()`, the allowlist round-trip, the
    AOT artifact audit, and the `python -m repro.analysis` exit codes.

Matrix sizes here (18/22) are unique to this file so module-level jit
caches never serve a stale trace from another test file.
"""
import dataclasses
import json
import pathlib
import re

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import obs
from repro.analysis import (
    AuditContext, AuditReport, Finding, PASSES, DEFAULT_PASS_IDS,
    apply_allowlist, audit_aot_dir, audit_artifact,
    expected_engine_stages, lint_source, lint_paths, load_allowlist,
    parse_module, run_passes,
)
from repro.analysis import DEFAULT_ALLOWLIST
from repro.analysis.ir import collective_payload_bytes
from repro.core.plan import plan as make_plan

FIXTURES = pathlib.Path(__file__).parent / "fixtures" / "hlo"
FIXTURE_NAMES = ("serial_rank1_stablehlo", "mesh_rank1_stablehlo",
                 "mesh_panel_lookahead_hlo")


@pytest.fixture(autouse=True)
def obs_off():
    """Tests below flip obs modes; never leak state into other files."""
    obs.configure("off")
    yield
    obs.configure("off")


def _fixture(name: str) -> str:
    return (FIXTURES / f"{name}.txt").read_text()


# =========================================================== parser: fixtures

@pytest.mark.parametrize("name", FIXTURE_NAMES)
def test_fixture_round_trip(name):
    """parse(fixture).dump() must equal the committed golden table."""
    got = parse_module(_fixture(name)).dump()
    want = (FIXTURES / f"{name}.golden.tsv").read_text()
    assert got == want, (
        f"{name}: parsed instruction table drifted from the golden — if "
        "the parser change is intentional, regenerate via "
        "tests/fixtures/hlo/regenerate.py and review the diff")


def test_fixture_dialects_and_content():
    serial = parse_module(_fixture("serial_rank1_stablehlo"))
    mesh = parse_module(_fixture("mesh_rank1_stablehlo"))
    hlo = parse_module(_fixture("mesh_panel_lookahead_hlo"))
    assert serial.dialect == mesh.dialect == "stablehlo"
    assert hlo.dialect == "hlo"
    # the mesh kernel's collectives survive normalization
    assert not serial.collectives()
    ops = {i.opcode.replace("-start", "") for i in mesh.collectives()}
    assert "all-gather" in ops and "all-reduce" in ops
    # compiled HLO carries the named-scope ancestry StableHLO lacks
    assert not serial.scope_names() and not mesh.scope_names()
    scopes = hlo.scope_names()
    for stage in ("engine.pivot", "engine.swap", "engine.update",
                  "engine.mesh_tail", "engine.broadcast",
                  "engine.lookahead_factor"):
        assert any(s == stage or s.endswith("/" + stage) or stage in s
                   for s in scopes) or stage in hlo.text, stage


# ========================================================= parser: edge cases

def test_hlo_tuple_of_tuple_and_token_shapes():
    txt = """HloModule t

ENTRY main {
  %p = f64[4]{0} parameter(0)
  %q = u1[2]{0} parameter(1)
  ROOT %t = ((f64[4]{0}, u1[2]{0}), token[]) tuple(%p, %q)
}
"""
    mod = parse_module(txt)
    assert mod.dialect == "hlo"
    t = mod.instructions[-1]
    assert t.opcode == "tuple"
    assert [s.dtype for s in t.result_shapes] == ["f64", "u1", "token"]
    # u1 occupies one unpacked byte, token none: 4*8 + 2*1 + 0
    assert t.result_bytes == 34
    assert t.operands == ("p", "q")


def test_mlir_token_dynamic_and_scalar_shapes():
    txt = """module @m {
  func.func public @main(%arg0: tensor<4x4xf32>) -> tensor<i1> {
    %0 = stablehlo.constant dense<true> : tensor<i1>
    %1 = stablehlo.create_token : !stablehlo.token
    %2 = stablehlo.custom_call @foo(%arg0) : (tensor<4x4xf32>) -> tensor<2x?xf64>
    return %0 : tensor<i1>
  }
}
"""
    mod = parse_module(txt)
    assert mod.dialect == "stablehlo"
    by_op = {i.opcode: i for i in mod.instructions}
    assert by_op["constant"].result_shapes[0].dtype == "pred"
    assert by_op["constant"].result_shapes[0].dims == ()
    assert by_op["create-token"].result_shapes[0].dtype == "token"
    assert by_op["create-token"].result_bytes == 0
    cc = by_op["custom-call"]
    assert cc.custom_call_target == "foo"
    assert cc.result_shapes[0].dims == (2, 0)       # dynamic dim -> 0
    assert cc.operand_shapes[0] .dims == (4, 4)


def test_custom_call_target_both_dialects():
    hlo = ('ENTRY e {\n  %c = f64[4]{0} custom-call(%a), '
           'custom_call_target="lapack_dgetrf_ffi"\n}\n')
    mlir = ('module @m {\n  %0 = stablehlo.custom_call @lapack_dgetrf_ffi'
            '(%arg0) : (tensor<4xf64>) -> tensor<4xf64>\n}\n')
    assert parse_module(hlo).custom_call_targets() == \
        {"lapack_dgetrf_ffi": 1}
    assert parse_module(mlir).custom_call_targets() == \
        {"lapack_dgetrf_ffi": 1}


def test_async_collective_pairs_count_once():
    txt = """HloModule a

ENTRY main {
  %p = f64[8]{0} parameter(0)
  %s = f64[16]{0} all-gather-start(%p), dimensions={0}
  ROOT %d = f64[16]{0} all-gather-done(%s)
}
"""
    mod = parse_module(txt)
    coll = mod.collectives()
    assert len(coll) == 1 and coll[0].opcode == "all-gather-start"
    # optimized HLO prints operands by NAME; payload resolution goes
    # through the symbol table, ring convention: received = out - in
    sizes = {i.name: i.result_bytes for i in mod.instructions}
    assert collective_payload_bytes(coll[0], sizes) == 8 * 8


def test_scope_ancestry_strips_wrappers():
    txt = ('  %x = f64[2]{0} add(%a, %b), metadata={op_name='
           '"jit(f)/jit(main)/while/body/engine.update/add"}\n')
    (i,) = parse_module("ENTRY e {\n" + txt + "}\n").instructions
    assert i.scopes == ("engine.update",)
    assert i.in_scope("engine.update")
    assert not i.in_scope("engine.pivot")


# ================================================== passes: mutation proofs

MESH_CTX = AuditContext(label="mesh|rank1 fwd", method="exact",
                        schedule="mesh", update="rank1", n=16, devices=1,
                        itemsize=8)


def test_payload_budget_clean_on_real_mesh_lowering():
    r = run_passes(_fixture("mesh_rank1_stablehlo"), MESH_CTX,
                   ("collective-payload-budget",))
    assert r.ok, r.summary()


def test_payload_budget_fails_on_unsliced_tail_gather():
    """Mutation: re-widen the tail all-gather to full (N,) rows — the
    pre-PR-8 wire bug — and the budget pass must trip."""
    broken = "\n".join(
        ln.replace("1x1xf64", "1x16xf64") if "all_gather" in ln else ln
        for ln in _fixture("mesh_rank1_stablehlo").splitlines())
    r = run_passes(broken, MESH_CTX, ("collective-payload-budget",))
    assert not r.ok
    assert any("all-gather" in f.message and "analytic bound" in f.message
               for f in r.errors)


def test_payload_budget_only_applies_to_mesh_schedule():
    broken = _fixture("mesh_rank1_stablehlo").replace("1x1xf64", "1x16xf64")
    ctx = dataclasses.replace(MESH_CTX, schedule="serial")
    assert run_passes(broken, ctx, ("collective-payload-budget",)).ok


def test_no_host_callback_catches_leaked_telemetry():
    """Mutation pair: the SAME chebyshev program lowered under obs=trace
    is an error for an obs-off context and legitimate for a trace one —
    this is tests/test_obs.py's grep as a reusable pass."""
    from repro.estimators.chebyshev import logdet_chebyshev

    obs.configure("trace")
    a = jax.ShapeDtypeStruct((18, 18), jnp.float64)
    txt = jax.jit(
        lambda x: logdet_chebyshev(x, degree=8, num_probes=4)[0]
    ).lower(a).as_text()
    obs.configure("off")

    leaked = run_passes(txt, AuditContext(method="chebyshev",
                                          obs_mode="off"),
                        ("no-host-callback",))
    assert not leaked.ok
    assert all(f.pass_id == "no-host-callback" for f in leaked.errors)
    legit = run_passes(txt, AuditContext(method="chebyshev",
                                         obs_mode="trace"),
                       ("no-host-callback",))
    assert legit.ok


def test_no_host_callback_flags_host_transfer_ops():
    txt = ("ENTRY e {\n  %o = token[] outfeed(%a, %t)\n}\n")
    r = run_passes(txt, AuditContext(obs_mode="off"), ("no-host-callback",))
    assert not r.ok and "outfeed" in r.errors[0].message


DENSE_HLO = """HloModule d

ENTRY main {
  %p = f64[16,16]{1,0} parameter(0)
  %f = (f64[16,16]{1,0}, s32[16]{0}) custom-call(%p), custom_call_target="lapack_dgetrf_ffi"
  ROOT %r = f64[16,16]{1,0} get-tuple-element(%f), index=0
}
"""


def test_no_dense_factorization_fails_on_lapack_call():
    r = run_passes(DENSE_HLO, AuditContext(method="slq", matrix_free=True),
                   ("no-dense-factorization",))
    assert not r.ok and "lapack_dgetrf_ffi" in r.errors[0].message


def test_no_dense_factorization_flags_structural_ops_too():
    txt = ("ENTRY e {\n  %c = f64[8,8]{1,0} cholesky(%a)\n"
           "  %s = f64[8,8]{1,0} triangular-solve(%c, %b), lower=true\n}\n")
    r = run_passes(txt, AuditContext(matrix_free=True),
                   ("no-dense-factorization",))
    assert len(r.errors) == 2


def test_no_dense_factorization_allows_exact_plans():
    """The exact route is ENTITLED to factorize — the pass keys off the
    matrix-free claim, so the same text is clean for an exact context."""
    r = run_passes(DENSE_HLO, AuditContext(method="exact",
                                           matrix_free=False),
                   ("no-dense-factorization",))
    assert r.ok


UPCAST_MLIR = """module @m {
  func.func public @main(%arg0: tensor<4x4xf32>) -> tensor<4x4xf64> {
    %0 = stablehlo.convert %arg0 : (tensor<4x4xf32>) -> tensor<4x4xf64>
    return %0 : tensor<4x4xf64>
  }
}
"""


def test_dtype_discipline_fails_on_silent_upcast():
    r = run_passes(UPCAST_MLIR, AuditContext(dtype="float32"),
                   ("dtype-discipline",))
    assert not r.ok and "upcast" in r.errors[0].message


def test_dtype_discipline_entitles_f64_plans():
    assert run_passes(UPCAST_MLIR, AuditContext(dtype="float64"),
                      ("dtype-discipline",)).ok


LA_CTX = AuditContext(label="mesh|panel|la fwd", method="exact",
                      schedule="mesh", update="panel", panel_k=4,
                      lookahead=True, n=16, devices=1)


def test_stage_coverage_clean_on_real_lookahead_program():
    r = run_passes(_fixture("mesh_panel_lookahead_hlo"), LA_CTX,
                   ("stage-coverage",))
    assert r.ok, r.summary()


def test_stage_coverage_fails_on_phantom_stage():
    """Mutation: claim lookahead=False against a program that DOES carry
    the pipelined stage — the inverse of the inert-flag bug."""
    ctx = dataclasses.replace(LA_CTX, lookahead=False)
    r = run_passes(_fixture("mesh_panel_lookahead_hlo"), ctx,
                   ("stage-coverage",))
    assert not r.ok
    assert any(f.where == "engine.lookahead_factor" and
               "forbid" in f.message for f in r.errors)


def test_stage_coverage_fails_on_missing_stages():
    """Mutation: a scope-free program (StableHLO never prints scopes)
    audited as a compiled serial engine must report every missing stage —
    the inert-flag bug class itself."""
    ctx = AuditContext(method="exact", schedule="serial", update="rank1",
                       n=16)
    r = run_passes(_fixture("serial_rank1_stablehlo"), ctx,
                   ("stage-coverage",))
    missing = sorted(f.where for f in r.errors)
    assert missing == ["engine.pivot", "engine.swap", "engine.update"]
    assert all("inert" in f.message for f in r.errors)


def test_stage_coverage_skips_estimators_without_explicit_map():
    r = run_passes(_fixture("serial_rank1_stablehlo"),
                   AuditContext(method="slq", n=16), ("stage-coverage",))
    assert r.ok and not r.findings


def test_expected_engine_stages_geometry():
    base = dict(method="exact", n=32, devices=1, panel_k=8)
    serial = expected_engine_stages(AuditContext(
        schedule="serial", update="rank1", **base))
    assert serial["engine.pivot"] and not serial["engine.mesh_tail"]
    assert not serial["engine.lookahead_factor"]
    # pipelined rank-1: pivot selection is subsumed into the lookahead
    # factorization — expecting a separate pivot phase would be wrong
    la_r1 = expected_engine_stages(AuditContext(
        schedule="mesh", update="rank1", lookahead=True, **base))
    assert la_r1["engine.lookahead_factor"] and not la_r1["engine.pivot"]
    # ...but at P >= 2 the (P, P) tail's serial condensation step brings
    # the pivot scope back
    la_r1_p8 = expected_engine_stages(AuditContext(
        schedule="mesh", update="rank1", lookahead=True, method="exact",
        n=32, devices=8, panel_k=8))
    assert la_r1_p8["engine.pivot"]
    # panel keeps its pivot; its loop only traces with > one full panel
    la_pn = expected_engine_stages(AuditContext(
        schedule="mesh", update="panel", lookahead=True, **base))
    assert la_pn["engine.lookahead_factor"] and la_pn["engine.pivot"]
    tiny = expected_engine_stages(AuditContext(
        schedule="mesh", update="panel", lookahead=True, method="exact",
        n=8, devices=1, panel_k=8))
    assert not tiny["engine.lookahead_factor"]


def test_exportable_custom_calls_policy():
    cb = ('module @m {\n  %0 = stablehlo.custom_call '
          '@xla_python_cpu_callback(%arg0) : (tensor<4xf64>) -> '
          'tensor<4xf64>\n}\n')
    legacy = cb.replace("xla_python_cpu_callback", "lapack_dgetrf")
    ffi = cb.replace("xla_python_cpu_callback", "lapack_dgetrf_ffi")
    exp = AuditContext(kind="export")
    # python callbacks and legacy opaque-pointer calls block the export;
    # registry-resolved *_ffi targets survive by name
    assert not run_passes(cb, exp, ("exportable-custom-calls",)).ok
    assert not run_passes(legacy, exp, ("exportable-custom-calls",)).ok
    assert run_passes(ffi, exp, ("exportable-custom-calls",)).ok
    # the pass only screens export lowerings
    assert run_passes(cb, AuditContext(kind="forward"),
                      ("exportable-custom-calls",)).ok


def test_run_passes_labels_and_registry():
    assert set(DEFAULT_PASS_IDS) <= set(PASSES)
    r = run_passes(DENSE_HLO, dataclasses.replace(MESH_CTX, label="lbl"),
                   ("no-dense-factorization",))
    assert r.passes_run == ["no-dense-factorization"]
    assert r.contexts == ["lbl"]


# ================================================== report + allowlist

def test_finding_ident_is_line_stable():
    a = Finding(pass_id="p", severity="error", message="m",
                where="src/x.py:12", context="lint")
    b = dataclasses.replace(a, where="src/x.py:99", message="other words")
    assert a.ident == b.ident == "p::lint::src/x.py"
    with pytest.raises(ValueError, match="severity"):
        Finding(pass_id="p", severity="fatal", message="m")


def test_report_json_round_trip():
    r = AuditReport(findings=[
        Finding(pass_id="p", severity="warning", message="m", where="w",
                context="c", code="snippet")],
        passes_run=["p"], contexts=["c"], meta={"k": 1})
    r2 = AuditReport.from_json(r.to_json())
    assert r2.findings == r.findings
    assert r2.passes_run == ["p"] and r2.meta == {"k": 1}
    assert json.loads(r.to_json())["ok"] is True   # warnings don't fail
    assert r.summary().startswith("audit: 1 finding(s)")


def test_allowlist_load_apply_and_reject(tmp_path):
    toml = tmp_path / "allow.toml"
    toml.write_text(
        '# waivers\n'
        '[[timing-no-block]]\n'
        'where = "src/launch/*.py:*"\n'
        'code = "run_cell"\n'
        'reason = "times compilation on purpose"\n')
    allow = load_allowlist(toml)
    assert list(allow) == ["timing-no-block"]

    hit = Finding(pass_id="timing-no-block", severity="error", message="m",
                  where="src/launch/dryrun.py:40", context="lint",
                  code="run_cell")
    miss_where = dataclasses.replace(hit, where="src/core/plan.py:40")
    miss_code = dataclasses.replace(hit, code="other_fn")
    report = apply_allowlist(
        AuditReport(findings=[hit, miss_where, miss_code]), allow)
    waived, kept_w, kept_c = report.findings
    assert waived.waived and waived.severity == "info" \
        and "[waived: times compilation on purpose]" in waived.message
    assert not kept_w.waived and kept_w.severity == "error"
    assert not kept_c.waived
    assert not report.ok          # the unmatched errors still fail

    # a reason-less waiver must refuse to load — never silently accept
    toml.write_text('[[x]]\nwhere = "*"\n')
    with pytest.raises(ValueError, match="reason"):
        load_allowlist(toml)
    # and so must a typo'd line (it would otherwise widen the waiver)
    toml.write_text('[[x]]\nreason = unquoted\n')
    with pytest.raises(ValueError, match="unparseable"):
        load_allowlist(toml)
    assert load_allowlist(tmp_path / "absent.toml") == {}


def test_committed_allowlist_is_valid():
    allow = load_allowlist(DEFAULT_ALLOWLIST)
    assert allow, "committed allowlist unexpectedly empty"
    for pid, entries in allow.items():
        assert pid in tuple(PASSES) + (
            "unused-config-kwarg", "implicit-dtype", "timing-no-block",
            "deprecated-route"), pid
        for e in entries:
            assert e["reason"].strip()


# ================================================================== lint

def test_lint_unused_config_kwarg():
    bad = ("def f(a, *, lookahead=False):\n"
           "    return a + 1\n")
    (f,) = lint_source(bad, "m.py", rules=("unused-config-kwarg",))
    assert "lookahead" in f.message and f.where == "m.py:1"
    good = ("def f(a, *, lookahead=False):\n"
            "    return a + int(lookahead)\n")
    assert not lint_source(good, "m.py", rules=("unused-config-kwarg",))
    # stubs and _-prefixed sinks are exempt by design
    stub = ("def f(a, *, lookahead=False):\n"
            "    raise NotImplementedError\n")
    sink = ("def f(a, *, _unused=False):\n"
            "    return a\n")
    assert not lint_source(stub, "m.py", rules=("unused-config-kwarg",))
    assert not lint_source(sink, "m.py", rules=("unused-config-kwarg",))


def test_lint_implicit_dtype():
    bad = "x = jnp.zeros((4, 4))\n"
    (f,) = lint_source(bad, "m.py", rules=("implicit-dtype",))
    assert "dtype" in f.message
    assert not lint_source("x = jnp.zeros((4, 4), dtype=a.dtype)\n",
                           "m.py", rules=("implicit-dtype",))
    assert not lint_source("x = jnp.zeros((4, 4), jnp.float32)\n",
                           "m.py", rules=("implicit-dtype",))
    assert not lint_source("x = jnp.zeros_like(a)\n",
                           "m.py", rules=("implicit-dtype",))
    assert not lint_source("x = np.zeros((4, 4))\n",
                           "m.py", rules=("implicit-dtype",))


def test_lint_timing_no_block():
    bad = ("def bench(f, a):\n"
           "    t0 = time.perf_counter()\n"
           "    f(a)\n"
           "    return time.perf_counter() - t0\n")
    (f,) = lint_source(bad, "m.py", rules=("timing-no-block",))
    assert "block_until_ready" in f.message
    good = bad.replace("    f(a)\n",
                       "    jax.block_until_ready(f(a))\n")
    assert not lint_source(good, "m.py", rules=("timing-no-block",))
    single = ("def stamp():\n"
              "    return time.perf_counter()\n")
    assert not lint_source(single, "m.py", rules=("timing-no-block",))


def test_lint_deprecated_route():
    bad = "r = slogdet(a, method='pmc')\n"
    (f,) = lint_source(bad, "launch/train.py",
                       rules=("deprecated-route",))
    assert "'pmc'" in f.message
    assert not lint_source(bad, "core/api.py",
                           rules=("deprecated-route",))
    assert not lint_source("r = slogdet(a, method='exact')\n",
                           "launch/train.py", rules=("deprecated-route",))


def test_lint_paths_reports_syntax_errors(tmp_path):
    (tmp_path / "ok.py").write_text("x = 1\n")
    (tmp_path / "broken.py").write_text("def f(:\n")
    report = lint_paths([tmp_path], root=tmp_path)
    assert [f.where for f in report.errors] == ["broken.py"]
    assert "unparseable" in report.errors[0].message


def test_repo_lint_is_clean_under_committed_allowlist():
    """Satellite (b)'s acceptance, as a test: the shipped source tree
    lints clean once the committed waivers apply."""
    import repro
    pkg = pathlib.Path(repro.__file__).resolve().parent
    report = apply_allowlist(
        lint_paths([pkg], root=pkg.parent),
        load_allowlist(DEFAULT_ALLOWLIST))
    assert report.ok, report.summary()


# ====================================================== audit integration

def test_plan_audit_serial_exact_clean():
    p = make_plan((18, 18), method="exact", schedule="serial",
                  update="rank1")
    report = p.audit()
    assert report.ok, report.summary()
    assert set(report.passes_run) == set(DEFAULT_PASS_IDS)
    assert report.contexts and "exact:serial/rank1" in report.contexts[0]


def test_plan_audit_mesh_lookahead_clean(mesh1):
    p = make_plan((22, 22), method="exact", schedule="mesh",
                  update="rank1", lookahead=True, mesh=mesh1)
    report = p.audit()
    assert report.ok, report.summary()


def test_plan_audit_estimator_with_grad_is_matrix_free():
    """tests/test_grad.py's dense-solve grep, via the shared pass — and
    include_grad covers the backward lowering too."""
    p = make_plan((18, 18), method="chebyshev", degree=8, num_probes=4,
                  seed=0, grad=True)
    report = p.audit(passes=["no-dense-factorization", "no-host-callback"],
                     include_grad=True)
    assert report.ok, report.summary()
    labels = report.contexts
    assert any("backward" in c for c in labels), labels


def test_plan_audit_pass_subset_respected():
    p = make_plan((18, 18), method="exact", schedule="serial")
    report = p.audit(passes=["no-host-callback"])
    assert report.passes_run == ["no-host-callback"]


def test_aot_artifact_audit_round_trip(tmp_path):
    p = make_plan((18, 18), method="exact", schedule="serial")
    path = str(tmp_path / "serial.reproplan")
    p.export(path)
    report = audit_artifact(path)
    assert report.ok, report.summary()
    assert "exportable-custom-calls" in report.passes_run
    assert "stage-coverage" not in report.passes_run   # post-fusion text

    dir_report = audit_aot_dir(tmp_path)
    assert dir_report.meta["artifacts"] == 1 and dir_report.ok


def test_aot_dir_audit_warns_when_empty(tmp_path):
    report = audit_aot_dir(tmp_path)
    assert report.ok                       # warning, not error
    assert any(f.pass_id == "aot-scan" for f in report.warnings)


# ================================================================== CLI

def _cli(argv):
    from repro.analysis.__main__ import main
    return main(argv)


def test_cli_lint_exit_codes(tmp_path, capsys):
    bad = tmp_path / "mod.py"
    bad.write_text("import jax.numpy as jnp\nx = jnp.zeros((4,))\n")
    assert _cli(["--lint", "--src", str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "implicit-dtype" in out

    waiver = tmp_path / "allow.toml"
    waiver.write_text('[[implicit-dtype]]\nwhere = "*mod.py:*"\n'
                      'reason = "test fixture"\n')
    assert _cli(["--lint", "--src", str(tmp_path),
                 "--allowlist", str(waiver)]) == 0

    bad.write_text("x = 1\n")
    assert _cli(["--lint", "--src", str(tmp_path)]) == 0


def test_cli_json_artifact(tmp_path, capsys):
    src = tmp_path / "clean.py"
    src.write_text("x = 1\n")
    out = tmp_path / "report.json"
    assert _cli(["--lint", "--src", str(tmp_path),
                 "--json", str(out)]) == 0
    payload = json.loads(out.read_text())
    assert payload["ok"] is True and "passes_run" in payload
    capsys.readouterr()


def test_cli_rejects_unknown_pass(tmp_path):
    with pytest.raises(SystemExit):
        _cli(["--lint", "--src", str(tmp_path), "--passes", "nope"])


def test_cli_requires_a_mode():
    with pytest.raises(SystemExit):
        _cli([])
