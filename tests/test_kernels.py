"""Per-kernel shape/dtype sweeps: Pallas (interpret on CPU) vs ref.py oracle,
plus integration through the condensation algorithms."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import slogdet_condense, slogdet_condense_blocked
from repro.kernels import ops, ref
from repro.kernels.condense_step import rank1_update_pallas
from repro.kernels.panel_update import panel_update_pallas

SHAPES_R1 = [(8, 8), (64, 64), (100, 130), (256, 512), (33, 257)]
SHAPES_PK = [(8, 8, 4), (64, 64, 8), (100, 130, 16), (256, 300, 32)]
DTYPES = [np.float32, np.float64]


def _tol(dt):
    return dict(rtol=2e-5, atol=2e-5) if dt == np.float32 else dict(rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("shape", SHAPES_R1)
@pytest.mark.parametrize("dt", DTYPES)
def test_rank1_update_sweep(shape, dt, rng):
    m, n = shape
    a = rng.standard_normal((m, n)).astype(dt)
    pc = rng.standard_normal((m,)).astype(dt)
    pr = rng.standard_normal((n,)).astype(dt)
    got = rank1_update_pallas(a, pc, pr, interpret=True)
    np.testing.assert_allclose(np.asarray(got), ref.rank1_update_ref(a, pc, pr),
                               **_tol(dt))


@pytest.mark.parametrize("shape", SHAPES_PK)
@pytest.mark.parametrize("dt", DTYPES)
def test_panel_update_sweep(shape, dt, rng):
    m, n, k = shape
    a = rng.standard_normal((m, n)).astype(dt)
    c = rng.standard_normal((m, k)).astype(dt)
    r = rng.standard_normal((k, n)).astype(dt)
    got = panel_update_pallas(a, c, r, interpret=True)
    np.testing.assert_allclose(np.asarray(got), ref.panel_update_ref(a, c, r),
                               **_tol(dt))


@pytest.mark.parametrize("bm,bn", [(8, 128), (16, 256), (256, 512)])
def test_rank1_block_shapes(bm, bn, rng):
    """Block-shape sweep: result must not depend on tiling."""
    a = rng.standard_normal((300, 520)).astype(np.float32)
    pc = rng.standard_normal((300,)).astype(np.float32)
    pr = rng.standard_normal((520,)).astype(np.float32)
    got = rank1_update_pallas(a, pc, pr, bm=bm, bn=bn, interpret=True)
    np.testing.assert_allclose(np.asarray(got), ref.rank1_update_ref(a, pc, pr),
                               rtol=2e-5, atol=2e-5)


def test_bf16_panel(rng):
    a = rng.standard_normal((64, 64)).astype(jnp.bfloat16)
    c = rng.standard_normal((64, 16)).astype(jnp.bfloat16)
    r = rng.standard_normal((16, 64)).astype(jnp.bfloat16)
    got = panel_update_pallas(a, c, r, interpret=True)
    want = ref.panel_update_ref(a.astype(np.float32), c.astype(np.float32),
                                r.astype(np.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               rtol=0.1, atol=0.5)


def test_kernel_in_condense(rng):
    a = rng.standard_normal((32, 32))
    s_ref, ld_ref = np.linalg.slogdet(a)
    s, ld = slogdet_condense(a, use_kernel=True)
    assert float(s) == pytest.approx(s_ref)
    np.testing.assert_allclose(float(ld), ld_ref, rtol=1e-9)


def test_kernel_in_blocked(rng):
    a = rng.standard_normal((48, 48))
    s_ref, ld_ref = np.linalg.slogdet(a)
    s, ld = slogdet_condense_blocked(a, k=16, use_kernel=True)
    assert float(s) == pytest.approx(s_ref)
    np.testing.assert_allclose(float(ld), ld_ref, rtol=1e-9)


@pytest.mark.parametrize("k,n,m0", [(4, 32, 32), (8, 64, 50), (16, 128, 128),
                                    (16, 256, 200)])
def test_panel_factor_vmem_matches_oracle(k, n, m0, rng):
    """VMEM-resident Pallas panel factorization == core.blocked.panel_factor."""
    from repro.core.blocked import panel_factor
    from repro.kernels.panel_factor import panel_factor_pallas
    panel = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    R1, ls1, s1, ld1 = panel_factor(panel, m0, r_pos=5)
    R2, ls2, s2, ld2 = panel_factor_pallas(panel, m0, 5, interpret=True)
    np.testing.assert_array_equal(np.asarray(R1), np.asarray(R2))
    assert (np.asarray(ls1) == np.asarray(ls2)).all()
    assert float(s1) == float(s2)
    np.testing.assert_allclose(float(ld1), float(ld2), rtol=0)


def test_panel_factor_vmem_budget():
    from repro.kernels.panel_factor import panel_factor_pallas
    big = jnp.zeros((64, 65536), jnp.float32)        # 16 MiB > budget
    with pytest.raises(ValueError, match="VMEM"):
        panel_factor_pallas(big, 65536, interpret=True)
