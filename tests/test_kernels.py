"""Per-kernel shape/dtype sweeps: Pallas (interpret on CPU) vs ref.py oracle,
plus integration through the condensation algorithms."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import slogdet_condense, slogdet_condense_blocked
from repro.kernels import ops, ref
from repro.kernels.condense_step import rank1_update_pallas
from repro.kernels.panel_update import panel_update_pallas

SHAPES_R1 = [(8, 8), (64, 64), (100, 130), (256, 512), (33, 257)]
SHAPES_PK = [(8, 8, 4), (64, 64, 8), (100, 130, 16), (256, 300, 32)]
DTYPES = [np.float32, np.float64]


def _tol(dt):
    return dict(rtol=2e-5, atol=2e-5) if dt == np.float32 else dict(rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("shape", SHAPES_R1)
@pytest.mark.parametrize("dt", DTYPES)
def test_rank1_update_sweep(shape, dt, rng):
    m, n = shape
    a = rng.standard_normal((m, n)).astype(dt)
    pc = rng.standard_normal((m,)).astype(dt)
    pr = rng.standard_normal((n,)).astype(dt)
    got = rank1_update_pallas(a, pc, pr, interpret=True)
    np.testing.assert_allclose(np.asarray(got), ref.rank1_update_ref(a, pc, pr),
                               **_tol(dt))


@pytest.mark.parametrize("shape", SHAPES_PK)
@pytest.mark.parametrize("dt", DTYPES)
def test_panel_update_sweep(shape, dt, rng):
    m, n, k = shape
    a = rng.standard_normal((m, n)).astype(dt)
    c = rng.standard_normal((m, k)).astype(dt)
    r = rng.standard_normal((k, n)).astype(dt)
    got = panel_update_pallas(a, c, r, interpret=True)
    np.testing.assert_allclose(np.asarray(got), ref.panel_update_ref(a, c, r),
                               **_tol(dt))


@pytest.mark.parametrize("bm,bn", [(8, 128), (16, 256), (256, 512)])
def test_rank1_block_shapes(bm, bn, rng):
    """Block-shape sweep: result must not depend on tiling."""
    a = rng.standard_normal((300, 520)).astype(np.float32)
    pc = rng.standard_normal((300,)).astype(np.float32)
    pr = rng.standard_normal((520,)).astype(np.float32)
    got = rank1_update_pallas(a, pc, pr, bm=bm, bn=bn, interpret=True)
    np.testing.assert_allclose(np.asarray(got), ref.rank1_update_ref(a, pc, pr),
                               rtol=2e-5, atol=2e-5)


def test_bf16_panel(rng):
    a = rng.standard_normal((64, 64)).astype(jnp.bfloat16)
    c = rng.standard_normal((64, 16)).astype(jnp.bfloat16)
    r = rng.standard_normal((16, 64)).astype(jnp.bfloat16)
    got = panel_update_pallas(a, c, r, interpret=True)
    want = ref.panel_update_ref(a.astype(np.float32), c.astype(np.float32),
                                r.astype(np.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               rtol=0.1, atol=0.5)


def test_kernel_in_condense(rng):
    a = rng.standard_normal((32, 32))
    s_ref, ld_ref = np.linalg.slogdet(a)
    s, ld = slogdet_condense(a, use_kernel=True)
    assert float(s) == pytest.approx(s_ref)
    np.testing.assert_allclose(float(ld), ld_ref, rtol=1e-9)


def test_kernel_in_blocked(rng):
    a = rng.standard_normal((48, 48))
    s_ref, ld_ref = np.linalg.slogdet(a)
    s, ld = slogdet_condense_blocked(a, k=16, use_kernel=True)
    assert float(s) == pytest.approx(s_ref)
    np.testing.assert_allclose(float(ld), ld_ref, rtol=1e-9)


@pytest.mark.parametrize("k,n,m0", [(4, 32, 32), (8, 64, 50), (16, 128, 128),
                                    (16, 256, 200)])
def test_panel_factor_vmem_matches_oracle(k, n, m0, rng):
    """VMEM-resident Pallas panel factorization == core.blocked.panel_factor."""
    from repro.core.blocked import panel_factor
    from repro.kernels.panel_factor import panel_factor_pallas
    panel = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    R1, ls1, s1, ld1 = panel_factor(panel, m0, r_pos=5)
    R2, ls2, s2, ld2 = panel_factor_pallas(panel, m0, 5, interpret=True)
    np.testing.assert_array_equal(np.asarray(R1), np.asarray(R2))
    assert (np.asarray(ls1) == np.asarray(ls2)).all()
    assert float(s1) == float(s2)
    np.testing.assert_allclose(float(ld1), float(ld2), rtol=0)


def test_panel_factor_vmem_budget():
    from repro.kernels.panel_factor import panel_factor_pallas
    big = jnp.zeros((64, 65536), jnp.float32)        # 16 MiB > budget
    with pytest.raises(ValueError, match="VMEM"):
        panel_factor_pallas(big, 65536, interpret=True)


# ----------------------------------------------- kernel <-> reference parity
# Interpret-mode sweeps at adversarial geometry: nothing a multiple of the
# (8, 128) f32 VREG tile, K not a multiple of 128, low-precision dtypes.

ODD_SHAPES_R1 = [(1, 1), (7, 129), (129, 7), (255, 383), (130, 130)]
ODD_SHAPES_PK = [(7, 129, 3), (65, 190, 33), (129, 257, 100), (50, 61, 50)]


@pytest.mark.parametrize("shape", ODD_SHAPES_R1)
def test_rank1_update_non_tile_multiple(shape, rng):
    m, n = shape
    a = rng.standard_normal((m, n)).astype(np.float32)
    pc = rng.standard_normal((m,)).astype(np.float32)
    pr = rng.standard_normal((n,)).astype(np.float32)
    got = rank1_update_pallas(a, pc, pr, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               ref.rank1_update_ref(a, pc, pr),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", ODD_SHAPES_PK)
def test_panel_update_k_not_multiple_of_128(shape, rng):
    m, n, k = shape
    a = rng.standard_normal((m, n)).astype(np.float32)
    c = rng.standard_normal((m, k)).astype(np.float32)
    r = rng.standard_normal((k, n)).astype(np.float32)
    got = panel_update_pallas(a, c, r, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               ref.panel_update_ref(a, c, r),
                               rtol=2e-4, atol=2e-4)


def test_rank1_update_bf16(rng):
    a = rng.standard_normal((33, 257)).astype(jnp.bfloat16)
    pc = rng.standard_normal((33,)).astype(jnp.bfloat16)
    pr = rng.standard_normal((257,)).astype(jnp.bfloat16)
    got = rank1_update_pallas(a, pc, pr, interpret=True)
    want = ref.rank1_update_ref(a.astype(np.float32), pc.astype(np.float32),
                                pr.astype(np.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               rtol=0.1, atol=0.1)


@pytest.mark.parametrize("k,n,m0", [(3, 33, 33), (5, 129, 100), (16, 200, 170)])
@pytest.mark.parametrize("dt", [np.float32, np.float64])
def test_panel_factor_non_tile_multiple(k, n, m0, dt, rng):
    from repro.core.engine import panel_factor
    from repro.kernels.panel_factor import panel_factor_pallas
    panel = jnp.asarray(rng.standard_normal((k, n)), dt)
    R1, ls1, s1, ld1 = panel_factor(panel, m0, r_pos=3)
    R2, ls2, s2, ld2 = panel_factor_pallas(panel, m0, 3, interpret=True)
    np.testing.assert_array_equal(np.asarray(R1), np.asarray(R2))
    assert (np.asarray(ls1) == np.asarray(ls2)).all()
    assert float(s1) == float(s2)
    np.testing.assert_allclose(float(ld1), float(ld2), rtol=0)


# ------------------------------------------------- backend dispatch (env)

def test_kernel_backend_env_override(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    assert ops.kernel_backend() in ops.KERNEL_BACKENDS
    for b in ops.KERNEL_BACKENDS:
        monkeypatch.setenv("REPRO_KERNEL_BACKEND", b)
        assert ops.kernel_backend() == b
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "metal")
    with pytest.raises(ValueError, match="REPRO_KERNEL_BACKEND"):
        ops.kernel_backend()


@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_ops_dispatch_agrees_across_backends(backend, monkeypatch, rng):
    """Forcing the env override must not change results — deterministic
    interpret-mode kernel coverage on CPU CI."""
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", backend)
    a = rng.standard_normal((33, 65)).astype(np.float32)
    pc = rng.standard_normal((33,)).astype(np.float32)
    pr = rng.standard_normal((65,)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(ops.rank1_update(a, pc, pr)),
                               ref.rank1_update_ref(a, pc, pr),
                               rtol=2e-5, atol=2e-5)
    c = rng.standard_normal((33, 5)).astype(np.float32)
    r = rng.standard_normal((5, 65)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(ops.panel_update(a, c, r)),
                               ref.panel_update_ref(a, c, r),
                               rtol=2e-5, atol=2e-5)
    x = rng.standard_normal((65, 3)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(ops.matvec(a, x)),
                               np.asarray(ref.matvec_ref(a, x)),
                               rtol=2e-4, atol=2e-4)
    panel = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
    R, ls, s, ld = ops.panel_factor_vmem(panel, 32)
    from repro.core.engine import panel_factor
    R_ref, ls_ref, s_ref, ld_ref = panel_factor(panel, 32)
    np.testing.assert_allclose(np.asarray(R), np.asarray(R_ref),
                               rtol=1e-6, atol=1e-6)
    assert (np.asarray(ls) == np.asarray(ls_ref)).all()


def test_use_kernel_runs_the_pallas_body_off_tpu(monkeypatch, rng):
    """use_kernel=True is an explicit kernel request: off-TPU it must run
    the Pallas kernel body in interpret mode, never silently fall through
    to the jnp reference (regression: the dispatch rewrite briefly routed
    it to ref.rank1_update_ref on CPU)."""
    import repro.kernels.ops as ops_mod
    calls = []
    real = ops_mod.rank1_update_pallas

    def spy(*a, **k):
        calls.append(k.get("interpret"))
        return real(*a, **k)

    monkeypatch.setattr(ops_mod, "rank1_update_pallas", spy)
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    a = rng.standard_normal((13, 13))          # fresh shape: forces a trace
    s, ld = slogdet_condense(a, use_kernel=True)
    assert calls, "use_kernel=True must reach the Pallas kernel"
    assert all(calls), "off-TPU the kernel must run in interpret mode"
    np.testing.assert_allclose(float(ld), np.linalg.slogdet(a)[1], rtol=1e-9)


def test_engine_backend_pallas_through_env(monkeypatch, rng):
    """REPRO_KERNEL_BACKEND=interpret routes the engine's backend='auto'
    through the Pallas kernels in interpret mode, end to end."""
    from repro.core.engine import EngineConfig, engine_slogdet
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    a = rng.standard_normal((24, 24))
    s_ref, ld_ref = np.linalg.slogdet(a)
    for update in ("rank1", "panel"):
        cfg = EngineConfig(schedule="serial", update=update, panel_k=8,
                           backend="auto")
        s, ld = engine_slogdet(jnp.asarray(a), cfg)
        assert float(s) == pytest.approx(s_ref), update
        np.testing.assert_allclose(float(ld), ld_ref, rtol=1e-9)
