"""Per-kernel shape/dtype sweeps: Pallas (interpret on CPU) vs ref.py oracle,
plus integration through the condensation algorithms."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import slogdet_condense, slogdet_condense_blocked
from repro.kernels import ops, ref
from repro.kernels.condense_step import rank1_update_pallas
from repro.kernels.panel_update import panel_update_pallas

SHAPES_R1 = [(8, 8), (64, 64), (100, 130), (256, 512), (33, 257)]
SHAPES_PK = [(8, 8, 4), (64, 64, 8), (100, 130, 16), (256, 300, 32)]
DTYPES = [np.float32, np.float64]


def _tol(dt):
    return dict(rtol=2e-5, atol=2e-5) if dt == np.float32 else dict(rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("shape", SHAPES_R1)
@pytest.mark.parametrize("dt", DTYPES)
def test_rank1_update_sweep(shape, dt, rng):
    m, n = shape
    a = rng.standard_normal((m, n)).astype(dt)
    pc = rng.standard_normal((m,)).astype(dt)
    pr = rng.standard_normal((n,)).astype(dt)
    got = rank1_update_pallas(a, pc, pr, interpret=True)
    np.testing.assert_allclose(np.asarray(got), ref.rank1_update_ref(a, pc, pr),
                               **_tol(dt))


@pytest.mark.parametrize("shape", SHAPES_PK)
@pytest.mark.parametrize("dt", DTYPES)
def test_panel_update_sweep(shape, dt, rng):
    m, n, k = shape
    a = rng.standard_normal((m, n)).astype(dt)
    c = rng.standard_normal((m, k)).astype(dt)
    r = rng.standard_normal((k, n)).astype(dt)
    got = panel_update_pallas(a, c, r, interpret=True)
    np.testing.assert_allclose(np.asarray(got), ref.panel_update_ref(a, c, r),
                               **_tol(dt))


@pytest.mark.parametrize("bm,bn", [(8, 128), (16, 256), (256, 512)])
def test_rank1_block_shapes(bm, bn, rng):
    """Block-shape sweep: result must not depend on tiling."""
    a = rng.standard_normal((300, 520)).astype(np.float32)
    pc = rng.standard_normal((300,)).astype(np.float32)
    pr = rng.standard_normal((520,)).astype(np.float32)
    got = rank1_update_pallas(a, pc, pr, bm=bm, bn=bn, interpret=True)
    np.testing.assert_allclose(np.asarray(got), ref.rank1_update_ref(a, pc, pr),
                               rtol=2e-5, atol=2e-5)


def test_bf16_panel(rng):
    a = rng.standard_normal((64, 64)).astype(jnp.bfloat16)
    c = rng.standard_normal((64, 16)).astype(jnp.bfloat16)
    r = rng.standard_normal((16, 64)).astype(jnp.bfloat16)
    got = panel_update_pallas(a, c, r, interpret=True)
    want = ref.panel_update_ref(a.astype(np.float32), c.astype(np.float32),
                                r.astype(np.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               rtol=0.1, atol=0.5)


def test_kernel_in_condense(rng):
    a = rng.standard_normal((32, 32))
    s_ref, ld_ref = np.linalg.slogdet(a)
    s, ld = slogdet_condense(a, use_kernel=True)
    assert float(s) == pytest.approx(s_ref)
    np.testing.assert_allclose(float(ld), ld_ref, rtol=1e-9)


def test_kernel_in_blocked(rng):
    a = rng.standard_normal((48, 48))
    s_ref, ld_ref = np.linalg.slogdet(a)
    s, ld = slogdet_condense_blocked(a, k=16, use_kernel=True)
    assert float(s) == pytest.approx(s_ref)
    np.testing.assert_allclose(float(ld), ld_ref, rtol=1e-9)


@pytest.mark.parametrize("k,n,m0", [(4, 32, 32), (8, 64, 50), (16, 128, 128),
                                    (16, 256, 200)])
def test_panel_factor_vmem_matches_oracle(k, n, m0, rng):
    """VMEM-resident Pallas panel factorization == core.blocked.panel_factor."""
    from repro.core.blocked import panel_factor
    from repro.kernels.panel_factor import panel_factor_pallas
    panel = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    R1, ls1, s1, ld1 = panel_factor(panel, m0, r_pos=5)
    R2, ls2, s2, ld2 = panel_factor_pallas(panel, m0, 5, interpret=True)
    np.testing.assert_array_equal(np.asarray(R1), np.asarray(R2))
    assert (np.asarray(ls1) == np.asarray(ls2)).all()
    assert float(s1) == float(s2)
    np.testing.assert_allclose(float(ld1), float(ld2), rtol=0)


def test_panel_factor_vmem_budget():
    from repro.kernels.panel_factor import panel_factor_pallas
    big = jnp.zeros((64, 65536), jnp.float32)        # 16 MiB > budget
    with pytest.raises(ValueError, match="VMEM"):
        panel_factor_pallas(big, 65536, interpret=True)


# ----------------------------------------------- kernel <-> reference parity
# Interpret-mode sweeps at adversarial geometry: nothing a multiple of the
# (8, 128) f32 VREG tile, K not a multiple of 128, low-precision dtypes.

ODD_SHAPES_R1 = [(1, 1), (7, 129), (129, 7), (255, 383), (130, 130)]
ODD_SHAPES_PK = [(7, 129, 3), (65, 190, 33), (129, 257, 100), (50, 61, 50)]


@pytest.mark.parametrize("shape", ODD_SHAPES_R1)
def test_rank1_update_non_tile_multiple(shape, rng):
    m, n = shape
    a = rng.standard_normal((m, n)).astype(np.float32)
    pc = rng.standard_normal((m,)).astype(np.float32)
    pr = rng.standard_normal((n,)).astype(np.float32)
    got = rank1_update_pallas(a, pc, pr, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               ref.rank1_update_ref(a, pc, pr),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", ODD_SHAPES_PK)
def test_panel_update_k_not_multiple_of_128(shape, rng):
    m, n, k = shape
    a = rng.standard_normal((m, n)).astype(np.float32)
    c = rng.standard_normal((m, k)).astype(np.float32)
    r = rng.standard_normal((k, n)).astype(np.float32)
    got = panel_update_pallas(a, c, r, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               ref.panel_update_ref(a, c, r),
                               rtol=2e-4, atol=2e-4)


def test_rank1_update_bf16(rng):
    a = rng.standard_normal((33, 257)).astype(jnp.bfloat16)
    pc = rng.standard_normal((33,)).astype(jnp.bfloat16)
    pr = rng.standard_normal((257,)).astype(jnp.bfloat16)
    got = rank1_update_pallas(a, pc, pr, interpret=True)
    want = ref.rank1_update_ref(a.astype(np.float32), pc.astype(np.float32),
                                pr.astype(np.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               rtol=0.1, atol=0.1)


@pytest.mark.parametrize("k,n,m0", [(3, 33, 33), (5, 129, 100), (16, 200, 170)])
@pytest.mark.parametrize("dt", [np.float32, np.float64])
def test_panel_factor_non_tile_multiple(k, n, m0, dt, rng):
    from repro.core.engine import panel_factor
    from repro.kernels.panel_factor import panel_factor_pallas
    panel = jnp.asarray(rng.standard_normal((k, n)), dt)
    R1, ls1, s1, ld1 = panel_factor(panel, m0, r_pos=3)
    R2, ls2, s2, ld2 = panel_factor_pallas(panel, m0, 3, interpret=True)
    np.testing.assert_array_equal(np.asarray(R1), np.asarray(R2))
    assert (np.asarray(ls1) == np.asarray(ls2)).all()
    assert float(s1) == float(s2)
    np.testing.assert_allclose(float(ld1), float(ld2), rtol=0)


# ------------------------------------------------- backend dispatch (env)

def test_kernel_backend_env_override(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    assert ops.kernel_backend() in ops.KERNEL_BACKENDS
    for b in ops.KERNEL_BACKENDS:
        monkeypatch.setenv("REPRO_KERNEL_BACKEND", b)
        assert ops.kernel_backend() == b
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "metal")
    with pytest.raises(ValueError, match="REPRO_KERNEL_BACKEND"):
        ops.kernel_backend()


@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_ops_dispatch_agrees_across_backends(backend, monkeypatch, rng):
    """Forcing the env override must not change results — deterministic
    interpret-mode kernel coverage on CPU CI."""
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", backend)
    a = rng.standard_normal((33, 65)).astype(np.float32)
    pc = rng.standard_normal((33,)).astype(np.float32)
    pr = rng.standard_normal((65,)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(ops.rank1_update(a, pc, pr)),
                               ref.rank1_update_ref(a, pc, pr),
                               rtol=2e-5, atol=2e-5)
    c = rng.standard_normal((33, 5)).astype(np.float32)
    r = rng.standard_normal((5, 65)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(ops.panel_update(a, c, r)),
                               ref.panel_update_ref(a, c, r),
                               rtol=2e-5, atol=2e-5)
    x = rng.standard_normal((65, 3)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(ops.matvec(a, x)),
                               np.asarray(ref.matvec_ref(a, x)),
                               rtol=2e-4, atol=2e-4)
    panel = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
    R, ls, s, ld = ops.panel_factor_vmem(panel, 32)
    from repro.core.engine import panel_factor
    R_ref, ls_ref, s_ref, ld_ref = panel_factor(panel, 32)
    np.testing.assert_allclose(np.asarray(R), np.asarray(R_ref),
                               rtol=1e-6, atol=1e-6)
    assert (np.asarray(ls) == np.asarray(ls_ref)).all()


def test_use_kernel_runs_the_pallas_body_off_tpu(monkeypatch, rng):
    """use_kernel=True is an explicit kernel request: off-TPU it must run
    the Pallas kernel body in interpret mode, never silently fall through
    to the jnp reference (regression: the dispatch rewrite briefly routed
    it to ref.rank1_update_ref on CPU)."""
    import repro.kernels.ops as ops_mod
    calls = []
    real = ops_mod.rank1_update_pallas

    def spy(*a, **k):
        calls.append(k.get("interpret"))
        return real(*a, **k)

    monkeypatch.setattr(ops_mod, "rank1_update_pallas", spy)
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    a = rng.standard_normal((13, 13))          # fresh shape: forces a trace
    s, ld = slogdet_condense(a, use_kernel=True)
    assert calls, "use_kernel=True must reach the Pallas kernel"
    assert all(calls), "off-TPU the kernel must run in interpret mode"
    np.testing.assert_allclose(float(ld), np.linalg.slogdet(a)[1], rtol=1e-9)


def test_engine_backend_pallas_through_env(monkeypatch, rng):
    """REPRO_KERNEL_BACKEND=interpret routes the engine's backend='auto'
    through the Pallas kernels in interpret mode, end to end."""
    from repro.core.engine import EngineConfig, engine_slogdet
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    a = rng.standard_normal((24, 24))
    s_ref, ld_ref = np.linalg.slogdet(a)
    for update in ("rank1", "panel"):
        cfg = EngineConfig(schedule="serial", update=update, panel_k=8,
                           backend="auto")
        s, ld = engine_slogdet(jnp.asarray(a), cfg)
        assert float(s) == pytest.approx(s_ref), update
        np.testing.assert_allclose(float(ld), ld_ref, rtol=1e-9)


# ------------------------------------------------- fused one-pass step

from repro.kernels import autotune
from repro.kernels.fused_step import fused_step_pallas
from repro.kernels.fused_est import cg_step_pallas, cheb_step_pallas

ODD_N = [7, 37, 129, 200]


def _scatter_step_oracle(a, l, last, pc, pr):
    """The engine's historical three-pass sequence: scatter column swap,
    then outer-product subtract — the arithmetic the fused pass must
    reproduce bit for bit (the swap is pure data movement)."""
    col_l, col_last = a[:, l], a[:, last]
    sw = a.at[:, l].set(col_last).at[:, last].set(col_l)
    return sw - np.multiply.outer(np.asarray(pc), np.asarray(pr)).astype(
        np.asarray(a).dtype)


@pytest.mark.parametrize("n", ODD_N)
@pytest.mark.parametrize("dt", DTYPES)
def test_fused_step_matches_scatter_sequence(n, dt, rng):
    """fused select-pass == scatter swap + rank-1, bitwise (both refs and
    the interpret-mode Pallas body)."""
    a = jnp.asarray(rng.standard_normal((n, n)), dt)
    pc = jnp.asarray(rng.standard_normal((n,)), dt)
    pr = jnp.asarray(rng.standard_normal((n,)), dt)
    l, last = min(3, n - 1), n - 1
    want = _scatter_step_oracle(a, l, last, pc, pr)
    got_ref = ref.fused_step_ref(a, l, last, pc, pr, a[:, l], a[:, last])
    np.testing.assert_array_equal(np.asarray(got_ref), np.asarray(want))
    # the Pallas body executes under jit, where XLA contracts the
    # multiply-subtract into an FMA — compare against the jitted ref
    # (the form the engine actually traces), which IS bitwise
    got_pal = fused_step_pallas(a, jnp.int32(l), jnp.int32(last), pc, pr,
                                a[:, l], a[:, last], interpret=True)
    want_jit = jax.jit(ref.fused_step_ref)(a, jnp.int32(l), jnp.int32(last),
                                           pc, pr, a[:, l], a[:, last])
    np.testing.assert_array_equal(np.asarray(got_pal), np.asarray(want_jit))


@pytest.mark.parametrize("bm,bn", [(8, 128), (64, 64), (256, 512)])
def test_fused_step_block_shape_invariant(bm, bn, rng):
    """Result must not depend on the autotuner's tile choice."""
    n = 150
    a = jnp.asarray(rng.standard_normal((n, n)), np.float32)
    pc = jnp.asarray(rng.standard_normal((n,)), np.float32)
    pr = jnp.asarray(rng.standard_normal((n,)), np.float32)
    want = jax.jit(ref.fused_step_ref)(a, jnp.int32(5), jnp.int32(n - 1),
                                       pc, pr, a[:, 5], a[:, n - 1])
    got = fused_step_pallas(a, jnp.int32(5), jnp.int32(n - 1), pc, pr,
                            a[:, 5], a[:, n - 1], bm=bm, bn=bn,
                            interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_fused_condense_step_backends_agree(backend, rng):
    """ops.fused_condense_step: identical (buf', l, p) across backends at
    a non-tile-multiple N, mid-condensation t."""
    n = 37
    buf = jnp.asarray(rng.standard_normal((n, n)), np.float32)
    # jit both legs: eager-vs-jit differs by one FMA contraction, and the
    # engine only ever runs this step inside a jitted condensation loop
    step = {
        be: jax.jit(lambda b, t, be=be: ops.fused_condense_step(
            b, t, backend=be), static_argnums=1)
        for be in ("xla", backend)}
    for t in (0, 3, n - 2):
        b1, l1, p1 = step["xla"](buf, t)
        b2, l2, p2 = step[backend](buf, t)
        np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
        assert int(l1) == int(l2) and float(p1) == float(p2)


def test_fused_condense_step_zero_pivot_row(rng):
    """An all-zero live row must produce p == 0 and a zero pr (no NaNs) —
    the singular-input guard the unfused engine step carries."""
    n = 9
    buf = jnp.asarray(rng.standard_normal((n, n)), np.float32)
    buf = buf.at[0].set(0.0)
    out, l, p = ops.fused_condense_step(buf, 0, backend="xla")
    assert float(p) == 0.0
    assert np.isfinite(np.asarray(out)).all()


def test_fused_condense_step_bf16_error_model(rng):
    """precision='bf16' quantizes only the rank-1 operands: the result
    stays in the buffer dtype and within the documented bf16 error model
    (|err| <= ~2^-8 * |pc||pr| elementwise against the f32 step)."""
    n = 64
    buf = jnp.asarray(rng.standard_normal((n, n)), np.float32)
    exact, l1, p1 = ops.fused_condense_step(buf, 2, backend="xla")
    quant, l2, p2 = ops.fused_condense_step(buf, 2, backend="xla",
                                            precision="bf16")
    assert quant.dtype == buf.dtype
    assert int(l1) == int(l2) and float(p1) == float(p2)  # pivot is exact
    scale = (np.abs(np.asarray(buf)).max() ** 2) / abs(float(p1))
    err = np.abs(np.asarray(quant) - np.asarray(exact)).max()
    assert err <= 2.0 ** -8 * scale * 4, (err, scale)


# ------------------------------------------------- fused estimator steps

@pytest.mark.parametrize("shape", [(8, 3), (37, 5), (130, 7)])
def test_cheb_step_pallas_matches_ref(shape, rng):
    n, k = shape
    a = jnp.asarray(rng.standard_normal((n, n)), np.float32)
    w = jnp.asarray(rng.standard_normal((n, k)), np.float32)
    wp = jnp.asarray(rng.standard_normal((n, k)), np.float32)
    v = jnp.asarray(rng.standard_normal((n, k)), np.float32)
    wn1, d1 = cheb_step_pallas(a, w, wp, v, 1.7, 3.1, interpret=True)
    wn2, d2 = jax.jit(ref.cheb_step_ref)(a, w, wp, v,
                                         jnp.float32(1.7), jnp.float32(3.1))
    np.testing.assert_array_equal(np.asarray(wn1), np.asarray(wn2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


@pytest.mark.parametrize("shape", [(8, 3), (37, 5), (130, 7)])
def test_cg_step_pallas_matches_ref(shape, rng):
    """Bitwise against the jitted unfused chain (the form the engine's
    while_loop traces; eager numpy differs by one FMA contraction)."""
    n, k = shape
    a = jnp.asarray(rng.standard_normal((n, n)), np.float32)
    p = jnp.asarray(rng.standard_normal((n, k)), np.float32)
    x = jnp.asarray(rng.standard_normal((n, k)), np.float32)
    r = jnp.asarray(rng.standard_normal((n, k)), np.float32)
    rz = jnp.asarray(rng.standard_normal((k,)), np.float32)
    x1, r1 = cg_step_pallas(a, p, x, r, rz, interpret=True)
    x2, r2 = jax.jit(ref.cg_step_ref)(a, p, x, r, rz)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))


def test_cg_step_converged_columns_take_noops():
    """Zero search direction (a converged column) must produce alpha == 0
    exactly, not NaN — the guarded 0/0 the solver relies on."""
    n, k = 16, 3
    a = jnp.eye(n, dtype=jnp.float32)
    p = jnp.zeros((n, k), jnp.float32)
    x = jnp.ones((n, k), jnp.float32)
    r = jnp.ones((n, k), jnp.float32)
    rz = jnp.ones((k,), jnp.float32)
    x1, r1 = cg_step_pallas(a, p, x, r, rz, interpret=True)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r))


def test_fused_est_oversized_falls_back_to_ref(monkeypatch, rng):
    """Operands beyond the VMEM budget must take the identical jnp
    reference, not a partial kernel (and never error)."""
    import repro.kernels.ops as ops_mod
    monkeypatch.setattr(ops_mod, "_EST_VMEM_BUDGET", 64)   # nothing fits
    calls = []
    monkeypatch.setattr(ops_mod, "cheb_step_pallas",
                        lambda *a, **k: calls.append("pallas"))
    a = jnp.asarray(rng.standard_normal((16, 16)), np.float32)
    w = jnp.asarray(rng.standard_normal((16, 2)), np.float32)
    wn, d = ops_mod.fused_cheb_step(a, w, w, w, 1.0, 2.0,
                                    backend="interpret")
    assert not calls, "oversized operands must not reach the kernel"
    wn_ref, d_ref = ref.cheb_step_ref(a, w, w, w, 1.0, 2.0)
    np.testing.assert_array_equal(np.asarray(wn), np.asarray(wn_ref))


def test_fused_estimators_integrate(rng):
    """End to end: dense chebyshev / cg_solve (fused loop bodies) equal
    the unfused operator path bit for bit."""
    from repro.estimators.chebyshev import logdet_chebyshev
    from repro.estimators.operators import cg_solve

    n = 48
    m = rng.standard_normal((n, n)).astype(np.float32)
    a = jnp.asarray(m @ m.T + n * np.eye(n, dtype=np.float32))

    class Unfused:          # duck-typed operator: misses the dense gate
        def __init__(self, a):
            self.a, self.shape, self.dtype = a, a.shape, a.dtype

        def mm(self, v):
            return self.a @ v

        mv = mm

        def diag(self):
            return jnp.diagonal(self.a)

        def trace_hint(self):
            return jnp.trace(self.a)

    rf = logdet_chebyshev(a, degree=16, num_probes=4, seed=3)
    ru = logdet_chebyshev(Unfused(a), degree=16, num_probes=4, seed=3)
    np.testing.assert_array_equal(np.asarray(rf.est), np.asarray(ru.est))

    b = jnp.asarray(rng.standard_normal((n, 4)), jnp.float32)
    sf = cg_solve(a, b, tol=1e-6)
    su = cg_solve(Unfused(a), b, tol=1e-6)
    np.testing.assert_array_equal(np.asarray(sf.x), np.asarray(su.x))
    assert int(sf.iters) == int(su.iters)
    assert bool(sf.converged)


# ------------------------------------------------- tile autotuner

def test_autotune_deterministic_and_cached():
    autotune.clear_autotune_cache()
    t1 = autotune.tile_config(512, itemsize=4)
    t2 = autotune.tile_config(512, itemsize=4)
    assert t1 == t2
    assert t1.panel_k in autotune.PANEL_K_CANDIDATES
    assert t1.source.startswith(("model", "env", "off"))


def test_autotune_panel_k_grows_with_n():
    """The model's k* ~ sqrt(n * gemm/stream): wider panels amortize
    more GEMM per byte streamed as N grows."""
    autotune.clear_autotune_cache()
    ks = [autotune.resolved_panel_k(n, itemsize=8)
          for n in (64, 512, 4096)]
    assert ks == sorted(ks), ks
    assert all(k <= max(8, (1 << (n - 1).bit_length()) // 4)
               for k, n in zip(ks, (64, 512, 4096)))


def test_autotune_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "panel_k=16,block_m=128,block_n=256")
    autotune.clear_autotune_cache()
    t = autotune.tile_config(1024)
    assert (t.panel_k, t.block_m, t.block_n) == (16, 128, 256)
    assert t.source == "env"
    monkeypatch.setenv("REPRO_AUTOTUNE", "off")
    autotune.clear_autotune_cache()
    assert autotune.tile_config(1024).source == "off"
    monkeypatch.setenv("REPRO_AUTOTUNE", "warp=9")
    autotune.clear_autotune_cache()
    with pytest.raises(ValueError, match="REPRO_AUTOTUNE"):
        autotune.tile_config(1024)
    monkeypatch.delenv("REPRO_AUTOTUNE")
    autotune.clear_autotune_cache()


def test_autotune_prices_bf16_separately():
    """A table with a faster bf16 GEMM rate must shift the modeled
    crossover: bf16 never picks a WIDER panel than native at equal
    stream cost, and an extreme bf16 rate drives k down."""
    from repro.core.calibration import Calibration
    cal = Calibration(gemm_flops=1e11, stream_bytes=1e10,
                      gemm_flops_bf16=1e14)
    k_native = autotune.resolved_panel_k(2048, itemsize=8, cal=cal)
    k_bf16 = autotune.resolved_panel_k(2048, itemsize=8, precision="bf16",
                                       cal=cal)
    assert k_bf16 <= k_native


def test_exact_cost_resolves_panel_k_through_autotuner():
    from repro.core.calibration import exact_cost, load_calibration
    cal = load_calibration()
    n = 1024
    k = autotune.resolved_panel_k(n, itemsize=8, cal=cal)
    assert exact_cost(n, 1, cal, update="panel") == \
        exact_cost(n, 1, cal, update="panel", panel_k=k)
    # bf16 prices the GEMM term at the bf16 rate: strictly cheaper
    assert exact_cost(n, 1, cal, update="panel", precision="bf16") < \
        exact_cost(n, 1, cal, update="panel")
