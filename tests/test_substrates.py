"""Substrate tests: optimizers, checkpoint (atomic/async/elastic), FT driver
(restart-on-failure, straggler log), data determinism, loss functions."""
import os
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt
from repro.configs.registry import get_config
from repro.data.synthetic import DataConfig, random_matrix, synth_batch
from repro.ft.driver import FTConfig, run_training
from repro.models import model as M
from repro.optim.optimizers import OptConfig, get_optimizer, global_norm
from repro.train.loss import chunked_cross_entropy, cross_entropy
from repro.train.step import TrainConfig, init_train_state, make_train_step


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["adamw", "adafactor", "sgd"])
def test_optimizer_reduces_quadratic(name):
    """Each optimizer must make progress on a convex toy problem."""
    cfg = OptConfig(name=name, lr=0.05, warmup=1, decay_steps=400,
                    weight_decay=0.0)
    init, update = get_optimizer(cfg)
    params = {"w": jnp.ones((4, 4)) * 3.0, "b": jnp.ones((4,)) * -2.0}
    opt = init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt = update(g, opt, params)
    assert float(loss(params)) < 0.05 * l0, name


def test_adamw_moments_dtype():
    init, _ = get_optimizer(OptConfig(name="adamw"))
    p = {"w": jnp.ones((2, 2), jnp.bfloat16)}
    st = init(p)
    assert st["m"]["w"].dtype == jnp.float32      # moments always f32


# ---------------------------------------------------------------------------
# chunked CE == plain CE
# ---------------------------------------------------------------------------

def test_chunked_ce_matches_dense(rng):
    b, t, d, v = 2, 24, 16, 64
    h = jnp.asarray(rng.standard_normal((b, t, d)), jnp.float32)
    table = jnp.asarray(rng.standard_normal((v, d)), jnp.float32)
    y = jnp.asarray(rng.integers(0, v, (b, t)), jnp.int32)
    dense = cross_entropy(jnp.einsum("btd,vd->btv", h, table), y)
    for chunk in (5, 8, 24):
        got = chunked_cross_entropy(h, table, y, chunk=chunk)
        np.testing.assert_allclose(float(got), float(dense), rtol=1e-6)
    # gradients must match too (checkpointed body)
    g1 = jax.grad(lambda hh: cross_entropy(
        jnp.einsum("btd,vd->btv", hh, table), y))(h)
    g2 = jax.grad(lambda hh: chunked_cross_entropy(hh, table, y, chunk=8))(h)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5,
                               atol=1e-6)


# ---------------------------------------------------------------------------
# data determinism
# ---------------------------------------------------------------------------

def test_synth_batch_deterministic():
    cfg = get_config("qwen2.5-3b", smoke=True)
    data = DataConfig(seed=3, batch=4, seq=16, kind="markov")
    a = synth_batch(cfg, data, 7)
    b = synth_batch(cfg, data, 7)
    c = synth_batch(cfg, data, 8)
    assert (a["tokens"] == b["tokens"]).all()
    assert not (a["tokens"] == c["tokens"]).all()
    assert a["targets"].shape == a["tokens"].shape


def test_random_matrix_kinds():
    for kind in ("normal", "spd", "corr_scaled", "pivot_adversarial"):
        a = random_matrix(32, kind=kind, seed=1)
        assert a.shape == (32, 32)
        s, ld = np.linalg.slogdet(a)
        assert np.isfinite(ld)


# ---------------------------------------------------------------------------
# checkpoint: atomic, async, elastic restore
# ---------------------------------------------------------------------------

def _tiny_state():
    return {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "opt": {"count": jnp.asarray(5, jnp.int32)},
            "step": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    st = _tiny_state()
    ckpt.save(tmp_path, st, 7)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    got, step = ckpt.restore(tmp_path, like)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.asarray(st["params"]["w"]))


def test_checkpoint_latest_and_atomicity(tmp_path):
    st = _tiny_state()
    ckpt.save(tmp_path, st, 1)
    ckpt.save(tmp_path, st, 3)
    (tmp_path / ".tmp_step_00000009_123").mkdir()   # crashed partial write
    assert ckpt.latest_step(tmp_path) == 3


def test_checkpoint_async(tmp_path):
    st = _tiny_state()
    t = ckpt.save_async(tmp_path, st, 11)
    t.join(timeout=30)
    assert ckpt.latest_step(tmp_path) == 11


def test_checkpoint_shape_mismatch_raises(tmp_path):
    st = _tiny_state()
    ckpt.save(tmp_path, st, 1)
    bad = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    bad["params"]["w"] = jax.ShapeDtypeStruct((3, 3), jnp.float32)
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, bad)


# ---------------------------------------------------------------------------
# FT driver: restart-on-failure resumes from checkpoint; stragglers logged
# ---------------------------------------------------------------------------

def test_ft_restart_resumes(tmp_path, rng):
    cfg = get_config("qwen2.5-3b", smoke=True).replace(
        dtype=jnp.float32, n_layers=1, d_model=32, d_ff=64, vocab=64,
        n_heads=2, n_kv_heads=2, head_dim=16)
    tcfg = TrainConfig(opt=OptConfig(name="sgd", lr=1e-3, warmup=1,
                                     decay_steps=50))
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    data = DataConfig(seed=0, batch=2, seq=8)

    boom = {"armed": True}

    def injector(step):
        if step == 12 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected node failure")

    ft = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=5, async_ckpt=False,
                  max_restarts=2)
    state, stats = run_training(
        state=state, train_step=step_fn,
        batch_fn=lambda s: synth_batch(cfg, data, s),
        n_steps=20, ft=ft, fault_injector=injector)
    assert stats.restarts == 1
    assert int(jax.device_get(state["step"])) == 20
    assert ckpt.latest_step(tmp_path) == 20


def test_ft_max_restarts(tmp_path):
    def always_fail(step):
        raise RuntimeError("dead node")
    with pytest.raises(RuntimeError, match="max_restarts"):
        run_training(
            state={"step": jnp.asarray(0)},
            train_step=lambda s, b: (_ for _ in ()).throw(RuntimeError()),
            batch_fn=lambda s: None, n_steps=3,
            ft=FTConfig(ckpt_dir=str(tmp_path), max_restarts=1),
            fault_injector=always_fail)


def test_straggler_detection(tmp_path):
    cfg = get_config("qwen2.5-3b", smoke=True).replace(
        dtype=jnp.float32, n_layers=1, d_model=32, d_ff=64, vocab=64,
        n_heads=2, n_kv_heads=2, head_dim=16)
    tcfg = TrainConfig(opt=OptConfig(name="sgd"))
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step_fn = make_train_step(cfg, tcfg)
    jitted = jax.jit(step_fn)
    data = DataConfig(seed=0, batch=2, seq=8)

    def slow_injector(step):
        if step == 15:
            time.sleep(1.0)           # simulated straggler

    ft = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=100, async_ckpt=False,
                  straggler_factor=3.0)
    _, stats = run_training(
        state=state, train_step=jitted,
        batch_fn=lambda s: synth_batch(cfg, data, s),
        n_steps=20, ft=ft, fault_injector=slow_injector)
    assert 15 in stats.stragglers
