"""Per-architecture smoke tests: instantiate the REDUCED config of each
family and run forward / prefill+decode on CPU, asserting shapes + no NaNs.
The FULL configs are exercised only via the dry-run (no allocation)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, get_config, skip_shapes
from repro.models import model as M


def _batch_for(cfg, b, t, rng):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, t)), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.enc_seq, cfg.d_model)), cfg.dtype)
    if cfg.family == "vlm":
        batch["img_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_img_tokens, cfg.d_model)), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch, rng):
    cfg = get_config(arch, smoke=True).replace(dtype=jnp.float32, remat=False)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    b, t = 2, 16
    batch = _batch_for(cfg, b, t, rng)
    logits, aux = M.forward(params, batch, cfg)
    assert logits.shape == (b, t, cfg.vocab)
    assert not bool(jnp.isnan(logits).any()), f"NaNs in {arch} logits"
    for k, v in aux.items():
        assert np.isfinite(float(v)), (arch, k)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_one(arch, rng):
    """One SGD step on the smoke config must reduce nothing to NaN."""
    cfg = get_config(arch, smoke=True).replace(dtype=jnp.float32, remat=True)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    b, t = 2, 8
    batch = _batch_for(cfg, b, t, rng)
    targets = jnp.asarray(rng.integers(0, cfg.vocab, (b, t)), jnp.int32)

    def loss_fn(p):
        logits, aux = M.forward(p, batch, cfg)
        ll = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(ll, targets[..., None], axis=-1).mean()
        return nll + 0.01 * sum(aux.values()) if aux else nll

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)), arch
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)), arch
    new = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype), params, grads)
    logits2, _ = M.forward(new, batch, cfg)
    assert not bool(jnp.isnan(logits2).any()), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch, rng):
    """prefill(t tokens) + decode steps == forward(t+k tokens) logits."""
    cfg = get_config(arch, smoke=True).replace(dtype=jnp.float32, remat=False)
    if cfg.n_experts:
        # dropless for the consistency check: capacity-dropping is inherently
        # call-shape-dependent (full forward vs prefill+decode see different
        # token sets), so remove it from this equivalence test.
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    b, t_pre, t_total, max_len = 2, 6, 10, 16
    batch = _batch_for(cfg, b, t_total, rng)

    full_logits, _ = M.forward(params, batch, cfg)

    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :t_pre]
    logits_p, caches = M.prefill(params, pre_batch, cfg, max_len)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0]), np.asarray(full_logits[:, t_pre - 1]),
        rtol=2e-4, atol=2e-4)

    extras = None
    if cfg.family == "encdec":
        extras = {"memory": M._encode(params, batch, cfg)}
    elif cfg.family == "vlm":
        extras = {"img_embeds": batch["img_embeds"]}

    for pos in range(t_pre, t_total):
        tok = batch["tokens"][:, pos:pos + 1]
        logits_d, caches = M.decode_step(params, tok, caches, pos, cfg,
                                         batch_extras=extras)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, pos]),
            rtol=2e-4, atol=2e-4, err_msg=f"{arch} pos={pos}")


@pytest.mark.parametrize("arch", ARCHS)
def test_cache_specs_match_runtime(arch, rng):
    """cache_specs must structurally match what prefill actually returns."""
    cfg = get_config(arch, smoke=True).replace(dtype=jnp.float32, remat=False)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    max_len = 12
    batch = _batch_for(cfg, 2, 6, rng)
    _, caches = M.prefill(params, batch, cfg, max_len)
    specs = M.cache_specs(cfg, 2, max_len)
    got = jax.tree.map(lambda x: (x.shape, str(x.dtype)), caches)
    want = jax.tree.map(lambda s: (s.shape, str(s.dtype)), specs)
    assert got == want, f"{arch}\n got={got}\nwant={want}"


def test_sliding_window_pattern():
    cfg = get_config("gemma3-1b")
    w = M.layer_windows(cfg)
    assert w.shape == (26,)
    assert (w[5::6] == 0).all()              # every 6th layer global
    assert (np.delete(w, np.s_[5::6]) == 512).all()


def test_param_counts_in_range():
    """count_params should land near the advertised model sizes."""
    expect = {
        "qwen2.5-3b": (2.5e9, 4.2e9),
        "qwen1.5-4b": (3.0e9, 5.0e9),
        "gemma3-1b": (0.7e9, 1.6e9),
        "phi3-mini-3.8b": (3.3e9, 4.3e9),
        "llama4-maverick-400b-a17b": (3.2e11, 4.8e11),
        "qwen2-moe-a2.7b": (1.0e10, 1.7e10),
        "mamba2-370m": (3.0e8, 4.6e8),
        "whisper-tiny": (2.0e7, 6.0e7),
        "llama-3.2-vision-11b": (0.8e10, 1.3e10),
        "zamba2-7b": (5.5e9, 9.0e9),
    }
    for arch, (lo, hi) in expect.items():
        n = M.count_params(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
