"""repro.serve: AOT export/import, bucketing, batching, the service, HTTP.

Everything runs on tiny matrices (buckets of 8/16/32) — the serving
semantics under test are size-independent.  The one subprocess test
(`test_aot_cross_process_bit_identical`) is the acceptance property:
an artifact exported here replays bit-identically in a fresh process
with zero traces and zero compiles.
"""
import json
import struct
import threading
import urllib.request

import numpy as np
import pytest

import jax

import repro
from repro import obs
from repro.serve import (
    BucketLadder, LogdetService, PlanCache, ServeConfig, ServiceClosed,
    bucket_batch, coalesce, pad_to_bucket, stack_to_bucket,
)
from repro.serve.aot import (
    PlanExportError, PlanFingerprintError, read_header,
)
from repro.serve.batching import Request, admit
from tests._subproc import run_with_devices


@pytest.fixture
def metrics():
    """Metrics-mode obs with a clean registry, restored afterwards."""
    prev = obs.mode()
    obs.configure("metrics")
    obs.reset()
    yield obs
    obs.reset()
    obs.configure(prev)


def _spd(rng, n):
    a = rng.standard_normal((n, n)) * 0.05
    return np.eye(n) * 2.0 + (a + a.T) / 2


# ---------------------------------------------------------------- ladder

def test_ladder_boundaries():
    lad = BucketLadder((8, 16, 32))
    assert lad.bucket_for(1) == 8
    assert lad.bucket_for(8) == 8        # exactly on a rung
    assert lad.bucket_for(9) == 16       # just over
    assert lad.bucket_for(16) == 16
    assert lad.bucket_for(17) == 32
    assert lad.bucket_for(32) == 32
    with pytest.raises(ValueError, match="exceeds the top bucket"):
        lad.bucket_for(33)
    with pytest.raises(ValueError):
        lad.bucket_for(0)


def test_ladder_sorts_and_dedupes():
    assert BucketLadder((32, 8, 8, 16)).buckets == (8, 16, 32)
    with pytest.raises(ValueError):
        BucketLadder(())
    with pytest.raises(ValueError):
        BucketLadder((0, 8))


def test_bucket_batch():
    assert bucket_batch(1, 8) == 1
    assert bucket_batch(2, 8) == 2
    assert bucket_batch(3, 8) == 4
    assert bucket_batch(5, 8) == 8
    assert bucket_batch(8, 8) == 8
    assert bucket_batch(100, 8) == 8     # capped
    with pytest.raises(ValueError):
        bucket_batch(0, 8)


def test_padding_preserves_slogdet(rng):
    a = rng.standard_normal((5, 5))
    padded = pad_to_bucket(a, 8)
    s0, ld0 = np.linalg.slogdet(a)
    s1, ld1 = np.linalg.slogdet(padded)
    assert s0 == s1
    assert ld1 == pytest.approx(ld0, abs=1e-12)


def test_stack_identity_filler(rng):
    mats = [rng.standard_normal((5, 5)), rng.standard_normal((7, 7))]
    stack = stack_to_bucket(mats, 8, 4)
    assert stack.shape == (4, 8, 8)
    for i, m in enumerate(mats):
        assert np.linalg.slogdet(stack[i])[1] == pytest.approx(
            np.linalg.slogdet(m)[1], abs=1e-12)
    for i in (2, 3):                     # filler slots: exact identity
        np.testing.assert_array_equal(stack[i], np.eye(8))


# ------------------------------------------------------------ plan cache

def test_plan_cache_lru_eviction_order(metrics):
    cache = PlanCache(capacity=2)
    cache.put(("a",), 1)
    cache.put(("b",), 2)
    assert cache.get(("a",)) == 1        # touch "a": "b" is now oldest
    cache.put(("c",), 3)                 # evicts "b"
    assert cache.keys() == [("a",), ("c",)]
    assert cache.get(("b",)) is None
    assert obs.counter_value("serve.plan_cache.evictions") == 1
    built = cache.get(("d",), lambda: 4)  # builder path evicts "a"
    assert built == 4
    assert cache.keys() == [("c",), ("d",)]
    assert obs.counter_value("serve.plan_cache.evictions") == 2
    assert obs.counter_value("serve.plan_cache.hits") == 1


# ------------------------------------------------------------- coalescing

def test_coalesce_groups_and_fifo(rng):
    lad = BucketLadder((8, 16))
    reqs = [admit(rng.standard_normal((n, n)), lad, method=m, rtol=None,
                  dtype=np.float64)
            for n, m in [(5, "exact"), (12, "exact"), (7, "exact"),
                         (6, "chebyshev"), (8, "exact")]]
    groups = coalesce(reqs, max_batch=8)
    keys = [(g.bucket, g.method) for g in groups]
    assert sorted(keys) == [(8, "chebyshev"), (8, "exact"), (16, "exact")]
    assert groups[0].oldest <= groups[1].oldest <= groups[2].oldest
    exact8 = next(g for g in groups if (g.bucket, g.method) == (8, "exact"))
    assert [r.n for r in exact8.requests] == [5, 7, 8]  # admission order


def test_coalesce_chunks_at_max_batch():
    reqs = [Request(a=np.eye(2), n=2, bucket=8, method="exact", rtol=None)
            for _ in range(5)]
    groups = coalesce(reqs, max_batch=2)
    assert [len(g.requests) for g in groups] == [2, 2, 1]
    flat = [r.id for g in groups for r in g.requests]
    assert flat == sorted(flat)          # FIFO across the chunks


def test_admit_rejects_bad_input(rng):
    lad = BucketLadder((8,))
    with pytest.raises(ValueError, match="square"):
        admit(rng.standard_normal((4, 5)), lad, method="exact", rtol=None,
              dtype=np.float64)
    bad = np.eye(4)
    bad[0, 0] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        admit(bad, lad, method="exact", rtol=None, dtype=np.float64)
    with pytest.raises(ValueError, match="exceeds the top bucket"):
        admit(np.eye(9), lad, method="exact", rtol=None, dtype=np.float64)


# -------------------------------------------------------------- AOT plans

def test_aot_roundtrip_bit_identical(tmp_path, rng, metrics):
    a = rng.standard_normal((12, 12))
    p = repro.plan((12, 12), method="exact", validate=False)
    want = float(p(a).logabsdet)
    path = str(tmp_path / "p.repro-plan")
    assert p.export(path) == path
    traces_before = obs.counter_value("plan.traces")

    q = repro.load_plan(path)
    assert q.trace_count == 0
    got = q(a)
    assert float(got.logabsdet) == want              # bit-identical
    assert float(q(a).logabsdet) == want
    assert q.trace_count == 0                        # still zero
    assert obs.counter_value("plan.traces") == traces_before
    assert got.method_used == "exact"


def test_aot_export_does_not_retrace_live_plan(rng, tmp_path):
    p = repro.plan((12, 12), method="exact", validate=False)
    p(rng.standard_normal((12, 12)))
    before = p.trace_count
    p.export(str(tmp_path / "p.repro-plan"))
    assert p.trace_count == before


def test_aot_estimator_roundtrip(tmp_path, rng):
    a = _spd(rng, 16)
    p = repro.plan((16, 16), method="slq", validate=False)
    want = float(p(a).logabsdet)
    path = str(tmp_path / "slq.repro-plan")
    p.export(path)
    q = repro.load_plan(path)
    assert float(q(a).logabsdet) == want       # default key == cfg.seed
    key = jax.random.PRNGKey(7)
    assert float(q(a, key=key).logabsdet) == float(p(a, key=key).logabsdet)
    with pytest.raises(TypeError, match="probes"):
        q(a, probes=np.ones((4, 16)))


def test_aot_loaded_plan_is_execute_only(tmp_path, rng):
    p = repro.plan((8, 8), method="exact", validate=False)
    path = str(tmp_path / "p.repro-plan")
    p.export(path)
    q = repro.load_plan(path)
    a = rng.standard_normal((8, 8))
    with pytest.raises(TypeError, match="takes no key"):
        q(a, key=jax.random.PRNGKey(0))
    with pytest.raises(TypeError, match="execute-only"):
        jax.jit(lambda x: q.logdet(x))(a)
    with pytest.raises(NotImplementedError, match="execute-only"):
        q.value_and_grad(a)


def test_aot_header_and_fingerprint_mismatch(tmp_path, rng):
    p = repro.plan((8, 8), method="exact", validate=False)
    path = str(tmp_path / "p.repro-plan")
    p.export(path)
    header = read_header(path)
    assert header["format"] == 1
    assert header["method"] == "exact"
    assert header["spec"]["n"] == 8
    assert header["fingerprint"]["platform"] == jax.devices()[0].platform

    # tamper: pretend the artifact came from another jax / device
    raw = open(path, "rb").read()
    magic_len = len(b"REPROPLAN\x00")
    (hlen,) = struct.unpack_from("<I", raw, magic_len)
    start = magic_len + 4
    header["fingerprint"]["jax_version"] = "9.9.9"
    new_head = json.dumps(header, sort_keys=True).encode()
    tampered = (raw[:magic_len] + struct.pack("<I", len(new_head))
                + new_head + raw[start + hlen:])
    bad = tmp_path / "tampered.repro-plan"
    bad.write_bytes(tampered)
    with pytest.raises(PlanFingerprintError, match="jax_version"):
        repro.load_plan(str(bad))
    # the escape hatch skips the check (same process, so actually safe)
    q = repro.load_plan(str(bad), check_device=False)
    a = rng.standard_normal((8, 8))
    assert np.isfinite(float(q(a).logabsdet))


def test_aot_rejects_non_artifact(tmp_path):
    junk = tmp_path / "junk.repro-plan"
    junk.write_bytes(b"definitely not a plan")
    with pytest.raises(PlanExportError, match="bad magic"):
        repro.load_plan(str(junk))


def test_aot_rejects_uncompiled_plan(mesh1):
    p = repro.plan((16, 16), method="exact", mesh=mesh1, validate=False)
    if p.compiled:
        pytest.skip("mesh plan unexpectedly compiled")
    with pytest.raises(PlanExportError, match="compiled"):
        p.export("/dev/null")


def test_aot_cross_process_bit_identical(tmp_path, rng):
    """The acceptance property: export here, load in a FRESH process,
    bit-identical logabsdet with zero traces/compiles (plan.trace_count
    and the plan.traces metric both stay 0 over there)."""
    a = rng.standard_normal((12, 12))
    p = repro.plan((12, 12), method="exact", validate=False)
    want = float(p(a).logabsdet)
    path = str(tmp_path / "x.repro-plan")
    p.export(path)
    np.save(tmp_path / "a.npy", a)
    out = run_with_devices(f"""
from repro import obs
obs.configure("metrics")
import repro
q = repro.load_plan({path!r})
a = np.load({str(tmp_path / 'a.npy')!r})
r = q(a)
assert q.trace_count == 0, q.trace_count
assert obs.counter_value("plan.traces") == 0
print(repr(float(r.logabsdet)))
""", 1)
    assert float(out.strip()) == want


# --------------------------------------------------------------- service

def test_serve_config_validation():
    with pytest.raises(ValueError, match="max_batch"):
        ServeConfig(max_batch=0)
    with pytest.raises(ValueError, match="max_wait_ms"):
        ServeConfig(max_wait_ms=-1)
    with pytest.raises(ValueError, match="default_method"):
        ServeConfig(default_method="nope")
    assert ServeConfig(buckets=(32, 8, 16)).buckets == (8, 16, 32)


def test_service_mixed_size_drain_unpermuted(rng, metrics):
    cfg = ServeConfig(buckets=(8, 16, 32), max_batch=4, max_wait_ms=1.0)
    with LogdetService(cfg) as svc:
        mats = [rng.standard_normal((n, n))
                for n in (5, 8, 13, 16, 30, 7, 9, 32)]
        futs = [svc.submit(a) for a in mats]
        for a, f in zip(mats, futs):     # results in submission order
            res = f.result(timeout=120)
            assert float(res.logabsdet) == pytest.approx(
                np.linalg.slogdet(a)[1], abs=1e-8)
            assert res.diagnostics.padded_n in (8, 16, 32)
        warm = svc.trace_count()
        futs = [svc.submit(a) for a in mats]
        for f in futs:
            f.result(timeout=120)
        assert svc.trace_count() == warm          # no request-time traces
        assert obs.counter_value("serve.responses", status="ok") == 16
        stats = svc.stats()
        assert stats["trace_count"] == warm
        assert stats["quantiles"]["serve.batch_size"]["p50"] is not None


def test_service_estimator_requests(rng):
    cfg = ServeConfig(buckets=(16,), max_batch=2, max_wait_ms=1.0)
    with LogdetService(cfg) as svc:
        a = _spd(rng, 14)
        res = svc.logdet(a, method="chebyshev", timeout=120)
        assert res.method_used == "chebyshev"
        assert float(res.logabsdet) == pytest.approx(
            np.linalg.slogdet(a)[1], rel=0.1)
        assert np.isfinite(float(res.sem))


def test_service_warmup_then_zero_traces(rng):
    cfg = ServeConfig(buckets=(8, 16), max_batch=2, max_wait_ms=0.0,
                      default_method="exact")
    with LogdetService(cfg) as svc:
        svc.warmup()
        warm = svc.trace_count()
        assert warm > 0
        futs = [svc.submit(rng.standard_normal((n, n)))
                for n in (3, 8, 11, 16, 5)]
        for f in futs:
            assert np.isfinite(float(f.result(timeout=120).logabsdet))
        assert svc.trace_count() == warm


def test_service_drain_failure_fails_futures(rng, monkeypatch):
    cfg = ServeConfig(buckets=(8,), max_batch=2)
    svc = LogdetService(cfg)
    monkeypatch.setattr(svc, "_build_plan",
                        lambda *a: (_ for _ in ()).throw(RuntimeError("boom")))
    try:
        fut = svc.submit(np.eye(4))
        with pytest.raises(RuntimeError, match="boom"):
            fut.result(timeout=60)
    finally:
        svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(np.eye(4))


def test_service_close_fails_queued_requests(rng, monkeypatch):
    # Regression: a request still queued when the drain thread stops used
    # to be left with a forever-pending future, hanging any client blocked
    # in .result().  close() must fail it with ServiceClosed promptly.
    cfg = ServeConfig(buckets=(8,), max_batch=1, max_wait_ms=0.0)
    svc = LogdetService(cfg)
    entered, release = threading.Event(), threading.Event()

    def wedge(group):
        entered.set()
        release.wait(60)

    monkeypatch.setattr(svc, "_run_group", wedge)
    try:
        first = svc.submit(np.eye(4))
        assert entered.wait(30)          # drain popped `first` and wedged
        queued = svc.submit(np.eye(4))   # stays queued behind the wedge

        got = {}

        def client():
            try:
                got["res"] = queued.result(timeout=60)
            except Exception as exc:     # noqa: BLE001 — recorded for assert
                got["exc"] = exc

        t = threading.Thread(target=client)
        t.start()
        svc.close(timeout=0.2)           # wedged drain: join times out
        t.join(30)
        assert not t.is_alive(), "client is still blocked on a dead request"
        assert isinstance(got.get("exc"), ServiceClosed)
        with pytest.raises(ServiceClosed, match="closed"):
            svc.submit(np.eye(4))
    finally:
        release.set()                    # unwedge so the thread can exit
    # once the drain resumes and exits, the popped-but-unprocessed request
    # is failed too (drain-exit cleanup), not leaked
    with pytest.raises(ServiceClosed):
        first.result(timeout=30)


def test_service_submit_rejections(rng):
    cfg = ServeConfig(buckets=(8,), max_batch=2)
    with LogdetService(cfg) as svc:
        with pytest.raises(ValueError, match="exceeds the top bucket"):
            svc.submit(np.eye(9))
        with pytest.raises(ValueError, match="unknown method"):
            svc.submit(np.eye(4), method="nope")


def test_service_plan_dir_loads_aot(tmp_path, rng):
    """A plan_dir-backed service never traces — not even at warmup."""
    from repro.serve.__main__ import main as serve_main
    serve_main(["export", "--out", str(tmp_path), "--buckets", "8",
                "--max-batch", "2", "--method", "exact"])
    cfg = ServeConfig(buckets=(8,), max_batch=2, plan_dir=str(tmp_path),
                      default_method="exact")
    with LogdetService(cfg) as svc:
        svc.warmup()
        assert svc.trace_count() == 0
        a = rng.standard_normal((6, 6))
        res = svc.logdet(a, timeout=120)
        assert float(res.logabsdet) == pytest.approx(
            np.linalg.slogdet(a)[1], abs=1e-8)
        assert svc.trace_count() == 0


# ------------------------------------------------------------------ HTTP

def test_http_roundtrip(rng):
    from repro.serve.http import serve_http

    cfg = ServeConfig(buckets=(8,), max_batch=2, max_wait_ms=0.5)
    with LogdetService(cfg) as svc:
        server = serve_http(svc, port=0)
        port = server.server_address[1]
        t = threading.Thread(target=server.serve_forever, daemon=True)
        t.start()
        try:
            base = f"http://127.0.0.1:{port}"
            a = rng.standard_normal((6, 6)) + np.eye(6) * 4

            req = urllib.request.Request(
                f"{base}/v1/logdet",
                data=json.dumps({"matrix": a.tolist()}).encode(),
                headers={"Content-Type": "application/json"})
            with urllib.request.urlopen(req) as resp:
                assert resp.status == 200
                body = json.load(resp)
            assert body["logabsdet"] == pytest.approx(
                np.linalg.slogdet(a)[1], abs=1e-8)
            assert body["bucket"] == 8

            multi = urllib.request.Request(
                f"{base}/v1/logdet",
                data=json.dumps(
                    {"matrices": [a.tolist(), (2 * np.eye(3)).tolist()],
                     "method": "exact"}).encode())
            with urllib.request.urlopen(multi) as resp:
                results = json.load(resp)["results"]
            assert results[1]["logabsdet"] == pytest.approx(
                3 * np.log(2.0), abs=1e-10)

            with urllib.request.urlopen(f"{base}/healthz") as resp:
                assert json.load(resp)["status"] == "ok"
            with urllib.request.urlopen(f"{base}/stats") as resp:
                assert json.load(resp)["buckets"] == [8]

            bad = urllib.request.Request(
                f"{base}/v1/logdet",
                data=json.dumps({"matrix": [[1, 2, 3]]}).encode())
            with pytest.raises(urllib.error.HTTPError) as err:
                urllib.request.urlopen(bad)
            assert err.value.code == 400
            with pytest.raises(urllib.error.HTTPError) as err:
                urllib.request.urlopen(f"{base}/nope")
            assert err.value.code == 404
        finally:
            server.shutdown()


# -------------------------------------------------------------- quantile

def test_obs_quantile(metrics):
    for v in range(1, 101):
        obs.observe("q.test", float(v))
    assert obs.quantile("q.test", 0.5) == pytest.approx(50.5)
    assert obs.quantile("q.test", 0.99) == pytest.approx(99.01)
    assert obs.quantile("q.test", 0.0) == 1.0
    assert obs.quantile("q.test", 1.0) == 100.0
    assert obs.quantile("nothing.observed", 0.5) is None
    with pytest.raises(ValueError):
        obs.quantile("q.test", 1.5)
    # the histogram summary dict shape is unchanged (snapshot contract)
    h = obs.snapshot()["histograms"]["q.test"]
    assert h == {"count": 100.0, "sum": 5050.0, "min": 1.0, "max": 100.0}
