"""Regenerate the committed HLO parser fixtures.

Run from the repo root (single-device CPU is fine — the mesh kernels
lower with their collectives even at P=1):

    PYTHONPATH=src python tests/fixtures/hlo/regenerate.py

Each fixture is a raw lowering plus a ``.golden.tsv`` — the normalized
instruction table `repro.analysis.ir.Module.dump()` produces from it.
tests/test_analysis.py asserts parse(fixture).dump() == golden, so a
parser change that silently re-reads shapes/opcodes/scopes shows up as a
golden diff, reviewable in the PR.

Regenerate ONLY when the engine lowering or the dump format genuinely
changes; jax version bumps reprint text and will churn these files.
"""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from jax.sharding import Mesh  # noqa: E402

from repro.analysis.ir import parse_module  # noqa: E402
from repro.core.engine import EngineConfig, build_mesh, build_serial  # noqa: E402

HERE = pathlib.Path(__file__).parent
N, K = 16, 4


def emit(name: str, text: str) -> None:
    (HERE / f"{name}.txt").write_text(text)
    (HERE / f"{name}.golden.tsv").write_text(parse_module(text).dump())
    print(f"{name}: {len(text)} chars, "
          f"{len(parse_module(text).instructions)} instructions")


def main() -> None:
    a = jnp.eye(N)
    mesh = Mesh(np.array(jax.devices()[:1]), ("rows",))

    serial = jax.jit(build_serial(EngineConfig(schedule="serial",
                                               update="rank1")))
    emit("serial_rank1_stablehlo", serial.lower(a).as_text())

    mesh_fn = build_mesh(EngineConfig(schedule="mesh", update="rank1"), mesh)
    emit("mesh_rank1_stablehlo", mesh_fn.lower(a).as_text())

    la_fn = build_mesh(EngineConfig(schedule="mesh", update="panel",
                                    panel_k=K, lookahead=True), mesh)
    emit("mesh_panel_lookahead_hlo", la_fn.lower(a).compile().as_text())


if __name__ == "__main__":
    main()
