"""The unified condensation engine: every (schedule x update x backend)
route must agree with ``jnp.linalg.slogdet`` on sign AND logabsdet —
including permuted, negative-determinant and near-singular inputs — and
the legacy route strings must be pure aliases of engine instantiations.

This file runs under the CI deprecation gate (-W error::DeprecationWarning)
so nothing here may touch a legacy spelling unguarded.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro
from repro.core.engine import (
    EngineConfig, LEGACY_ROUTES, build_mesh, build_serial, engine_slogdet,
)

SCHEDULES_SERIAL = ("serial", "staged")
UPDATES = ("rank1", "panel")
BACKENDS = ("xla", "pallas")


def _cases():
    rng = np.random.default_rng(42)
    cases = {}
    cases["random"] = rng.standard_normal((48, 48))
    # odd size, big scale: exercises remainder steps + log-domain math
    cases["scaled_odd"] = rng.standard_normal((37, 37)) * 1e6
    # permutation matrix: det = +-1, sign tracking must be exact
    cases["permutation"] = np.eye(41)[rng.permutation(41)]
    # negative determinant: SPD with one negated row
    spd = rng.standard_normal((32, 64))
    spd = spd @ spd.T / 64 + 2.0 * np.eye(32)
    neg = spd.copy()
    neg[3] = -neg[3]
    cases["negative_det"] = neg
    # near-singular: rank-4 + tiny ridge (logabsdet very negative but finite)
    b = rng.standard_normal((24, 4))
    cases["near_singular"] = b @ b.T + 1e-10 * np.eye(24)
    return cases


CASES = _cases()


# near_singular sits at condition ~1e10: condensation and LAPACK may
# legitimately differ in the last ~6 bits of a very negative logabsdet
_CASE_RTOL = {"near_singular": 1e-5}


def assert_matches_ref(got, a, rtol=1e-9, case=None):
    s, ld = float(got[0]), float(got[1])
    s_ref, ld_ref = np.linalg.slogdet(np.asarray(a))
    assert s == pytest.approx(s_ref), (s, s_ref)
    rtol = max(rtol, _CASE_RTOL.get(case, 0.0))
    np.testing.assert_allclose(ld, ld_ref, rtol=rtol, atol=1e-8)


@pytest.mark.parametrize("case", sorted(CASES))
@pytest.mark.parametrize("update", UPDATES)
@pytest.mark.parametrize("schedule", SCHEDULES_SERIAL)
def test_serial_routes_match_slogdet(schedule, update, case):
    cfg = EngineConfig(schedule=schedule, update=update, panel_k=8,
                       min_size=16, backend="xla")
    a = jnp.asarray(CASES[case])
    if update == "panel":
        # panel routes factor full K-panels; plans pad — mirror that here
        from repro.core import pad_to_multiple
        a = pad_to_multiple(a, 8)
    assert_matches_ref(engine_slogdet(a, cfg), a, rtol=1e-8, case=case)


@pytest.mark.parametrize("update", UPDATES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_axis_matches_slogdet(update, backend, monkeypatch):
    """The Pallas hook path (interpret mode on CPU, forced via the env
    override) must agree with the XLA expressions digit for digit."""
    if backend == "pallas":
        monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    a = jnp.asarray(CASES["random"][:24, :24])
    cfg = EngineConfig(schedule="serial", update=update, panel_k=8,
                       backend=backend)
    assert_matches_ref(engine_slogdet(a, cfg), a, rtol=1e-8)


def test_staged_panel_combination_is_new_but_correct():
    """staged x panel had no legacy route string; it must still be a
    first-class engine point."""
    rng = np.random.default_rng(7)
    a = rng.standard_normal((200, 200))
    cfg = EngineConfig(schedule="staged", update="panel", panel_k=16,
                       min_size=32)
    assert_matches_ref(engine_slogdet(jnp.asarray(a), cfg), a, rtol=1e-8)


@pytest.mark.parametrize("update", UPDATES)
def test_mesh_routes_match_slogdet_one_device(update, mesh1):
    rng = np.random.default_rng(3)
    a = rng.standard_normal((24, 24))
    cfg = EngineConfig(schedule="mesh", update=update, panel_k=8)
    fn = build_mesh(cfg, mesh1)
    assert_matches_ref(fn(jnp.asarray(a)), a)


def test_mesh_route_validates_divisibility(mesh1):
    cfg = EngineConfig(schedule="mesh")
    fn = build_mesh(cfg, mesh1)
    fn(jnp.eye(8))                      # 8 % 1 == 0: fine
    with pytest.raises(ValueError, match="schedule"):
        build_serial(cfg)               # mesh cfg needs build_mesh
    with pytest.raises(ValueError, match="mesh"):
        engine_slogdet(jnp.eye(8), cfg)  # no mesh supplied


def test_engine_config_validation():
    with pytest.raises(ValueError, match="schedule"):
        EngineConfig(schedule="spiral")
    with pytest.raises(ValueError, match="update"):
        EngineConfig(update="rank3")
    with pytest.raises(ValueError, match="backend"):
        EngineConfig(backend="rocm")
    with pytest.raises(ValueError, match="shrink"):
        EngineConfig(shrink=1.5)


def test_legacy_route_table_covers_the_condensation_matrix():
    """Every non-mesh legacy route string denotes a serial engine point and
    reproduces it exactly (the step logic exists once)."""
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.standard_normal((40, 40)))
    from repro.core import pad_to_multiple
    for route, (schedule, update) in LEGACY_ROUTES.items():
        if schedule == "mesh":
            continue
        cfg = EngineConfig(schedule=schedule, update=update)
        x = pad_to_multiple(a, cfg.panel_k) if update == "panel" else a
        s, ld = engine_slogdet(x, cfg)
        s_ref, ld_ref = np.linalg.slogdet(np.asarray(a))
        assert float(s) == pytest.approx(s_ref), route
        np.testing.assert_allclose(float(ld), ld_ref, rtol=1e-8)


def test_legacy_wrappers_are_engine_aliases():
    """The historical module entry points must be the engine's functions,
    not copies — the acceptance criterion that the rank-1/panel step logic
    exists in exactly one module."""
    from repro.core import blocked, condense, engine, parallel
    assert condense.slogdet_condense is engine.condense_full
    assert condense.condense_steps is engine.condense_steps
    assert condense.combine_slogdet is engine.combine_slogdet
    assert blocked.panel_factor is engine.panel_factor
    assert blocked.apply_panel is engine.apply_panel
    assert blocked.slogdet_condense_blocked is engine.blocked_full
    assert parallel.mc_step_fn is engine.mc_step_fn
    assert parallel.mc_local_phase is engine.mc_local_phase


def test_shared_sign_helpers_back_the_baselines():
    from repro.core import engine, gaussian, scalapack
    assert gaussian.cyclic_perm is engine.cyclic_perm
    assert gaussian.perm_parity is engine.perm_parity
    perm = np.array([1, 0, 2])
    assert engine.perm_parity(perm) == -1.0
    assert engine.perm_parity(engine.cyclic_perm(8, 2)).__abs__() == 1.0


# ---------------------------------------------------------------------------
# lookahead: the pipelined mesh schedule must be bit-identical to the
# plain one and its factor stage must exist only when enabled
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", sorted(CASES))
@pytest.mark.parametrize("update", UPDATES)
def test_lookahead_bit_identical_one_device(update, case, mesh1):
    """lookahead=True reorders the schedule but never the arithmetic on
    the local block: (sign, logabsdet) must match bit for bit."""
    a = jnp.asarray(CASES[case])
    plain = build_mesh(
        EngineConfig(schedule="mesh", update=update, panel_k=8), mesh1)(a)
    la = build_mesh(
        EngineConfig(schedule="mesh", update=update, panel_k=8,
                     lookahead=True), mesh1)(a)
    assert float(la[0]) == float(plain[0]), case
    assert float(la[1]) == float(plain[1]), case


def test_lookahead_requires_mesh_schedule():
    with pytest.raises(ValueError, match="lookahead"):
        EngineConfig(schedule="staged", lookahead=True)
    from repro.core.configs import ExactConfig
    with pytest.raises(ValueError, match="lookahead"):
        ExactConfig(schedule="serial", lookahead=True)
    with pytest.raises(ValueError, match="mesh"):
        ExactConfig(lookahead=True).resolved(mesh_present=False)
    assert ExactConfig(lookahead=True).resolved(
        mesh_present=True).engine_config().lookahead


@pytest.mark.parametrize("update", UPDATES)
def test_lookahead_stage_only_when_enabled(update, mesh1):
    """The obs.stage("engine.lookahead_factor") named scope must reach the
    compiled HLO exactly when the flag is set — the structural half of
    the 'lookahead is real now' claim, certified by the `stage-coverage`
    analysis pass in BOTH directions: the flag-on program carries the
    stage, and auditing it under a flag-off claim fails (and vice versa —
    each program is the other's mutation proof).  n=32 with panel_k=8
    gives the panel kernel more than one full panel, so the pipelined
    loop body (where the stage lives) actually traces."""
    from repro.analysis import AuditContext, run_passes

    a = jnp.eye(32)
    cfgs = [EngineConfig(schedule="mesh", update=update, panel_k=8,
                         lookahead=la) for la in (False, True)]
    plain, la = (build_mesh(c, mesh1).lower(a).compile().as_text()
                 for c in cfgs)
    ctxs = [AuditContext(label=f"mesh|{update}|la={flag}", method="exact",
                         schedule="mesh", update=update, panel_k=8,
                         lookahead=flag, n=32, devices=1)
            for flag in (False, True)]
    pid = ("stage-coverage",)
    assert run_passes(plain, ctxs[0], pid).ok
    assert run_passes(la, ctxs[1], pid).ok
    # cross-audits: an inert flag or a phantom stage must be findings
    assert any(f.where == "engine.lookahead_factor"
               for f in run_passes(la, ctxs[0], pid).errors)
    assert any(f.where == "engine.lookahead_factor"
               for f in run_passes(plain, ctxs[1], pid).errors)


def test_lookahead_wrappers_accept_and_thread_the_flag(mesh1):
    """The historical wrappers must run the pipelined kernel silently —
    no stale UserWarning — and still reject unknown keywords."""
    import warnings
    from repro.core.blocked import parallel_slogdet_mc_blocked
    from repro.core.parallel import parallel_slogdet_mc
    rng = np.random.default_rng(9)
    a = jnp.asarray(rng.standard_normal((24, 24)))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        got_p = parallel_slogdet_mc_blocked(mesh1, k=8, lookahead=True)(a)
        got_r = parallel_slogdet_mc(mesh1, lookahead=True)(a)
    assert not [w for w in caught if "lookahead" in str(w.message)], caught
    assert_matches_ref(got_p, a)
    assert_matches_ref(got_r, a)
    # unknown inert kwargs are a TypeError, not silent acceptance
    with pytest.raises(TypeError):
        parallel_slogdet_mc_blocked(mesh1, lookbehind=True)
    with pytest.raises(TypeError):
        parallel_slogdet_mc(mesh1, lookbehind=True)


def test_mesh_tail_gathers_only_live_columns(mesh1):
    """The tail all_gather must move the (P,) live-column prefix, never
    full (N,) rows — 8*P^2 bytes on the wire, not 8*N*P.  Certified by
    the `collective-payload-budget` analysis pass, whose analytic bound
    encodes exactly this; the pass's own mutation proof (an artificially
    re-widened gather) lives in tests/test_analysis.py."""
    from repro.analysis import AuditContext, parse_module, run_passes

    n = 32
    fn = build_mesh(EngineConfig(schedule="mesh", update="rank1"), mesh1)
    txt = fn.lower(jnp.eye(n)).as_text()
    mod = parse_module(txt)
    gathers = [i for i in mod.collectives()
               if i.opcode.startswith("all-gather")]
    assert gathers, "tail all_gather missing from the lowered mesh kernel"
    report = run_passes(mod, AuditContext(
        label="mesh|rank1 fwd", method="exact", schedule="mesh",
        update="rank1", n=n, devices=1), ("collective-payload-budget",))
    assert report.ok, report.summary()


@pytest.mark.slow
def test_lookahead_bit_identical_across_devices():
    """Bit-identity of the pipelined schedule on real fake-device meshes:
    P in {2, 4, 8} x update x sign-stressing inputs."""
    from tests._subproc import run_with_devices, SRC
    out = run_with_devices(
        """
import sys; sys.path.insert(0, %r)
from repro.core.engine import EngineConfig, build_mesh
from repro._compat import make_mesh
rng = np.random.default_rng(13)
n = 48
cases = {
    "random": rng.standard_normal((n, n)),
    "permutation": np.eye(n)[rng.permutation(n)],
    "near_singular": None,
}
b = rng.standard_normal((n, 4))
cases["near_singular"] = b @ b.T + 1e-10 * np.eye(n)
neg = rng.standard_normal((n, n)); neg[5] = -neg[5]
cases["negative_det"] = neg
for P in (2, 4, 8):
    mesh = make_mesh((P,), ("rows",))
    for update in ("rank1", "panel"):
        for name, a in cases.items():
            k = dict(schedule="mesh", update=update, panel_k=8)
            s0, l0 = build_mesh(EngineConfig(**k), mesh)(a)
            s1, l1 = build_mesh(EngineConfig(**k, lookahead=True), mesh)(a)
            assert float(s0) == float(s1), (P, update, name)
            assert float(l0) == float(l1), (P, update, name)
print("OK")
""" % SRC,
        n_devices=8,
    )
    assert "OK" in out


@pytest.mark.slow
def test_engine_mesh_routes_eight_devices():
    """The unified engine on a real 8-fake-device mesh: round-robin
    schedule, both update modes, against numpy."""
    from tests._subproc import run_with_devices, SRC
    out = run_with_devices(
        """
import sys; sys.path.insert(0, %r)
import repro
from repro.core.engine import EngineConfig, build_mesh
from repro._compat import make_mesh
mesh = make_mesh((8,), ("rows",))
rng = np.random.default_rng(5)
for n in (64, 96):
    a = rng.standard_normal((n, n))
    s_ref, ld_ref = np.linalg.slogdet(a)
    for update in ("rank1", "panel"):
        cfg = EngineConfig(schedule="mesh", update=update, panel_k=4)
        s, ld = build_mesh(cfg, mesh)(jnp.asarray(a))
        assert float(s) == s_ref, (update, n, float(s), s_ref)
        assert abs(float(ld) - ld_ref) < 1e-8, (update, n, float(ld), ld_ref)
# diagnostics reflect execution: a serial route ignores the mesh
p_mesh = repro.plan((64, 64), method="exact", schedule="mesh", mesh=mesh)
p_serial = repro.plan((64, 64), method="exact", schedule="staged", mesh=mesh)
assert p_mesh.diagnostics.device_count == 8, p_mesh.diagnostics
assert p_serial.diagnostics.device_count == 1, p_serial.diagnostics
print("OK")
""" % SRC,
        n_devices=8,
    )
    assert "OK" in out


# ------------------------------------------------- fused one-pass steps

@pytest.mark.parametrize("case", sorted(CASES))
@pytest.mark.parametrize("update", UPDATES)
@pytest.mark.parametrize("schedule", SCHEDULES_SERIAL)
def test_fused_bit_identical(schedule, update, case):
    """fused=True collapses pivot/swap/update into one pass but reorders
    no arithmetic: (sign, logabsdet) must match the unfused engine bit
    for bit on every case, including permuted / negative-det /
    near-singular inputs."""
    a = jnp.asarray(CASES[case])
    if update == "panel":
        from repro.core import pad_to_multiple
        a = pad_to_multiple(a, 8)
    kw = dict(schedule=schedule, update=update, panel_k=8, min_size=16,
              backend="xla")
    plain = engine_slogdet(a, EngineConfig(**kw))
    fused = engine_slogdet(a, EngineConfig(fused=True, **kw))
    assert float(fused[0]) == float(plain[0]), case
    assert float(fused[1]) == float(plain[1]), case


@pytest.mark.parametrize("update", UPDATES)
def test_fused_interpret_backend_matches_slogdet(update, monkeypatch):
    """The fused Pallas kernel (interpret mode on CPU, forced via the env
    override) must still produce a correct logdet on odd-size input."""
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    a = jnp.asarray(CASES["scaled_odd"])
    if update == "panel":
        from repro.core import pad_to_multiple
        a = pad_to_multiple(a, 8)
    cfg = EngineConfig(schedule="staged", update=update, panel_k=8,
                       min_size=16, fused=True, backend="auto")
    assert_matches_ref(engine_slogdet(a, cfg), a, rtol=1e-8,
                       case="scaled_odd")


@pytest.mark.parametrize("case", ["random", "negative_det"])
def test_bf16_precision_error_model(case):
    """precision='bf16' quantizes GEMM operands only: the sign must stay
    exact and logabsdet within the documented |rel err| <= 5e-3 of the
    full-precision engine at these sizes (measured 4e-4..2e-3); fused and unfused bf16 routes
    agree bit for bit (same quantization points)."""
    a = jnp.asarray(CASES[case], jnp.float32)
    from repro.core import pad_to_multiple
    a = pad_to_multiple(a, 8)
    kw = dict(schedule="staged", update="panel", panel_k=8, min_size=16,
              backend="xla")
    exact = engine_slogdet(a, EngineConfig(**kw))
    mixed = engine_slogdet(a, EngineConfig(precision="bf16", **kw))
    assert float(mixed[0]) == float(exact[0]), "sign must survive bf16"
    rel = abs(float(mixed[1]) - float(exact[1])) / abs(float(exact[1]))
    assert rel < 5e-3, (case, rel)
    mixed_fused = engine_slogdet(
        a, EngineConfig(fused=True, precision="bf16", **kw))
    assert float(mixed_fused[0]) == float(mixed[0])
    assert float(mixed_fused[1]) == float(mixed[1])


def test_fused_requires_serial_schedule():
    with pytest.raises(ValueError, match="fused"):
        EngineConfig(schedule="mesh", fused=True)
    from repro.core.configs import ExactConfig
    with pytest.raises(ValueError, match="fused"):
        ExactConfig(fused=True).resolved(mesh_present=True)
    # serial resolution keeps the flag
    assert ExactConfig(fused=True).resolved(
        mesh_present=False).engine_config().fused
    with pytest.raises(ValueError, match="precision"):
        EngineConfig(precision="fp8")


@pytest.mark.parametrize("update", UPDATES)
def test_fused_stage_only_when_enabled(update):
    """Mirror of the lookahead stage-coverage proof: the compiled program
    must carry engine.fused_step exactly when fused=True (and then drop
    engine.pivot/swap/update), certified by the stage-coverage pass in
    both directions so an inert flag or a phantom stage is a finding."""
    from repro.analysis import AuditContext, run_passes

    a = jnp.eye(32)
    cfgs = [EngineConfig(schedule="staged", update=update, panel_k=8,
                         min_size=16, fused=f) for f in (False, True)]
    plain, fused = (jax.jit(lambda x, c=c: engine_slogdet(x, c))
                    .lower(a).compile().as_text() for c in cfgs)
    ctxs = [AuditContext(label=f"staged|{update}|fused={flag}",
                         method="exact", schedule="staged", update=update,
                         panel_k=8, fused=flag, n=32, devices=1)
            for flag in (False, True)]
    pid = ("stage-coverage",)
    assert run_passes(plain, ctxs[0], pid).ok
    assert run_passes(fused, ctxs[1], pid).ok
    assert any(f.where == "engine.fused_step"
               for f in run_passes(fused, ctxs[0], pid).errors)
    assert any(f.where == "engine.fused_step"
               for f in run_passes(plain, ctxs[1], pid).errors)
