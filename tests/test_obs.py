"""repro.obs — spans, metrics, telemetry, exporters, and the off-mode
overhead contract.

Everything here runs in-process by flipping the obs mode with
``obs.configure``; the ``obs_state`` fixture restores ``off`` and clears
all buffers around every test so the rest of the suite sees the default
(uninstrumented) behaviour.  Tests that execute plans use matrix sizes
unique to this file (37/41/43/47/53/59/61) so the module-level jit caches
never serve a stale trace from another test.
"""
import json
import urllib.error
import urllib.request

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro
from repro import obs
from repro.core.plan import clear_plan_cache


@pytest.fixture(autouse=True)
def obs_state():
    """Reset obs to a clean 'off' state before and after each test."""
    obs.reset()
    obs.configure("off")
    yield
    obs.reset()
    obs.configure("off")


def _spd(n, seed=0):
    rng = np.random.default_rng(seed)
    m = rng.standard_normal((n, n))
    return jnp.asarray(m @ m.T + n * np.eye(n))


# ------------------------------------------------------------------ config
def test_default_mode_off():
    assert obs.mode() == "off"
    assert not obs.metrics_enabled()
    assert not obs.trace_enabled()


def test_configure_rejects_unknown_mode():
    with pytest.raises(ValueError, match="choose one of"):
        obs.configure("verbose")


def test_mode_levels():
    obs.configure("metrics")
    assert obs.metrics_enabled() and not obs.trace_enabled()
    obs.configure("trace")
    assert obs.metrics_enabled() and obs.trace_enabled()


# ------------------------------------------------------------------- spans
def test_span_noop_when_off():
    with obs.span("outer"):
        with obs.span("inner"):
            pass
    assert obs.events() == []


def test_span_nesting_and_ordering():
    obs.configure("trace")
    with obs.span("outer"):
        with obs.span("inner.a"):
            pass
        with obs.span("inner.b"):
            pass
    evs = obs.events()
    by_name = {e["name"]: e for e in evs}
    # children are recorded on exit, before the parent
    assert [e["name"] for e in evs] == ["inner.a", "inner.b", "outer"]
    assert by_name["outer"]["depth"] == 0
    assert by_name["inner.a"]["depth"] == 1
    assert by_name["inner.b"]["depth"] == 1
    # time containment: children inside the parent interval
    o = by_name["outer"]
    for child in ("inner.a", "inner.b"):
        c = by_name[child]
        assert c["ts"] >= o["ts"]
        assert c["ts"] + c["dur"] <= o["ts"] + o["dur"] + 1e-3
    # siblings ordered in time
    assert by_name["inner.a"]["ts"] <= by_name["inner.b"]["ts"]


def test_span_sync_blocks_even_when_off():
    """span(sync=...) must block on device work in EVERY mode, so the
    wall times reported on Diagnostics never measure dispatch alone."""
    blocked = []

    class Fake:
        def block_until_ready(self):
            blocked.append(1)
            return self

    with obs.span("timed", sync=Fake()):
        pass
    assert blocked, "sync value was not blocked on with obs off"


def test_stage_is_named_scope_when_off():
    # with obs off, stage() must still be a usable context manager (it is
    # the bare jax.named_scope) and must record nothing
    with obs.stage("engine.pivot"):
        pass
    assert obs.events() == []


def test_stage_records_event_in_trace_mode():
    obs.configure("trace")
    with obs.stage("engine.pivot", k=3):
        pass
    evs = obs.events()
    assert len(evs) == 1 and evs[0]["name"] == "engine.pivot"
    assert evs[0]["cat"] == "stage"


# ----------------------------------------------------------------- metrics
def test_metrics_noop_when_off():
    obs.inc("x")
    obs.set_gauge("g", 1.0)
    obs.observe("h", 2.0)
    snap = obs.snapshot()
    assert snap == {"counters": {}, "gauges": {}, "histograms": {}}


def test_counters_gauges_histograms():
    obs.configure("metrics")
    obs.inc("hits")
    obs.inc("hits", 2)
    obs.set_gauge("flops", 1e9, method="exact")
    for v in (1.0, 3.0, 2.0):
        obs.observe("iters", v)
    snap = obs.snapshot()
    assert snap["counters"]["hits"] == 3.0
    assert snap["gauges"]["flops{method=exact}"] == 1e9
    h = snap["histograms"]["iters"]
    assert h == {"count": 3.0, "sum": 6.0, "min": 1.0, "max": 3.0}
    assert obs.counter_value("hits") == 3.0
    assert obs.counter_value("never.touched") == 0.0


def test_prometheus_text_format():
    obs.configure("metrics")
    obs.inc("plan.cache.hits")
    obs.set_gauge("serve.tok_per_s", 12.5, arch="a-b")
    obs.observe("cg.iters", 7.0)
    text = obs.prometheus_text()
    assert "# TYPE repro_plan_cache_hits_total counter" in text
    assert "repro_plan_cache_hits_total 1" in text
    assert 'repro_serve_tok_per_s{arch="a-b"} 12.5' in text
    assert "repro_cg_iters_count 1" in text
    assert "repro_cg_iters_sum 7" in text
    assert text.endswith("\n")


def test_metrics_http_endpoint():
    obs.configure("metrics")
    obs.inc("serve.requests")
    server = obs.start_metrics_server(0)
    try:
        port = server.server_address[1]
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics", timeout=10) as r:
            body = r.read().decode()
            ctype = r.headers.get("Content-Type", "")
        assert "repro_serve_requests_total 1" in body
        assert "text/plain" in ctype
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://127.0.0.1:{port}/nope", timeout=10)
    finally:
        server.shutdown()


# ----------------------------------------------------------- plan counters
def test_plan_cache_hit_miss_counters():
    obs.configure("metrics")
    clear_plan_cache()
    a = _spd(41)
    repro.plan(a, method="exact")
    assert obs.counter_value("plan.cache.misses") == 1.0
    assert obs.counter_value("plan.cache.hits") == 0.0
    repro.plan(a, method="exact")
    assert obs.counter_value("plan.cache.misses") == 1.0
    assert obs.counter_value("plan.cache.hits") == 1.0


def test_cached_plan_does_not_retrace():
    obs.configure("metrics")
    clear_plan_cache()
    a = _spd(43)
    p = repro.plan(a, method="exact")
    before = obs.counter_value("plan.retraces")
    for _ in range(3):
        p(a)
    assert p.trace_count == 1
    assert obs.counter_value("plan.retraces") == before
    assert obs.counter_value("plan.executions", method="exact") == 3.0


def test_deprecated_shim_counter():
    obs.configure("metrics")
    a = _spd(37, seed=1)
    with pytest.warns(DeprecationWarning):
        repro.core.slogdet(a, method="ge")
    assert obs.counter_value("compat.deprecated", fn="slogdet") == 1.0


# --------------------------------------------------------------- telemetry
def test_running_sem_matches_numpy():
    x = jnp.asarray(np.random.default_rng(3).standard_normal(16))
    sem = np.asarray(obs.running_sem(x))
    assert sem.shape == (16,)
    assert np.isinf(sem[0])
    for j in (2, 7, 16):
        ref = np.std(np.asarray(x)[:j], ddof=1) / np.sqrt(j)
        assert sem[j - 1] == pytest.approx(ref, rel=1e-6)


def test_convergence_trace_length_equals_probes():
    obs.configure("trace")
    a = _spd(47)
    p = repro.plan(a, method="chebyshev", num_probes=8)
    res = p(a)
    conv = res.diagnostics.convergence
    assert conv is not None and "chebyshev.sem" in conv
    assert len(conv["chebyshev.sem"]) == 8
    # curve is a running sem: entry 0 has no spread estimate
    assert not np.isfinite(conv["chebyshev.sem"][0])
    assert all(np.isfinite(v) for v in conv["chebyshev.sem"][1:])


def test_slq_convergence_trace():
    obs.configure("trace")
    a = _spd(53)
    p = repro.plan(a, method="slq", num_probes=6)
    res = p(a)
    conv = res.diagnostics.convergence
    assert conv is not None
    assert len(conv["slq.sem"]) == 6


def test_no_convergence_when_off():
    a = _spd(47, seed=2)
    p = repro.plan(a, method="chebyshev", num_probes=4)
    res = p(a)
    assert res.diagnostics.convergence is None


def test_cg_residual_stream():
    from repro.estimators.operators.solve import cg_solve

    obs.configure("trace")
    a = _spd(37, seed=4)
    b = jnp.ones((37,), a.dtype)
    cg_solve(a, b, tol=1e-8)
    obs.flush_telemetry()
    streams = obs.drain_telemetry()
    resid = streams.get("cg.resnorm")
    assert resid, "CG emitted no residual telemetry"
    # converged: final residual far below the first
    assert resid[-1] < 1e-6 * max(resid[0], 1.0)


# ------------------------------------------------- off-mode overhead (HLO)
def test_hlo_has_no_callbacks_when_off():
    """The `no-host-callback` analysis pass certifies obs-off programs
    are structurally callback-free (the shared form of the old grep)."""
    from repro.analysis import AuditContext, run_passes
    from repro.estimators.chebyshev import logdet_chebyshev

    a = _spd(41, seed=5)

    def f(x):
        return logdet_chebyshev(x, degree=8, num_probes=4)[0]

    txt = jax.jit(f).lower(a).as_text()
    report = run_passes(txt, AuditContext(method="chebyshev",
                                          obs_mode="off"),
                        ("no-host-callback",))
    assert report.ok, report.summary()


def test_hlo_has_callbacks_when_tracing():
    """Trace mode plants callbacks — and auditing that program under an
    obs-off claim must FAIL, which is the pass's mutation proof."""
    from repro.analysis import AuditContext, run_passes
    from repro.estimators.chebyshev import logdet_chebyshev

    obs.configure("trace")
    a = _spd(41, seed=6)

    def f(x):
        return logdet_chebyshev(x, degree=8, num_probes=4)[0]

    txt = jax.jit(f).lower(a).as_text()
    assert "callback" in txt.lower()
    report = run_passes(txt, AuditContext(method="chebyshev",
                                          obs_mode="off"),
                        ("no-host-callback",))
    assert not report.ok, "trace-mode callbacks invisible to the pass"
    # ...while a truthful trace-mode context accepts the same program
    assert run_passes(txt, AuditContext(method="chebyshev",
                                        obs_mode="trace"),
                      ("no-host-callback",)).ok


# ------------------------------------------------------- wall-time honesty
def test_timeit_blocks_on_device_work():
    """benchmarks._common.timeit must include device time, not dispatch:
    jax.block_until_ready recurses into any object exposing
    block_until_ready, so a sleeping fake is indistinguishable from an
    unfinished device buffer."""
    import time as _time

    from benchmarks._common import timeit

    calls = []

    class Slow:
        def block_until_ready(self):
            _time.sleep(0.02)
            calls.append(1)
            return self

    t = timeit(lambda: Slow(), warmup=1, iters=3)
    assert len(calls) == 4          # every call blocked, warmup included
    assert t >= 0.015               # median reflects the "device" time


# --------------------------------------------------------------- exporters
def test_chrome_trace_export_and_validate(tmp_path):
    obs.configure("trace")
    with obs.span("plan.build"):
        with obs.stage("engine.pivot"):
            pass
    path = tmp_path / "trace.json"
    obs.export_chrome_trace(path)
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    for ev in doc["traceEvents"]:
        assert ev["ph"] == "X"
        assert set(ev) >= {"name", "cat", "ts", "dur", "pid", "tid"}
    info = obs.validate_chrome_trace(path)
    assert set(info["names"]) >= {"plan.build", "engine.pivot"}
    assert info["max_depth"] >= 1


def test_validate_rejects_garbage(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text('{"traceEvents": [{"ph": "X"}]}')
    with pytest.raises(ValueError):
        obs.validate_chrome_trace(bad)


def test_write_all_artifacts(tmp_path):
    obs.configure("trace")
    with obs.span("plan.build"):
        pass
    obs.inc("plan.cache.misses")
    paths = obs.write_all(tmp_path)
    written = {p.name for p in tmp_path.iterdir()}
    assert {"trace.json", "events.jsonl", "metrics.prom"} <= written
    assert paths
    lines = [json.loads(ln) for ln in
             (tmp_path / "events.jsonl").read_text().splitlines()]
    assert any(rec.get("name") == "plan.build" for rec in lines)


def test_plan_execute_trace_end_to_end(tmp_path):
    """The acceptance path in miniature: plan build/execute under trace
    produces a validating Chrome trace with nested engine stages."""
    obs.configure("trace")
    clear_plan_cache()
    a = _spd(59)
    p = repro.plan(a, method="exact")
    p(a)
    path = tmp_path / "trace.json"
    obs.export_chrome_trace(path)
    info = obs.validate_chrome_trace(path)
    names = set(info["names"])
    assert {"plan.build", "plan.compile", "plan.execute"} <= names
    assert any(n.startswith("engine.") for n in names)
    assert info["max_depth"] >= 1


def test_explain_reports_execution_and_obs_state():
    obs.configure("metrics")
    clear_plan_cache()
    a = _spd(61)
    p = repro.plan(a, method="exact")
    p(a)
    txt = p.explain()
    assert "LogdetPlan[exact]" in txt
    assert "traces: 1" in txt
    assert "RETRACED" not in txt


# ------------------------------------------------------------- former inert knobs
def test_lookahead_no_longer_warns(mesh1):
    """lookahead is implemented now: requesting it must be silent, the
    default path stays silent, and unknown kwargs are a TypeError (no
    silent-acceptance signature-compat surface left)."""
    from repro.core.blocked import parallel_slogdet_mc_blocked
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        parallel_slogdet_mc_blocked(mesh1, lookahead=True)
        parallel_slogdet_mc_blocked(mesh1)
    with pytest.raises(TypeError):
        parallel_slogdet_mc_blocked(mesh1, lookahed=True)  # typo'd knob


# ------------------------------------------------------------- environment
def test_env_var_drives_mode_and_artifacts(tmp_path):
    """REPRO_OBS=trace in the environment: spans recorded with no code
    changes and artifacts dumped at interpreter exit."""
    import os
    import subprocess
    import sys

    from tests._subproc import SRC

    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["REPRO_OBS"] = "trace"
    env["REPRO_OBS_DIR"] = str(tmp_path)
    code = (
        "import numpy as np, jax.numpy as jnp, repro\n"
        "m = np.random.default_rng(0).standard_normal((24, 24))\n"
        "a = jnp.asarray(m @ m.T + 24 * np.eye(24))\n"
        "p = repro.plan(a, method='exact')\n"
        "print(p(a).logabsdet)\n"
    )
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    info = obs.validate_chrome_trace(tmp_path / "trace.json")
    assert {"plan.build", "plan.execute"} <= set(info["names"])
    assert (tmp_path / "metrics.prom").read_text().strip()


def test_bad_env_value_is_a_hard_error():
    import os
    import subprocess
    import sys

    from tests._subproc import SRC

    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["REPRO_OBS"] = "loud"
    proc = subprocess.run(
        [sys.executable, "-c", "import repro.obs"], env=env,
        capture_output=True, text=True, timeout=600)
    assert proc.returncode != 0
    assert "REPRO_OBS" in proc.stderr
