"""Edge cases of the public API: pad_to_multiple embedding and slogdet on
degenerate inputs (N=0, N=1, non-square, unknown method, singular),
checked for numpy.linalg.slogdet consistency."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import METHODS, pad_to_multiple, slogdet, slogdet_condense


# ---------------------------------------------------------- pad_to_multiple

@pytest.mark.parametrize("n,mult", [(5, 4), (1, 8), (7, 7), (12, 5)])
def test_pad_to_multiple_shape_and_values(n, mult, rng):
    a = jnp.asarray(rng.standard_normal((n, n)))
    out = pad_to_multiple(a, mult)
    n_pad = -(-n // mult) * mult
    assert out.shape == (n_pad, n_pad)
    np.testing.assert_array_equal(np.asarray(out[:n, :n]), np.asarray(a))
    tail = np.asarray(out[n:, n:])
    np.testing.assert_array_equal(tail, np.eye(n_pad - n))
    assert not np.asarray(out[:n, n:]).any()
    assert not np.asarray(out[n:, :n]).any()


def test_pad_to_multiple_noop_when_divisible(rng):
    a = jnp.asarray(rng.standard_normal((8, 8)))
    assert pad_to_multiple(a, 4) is a


def test_pad_to_multiple_preserves_logdet(rng):
    a = rng.standard_normal((10, 10))
    s_ref, ld_ref = np.linalg.slogdet(a)
    s, ld = slogdet_condense(pad_to_multiple(jnp.asarray(a), 8))
    assert float(s) == pytest.approx(s_ref)
    np.testing.assert_allclose(float(ld), ld_ref, rtol=1e-9)


def test_pad_to_multiple_empty():
    out = pad_to_multiple(jnp.zeros((0, 0)), 4)
    assert out.shape == (0, 0)


# ---------------------------------------------------------------- slogdet

def test_slogdet_empty_matrix():
    """det of the 0x0 matrix is 1 (empty product) — numpy semantics."""
    s_ref, ld_ref = np.linalg.slogdet(np.zeros((0, 0)))
    s, ld = slogdet(np.zeros((0, 0)), method="mc")
    assert float(s) == s_ref == 1.0
    assert float(ld) == ld_ref == 0.0


@pytest.mark.parametrize("val", [2.5, -3.0, 1e-30])
def test_slogdet_one_by_one(val):
    s_ref, ld_ref = np.linalg.slogdet(np.array([[val]]))
    s, ld = slogdet(np.array([[val]]), method="mc")
    assert float(s) == pytest.approx(s_ref)
    np.testing.assert_allclose(float(ld), ld_ref, rtol=1e-12)


@pytest.mark.parametrize("shape", [(3, 4), (4, 3), (4,), (2, 2, 2)])
def test_slogdet_rejects_non_square(shape):
    with pytest.raises(ValueError, match="square"):
        slogdet(np.zeros(shape))


def test_slogdet_rejects_unknown_method():
    with pytest.raises(ValueError, match="unknown method"):
        slogdet(np.eye(4), method="cholesky")


def test_slogdet_method_list_is_exhaustive():
    """Every advertised method must dispatch (mesh-less ones here)."""
    a = np.eye(6) * 2.0
    for method in METHODS:
        if method in ("pmc", "pmc_blocked", "pge", "plu"):
            with pytest.raises(ValueError, match="mesh"):
                slogdet(a, method=method)
            continue
        s, ld = slogdet(a, method=method)
        np.testing.assert_allclose(float(ld), 6 * np.log(2.0), rtol=1e-2)


def test_slogdet_singular_consistency():
    """Singular input: numpy returns (0, -inf); condensation's static-shape
    pipeline must agree up to roundoff (sign 0 or logdet -> -inf/very small).
    """
    a = np.ones((8, 8))
    s_ref, ld_ref = np.linalg.slogdet(a)
    assert s_ref == 0.0 and ld_ref == -np.inf
    s, ld = slogdet(a, method="mc")
    assert float(ld) == -np.inf or float(ld) < -30
    if float(ld) == -np.inf:
        assert float(s) == 0.0


def test_logdet_discards_sign():
    from repro.core import logdet
    a = -np.eye(3)  # det = -1, log|det| = 0
    np.testing.assert_allclose(float(logdet(a, method="mc")), 0.0, atol=1e-12)
