"""Hypothesis property tests for the condensation core (the paper claims 10
significant digits in f64 — we assert tighter) and its gradient rule
(``grad(logdet) == inv(A).T`` for random SPD and non-symmetric inputs,
invariant under diag(A, I) padding).

Kept separate from tests/test_condense.py so a clean environment without
``hypothesis`` still collects and runs the deterministic suite; here the
whole module is skipped via ``pytest.importorskip``.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import (
    logdet,
    pad_to_multiple,
    slogdet_condense,
    slogdet_condense_blocked,
    slogdet_condense_staged,
    slogdet_ge,
)
from tests.test_condense import assert_slogdet_close


@st.composite
def square_matrices(draw, max_n=48):
    n = draw(st.integers(min_value=1, max_value=max_n))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    scale = draw(st.sampled_from([1e-6, 1.0, 1e6]))
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, n)) * scale


@settings(max_examples=40, deadline=None)
@given(square_matrices())
def test_condense_matches_numpy(a):
    assert_slogdet_close(slogdet_condense(a), np.linalg.slogdet(a))


@settings(max_examples=20, deadline=None)
@given(square_matrices())
def test_ge_matches_numpy(a):
    assert_slogdet_close(slogdet_ge(a), np.linalg.slogdet(a))


@settings(max_examples=15, deadline=None)
@given(square_matrices(max_n=96))
def test_staged_matches_numpy(a):
    got = slogdet_condense_staged(a, min_size=16)
    assert_slogdet_close(got, np.linalg.slogdet(a))


@settings(max_examples=15, deadline=None)
@given(square_matrices(max_n=80), st.sampled_from([4, 8, 16]))
def test_blocked_matches_numpy(a, k):
    got = slogdet_condense_blocked(a, k=k)
    assert_slogdet_close(got, np.linalg.slogdet(a), rtol=1e-8, atol=1e-8)


# ------------------------------------------------------------- gradients
#
# The custom VJPs (repro/estimators/grad.py) must reproduce the analytic
# d log|det A| / dA = A^{-T} for any invertible input — SPD or not — and
# padding through diag(A, I) must leave the embedded block's gradient
# untouched.  Well-conditioned strategies keep inv(A) numerically clean so
# the comparison tests the rule, not the conditioning.


@st.composite
def well_conditioned_spd(draw, max_n=24):
    n = draw(st.integers(min_value=1, max_value=max_n))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    shift = draw(st.sampled_from([1.0, 2.0, 5.0]))
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, 2 * n))
    return x @ x.T / (2 * n) + shift * np.eye(n)


@st.composite
def well_conditioned_nonsym(draw, max_n=24):
    n = draw(st.integers(min_value=1, max_value=max_n))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    # diagonally dominated: eigenvalues near 2, far from singular
    return rng.standard_normal((n, n)) * (0.5 / np.sqrt(n)) + 2.0 * np.eye(n)


@settings(max_examples=15, deadline=None)
@given(well_conditioned_spd(), st.sampled_from(["mc", "ge"]))
def test_grad_logdet_is_inverse_transpose_spd(a, method):
    g = jax.grad(lambda x: logdet(x, method=method))(jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(g), np.linalg.inv(a).T,
                               rtol=1e-7, atol=1e-9)


@settings(max_examples=15, deadline=None)
@given(well_conditioned_nonsym(), st.sampled_from(["mc", "ge"]))
def test_grad_logdet_is_inverse_transpose_nonsym(a, method):
    g = jax.grad(lambda x: logdet(x, method=method))(jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(g), np.linalg.inv(a).T,
                               rtol=1e-7, atol=1e-9)


@settings(max_examples=10, deadline=None)
@given(well_conditioned_nonsym(max_n=16), st.sampled_from([4, 8, 7]))
def test_grad_unchanged_by_padding(a, mult):
    """diag(A, I) embedding: the padded logdet's gradient with respect to
    the embedded block equals the unpadded gradient."""
    a = jnp.asarray(a)
    g_plain = jax.grad(lambda x: logdet(x, method="mc"))(a)
    g_pad = jax.grad(
        lambda x: logdet(pad_to_multiple(x, mult), method="mc"))(a)
    np.testing.assert_allclose(np.asarray(g_pad), np.asarray(g_plain),
                               rtol=1e-8, atol=1e-10)
