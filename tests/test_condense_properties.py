"""Hypothesis property tests for the condensation core (the paper claims 10
significant digits in f64 — we assert tighter).

Kept separate from tests/test_condense.py so a clean environment without
``hypothesis`` still collects and runs the deterministic suite; here the
whole module is skipped via ``pytest.importorskip``.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    slogdet_condense,
    slogdet_condense_blocked,
    slogdet_condense_staged,
    slogdet_ge,
)
from tests.test_condense import assert_slogdet_close


@st.composite
def square_matrices(draw, max_n=48):
    n = draw(st.integers(min_value=1, max_value=max_n))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    scale = draw(st.sampled_from([1e-6, 1.0, 1e6]))
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, n)) * scale


@settings(max_examples=40, deadline=None)
@given(square_matrices())
def test_condense_matches_numpy(a):
    assert_slogdet_close(slogdet_condense(a), np.linalg.slogdet(a))


@settings(max_examples=20, deadline=None)
@given(square_matrices())
def test_ge_matches_numpy(a):
    assert_slogdet_close(slogdet_ge(a), np.linalg.slogdet(a))


@settings(max_examples=15, deadline=None)
@given(square_matrices(max_n=96))
def test_staged_matches_numpy(a):
    got = slogdet_condense_staged(a, min_size=16)
    assert_slogdet_close(got, np.linalg.slogdet(a))


@settings(max_examples=15, deadline=None)
@given(square_matrices(max_n=80), st.sampled_from([4, 8, 16]))
def test_blocked_matches_numpy(a, k):
    got = slogdet_condense_blocked(a, k=k)
    assert_slogdet_close(got, np.linalg.slogdet(a), rtol=1e-8, atol=1e-8)
