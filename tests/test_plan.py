"""The plan/execute API: `repro.plan` -> `LogdetPlan` -> `LogdetResult`.

This file is the deprecation gate's target: it exercises ONLY the new API
(plus `pytest.warns`-guarded shim checks), so CI runs it with
``-W error::DeprecationWarning`` to prove internal code never routes
through the legacy string shims.

Covers: typed config validation, the auto-selector's crossover (exact for
small dense N, estimators for large N / implicit operators, mesh-aware),
the unified `LogdetResult` across every path, the non-SPD screen, plan
caching / no-retrace behavior, and diagnostics-rich gradients.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro
from repro import (
    ChebyshevConfig, EngineConfig, ExactConfig, LogdetResult, SLQConfig,
    select_method, select_route,
)
from repro.estimators import StencilOperator, ToeplitzOperator


def make_spd(n, seed, shift=2.0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, 2 * n))
    return x @ x.T / (2 * n) + shift * np.eye(n)


def spec_with_devices(n, devices):
    return dataclasses.replace(repro.spec_of((n, n)), device_count=devices)


# ------------------------------------------------------------ typed configs

def test_config_validation_rejects_bad_values():
    with pytest.raises(ValueError, match="degree"):
        ChebyshevConfig(degree=0)
    with pytest.raises(ValueError, match="num_probes"):
        SLQConfig(num_probes=0)
    with pytest.raises(ValueError, match="lmax"):
        ChebyshevConfig(lmin=4.0, lmax=1.0)
    with pytest.raises(ValueError, match="k must be"):
        ExactConfig(k=0)
    with pytest.raises(ValueError, match="schedule"):
        ExactConfig(schedule="diagonal")
    with pytest.raises(ValueError, match="update"):
        ExactConfig(update="rank2")
    with pytest.raises(ValueError, match="backend"):
        ExactConfig(backend="cuda")
    with pytest.raises(ValueError, match="schedule"):
        EngineConfig(schedule="bogus")
    with pytest.raises(ValueError, match="panel_k"):
        EngineConfig(panel_k=0)


def test_plan_rejects_unknown_and_misfiled_kwargs():
    with pytest.raises(TypeError, match="estimator keywords"):
        repro.plan((8, 8), method="exact", num_probes=4)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        with pytest.raises(TypeError, match="estimator keywords"):
            repro.plan((8, 8), method="mc", num_probes=4)
    with pytest.raises(TypeError, match="unknown keywords"):
        repro.plan((8, 8), method="chebyshev", num_steps=10)
    with pytest.raises(TypeError, match="unknown keywords"):
        repro.plan((8, 8), method="slq", degree=16)
    with pytest.raises(ValueError, match="unknown method"):
        repro.plan((8, 8), method="cholesky")


def test_plan_config_instance_must_match_method():
    p = repro.plan((8, 8), method="slq", config=SLQConfig(num_steps=5))
    assert p.config.num_steps == 5
    with pytest.raises(TypeError, match="SLQConfig"):
        repro.plan((8, 8), method="slq", config=ChebyshevConfig())
    with pytest.raises(TypeError, match="not both"):
        repro.plan((8, 8), method="slq", config=SLQConfig(), num_probes=4)
    with pytest.raises(ValueError, match="ambiguous"):
        repro.plan((8, 8), method="auto", config=SLQConfig())


# ------------------------------------------------------------- auto select

def test_auto_picks_exact_below_crossover():
    assert select_method((64, 64)) == "exact"
    assert select_method((512, 512)) == "exact"
    # batched small stacks: vmapped exact condensation per matrix
    assert select_method((8, 64, 64)) == "exact"


def test_auto_resolves_route_tuples_not_strings():
    """The selector answers with an EngineConfig tuple for the exact
    family — and the tuple's axes respond to the problem shape."""
    m, route = select_route((64, 64))
    assert m == "exact" and isinstance(route, EngineConfig)
    # small N: the autotuner narrows panels (k=8) so rank-K updates
    # amortize even here — fixed-width 32 used to force rank-1
    assert route.update in ("rank1", "panel")
    assert route.schedule in ("serial", "staged")
    if route.update == "panel":
        assert route.panel_k <= 16, route.panel_k
    # large single-device exact work rides the MXU: panel updates
    m2, route2 = select_route((2048, 2048), rtol=1e-9)
    assert m2 == "exact" and route2.update == "panel"
    # batched stacks run the vmapped serial schedule, never mesh
    m3, route3 = select_route((8, 64, 64))
    assert m3 == "exact" and route3.schedule == "serial"
    # estimator picks carry no engine tuple
    m4, route4 = select_route((8192, 8192))
    assert m4 == "slq" and route4 is None


def test_auto_picks_estimator_above_crossover():
    assert select_method((8192, 8192)) == "slq"
    assert select_method((4, 8192, 8192)) == "slq"
    # known spectral bounds unlock the cheaper Chebyshev path
    assert select_method((8192, 8192), bounds_known=True) == "chebyshev"


def test_auto_picks_estimator_for_implicit_operators():
    # structure makes the matvec cheap AND the matrix unmaterializable:
    # estimators are the only family, at any size
    op = ToeplitzOperator(jnp.asarray(np.r_[2.5, 0.5 ** np.arange(1, 64)]))
    assert select_method(op) == "slq"
    st = StencilOperator((-1, 0, 1), jnp.asarray([-1.0, 2.5, -1.0]), n=64)
    assert select_method(st) == "slq"


def test_auto_on_materializable_operator_stays_matrix_free():
    """Dense/sharded OPERATORS advertise materializable=True, but exact
    methods take arrays, not operators — auto must stay on estimators."""
    from repro.estimators import DenseOperator
    op = DenseOperator(jnp.asarray(make_spd(32, 0)))
    assert op.plan_hints().materializable
    assert select_method(op) == "slq"
    p = repro.plan(op, method="auto", num_probes=16)
    assert p.method == "slq"
    assert jnp.isfinite(p().logabsdet)


def test_batched_stack_rejects_mesh_up_front(mesh1):
    stack = np.stack([make_spd(16, s) for s in range(2)])
    for method in ("auto", "mc", "slq"):
        with pytest.raises(TypeError, match="one device per matrix"):
            repro.plan(stack, method=method, mesh=mesh1)


def test_auto_drops_other_familys_kwargs():
    # below the crossover auto resolves to exact: the estimator knobs are
    # dropped rather than crashing the plan the selector picked
    p = repro.plan((64, 64), method="auto", num_probes=16)
    assert p.method == "exact" and isinstance(p.config, ExactConfig)
    # above the crossover the same knobs land in the estimator config
    p2 = repro.plan((8192, 8192), method="auto", num_probes=16)
    assert p2.method == "slq" and p2.config.num_probes == 16
    # typos no family understands still fail loudly
    with pytest.raises(TypeError, match="unknown keywords"):
        repro.plan((64, 64), method="auto", num_probs=16)


def test_auto_accuracy_demand_forces_exact():
    # at rtol below the Monte-Carlo floor only exact methods qualify
    assert select_method((8192, 8192), rtol=1e-8) == "exact"
    assert select_method((8192, 8192), rtol=1e-2) == "slq"


# ------------------------------------------- calibrated cost model (roofline)

def test_calibration_table_is_measured_not_static():
    """The selector must load the committed measured roofline table, not
    fall back to the static defaults."""
    from repro.core.calibration import load_calibration
    cal = load_calibration()
    assert cal.source.startswith("measured"), cal
    for v in (cal.gemm_flops, cal.stream_bytes, cal.collective_lat,
              cal.collective_bytes):
        assert v > 0


def _dense_est_crossover(devices, lo=32, hi=1 << 22):
    """Smallest N where the selector leaves the exact family."""
    assert select_method(spec_with_devices(lo, devices)) == "exact"
    assert select_method(spec_with_devices(hi, devices)) != "exact"
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if select_method(spec_with_devices(mid, devices)) == "exact":
            lo = mid
        else:
            hi = mid
    return hi


def _serial_mesh_crossover(devices, lo=8, hi=1 << 22):
    """Smallest N where the exact family flips to the mesh schedule
    (rtol pinned below the Monte-Carlo floor so exact always wins)."""
    def schedule(n):
        return select_route(spec_with_devices(n, devices), rtol=1e-9)[1] \
            .schedule
    assert schedule(lo) != "mesh"
    assert schedule(hi) == "mesh"
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if schedule(mid) == "mesh":
            hi = mid
        else:
            lo = mid
    return hi


def test_dense_estimator_crossover_varies_with_devices():
    """The static-FLOP model divided both sides by P, making the crossover
    device-count-invariant; the measured model's collective terms do not
    shrink with P, so it must move."""
    assert _dense_est_crossover(1) != _dense_est_crossover(8)


def test_serial_mesh_crossover_varies_with_devices():
    c2, c8 = _serial_mesh_crossover(2), _serial_mesh_crossover(8)
    assert c2 != c8
    # small matrices never pay the per-step broadcast latency
    assert min(c2, c8) > 32


def _serial_mesh_crossover_cal(devices, cal, lo=8, hi=1 << 22):
    def schedule(n):
        return select_route(spec_with_devices(n, devices), rtol=1e-9,
                            calibration=cal)[1].schedule
    if schedule(hi) != "mesh":
        return hi + 1
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if schedule(mid) == "mesh":
            hi = mid
        else:
            lo = mid
    return hi


def test_mesh_crossover_prices_the_communication_term():
    """Degrading the measured collective terms 100x must push the mesh
    schedule's break-even point up — the selector really reads the table,
    not a constant."""
    from repro.core.calibration import load_calibration
    cal = load_calibration()
    slow = dataclasses.replace(cal, collective_lat=cal.collective_lat * 100,
                               collective_bytes=cal.collective_bytes / 100)
    assert _serial_mesh_crossover_cal(8, slow) > \
        _serial_mesh_crossover_cal(8, cal)


def test_auto_plan_resolves_and_executes():
    a = make_spd(48, 0)
    p = repro.plan(a, method="auto")
    assert p.method == "exact"              # resolved, never "auto"
    assert p.config.schedule in ("serial", "staged")
    res = p()
    assert isinstance(res, LogdetResult)
    assert res.method_used == "exact"
    np.testing.assert_allclose(float(res.logabsdet),
                               np.linalg.slogdet(a)[1], rtol=1e-9)


def test_auto_operator_plan_executes():
    c = np.zeros(96)
    c[0], c[1] = 2.5, -1.0
    op = ToeplitzOperator(jnp.asarray(c))
    p = repro.plan(op, method="auto", num_probes=32)
    assert p.method == "slq"
    res = p()
    i = np.arange(96)
    ref = np.linalg.slogdet(c[np.abs(i[:, None] - i[None, :])])[1]
    assert abs(float(res.logabsdet) - ref) < 5 * float(res.sem) + 0.5


def test_auto_routes_non_spd_to_clear_error():
    n = 4096                                # above the crossover
    rng = np.random.default_rng(0)
    a = rng.standard_normal((n, n)) + n * np.eye(n)   # NOT symmetric
    p = repro.plan((n, n), method="auto")
    assert p.method in ("chebyshev", "slq")
    with pytest.raises(ValueError, match="not symmetric"):
        p(a)
    bad_diag = -np.eye(n)                   # symmetric but indefinite
    with pytest.raises(ValueError, match="positive-definite"):
        p(bad_diag)


def test_validate_false_skips_spd_screen():
    a = make_spd(16, 0)
    p = repro.plan((16, 16), method="slq", validate=False, num_probes=8)
    assert jnp.isfinite(p(a).logabsdet)


# --------------------------------------------------------- unified results

@pytest.mark.parametrize("method,kw", [
    ("exact", dict(schedule="serial")),
    ("exact", dict(schedule="staged")),
    ("exact", dict(schedule="serial", update="panel", k=16)),
    ("ge", {}),
    ("chebyshev", dict(degree=48, num_probes=32)),
    ("slq", dict(num_steps=20, num_probes=32)),
])
def test_every_path_returns_logdet_result(method, kw):
    a = make_spd(96, 1)
    ref = np.linalg.slogdet(a)[1]
    res = repro.plan(a, method=method, **kw)()
    assert isinstance(res, LogdetResult)
    assert res.method_used == method
    assert float(res.sign) == 1.0
    assert res.sem is not None
    np.testing.assert_allclose(float(res.logabsdet), ref, rtol=5e-2)
    d = res.diagnostics
    assert d.wall_time_s is not None and d.wall_time_s >= 0
    assert d.padded_n == 96 and d.device_count == 1
    if method in ("chebyshev", "slq"):
        assert float(res.sem) > 0
        assert d.matvec_cols is not None and d.matvec_cols > 0
    else:
        assert float(res.sem) == 0.0
        assert d.matvec_cols is None
    assert d.flops_est is not None and d.flops_est > 0
    # legacy-style tuple unpacking works on the unified result
    s, ld = res
    assert float(s) == 1.0 and float(ld) == float(res.logabsdet)


def test_batched_plan_unified_result():
    stack = np.stack([make_spd(32, s, shift=1.5 + 0.1 * s) for s in range(4)])
    ref = np.array([np.linalg.slogdet(m)[1] for m in stack])
    exact = repro.plan(stack, method="exact", schedule="serial")()
    np.testing.assert_allclose(np.asarray(exact.logabsdet), ref, rtol=1e-9)
    assert exact.sign.shape == (4,) and float(exact.sem.max()) == 0.0
    est = repro.plan(stack, method="slq", num_probes=48)()
    assert est.logabsdet.shape == (4,) and est.sem.shape == (4,)
    rel = np.abs(np.asarray(est.logabsdet) - ref) / np.abs(ref)
    assert np.median(rel) < 5e-2


def test_batched_stack_accepts_any_serial_engine_route():
    """logdet_batched used to hardwire the 'mc' route; stacks now run any
    engine route — panel updates included — and mesh schedules fail with
    a targeted error, not a generic one."""
    stack = np.stack([make_spd(24, s) for s in range(3)])
    ref = np.array([np.linalg.slogdet(m)[1] for m in stack])
    for kw in (dict(schedule="serial", update="panel", k=8),
               dict(schedule="staged"),
               dict(schedule="staged", update="panel", k=8)):
        res = repro.plan(stack, method="exact", **kw)()
        np.testing.assert_allclose(np.asarray(res.logabsdet), ref,
                                   rtol=1e-8)
    with pytest.raises(TypeError, match="ONE matrix"):
        repro.plan(stack, method="exact", schedule="mesh")
    # the non-deprecated batched entry point takes engine routes too
    from repro.estimators import logdet_batched as est_batched
    got = est_batched(stack, method="exact", update="panel", k=8)
    np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-8)


def test_mesh_plan_matches_serial(mesh1):
    a = make_spd(24, 2)
    res = repro.plan(a, method="exact", schedule="mesh", mesh=mesh1)()
    np.testing.assert_allclose(float(res.logabsdet),
                               np.linalg.slogdet(a)[1], rtol=1e-9)
    # a supplied mesh resolves the default schedule to "mesh"
    p_default = repro.plan(a, method="exact", mesh=mesh1)
    assert p_default.config.schedule == "mesh"
    est = repro.plan(a, method="chebyshev", mesh=mesh1,
                     num_probes=16, degree=32)()
    direct = repro.plan(a, method="chebyshev", num_probes=16, degree=32)()
    np.testing.assert_allclose(float(est.logabsdet),
                               float(direct.logabsdet), rtol=1e-10)


def test_mesh_panel_plan_matches_serial(mesh1):
    a = make_spd(24, 12)
    res = repro.plan(a, method="exact", schedule="mesh", update="panel",
                     k=8, mesh=mesh1)()
    np.testing.assert_allclose(float(res.logabsdet),
                               np.linalg.slogdet(a)[1], rtol=1e-9)
    assert float(res.sign) == float(np.linalg.slogdet(a)[0])


def test_mesh_schedule_without_mesh_is_an_error():
    with pytest.raises(ValueError, match="requires a mesh"):
        repro.plan((16, 16), method="exact", schedule="mesh")


def test_spec_only_plan_requires_matching_input():
    p = repro.plan((16, 16), method="exact", schedule="serial")
    with pytest.raises(TypeError, match="shape spec"):
        p()
    with pytest.raises(ValueError, match="compiled for shape"):
        p(np.eye(8))
    s, ld = p(np.eye(16) * 3.0)
    np.testing.assert_allclose(float(ld), 16 * np.log(3.0), rtol=1e-12)


def test_precision_override_casts():
    a = make_spd(24, 3)                      # float64 under x64
    p = repro.plan((24, 24), method="exact", precision="float32")
    res = p(a)
    assert res.logabsdet.dtype == jnp.float32


def test_exact_plan_rejects_runtime_randomness():
    p = repro.plan((8, 8), method="exact")
    with pytest.raises(TypeError, match="key"):
        p(np.eye(8), key=jax.random.PRNGKey(0))


# ------------------------------------------------- caching and no-retrace

def test_plan_cache_shares_compiled_executable():
    a = make_spd(20, 4)
    p1 = repro.plan(a, method="exact", schedule="staged")
    p2 = repro.plan((20, 20), method="exact", schedule="staged")
    assert p1._fwd is p2._fwd                 # one artifact, both handles
    p3 = repro.plan((20, 20), method="exact",
                    config=ExactConfig(schedule="staged"))
    assert p3._fwd is p1._fwd                 # default config == no kwargs
    # the bare default spelling resolves to staged x rank1 too
    p4 = repro.plan((20, 20), method="exact")
    assert p4._fwd is p1._fwd


# ------------------------------------------------- legacy exact routes

def test_legacy_route_strings_resolve_to_engine_instantiations():
    """Every legacy condensation route string is a deprecated alias for an
    engine tuple: same plan cache entry, hence bit-identical results."""
    from repro.core.engine import LEGACY_ROUTES
    a = make_spd(20, 4)
    for route, (schedule, update) in LEGACY_ROUTES.items():
        if schedule == "mesh":
            continue                          # exercised in the mesh tests
        with pytest.warns(DeprecationWarning, match="deprecated"):
            p_legacy = repro.plan((20, 20), method=route, k=8)
        p_engine = repro.plan((20, 20), method="exact", schedule=schedule,
                              update=update, k=8)
        assert p_legacy.method == "exact"
        assert p_legacy.config.schedule == schedule
        assert p_legacy.config.update == update
        assert p_legacy._fwd is p_engine._fwd, route   # bit-identical
        legacy_res = p_legacy(a)
        engine_res = p_engine(a)
        assert float(legacy_res.sign) == float(engine_res.sign)
        assert float(legacy_res.logabsdet) == float(engine_res.logabsdet)


def test_legacy_mesh_route_strings_resolve_to_engine(mesh1):
    a = make_spd(16, 6)
    for route, update in (("pmc", "rank1"), ("pmc_blocked", "panel")):
        with pytest.warns(DeprecationWarning, match="deprecated"):
            p_legacy = repro.plan((16, 16), method=route, mesh=mesh1, k=8)
        p_engine = repro.plan((16, 16), method="exact", schedule="mesh",
                              update=update, k=8, mesh=mesh1)
        assert p_legacy.config.schedule == "mesh"
        assert p_legacy.config.update == update
        assert p_legacy._fwd is p_engine._fwd, route
        np.testing.assert_allclose(float(p_legacy(a).logabsdet),
                                   np.linalg.slogdet(a)[1], rtol=1e-9)


def test_legacy_route_rejects_conflicting_engine_axes():
    with pytest.warns(DeprecationWarning):
        with pytest.raises(TypeError, match="pins"):
            repro.plan((16, 16), method="mc", schedule="staged")
    with pytest.warns(DeprecationWarning):
        with pytest.raises(TypeError, match="pins"):
            repro.plan((16, 16), method="mc_blocked", update="rank1")


def test_plan_cache_keys_on_resolved_kernel_backend(monkeypatch):
    """backend='auto' is pinned at plan time: flipping the env override
    must build a new executable, not serve the stale cached one."""
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    p1 = repro.plan((21, 21), method="exact", schedule="serial")
    assert p1.config.backend in ("xla", "pallas")     # pinned, not "auto"
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    p2 = repro.plan((21, 21), method="exact", schedule="serial")
    assert p2.config.backend == "interpret"
    assert p2._fwd is not p1._fwd


def test_repeated_plan_calls_do_not_retrace():
    p = repro.plan((24, 24), method="chebyshev", num_probes=8, degree=16)
    assert p.compiled
    a = make_spd(24, 0)
    r1 = p(a, key=jax.random.PRNGKey(0))
    r2 = p(jnp.asarray(a) + 0.01 * jnp.eye(24), key=jax.random.PRNGKey(1))
    r3 = p(a, key=jax.random.PRNGKey(2))
    assert p.trace_count == 1, f"retraced: {p.trace_count}"
    assert float(r1.logabsdet) != float(r2.logabsdet)
    assert float(r1.logabsdet) != float(r3.logabsdet)  # fresh key, new draw


def test_exact_plan_does_not_retrace_either():
    p = repro.plan((16, 16), method="exact", schedule="serial")
    p(make_spd(16, 0))
    p(make_spd(16, 1))
    p(make_spd(16, 2))
    assert p.trace_count == 1
    # value_and_grad reuses the plan's own compiled forward
    p.value_and_grad(make_spd(16, 3))
    assert p.trace_count == 1


def test_legacy_shim_reuses_plan_cache():
    from repro.core.plan import _PLAN_CACHE
    a = make_spd(28, 5)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        from repro.core import slogdet
        s1, ld1 = slogdet(a, method="mc_staged")
    before = len(_PLAN_CACHE)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        s2, ld2 = slogdet(np.asarray(a) * 1.0, method="mc_staged")
    assert len(_PLAN_CACHE) == before         # second call: cache hit
    assert float(ld1) == float(ld2)
    # and the shim agrees with the engine plan it wraps
    res = repro.plan(a, method="exact", schedule="staged")()
    assert float(res.logabsdet) == float(ld1)


def test_legacy_logdet_batched_warns_and_matches():
    stack = np.stack([make_spd(24, s) for s in range(3)])
    with pytest.warns(DeprecationWarning, match="deprecated"):
        from repro.core import logdet_batched
        legacy = logdet_batched(stack, method="mc")
    res = repro.plan(stack, method="exact", schedule="serial")()
    np.testing.assert_array_equal(np.asarray(legacy),
                                  np.asarray(res.logabsdet))


def test_runtime_bounds_are_execution_inputs():
    """Concrete scalar bounds bake into the (hashable) config; traced
    bounds ride the call — both produce the bounded-Chebyshev value."""
    a = make_spd(32, 9)
    lo, hi = 0.5, 40.0
    static = repro.plan(a, method="chebyshev", num_probes=8, degree=16,
                        lmin=lo, lmax=hi)
    base = static()
    # concrete 0-d arrays coerce into the config (cache stays hashable)
    arr_cfg = repro.plan(a, method="chebyshev", num_probes=8, degree=16,
                         lmin=jnp.asarray(lo), lmax=jnp.asarray(hi))
    assert arr_cfg.config.lmin == lo and arr_cfg._fwd is static._fwd
    # traced bounds cannot be static config ...
    with pytest.raises(TypeError, match="execution time"):
        jax.jit(lambda b: repro.plan((32, 32), method="chebyshev",
                                     lmin=b, lmax=4.0).config)(jnp.asarray(lo))
    # ... they are runtime inputs instead, inside or outside jit
    unbounded = repro.plan(a, method="chebyshev", num_probes=8, degree=16)
    rt = unbounded(lmin=jnp.asarray(lo), lmax=jnp.asarray(hi))
    np.testing.assert_allclose(float(rt.logabsdet), float(base.logabsdet),
                               rtol=1e-12)
    jit_ld = jax.jit(lambda x, b: unbounded.logdet(x, lmin=b[0], lmax=b[1]))(
        jnp.asarray(a), jnp.asarray([lo, hi]))
    np.testing.assert_allclose(float(jit_ld), float(base.logabsdet),
                               rtol=1e-12)


def test_legacy_shim_accepts_traced_bounds():
    a = jnp.asarray(make_spd(24, 10))
    from repro.core import slogdet
    with pytest.warns(DeprecationWarning):
        ref = slogdet(a, method="chebyshev", num_probes=8, degree=16,
                      lmin=0.5, lmax=40.0)[1]

        def f(x, b):
            return slogdet(x, method="chebyshev", num_probes=8, degree=16,
                           lmin=b[0], lmax=b[1])[1]

        got = jax.jit(f)(a, jnp.asarray([0.5, 40.0]))
    np.testing.assert_allclose(float(got), float(ref), rtol=1e-12)


def test_mesh_value_and_grad_matches_forward_without_padding(mesh1):
    """User bounds that exclude 1 must NOT be widened when the mesh
    embedding did not pad — forward and value_and_grad must agree."""
    a = make_spd(32, 11)                     # 32 % 1 == 0: no padding
    p = repro.plan(a, method="chebyshev", mesh=mesh1, num_probes=8,
                   degree=16, lmin=1.5, lmax=40.0)
    k = jax.random.PRNGKey(0)
    fwd = p(a, key=k)
    vag_res, _ = p.value_and_grad(a, key=k)
    np.testing.assert_allclose(float(vag_res.logabsdet),
                               float(fwd.logabsdet), rtol=1e-12)


def test_grad_prebuild_honored_on_cache_hit():
    repro.plan((20, 20), method="ge")                  # populate cache
    p = repro.plan((20, 20), method="ge", grad=True)   # cache hit
    assert p.grad and "vag" in p._cache


# ---------------------------------------------------------------- gradients

def test_plan_logdet_fn_is_differentiable_exact():
    a = jnp.asarray(make_spd(12, 6))
    p = repro.plan((12, 12), method="exact")
    g = jax.grad(lambda x: p.logdet(x))(a)
    np.testing.assert_allclose(np.asarray(g),
                               np.linalg.inv(np.asarray(a)).T,
                               rtol=1e-8, atol=1e-10)


def test_plan_logdet_fn_composes_with_jit_and_vmap():
    p = repro.plan((12, 12), method="slq", num_probes=8, num_steps=10)
    stack = jnp.asarray(np.stack([make_spd(12, s) for s in range(3)]))
    g = jax.vmap(jax.grad(lambda x: p.logdet(x, key=jax.random.PRNGKey(0))))(
        stack)
    assert g.shape == stack.shape and bool(jnp.isfinite(g).all())


def test_value_and_grad_exact():
    a = make_spd(16, 7)
    res, bar = repro.plan(a, method="exact").value_and_grad()
    np.testing.assert_allclose(float(res.logabsdet),
                               np.linalg.slogdet(a)[1], rtol=1e-9)
    np.testing.assert_allclose(np.asarray(bar), np.linalg.inv(a).T,
                               rtol=1e-8, atol=1e-10)
    assert res.diagnostics.cg_iters is None   # analytic inverse, no CG


def test_value_and_grad_estimator_reports_cg_iters():
    a = make_spd(32, 8)
    p = repro.plan(a, method="chebyshev", num_probes=64, degree=48)
    res, bar = p.value_and_grad(key=jax.random.PRNGKey(3))
    assert res.diagnostics.cg_iters is not None
    assert res.diagnostics.cg_iters > 0
    # the explicit pullback must agree with autodiff through the plan
    g = jax.grad(lambda x: p.logdet(x, key=jax.random.PRNGKey(3)))(
        jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(bar), np.asarray(g),
                               rtol=1e-6, atol=1e-8)


def test_value_and_grad_structured_operator():
    ka = make_spd(5, 0)
    kb = make_spd(6, 1)
    from repro.estimators import KroneckerOperator
    op = KroneckerOperator(jnp.asarray(ka), jnp.asarray(kb))
    p = repro.plan(op, method="slq", num_probes=32, num_steps=20)
    res, (ga, gb) = p.value_and_grad()
    assert ga.shape == (5, 5) and gb.shape == (6, 6)   # factor-shaped
    assert res.diagnostics.cg_iters > 0
    ref = 6 * np.linalg.slogdet(ka)[1] + 5 * np.linalg.slogdet(kb)[1]
    assert abs(float(res.logabsdet) - ref) < 5 * float(res.sem) + 0.5


# --------------------------------------------------------- pad dtype fix

@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float16, jnp.bfloat16])
def test_pad_to_multiple_preserves_dtype(dtype):
    from repro.core import pad_to_multiple
    a = jnp.ones((3, 3), dtype)
    out = pad_to_multiple(a, 4)
    assert out.dtype == dtype, (out.dtype, dtype)
    assert out.shape == (4, 4)
    assert int(out[3, 3]) == 1


# ----------------------------------------------------------- plan hints

def test_plan_hints_advertised_by_all_backends():
    from repro.estimators import (
        BatchedOperator, DenseOperator, KroneckerOperator, StencilOperator,
        ToeplitzOperator,
    )
    n = 36
    a = jnp.asarray(make_spd(n, 0))
    cases = {
        "dense": DenseOperator(a),
        "batched": BatchedOperator(a[None]),
        "kron": KroneckerOperator(a[:6, :6], a[:6, :6]),
        "toeplitz": ToeplitzOperator(a[0]),
        "stencil": StencilOperator((-1, 0, 1),
                                   jnp.asarray([-1.0, 2.5, -1.0]), n=n),
    }
    for name, op in cases.items():
        h = op.plan_hints()
        assert h.structure == name
        assert h.matvec_flops > 0
        assert h.device_count >= 1
    # structure beats dense on per-column cost
    assert (cases["stencil"].plan_hints().matvec_flops
            < cases["dense"].plan_hints().matvec_flops)
    assert cases["dense"].plan_hints().materializable
    assert not cases["kron"].plan_hints().materializable


# ------------------------------------------- bf16 route + tile autotuning

def test_plan_bf16_is_engine_route_not_storage_cast():
    """precision='bf16' selects the mixed-precision engine route: the
    spec keeps its storage dtype, the config carries precision='bf16',
    and the result stays within the engine's documented error model."""
    a = jnp.asarray(make_spd(64, 0), jnp.float32)
    p = repro.plan(a, method="exact", precision="bf16")
    assert p.method == "exact"
    assert p.spec.dtype == "float32"          # storage untouched
    assert p.config.precision == "bf16"
    r = p(a)
    s_ref, ld_ref = np.linalg.slogdet(np.asarray(a))
    assert float(r.sign) == s_ref
    assert abs(float(r.logabsdet) - ld_ref) / abs(ld_ref) < 5e-3


def test_plan_bf16_rejects_estimators_and_conflicts():
    a = jnp.asarray(make_spd(64, 1), jnp.float32)
    with pytest.raises(ValueError, match="mixed-precision"):
        repro.plan(a, method="slq", precision="bf16")
    with pytest.raises(ValueError, match="mixed-precision"):
        repro.plan(a, method="chebyshev", precision="bf16", degree=8)
    # an explicit matching config precision merges cleanly
    p = repro.plan(a, method="exact", precision="bf16",
                   config=ExactConfig(precision="bf16"))
    assert p.config.precision == "bf16"
    # and a bare config carries the route without the top-level kwarg
    p2 = repro.plan(a, method="exact", config=ExactConfig(precision="bf16"))
    assert p2.config.precision == "bf16"


def test_select_route_prices_bf16_separately():
    """bf16 restricts auto to the exact family and prices its GEMM term
    at the calibrated bf16 rate through the autotuner."""
    m, route = select_route((2048, 2048), precision="bf16")
    assert m == "exact" and route.precision == "bf16"
    # a size where native auto would hand off to estimators stays exact
    m2, route2 = select_route((8192, 8192), precision="bf16")
    assert m2 == "exact" and route2 is not None


def test_auto_runs_the_panel_k_it_priced():
    """The auto path must execute the autotuned panel width exact_cost
    modeled — cfg.k == route.panel_k, no fixed-32 drift."""
    n = 512
    m, route = select_route((n, n))
    assert m == "exact"
    a = jnp.asarray(make_spd(n, 2))
    p = repro.plan(a, method="auto")
    if p.method == "exact":
        assert p.config.k == route.panel_k
    from repro.kernels.autotune import resolved_panel_k
    assert route.panel_k == resolved_panel_k(
        n, itemsize=8, precision=None)


def test_explain_reports_precision_and_tiles():
    a = jnp.asarray(make_spd(64, 3), jnp.float32)
    text = repro.plan(a, method="exact", precision="bf16").explain()
    assert "precision: bf16" in text
    assert "tiles[" in text and "panel_k=" in text
    native = repro.plan(a, method="exact").explain()
    assert "precision: native" in native
