"""Correctness of the condensation core vs numpy.linalg.slogdet.

Deterministic cases only, including the paper's §2.2 adversarial pivot-row
case; the hypothesis property tests live in test_condense_properties.py so
this module still runs when ``hypothesis`` is absent.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    slogdet,
    slogdet_condense,
    slogdet_condense_blocked,
    slogdet_condense_staged,
    slogdet_ge,
)


def assert_slogdet_close(got, ref, rtol=1e-9, atol=1e-9):
    s, ld = float(got[0]), float(got[1])
    s_ref, ld_ref = ref
    if np.isfinite(ld_ref):
        assert s == pytest.approx(s_ref)
        np.testing.assert_allclose(ld, ld_ref, rtol=rtol, atol=atol)
    else:
        assert not np.isfinite(ld) or ld < -1e10


def test_seeded_random_matrices(rng):
    """Deterministic stand-in for the hypothesis sweep: random matrices at
    several sizes/scales against numpy for every serial algorithm."""
    for n in (1, 7, 24, 48):
        for scale in (1e-6, 1.0, 1e6):
            a = rng.standard_normal((n, n)) * scale
            ref = np.linalg.slogdet(a)
            assert_slogdet_close(slogdet_condense(a), ref)
            assert_slogdet_close(slogdet_ge(a), ref)
            assert_slogdet_close(slogdet_condense_staged(a, min_size=16), ref)
            assert_slogdet_close(slogdet_condense_blocked(a, k=8), ref,
                                 rtol=1e-8, atol=1e-8)


def test_extreme_pivot_row():
    """Paper §2.2: rows with entries like {1e-10, 2.01}.

    Closest-to-1 pivoting would pick 1e-10 (|log distance| smaller than 2.01
    in Haque's metric) and overflow; max-|.| pivoting must stay stable.
    """
    rng = np.random.default_rng(7)
    n = 32
    a = np.where(rng.random((n, n)) < 0.5, 1e-10, 2.01)
    a += np.diag(rng.random(n) * 3.0)  # keep it nonsingular
    s_ref, ld_ref = np.linalg.slogdet(a)
    s, ld = slogdet_condense(a)
    assert np.isfinite(float(ld))
    np.testing.assert_allclose(float(ld), ld_ref, rtol=1e-9)
    assert float(s) == pytest.approx(s_ref)


def test_scaled_spatial_correlation_like():
    """The paper's motivating input: scaled covariance-like SPD matrices."""
    rng = np.random.default_rng(8)
    x = rng.standard_normal((64, 32))
    cov = x @ x.T / 32 + 1e-3 * np.eye(64)
    cov *= 1e-8  # extreme scaling
    s_ref, ld_ref = np.linalg.slogdet(cov)
    s, ld = slogdet_condense(cov)
    assert float(s) == pytest.approx(s_ref) == 1.0
    np.testing.assert_allclose(float(ld), ld_ref, rtol=1e-9)


@pytest.mark.parametrize("n", [1, 2, 3, 5])
def test_tiny_sizes(n, rng):
    a = rng.standard_normal((n, n))
    assert_slogdet_close(slogdet_condense(a), np.linalg.slogdet(a))


def test_singular_matrix():
    a = np.ones((8, 8))
    s, ld = slogdet_condense(a)
    assert float(ld) == -np.inf or float(ld) < -30  # rank-1: det == 0


def test_permutation_sign():
    """Sign tracking must be exact for permutation matrices (det = ±1)."""
    rng = np.random.default_rng(9)
    for _ in range(10):
        n = int(rng.integers(2, 24))
        p = np.eye(n)[rng.permutation(n)]
        s_ref, _ = np.linalg.slogdet(p)
        s, ld = slogdet_condense(p)
        assert float(s) == s_ref
        np.testing.assert_allclose(float(ld), 0.0, atol=1e-12)


def test_f32_accuracy():
    rng = np.random.default_rng(10)
    a = rng.standard_normal((64, 64)).astype(np.float32)
    _, ld_ref = np.linalg.slogdet(a.astype(np.float64))
    _, ld = slogdet_condense(a)
    np.testing.assert_allclose(float(ld), ld_ref, rtol=1e-4)


def test_api_validation():
    with pytest.raises(ValueError):
        slogdet(np.zeros((3, 4)))
    with pytest.raises(ValueError):
        slogdet(np.eye(4), method="nope")
    with pytest.raises(ValueError):
        slogdet(np.eye(4), method="pmc")  # mesh required
