"""Helper: run a python snippet in a subprocess with N fake XLA devices."""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

PRELUDE = """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
import jax.numpy as jnp
"""


def run_with_devices(code: str, n_devices: int, *, timeout: int = 600) -> str:
    """Run `code` with ``--xla_force_host_platform_device_count=n_devices``.

    Returns stdout; raises on nonzero exit with stderr attached.
    """
    env = dict(os.environ)
    # replace (not prepend to) any inherited device-count flag — e.g. the CI
    # multi-device job exports one for in-process tests; duplicating the
    # flag is undefined behaviour in XLA's parser
    inherited = [f for f in env.get("XLA_FLAGS", "").split()
                 if not f.startswith("--xla_force_host_platform_device_count")]
    env["XLA_FLAGS"] = " ".join(
        [f"--xla_force_host_platform_device_count={n_devices}"] + inherited)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", PRELUDE + code],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"subprocess failed (rc={proc.returncode}):\n{proc.stderr[-4000:]}"
        )
    return proc.stdout
