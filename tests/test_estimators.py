"""Stochastic estimator subsystem: accuracy vs the exact condensation core,
operator backends (dense / batched / sharded), probe statistics, and the
Pallas tiled matvec kernel vs its jnp oracle."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import logdet_batched, slogdet
from repro.estimators import (
    BatchedOperator,
    DenseOperator,
    ShardedOperator,
    chebyshev_coeffs_log,
    estimate_logdet,
    hutchinson_trace,
    lanczos,
    logdet_chebyshev,
    logdet_slq,
    make_probes,
    spectral_bounds,
)
from repro.kernels import ref
from repro.kernels.matvec import matvec_pallas


def make_spd(n, seed, shift=2.0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, 2 * n))
    return x @ x.T / (2 * n) + shift * np.eye(n)


# ---------------------------------------------------------------- accuracy

@pytest.mark.parametrize("method,kw", [
    ("chebyshev", dict(degree=64, num_probes=32)),
    ("slq", dict(num_steps=25, num_probes=32)),
])
def test_estimator_median_rel_err(method, kw):
    """Acceptance: < 1e-2 median relative error vs method='mc' on seeded
    well-conditioned SPD matrices."""
    errs = []
    for seed in range(5):
        a = make_spd(192, seed)
        _, ld_exact = slogdet(a, method="mc")
        _, ld_est = slogdet(a, method=method, seed=seed, **kw)
        errs.append(abs(float(ld_est) - float(ld_exact)) / abs(float(ld_exact)))
    assert np.median(errs) < 1e-2, errs


def test_estimate_logdet_tracks_uncertainty():
    a = make_spd(128, 0)
    res = estimate_logdet(a, method="chebyshev", num_probes=16, seed=1)
    assert res.samples.shape == (16,)
    assert float(res.sem) > 0
    # the reported standard error should bracket the truth within ~5 sigma
    _, ld_ref = np.linalg.slogdet(a)
    assert abs(float(res.est) - ld_ref) < 5 * float(res.sem) + 1.0


def test_estimator_unknown_method():
    with pytest.raises(ValueError, match="unknown estimator"):
        estimate_logdet(make_spd(16, 0), method="taylor")


def test_slogdet_rejects_estimator_kwargs_on_exact():
    with pytest.raises(TypeError, match="estimator keywords"):
        slogdet(np.eye(8), method="mc", num_probes=4)


# ---------------------------------------------------------------- batched

def test_logdet_batched_matches_vmapped_exact():
    """Acceptance: stack of >= 8 covariances vs a vmapped exact reference."""
    stack = np.stack([make_spd(64, s, shift=1.5 + 0.1 * s) for s in range(8)])
    ref_ld = np.array([np.linalg.slogdet(m)[1] for m in stack])

    exact = np.asarray(logdet_batched(stack, method="mc"))
    np.testing.assert_allclose(exact, ref_ld, rtol=1e-10)

    for method, kw in [("chebyshev", dict(degree=64, num_probes=48)),
                       ("slq", dict(num_steps=25, num_probes=48))]:
        est = np.asarray(logdet_batched(stack, method=method, seed=0, **kw))
        rel = np.abs(est - ref_ld) / np.abs(ref_ld)
        assert np.median(rel) < 1e-2, (method, rel)


def test_logdet_batched_validation():
    with pytest.raises(ValueError, match="stack"):
        logdet_batched(np.eye(4))
    with pytest.raises(TypeError, match="keywords"):
        logdet_batched(np.stack([np.eye(4)] * 2), method="mc", num_probes=4)


# ---------------------------------------------------------------- operators

def test_dense_and_batched_operator_agree(rng):
    stack = np.stack([make_spd(32, s) for s in range(3)])
    v = rng.standard_normal((3, 32, 5))
    got = BatchedOperator(stack).mm(jnp.asarray(v))
    want = np.stack([stack[b] @ v[b] for b in range(3)])
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-12)
    one = DenseOperator(stack[1]).mm(jnp.asarray(v[1]))
    np.testing.assert_allclose(np.asarray(one), want[1], rtol=1e-12)


def test_sharded_operator_matches_dense(mesh1, rng):
    a = make_spd(48, 7)
    v = rng.standard_normal((48, 6))
    for use_kernel in (False, True):
        op = ShardedOperator(jnp.asarray(a), mesh1, use_kernel=use_kernel)
        np.testing.assert_allclose(np.asarray(op.mm(jnp.asarray(v))), a @ v,
                                   rtol=1e-12)


def test_sharded_operator_validation(mesh1):
    with pytest.raises(ValueError, match="square"):
        ShardedOperator(jnp.zeros((4, 5)), mesh1)


def test_sharded_estimate_matches_dense_path(mesh1):
    a = make_spd(64, 3)
    op = ShardedOperator(jnp.asarray(a), mesh1)
    got = logdet_chebyshev(op, degree=48, num_probes=32, seed=0)
    want = logdet_chebyshev(a, degree=48, num_probes=32, seed=0)
    np.testing.assert_allclose(float(got.est), float(want.est), rtol=1e-10)


@pytest.mark.slow
def test_sharded_operator_four_devices():
    from tests._subproc import run_with_devices
    out = run_with_devices(
        """
import sys; sys.path.insert(0, %r)
from repro._compat import make_mesh
from repro.estimators import ShardedOperator, logdet_slq
rng = np.random.default_rng(0)
n = 96
x = rng.standard_normal((n, 2 * n))
a = x @ x.T / (2 * n) + 2.0 * np.eye(n)
mesh = make_mesh((4,), ("rows",))
op = ShardedOperator(jnp.asarray(a), mesh)
v = jnp.asarray(rng.standard_normal((n, 4)))
assert np.allclose(np.asarray(op.mm(v)), a @ np.asarray(v), rtol=1e-10)
est = logdet_slq(op, num_steps=25, num_probes=32, seed=0)
ref = np.linalg.slogdet(a)[1]
assert abs(float(est.est) - ref) / abs(ref) < 2e-2, (float(est.est), ref)
print("OK")
""" % __import__("tests._subproc", fromlist=["SRC"]).SRC,
        n_devices=4,
    )
    assert "OK" in out


# ---------------------------------------------------------------- pieces

def test_hutchinson_trace_plain(rng):
    a = make_spd(96, 1)
    for kind in ("rademacher", "gaussian"):
        probes = make_probes(jax.random.PRNGKey(0), 96, 128, kind=kind)
        res = hutchinson_trace(lambda v: jnp.asarray(a) @ v, probes)
        rel = abs(float(res.est) - np.trace(a)) / np.trace(a)
        assert rel < 0.05, (kind, rel)
        assert float(res.sem) > 0


def test_make_probes_validation():
    with pytest.raises(ValueError, match="probe kind"):
        make_probes(jax.random.PRNGKey(0), 8, 4, kind="sobol")


def test_spectral_bounds_bracket():
    a = make_spd(80, 2)
    w = np.linalg.eigvalsh(a)
    lo, hi = spectral_bounds(DenseOperator(jnp.asarray(a)),
                             jax.random.PRNGKey(0))
    assert float(lo) <= w.min() * 1.001
    assert float(hi) >= w.max() * 0.999
    assert float(lo) > 0


def test_chebyshev_coeffs_recover_log():
    """sum_j c_j T_j(t(x)) must reproduce log(x) on the interval."""
    lmin, lmax = 0.5, 4.0
    c = np.asarray(chebyshev_coeffs_log(lmin, lmax, 48, jnp.float64))
    xs = np.linspace(lmin * 1.01, lmax * 0.99, 50)
    ts = (2 * xs - (lmax + lmin)) / (lmax - lmin)
    acc = np.polynomial.chebyshev.chebval(ts, c)
    np.testing.assert_allclose(acc, np.log(xs), atol=1e-10)


def test_lanczos_tridiagonalizes():
    """For m = n the Gauss quadrature is exact: recover v^T log(A) v."""
    n = 24
    a = make_spd(n, 4)
    v0 = jnp.asarray(np.random.default_rng(0).standard_normal((n, 1)))
    alpha, beta = lanczos(lambda v: jnp.asarray(a) @ v, v0, n)
    t = (np.diag(np.asarray(alpha)[0]) + np.diag(np.asarray(beta)[0], 1)
         + np.diag(np.asarray(beta)[0], -1))
    # T and A share a spectrum when the Krylov space fills the whole space
    np.testing.assert_allclose(np.sort(np.linalg.eigvalsh(t)),
                               np.linalg.eigvalsh(a), rtol=1e-8)


def test_slq_breakdown_safe():
    """Early Krylov breakdown (A = c*I) must not produce NaNs."""
    a = 3.0 * np.eye(32)
    res = logdet_slq(a, num_steps=10, num_probes=8, seed=0)
    assert np.isfinite(float(res.est))
    np.testing.assert_allclose(float(res.est), 32 * np.log(3.0), rtol=1e-10)


def test_chebyshev_degree_validation():
    with pytest.raises(ValueError, match="degree"):
        logdet_chebyshev(np.eye(8), degree=0)


# ---------------------------------------------------------------- kernel

SHAPES_MV = [(8, 8, 1), (64, 64, 8), (100, 130, 16), (256, 512, 64),
             (33, 257, 3)]


@pytest.mark.parametrize("shape", SHAPES_MV)
@pytest.mark.parametrize("dt", [np.float32, np.float64])
def test_matvec_kernel_sweep(shape, dt, rng):
    m, n, k = shape
    a = rng.standard_normal((m, n)).astype(dt)
    x = rng.standard_normal((n, k)).astype(dt)
    tol = dict(rtol=3e-5, atol=3e-5) if dt == np.float32 else \
        dict(rtol=1e-12, atol=1e-12)
    got = matvec_pallas(a, x, interpret=True)
    np.testing.assert_allclose(np.asarray(got), ref.matvec_ref(a, x), **tol)


def test_matvec_kernel_vector_form(rng):
    a = rng.standard_normal((96, 112)).astype(np.float32)
    v = rng.standard_normal((112,)).astype(np.float32)
    got = matvec_pallas(a, v, interpret=True)
    assert got.shape == (96,)
    np.testing.assert_allclose(np.asarray(got), a @ v, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("bm,bn", [(8, 128), (32, 64), (256, 512)])
def test_matvec_block_shapes(bm, bn, rng):
    """Result must not depend on tiling."""
    a = rng.standard_normal((300, 520)).astype(np.float32)
    x = rng.standard_normal((520, 7)).astype(np.float32)
    got = matvec_pallas(a, x, bm=bm, bn=bn, interpret=True)
    np.testing.assert_allclose(np.asarray(got), ref.matvec_ref(a, x),
                               rtol=3e-5, atol=3e-5)
