"""Shared test fixtures.

NOTE: XLA_FLAGS / host device count is deliberately NOT set here — smoke
tests and benchmarks must see the real single CPU device.  Tests that need
multiple devices spawn a subprocess (see tests/_subproc.py).
"""
import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def mesh1():
    """Single-device 1-D mesh — exercises shard_map plumbing in-process."""
    from repro._compat import make_mesh
    return make_mesh((1,), ("rows",))
