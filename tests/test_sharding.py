"""Sharding rules unit tests: logical axes resolution, divisibility guards,
cache specs, batch specs — all pure (no multi-device needed)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_config
from repro.models import model as M
from repro.optim.optimizers import OptConfig
from repro.sharding.rules import (
    batch_spec, cache_shardings, logical_axes_for, make_rules, param_specs,
)
from repro.train.step import TrainConfig, init_train_state


class FakeMesh:
    """Just enough Mesh interface for the pure spec functions."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def norm(entry):
    """PartitionSpec entries may be 'x' or ('x',) — normalize to tuple."""
    if entry is None:
        return ()
    return entry if isinstance(entry, tuple) else (entry,)


def _specs_for(arch, *, opt="adamw", mesh=MESH):
    cfg = get_config(arch, smoke=True)
    tcfg = TrainConfig(opt=OptConfig(name=opt))
    shapes = jax.eval_shape(
        lambda k: init_train_state(k, cfg, tcfg), jax.random.PRNGKey(0))
    full_cfg = get_config(arch)
    full_shapes = jax.eval_shape(
        lambda k: init_train_state(k, full_cfg, tcfg), jax.random.PRNGKey(0))
    rules = make_rules(full_cfg, mesh)
    return full_cfg, full_shapes, rules


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "qwen2-moe-a2.7b",
                                  "mamba2-370m", "zamba2-7b"])
def test_every_param_and_opt_leaf_has_a_spec(arch):
    cfg, shapes, rules = _specs_for(
        arch, opt="adafactor" if arch == "qwen2-moe-a2.7b" else "adamw")
    specs = param_specs(shapes, cfg, rules, MESH)   # must not raise
    flat_p = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    # every sharded dim must divide evenly
    for (path, leaf), spec in zip(flat_p, flat_s):
        for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = int(np.prod([MESH.shape[a] for a in axes]))
            assert dim % size == 0, (path, leaf.shape, spec)


def test_embed_replicated_when_vocab_indivisible():
    cfg = get_config("mamba2-370m")               # vocab 50280, not /16
    shapes = jax.eval_shape(lambda k: M.init_model(k, cfg),
                            jax.random.PRNGKey(0))
    rules = make_rules(cfg, MESH)
    specs = param_specs(shapes, cfg, rules, MESH)
    emb = specs["embed"]
    assert emb[0] is None                          # vocab can't shard on 16


def test_expert_axis_guard():
    cfg = get_config("qwen2-moe-a2.7b")           # 60 experts, not /16
    shapes = jax.eval_shape(lambda k: M.init_model(k, cfg),
                            jax.random.PRNGKey(0))
    rules = make_rules(cfg, MESH)
    specs = param_specs(shapes, cfg, rules, MESH)
    we = specs["blocks"]["moe"]["we_gate"]         # (L, E, D, F)
    flat = tuple(we) + (None,) * 4
    assert flat[1] is None                         # E=60 replicated
    cfg4 = get_config("llama4-maverick-400b-a17b")  # 128 experts /16 ok
    shapes4 = jax.eval_shape(lambda k: M.init_model(k, cfg4),
                             jax.random.PRNGKey(0))
    specs4 = param_specs(shapes4, cfg4, make_rules(cfg4, MESH), MESH)
    we4 = tuple(specs4["moe_blocks"]["moe"]["we_gate"]) + (None,) * 4
    assert norm(we4[1]) == ("model",)


def test_batch_spec_small_batch_replicates():
    cfg = get_config("zamba2-7b")
    bs = batch_spec(cfg, MESH, kind="decode", batch=1)
    assert bs["tokens"][0] is None
    bs128 = batch_spec(cfg, MESH, kind="decode", batch=128)
    assert norm(bs128["tokens"][0]) == ("data",)


def test_cache_shardings_decode_never_shards_seq_for_batchful():
    """Divisible batch -> S unsharded (dynamic_update_slice stays local)."""
    cfg = get_config("qwen1.5-4b")                 # kv=20: heads don't divide
    spec = M.cache_specs(cfg, 128, 32768)
    cs = cache_shardings(spec, cfg, MESH)
    k = cs["k"]                                    # (L, B, S, kvh, hd)
    entries = tuple(k) + (None,) * 5
    assert entries[2] is None                      # S local
    assert norm(entries[4]) == ("model",)          # hd sharded


def test_cache_shardings_long500k_shards_seq():
    cfg = get_config("zamba2-7b")
    spec = M.cache_specs(cfg, 1, 524288)
    cs = cache_shardings(spec, cfg, MESH)
    kspec = cs["super"][1]["k"]                    # (n_super, B, S, kvh, hd)
    entries = tuple(kspec) + (None,) * 5
    assert norm(entries[2]) == ("data",)           # S carries data axes


def test_multipod_rules_use_pod_axis():
    cfg = get_config("qwen2.5-3b")
    rules = make_rules(cfg, MESH3)
    assert rules["embed"] == ("pod", "data")


def test_unknown_param_raises():
    class K:
        def __init__(self, key):
            self.key = key
    with pytest.raises(ValueError, match="no sharding rule"):
        logical_axes_for((K("mystery_weight"),),
                         jax.ShapeDtypeStruct((4, 4), jnp.float32))
