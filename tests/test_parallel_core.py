"""Parallel algorithms: in-process on a 1-device mesh (plumbing) and in a
subprocess with 8 fake devices (real multi-device semantics, incl. the
paper's block/cyclic distributions and pivot broadcasts)."""
import numpy as np
import pytest

import jax

from repro.core import slogdet
from tests._subproc import run_with_devices


PARALLEL_METHODS = ["pmc", "pmc_blocked", "pge", "plu"]


@pytest.mark.parametrize("method", PARALLEL_METHODS)
def test_parallel_one_device(method, mesh1, rng):
    a = rng.standard_normal((24, 24))
    s_ref, ld_ref = np.linalg.slogdet(a)
    s, ld = slogdet(a, method=method, mesh=mesh1, k=8, nb=4)
    assert float(s) == pytest.approx(s_ref)
    np.testing.assert_allclose(float(ld), ld_ref, rtol=1e-9)


@pytest.mark.slow
def test_parallel_eight_devices():
    out = run_with_devices(
        """
import sys; sys.path.insert(0, %r)
from repro.core import slogdet
from repro._compat import make_mesh
mesh = make_mesh((8,), ("rows",))
rng = np.random.default_rng(11)
for n in (64, 100):
    a = rng.standard_normal((n, n))
    s_ref, ld_ref = np.linalg.slogdet(a)
    for m in ("pmc", "pmc_blocked", "pge", "plu"):
        s, ld = slogdet(a, method=m, mesh=mesh, k=4, nb=2)
        assert float(s) == s_ref, (m, n, float(s), s_ref)
        assert abs(float(ld) - ld_ref) < 1e-8, (m, n, float(ld), ld_ref)
print("OK")
""" % __import__("tests._subproc", fromlist=["SRC"]).SRC,
        n_devices=8,
    )
    assert "OK" in out


@pytest.mark.slow
def test_parallel_matches_across_device_counts():
    """Same matrix, 1/2/4/8 devices -> identical logdet to 1e-10 (paper §3
    reports 10 significant digits across processor counts)."""
    code = """
import sys; sys.path.insert(0, %r)
from repro.core import slogdet
from repro._compat import make_mesh
mesh = make_mesh((jax.device_count(),), ("rows",))
rng = np.random.default_rng(42)
a = rng.standard_normal((96, 96))
s, ld = slogdet(a, method="pmc", mesh=mesh)
print(repr(float(ld)))
""" % __import__("tests._subproc", fromlist=["SRC"]).SRC
    vals = [float(run_with_devices(code, n).strip()) for n in (1, 2, 4, 8)]
    ref = np.linalg.slogdet(np.random.default_rng(42).standard_normal((96, 96)))[1]
    for v in vals:
        np.testing.assert_allclose(v, ref, rtol=1e-10)
