"""Differentiable log-determinants (repro/estimators/grad.py).

The contract under test:

  * every exact method's ``jax.grad`` passes finite-difference checks and
    equals the analytic ``inv(A).T`` — without differentiating through
    pivot control flow;
  * estimator methods return the Hutchinson pullback on the forward's own
    probes — matching the exact ``A^{-T}`` within 3x its Monte-Carlo
    standard error at a fixed seed, computed matrix-free (no dense
    inverse/solve in the lowered backward HLO);
  * structured operators receive structured cotangents (Kronecker factors,
    Toeplitz first column, stencil bands) identical to what the dense path
    would chain through the materialization;
  * batching (vmap / logdet_batched) and jit (no recompile on reuse)
    compose with the custom VJPs;
  * the `rmm`/`transpose` solve hooks and the cg_solve zero-rhs early exit
    behave.
"""
import importlib.util
import pathlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import logdet, logdet_batched, pad_to_multiple, slogdet
from repro.estimators import (
    BatchedOperator,
    DenseOperator,
    KroneckerOperator,
    LinearOperator,
    ShardedOperator,
    StencilOperator,
    ToeplitzOperator,
    cg_solve,
    estimate_logdet,
    logdet_chebyshev,
    logdet_slq,
    make_probes,
    operator_grad_info,
    register_operator_grad,
)


def make_spd(n, seed, shift=2.0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, 2 * n))
    return x @ x.T / (2 * n) + shift * np.eye(n)


def make_nonsym(n, seed):
    """Well-conditioned non-symmetric matrix (diagonally dominated)."""
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, n)) * 0.3 + 2.0 * np.eye(n)


def fd_directional(f, a, d, h=1e-5):
    """Central finite difference of scalar f along direction d."""
    return (float(f(a + h * d)) - float(f(a - h * d))) / (2 * h)


SERIAL_EXACT = ("mc", "mc_staged", "mc_blocked", "ge")
PARALLEL_EXACT = ("pmc", "pmc_blocked", "pge", "plu")


# ------------------------------------------------- exact methods: gradcheck

@pytest.mark.parametrize("method", SERIAL_EXACT)
@pytest.mark.parametrize("n", [4, 16, 33])
def test_exact_gradcheck_fd(method, n):
    """Finite-difference check at N in {4, 16, 33 (padded inside)}."""
    a = jnp.asarray(make_spd(n, seed=n))
    f = lambda x: slogdet(x, method=method)[1]
    g = jax.grad(f)(a)
    rng = np.random.default_rng(7)
    for _ in range(3):
        d = jnp.asarray(rng.standard_normal((n, n)))
        want = fd_directional(f, a, d)
        got = float((g * d).sum())
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("method", SERIAL_EXACT)
def test_exact_grad_is_inverse_transpose(method):
    a = make_spd(24, 3)
    g = jax.grad(lambda x: slogdet(x, method=method)[1])(jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(g), np.linalg.inv(a).T,
                               rtol=1e-9, atol=1e-11)


@pytest.mark.parametrize("method", ("mc", "ge"))
def test_exact_grad_nonsymmetric(method):
    """d log|det A| / dA = A^{-T} holds for general (non-SPD) matrices."""
    a = make_nonsym(20, 5)
    g = jax.grad(lambda x: slogdet(x, method=method)[1])(jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(g), np.linalg.inv(a).T,
                               rtol=1e-9, atol=1e-11)


@pytest.mark.parametrize("method", PARALLEL_EXACT)
def test_parallel_exact_grad(method, mesh1):
    a = make_spd(12, 1)
    g = jax.grad(
        lambda x: slogdet(x, method=method, mesh=mesh1)[1])(jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(g), np.linalg.inv(a).T,
                               rtol=1e-9, atol=1e-11)


def test_exact_sign_has_zero_grad():
    """The sign output is piecewise constant: cotangent discarded."""
    a = jnp.asarray(make_nonsym(8, 0))
    g = jax.grad(lambda x: slogdet(x, method="mc")[0])(a)
    np.testing.assert_array_equal(np.asarray(g), 0.0)


def test_exact_grad_through_padding():
    """pad_to_multiple embeds diag(A, I): gradients of the block unchanged."""
    a = jnp.asarray(make_spd(10, 2))
    g_plain = jax.grad(lambda x: slogdet(x, method="mc")[1])(a)
    g_pad = jax.grad(
        lambda x: slogdet(pad_to_multiple(x, 8), method="mc")[1])(a)
    np.testing.assert_allclose(np.asarray(g_pad), np.asarray(g_plain),
                               rtol=1e-9, atol=1e-11)


# -------------------------------------- estimator methods: Hutchinson VJP

def _forward_probes(method, n, k, seed):
    """The probe slab the named estimator draws internally for this seed."""
    key = jax.random.PRNGKey(seed)
    if method == "chebyshev":
        key = jax.random.split(key)[1]
    return np.asarray(make_probes(key, n, k, dtype=jnp.float64))


@pytest.mark.parametrize("method,kw", [
    ("chebyshev", dict(degree=48)),
    ("slq", dict(num_steps=20)),
])
def test_estimator_grad_is_hutchinson_pullback(method, kw):
    """The VJP must equal (1/k) sum_c (A^{-1} z_c) z_c^T on the forward's
    own probes, up to the backward CG tolerance."""
    n, k, seed = 32, 64, 3
    a = make_spd(n, 0)
    g = jax.grad(lambda x: slogdet(
        x, method=method, num_probes=k, seed=seed, **kw)[1])(jnp.asarray(a))
    z = _forward_probes(method, n, k, seed)
    bar = (np.linalg.solve(a, z) @ z.T) / k
    np.testing.assert_allclose(np.asarray(g), bar, rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("method,kw", [
    ("chebyshev", dict(degree=48)),
    ("slq", dict(num_steps=20)),
])
def test_estimator_grad_within_3sem_of_exact(method, kw):
    """Acceptance: estimator VJP vs exact A^{-T} within 3*SEM, fixed seed."""
    n, k, seed = 32, 64, 3
    a = make_spd(n, 0)
    g = np.asarray(jax.grad(lambda x: slogdet(
        x, method=method, num_probes=k, seed=seed, **kw)[1])(jnp.asarray(a)))
    z = _forward_probes(method, n, k, seed)
    samples = np.einsum("ik,jk->ijk", np.linalg.solve(a, z), z)
    sem = samples.std(-1, ddof=1) / np.sqrt(k)
    err = np.linalg.norm(g - np.linalg.inv(a).T)
    bound = 3.0 * np.sqrt((sem ** 2).sum())
    assert err <= bound, (err, bound)


@pytest.mark.parametrize("method", ("chebyshev", "slq"))
def test_estimator_forward_value_unchanged_by_grad_path(method):
    """estimate_logdet (custom-VJP path, externally shared probes) must be
    bit-identical to calling the estimator directly."""
    a = make_spd(48, 4)
    direct_fn = {"chebyshev": logdet_chebyshev, "slq": logdet_slq}[method]
    direct = direct_fn(jnp.asarray(a), num_probes=16, seed=9)
    routed = estimate_logdet(a, method=method, num_probes=16, seed=9)
    assert float(direct.est) == float(routed.est)
    assert float(direct.sem) == float(routed.sem)


def test_estimator_sem_and_samples_nondifferentiable():
    a = jnp.asarray(make_spd(16, 1))
    g = jax.grad(
        lambda x: estimate_logdet(x, num_probes=8, degree=16).sem)(a)
    np.testing.assert_array_equal(np.asarray(g), 0.0)


def test_estimator_grad_cg_knobs():
    """grad_cg_tol / grad_cg_maxiter control the backward solve."""
    a = jnp.asarray(make_spd(24, 2))
    f = lambda tol: jax.grad(lambda x: slogdet(
        x, method="chebyshev", num_probes=8, degree=16,
        grad_cg_tol=tol)[1])(a)
    loose, tight = f(1e-2), f(1e-12)
    assert jnp.isfinite(loose).all() and jnp.isfinite(tight).all()
    # a 1-iteration budget must change (degrade) the pullback
    g1 = jax.grad(lambda x: slogdet(
        x, method="chebyshev", num_probes=8, degree=16,
        grad_cg_maxiter=1)[1])(a)
    assert float(jnp.abs(g1 - tight).max()) > 1e-6


def test_estimator_grad_mesh_matches_dense(mesh1):
    a = jnp.asarray(make_spd(16, 3))
    kw = dict(num_probes=16, degree=32, seed=0)
    gm = jax.grad(lambda x: slogdet(
        x, method="chebyshev", mesh=mesh1, **kw)[1])(a)
    gd = jax.grad(lambda x: slogdet(x, method="chebyshev", **kw)[1])(a)
    np.testing.assert_allclose(np.asarray(gm), np.asarray(gd),
                               rtol=1e-10, atol=1e-12)


def test_probes_kwarg_validation():
    with pytest.raises(ValueError, match="probes rows"):
        logdet_chebyshev(jnp.asarray(make_spd(8, 0)),
                         probes=jnp.ones((4, 3)))
    with pytest.raises(ValueError, match="probes rows"):
        logdet_slq(jnp.asarray(make_spd(8, 0)), probes=jnp.ones((4, 3)))


# ------------------------------------------- structured operator pullbacks

def _toeplitz_dense_jnp(c):
    n = c.shape[0]
    i = jnp.arange(n)
    vals = jnp.concatenate([c[1:][::-1], c])
    return vals[(i[:, None] - i[None, :]) + n - 1]


def _stencil_dense_jnp(bands, n):
    # offsets (-1, 0, 1) materialized with differentiable ops, matching
    # StencilOperator.to_dense
    return (jnp.diag(bands[1]) + jnp.diag(bands[2][:n - 1], 1)
            + jnp.diag(bands[0][1:], -1))


EST_KW = dict(method="slq", num_probes=16, num_steps=20, seed=5)


def test_kron_pullback_factor_shaped():
    na, nb = 5, 6
    fa, fb = jnp.asarray(make_spd(na, 2)), jnp.asarray(make_spd(nb, 3))
    ga, gb = jax.grad(lambda p: slogdet(
        KroneckerOperator(p[0], p[1]), **EST_KW)[1])((fa, fb))
    assert ga.shape == (na, na) and gb.shape == (nb, nb)


def test_kron_pullback_matches_dense_path():
    na, nb = 5, 6
    fa, fb = jnp.asarray(make_spd(na, 2)), jnp.asarray(make_spd(nb, 3))
    g_struct = jax.grad(lambda p: slogdet(
        KroneckerOperator(p[0], p[1]), **EST_KW)[1])((fa, fb))
    g_dense = jax.grad(lambda p: slogdet(
        jnp.kron(p[0], p[1]), **EST_KW)[1])((fa, fb))
    for gs, gd in zip(g_struct, g_dense):
        np.testing.assert_allclose(np.asarray(gs), np.asarray(gd),
                                   rtol=1e-7, atol=1e-9)


def test_toeplitz_pullback_first_column_shaped():
    n = 24
    c = np.zeros(n)
    c[0], c[1], c[2] = 2.5, -1.0, 0.25
    g = jax.grad(lambda cc: slogdet(
        ToeplitzOperator(cc), **EST_KW)[1])(jnp.asarray(c))
    assert g.shape == (n,)
    assert bool(jnp.isfinite(g).all())


def test_toeplitz_pullback_matches_dense_path():
    n = 24
    c = np.zeros(n)
    c[0], c[1], c[2] = 2.5, -1.0, 0.25
    g_struct = jax.grad(lambda cc: slogdet(
        ToeplitzOperator(cc), **EST_KW)[1])(jnp.asarray(c))
    g_dense = jax.grad(lambda cc: slogdet(
        _toeplitz_dense_jnp(cc), **EST_KW)[1])(jnp.asarray(c))
    np.testing.assert_allclose(np.asarray(g_struct), np.asarray(g_dense),
                               rtol=1e-7, atol=1e-9)


def test_stencil_pullback_band_shaped():
    n = 24
    bands = jnp.asarray(np.stack([np.full(n, -1.0), np.full(n, 2.5),
                                  np.full(n, -1.0)]))
    g = jax.grad(lambda b: slogdet(
        StencilOperator((-1, 0, 1), b), **EST_KW)[1])(bands)
    assert g.shape == (3, n)
    assert bool(jnp.isfinite(g).all())


def test_stencil_pullback_matches_dense_path():
    n = 24
    bands = jnp.asarray(np.stack([np.full(n, -1.0), np.full(n, 2.5),
                                  np.full(n, -1.0)]))
    g_struct = jax.grad(lambda b: slogdet(
        StencilOperator((-1, 0, 1), b), **EST_KW)[1])(bands)
    g_dense = jax.grad(lambda b: slogdet(
        _stencil_dense_jnp(b, n), **EST_KW)[1])(bands)
    np.testing.assert_allclose(np.asarray(g_struct), np.asarray(g_dense),
                               rtol=1e-7, atol=1e-9)


def test_register_operator_grad_duck_type():
    """A duck-typed operator opts into structured grads via the registry."""

    class ScaledIdentity(LinearOperator):
        def __init__(self, s, n):
            self.s = s
            self.shape = (n, n)
            self.dtype = jnp.result_type(s)

        def mm(self, v):
            return self.s * v

        def diag(self):
            return jnp.full((self.n,), self.s)

    register_operator_grad(
        ScaledIdentity,
        params=lambda op: op.s,
        rebuild=lambda op, s: ScaledIdentity(s, op.n))
    assert operator_grad_info(ScaledIdentity(jnp.asarray(2.0), 4)) is not None

    n = 16
    g = jax.grad(lambda s: estimate_logdet(
        ScaledIdentity(s, n), method="slq", num_probes=8,
        num_steps=8).est)(jnp.asarray(3.0))
    # logdet(s I_n) = n log s  ->  d/ds = n / s (quadrature exact for c*I)
    np.testing.assert_allclose(float(g), n / 3.0, rtol=1e-8)


def test_unregistered_duck_operator_still_estimates():
    """No registry entry: forward works; grad falls back to autodiff
    through the recurrence (not asserted here, just no custom path)."""

    class Duck:
        def __init__(self, a):
            self.a = a
            self.shape = a.shape
            self.dtype = a.dtype

        def mm(self, v):
            return self.a @ v

    a = make_spd(24, 6)
    res = estimate_logdet(Duck(jnp.asarray(a)), method="chebyshev",
                          num_probes=32, degree=48, seed=0)
    ref = np.linalg.slogdet(a)[1]
    assert abs(float(res.est) - ref) / abs(ref) < 0.05


# ------------------------------------------------------- batching and jit

def test_vmap_grad_matches_batched_grad_exact():
    """vmap(grad(logdet)) and grad(sum(logdet_batched)) agree exactly for
    the deterministic mc path."""
    stack = jnp.asarray(np.stack([make_spd(12, s) for s in range(4)]))
    g_vmap = jax.vmap(jax.grad(lambda a: logdet(a, method="mc")))(stack)
    g_batch = jax.grad(
        lambda s: logdet_batched(s, method="mc").sum())(stack)
    np.testing.assert_allclose(np.asarray(g_vmap), np.asarray(g_batch),
                               rtol=1e-10, atol=1e-12)
    ref = np.stack([np.linalg.inv(np.asarray(m)).T for m in stack])
    np.testing.assert_allclose(np.asarray(g_batch), ref,
                               rtol=1e-9, atol=1e-11)


def test_batched_estimator_grad_is_blockwise_hutchinson():
    """The batched VJP is the per-matrix Hutchinson pullback on the batched
    probe slab (vmapped CG under the hood)."""
    b_, n, k, seed = 3, 24, 48, 2
    stack = np.stack([make_spd(n, s) for s in range(b_)])
    g = np.asarray(jax.grad(lambda s: logdet_batched(
        s, method="slq", num_probes=k, num_steps=20,
        seed=seed).sum())(jnp.asarray(stack)))
    z = np.asarray(make_probes(jax.random.PRNGKey(seed), n, k,
                               dtype=jnp.float64, batch_shape=(b_,)))
    for b in range(b_):
        bar = (np.linalg.solve(stack[b], z[b]) @ z[b].T) / k
        np.testing.assert_allclose(g[b], bar, rtol=1e-6, atol=1e-7)
        sem = (np.einsum("ik,jk->ijk", np.linalg.solve(stack[b], z[b]),
                         z[b]).std(-1, ddof=1) / np.sqrt(k))
        err = np.linalg.norm(g[b] - np.linalg.inv(stack[b]).T)
        assert err <= 3.0 * np.sqrt((sem ** 2).sum())


def test_vmap_grad_estimator_shape_and_finite():
    stack = jnp.asarray(np.stack([make_spd(12, s) for s in range(3)]))
    g = jax.vmap(jax.grad(lambda a: logdet(
        a, method="chebyshev", num_probes=8, degree=16, seed=0)))(stack)
    assert g.shape == stack.shape
    assert bool(jnp.isfinite(g).all())


@pytest.mark.parametrize("method,kw", [
    ("mc", {}),
    ("chebyshev", dict(num_probes=8, degree=16, seed=0)),
    ("slq", dict(num_probes=8, num_steps=10, seed=0)),
])
def test_grad_under_jit_no_recompile(method, kw):
    """Same shapes on a second call must reuse the compiled executable."""
    traces = []

    def f(a):
        traces.append(1)          # runs only while tracing
        return slogdet(a, method=method, **kw)[1]

    jf = jax.jit(jax.grad(f))
    a = jnp.asarray(make_spd(16, 0))
    g1 = jf(a)
    g2 = jf(a + 0.01)
    assert len(traces) == 1, f"recompiled: {len(traces)} traces"
    assert g1.shape == g2.shape == (16, 16)


@pytest.mark.parametrize("method,kw", [
    ("chebyshev", dict(num_probes=8, degree=16)),
    ("slq", dict(num_probes=8, num_steps=10)),
])
def test_estimator_backward_has_no_dense_solve(method, kw):
    """Acceptance: the estimator backward pass is matrix-free — the
    `no-dense-factorization` analysis pass finds no LU/Cholesky/
    triangular-solve in the lowered grad program."""
    from repro.analysis import AuditContext, run_passes

    a = jnp.asarray(make_spd(16, 0))
    txt = jax.jit(jax.grad(lambda x: slogdet(
        x, method=method, **kw)[1])).lower(a).as_text()
    report = run_passes(
        txt, AuditContext(label=f"{method} bwd", method=method,
                          kind="backward", matrix_free=True),
        ("no-dense-factorization",))
    assert report.ok, report.summary()


def test_exact_backward_does_use_factorization():
    """Contrast case — and the pass's mutation proof: the exact path's
    backward inverse factorizes, so auditing it under a (false)
    matrix-free claim must produce findings.  A pass that stayed silent
    here would prove nothing above."""
    from repro.analysis import AuditContext, run_passes

    a = jnp.asarray(make_spd(16, 0))
    txt = jax.jit(jax.grad(lambda x: slogdet(
        x, method="mc")[1])).lower(a).as_text()
    report = run_passes(
        txt, AuditContext(label="exact bwd", method="exact",
                          kind="backward", matrix_free=True),
        ("no-dense-factorization",))
    assert not report.ok, "exact backward unexpectedly factorization-free"


# --------------------------------------------------- rmm / transposed solve

def _rmm_cases(mesh1):
    rng = np.random.default_rng(0)
    nonsym = make_nonsym(12, 5)
    c = np.zeros(12)
    c[0], c[1], c[2] = 2.5, -1.0, 0.3
    r = np.zeros(12)
    r[0], r[1] = 2.5, 0.7
    ka = rng.standard_normal((3, 3))
    kb = rng.standard_normal((4, 4))
    bands = rng.standard_normal((3, 12))
    stack = np.stack([make_nonsym(12, s) for s in range(2)])
    return {
        "dense": DenseOperator(jnp.asarray(nonsym)),
        "sharded": ShardedOperator(jnp.asarray(nonsym), mesh1),
        "batched": BatchedOperator(jnp.asarray(stack)),
        "toeplitz": ToeplitzOperator(jnp.asarray(c), jnp.asarray(r)),
        "kron": KroneckerOperator(jnp.asarray(ka), jnp.asarray(kb)),
        "stencil": StencilOperator((-2, 0, 1), jnp.asarray(bands)),
    }


@pytest.mark.parametrize("name", ["dense", "sharded", "batched", "toeplitz",
                                  "kron", "stencil"])
def test_rmm_matches_dense_transpose(name, mesh1, rng):
    op = _rmm_cases(mesh1)[name]
    dense = np.asarray(op.to_dense())
    if name == "batched":
        v = rng.standard_normal((2, 12, 4))
        want = np.einsum("bji,bjk->bik", dense, v)
        got = op.rmm(jnp.asarray(v))
        single = op.rmv(jnp.asarray(v[..., 0]))
        want_single = np.einsum("bji,bj->bi", dense, v[..., 0])
    else:
        v = rng.standard_normal((12, 4))
        want = dense.T @ v
        got = op.rmm(jnp.asarray(v))
        single = op.rmv(jnp.asarray(v[:, 0]))
        want_single = dense.T @ v[:, 0]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-11, atol=1e-11)
    np.testing.assert_allclose(np.asarray(single), want_single,
                               rtol=1e-11, atol=1e-11)


def test_base_rmm_defaults_to_mm():
    """Protocol default: symmetric assumption routes rmm through mm."""

    class Sym(LinearOperator):
        def __init__(self, a):
            self.a = a
            self.shape = a.shape
            self.dtype = a.dtype

        def mm(self, v):
            return self.a @ v

    a = jnp.asarray(make_spd(8, 0))
    op = Sym(a)
    v = jnp.asarray(np.random.default_rng(0).standard_normal((8, 2)))
    np.testing.assert_allclose(np.asarray(op.rmm(v)), np.asarray(op.mm(v)))
    np.testing.assert_allclose(np.asarray(op.rmv(v[:, 0])),
                               np.asarray(op.mv(v[:, 0])))


@pytest.mark.parametrize("structure", ["dense", "toeplitz", "stencil"])
def test_cg_transpose_solves_transposed_system(structure, rng):
    """cg_solve(..., transpose=True) applies A^T through rmm; on symmetric
    SPD operators it must agree with the plain solve, and it goes through
    the transposed-symbol code path for the structured backends."""
    n = 16
    if structure == "dense":
        op = DenseOperator(jnp.asarray(make_spd(n, 0)))
    elif structure == "toeplitz":
        c = np.zeros(n)
        c[0], c[1] = 2.5, -1.0
        op = ToeplitzOperator(jnp.asarray(c))
    else:
        op = StencilOperator((-1, 0, 1),
                             jnp.asarray([-1.0, 2.5, -1.0]), n=n)
    dense = np.asarray(op.to_dense())
    b = rng.standard_normal((n, 3))
    res = cg_solve(op, jnp.asarray(b), transpose=True, tol=1e-12)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x),
                               np.linalg.solve(dense.T, b),
                               rtol=1e-8, atol=1e-8)


# ------------------------------------------------------- cg zero-rhs fix

def test_cg_zero_rhs_early_exit():
    """Regression: an all-zero rhs must return x=0 after 0 iterations
    instead of maxiter guarded 0/0 no-op steps."""
    op = DenseOperator(jnp.asarray(make_spd(16, 0)))
    res = cg_solve(op, jnp.zeros((16, 3)))
    assert int(res.iters) == 0
    assert bool(res.converged)
    np.testing.assert_array_equal(np.asarray(res.x), 0.0)
    np.testing.assert_array_equal(np.asarray(res.resnorm), 0.0)


def test_cg_zero_rhs_overrides_x0():
    """With b = 0 the unique SPD solution is 0 — any x0 guess is discarded
    without spending iterations."""
    op = DenseOperator(jnp.asarray(make_spd(16, 0)))
    res = cg_solve(op, jnp.zeros((16,)), x0=jnp.ones((16,)))
    assert int(res.iters) == 0
    assert bool(res.converged)
    np.testing.assert_array_equal(np.asarray(res.x), 0.0)


def test_cg_mixed_zero_and_nonzero_columns(rng):
    a = make_spd(16, 0)
    op = DenseOperator(jnp.asarray(a))
    b = rng.standard_normal((16, 3))
    b[:, 1] = 0.0
    res = cg_solve(op, jnp.asarray(b), tol=1e-12)
    assert bool(res.converged)
    assert int(res.iters) > 0
    x = np.asarray(res.x)
    np.testing.assert_array_equal(x[:, 1], 0.0)
    np.testing.assert_allclose(x[:, [0, 2]],
                               np.linalg.solve(a, b[:, [0, 2]]),
                               rtol=1e-8, atol=1e-8)


# ------------------------------------------------------------ gmm_fit demo

def _load_gmm_fit():
    path = (pathlib.Path(__file__).resolve().parent.parent
            / "examples" / "gmm_fit.py")
    spec = importlib.util.spec_from_file_location("gmm_fit", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.mark.parametrize("method", ["chebyshev", "mc"])
def test_gmm_fit_nll_decreases(method):
    """Acceptance: gradient training through the (batched) logdet VJP
    decreases the mixture NLL on synthetic data."""
    mod = _load_gmm_fit()
    hist = mod.train(dim=6, components=2, samples=160, steps=25,
                     method=method, num_probes=8, lr=0.05, seed=0,
                     log_every=0)
    assert hist["nll"][-1] < hist["nll"][0], hist["nll"][:3] + hist["nll"][-3:]


def test_gmm_fit_estimator_tracks_exact_logdet():
    """The estimator-path training monitor agrees with the closed-form
    cholesky logdet it parameterizes (sanity of the whole wiring)."""
    mod = _load_gmm_fit()
    hist = mod.train(dim=6, components=2, samples=120, steps=5,
                     method="slq", num_probes=16, lr=0.05, seed=1,
                     log_every=0)
    assert np.isfinite(hist["nll"]).all()
