"""HLO collective-bytes parser + roofline math unit tests."""
import numpy as np
import pytest

from repro.launch.hlo_analysis import HW, collective_bytes, roofline

SAMPLE = """
HloModule jit_step, entry_computation_layout={...}

ENTRY %main {
  %p0 = f32[16,1024]{1,0} parameter(0)
  %ag = f32[256,1024]{1,0} all-gather(%p0), replica_groups=[...], dimensions={0}
  %ar = f32[16,1024]{1,0} all-reduce(%p0), to_apply=%add
  %rs = f32[1,1024]{1,0} reduce-scatter(%p0), to_apply=%add, dimensions={0}
  %a2a = f32[16,1024]{1,0} all-to-all(%p0), dimensions={0}
  %cp = f32[16,1024]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  %ags = (f32[16,1024]{1,0}, f32[256,1024]{1,0}) all-gather-start(%p0), dimensions={0}
  %agd = f32[256,1024]{1,0} all-gather-done(%ags)
}
"""


def test_collective_parse_counts_and_bytes():
    st = collective_bytes(SAMPLE)
    assert st.counts == {"all-gather": 2, "all-reduce": 1,
                         "reduce-scatter": 1, "all-to-all": 1,
                         "collective-permute": 1}
    p0 = 16 * 1024 * 4
    full = 256 * 1024 * 4
    assert st.by_op["all-reduce"] == 2 * p0
    # named-operand resolution: in_bytes from the symbol table
    assert st.by_op["all-gather"] >= full - p0
    assert st.by_op["collective-permute"] == p0
    assert st.wire_bytes == sum(st.by_op.values())


def test_async_pairs_counted_once():
    st = collective_bytes(SAMPLE)
    # -start counted, -done skipped
    assert st.counts["all-gather"] == 2


def test_roofline_terms_and_bottleneck():
    r = roofline(flops=197e12 * 256, hbm_bytes=0.0, wire_bytes_per_chip=0.0,
                 chips=256)
    assert r["compute_s"] == pytest.approx(1.0)
    assert r["bottleneck"] == "compute_s"
    r2 = roofline(flops=1.0, hbm_bytes=819e9 * 256 * 2.0,
                  wire_bytes_per_chip=49.5e9 * 0.5, chips=256)
    assert r2["memory_s"] == pytest.approx(2.0)
    assert r2["collective_s"] == pytest.approx(0.5)
    assert r2["bottleneck"] == "memory_s"
    assert r2["step_s_lower_bound"] == pytest.approx(2.0)


def test_roofline_hardware_constants():
    assert HW["peak_flops_bf16"] == 197e12
    assert HW["hbm_bw"] == 819e9
    assert 45e9 < HW["ici_bw"] < 55e9
