"""Launcher integration tests (subprocess, fake devices; marked slow)."""
import json

import numpy as np
import pytest

from tests._subproc import SRC, run_with_devices


@pytest.mark.slow
def test_dryrun_smoke_cell():
    """lower+compile one SMOKE-config cell on the production mesh wiring."""
    out = run_with_devices(
        """
import sys; sys.path.insert(0, %r)
from repro.launch.dryrun import run_cell
rec = run_cell("gemma3-1b", "train_4k", multi_pod=False, smoke=True, fast=True)
assert rec["memory"]["peak_bytes_per_device"] > 0
assert rec["bottleneck"] in ("compute_s", "memory_s", "collective_s")
rec2 = run_cell("gemma3-1b", "decode_32k", multi_pod=True, smoke=True, fast=True)
assert rec2["mesh"] == "2x16x16"
print("DRYRUN_OK")
""" % SRC, n_devices=512, timeout=900)
    assert "DRYRUN_OK" in out


@pytest.mark.slow
def test_train_cli_multidevice():
    """launch.train end-to-end on a 2x2 mesh: loss decreases on markov data."""
    out = run_with_devices(
        """
import sys; sys.path.insert(0, %r)
from repro.launch import train as T
state = T.main(["--arch", "gemma3-1b", "--steps", "30", "--batch", "4",
                "--seq", "32", "--mesh", "2x2", "--lr", "3e-3",
                "--ckpt-dir", "/tmp/test_train_cli", "--ckpt-every", "25"])
print("TRAIN_OK")
""" % SRC, n_devices=4, timeout=1200)
    assert "TRAIN_OK" in out


@pytest.mark.slow
def test_serve_cli():
    out = run_with_devices(
        """
import sys; sys.path.insert(0, %r)
from repro.launch import serve as S
toks = S.main(["--arch", "mamba2-370m", "--batch", "2", "--prompt-len", "8",
               "--gen", "4"])
assert toks.shape == (2, 12)
print("SERVE_OK")
""" % SRC, n_devices=1, timeout=900)
    assert "SERVE_OK" in out


@pytest.mark.slow
def test_logdet_reg_training_uses_core():
    """The paper's technique as a first-class training feature."""
    out = run_with_devices(
        """
import sys; sys.path.insert(0, %r)
import jax, jax.numpy as jnp
from repro.configs.registry import get_config
from repro.data.synthetic import DataConfig, synth_batch
from repro.optim.optimizers import OptConfig
from repro.train.step import TrainConfig, init_train_state, make_train_step
cfg = get_config("qwen2.5-3b", smoke=True).replace(dtype=jnp.float32)
tcfg = TrainConfig(opt=OptConfig(name="sgd"), logdet_reg=0.05)
state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
step = jax.jit(make_train_step(cfg, tcfg))
batch = synth_batch(cfg, DataConfig(batch=2, seq=16), 0)
state, m = step(state, batch)
assert "logdet_reg" in m and bool(jnp.isfinite(m["logdet_reg"]))
print("LOGDETREG_OK", float(m["logdet_reg"]))
""" % SRC, n_devices=1, timeout=900)
    assert "LOGDETREG_OK" in out
