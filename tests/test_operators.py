"""Structured implicit-operator backends + matrix-free CG.

Every structured ``mm`` is checked against an independently built dense
materialization, the protocol surface (diag / trace_hint / to_dense)
against numpy, CG against ``jnp.linalg.solve``, and the adversarial
shapes the backends must survive: n=1, non-power-of-two sizes, odd and
asymmetric bandwidths.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import slogdet
from repro.estimators import (
    BatchedOperator,
    DenseOperator,
    KroneckerOperator,
    LinearOperator,
    ShardedOperator,
    StencilOperator,
    ToeplitzOperator,
    as_operator,
    cg_solve,
    estimate_logdet,
    make_probes,
)
from repro.kernels.ref import stencil_mv_ref
from repro.kernels.stencil_mv import stencil_mv_pallas


def make_spd(n, seed, shift=2.0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, 2 * n))
    return x @ x.T / (2 * n) + shift * np.eye(n)


def toeplitz_dense(c, r=None):
    r = c if r is None else r
    n = len(c)
    i = np.arange(n)
    d = i[:, None] - i[None, :]
    vals = np.concatenate([np.asarray(r)[1:][::-1], np.asarray(c)])
    return vals[d + n - 1]


# ------------------------------------------------------------- Kronecker

@pytest.mark.parametrize("na,nb", [(4, 6), (6, 4), (1, 5), (8, 8)])
def test_kron_mm_matches_dense(na, nb, rng):
    a, b = make_spd(na, 0), make_spd(nb, 1)
    op = KroneckerOperator(jnp.asarray(a), jnp.asarray(b))
    dense = np.kron(a, b)
    v = rng.standard_normal((na * nb, 5))
    np.testing.assert_allclose(np.asarray(op.mm(jnp.asarray(v))), dense @ v,
                               rtol=1e-11, atol=1e-11)
    w = rng.standard_normal((na * nb,))
    np.testing.assert_allclose(np.asarray(op.mv(jnp.asarray(w))), dense @ w,
                               rtol=1e-11, atol=1e-11)


def test_kron_protocol_surface():
    a, b = make_spd(5, 2), make_spd(3, 3)
    op = KroneckerOperator(jnp.asarray(a), jnp.asarray(b))
    dense = np.kron(a, b)
    np.testing.assert_allclose(np.asarray(op.diag()), np.diag(dense),
                               rtol=1e-12)
    np.testing.assert_allclose(float(op.trace_hint()), np.trace(dense),
                               rtol=1e-12)
    np.testing.assert_allclose(np.asarray(op.to_dense()), dense, rtol=1e-12)
    assert op.shape == (15, 15)


def test_kron_validation():
    with pytest.raises(ValueError, match="left factor"):
        KroneckerOperator(jnp.zeros((2, 3)), jnp.eye(2))
    with pytest.raises(ValueError, match="slab"):
        KroneckerOperator(jnp.eye(2), jnp.eye(3)).mm(jnp.zeros((5, 2)))


def test_kron_slogdet_acceptance():
    """slogdet(KroneckerOperator(A, B), method="slq") matches dense
    slogdet(jnp.kron(A, B)) to within 3 sem at n_A = n_B = 64."""
    a, b = make_spd(64, 10), make_spd(64, 11)
    op = KroneckerOperator(jnp.asarray(a), jnp.asarray(b))
    _, ld = slogdet(op, method="slq", num_probes=32, num_steps=25, seed=0)
    res = estimate_logdet(op, method="slq", num_probes=32, num_steps=25,
                          seed=0)
    _, ld_dense = np.linalg.slogdet(np.kron(a, b))
    assert abs(float(ld) - ld_dense) < 3 * float(res.sem), \
        (float(ld), ld_dense, float(res.sem))


# -------------------------------------------------------------- Toeplitz

@pytest.mark.parametrize("n", [1, 2, 37, 64])
def test_toeplitz_symmetric_matches_dense(n, rng):
    c = 0.5 ** np.arange(n)
    c[0] = 2.5
    op = ToeplitzOperator(jnp.asarray(c))
    dense = toeplitz_dense(c)
    v = rng.standard_normal((n, 3))
    np.testing.assert_allclose(np.asarray(op.mm(jnp.asarray(v))), dense @ v,
                               rtol=1e-11, atol=1e-11)
    np.testing.assert_allclose(np.asarray(op.to_dense()), dense, rtol=1e-12)


def test_toeplitz_nonsymmetric_matches_dense(rng):
    n = 23                                     # non-power-of-two
    g = np.random.default_rng(5)
    c = g.standard_normal(n)
    r = g.standard_normal(n)
    r[0] = c[0]
    op = ToeplitzOperator(jnp.asarray(c), jnp.asarray(r))
    dense = toeplitz_dense(c, r)
    v = rng.standard_normal((n, 4))
    np.testing.assert_allclose(np.asarray(op.mm(jnp.asarray(v))), dense @ v,
                               rtol=1e-10, atol=1e-10)


def test_toeplitz_protocol_surface():
    c = np.array([3.0, 1.0, 0.5])
    op = ToeplitzOperator(jnp.asarray(c))
    np.testing.assert_allclose(np.asarray(op.diag()), [3.0, 3.0, 3.0])
    assert float(op.trace_hint()) == pytest.approx(9.0)


def test_toeplitz_validation():
    with pytest.raises(ValueError, match="first column"):
        ToeplitzOperator(jnp.eye(3))
    with pytest.raises(ValueError, match="first row"):
        ToeplitzOperator(jnp.ones((4,)), jnp.ones((3,)))


def test_toeplitz_estimator_logdet():
    n = 100
    c = 0.5 ** np.arange(n)
    c[0] = 2.5
    dense = toeplitz_dense(c)
    _, ld_ref = np.linalg.slogdet(dense)
    res = estimate_logdet(ToeplitzOperator(jnp.asarray(c)),
                          method="chebyshev", degree=64, num_probes=48,
                          seed=0)
    assert abs(float(res.est) - ld_ref) / abs(ld_ref) < 2e-2


# --------------------------------------------------------------- Stencil

@pytest.mark.parametrize("n,offsets", [
    (11, (-1, 0, 1)),
    (37, (-3, -1, 0, 2, 7)),                   # odd, asymmetric bandwidths
    (1, (0,)),
    (64, (-5, 0, 5)),
])
def test_stencil_mm_matches_dense(n, offsets, rng):
    bands = rng.standard_normal((len(offsets), n))
    op = StencilOperator(offsets, jnp.asarray(bands))
    dense = np.zeros((n, n))
    for d, off in enumerate(offsets):
        for i in range(max(0, -off), min(n, n - off)):
            dense[i, i + off] = bands[d, i]
    v = rng.standard_normal((n, 3))
    np.testing.assert_allclose(np.asarray(op.mm(jnp.asarray(v))), dense @ v,
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(op.to_dense()), dense, atol=1e-15)


def test_stencil_constant_bands_and_diag():
    op = StencilOperator((-1, 0, 1), jnp.asarray([-1.0, 2.5, -1.0]), n=9)
    np.testing.assert_allclose(np.asarray(op.diag()), np.full(9, 2.5))
    assert float(op.trace_hint()) == pytest.approx(9 * 2.5)
    off_diag = StencilOperator((1,), jnp.asarray([1.0]), n=4)
    np.testing.assert_allclose(np.asarray(off_diag.diag()), np.zeros(4))


def test_stencil_validation():
    with pytest.raises(ValueError, match="duplicate"):
        StencilOperator((0, 0), jnp.asarray([1.0, 2.0]), n=4)
    with pytest.raises(ValueError, match="require n"):
        StencilOperator((0,), jnp.asarray([1.0]))
    with pytest.raises(ValueError, match="out of range"):
        StencilOperator((4,), jnp.asarray([1.0]), n=4)
    with pytest.raises(ValueError, match="band rows"):
        StencilOperator((0, 1), jnp.asarray([[1.0] * 4]), n=4)


def test_stencil_estimator_logdet():
    n = 128
    op = StencilOperator((-1, 0, 1), jnp.asarray([-1.0, 2.5, -1.0]), n=n)
    _, ld_ref = np.linalg.slogdet(np.asarray(op.to_dense()))
    res = estimate_logdet(op, method="slq", num_steps=30, num_probes=48,
                          seed=0)
    assert abs(float(res.est) - ld_ref) / abs(ld_ref) < 5e-2


# -------------------------------------------------- stencil Pallas kernel

@pytest.mark.parametrize("n,offsets,bm", [
    (11, (-1, 0, 1), 4),
    (300, (-3, -1, 0, 2, 7), 256),
    (1, (0,), 8),
    (37, (-5, 0, 5), 16),                      # bm does not divide n
])
def test_stencil_kernel_vs_ref(n, offsets, bm, rng):
    bands = jnp.asarray(rng.standard_normal((len(offsets), n)))
    x = jnp.asarray(rng.standard_normal((n, 3)))
    got = stencil_mv_pallas(bands, x, offsets=offsets, bm=bm, interpret=True)
    want = stencil_mv_ref(bands, x, offsets=offsets)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-12, atol=1e-12)


def test_stencil_kernel_vector_form(rng):
    bands = jnp.asarray(rng.standard_normal((3, 50)))
    v = jnp.asarray(rng.standard_normal((50,)))
    got = stencil_mv_pallas(bands, v, offsets=(-1, 0, 1), interpret=True)
    assert got.shape == (50,)
    want = stencil_mv_ref(bands, v, offsets=(-1, 0, 1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


# -------------------------------------------------------------------- CG

def test_cg_matches_dense_solve(rng):
    a = make_spd(48, 0)
    b = rng.standard_normal((48, 5))
    res = cg_solve(jnp.asarray(a), jnp.asarray(b))
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), np.linalg.solve(a, b),
                               rtol=1e-7, atol=1e-8)


def test_cg_vector_rhs_and_no_precondition(rng):
    a = make_spd(32, 1)
    b = rng.standard_normal((32,))
    for precondition in (True, False):
        res = cg_solve(jnp.asarray(a), jnp.asarray(b),
                       precondition=precondition)
        assert res.x.shape == (32,)
        np.testing.assert_allclose(np.asarray(res.x), np.linalg.solve(a, b),
                                   rtol=1e-7, atol=1e-8)


def test_cg_batched_operator(rng):
    stack = np.stack([make_spd(24, s, shift=1.5 + 0.2 * s) for s in range(4)])
    b = rng.standard_normal((4, 24, 3))
    res = cg_solve(BatchedOperator(jnp.asarray(stack)), jnp.asarray(b))
    want = np.stack([np.linalg.solve(stack[i], b[i]) for i in range(4)])
    assert bool(res.converged)
    assert res.resnorm.shape == (4, 3)
    np.testing.assert_allclose(np.asarray(res.x), want, rtol=1e-7, atol=1e-8)


@pytest.mark.parametrize("structure", ["kron", "toeplitz", "stencil"])
def test_cg_on_structured_operators(structure, rng):
    if structure == "kron":
        a, b_f = make_spd(6, 2), make_spd(7, 3)
        op = KroneckerOperator(jnp.asarray(a), jnp.asarray(b_f))
        dense = np.kron(a, b_f)
    elif structure == "toeplitz":
        c = 0.5 ** np.arange(40)
        c[0] = 2.5
        op = ToeplitzOperator(jnp.asarray(c))
        dense = toeplitz_dense(c)
    else:
        op = StencilOperator((-1, 0, 1), jnp.asarray([-1.0, 2.5, -1.0]),
                             n=40)
        dense = np.asarray(op.to_dense())
    b = rng.standard_normal((op.n, 4))
    res = cg_solve(op, jnp.asarray(b))
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), np.linalg.solve(dense, b),
                               rtol=1e-6, atol=1e-7)


def test_cg_adversarial_shapes(rng):
    # n = 1: one scalar division must solve it in a step
    res = cg_solve(jnp.asarray([[4.0]]), jnp.asarray([8.0]))
    np.testing.assert_allclose(np.asarray(res.x), [2.0], rtol=1e-12)
    # maxiter too small: must report non-convergence, not hang or lie
    a = make_spd(64, 4, shift=0.05)            # stiffer spectrum
    b = rng.standard_normal((64, 2))
    res = cg_solve(jnp.asarray(a), jnp.asarray(b), maxiter=1, tol=1e-14)
    assert not bool(res.converged)
    assert int(res.iters) == 1


def test_cg_x0_and_validation(rng):
    a = make_spd(16, 5)
    b = rng.standard_normal((16, 2))
    x_true = np.linalg.solve(a, b)
    res = cg_solve(jnp.asarray(a), jnp.asarray(b),
                   x0=jnp.asarray(x_true * 0.99))
    assert int(res.iters) < 16                 # warm start converges faster
    np.testing.assert_allclose(np.asarray(res.x), x_true, rtol=1e-7,
                               atol=1e-8)
    with pytest.raises(ValueError, match="rhs rows"):
        cg_solve(jnp.asarray(a), jnp.asarray(b[:7]))


# ------------------------------------------------------ protocol plumbing

def test_as_operator_passthrough_and_duck_typing():
    a = make_spd(8, 0)
    op = DenseOperator(jnp.asarray(a))
    assert as_operator(op) is op
    assert isinstance(as_operator(KroneckerOperator(jnp.eye(2), jnp.eye(2))),
                      KroneckerOperator)

    class Scaled:                              # duck-typed, no subclassing
        shape = (8, 8)
        dtype = jnp.float64

        def mm(self, v):
            return 2.0 * v

    duck = Scaled()
    assert as_operator(duck) is duck


def test_base_to_dense_and_trace_hint_defaults():
    class Shift(LinearOperator):
        def __init__(self, n):
            self.shape = (n, n)
            self.dtype = jnp.float64

        def mm(self, v):
            return 3.0 * v

    op = Shift(6)
    np.testing.assert_allclose(np.asarray(op.to_dense()), 3.0 * np.eye(6))
    assert op.diag() is None
    assert op.trace_hint() is None             # no diag -> no free trace


def test_dense_batched_protocol_surface(rng):
    a = make_spd(12, 6)
    op = DenseOperator(jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(op.diag()), np.diag(a))
    np.testing.assert_allclose(float(op.trace_hint()), np.trace(a))
    stack = np.stack([make_spd(10, s) for s in range(3)])
    bop = BatchedOperator(jnp.asarray(stack))
    np.testing.assert_allclose(np.asarray(bop.diag()),
                               np.stack([np.diag(m) for m in stack]))
    np.testing.assert_allclose(np.asarray(bop.trace_hint()),
                               np.stack([np.trace(m) for m in stack]))


def test_logdet_batched_accepts_batched_operator():
    from repro.core import logdet_batched
    stack = np.stack([make_spd(48, s, shift=1.5 + 0.1 * s)
                      for s in range(4)])
    ref = np.array([np.linalg.slogdet(m)[1] for m in stack])
    op = BatchedOperator(jnp.asarray(stack))
    est = np.asarray(logdet_batched(op, method="slq", num_steps=25,
                                    num_probes=48, seed=0))
    assert est.shape == (4,)
    assert np.median(np.abs(est - ref) / np.abs(ref)) < 1e-2
    with pytest.raises(TypeError, match="materialized"):
        logdet_batched(op, method="mc")
    with pytest.raises(ValueError, match="batched operator"):
        logdet_batched(DenseOperator(jnp.asarray(stack[0])), method="slq")


def test_slogdet_operator_rejects_exact_and_mesh(mesh1):
    op = KroneckerOperator(jnp.eye(4), jnp.eye(4))
    with pytest.raises(TypeError, match="materialized"):
        slogdet(op, method="mc")
    with pytest.raises(TypeError, match="own distribution"):
        slogdet(op, method="slq", mesh=mesh1)


# ------------------------------------------------------------ dtype hygiene

def test_make_probes_threads_dtype():
    """On float64-enabled hosts an f32 operator must get f32 probes — the
    default must not silently upcast the matvec slab."""
    v64 = make_probes(jax.random.PRNGKey(0), 16, 4)
    assert v64.dtype == jnp.result_type(float)  # canonical default (x64 on)
    v32 = make_probes(jax.random.PRNGKey(0), 16, 4, dtype=jnp.float32)
    assert v32.dtype == jnp.float32
    with pytest.raises(ValueError, match="floating"):
        make_probes(jax.random.PRNGKey(0), 16, 4, dtype=jnp.int32)


@pytest.mark.parametrize("method,kw", [
    ("chebyshev", dict(degree=32, num_probes=8)),
    ("slq", dict(num_steps=15, num_probes=8)),
])
def test_estimators_preserve_f32_under_x64(method, kw):
    a = jnp.asarray(make_spd(48, 0), jnp.float32)
    res = estimate_logdet(a, method=method, seed=0, **kw)
    assert res.est.dtype == jnp.float32
    assert res.samples.dtype == jnp.float32


# --------------------------------------------------------------- sharded

def test_sharded_operator_all_devices(rng):
    """Runs on however many devices the process sees — 1 on dev boxes, 8 in
    the CI multi-device job (XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
    from repro._compat import make_mesh
    ndev = jax.device_count()
    n = 96 if 96 % ndev == 0 else 12 * ndev
    a = make_spd(n, 9)
    mesh = make_mesh((ndev,), ("rows",))
    op = ShardedOperator(jnp.asarray(a), mesh)
    v = rng.standard_normal((n, 6))
    np.testing.assert_allclose(np.asarray(op.mm(jnp.asarray(v))), a @ v,
                               rtol=1e-10)
    np.testing.assert_allclose(np.asarray(op.diag()), np.diag(a), rtol=1e-12)
    est = estimate_logdet(op, method="slq", num_steps=25, num_probes=32,
                          seed=0)
    ld_ref = np.linalg.slogdet(a)[1]
    assert abs(float(est.est) - ld_ref) / abs(ld_ref) < 2e-2


def test_cg_on_sharded_operator(mesh1, rng):
    a = make_spd(32, 8)
    op = ShardedOperator(jnp.asarray(a), mesh1)
    b = rng.standard_normal((32, 2))
    res = cg_solve(op, jnp.asarray(b))
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), np.linalg.solve(a, b),
                               rtol=1e-7, atol=1e-8)
