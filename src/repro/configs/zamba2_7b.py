"""zamba2-7b [hybrid]: 81 Mamba2 layers, d_model=3584, + ONE shared
attention+MLP block (32H kv=32, d_ff=14336) applied every 6th position,
vocab=32000, ssm_state=64.  [arXiv:2411.15242; unverified]

Sub-quadratic backbone: runs long_500k (the shared attention block keeps a
KV cache per invocation — 13 caches of the single shared block)."""
from repro.models.common import ModelConfig

ARCH_ID = "zamba2-7b"
SKIP_SHAPES: set = set()


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
        d_ff=14336, vocab=32000,
        ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
        ssm_conv=4, ssm_groups=1, shared_attn_every=6,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, ssm_state=16, ssm_headdim=16, ssm_chunk=16,
        shared_attn_every=2,
    )
