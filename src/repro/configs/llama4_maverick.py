"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1, interleaved every 2nd layer,
1 shared expert.  [hf:meta-llama/Llama-4 family; unverified]

Config note (DESIGN.md §Arch-applicability): the brief's flat numbers (MoE in
all 48 layers) would give ~773B total; the released Maverick interleaves MoE
every 2nd layer with one shared expert, which lands at ~400B total / ~17B
active — we implement that interpretation (moe_every=2, n_shared_experts=1).
"""
from repro.models.common import ModelConfig

ARCH_ID = "llama4-maverick-400b-a17b"
SKIP_SHAPES = {"long_500k"}


def full() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab=202048, rope_theta=5e5,
        n_experts=128, top_k=1, n_shared_experts=1, d_ff_expert=8192,
        moe_every=2, tie_embeddings=False,
        # 400B on 256 x 16GB chips: bf16 weights (+ Adafactor f32 factored
        # slots, PaLM-style) — f32 master weights alone would be 6.4 GB/chip
        param_dtype=jnp.bfloat16,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, n_experts=8, d_ff_expert=32, moe_every=2,
    )
