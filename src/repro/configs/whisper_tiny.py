"""whisper-tiny [audio enc-dec]: 4L enc + 4L dec, d_model=384 6H (MHA kv=6)
d_ff=1536 vocab=51865 — conv audio frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, 1500, d_model).  [arXiv:2212.04356; unverified]
"""
from repro.models.common import ModelConfig

ARCH_ID = "whisper-tiny"
SKIP_SHAPES = {"long_500k"}


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="encdec",
        n_layers=4, n_enc_layers=4, enc_seq=1500,
        d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
        d_ff=1536, vocab=51865, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, n_enc_layers=2, enc_seq=16, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab=256,
    )
