"""Assigned input-shape set (same four cells for every LM-family arch).

  train_4k      seq 4096  x global_batch 256   -> train_step
  prefill_32k   seq 32768 x global_batch 32    -> prefill
  decode_32k    seq 32768 x global_batch 128   -> serve_step (1 new token,
                                                  KV cache of 32768)
  long_500k     seq 524288 x global_batch 1    -> serve_step; ONLY for
                sub-quadratic archs (ssm/hybrid/sliding-window); pure
                full-attention archs skip it (DESIGN.md §Arch-applicability)
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

SHAPE_NAMES = tuple(SHAPES)
