"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144
— 5:1 local:global sliding-window attention, 128k rope, head_dim=256.
[hf:google/gemma-3-1b-pt; unverified]

long_500k RUNS for this arch: 5/6 of its layers are 512-token sliding-window
(sub-quadratic); only every 6th layer is global — noted in DESIGN.md."""
from repro.models.common import ModelConfig

ARCH_ID = "gemma3-1b"
SKIP_SHAPES: set = set()


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
        d_ff=6912, vocab=262144, rope_theta=1e6,
        sliding_window=512, global_every=6,
        tie_embeddings=True, logits_softcap=30.0,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=256, sliding_window=8, global_every=3,
    )
