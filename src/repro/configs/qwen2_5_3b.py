"""qwen2.5-3b [dense]: 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936 — GQA, QKV bias.  [hf:Qwen/Qwen2.5-0.5B family; hf]"""
from repro.models.common import ModelConfig

ARCH_ID = "qwen2.5-3b"
SKIP_SHAPES = {"long_500k"}      # pure full attention -> no sub-quadratic path


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, head_dim=128,
        d_ff=11008, vocab=151936, qkv_bias=True, rope_theta=1e6,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256,
    )
