"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (MHA kv=32) d_ff=8192
vocab=32064 — RoPE SwiGLU.  [arXiv:2404.14219; unverified]"""
from repro.models.common import ModelConfig

ARCH_ID = "phi3-mini-3.8b"
SKIP_SHAPES = {"long_500k"}


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
        d_ff=8192, vocab=32064, rope_theta=10000.0,
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256,
    )
