"""llama-3.2-vision-11b [vlm]: 40L total (32 self + 8 gated cross-attn,
one cross layer per 5) d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
The vision tower is a STUB: input_specs() provides precomputed patch
embeddings (B, n_img_tokens, d_model).  [hf:meta-llama/Llama-3.2-11B-Vision;
unverified]"""
from repro.models.common import ModelConfig

ARCH_ID = "llama-3.2-vision-11b"
SKIP_SHAPES = {"long_500k"}


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=128256, rope_theta=5e5,
        cross_attn_every=5, n_img_tokens=1600, tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, cross_attn_every=2, n_img_tokens=8,
    )
