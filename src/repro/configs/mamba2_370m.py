"""mamba2-370m [ssm]: 48L d_model=1024 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]

Sub-quadratic: runs long_500k (O(1)-state decode, chunked-scan prefill)."""
from repro.models.common import ModelConfig

ARCH_ID = "mamba2-370m"
SKIP_SHAPES: set = set()  # sub-quadratic: runs everything incl. long_500k


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm",
        n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=50280,
        ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
        ssm_conv=4, ssm_groups=1, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, vocab=256, ssm_state=16, ssm_headdim=16,
        ssm_chunk=16,
    )
