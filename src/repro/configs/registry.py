"""Arch registry + input_specs (ShapeDtypeStruct stand-ins for every input).

``input_specs(cfg, shape, kind)`` returns the exact pytree the corresponding
step function is lowered with — weak-type-correct, shardable, no device
allocation.  Used by launch/dryrun.py and the benchmarks.
"""
from __future__ import annotations

import importlib
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, ShapeSpec
from repro.models.common import ModelConfig

_MODULES = {
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "qwen1.5-4b": "repro.configs.qwen1_5_4b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "phi3-mini-3.8b": "repro.configs.phi3_mini_3_8b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "llama-3.2-vision-11b": "repro.configs.llama32_vision_11b",
    "zamba2-7b": "repro.configs.zamba2_7b",
}

ARCHS = tuple(_MODULES)


def arch_ids():
    return ARCHS


def _module(arch: str):
    if arch not in _MODULES:
        raise ValueError(f"unknown arch {arch!r}; known: {ARCHS}")
    return importlib.import_module(_MODULES[arch])


def get_config(arch: str, *, smoke: bool = False) -> ModelConfig:
    mod = _module(arch)
    return mod.smoke() if smoke else mod.full()


def skip_shapes(arch: str) -> set:
    return set(_module(arch).SKIP_SHAPES)


def batch_specs(cfg: ModelConfig, batch: int, seq: int, *, kind: str) -> Dict:
    """ShapeDtypeStructs for the model-input batch dict."""
    sd = jax.ShapeDtypeStruct
    tok = jnp.int32
    specs: Dict = {}
    if kind == "train":
        specs["tokens"] = sd((batch, seq), tok)
        specs["targets"] = sd((batch, seq), tok)
    elif kind == "prefill":
        specs["tokens"] = sd((batch, seq), tok)
    elif kind == "decode":
        specs["tokens"] = sd((batch, 1), tok)
    else:
        raise ValueError(kind)

    if cfg.family == "encdec":
        if kind == "decode":
            specs["memory"] = sd((batch, cfg.enc_seq, cfg.d_model), cfg.dtype)
        else:
            specs["frames"] = sd((batch, cfg.enc_seq, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        specs["img_embeds"] = sd((batch, cfg.n_img_tokens, cfg.d_model), cfg.dtype)
    return specs


def input_specs(arch_or_cfg, shape_name: str, *, smoke: bool = False):
    """(cfg, shape, batch-dict specs) for one (arch, shape) cell."""
    if isinstance(arch_or_cfg, ModelConfig):
        cfg = arch_or_cfg
    else:
        cfg = get_config(arch_or_cfg, smoke=smoke)
    shape = SHAPES[shape_name]
    specs = batch_specs(cfg, shape.global_batch, shape.seq_len, kind=shape.kind)
    return cfg, shape, specs
