"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (MHA kv=16) d_ff_expert=1408
vocab=151936, MoE 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from repro.models.common import ModelConfig

ARCH_ID = "qwen2-moe-a2.7b"
SKIP_SHAPES = {"long_500k"}


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=1408, vocab=151936, qkv_bias=True, rope_theta=1e6,
        n_experts=60, top_k=4, n_shared_experts=4, d_ff_expert=1408,
        moe_every=1, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=32, vocab=256, n_experts=8, top_k=2, n_shared_experts=1,
        d_ff_expert=32,
    )
