"""Architecture configs: one module per assigned arch (+ shapes + registry)."""
from repro.configs.registry import ARCHS, get_config, arch_ids
from repro.configs.shapes import SHAPES, SHAPE_NAMES, ShapeSpec
