"""Stochastic Lanczos Quadrature for log-determinant (Ubaru–Chen–Saad).

Per unit probe ``u``, ``m`` Lanczos steps on SPD ``A`` build an orthonormal
Krylov basis and a tridiagonal ``T (m, m)``; the Gauss quadrature rule hidden
in ``T`` gives

    u^T log(A) u ~= e_1^T log(T) e_1 = sum_k tau_k^2 log(theta_k)

with ``(theta, tau)`` the eigenvalues of ``T`` and first components of its
eigenvectors.  Averaging ``n * (quadrature)`` over Rademacher probes
estimates ``tr(log A) = logdet(A)``.

Compared to the Chebyshev expansion (chebyshev.py) SLQ needs no spectral
bounds and adapts its quadrature nodes to the actual spectrum — quadrature
error decays ~ exp(-4m / sqrt(cond)) — at the price of keeping the ``m``
basis vectors resident for re-orthogonalization (O(m n k) memory here;
classical three-term Lanczos drifts in floating point without it).

The whole pipeline is one ``lax.fori_loop`` over a (..., n, k) probe slab —
every Lanczos step is a single blocked matvec through the operator backend
(dense / batched / mesh-sharded), and the final eigendecompositions batch
over probes (and stack entries) in one `eigh` call.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.estimators.hutchinson import TraceEstimate, make_probes, mean_sem
from repro.estimators.operators import as_operator
from repro.obs import telemetry as _telemetry

__all__ = ["lanczos", "logdet_slq"]


def lanczos(mm, v0: jax.Array, num_steps: int):
    """Blocked Lanczos with full re-orthogonalization.

    ``mm`` maps (..., n, k) -> (..., n, k); ``v0`` is a slab of k starting
    vectors (normalized internally).  Returns ``(alpha, beta)`` with shapes
    (..., k, m) and (..., k, m-1): per-column tridiagonal coefficients.

    On exact breakdown (Krylov space exhausted, beta ~ 0) the recurrence
    continues with a zero vector: the trailing T block becomes zero and
    carries no e_1 weight, so quadrature results are unaffected.
    """
    m = num_steps
    norm0 = jnp.linalg.norm(v0, axis=-2, keepdims=True)
    q = v0 / norm0
    shape = q.shape                                     # (..., n, k)
    basis0 = jnp.zeros((m, *shape), q.dtype)
    alpha0 = jnp.zeros((m, *shape[:-2], shape[-1]), q.dtype)
    beta0 = jnp.zeros((m, *shape[:-2], shape[-1]), q.dtype)
    eps = jnp.finfo(q.dtype).eps

    def body(j, carry):
        basis, alpha, beta, q, q_prev, b_prev = carry
        basis = basis.at[j].set(q)
        w = mm(q)
        a_j = (q * w).sum(-2)                            # (..., k)
        w = w - a_j[..., None, :] * q - b_prev[..., None, :] * q_prev
        # full re-orthogonalization against the basis so far (rows > j are
        # zero and project out nothing)
        proj = (basis * w).sum(-2)                       # (m, ..., k)
        w = w - (basis * proj[..., None, :]).sum(0)
        b_j = jnp.linalg.norm(w, axis=-2)                # (..., k)
        safe = jnp.where(b_j > eps, b_j, 1.0)
        q_next = jnp.where((b_j > eps)[..., None, :], w / safe[..., None, :],
                           jnp.zeros_like(w))
        alpha = alpha.at[j].set(a_j)
        beta = beta.at[j].set(b_j)
        return basis, alpha, beta, q_next, q, b_j

    zeros = jnp.zeros(shape[:-2] + (shape[-1],), q.dtype)
    _, alpha, beta, _, _, _ = lax.fori_loop(
        0, m, body, (basis0, alpha0, beta0, q, jnp.zeros_like(q), zeros))
    alpha = jnp.moveaxis(alpha, 0, -1)                   # (..., k, m)
    beta = jnp.moveaxis(beta[:-1], 0, -1)                # (..., k, m-1)
    return alpha, beta


def logdet_slq(a, *, num_steps: int = 25, num_probes: int = 32,
               key=None, seed: int = 0, probes=None, mesh=None,
               axis_name: str = "rows") -> TraceEstimate:
    """Estimate ``log|det(A)|`` of an SPD matrix/operator/stack via SLQ.

    Returns a `TraceEstimate` (batched for (B, n, n) stacks): ``est`` is the
    logdet estimate, ``sem`` the Monte-Carlo standard error over probes.

    ``probes`` supplies a pre-drawn (..., n, k) slab instead of sampling
    ``num_probes`` Rademacher probes from ``key`` — used by the grad
    machinery (`estimators.grad`) to share one probe set between forward
    and backward passes.  Any isotropic (``E[v v^T] = I``) probe
    distribution is weighted correctly (per-column ``||v||^2`` quadrature
    weights, not a blanket ``n``).
    """
    op = as_operator(a, mesh=mesh, axis_name=axis_name)
    n = op.shape[-1]
    m = min(num_steps, n)
    dtype = op.dtype
    batch = getattr(op, "batch", None)
    if key is None:
        key = jax.random.PRNGKey(seed)

    if probes is None:
        v0 = make_probes(key, n, num_probes, dtype=dtype,
                         batch_shape=(batch,) if batch else ())
    else:
        v0 = jnp.asarray(probes, dtype)
        if v0.shape[-2] != n:
            raise ValueError(
                f"probes rows {v0.shape} do not match operator n={n}")
    alpha, beta = lanczos(op.mm, v0, m)

    # tridiagonal T per probe -> Gauss quadrature nodes/weights, batched eigh
    diag = alpha[..., None] * jnp.eye(m, dtype=dtype)
    upper = beta_pad(beta, m)[..., None] * jnp.eye(m, k=1, dtype=dtype)
    t = diag + upper + jnp.swapaxes(upper, -1, -2)
    theta, u = jnp.linalg.eigh(t)
    tau2 = u[..., 0, :] ** 2                            # e_1 weights (..., k, m)
    # Zero-block eigenvalues from early breakdown arrive as theta ~ 0 with
    # tau ~ 0; clip so log stays finite before the weight kills the term.
    tiny = jnp.asarray(jnp.finfo(dtype).tiny, dtype)
    quad = (tau2 * jnp.log(jnp.maximum(theta, tiny))).sum(-1)   # (..., k)
    # Weight each quadrature by its probe's squared norm: quad approximates
    # u^T log(A) u for the UNIT vector u = v/||v||, so ||v||^2 * quad is the
    # Hutchinson form v^T log(A) v — unbiased for any E[v v^T] = I probes.
    # For Rademacher probes ||v||^2 == n exactly (the classical n * quad).
    samples = (v0 * v0).sum(-2) * quad
    est, sem = mean_sem(samples)
    # REPRO_OBS=trace: ship the sem-vs-probes curve to the host buffer
    _telemetry.emit_curve("slq.sem", _telemetry.running_sem(samples))
    return TraceEstimate(est, sem, samples)


def beta_pad(beta: jax.Array, m: int) -> jax.Array:
    """(..., k, m-1) off-diagonals -> (..., k, m) padded for diag placement."""
    pad = [(0, 0)] * (beta.ndim - 1) + [(0, 1)]
    return jnp.pad(beta, pad)
