"""Stochastic Chebyshev expansion for log-determinant (Han–Malioutov–Shin).

For SPD ``A`` with spectrum inside ``[lmin, lmax]``:

    logdet(A) = tr(log A) ~= sum_{j=0}^{deg} c_j tr(T_j(B)),
    B = (2A - (lmax + lmin) I) / (lmax - lmin)           (spectrum in [-1, 1])

where ``c_j`` are the Chebyshev coefficients of
``g(t) = log((lmax - lmin) t / 2 + (lmax + lmin) / 2)`` and each trace is
estimated with Hutchinson probes via the three-term recurrence

    w_0 = v,  w_1 = B v,  w_{j+1} = 2 B w_j - w_{j-1}

— O(deg * num_probes) matvecs total, no factorization, no O(n^3) term.
Degree cost/accuracy: the truncation error decays like
``rho^{-deg}`` with ``rho`` driven by sqrt(cond(A)) (Han et al. Thm. 4.1) —
well-conditioned matrices need deg ~ tens; raise ``degree`` (and probes)
for stiffer spectra, or switch to SLQ which adapts to the spectrum.

Batch-polymorphic like the rest of the package: give it a `BatchedOperator`
and probes (B, n, k) and every quantity (bounds, coefficients, estimates)
carries the leading batch axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.estimators.hutchinson import TraceEstimate, make_probes, mean_sem
from repro.estimators.operators import DenseOperator, as_operator
from repro.kernels import ops as _kops
from repro.obs import telemetry as _telemetry

__all__ = ["spectral_bounds", "chebyshev_coeffs_log", "logdet_chebyshev"]


def spectral_bounds(op, key, *, iters: int = 32, safety: float = 1.05):
    """(lmin, lmax) bracket for an SPD operator, by matvecs alone.

    Power iteration gives ``lmax``; a second power iteration on the shifted
    operator ``lmax*I - A`` (largest eigenvalue ``lmax - lmin``) gives
    ``lmin``.  ``safety`` widens the bracket so the Chebyshev interval
    certainly contains the spectrum despite early termination.
    """
    n = op.shape[-1]
    batch = getattr(op, "batch", None)
    shape = (batch, n, 1) if batch else (n, 1)
    v0 = jax.random.normal(key, shape, dtype=op.dtype)

    def power(mv_fn):
        def body(_, v):
            w = mv_fn(v)
            return w / jnp.linalg.norm(w, axis=-2, keepdims=True)
        v = lax.fori_loop(0, iters, body, v0)
        w = mv_fn(v)
        return (v * w).sum((-2, -1)) / (v * v).sum((-2, -1))

    lmax = power(op.mm) * safety
    lmax_b = lmax[..., None, None]
    shifted = power(lambda v: lmax_b * v - op.mm(v))
    lmin = (lmax - shifted) / safety
    return jnp.maximum(lmin, lmax * 1e-12), lmax


def chebyshev_coeffs_log(lmin, lmax, degree: int, dtype):
    """(..., degree+1) Chebyshev coefficients of log(x) mapped to [-1, 1].

    Chebyshev–Gauss quadrature at the deg+1 nodes x_q = cos(theta_q):
    ``c_j = 2/(deg+1) * sum_q log(x(x_q)) cos(j theta_q)`` (halved for j=0)
    — closed-form in jnp so traced spectral bounds flow straight through.
    """
    q = degree + 1
    theta = (jnp.arange(q, dtype=dtype) + 0.5) * (jnp.pi / q)
    xq = jnp.cos(theta)                                        # (q,)
    lmin = jnp.asarray(lmin, dtype)[..., None]
    lmax = jnp.asarray(lmax, dtype)[..., None]
    g = jnp.log(0.5 * (lmax - lmin) * xq + 0.5 * (lmax + lmin))  # (..., q)
    tjk = jnp.cos(jnp.arange(q, dtype=dtype)[:, None] * theta)   # (j, q)
    c = (2.0 / q) * jnp.einsum("jq,...q->...j", tjk, g)
    return c.at[..., 0].mul(0.5)


def logdet_chebyshev(a, *, degree: int = 64, num_probes: int = 32,
                     key=None, seed: int = 0, lmin=None, lmax=None,
                     probe_kind: str = "rademacher", probes=None,
                     mesh=None, axis_name: str = "rows") -> TraceEstimate:
    """Estimate ``log|det(A)|`` of an SPD matrix/operator/stack.

    Returns a `TraceEstimate` — ``est`` is the logdet estimate (batched when
    ``a`` is a (B, n, n) stack), ``sem`` its Monte-Carlo standard error
    (which does NOT include the deterministic truncation bias; see module
    docstring for the degree trade-off).

    ``probes`` supplies a pre-drawn (..., n, k) slab instead of sampling
    ``num_probes`` internally — the grad machinery (`estimators.grad`) uses
    it to share one probe set between the forward estimate and the
    backward Hutchinson pullback.  The key is still split identically, so
    a call with ``probes`` drawn from the second half reproduces the
    internally-sampled value bit for bit.
    """
    if degree < 1:
        raise ValueError(f"degree must be >= 1, got {degree}")
    op = as_operator(a, mesh=mesh, axis_name=axis_name)
    n = op.shape[-1]
    dtype = op.dtype
    batch = getattr(op, "batch", None)
    if key is None:
        key = jax.random.PRNGKey(seed)
    kb, kp = jax.random.split(key)

    if lmin is None or lmax is None:
        lo, hi = spectral_bounds(op, kb)
        lmin = lo if lmin is None else jnp.asarray(lmin, dtype)
        lmax = hi if lmax is None else jnp.asarray(lmax, dtype)
    lmin = jnp.broadcast_to(jnp.asarray(lmin, dtype), (batch,) if batch else ())
    lmax = jnp.broadcast_to(jnp.asarray(lmax, dtype), (batch,) if batch else ())
    c = chebyshev_coeffs_log(lmin, lmax, degree, dtype)   # (..., deg+1)

    center = (lmax + lmin)[..., None, None]
    width = (lmax - lmin)[..., None, None]

    def mv_b(v):                       # spectrum-normalized operator B
        return (2.0 * op.mm(v) - center * v) / width

    if probes is None:
        v = make_probes(kp, n, num_probes, kind=probe_kind, dtype=dtype,
                        batch_shape=(batch,) if batch else ())
    else:
        v = jnp.asarray(probes, dtype)
        if v.shape[-2] != n:
            raise ValueError(
                f"probes rows {v.shape} do not match operator n={n}")
    w_prev, w = v, mv_b(v)
    samples = (c[..., 0, None] * (v * v).sum(-2)
               + c[..., 1, None] * (v * w).sum(-2))       # (..., k)

    if isinstance(op, DenseOperator):
        # dense operators take the fused three-term kernel: shifted
        # matvec + axpy + probe dot in one pass over A (op-for-op the
        # unfused body below, so f32 results are bit-identical; the
        # dispatch layer falls back to the identical jnp reference when
        # A exceeds the VMEM budget or off-TPU)
        def body(j, carry):
            w_prev, w, samples = carry
            w_next, dots = _kops.fused_cheb_step(op.a, w, w_prev, v,
                                                 center, width)
            cj = jnp.take(c, j, axis=-1)[..., None]
            return w, w_next, samples + cj * dots
    else:
        def body(j, carry):
            w_prev, w, samples = carry
            w_next = 2.0 * mv_b(w) - w_prev
            cj = jnp.take(c, j, axis=-1)[..., None]
            samples = samples + cj * (v * w_next).sum(-2)
            return w, w_next, samples

    _, _, samples = lax.fori_loop(2, degree + 1, body, (w_prev, w, samples))
    est, sem = mean_sem(samples)
    # REPRO_OBS=trace: ship the sem-vs-probes curve to the host buffer
    _telemetry.emit_curve("chebyshev.sem", _telemetry.running_sem(samples))
    return TraceEstimate(est, sem, samples)
