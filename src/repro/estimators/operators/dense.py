"""Dense backend: wraps an in-memory (n, n) matrix.

The reference backend — zero structure assumed, one XLA GEMM per ``mm``.
Right when the matrix already fits in device memory and N is moderate;
every structured backend in this package exists to beat it on memory
(never materialize A) or FLOPs (exploit structure in the product).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.estimators.operators.base import (
    LinearOperator, PlanHints, check_square,
)

__all__ = ["DenseOperator"]


class DenseOperator(LinearOperator):
    """Wraps an in-memory (n, n) matrix."""

    def __init__(self, a: jax.Array):
        a = jnp.asarray(a)
        check_square(a.shape)
        self.a = a
        self.shape = a.shape
        self.dtype = a.dtype

    def mm(self, v):
        return self.a @ v

    def mv(self, v):
        return self.a @ v

    def rmm(self, v):
        return self.a.T @ v

    def rmv(self, v):
        return self.a.T @ v

    def diag(self):
        return jnp.diagonal(self.a)

    def trace_hint(self):
        return jnp.trace(self.a)

    def to_dense(self):
        return self.a

    def plan_hints(self):
        # the matrix is already resident: exact O(n^3) methods are fair game
        n = self.n
        return PlanHints(structure="dense", matvec_flops=2.0 * n * n,
                         materializable=True)
