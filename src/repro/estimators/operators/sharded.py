"""Mesh-sharded backend: row-distributed dense matvec via shard_map.

Matrix rows live sharded over a 1-D device mesh; probe slabs are
replicated; each device multiplies its (L, n) row block against the
resident (n, k) slab (through the tiled Pallas matvec kernel on TPU) and
the row chunks concatenate back along the row axis.  The layout matches
the parallel condensation core (device ``p`` owns rows ``[p*L, (p+1)*L)``)
so a matrix can be handed from the exact path to the estimator path
without a resharding pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro._compat import shard_map as _shard_map
from repro.estimators.operators.base import (
    LinearOperator, PlanHints, check_square,
)

__all__ = ["ShardedOperator", "rowwise_matvec_specs"]


def rowwise_matvec_specs(axis_name: str):
    """(in_specs, out_specs) for a row-distributed matvec under shard_map.

    Matrix rows sharded over ``axis_name``, probe slab replicated, result row
    chunks concatenated back along the row axis.
    """
    p = PartitionSpec
    return (p(axis_name, None), p(None, None)), p(axis_name, None)


@functools.lru_cache(maxsize=16)
def _sharded_mm(mesh, axis_name: str, use_kernel: bool):
    from repro.kernels import ops as _kops

    def kernel(local, v):            # local (L, n), v (n, k) replicated
        if use_kernel:
            return _kops.matvec(local, v)
        return local @ v

    in_specs, out_specs = rowwise_matvec_specs(axis_name)
    return jax.jit(_shard_map(kernel, mesh=mesh,
                              in_specs=in_specs, out_specs=out_specs))


class ShardedOperator(LinearOperator):
    """Row-distributed dense operator over a 1-D mesh.

    ``n`` must be divisible by the mesh size (pad via
    ``repro.core.pad_to_multiple``, which leaves the determinant unchanged).
    """

    def __init__(self, a: jax.Array, mesh, axis_name: str = "rows", *,
                 use_kernel: bool = True):
        a = jnp.asarray(a)
        check_square(a.shape)
        nproc = int(mesh.shape[axis_name])
        if a.shape[0] % nproc:
            raise ValueError(
                f"N={a.shape[0]} not divisible by mesh size {nproc}; "
                "pad with repro.core.pad_to_multiple first")
        self.mesh = mesh
        self.axis_name = axis_name
        self.use_kernel = use_kernel
        self.shape = a.shape
        self.dtype = a.dtype
        self.a = jax.device_put(
            a, NamedSharding(mesh, PartitionSpec(axis_name, None)))
        self._mm = _sharded_mm(mesh, axis_name, use_kernel)

    def mm(self, v):
        return self._mm(self.a, v.astype(self.dtype))

    def rmm(self, v):
        # transposed matvec as (v^T A)^T on the row-sharded buffer — the
        # contraction over the sharded row axis lowers to a psum under
        # XLA's sharding propagation, no explicit shard_map needed
        vt = jnp.swapaxes(v.astype(self.dtype), -1, -2)
        return jnp.swapaxes(vt @ self.a, -1, -2)

    def diag(self):
        # gathers one element per row — cheap relative to any matvec
        return jnp.diagonal(self.a)

    def trace_hint(self):
        return jnp.trace(self.a)

    def to_dense(self):
        return self.a

    def plan_hints(self):
        # dense cost split across the mesh; rows are resident (sharded), so
        # the exact parallel condensation path stays available
        n = self.n
        p = int(self.mesh.shape[self.axis_name])
        return PlanHints(structure="sharded", matvec_flops=2.0 * n * n / p,
                         materializable=True, device_count=p)
