"""The `LinearOperator` protocol: how estimators and solvers see a matrix.

Every matrix-free algorithm in this package (Hutchinson traces, stochastic
Chebyshev, SLQ, conjugate gradient) touches the operator through four
methods:

  mm(v)         blocked matvec with a slab of column vectors (n, k) -> (n, k)
                — THE hot path; one call per polynomial / Lanczos / CG step.
  mv(v)         single matvec (n,) -> (n,); default routes through ``mm``.
  diag()        the operator's diagonal (n,) when cheaply available, else
                ``None``.  Powers Jacobi preconditioning in `solve.cg_solve`
                and diagonal peel-off variance reduction (ROADMAP).
  trace_hint()  exact trace when the structure makes it free (Kronecker:
                tr(A)tr(B); Toeplitz: n*c0), else ``None`` — estimators can
                use it as a control variate instead of spending probes.
  rmm(v)/rmv(v) transposed matvec A^T v; defaults assume symmetry (the SPD
                estimator context), non-symmetric-capable backends override.
                Powers `cg_solve(..., transpose=True)` and the logdet
                gradient pullback (`repro.estimators.grad`).

Anything with ``.shape``, ``.dtype`` and ``.mm`` quacks as an operator, so
user-defined implicit operators (data covariances, Jacobians, graph
Laplacians) plug in without subclassing — see `EmpiricalCovOperator` in
examples/gmm_loglik.py for a ~15-line external implementation.

Batch semantics: operators are square (n, n).  A `BatchedOperator` stack
additionally exposes ``batch`` and takes slabs with a leading batch axis
(B, n, k); estimators detect the attribute and broadcast everything else.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["LinearOperator", "PlanHints", "is_operator"]


class PlanHints(NamedTuple):
    """What an operator tells the plan-time cost model (`repro.plan`).

    ``structure``        short tag ("dense", "kron", "toeplitz", ...) used
                         in diagnostics and the method decision tree
    ``matvec_flops``     FLOPs one matvec column costs through this backend
                         — the unit the estimator cost model multiplies by
                         its probe x step budget
    ``materializable``   True when `to_dense` is a cheap O(n^2) read (the
                         matrix already exists in memory — dense entries,
                         sharded rows); False for implicit backends.
                         Advisory: operator inputs always route to the
                         matrix-free estimator family (exact methods take
                         the array itself, not an operator), but the flag
                         feeds cost accounting and diagnostics
    ``device_count``     devices a matvec spans (mesh size, else 1)
    """
    structure: str
    matvec_flops: float
    materializable: bool = False
    device_count: int = 1


class LinearOperator:
    """Protocol base: square operator exposing blocked matvec ``mm``."""

    shape: Tuple[int, ...]
    dtype = None

    def mm(self, v: jax.Array) -> jax.Array:
        """Product with a slab of column vectors: (..., n, k) -> (..., n, k)."""
        raise NotImplementedError

    def mv(self, v: jax.Array) -> jax.Array:
        """Single matvec (..., n) -> (..., n)."""
        return self.mm(v[..., :, None])[..., :, 0]

    def rmm(self, v: jax.Array) -> jax.Array:
        """Transposed blocked matvec ``A^T v``: (..., n, k) -> (..., n, k).

        Default delegates to ``mm`` — correct for the symmetric (SPD)
        operators the estimators assume; backends that can represent
        non-symmetric matrices override it.  This is the hook
        `solve.cg_solve(..., transpose=True)` and the logdet-gradient
        pullback (`estimators.grad`) use to apply ``A^{-T}`` safely.
        """
        return self.mm(v)

    def rmv(self, v: jax.Array) -> jax.Array:
        """Single transposed matvec ``A^T v``: (..., n) -> (..., n)."""
        return self.rmm(v[..., :, None])[..., :, 0]

    def diag(self) -> Optional[jax.Array]:
        """Operator diagonal (..., n) when cheap, else None (unknown)."""
        return None

    def trace_hint(self) -> Optional[jax.Array]:
        """Exact trace when the structure makes it free, else None.

        Default: sum of `diag` when that is available.
        """
        d = self.diag()
        return None if d is None else d.sum(-1)

    def plan_hints(self) -> PlanHints:
        """Cost-model advertisement for ``repro.plan(method="auto")``.

        The default assumes an unstructured implicit operator: a dense-cost
        matvec (2 n^2 FLOPs per column) that cannot be materialized, which
        routes the auto-selector to the estimator family.  Backends with
        real structure override this with their actual per-column cost.
        """
        n = self.shape[-1]
        return PlanHints(structure="implicit", matvec_flops=2.0 * n * n,
                         materializable=False)

    def to_dense(self) -> jax.Array:
        """Materialize as (n, n) — O(n) matvecs; testing / small-n only."""
        return self.mm(jnp.eye(self.n, dtype=self.dtype))

    @property
    def n(self) -> int:
        return self.shape[0]


def is_operator(a) -> bool:
    """True if ``a`` satisfies the operator protocol (subclass or duck).

    The single source of truth for "is this an operator, not an array":
    arrays expose ``ndim``; operators expose ``mm`` and ``shape`` and
    don't.  Used by `as_operator`, ``slogdet`` and ``logdet_batched`` so
    the routing rule cannot drift between entry points.
    """
    if isinstance(a, LinearOperator):
        return True
    return (hasattr(a, "mm") and hasattr(a, "shape")
            and not hasattr(a, "ndim"))


def check_square(shape, what: str = "matrix"):
    if len(shape) != 2 or shape[0] != shape[1]:
        raise ValueError(f"expected square {what}, got {tuple(shape)}")
