"""Toeplitz backend: constant-diagonal matvecs via circulant FFT embedding.

Stationary covariances — autoregressive processes, time-series kernels,
translation-invariant grids — are Toeplitz: ``T[i, j] = t_{i-j}`` is fully
determined by its first column ``c`` (and first row ``r`` when
non-symmetric).  Storage is O(n); the matvec embeds T in the 2n-circulant

    col(C) = [c_0, ..., c_{n-1}, 0, r_{n-1}, ..., r_1]

whose eigenvectors are the DFT, so

    T x = (C [x; 0])[:n] = irfft( rfft(col) * rfft([x; 0]) )[:n]

— O(n log n) per probe column instead of O(n^2), with ``rfft(col)``
precomputed once at construction.  Exact to roundoff (the embedding is an
identity, not an approximation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.estimators.operators.base import LinearOperator, PlanHints

__all__ = ["ToeplitzOperator"]


class ToeplitzOperator(LinearOperator):
    """Implicit Toeplitz operator from first column ``c`` (and row ``r``).

    ``c (n,)`` is the first column; ``r (n,)`` the first row (defaults to
    ``c`` — the symmetric case, the one SPD estimators assume).  ``r[0]``
    must agree with ``c[0]``; the diagonal is taken from ``c``.
    """

    def __init__(self, c: jax.Array, r: jax.Array = None):
        c = jnp.asarray(c)
        if c.ndim != 1 or c.shape[0] < 1:
            raise ValueError(f"expected first column (n,), got {c.shape}")
        if jnp.issubdtype(c.dtype, jnp.complexfloating):
            raise ValueError("complex Toeplitz not supported (SPD context)")
        r = c if r is None else jnp.asarray(r)
        if r.shape != c.shape:
            raise ValueError(f"first row shape {r.shape} != column {c.shape}")
        n = c.shape[0]
        self.c, self.r = c, r
        self.shape = (n, n)
        self.dtype = jnp.result_type(c.dtype, r.dtype)
        # 2n-circulant first column; the n-th entry is never touched by the
        # top-left (n, n) block, zero keeps the embedding well-scaled.
        zero = jnp.zeros((1,), self.dtype)
        col = jnp.concatenate([c, zero, r[1:][::-1]]).astype(self.dtype)
        self._m = 2 * n
        self._fcol = jnp.fft.rfft(col)
        self._fcol_t = None              # transposed symbol, built on demand

    def _circulant_mm(self, fcol, v):
        if v.ndim != 2 or v.shape[0] != self.n:
            raise ValueError(f"expected ({self.n}, k) slab, got {v.shape}")
        vp = jnp.pad(v.astype(self.dtype), ((0, self._m - self.n), (0, 0)))
        y = jnp.fft.irfft(fcol[:, None] * jnp.fft.rfft(vp, axis=0),
                          self._m, axis=0)
        return y[:self.n].astype(self.dtype)

    def mm(self, v):  # (n, k) -> (n, k)
        return self._circulant_mm(self._fcol, v)

    def rmm(self, v):  # (n, k) -> (n, k): T^T via the swapped-symbol embedding
        if self._fcol_t is None:
            # transpose swaps first column and first row: T^T[i, j] = t_{j-i};
            # lazy so mm-only uses never pay the extra rfft
            zero = jnp.zeros((1,), self.dtype)
            col_t = jnp.concatenate(
                [self.r, zero, self.c[1:][::-1]]).astype(self.dtype)
            self._fcol_t = jnp.fft.rfft(col_t)
        return self._circulant_mm(self._fcol_t, v)

    def diag(self):
        return jnp.full((self.n,), self.c[0], self.dtype)

    def trace_hint(self):
        return self.n * self.c[0].astype(self.dtype)

    def plan_hints(self):
        import numpy as _np
        # three length-2n FFTs per column: ~ 15 n log2(n) real FLOPs
        n = max(self.n, 2)
        return PlanHints(structure="toeplitz",
                         matvec_flops=15.0 * n * float(_np.log2(n)),
                         materializable=False)

    def to_dense(self):
        i = jnp.arange(self.n)
        d = i[:, None] - i[None, :]                  # i - j
        vals = jnp.concatenate([self.r[1:][::-1], self.c])  # index d + n - 1
        return vals[d + self.n - 1]
