"""Implicit linear-operator backends for the matrix-free estimators.

Every estimator and solver in `repro.estimators` touches the matrix ONLY
through the `LinearOperator` protocol (``mm``/``mv``/``diag``/
``trace_hint`` — see base.py).  Backends by scenario:

  DenseOperator      in-memory (n, n) matrix                       [1 dev]
  BatchedOperator    (B, n, n) stack, one batched GEMM per step
  ShardedOperator    row-distributed dense matvec over a 1-D mesh  [mesh]
  KroneckerOperator  A ⊗ B via reshaped GEMMs — O(n^1.5) memory
  ToeplitzOperator   constant diagonals via circulant FFT — O(n) memory
  StencilOperator    banded contraction via Pallas kernel — O(nb*n)

plus `cg_solve` (solve.py): batched preconditioned conjugate gradient on
any of the above, making linear solves as matrix-free as the logdets.

See README.md in this directory for the selection guide, and
`as_operator` for the coercion rules arrays follow into the protocol.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.estimators.operators.base import (
    LinearOperator, PlanHints, is_operator,
)
from repro.estimators.operators.batched import BatchedOperator
from repro.estimators.operators.dense import DenseOperator
from repro.estimators.operators.kron import KroneckerOperator
from repro.estimators.operators.sharded import (
    ShardedOperator, rowwise_matvec_specs,
)
from repro.estimators.operators.stencil import StencilOperator
from repro.estimators.operators.toeplitz import ToeplitzOperator

__all__ = [
    "LinearOperator", "PlanHints", "DenseOperator", "BatchedOperator",
    "ShardedOperator", "KroneckerOperator", "ToeplitzOperator",
    "StencilOperator", "as_operator", "is_operator", "rowwise_matvec_specs",
    "CGResult", "cg_solve",
]


def as_operator(a, *, mesh=None, axis_name: str = "rows",
                use_kernel: bool = True) -> LinearOperator:
    """Coerce a matrix / stack / operator to the estimator protocol.

    (n, n) array -> `DenseOperator` (or `ShardedOperator` when ``mesh`` is
    given); (B, n, n) array -> `BatchedOperator`; an existing operator —
    including user-defined duck-typed ones — passes through untouched.
    """
    if is_operator(a):
        return a
    a = jnp.asarray(a)
    if a.ndim == 3:
        return BatchedOperator(a)
    if mesh is not None and int(mesh.shape[axis_name]) > 1:
        return ShardedOperator(a, mesh, axis_name, use_kernel=use_kernel)
    return DenseOperator(a)


from repro.estimators.operators.solve import CGResult, cg_solve  # noqa: E402
