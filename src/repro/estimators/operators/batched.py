"""Batched backend: a (B, n, n) stack driven as one operator.

Used by ``logdet_batched`` and the GMM example: one estimator / CG
invocation drives the whole stack, so XLA sees a single batched GEMM per
polynomial / Lanczos / CG step instead of B small ones.  Probe and
right-hand-side slabs carry a leading batch axis (B, n, k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.estimators.operators.base import LinearOperator, PlanHints

__all__ = ["BatchedOperator"]


class BatchedOperator(LinearOperator):
    """Wraps a (B, n, n) stack; slabs carry a leading batch axis (B, n, k)."""

    def __init__(self, stack: jax.Array):
        stack = jnp.asarray(stack)
        if stack.ndim != 3 or stack.shape[1] != stack.shape[2]:
            raise ValueError(f"expected (B, n, n) stack, got {stack.shape}")
        self.stack = stack
        self.shape = stack.shape[1:]
        self.batch = stack.shape[0]
        self.dtype = stack.dtype

    def mm(self, v):  # (B, n, k) -> (B, n, k)
        return jnp.einsum("bij,bjk->bik", self.stack, v)

    def mv(self, v):  # (B, n) -> (B, n)
        return jnp.einsum("bij,bj->bi", self.stack, v)

    def rmm(self, v):  # (B, n, k) -> (B, n, k): per-matrix A_b^T v_b
        return jnp.einsum("bji,bjk->bik", self.stack, v)

    def rmv(self, v):  # (B, n) -> (B, n)
        return jnp.einsum("bji,bj->bi", self.stack, v)

    def diag(self):  # (B, n)
        return jnp.diagonal(self.stack, axis1=-2, axis2=-1)

    def trace_hint(self):  # (B,)
        return jnp.trace(self.stack, axis1=-2, axis2=-1)

    def to_dense(self):
        return self.stack

    def plan_hints(self):
        # per-matrix dense cost; the stack is resident, so the exact path
        # (vmapped condensation) is available below the crossover
        n = self.shape[-1]
        return PlanHints(structure="batched", matvec_flops=2.0 * n * n,
                         materializable=True)
