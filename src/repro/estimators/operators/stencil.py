"""Stencil backend: banded matrices as (offset, coefficient-row) pairs.

Discretized differential operators, graph Laplacians on paths/grids, and
banded precision matrices are defined by a few diagonals:

    A[i, i + offsets[d]] = bands[d, i]          (zero outside the bands)

Storage is O(nb * n) for nb bands; the matvec is a bandwidth-bound
contraction ``y[i] = sum_d bands[d, i] * x[i + offsets[d]]`` — O(nb * n)
FLOPs per probe column instead of O(n^2) — routed through the Pallas
kernel `repro.kernels.stencil_mv` on TPU (jnp reference elsewhere).

Entries whose stencil pokes outside ``[0, n)`` read zero (Dirichlet
boundary), matching the dense banded materialization in `to_dense`.

For the SPD workloads the estimators assume, use symmetric band tables:
offset ``-d`` carrying the transpose coefficients of offset ``+d``
(e.g. the 1-D Laplacian ``offsets=(-1, 0, 1)``,
``bands=(-1, 2 + eps, -1)``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.estimators.operators.base import LinearOperator, PlanHints

__all__ = ["StencilOperator"]


def _transpose_bands(bands: jax.Array, offsets) -> jax.Array:
    """Band table of ``A^T``: row ``d`` holds ``bands[d]`` shifted by its
    offset (entries whose source row falls outside ``[0, n)`` address
    columns outside the matrix and are zeroed)."""
    n = bands.shape[1]
    rows = []
    for d, o in enumerate(offsets):
        b = bands[d]
        if o > 0:
            b = jnp.concatenate([jnp.zeros((o,), bands.dtype), b[:n - o]])
        elif o < 0:
            b = jnp.concatenate([b[-o:], jnp.zeros((-o,), bands.dtype)])
        rows.append(b)
    return jnp.stack(rows)


class StencilOperator(LinearOperator):
    """Implicit banded operator from diagonal offsets + coefficient rows.

    ``offsets`` — tuple of distinct ints in (-n, n), one per band.
    ``bands`` — (nb, n) per-row coefficients, or (nb,) constants broadcast
    along each diagonal (requires ``n``).
    """

    def __init__(self, offsets, bands, n: int = None):
        offsets = tuple(int(o) for o in offsets)
        if len(set(offsets)) != len(offsets):
            raise ValueError(f"duplicate offsets: {offsets}")
        bands = jnp.asarray(bands)
        if bands.ndim == 1:
            if n is None:
                raise ValueError("constant bands (nb,) require n")
            bands = jnp.broadcast_to(bands[:, None], (bands.shape[0], n))
        elif bands.ndim == 2:
            n = bands.shape[1]
        else:
            raise ValueError(f"bands must be (nb,) or (nb, n), "
                             f"got {bands.shape}")
        if bands.shape[0] != len(offsets):
            raise ValueError(f"{len(offsets)} offsets but "
                             f"{bands.shape[0]} band rows")
        if any(abs(o) >= n for o in offsets):
            raise ValueError(f"offsets {offsets} out of range for n={n}")
        self.offsets = offsets
        self.bands = bands
        self.shape = (n, n)
        self.dtype = bands.dtype
        # transposed band table: A^T has offset -o carrying bands[d]
        # shifted so that A^T[i, i-o] = A[i-o, i] = bands[d, i-o];
        # the shifted table itself is built lazily on first rmm use
        self._offsets_t = tuple(-o for o in offsets)
        self._bands_t = None

    def mm(self, v):  # (n, k) -> (n, k)
        from repro.kernels import ops as _kops
        if v.ndim != 2 or v.shape[0] != self.n:
            raise ValueError(f"expected ({self.n}, k) slab, got {v.shape}")
        return _kops.stencil_mv(self.bands, v.astype(self.dtype),
                                offsets=self.offsets)

    def mv(self, v):
        from repro.kernels import ops as _kops
        return _kops.stencil_mv(self.bands, v.astype(self.dtype),
                                offsets=self.offsets)

    def rmm(self, v):  # (n, k) -> (n, k): A^T via the transposed band table
        from repro.kernels import ops as _kops
        if v.ndim != 2 or v.shape[0] != self.n:
            raise ValueError(f"expected ({self.n}, k) slab, got {v.shape}")
        if self._bands_t is None:
            self._bands_t = _transpose_bands(self.bands, self.offsets)
        return _kops.stencil_mv(self._bands_t, v.astype(self.dtype),
                                offsets=self._offsets_t)

    def diag(self):
        if 0 in self.offsets:
            return self.bands[self.offsets.index(0)]
        return jnp.zeros((self.n,), self.dtype)

    def plan_hints(self):
        # banded contraction: 2 FLOPs per band entry per column
        return PlanHints(structure="stencil",
                         matvec_flops=2.0 * len(self.offsets) * self.n,
                         materializable=False)

    def to_dense(self):
        n = self.n
        a = jnp.zeros((n, n), self.dtype)
        for d, off in enumerate(self.offsets):
            if off >= 0:
                a = a + jnp.diag(self.bands[d, :n - off], off)
            else:
                a = a + jnp.diag(self.bands[d, -off:], off)
        return a
