"""Kronecker backend: ``A (nA, nA) ⊗ B (nB, nB)`` without materializing it.

Covariances with separable structure — spatio-temporal grids, matrix-normal
models, per-axis kernels — factor as ``Sigma = A ⊗ B`` with
``n = nA * nB``.  Materializing Sigma costs O(n^2) memory; storing the
factors costs O(nA^2 + nB^2) = O(n) when nA ~ nB ~ sqrt(n).

The matvec uses the reshape identity (row-major flattening, index
``i = i1 * nB + i2``):

    (A ⊗ B) x = vec( A X B^T ),   X = reshape(x, (nA, nB))

— two GEMMs of shape (nA, nA)@(nA, nB*k) and (nB, nB)@(nB, nA*k) per slab,
O(n (nA + nB)) = O(n^1.5) FLOPs per probe column instead of O(n^2), and
peak memory O(n^1.5) for the factors plus the slab.

Structure also makes spectra and traces free:
``tr(A ⊗ B) = tr(A) tr(B)``, ``diag(A ⊗ B) = diag(A) ⊗ diag(B)``, and
``logdet(A ⊗ B) = nB logdet(A) + nA logdet(B)`` (the exact cross-check the
benchmarks use).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.estimators.operators.base import (
    LinearOperator, PlanHints, check_square,
)

__all__ = ["KroneckerOperator"]


class KroneckerOperator(LinearOperator):
    """Implicit ``A ⊗ B`` for square factors A (nA, nA), B (nB, nB)."""

    def __init__(self, a: jax.Array, b: jax.Array):
        a, b = jnp.asarray(a), jnp.asarray(b)
        check_square(a.shape, "left factor")
        check_square(b.shape, "right factor")
        self.dtype = jnp.result_type(a.dtype, b.dtype)
        self.a = a.astype(self.dtype)
        self.b = b.astype(self.dtype)
        self.na = a.shape[0]
        self.nb = b.shape[0]
        n = self.na * self.nb
        self.shape = (n, n)

    def mm(self, v):  # (n, k) -> (n, k)
        if v.ndim != 2 or v.shape[0] != self.n:
            raise ValueError(f"expected ({self.n}, k) slab, got {v.shape}")
        k = v.shape[1]
        x = v.reshape(self.na, self.nb, k)
        t = jnp.einsum("ij,jbk->ibk", self.a, x)      # A over the left factor
        y = jnp.einsum("cb,ibk->ick", self.b, t)      # B over the right factor
        return y.reshape(self.n, k)

    def rmm(self, v):  # (n, k) -> (n, k): (A ⊗ B)^T = A^T ⊗ B^T
        if v.ndim != 2 or v.shape[0] != self.n:
            raise ValueError(f"expected ({self.n}, k) slab, got {v.shape}")
        k = v.shape[1]
        x = v.reshape(self.na, self.nb, k)
        t = jnp.einsum("ji,jbk->ibk", self.a, x)      # A^T over the left
        y = jnp.einsum("bc,ibk->ick", self.b, t)      # B^T over the right
        return y.reshape(self.n, k)

    def diag(self):
        d = self.a.diagonal()[:, None] * self.b.diagonal()[None, :]
        return d.reshape(self.n)

    def trace_hint(self):
        return jnp.trace(self.a) * jnp.trace(self.b)

    def to_dense(self):
        return jnp.kron(self.a, self.b)

    def plan_hints(self):
        # two reshaped GEMMs: O(n (na + nb)) per column, never materialized
        return PlanHints(structure="kron",
                         matvec_flops=2.0 * self.n * (self.na + self.nb),
                         materializable=False)
