"""Matrix-free conjugate gradient on the `LinearOperator` protocol.

Solves ``A X = B`` for SPD ``A`` touching the operator only through
``mm`` — one blocked matvec per iteration, batched over a slab of
right-hand sides ``B (..., n, k)`` exactly the way the estimators batch
probe columns (and over a leading batch axis for `BatchedOperator`
stacks).  Iteration count scales with sqrt(cond(A)); Jacobi
preconditioning from ``op.diag()`` (free for every structured backend)
divides out diagonal disparity before the Krylov iteration pays for it.

All columns iterate in lockstep inside one ``lax.while_loop`` — the loop
stops when EVERY column's residual passes ``||r|| <= tol * ||b|| + atol``
or at ``maxiter``; converged columns take guarded no-op steps (their
search directions underflow to zero) so there is no per-column control
flow to break batching.

This is what makes the GMM example's Mahalanobis term sub-cubic: the
E-step solve goes from one O(n^3) factorization per covariance to
O(iters) structured matvecs (see examples/gmm_loglik.py --solver cg).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.obs import telemetry as _telemetry

__all__ = ["CGResult", "cg_solve"]


class CGResult(NamedTuple):
    """Solution with convergence evidence."""
    x: jax.Array          # (..., n, k) solution slab (or (..., n) for mv rhs)
    iters: jax.Array      # () iterations taken
    resnorm: jax.Array    # (..., k) final residual 2-norms per column
    converged: jax.Array  # () all columns under tolerance?


def _safe_div(num, den):
    """num / den with 0/0 -> 0 (converged columns have vanishing den)."""
    tiny = jnp.finfo(den.dtype).tiny
    safe = jnp.where(jnp.abs(den) > tiny, den, 1.0)
    return jnp.where(jnp.abs(den) > tiny, num / safe, jnp.zeros_like(num))


def cg_solve(a, b, *, tol: float = 1e-10, atol: float = 0.0,
             maxiter: Optional[int] = None, precondition: bool = True,
             x0: Optional[jax.Array] = None,
             transpose: bool = False) -> CGResult:
    """Preconditioned conjugate gradient: solve SPD ``a @ x = b``.

    ``a`` is anything `as_operator` accepts — a matrix, a (B, n, n) stack,
    or any `LinearOperator`.  ``b`` is a slab (..., n, k) or a single
    vector (..., n) matching the operator's batching.  ``precondition``
    uses Jacobi scaling from ``op.diag()`` when the backend provides it.
    ``transpose=True`` solves ``a^T x = b`` through the operator's ``rmm``
    hook — a no-op for symmetric operators but what makes the logdet
    gradient pullback (`repro.estimators.grad`) safe on backends that can
    represent non-symmetric matrices (CG itself still assumes the applied
    operator is SPD).

    Zero right-hand-side columns are recognized up front: their unique SPD
    solution is ``x = 0``, returned without spending iterations (and
    overriding any ``x0`` guess), so an all-zero ``b`` exits immediately
    instead of grinding through ``maxiter`` guarded 0/0 no-op steps.

    Returns a `CGResult`; ``converged`` is a traced bool — check it (or
    ``resnorm``) rather than assuming ``maxiter`` sufficed.
    """
    from repro.estimators.operators import (  # lazy: package cycle
        DenseOperator, as_operator)
    op = as_operator(a)
    mm = op.rmm if transpose else op.mm
    # dense forward solves take the fused matvec+axpy+dot kernel (one
    # pass over A per iteration); rmm has no fused form, and the
    # dispatch layer falls back to the identical jnp reference when A
    # exceeds the VMEM budget or off-TPU — either way op-for-op the
    # inline chain below, so results are bit-identical
    fused_a = op.a if (isinstance(op, DenseOperator)
                       and not transpose) else None
    n = op.shape[-1]
    if maxiter is None:
        maxiter = 10 * n
    b = jnp.asarray(b, op.dtype)
    batch = getattr(op, "batch", None)
    vec = b.ndim == (1 if batch is None else 2)
    b2 = b[..., :, None] if vec else b
    if b2.shape[-2] != n:
        raise ValueError(f"rhs rows {b2.shape} do not match operator n={n}")

    d = op.diag() if precondition else None
    if d is None:
        def apply_minv(r):
            return r
    else:
        tiny = jnp.finfo(op.dtype).tiny
        dinv = jnp.where(jnp.abs(d) > tiny, 1.0 / d, 1.0)[..., :, None]

        def apply_minv(r):
            return dinv * r

    bnorm = jnp.linalg.norm(b2, axis=-2)                     # (..., k)
    zero_rhs = bnorm == 0                                    # x = 0 exactly
    thresh = tol * bnorm + atol

    x = jnp.zeros_like(b2) if x0 is None else jnp.asarray(x0, op.dtype)
    x = x[..., :, None] if (x0 is not None and vec) else x
    r = b2 - mm(x) if x0 is not None else b2
    z = apply_minv(r)
    p = z
    rz = (r * z).sum(-2)                                     # (..., k)

    def resnorm(r):
        return jnp.linalg.norm(r, axis=-2)

    def cond(state):
        _, r, _, _, it = state
        live = (resnorm(r) > thresh) & ~zero_rhs
        return (it < maxiter) & jnp.any(live)

    def body(state):
        x, r, p, rz, it = state
        if fused_a is not None:
            from repro.kernels import ops as _kops
            x, r = _kops.fused_cg_step(fused_a, p, x, r, rz)
        else:
            ap = mm(p)
            alpha = _safe_div(rz, (p * ap).sum(-2))[..., None, :]
            x = x + alpha * p
            r = r - alpha * ap
        z = apply_minv(r)
        rz_new = (r * z).sum(-2)
        beta = _safe_div(rz_new, rz)[..., None, :]
        p = z + beta * p
        # REPRO_OBS=trace: worst-column residual per iteration; callbacks
        # may land out of order, so the step index rides along
        _telemetry.emit_point("cg.resnorm", jnp.max(resnorm(r)), it)
        return x, r, p, rz_new, it + 1

    x, r, _, _, it = lax.while_loop(
        cond, body, (x, r, p, rz, jnp.zeros((), jnp.int32)))
    x = jnp.where(zero_rhs[..., None, :], jnp.zeros_like(x), x)
    rn = jnp.where(zero_rhs, jnp.zeros_like(bnorm), resnorm(r))
    out = x[..., :, 0] if vec else x
    return CGResult(out, it, rn, jnp.all((rn <= thresh) | zero_rhs))
