"""Hutchinson trace estimation: probe generation + variance tracking.

    tr(f(A)) = E[ v^T f(A) v ],   E[v v^T] = I

with Rademacher (entries +-1, the variance-minimizing classical choice:
Var = 2(||C||_F^2 - sum_i c_ii^2), zero for diagonal C) or Gaussian probes.

Everything is *batch-polymorphic*: probe slabs have shape ``(..., n, k)``
(k probes as columns), quadratic-form samples ``(..., k)``, estimates
``(...,)`` — the same code path serves a single operator and a
``BatchedOperator`` stack with a leading batch axis.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.obs import telemetry as _telemetry

__all__ = ["make_probes", "mean_sem", "hutchinson_trace", "TraceEstimate"]

PROBE_KINDS = ("rademacher", "gaussian")


class TraceEstimate(NamedTuple):
    """Estimate with uncertainty: ``est`` +- ``sem`` from ``samples``."""
    est: jax.Array       # (...,) mean over probes
    sem: jax.Array       # (...,) standard error of the mean
    samples: jax.Array   # (..., k) per-probe quadratic forms


def make_probes(key, n: int, num: int, *, kind: str = "rademacher",
                dtype=None, batch_shape: Tuple[int, ...] = ()):
    """(*batch_shape, n, num) slab of i.i.d. probe columns, E[v v^T] = I.

    ``dtype`` should be threaded from the operator (``op.dtype``) so the
    matvec slab matches it exactly: on float64-enabled hosts a float64
    default would silently upcast probes for a float32 operator, and
    mixed-dtype Pallas calls fail on TPU.  When omitted, the canonical
    default float dtype is used (float32 unless ``jax_enable_x64``).
    """
    if kind not in PROBE_KINDS:
        raise ValueError(f"unknown probe kind {kind!r}; choose {PROBE_KINDS}")
    dtype = jnp.result_type(float) if dtype is None else jnp.dtype(dtype)
    if not jnp.issubdtype(dtype, jnp.floating):
        raise ValueError(f"probes must be real floating, got {dtype}")
    shape = (*batch_shape, n, num)
    if kind == "rademacher":
        return jax.random.rademacher(key, shape, dtype=dtype)
    return jax.random.normal(key, shape, dtype=dtype)


def mean_sem(samples: jax.Array):
    """Mean and standard error over the trailing probe axis."""
    k = samples.shape[-1]
    est = samples.mean(-1)
    if k < 2:
        return est, jnp.full_like(est, jnp.inf)
    sem = samples.std(-1, ddof=1) / jnp.sqrt(jnp.asarray(k, samples.dtype))
    return est, sem


def hutchinson_trace(mm, probes: jax.Array) -> TraceEstimate:
    """Trace of the operator behind ``mm`` from a probe slab.

    ``mm`` maps (..., n, k) -> (..., n, k); ``probes`` is the slab from
    `make_probes`.  Returns the estimate with its standard error — callers
    surface ``sem`` so users can judge (and iterate on) probe counts.
    """
    samples = (probes * mm(probes)).sum(-2)          # v_i^T A v_i per column
    est, sem = mean_sem(samples)
    # REPRO_OBS=trace: ship the sem-vs-probes curve to the host buffer
    _telemetry.emit_curve("hutchinson.sem", _telemetry.running_sem(samples))
    return TraceEstimate(est, sem, samples)
