"""Pluggable matvec backends for the matrix-free estimators.

Every estimator in this package touches the matrix ONLY through products
``A @ V`` with a slab of probe vectors ``V (n, k)`` — the ``mm`` method of a
linear operator.  Three backends cover the scenario classes:

  DenseOperator    single in-memory matrix                        [1 dev]
  BatchedOperator  stack of matrices, one product per batch entry
                   (`vmap`-style contraction — GMM covariance stacks)
  ShardedOperator  row-distributed dense matvec over a 1-D device
                   mesh via shard_map; probes replicated, row chunks
                   all-gathered.  The local (L, n) @ (n, k) product
                   routes through the tiled Pallas matvec kernel
                   (repro/kernels/matvec.py) on TPU.               [mesh]

Anything with ``.shape``, ``.dtype`` and ``.mm`` quacks as an operator, so
implicit operators (Kronecker products, sparse stencils, Jacobians) plug in
without materializing ``A``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro._compat import shard_map as _shard_map

__all__ = ["LinearOperator", "DenseOperator", "BatchedOperator",
           "ShardedOperator", "as_operator", "rowwise_matvec_specs"]


class LinearOperator:
    """Minimal protocol: square operator exposing blocked matvec ``mm``."""

    shape: tuple
    dtype = None

    def mm(self, v: jax.Array) -> jax.Array:
        """Product with a slab of column vectors: (n, k) -> (n, k)."""
        raise NotImplementedError

    def mv(self, v: jax.Array) -> jax.Array:
        """Single matvec (n,) -> (n,)."""
        return self.mm(v[:, None])[:, 0]

    @property
    def n(self) -> int:
        return self.shape[0]


class DenseOperator(LinearOperator):
    """Wraps an in-memory (n, n) matrix."""

    def __init__(self, a: jax.Array):
        a = jnp.asarray(a)
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"expected square matrix, got {a.shape}")
        self.a = a
        self.shape = a.shape
        self.dtype = a.dtype

    def mm(self, v):
        return self.a @ v

    def mv(self, v):
        return self.a @ v


class BatchedOperator(LinearOperator):
    """Wraps a (B, n, n) stack; probes carry a leading batch axis (B, n, k).

    Used by ``logdet_batched``: one estimator invocation drives the whole
    stack, so XLA sees a single batched GEMM per polynomial/Lanczos step
    instead of B small ones.
    """

    def __init__(self, stack: jax.Array):
        stack = jnp.asarray(stack)
        if stack.ndim != 3 or stack.shape[1] != stack.shape[2]:
            raise ValueError(f"expected (B, n, n) stack, got {stack.shape}")
        self.stack = stack
        self.shape = stack.shape[1:]
        self.batch = stack.shape[0]
        self.dtype = stack.dtype

    def mm(self, v):  # (B, n, k) -> (B, n, k)
        return jnp.einsum("bij,bjk->bik", self.stack, v)

    def mv(self, v):  # (B, n) -> (B, n)
        return jnp.einsum("bij,bj->bi", self.stack, v)


def rowwise_matvec_specs(axis_name: str):
    """(in_specs, out_specs) for a row-distributed matvec under shard_map.

    Matrix rows sharded over ``axis_name``, probe slab replicated, result row
    chunks concatenated back along the row axis.
    """
    p = PartitionSpec
    return (p(axis_name, None), p(None, None)), p(axis_name, None)


@functools.lru_cache(maxsize=16)
def _sharded_mm(mesh, axis_name: str, use_kernel: bool):
    from repro.kernels import ops as _kops

    def kernel(local, v):            # local (L, n), v (n, k) replicated
        if use_kernel:
            return _kops.matvec(local, v)
        return local @ v

    in_specs, out_specs = rowwise_matvec_specs(axis_name)
    return jax.jit(_shard_map(kernel, mesh=mesh,
                              in_specs=in_specs, out_specs=out_specs))


class ShardedOperator(LinearOperator):
    """Row-distributed dense operator over a 1-D mesh.

    Device ``p`` owns the contiguous row block ``[p*L, (p+1)*L)`` — the same
    layout the parallel condensation core uses, so an operator can be handed
    from the exact path to the estimator path without a resharding pass.
    ``n`` must be divisible by the mesh size (pad via
    ``repro.core.pad_to_multiple``, which leaves the determinant unchanged).
    """

    def __init__(self, a: jax.Array, mesh, axis_name: str = "rows", *,
                 use_kernel: bool = True):
        a = jnp.asarray(a)
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"expected square matrix, got {a.shape}")
        nproc = int(mesh.shape[axis_name])
        if a.shape[0] % nproc:
            raise ValueError(
                f"N={a.shape[0]} not divisible by mesh size {nproc}; "
                "pad with repro.core.pad_to_multiple first")
        self.mesh = mesh
        self.axis_name = axis_name
        self.shape = a.shape
        self.dtype = a.dtype
        self.a = jax.device_put(
            a, NamedSharding(mesh, PartitionSpec(axis_name, None)))
        self._mm = _sharded_mm(mesh, axis_name, use_kernel)

    def mm(self, v):
        return self._mm(self.a, v.astype(self.dtype))


def as_operator(a, *, mesh=None, axis_name: str = "rows",
                use_kernel: bool = True) -> LinearOperator:
    """Coerce a matrix / stack / operator to the estimator protocol.

    (n, n) array -> DenseOperator (or ShardedOperator when ``mesh`` given);
    (B, n, n) array -> BatchedOperator; an existing operator passes through.
    """
    if isinstance(a, LinearOperator):
        return a
    a = jnp.asarray(a)
    if a.ndim == 3:
        return BatchedOperator(a)
    if mesh is not None and int(mesh.shape[axis_name]) > 1:
        return ShardedOperator(a, mesh, axis_name, use_kernel=use_kernel)
    return DenseOperator(a)
