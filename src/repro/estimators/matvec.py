"""Back-compat shim: the matvec backends moved to a full subsystem.

The ad-hoc backends that lived here grew into the structured operator
package `repro.estimators.operators` (Kronecker / Toeplitz / stencil
backends, matrix-free CG, the diag/trace_hint protocol).  Import from
there; this module re-exports the original names so existing callers keep
working and will be dropped once downstream code migrates.
"""
from __future__ import annotations

from repro.estimators.operators import (          # noqa: F401
    BatchedOperator,
    DenseOperator,
    LinearOperator,
    ShardedOperator,
    as_operator,
    rowwise_matvec_specs,
)

__all__ = ["LinearOperator", "DenseOperator", "BatchedOperator",
           "ShardedOperator", "as_operator", "rowwise_matvec_specs"]
