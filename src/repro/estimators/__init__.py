"""Stochastic log-determinant estimators (sub-cubic, matrix-free).

The condensation core (repro/core) computes *exact* log-determinants in
O(N^3) FLOPs.  This package trades a controlled approximation for
O(matvec) cost — the scaling regime Han et al. (stochastic Chebyshev,
arXiv:1503.06394) and Ubaru–Chen–Saad (stochastic Lanczos quadrature)
showed wins for huge SPD, implicit, and batched operators:

  hutchinson   probe generation + trace estimation with variance tracking
  chebyshev    stochastic Chebyshev expansion of log on a spectral interval
  slq          stochastic Lanczos quadrature (no spectral bounds needed)
  operators    the `LinearOperator` protocol + backends: dense, batched
               stack, mesh-sharded rows, Kronecker, Toeplitz, stencil —
               and matrix-free conjugate gradient (`cg_solve`) on any of
               them (see operators/README.md)
  grad         custom VJP rules: `estimate_logdet` is differentiable (the
               backward pass reuses the forward's probes through one
               matrix-free CG solve; structured operators get structured
               cotangents), `exact_slogdet_vjp` wraps the exact O(N^3)
               paths with the analytic A^{-T} pullback

User-facing entry points: ``repro.core.slogdet(a, method="chebyshev"|"slq")``
for a single matrix or operator and `logdet_batched` for stacks (GMM
covariances).  All estimators assume SPD input (they estimate
``tr(log A)``).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.estimators.chebyshev import (
    chebyshev_coeffs_log, logdet_chebyshev, spectral_bounds,
)
from repro.estimators.hutchinson import (
    TraceEstimate, hutchinson_trace, make_probes, mean_sem,
)
from repro.estimators.operators import (
    BatchedOperator, CGResult, DenseOperator, KroneckerOperator,
    LinearOperator, PlanHints, ShardedOperator, StencilOperator,
    ToeplitzOperator, as_operator, cg_solve, is_operator,
    rowwise_matvec_specs,
)
from repro.estimators.slq import lanczos, logdet_slq
from repro.estimators.grad import (
    ESTIMATOR_METHODS, estimate_logdet, exact_slogdet_vjp,
    hutchinson_pullback, operator_grad_info, register_operator_grad,
    shared_probes,
)

__all__ = [
    "TraceEstimate", "hutchinson_trace", "make_probes", "mean_sem",
    "logdet_chebyshev", "chebyshev_coeffs_log", "spectral_bounds",
    "logdet_slq", "lanczos",
    "LinearOperator", "PlanHints", "DenseOperator", "BatchedOperator",
    "ShardedOperator", "KroneckerOperator", "ToeplitzOperator",
    "StencilOperator", "as_operator", "is_operator", "rowwise_matvec_specs",
    "CGResult", "cg_solve",
    "ESTIMATOR_METHODS", "estimate_logdet", "logdet_batched",
    "exact_slogdet_vjp", "hutchinson_pullback", "shared_probes",
    "register_operator_grad", "operator_grad_info",
]


def logdet_batched(stack, *, method: str = "chebyshev", **kw):
    """``log|det|`` of every matrix in an SPD (B, n, n) stack -> (B,).

    ``stack`` is a (B, n, n) array or a batched operator (an operator
    exposing ``batch`` — e.g. `BatchedOperator` or a duck-typed implicit
    covariance stack); operators require an estimator method.  ``method``
    is an estimator name or any *serial* exact engine route ("exact" with
    schedule/update knobs, the legacy "mc"/"mc_staged"/"mc_blocked"
    aliases, or "ge") mapped over the stack — the crossover reference:
    exact is the right call for small n, estimators for large.  Mesh
    schedules distribute ONE matrix and raise a clear TypeError on
    batched input.  Estimator keywords pass through (``num_probes``,
    ``degree`` / ``num_steps``, ``seed``, ...).
    """
    if is_operator(stack):
        if getattr(stack, "batch", None) is None:
            raise ValueError(
                "logdet_batched needs a batched operator (with a .batch "
                "axis); use estimate_logdet for a single operator")
        if method not in ESTIMATOR_METHODS:
            raise TypeError(
                f"method {method!r} needs a materialized (B, n, n) stack; "
                "operator inputs require an estimator method "
                f"{ESTIMATOR_METHODS}")
        return estimate_logdet(stack, method=method, **kw).est
    stack = jnp.asarray(stack)
    if stack.ndim != 3 or stack.shape[1] != stack.shape[2]:
        raise ValueError(f"expected (B, n, n) stack, got {stack.shape}")
    if method not in ESTIMATOR_METHODS:
        # exact engine routes (and the GE baseline) run vmapped per matrix
        # through a cached plan: the analytic-VJP wrapper, padding and the
        # batched/mesh validation live in one place (repro.core.plan)
        from repro.core.plan import plan as _make_plan
        p = _make_plan(stack, method=method, validate=False, **kw)
        return p.logdet(stack)
    return estimate_logdet(stack, method=method, **kw).est
