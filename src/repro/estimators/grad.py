"""Differentiable log-determinants: custom VJP rules for every path.

The paper motivates log-determinants through generative learning, which in
practice means *training* — ``jax.grad`` through ``logdet(Sigma)``.  The
analytic derivative is

    d logdet(A) = tr(A^{-1} dA),      i.e.  d logdet / dA = A^{-T},

and neither the condensation pivot schedule nor the estimator recurrences
are things one wants to differentiate *through*: pivoting is piecewise
control flow (autodiff would thread cotangents through argmax/swap noise),
and the Chebyshev/Lanczos recurrences would retain every intermediate slab.
This module registers ``jax.custom_vjp`` rules instead:

Exact methods (``mc``, ``ge``, ``pmc``, ...)
    `exact_slogdet_vjp` wraps any ``a -> (sign, logdet)`` computation with
    the analytic pullback ``bar_a = g * A^{-T}`` (one LU-based inverse in
    the backward pass — the same O(N^3) class as the forward; the
    condensation core does not retain its factors, so the inverse is
    recomputed rather than read off the forward's elimination).  The sign
    output is piecewise constant and gets a zero gradient.

Estimator methods (``chebyshev``, ``slq``)
    The Hutchinson identity runs backwards: with probes ``z_c``,

        A^{-T} = E[(A^{-T} z) z^T]  ~=  (1/k) sum_c (A^{-T} z_c) z_c^T,

    so the cotangent is realized *matrix-free* by one batched CG solve on
    the SAME probe slab the forward pass consumed (the shared key/probes
    are plumbed through `estimate_logdet`; backward cost ~ one CG solve
    per probe column, no dense inverse and no O(n^2) intermediate for
    structured operators).  ``sem``/``samples`` of the returned
    `TraceEstimate` are Monte-Carlo diagnostics and are treated as
    non-differentiable constants.

Structured operators receive *structured* cotangents: the pullback of the
bilinear form ``sum_c w_c^T A(theta) z_c`` with respect to the operator's
own parameters — factor-shaped for `KroneckerOperator`, first-column/
row-shaped for `ToeplitzOperator`, band-shaped for `StencilOperator` —
never a dense (n, n) tangent.  Third-party duck-typed operators can opt in
via `register_operator_grad`; unregistered operators fall back to plain
autodiff through the estimator recurrence (correct but memory-hungry,
and it differentiates the *estimate*, not the estimand).

Second-order derivatives of these rules are not defined (the backward pass
itself contains a ``lax.while_loop``); take gradients once.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax import dtypes as _jdtypes

from repro.estimators.chebyshev import logdet_chebyshev
from repro.estimators.hutchinson import TraceEstimate, make_probes
from repro.estimators.operators import (
    BatchedOperator, DenseOperator, KroneckerOperator, ShardedOperator,
    StencilOperator, ToeplitzOperator, as_operator, cg_solve,
)
from repro.estimators.slq import logdet_slq
from repro.kernels.ref import stencil_mv_ref

__all__ = [
    "estimate_logdet", "exact_slogdet_vjp", "hutchinson_pullback",
    "shared_probes",
    "register_operator_grad", "operator_grad_info", "OperatorGradInfo",
]

_ESTIMATORS = {"chebyshev": logdet_chebyshev, "slq": logdet_slq}
ESTIMATOR_METHODS = tuple(_ESTIMATORS)


# --------------------------------------------------------------------------
# operator registry: how each backend exposes its differentiable parameters
# --------------------------------------------------------------------------

class OperatorGradInfo(NamedTuple):
    """How the grad machinery sees one operator class.

    ``params(op)`` extracts the differentiable parameter pytree;
    ``rebuild(op, params)`` reconstructs an equivalent operator from it
    (reading only *static* attributes — offsets, mesh, axis names — off
    the original instance); ``apply(op, params, z)`` computes
    ``A(params) @ z`` with plain differentiable jnp ops for the bilinear
    pullback (defaults to ``rebuild(op, params).mm(z)``); ``dense=True``
    short-circuits the pullback to the closed-form outer product
    ``(g/k) * W Z^T`` when the parameters ARE the matrix entries.
    """
    params: Callable[[Any], Any]
    rebuild: Callable[[Any, Any], Any]
    apply: Optional[Callable[[Any, Any, jax.Array], jax.Array]] = None
    dense: bool = False


_REGISTRY: dict = {}


def register_operator_grad(cls, *, params, rebuild, apply=None,
                           dense: bool = False) -> None:
    """Register a structured pullback for an operator class.

    Lets user-defined (duck-typed) operators receive structured gradients
    from the logdet estimators instead of the autodiff-through-recurrence
    fallback.  See `OperatorGradInfo` for the callback contracts.
    """
    _REGISTRY[cls] = OperatorGradInfo(params, rebuild, apply, dense)


def operator_grad_info(op) -> Optional[OperatorGradInfo]:
    """Registered grad info for ``op`` (exact class first, then bases)."""
    info = _REGISTRY.get(type(op))
    if info is not None:
        return info
    for cls, entry in _REGISTRY.items():
        if isinstance(op, cls):
            return entry
    return None


register_operator_grad(
    DenseOperator,
    params=lambda op: op.a,
    rebuild=lambda op, a: DenseOperator(a),
    dense=True)
register_operator_grad(
    BatchedOperator,
    params=lambda op: op.stack,
    rebuild=lambda op, s: BatchedOperator(s),
    dense=True)
register_operator_grad(
    ShardedOperator,
    params=lambda op: op.a,
    rebuild=lambda op, a: ShardedOperator(
        a, op.mesh, op.axis_name, use_kernel=op.use_kernel),
    dense=True)
register_operator_grad(
    KroneckerOperator,
    params=lambda op: (op.a, op.b),
    rebuild=lambda op, p: KroneckerOperator(p[0], p[1]))
register_operator_grad(
    ToeplitzOperator,
    # symmetric operators hold the same array as c and r, so both halves
    # of the cotangent flow back into the single first-column parameter
    params=lambda op: (op.c, op.r),
    rebuild=lambda op, p: ToeplitzOperator(p[0], p[1]))
register_operator_grad(
    StencilOperator,
    params=lambda op: op.bands,
    rebuild=lambda op, b: StencilOperator(op.offsets, b),
    # bypass the Pallas kernel dispatch: the jnp reference is the
    # differentiable description of the banded contraction on any backend
    apply=lambda op, b, z: stencil_mv_ref(b, z, offsets=op.offsets))


# --------------------------------------------------------------------------
# exact methods: shared analytic VJP
# --------------------------------------------------------------------------

def exact_slogdet_vjp(fn: Callable[[jax.Array], Any]):
    """Wrap an exact ``a -> (sign, logabsdet)`` computation with its VJP.

    The backward pass is the analytic ``bar_a = g_logdet * inv(a).T`` —
    the pivot control flow of the forward (condensation column swaps, GE
    row exchanges, blocked panels) is never differentiated through.  The
    sign output is locally constant: its cotangent is discarded.
    """

    @jax.custom_vjp
    def f(a):
        return fn(a)

    def f_fwd(a):
        return fn(a), a

    def f_bwd(a, ct):
        g = ct[1]                                  # logdet cotangent only
        if a.shape[-1] == 0:
            return (jnp.zeros_like(a),)
        bar = g * jnp.swapaxes(jnp.linalg.inv(a), -1, -2)
        return (bar.astype(a.dtype),)

    f.defvjp(f_fwd, f_bwd)
    return f


# --------------------------------------------------------------------------
# estimator methods: Hutchinson pullback on the forward's own probes
# --------------------------------------------------------------------------

def shared_probes(method: str, op, key, kw):
    """The exact probe slab the named estimator would draw internally.

    Mirrors each estimator's key discipline (`logdet_chebyshev` splits the
    key into bounds/probes halves; `logdet_slq` consumes it whole) so the
    forward value is bit-identical to a direct estimator call, and the
    backward pass reuses the very same probes.
    """
    n = op.shape[-1]
    batch = getattr(op, "batch", None)
    num = kw.get("num_probes", 32)
    if method == "chebyshev":
        kp = jax.random.split(key)[1]
        kind = kw.get("probe_kind", "rademacher")
    else:
        kp, kind = key, "rademacher"
    return make_probes(kp, n, num, kind=kind, dtype=op.dtype,
                       batch_shape=(batch,) if batch else ())


def hutchinson_pullback(op, params, probes, g, *, info=None,
                        cg_tol: float = 1e-8, cg_maxiter=None):
    """The logdet cotangent on an operator's own parameters, matrix-free.

    Realizes ``bar_params = vjp_params[(g/k) sum_c w_c^T A(params) z_c]``
    with ``w = A^{-T} Z`` solved by one batched transposed CG on the probe
    slab ``Z`` — the estimator backward pass, exposed as a plain function
    so callers (the custom-VJP rule below, and `repro.plan`'s explicit
    ``value_and_grad`` path) can also read the solve's convergence
    evidence.  Returns ``(bar_params, CGResult)``.

    ``op`` is the template operator (static attributes), ``params`` its
    differentiable parameter pytree (may be traced), ``g`` the logdet
    cotangent (scalar, or (B,) for batched operators).
    """
    info = operator_grad_info(op) if info is None else info
    if info is None:
        raise TypeError(
            f"no grad registration for {type(op).__name__}; register one "
            "with repro.estimators.register_operator_grad")
    op_b = info.rebuild(op, params)
    cg = cg_solve(op_b, probes, transpose=True, tol=cg_tol,
                  maxiter=cg_maxiter)
    w = cg.x                                         # A^{-T} Z, matrix-free
    k = probes.shape[-1]
    scale = (jnp.asarray(g) / k).astype(probes.dtype)
    if info.dense:
        bar = scale[..., None, None] * jnp.einsum("...ik,...jk->...ij",
                                                  w, probes)
    else:
        w2 = scale[..., None, None] * w
        apply_fn = info.apply or (
            lambda o, pp, zz: info.rebuild(o, pp).mm(zz))
        _, pull = jax.vjp(
            lambda pp: (w2 * apply_fn(op, pp, probes)).sum(), params)
        (bar,) = pull(jnp.ones((), w2.dtype))
    return bar, cg


def _zero_cotangent(x):
    """Zero cotangent matching jax's tangent-type rules (float0 for ints)."""
    if jnp.issubdtype(jnp.result_type(x), jnp.inexact):
        return jnp.zeros_like(x)
    return np.zeros(jnp.shape(x), _jdtypes.float0)


def estimate_logdet(a, method: str = "chebyshev", **kw) -> TraceEstimate:
    """Dispatch to a logdet estimator by name — differentiably.

    See `logdet_chebyshev` / `logdet_slq` for the method-specific keywords.
    The returned `TraceEstimate` supports ``jax.grad`` through ``.est``:
    the custom VJP solves ``A^T W = Z`` on the forward pass's own probe
    slab with `cg_solve` (matrix-free; control the solve with
    ``grad_cg_tol`` / ``grad_cg_maxiter``) and pulls the Hutchinson
    cotangent back onto the operator's parameters — dense entries,
    Kronecker factors, Toeplitz first column/row, or stencil bands.
    ``sem`` and ``samples`` are non-differentiable diagnostics.
    """
    if method not in _ESTIMATORS:
        raise ValueError(
            f"unknown estimator {method!r}; choose from {ESTIMATOR_METHODS}")
    fwd_fn = _ESTIMATORS[method]
    mesh = kw.pop("mesh", None)
    axis_name = kw.pop("axis_name", "rows")
    cg_tol = kw.pop("grad_cg_tol", 1e-8)
    cg_maxiter = kw.pop("grad_cg_maxiter", None)

    op = as_operator(a, mesh=mesh, axis_name=axis_name)
    info = operator_grad_info(op)
    if info is None:
        # unregistered duck-typed operator: plain forward; jax.grad (if
        # requested) differentiates through the estimator recurrence
        return fwd_fn(op, **kw)

    params = info.params(op)
    key = kw.pop("key", None)
    seed = kw.pop("seed", 0)
    if key is None:
        key = jax.random.PRNGKey(seed)
    probes = kw.pop("probes", None)
    if probes is None:
        probes = shared_probes(method, op, key, kw)
    else:
        probes = jnp.asarray(probes, op.dtype)

    # split remaining keywords: traced/array values (lmin/lmax bounds, ...)
    # must ride through the custom_vjp as explicit arguments — closing over
    # a tracer inside fwd/bwd would leak it
    static_kw, array_kw = {}, {}
    for name, val in kw.items():
        (array_kw if isinstance(val, jax.Array) else static_kw)[name] = val
    array_kw["key"] = key
    array_kw["probes"] = probes

    def compute(p, arrs):
        call_kw = dict(static_kw)
        call_kw.update(arrs)
        return fwd_fn(info.rebuild(op, p), **call_kw)

    @jax.custom_vjp
    def f(p, arrs):
        return compute(p, arrs)

    def f_fwd(p, arrs):
        return compute(p, arrs), (p, arrs)

    def f_bwd(res, ct):
        p, arrs = res
        bar, _ = hutchinson_pullback(op, p, arrs["probes"], ct.est,
                                     info=info, cg_tol=cg_tol,
                                     cg_maxiter=cg_maxiter)
        zeros = jax.tree_util.tree_map(_zero_cotangent, arrs)
        return bar, zeros

    f.defvjp(f_fwd, f_bwd)
    return f(params, array_kw)
