"""Gated-linear-unit MLP (SwiGLU / GeGLU) used by every transformer arch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, keygen, param


def mlp_init(key, cfg: ModelConfig, *, d_ff: int | None = None):
    kg = keygen(key)
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    return {
        "w_gate": param(next(kg), (d, f), ("embed", "mlp"), cfg.param_dtype),
        "w_up": param(next(kg), (d, f), ("embed", "mlp"), cfg.param_dtype),
        "w_down": param(next(kg), (f, d), ("mlp", "embed"), cfg.param_dtype),
    }


def mlp_apply(p, x, *, act=jax.nn.silu):
    from repro.sharding import hints
    dt = x.dtype
    x = hints.constrain(x, "residual")
    g = hints.constrain(jnp.einsum("btd,df->btf", x, p["w_gate"].astype(dt)),
                        "mlp_hidden")
    u = hints.constrain(jnp.einsum("btd,df->btf", x, p["w_up"].astype(dt)),
                        "mlp_hidden")
    out = jnp.einsum("btf,fd->btd", act(g) * u, p["w_down"].astype(dt))
    return hints.constrain(out, "residual")
