"""Transformer / SSM / hybrid block definitions (init + apply pairs).

A "block" is the unit that model.py stacks (via lax.scan over stacked
params).  Every block apply returns ``(x, new_cache, aux)`` where new_cache
is None unless prefill/decode and aux is a dict of auxiliary scalars (MoE
balance loss etc.).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import attn_apply, attn_init
from repro.models.common import ModelConfig, keygen, param, rmsnorm
from repro.models.mlp import mlp_apply, mlp_init
from repro.models.moe import moe_apply, moe_init
from repro.models.ssm import ssm_apply, ssm_init


# ---------------------------------------------------------------------------
# dense transformer block (attn + GLU mlp)
# ---------------------------------------------------------------------------

def dense_block_init(key, cfg: ModelConfig):
    kg = keygen(key)
    d = cfg.d_model
    return {
        "attn_norm": param(next(kg), (d,), ("embed",), cfg.param_dtype),
        "attn": attn_init(next(kg), cfg),
        "mlp_norm": param(next(kg), (d,), ("embed",), cfg.param_dtype),
        "mlp": mlp_init(next(kg), cfg),
    }


def dense_block_apply(p, x, cfg, *, mode="train", window=0, positions=None,
                      cache=None, cache_pos=None):
    h, new_cache = attn_apply(
        p["attn"], rmsnorm({"scale": p["attn_norm"]}, x, cfg.norm_eps), cfg,
        mode=("train" if mode == "train" else mode), window=window,
        positions=positions, cache=cache, cache_pos=cache_pos)
    x = x + h
    x = x + mlp_apply(p["mlp"], rmsnorm({"scale": p["mlp_norm"]}, x, cfg.norm_eps))
    return x, new_cache, {}


# ---------------------------------------------------------------------------
# MoE block (attn + mixture of experts, optional shared experts)
# ---------------------------------------------------------------------------

def moe_block_init(key, cfg: ModelConfig):
    kg = keygen(key)
    d = cfg.d_model
    return {
        "attn_norm": param(next(kg), (d,), ("embed",), cfg.param_dtype),
        "attn": attn_init(next(kg), cfg),
        "mlp_norm": param(next(kg), (d,), ("embed",), cfg.param_dtype),
        "moe": moe_init(next(kg), cfg),
    }


def moe_block_apply(p, x, cfg, *, mode="train", window=0, positions=None,
                    cache=None, cache_pos=None):
    h, new_cache = attn_apply(
        p["attn"], rmsnorm({"scale": p["attn_norm"]}, x, cfg.norm_eps), cfg,
        mode=("train" if mode == "train" else mode), window=window,
        positions=positions, cache=cache, cache_pos=cache_pos)
    x = x + h
    h, aux = moe_apply(p["moe"], rmsnorm({"scale": p["mlp_norm"]}, x, cfg.norm_eps), cfg)
    return x + h, new_cache, aux


# ---------------------------------------------------------------------------
# SSM (mamba2) block
# ---------------------------------------------------------------------------

def ssm_block_init(key, cfg: ModelConfig):
    kg = keygen(key)
    return {
        "norm": param(next(kg), (cfg.d_model,), ("embed",), cfg.param_dtype),
        "ssm": ssm_init(next(kg), cfg),
    }


def ssm_block_apply(p, x, cfg, *, mode="train", cache=None, **_):
    h, new_cache = ssm_apply(
        p["ssm"], rmsnorm({"scale": p["norm"]}, x, cfg.norm_eps), cfg,
        mode=mode if mode in ("prefill", "decode") else "train", cache=cache)
    return x + h, new_cache, {}


# ---------------------------------------------------------------------------
# cross-attention block (llama-3.2-vision style: gated cross-attn + mlp)
# ---------------------------------------------------------------------------

def cross_block_init(key, cfg: ModelConfig):
    kg = keygen(key)
    d = cfg.d_model
    return {
        "xattn_norm": param(next(kg), (d,), ("embed",), cfg.param_dtype),
        "xattn": attn_init(next(kg), cfg),
        "xattn_gate": param(None, (1,), ("null",), cfg.param_dtype),
        "mlp_norm": param(next(kg), (d,), ("embed",), cfg.param_dtype),
        "mlp": mlp_init(next(kg), cfg),
        "mlp_gate": param(None, (1,), ("null",), cfg.param_dtype),
    }


def cross_block_apply(p, x, cfg, *, memory, **_):
    h, _ = attn_apply(
        p["xattn"], rmsnorm({"scale": p["xattn_norm"]}, x, cfg.norm_eps), cfg,
        mode="cross", memory=memory)
    x = x + jnp.tanh(p["xattn_gate"].astype(x.dtype)) * h
    h = mlp_apply(p["mlp"], rmsnorm({"scale": p["mlp_norm"]}, x, cfg.norm_eps))
    return x + jnp.tanh(p["mlp_gate"].astype(x.dtype)) * h, None, {}


# ---------------------------------------------------------------------------
# encoder block (whisper: bidirectional attn + mlp, GELU)
# ---------------------------------------------------------------------------

def encoder_block_init(key, cfg: ModelConfig):
    return dense_block_init(key, cfg)


def encoder_block_apply(p, x, cfg):
    h, _ = attn_apply(
        p["attn"], rmsnorm({"scale": p["attn_norm"]}, x, cfg.norm_eps), cfg,
        mode="encoder")
    x = x + h
    x = x + mlp_apply(p["mlp"], rmsnorm({"scale": p["mlp_norm"]}, x, cfg.norm_eps),
                      act=jax.nn.gelu)
    return x


# ---------------------------------------------------------------------------
# decoder block with cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def xdec_block_init(key, cfg: ModelConfig):
    kg = keygen(key)
    d = cfg.d_model
    return {
        "attn_norm": param(next(kg), (d,), ("embed",), cfg.param_dtype),
        "attn": attn_init(next(kg), cfg),
        "xattn_norm": param(next(kg), (d,), ("embed",), cfg.param_dtype),
        "xattn": attn_init(next(kg), cfg),
        "mlp_norm": param(next(kg), (d,), ("embed",), cfg.param_dtype),
        "mlp": mlp_init(next(kg), cfg),
    }


def xdec_block_apply(p, x, cfg, *, memory, mode="train", positions=None,
                     cache=None, cache_pos=None):
    h, new_cache = attn_apply(
        p["attn"], rmsnorm({"scale": p["attn_norm"]}, x, cfg.norm_eps), cfg,
        mode=("train" if mode == "train" else mode),
        positions=positions, cache=cache, cache_pos=cache_pos)
    x = x + h
    h, _ = attn_apply(
        p["xattn"], rmsnorm({"scale": p["xattn_norm"]}, x, cfg.norm_eps), cfg,
        mode="cross", memory=memory)
    x = x + h
    x = x + mlp_apply(p["mlp"], rmsnorm({"scale": p["mlp_norm"]}, x, cfg.norm_eps),
                      act=jax.nn.gelu)
    return x, new_cache, {}
