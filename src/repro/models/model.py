"""Model assembly: stacks blocks per architecture family, with lax.scan over
stacked layer params (+ remat), KV/SSM caches, and three entry points:

    init_model(key, cfg)                          -> params
    forward(params, batch, cfg)                   -> (logits, aux)     [train]
    prefill(params, batch, cfg, max_len)          -> (logits, caches)
    decode_step(params, tokens, caches, pos, cfg) -> (logits, caches)
    cache_specs(cfg, batch_size, max_len)         -> ShapeDtypeStruct pytree

Families: dense | moe | ssm | encdec | vlm | hybrid.  Heterogeneous stacks
(gemma3 local:global, llama4 dense/moe interleave, vision cross-attn every
5th, zamba2 shared-attn every 6th) are expressed as *super-blocks* so the
scan stays homogeneous; per-layer sliding windows ride the scan as data.

batch dict keys: "tokens" (B, T) int32 — always.  Family extras:
  encdec: "frames"     (B, enc_seq, d_model)  precomputed audio embeddings (stub)
  vlm:    "img_embeds" (B, n_img_tokens, d_model) precomputed patch embeds (stub)
  any:    "memory"     precomputed encoder output (decode loops pass this to
                       avoid re-encoding every step)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import blocks as B
from repro.models.common import (
    ModelConfig, embed_init, embed_lookup, keygen, param, rmsnorm, unembed,
)
from repro.models.ssm import ssm_cache_spec

__all__ = ["init_model", "forward", "prefill", "decode_step", "cache_specs",
           "layer_windows", "model_flops", "count_params"]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _stack_init(block_init, key, cfg, n):
    """Initialize ``n`` blocks with stacked (leading-axis n) params."""
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: block_init(k, cfg))(keys)


def layer_windows(cfg: ModelConfig) -> np.ndarray:
    """Per-layer sliding window (0 = full attention)."""
    n = cfg.n_layers
    if not cfg.sliding_window or not cfg.global_every:
        return np.zeros((n,), np.int32)
    w = np.full((n,), cfg.sliding_window, np.int32)
    w[cfg.global_every - 1::cfg.global_every] = 0   # every k-th layer global
    return w


def _maybe_remat(fn, cfg, mode):
    if cfg.remat and mode == "train":
        return jax.checkpoint(fn)
    return fn


def _attn_cache_spec(cfg, batch, max_len, dtype):
    return {
        "k": jax.ShapeDtypeStruct((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
    }


def _pad_kv(nc, pad_to):
    """Pad a block-level {"k","v"} (B, T, kvh, hd) cache along time."""
    if nc is None or pad_to is None:
        return nc
    def pad(x):
        t = x.shape[1]
        if t >= pad_to:
            return x[:, :pad_to]
        return jnp.pad(x, ((0, 0), (0, pad_to - t), (0, 0), (0, 0)))
    return {k: pad(v) for k, v in nc.items()}


# ---------------------------------------------------------------------------
# per-family structure tables
# ---------------------------------------------------------------------------

def _family_plan(cfg: ModelConfig):
    """Returns (plan_name, counts) describing the stacked structure."""
    fam = cfg.family
    if fam == "dense":
        return "uniform_dense", {"n": cfg.n_layers}
    if fam == "moe":
        if cfg.moe_every <= 1:
            return "uniform_moe", {"n": cfg.n_layers}
        assert cfg.n_layers % cfg.moe_every == 0
        return "pair_moe", {"n": cfg.n_layers // cfg.moe_every,
                            "dense_per": cfg.moe_every - 1}
    if fam == "ssm":
        return "uniform_ssm", {"n": cfg.n_layers}
    if fam == "encdec":
        return "encdec", {"n_enc": cfg.n_enc_layers, "n_dec": cfg.n_layers}
    if fam == "vlm":
        assert cfg.cross_attn_every > 1
        per = cfg.cross_attn_every
        assert cfg.n_layers % per == 0
        return "vlm", {"n": cfg.n_layers // per, "self_per": per - 1}
    if fam == "hybrid":
        per = cfg.shared_attn_every
        n_super = cfg.n_layers // per
        extra = cfg.n_layers - n_super * per
        return "hybrid", {"n": n_super, "per": per, "extra": extra}
    raise ValueError(f"unknown family {fam}")


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_model(key, cfg: ModelConfig):
    kg = keygen(key)
    plan, c = _family_plan(cfg)
    p: Dict[str, Any] = {"embed": embed_init(next(kg), cfg)}

    if plan in ("uniform_dense",):
        p["blocks"] = _stack_init(B.dense_block_init, next(kg), cfg, c["n"])
    elif plan == "uniform_moe":
        p["blocks"] = _stack_init(B.moe_block_init, next(kg), cfg, c["n"])
    elif plan == "pair_moe":
        p["dense_blocks"] = _stack_init(
            lambda k, f: _stack_init(B.dense_block_init, k, f, c["dense_per"]),
            next(kg), cfg, c["n"])
        p["moe_blocks"] = _stack_init(B.moe_block_init, next(kg), cfg, c["n"])
    elif plan == "uniform_ssm":
        p["blocks"] = _stack_init(B.ssm_block_init, next(kg), cfg, c["n"])
    elif plan == "encdec":
        p["enc_blocks"] = _stack_init(B.encoder_block_init, next(kg), cfg, c["n_enc"])
        p["enc_norm"] = param(next(kg), (cfg.d_model,), ("embed",), cfg.param_dtype)
        p["dec_blocks"] = _stack_init(B.xdec_block_init, next(kg), cfg, c["n_dec"])
    elif plan == "vlm":
        p["self_blocks"] = _stack_init(
            lambda k, f: _stack_init(B.dense_block_init, k, f, c["self_per"]),
            next(kg), cfg, c["n"])
        p["cross_blocks"] = _stack_init(B.cross_block_init, next(kg), cfg, c["n"])
    elif plan == "hybrid":
        p["ssm_blocks"] = _stack_init(
            lambda k, f: _stack_init(B.ssm_block_init, k, f, c["per"]),
            next(kg), cfg, c["n"])
        p["shared_attn"] = B.dense_block_init(next(kg), cfg)   # ONE copy
        if c["extra"]:
            p["extra_ssm"] = _stack_init(B.ssm_block_init, next(kg), cfg, c["extra"])
    else:
        raise AssertionError(plan)

    p["final_norm"] = param(next(kg), (cfg.d_model,), ("embed",), cfg.param_dtype)
    if not cfg.tie_embeddings:
        p["head"] = param(next(kg), (cfg.vocab, cfg.d_model), ("vocab", "embed"),
                          cfg.param_dtype)
    return p


# ---------------------------------------------------------------------------
# the stack runner (shared by train / prefill / decode)
# ---------------------------------------------------------------------------

def _run_stack(params, x, cfg, *, mode, caches=None, cache_pos=None,
               positions=None, memory=None, pad_to=None):
    """Run all blocks.  Returns (x, new_caches, aux_sum).

    ``caches is None`` (train/prefill) vs provided (decode) is a STATIC
    (python-level) distinction; scan xs always include the caches pytree when
    present so per-layer slices ride the scan.
    """
    plan, c = _family_plan(cfg)
    has_cache = caches is not None
    aux_tot: Dict[str, Any] = {}
    windows = jnp.asarray(layer_windows(cfg))

    def scan2(body, x, xs):
        if cfg.scan_layers:
            return lax.scan(body, x, xs)
        # unrolled: identical semantics, layer-indexed slices of xs
        n = jax.tree.leaves(xs)[0].shape[0]
        ys = []
        for i in range(n):
            x, y = body(x, jax.tree.map(lambda l: l[i], xs))
            ys.append(y)
        # None/{} subtrees pass through tree.map untouched (scan semantics)
        return x, jax.tree.map(lambda *ls: jnp.stack(ls), *ys)

    if plan in ("uniform_dense", "uniform_moe"):
        apply = B.dense_block_apply if plan == "uniform_dense" else B.moe_block_apply

        def body(xc, scanned):
            if has_cache:
                bp, w, cache = scanned
            else:
                (bp, w), cache = scanned, None
            fn = _maybe_remat(
                lambda bp_, x_, cache_: apply(
                    bp_, x_, cfg, mode=mode, window=w, positions=positions,
                    cache=cache_, cache_pos=cache_pos), cfg, mode)
            x_, nc, aux = fn(bp, xc, cache)
            return x_, (_pad_kv(nc, pad_to), aux)

        xs = ((params["blocks"], windows, caches) if has_cache
              else (params["blocks"], windows))
        x, (new_caches, auxs) = scan2(body, x, xs)
        if auxs:
            aux_tot = {k: v.sum() for k, v in auxs.items()}
        return x, new_caches, aux_tot

    if plan == "pair_moe":
        def body(xc, scanned):
            if has_cache:
                (dense_p, moe_p), (dcaches, mcache) = scanned
            else:
                (dense_p, moe_p), dcaches, mcache = scanned, None, None

            dense_fn = _maybe_remat(
                lambda blk_, x_, ci_: B.dense_block_apply(
                    blk_, x_, cfg, mode=mode, window=0, positions=positions,
                    cache=ci_, cache_pos=cache_pos), cfg, mode)
            moe_fn = _maybe_remat(
                lambda blk_, x_, ci_: B.moe_block_apply(
                    blk_, x_, cfg, mode=mode, window=0, positions=positions,
                    cache=ci_, cache_pos=cache_pos), cfg, mode)

            def inner(x_, dense_caches, moe_cache):
                new_d = []
                for i in range(c["dense_per"]):
                    blk = jax.tree.map(lambda l: l[i], dense_p)
                    ci = (jax.tree.map(lambda l: l[i], dense_caches)
                          if dense_caches is not None else None)
                    x_, nc, _ = dense_fn(blk, x_, ci)
                    new_d.append(_pad_kv(nc, pad_to))
                x_, nc_m, aux = moe_fn(moe_p, x_, moe_cache)
                new_d = (jax.tree.map(lambda *ls: jnp.stack(ls), *new_d)
                         if new_d and new_d[0] is not None else None)
                return x_, (new_d, _pad_kv(nc_m, pad_to)), aux

            fn = _maybe_remat(inner, cfg, mode)
            x_, ncs, aux = fn(xc, dcaches, mcache)
            return x_, (ncs, aux)

        xs = (((params["dense_blocks"], params["moe_blocks"]), caches)
              if has_cache else (params["dense_blocks"], params["moe_blocks"]))
        x, (new_caches, auxs) = scan2(body, x, xs)
        aux_tot = {k: v.sum() for k, v in auxs.items()}
        return x, new_caches, aux_tot

    if plan == "uniform_ssm":
        def body(xc, scanned):
            if has_cache:
                bp, cache = scanned
            else:
                bp, cache = scanned, None
            fn = _maybe_remat(
                lambda bp_, x_, cache_: B.ssm_block_apply(
                    bp_, x_, cfg, mode=mode, cache=cache_), cfg, mode)
            x_, nc, _ = fn(bp, xc, cache)
            return x_, nc

        xs = (params["blocks"], caches) if has_cache else params["blocks"]
        x, new_caches = scan2(body, x, xs)
        return x, new_caches, aux_tot

    if plan == "encdec":
        def body(xc, scanned):
            if has_cache:
                bp, cache = scanned
            else:
                bp, cache = scanned, None
            fn = _maybe_remat(
                lambda bp_, x_, cache_: B.xdec_block_apply(
                    bp_, x_, cfg, memory=memory, mode=mode, positions=positions,
                    cache=cache_, cache_pos=cache_pos), cfg, mode)
            x_, nc, _ = fn(bp, xc, cache)
            return x_, _pad_kv(nc, pad_to)

        xs = (params["dec_blocks"], caches) if has_cache else params["dec_blocks"]
        x, new_caches = scan2(body, x, xs)
        return x, new_caches, aux_tot

    if plan == "vlm":
        def body(xc, scanned):
            if has_cache:
                (self_p, cross_p), cache = scanned
            else:
                (self_p, cross_p), cache = scanned, None

            self_fn = _maybe_remat(
                lambda blk_, x_, ci_: B.dense_block_apply(
                    blk_, x_, cfg, mode=mode, window=0, positions=positions,
                    cache=ci_, cache_pos=cache_pos), cfg, mode)
            cross_fn = _maybe_remat(
                lambda blk_, x_: B.cross_block_apply(
                    blk_, x_, cfg, memory=memory), cfg, mode)

            def inner(x_, self_caches):
                new_s = []
                for i in range(c["self_per"]):
                    blk = jax.tree.map(lambda l: l[i], self_p)
                    ci = (jax.tree.map(lambda l: l[i], self_caches)
                          if self_caches is not None else None)
                    x_, nc, _ = self_fn(blk, x_, ci)
                    new_s.append(_pad_kv(nc, pad_to))
                x_, _, _ = cross_fn(cross_p, x_)
                new_s = (jax.tree.map(lambda *ls: jnp.stack(ls), *new_s)
                         if new_s and new_s[0] is not None else None)
                return x_, new_s

            fn = _maybe_remat(inner, cfg, mode)
            x_, ncs = fn(xc, cache)
            return x_, ncs

        xs = (((params["self_blocks"], params["cross_blocks"]), caches)
              if has_cache else (params["self_blocks"], params["cross_blocks"]))
        x, new_caches = scan2(body, x, xs)
        return x, new_caches, aux_tot

    if plan == "hybrid":
        shared = params["shared_attn"]

        def body(xc, scanned):
            if has_cache:
                bp, (scache, acache) = scanned
            else:
                bp, scache, acache = scanned, None, None

            ssm_fn = _maybe_remat(
                lambda blk_, x_, ci_: B.ssm_block_apply(
                    blk_, x_, cfg, mode=mode, cache=ci_), cfg, mode)
            attn_fn = _maybe_remat(
                lambda blk_, x_, ci_: B.dense_block_apply(
                    blk_, x_, cfg, mode=mode, window=0, positions=positions,
                    cache=ci_, cache_pos=cache_pos), cfg, mode)

            def inner(x_, ssm_caches, attn_cache):
                new_s = []
                for i in range(c["per"]):
                    blk = jax.tree.map(lambda l: l[i], bp)
                    ci = (jax.tree.map(lambda l: l[i], ssm_caches)
                          if ssm_caches is not None else None)
                    x_, nc, _ = ssm_fn(blk, x_, ci)
                    new_s.append(nc)
                x_, nca, _ = attn_fn(shared, x_, attn_cache)
                new_s = (jax.tree.map(lambda *ls: jnp.stack(ls), *new_s)
                         if new_s and new_s[0] is not None else None)
                return x_, (new_s, _pad_kv(nca, pad_to))

            fn = _maybe_remat(inner, cfg, mode)
            x_, ncs = fn(xc, scache, acache)
            return x_, ncs

        xs = ((params["ssm_blocks"], caches["super"]) if has_cache
              else params["ssm_blocks"])
        x, new_super = scan2(body, x, xs)

        new_extra = None
        if "extra_ssm" in params:
            def ebody(xc, scanned):
                if has_cache:
                    bp, cache = scanned
                else:
                    bp, cache = scanned, None
                x_, nc, _ = B.ssm_block_apply(bp, xc, cfg, mode=mode, cache=cache)
                return x_, nc
            exs = ((params["extra_ssm"], caches["extra"]) if has_cache
                   else params["extra_ssm"])
            x, new_extra = scan2(ebody, x, exs)

        new_caches = {"super": new_super, "extra": new_extra}
        return x, new_caches, aux_tot

    raise AssertionError(plan)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def _encode(params, batch, cfg):
    """Encoder side (whisper): frames (B, S, d) -> memory (B, S, d)."""
    x = batch["frames"].astype(cfg.dtype)
    pos = jnp.arange(x.shape[1])
    half = cfg.d_model // 2
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * np.log(10000.0) / half)
    ang = pos[:, None].astype(jnp.float32) * freq[None, :]
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(cfg.dtype)
    x = x + pe[None]

    def body(xc, bp):
        return B.encoder_block_apply(bp, xc, cfg), None

    x, _ = lax.scan(body, x, params["enc_blocks"])
    return rmsnorm({"scale": params["enc_norm"]}, x, cfg.norm_eps)


def _memory_for(params, batch, cfg):
    if "memory" in batch:
        return batch["memory"].astype(cfg.dtype)
    if cfg.family == "encdec":
        return _encode(params, batch, cfg)
    if cfg.family == "vlm":
        return batch["img_embeds"].astype(cfg.dtype)
    return None


def forward_hidden(params, batch, cfg: ModelConfig):
    """Backbone only: final-norm hidden states (B, T, d) + aux.  The caller
    owns the unembedding — the training loss uses this with a CHUNKED
    cross-entropy so the (B, T, vocab) f32 logits never materialize."""
    from repro.sharding import hints
    x = embed_lookup(params["embed"], batch["tokens"], cfg.dtype)
    x = hints.constrain(x, "residual")
    memory = _memory_for(params, batch, cfg)
    x, _, aux = _run_stack(params, x, cfg, mode="train", memory=memory)
    x = rmsnorm({"scale": params["final_norm"]}, x, cfg.norm_eps)
    return x, aux


def forward(params, batch, cfg: ModelConfig):
    """Training/teacher-forcing forward: logits (B, T, vocab) f32 + aux."""
    x, aux = forward_hidden(params, batch, cfg)
    logits = unembed(params.get("head", params["embed"]), x,
                     softcap=cfg.logits_softcap)
    return logits, aux


def prefill(params, batch, cfg: ModelConfig, max_len: int):
    """Prompt processing; returns (last-token logits, caches @ max_len)."""
    tokens = batch["tokens"]
    t = tokens.shape[1]
    x = embed_lookup(params["embed"], tokens, cfg.dtype)
    memory = _memory_for(params, batch, cfg)
    x, caches, _ = _run_stack(params, x, cfg, mode="prefill",
                              positions=jnp.arange(t), memory=memory,
                              pad_to=max_len)
    x = rmsnorm({"scale": params["final_norm"]}, x, cfg.norm_eps)
    logits = unembed(params.get("head", params["embed"]), x[:, -1:],
                     softcap=cfg.logits_softcap)
    return logits, caches


def decode_step(params, tokens, caches, pos, cfg: ModelConfig, batch_extras=None):
    """One decoding step.  tokens (B, 1); pos scalar index into the cache."""
    x = embed_lookup(params["embed"], tokens, cfg.dtype)
    memory = None
    if batch_extras is not None:
        memory = _memory_for(params, batch_extras, cfg)
    positions = jnp.full((1,), pos, dtype=jnp.asarray(pos).dtype)
    x, new_caches, _ = _run_stack(params, x, cfg, mode="decode", caches=caches,
                                  cache_pos=pos, positions=positions,
                                  memory=memory)
    x = rmsnorm({"scale": params["final_norm"]}, x, cfg.norm_eps)
    logits = unembed(params.get("head", params["embed"]), x,
                     softcap=cfg.logits_softcap)
    return logits, new_caches


# ---------------------------------------------------------------------------
# cache specs (ShapeDtypeStructs — used by serve dry-run; no allocation)
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    plan, c = _family_plan(cfg)
    dt = cfg.dtype

    def stack(spec, n):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), spec)

    attn = (_attn_cache_spec(cfg, batch, max_len, dt)
            if cfg.n_heads else None)
    ssm = ssm_cache_spec(cfg, batch, dt) if cfg.ssm_state else None

    if plan in ("uniform_dense", "uniform_moe"):
        return stack(attn, c["n"])
    if plan == "pair_moe":
        return (stack(stack(attn, c["dense_per"]), c["n"]), stack(attn, c["n"]))
    if plan == "uniform_ssm":
        return stack(ssm, c["n"])
    if plan == "encdec":
        return stack(attn, c["n_dec"])
    if plan == "vlm":
        return stack(stack(attn, c["self_per"]), c["n"])
    if plan == "hybrid":
        return {"super": (stack(stack(ssm, c["per"]), c["n"]),
                          stack(attn, c["n"])),
                "extra": stack(ssm, c["extra"]) if c["extra"] else None}
    raise AssertionError(plan)


# ---------------------------------------------------------------------------
# analytic params/FLOPs (6·N_active·D) for §Roofline's MODEL_FLOPS row
# ---------------------------------------------------------------------------

def count_params(cfg: ModelConfig, *, active_only: bool = False) -> int:
    """Approximate parameter count from the config (embeddings included)."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    h, kvh = cfg.n_heads, cfg.n_kv_heads
    hd = cfg.hd if h else 0
    attn = d * h * hd + 2 * d * kvh * hd + h * hd * d
    mlp = 3 * d * f
    fe = cfg.d_ff_expert or f
    moe_total = (cfg.n_experts + cfg.n_shared_experts) * 3 * d * fe + d * cfg.n_experts
    moe_active = ((cfg.top_k + cfg.n_shared_experts) * 3 * d * fe
                  + d * cfg.n_experts)
    moe_used = moe_active if active_only else moe_total

    d_in = cfg.d_inner
    g, st, nh = cfg.ssm_groups, cfg.ssm_state, cfg.nh_ssm
    ssm = (d * (2 * d_in + 2 * g * st + nh)
           + cfg.ssm_conv * (d_in + 2 * g * st) + d_in * d + d_in + 3 * nh)

    plan, c = _family_plan(cfg)
    if plan == "uniform_dense":
        core = cfg.n_layers * (attn + mlp)
    elif plan == "uniform_moe":
        core = cfg.n_layers * (attn + moe_used)
    elif plan == "pair_moe":
        core = c["n"] * (c["dense_per"] * (attn + mlp) + attn + moe_used)
    elif plan == "uniform_ssm":
        core = cfg.n_layers * ssm
    elif plan == "encdec":
        core = cfg.n_enc_layers * (attn + mlp) + cfg.n_layers * (2 * attn + mlp)
    elif plan == "vlm":
        core = c["n"] * (c["self_per"] * (attn + mlp) + attn + mlp)
    elif plan == "hybrid":
        core = cfg.n_layers * ssm + (attn + mlp)  # shared block counted once
    else:
        raise AssertionError(plan)
    return int(core + v * d * (1 if cfg.tie_embeddings else 2))


def model_flops(cfg: ModelConfig, n_tokens: int) -> int:
    """6 * N_active * D — the §Roofline MODEL_FLOPS convention."""
    return 6 * count_params(cfg, active_only=True) * n_tokens
