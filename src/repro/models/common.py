"""Model config + shared layers (pure-functional JAX, no framework deps).

Every module in repro.models follows the same protocol:

    init(key, cfg)        -> params pytree (jnp arrays)
    apply(params, x, ...) -> activations
    *param logical axes*  -> every array is created through ``param()`` which
                             registers logical sharding axes; ``specs_of`` then
                             rebuilds the matching pytree of logical-axis
                             tuples for sharding/rules.py.

Full-size configs are NEVER materialized in tests — the dry-run uses
``jax.eval_shape(init, ...)`` to get ShapeDtypeStructs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"          # dense | moe | ssm | encdec | vlm | hybrid
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0              # 0 -> d_model // n_heads
    d_ff: int = 256
    vocab: int = 512
    qkv_bias: bool = False
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    # sliding-window pattern (gemma3): window size + one global layer every k
    sliding_window: int = 0        # 0 -> all layers full attention
    global_every: int = 0          # e.g. 6 -> layers 5, 11, ... are global
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1             # 2 -> every 2nd layer is MoE (llama4)
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    ssm_conv: int = 4
    ssm_groups: int = 1
    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 1500            # whisper: 30s of audio -> 1500 frames
    # vision (llama-3.2-vision)
    cross_attn_every: int = 0      # e.g. 5 -> one cross-attn layer per 5
    n_img_tokens: int = 0
    # hybrid (zamba2)
    shared_attn_every: int = 0     # e.g. 6 -> shared attn block every 6 ssm
    # compute
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    attn_impl: str = "full"        # full | chunked
    attn_chunk: int = 2048
    remat: bool = True
    scan_layers: bool = True       # False: unroll (exact cost_analysis FLOPs;
                                   # XLA can overlap collectives across layers)
    logits_softcap: float = 0.0

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def nh_ssm(self) -> int:
        return self.ssm_heads or (self.d_inner // self.ssm_headdim)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Param creation with logical-axis registration
# ---------------------------------------------------------------------------

# Leaves are plain arrays; logical axes are reconstructed structurally by
# ``specs_of`` walking the same init code with a tracing context.
_AXES_TLS: list = []


class _AxisRecorder:
    def __init__(self):
        self.tree = None


def keygen(key):
    """Infinite stream of subkeys; yields None when key is None (recording)."""
    if key is None or _AXES_TLS:
        while True:
            yield None
    while True:
        key, sub = jax.random.split(key)
        yield sub


def param(key, shape, axes, dtype, *, scale: float | None = None):
    """Create (or abstractly trace) a parameter and register logical axes."""
    assert len(shape) == len(axes), (shape, axes)
    if _AXES_TLS:
        # Recording pass: return axes tuple as the leaf.
        return _Axes(axes)
    if scale is None:
        scale = 1.0 / np.sqrt(shape[0]) if len(shape) >= 2 else 0.02
    if key is None:
        return jnp.zeros(shape, dtype)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


class _Axes(tuple):
    """Marker leaf used during the axis-recording pass."""
    def __new__(cls, axes):
        return super().__new__(cls, axes)


def specs_of(init_fn, *args, **kw):
    """Re-run ``init_fn`` in recording mode; returns pytree of axis tuples."""
    _AXES_TLS.append(True)
    try:
        tree = init_fn(*args, **kw)
    finally:
        _AXES_TLS.pop()
    return tree


def is_axes_leaf(x):
    return isinstance(x, _Axes)


# ---------------------------------------------------------------------------
# Shared layers
# ---------------------------------------------------------------------------


def rmsnorm_init(key, d, dtype):
    return {"scale": param(key, (d,), ("embed",), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


def embed_init(key, cfg: ModelConfig):
    # sigma=0.02 (GPT-2 convention): with tied unembedding this keeps the
    # initial logit scale ~N(0, 0.02^2 * d) so initial NLL ~ ln(vocab)
    return param(key, (cfg.vocab, cfg.d_model), ("vocab", "embed"),
                 cfg.param_dtype, scale=0.02)


def embed_lookup(table, ids, dtype):
    return jnp.take(table, ids, axis=0).astype(dtype)


def unembed(table_or_head, x, *, softcap: float = 0.0):
    logits = jnp.einsum("btd,vd->btv", x.astype(jnp.float32),
                        table_or_head.astype(jnp.float32))
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float, positions):
    """positions (...,) -> (sin, cos) of shape (..., head_dim//2), f32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x (..., T, H, D); sin/cos (..., T, D/2) broadcast over heads.

    Rotation happens in f32 (sin/cos precision matters at 500k positions);
    the result is cast back to x.dtype so activations stay bf16.
    """
    half = x.shape[-1] // 2
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., :half], xf[..., half:]
    s = sin[..., None, :]
    c = cos[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)
