"""Multi-head attention: GQA, RoPE, optional QKV bias, sliding-window masks,
KV caches (prefill/decode), cross-attention, and a chunked (flash-style)
implementation for long sequences.

Modes (``mode`` argument of ``attn_apply``):
  "train"    causal self-attention over the whole sequence, no cache
  "encoder"  bidirectional self-attention (whisper encoder)
  "prefill"  causal self-attention that also RETURNS the (k, v) to cache
  "decode"   single-step: q has T=1; reads keys/values from the cache
  "cross"    queries over a fixed memory (encoder output / image tokens)

KV cache layout: {"k": (B, S, n_kv, hd), "v": (B, S, n_kv, hd)} with S the
static max length; ``cache_pos`` scalar gives the current fill.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import (
    ModelConfig, apply_rope, keygen, param, rope_freqs,
)

NEG_INF = -2.0 ** 30  # large-but-finite: keeps padded rows NaN-free


def attn_init(key, cfg: ModelConfig):
    kg = keygen(key)
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {
        "wq": param(next(kg), (d, h, hd), ("embed", "heads", "head_dim"),
                    cfg.param_dtype),
        "wk": param(next(kg), (d, kvh, hd), ("embed", "kv_heads", "head_dim"),
                    cfg.param_dtype),
        "wv": param(next(kg), (d, kvh, hd), ("embed", "kv_heads", "head_dim"),
                    cfg.param_dtype),
        "wo": param(next(kg), (h, hd, d), ("heads", "head_dim", "embed"),
                    cfg.param_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = param(None, (h, hd), ("heads", "head_dim"), cfg.param_dtype)
        p["bk"] = param(None, (kvh, hd), ("kv_heads", "head_dim"), cfg.param_dtype)
        p["bv"] = param(None, (kvh, hd), ("kv_heads", "head_dim"), cfg.param_dtype)
    return p


def _project_q(p, x, cfg):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    return q


def _project_kv(p, x, cfg):
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"].astype(x.dtype))
    if "bk" in p:
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return k, v


def _repeat_kv(k, n_heads):
    """(B, S, kvh, hd) -> (B, S, H, hd) by repeating each group."""
    b, s, kvh, hd = k.shape
    if kvh == n_heads:
        return k
    rep = n_heads // kvh
    return jnp.repeat(k, rep, axis=2)


FULL_WINDOW = 2 ** 30  # "no sliding window" sentinel (works traced or static)


def _mask_bias(mode, q_pos, k_pos, window, dtype):
    """(Tq, Tk) additive bias from mode/window; f32.

    ``window`` may be a TRACED scalar (per-layer window array under scan —
    gemma3's 5-local:1-global pattern); 0 / FULL_WINDOW both mean full.
    """
    if mode == "encoder" or mode == "cross":
        return None
    keep = k_pos[None, :] <= q_pos[:, None]              # causal
    w = jnp.where(jnp.asarray(window) <= 0, FULL_WINDOW, window)
    keep &= (q_pos[:, None] - k_pos[None, :]) < w
    return jnp.where(keep, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa_full(q, k, v, bias):
    """q (B,Tq,H,hd), k/v (B,Tk,H,hd); logits in f32.

    The logits tensor carries a GSPMD hint: heads on "model" when they
    divide, otherwise Tq on "model" (sequence parallelism) — see
    sharding/hints.py.  No-op off-mesh.
    """
    from repro.sharding import hints
    hd = q.shape[-1]
    logits = jnp.einsum("bqhk,bshk->bhqs", q, k,
                        preferred_element_type=jnp.float32)
    logits = hints.constrain(logits / jnp.sqrt(jnp.float32(hd)), "attn_logits")
    if bias is not None:
        logits = logits + bias[None, None]
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return hints.constrain(jnp.einsum("bhqs,bshk->bqhk", w, v), "attn_out")


def _sdpa_chunked(q, k, v, q_pos, k_pos, window, mode, chunk, unroll=False):
    """Flash-style: lax.scan over KV chunks with running (max, sum, acc).

    Memory: O(Tq * chunk) logits instead of O(Tq * Tk) — required for the
    500k-token cells and available to every arch via cfg.attn_impl.
    """
    b, tq, h, hd = q.shape
    tk = k.shape[1]
    n_chunks = -(-tk // chunk)
    pad = n_chunks * chunk - tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=2**30)
    kc = k.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(n_chunks, chunk)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    from repro.sharding import hints

    @jax.checkpoint   # don't save per-chunk logits in backward (O(T^2) mem)
    def body(carry, inp):
        m, s, acc = carry
        kb, vb, pb = inp
        logits = jnp.einsum("bqhk,bshk->bhqs", q, kb,
                            preferred_element_type=jnp.float32) * scale
        logits = hints.constrain(logits, "attn_logits")
        if mode not in ("encoder", "cross"):
            keep = pb[None, :] <= q_pos[:, None]
            w = jnp.where(jnp.asarray(window) <= 0, FULL_WINDOW, window)
            keep &= (q_pos[:, None] - pb[None, :]) < w
            logits = logits + jnp.where(keep, 0.0, NEG_INF)[None, None]
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(logits - m_new[..., None])
        s_new = s * alpha + pexp.sum(axis=-1)
        # f32 accumulator: keeps the scan carry type stable and the sum exact
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqs,bshk->bhqk", pexp.astype(q.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, s_new, acc_new), None

    m0 = jnp.full((b, h, tq), -jnp.inf, jnp.float32)
    s0 = jnp.zeros((b, h, tq), jnp.float32)
    acc0 = jnp.zeros((b, h, tq, hd), jnp.float32)
    (m, s, acc), _ = lax.scan(body, (m0, s0, acc0), (kc, vc, pc),
                              unroll=True if unroll else 1)
    out = (acc / jnp.maximum(s, 1e-30)[..., None]).astype(q.dtype)
    return out.transpose(0, 2, 1, 3)  # (B, Tq, H, hd)


def attn_apply(p, x, cfg: ModelConfig, *, mode: str = "train",
               window: int = 0, positions=None, cache=None, cache_pos=None,
               memory=None):
    """Returns (out, new_cache_kv).

    new_cache_kv is None except: "prefill" returns the (k, v) to store;
    "decode" returns the updated cache dict.
    """
    from repro.sharding import hints
    b, t, d = x.shape
    q = hints.constrain(_project_q(p, x, cfg), "qkv")

    if mode == "cross":
        k, v = _project_kv(p, memory, cfg)
        k_pos = jnp.arange(memory.shape[1])
        q_pos = jnp.arange(t) if positions is None else positions
    else:
        k, v = _project_kv(p, x, cfg)
        q_pos = jnp.arange(t) if positions is None else positions
        if mode != "encoder":
            sin, cos = rope_freqs(cfg.hd, cfg.rope_theta, q_pos)
            q = apply_rope(q, sin, cos)
            k = apply_rope(k, sin, cos)
        k_pos = q_pos

    new_cache = None
    if mode == "prefill":
        new_cache = {"k": k, "v": v}
    elif mode == "decode":
        assert cache is not None and cache_pos is not None
        if hints.flag("kv_masked_write"):
            # S is sharded (long_500k): one-hot masked merge keeps the write
            # shard-local (a traced-pos dynamic_update_slice would regather)
            slot = (jnp.arange(cache["k"].shape[1]) == cache_pos
                    )[None, :, None, None]
            ck = jnp.where(slot, k.astype(cache["k"].dtype), cache["k"])
            cv = jnp.where(slot, v.astype(cache["v"].dtype), cache["v"])
        else:
            # index dtypes must match exactly on jax 0.4.x (no int promotion)
            pos = jnp.asarray(cache_pos)
            z = jnp.zeros((), pos.dtype)
            ck = lax.dynamic_update_slice(cache["k"], k, (z, pos, z, z))
            cv = lax.dynamic_update_slice(cache["v"], v, (z, pos, z, z))
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        s = ck.shape[1]
        k_pos = jnp.arange(s)
        # mask out unwritten slots
        q_pos = jnp.full((t,), cache_pos, dtype=k_pos.dtype) \
            if positions is None else positions

    kf = _repeat_kv(k, cfg.n_heads)
    vf = _repeat_kv(v, cfg.n_heads)

    if mode == "decode":
        # single-token query: a (B, H, 1, S) einsum — linear in S
        valid = k_pos <= cache_pos
        keep = valid[None, :] & (k_pos[None, :] <= q_pos[:, None])
        w = jnp.where(jnp.asarray(window) <= 0, FULL_WINDOW, window)
        keep &= (q_pos[:, None] - k_pos[None, :]) < w
        bias = jnp.where(keep, 0.0, NEG_INF).astype(jnp.float32)
        out = _sdpa_full(q, kf, vf, bias)
    elif cfg.attn_impl == "chunked" and mode in ("train", "prefill"):
        out = _sdpa_chunked(q, kf, vf, q_pos, k_pos, window, mode,
                            cfg.attn_chunk, unroll=not cfg.scan_layers)
    else:
        bias = _mask_bias(mode, q_pos, k_pos, window, x.dtype)
        out = _sdpa_full(q, kf, vf, bias)

    o = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(x.dtype))
    return hints.constrain(o, "residual"), new_cache
