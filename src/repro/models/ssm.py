"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) layer.

Implements the chunked SSD algorithm: within a chunk of Q tokens the output
is a masked (decay-weighted) attention-like contraction; across chunks a
single recurrent state (nh, hp, state) is carried by lax.scan.  Train and
prefill cost O(T*Q) instead of O(T^2); decode is an O(1) recurrence — this is
what makes the ``long_500k`` cells sub-quadratic for mamba2/zamba2.

Layer structure (following the paper's Mamba-2 block):
  in_proj -> [z | x | B | C | dt],  causal depthwise conv on [x|B|C],
  SSD with per-head scalar decay A, skip D, gated RMSNorm, out_proj.

Decode cache: {"conv": (B, d_conv-1, convdim), "ssm": (B, nh, hp, state)}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import ModelConfig, keygen, param, rmsnorm

__all__ = ["ssm_init", "ssm_apply", "ssm_cache_spec"]


def _dims(cfg: ModelConfig):
    d_in = cfg.d_inner
    nh = cfg.nh_ssm
    hp = d_in // nh
    g = cfg.ssm_groups
    st = cfg.ssm_state
    convdim = d_in + 2 * g * st
    proj = 2 * d_in + 2 * g * st + nh
    return d_in, nh, hp, g, st, convdim, proj


def ssm_init(key, cfg: ModelConfig):
    kg = keygen(key)
    d = cfg.d_model
    d_in, nh, hp, g, st, convdim, proj = _dims(cfg)
    return {
        "in_proj": param(next(kg), (d, proj), ("embed", "inner"), cfg.param_dtype),
        "conv_w": param(next(kg), (cfg.ssm_conv, convdim), ("conv", "inner"),
                        cfg.param_dtype, scale=0.5),
        "conv_b": param(None, (convdim,), ("inner",), cfg.param_dtype),
        "A_log": param(next(kg), (nh,), ("heads",), jnp.float32, scale=1.0),
        "D": param(None, (nh,), ("heads",), jnp.float32),
        "dt_bias": param(None, (nh,), ("heads",), jnp.float32),
        "norm": param(None, (d_in,), ("inner",), cfg.param_dtype),
        "out_proj": param(next(kg), (d_in, d), ("inner", "embed"), cfg.param_dtype),
    }


def _split_proj(zxbcdt, cfg):
    d_in, nh, hp, g, st, convdim, proj = _dims(cfg)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + convdim]
    dt = zxbcdt[..., d_in + convdim:]
    return z, xbc, dt


def _split_xbc(xbc, cfg):
    d_in, nh, hp, g, st, convdim, _ = _dims(cfg)
    x = xbc[..., :d_in]
    bmat = xbc[..., d_in:d_in + g * st]
    cmat = xbc[..., d_in + g * st:]
    return x, bmat, cmat


def _conv_full(xbc, w, b):
    """Causal depthwise conv over time; xbc (B, T, C), w (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def _ssd_chunked(x, bmat, cmat, dt, A, cfg, h0=None):
    """Chunked SSD scan.

    x (B,T,nh,hp), bmat/cmat (B,T,g,st) broadcast to heads, dt (B,T,nh) f32,
    A (nh,) negative.  Returns (y (B,T,nh,hp), h_final (B,nh,hp,st)).
    """
    d_in, nh, hp, g, st, convdim, _ = _dims(cfg)
    b_sz, t, _, _ = x.shape
    q = min(cfg.ssm_chunk, t)
    nc = -(-t // q)
    pad = nc * q - t
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))

    rep = nh // g
    # reshape into chunks, put chunk axis first for scan
    def chunked(u):
        return u.reshape(b_sz, nc, q, *u.shape[2:]).swapaxes(0, 1)

    xc, bc, cc, dtc = chunked(x), chunked(bmat), chunked(cmat), chunked(dt)

    @jax.checkpoint   # recompute chunk internals in backward: the (q x q)
    def body(h, inp):  # decay panels would otherwise be saved PER CHUNK
        xq, bq, cq, dtq = inp                       # (B,q,...) one chunk
        a = dtq * A                                  # (B,q,nh) log-decay <= 0
        cum = jnp.cumsum(a, axis=1)                  # (B,q,nh)
        total = cum[:, -1]                           # (B,nh)
        bh = jnp.repeat(bq, rep, axis=2)             # (B,q,nh,st)
        ch = jnp.repeat(cq, rep, axis=2)
        xdt = xq * dtq[..., None].astype(xq.dtype)   # (B,q,nh,hp)

        # intra-chunk: masked decay attention  L[i,j] = exp(cum_i - cum_j), j<=i
        scores = jnp.einsum("bihs,bjhs->bhij", ch, bh,
                            preferred_element_type=jnp.float32)
        ldiff = cum[:, :, None, :] - cum[:, None, :, :]      # (B,i,j,nh)
        causal = jnp.tril(jnp.ones((q, q), bool))
        # mask BEFORE exp: masked entries are exp(-inf)=0 with a zero (not
        # 0*inf=NaN) gradient — exp(ldiff) overflows for j>i.
        decay = jnp.exp(jnp.where(causal[None, :, :, None], ldiff, -jnp.inf))
        w = scores * decay.transpose(0, 3, 1, 2)             # (B,nh,i,j)
        y_intra = jnp.einsum("bhij,bjhp->bihp", w.astype(xq.dtype), xdt)

        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bihs,bhps->bihp",
                             (ch.astype(jnp.float32)
                              * jnp.exp(cum)[..., None]).astype(xq.dtype), h)

        # state update: h' = h * exp(total) + sum_j exp(total - cum_j) B_j xdt_j^T
        wj = jnp.exp(total[:, None] - cum)                    # (B,q,nh)
        dh = jnp.einsum("bjhs,bjhp->bhps",
                        (bh.astype(jnp.float32) * wj[..., None]).astype(xq.dtype),
                        xdt)
        h = h * jnp.exp(total)[..., None, None].astype(h.dtype) + dh
        return h, y_intra + y_inter

    if h0 is None:
        h0 = jnp.zeros((b_sz, nh, hp, st), x.dtype)
    h, ys = lax.scan(body, h0, (xc, bc, cc, dtc))
    y = ys.swapaxes(0, 1).reshape(b_sz, nc * q, nh, hp)
    if pad:
        y = y[:, :t]
    return y, h


def ssm_apply(p, xin, cfg: ModelConfig, *, mode: str = "train", cache=None):
    """Returns (out (B,T,d), new_cache)."""
    b, t, d = xin.shape
    d_in, nh, hp, g, st, convdim, _ = _dims(cfg)
    dt_f = xin.dtype

    zxbcdt = jnp.einsum("btd,dp->btp", xin, p["in_proj"].astype(dt_f))
    z, xbc_raw, dtp = _split_proj(zxbcdt, cfg)
    A = -jnp.exp(p["A_log"])                                  # (nh,)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + p["dt_bias"])

    new_cache = None
    if mode == "decode":
        assert cache is not None and t == 1
        conv_hist = jnp.concatenate([cache["conv"], xbc_raw], axis=1)
        w, bias = p["conv_w"].astype(dt_f), p["conv_b"].astype(dt_f)
        k = w.shape[0]
        xbc = jax.nn.silu((conv_hist[:, -k:] * w[None]).sum(1) + bias)[:, None]
        x, bmat, cmat = _split_xbc(xbc, cfg)
        xh = x.reshape(b, 1, nh, hp)
        bh = jnp.repeat(bmat.reshape(b, 1, g, st)[:, 0], nh // g, axis=1)
        ch = jnp.repeat(cmat.reshape(b, 1, g, st)[:, 0], nh // g, axis=1)
        dt1 = dt[:, 0]                                        # (B,nh)
        da = jnp.exp(dt1 * A)                                 # (B,nh)
        xdt = xh[:, 0] * dt1[..., None].astype(dt_f)
        h = (cache["ssm"] * da[..., None, None].astype(dt_f)
             + jnp.einsum("bhp,bhs->bhps", xdt, bh.astype(dt_f)))
        y = jnp.einsum("bhs,bhps->bhp", ch.astype(dt_f), h)[:, None]
        new_cache = {"conv": conv_hist[:, -(k - 1):], "ssm": h}
    else:
        xbc = _conv_full(xbc_raw, p["conv_w"].astype(dt_f),
                         p["conv_b"].astype(dt_f))
        x, bmat, cmat = _split_xbc(xbc, cfg)
        xh = x.reshape(b, t, nh, hp)
        bm = bmat.reshape(b, t, g, st)
        cm = cmat.reshape(b, t, g, st)
        y, h = _ssd_chunked(xh, bm, cm, dt, A, cfg)
        if mode == "prefill":
            k = p["conv_w"].shape[0]
            tail = xbc_raw[:, -(k - 1):]
            new_cache = {"conv": tail, "ssm": h}

    y = y + xh * p["D"][None, None, :, None].astype(dt_f)
    y = y.reshape(b, t, d_in)
    y = rmsnorm({"scale": p["norm"]}, y * jax.nn.silu(z), cfg.norm_eps)
    return jnp.einsum("bti,id->btd", y, p["out_proj"].astype(dt_f)), new_cache


def ssm_cache_spec(cfg: ModelConfig, batch: int, dtype):
    """ShapeDtypeStructs for one layer's decode cache."""
    d_in, nh, hp, g, st, convdim, _ = _dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, convdim), dtype),
        "ssm": jax.ShapeDtypeStruct((batch, nh, hp, st), dtype),
    }
