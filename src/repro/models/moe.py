"""Mixture-of-Experts layer: top-k routing, capacity-based scatter dispatch,
optional shared experts (qwen2-moe), expert-parallel-friendly layout.

Dispatch is O(T * d) gather/scatter (not the O(T * E * C * d) one-hot einsum):
tokens are assigned a slot = rank within their expert (cumsum of a one-hot
(T, E) int matrix), scattered into an (E, C, d) buffer, processed by a batched
expert GLU, and combined back with router weights.  Tokens overflowing the
capacity C = ceil(T * top_k / E * capacity_factor) are dropped (their combine
weight is 0) — standard capacity-based MoE semantics.

The (E, ...) leading expert axis is the EP sharding axis ("expert" logical
axis -> "model" mesh axis); dispatch/combine become all-to-alls under GSPMD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, keygen, param
from repro.models.mlp import mlp_init, mlp_apply


def moe_init(key, cfg: ModelConfig):
    kg = keygen(key)
    d = cfg.d_model
    e = cfg.n_experts
    f = cfg.d_ff_expert or cfg.d_ff
    p = {
        "router": param(next(kg), (d, e), ("embed", "expert"), jnp.float32),
        "we_gate": param(next(kg), (e, d, f), ("expert", "embed", "mlp"),
                         cfg.param_dtype),
        "we_up": param(next(kg), (e, d, f), ("expert", "embed", "mlp"),
                       cfg.param_dtype),
        "we_down": param(next(kg), (e, f, d), ("expert", "mlp", "embed"),
                         cfg.param_dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(
            next(kg), cfg, d_ff=(cfg.d_ff_expert or cfg.d_ff) * cfg.n_shared_experts)
    return p


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor) + 1
    c = min(max(c, 1), n_tokens)
    # round up to a multiple of 256 so the capacity dim stays shardable
    # (the dispatch buffer shards on capacity when experts don't divide
    # the model axis — qwen2-moe's 60 experts on a 16-way axis)
    return -(-c // 256) * 256 if n_tokens >= 256 else c


def moe_apply(p, x, cfg: ModelConfig):
    """x (B, T, d) -> (B, T, d); aux losses returned as dict."""
    from repro.sharding import hints
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    # gather the sequence axis before the (b*t) token flatten: dispatch is
    # global over tokens; a model-sharded T would shuffle the merged dim
    x = hints.constrain(x, "gathered")
    xt = x.reshape(b * t, d)
    n = b * t
    # decode (t == 1): dropless — capacity covers the worst case so serving
    # never silently drops a live token's expert assignment.
    c = capacity(cfg, n) if t > 1 else n

    gates = jax.nn.softmax(
        jnp.einsum("nd,de->ne", xt.astype(jnp.float32), p["router"]), axis=-1)
    topw, topi = jax.lax.top_k(gates, k)                    # (n, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # slot of assignment (n, k) within its expert, via one-hot cumsum ranks
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.int32)        # (n, k, e)
    flat = onehot.reshape(n * k, e)
    ranks = (jnp.cumsum(flat, axis=0) - flat)                # exclusive ranks
    slot = (ranks * flat).sum(-1).reshape(n, k)              # (n, k)
    keep = slot < c                                          # capacity filter
    w = topw * keep.astype(topw.dtype)

    # scatter tokens into (e, c, d); overflow writes land out of bounds and
    # mode="drop" discards them (their combine weight is already 0)
    ei = topi
    si = jnp.where(keep, slot, c)                            # c -> dropped
    buf = jnp.zeros((e, c, d), x.dtype)
    buf = hints.constrain(buf, "moe_buf")
    tok_idx = jnp.broadcast_to(jnp.arange(n)[:, None], (n, k))
    buf = buf.at[ei.reshape(-1), si.reshape(-1)].add(
        xt[tok_idx.reshape(-1)], mode="drop")
    ex_in = hints.constrain(buf, "moe_buf")                  # (e, c, d) EP

    # batched expert GLU
    dt = x.dtype
    g = jnp.einsum("ecd,edf->ecf", ex_in, p["we_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", ex_in, p["we_up"].astype(dt))
    ex_out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                        p["we_down"].astype(dt))

    # combine: gather each assignment's output, weight, sum over k
    gathered = ex_out[ei.reshape(-1), jnp.minimum(si, c - 1).reshape(-1)]
    gathered = gathered.reshape(n, k, d) * w[..., None].astype(dt)
    out = gathered.sum(axis=1)

    if "shared" in p:
        out = out + mlp_apply(p["shared"], x, ).reshape(n, d)

    # load-balancing aux (Switch-style): mean_gate * mean_assign per expert
    me = gates.mean(0)
    ce = (onehot.sum(1).astype(jnp.float32)).mean(0)
    aux = {"moe_balance": (me * ce).sum() * e}
    return out.reshape(b, t, d), aux
