from repro.checkpoint import checkpoint
