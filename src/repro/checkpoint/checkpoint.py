"""Checkpointing: atomic, async-capable, elastic-on-restore.

Layout: one .npy per pytree leaf (path-encoded filenames) + manifest.json
with the treedef, step, and dtype/shape table.  Writes go to a temp dir and
are atomically renamed — a crash mid-save never corrupts the latest
checkpoint.  ``save_async`` runs serialization on a background thread
(double-buffered: at most one outstanding save, older pending save joined).

Elastic restore: leaves are stored UNSHARDED (gathered); ``restore`` places
them onto the *current* mesh with the *current* sharding rules, so the same
checkpoint restores onto any device count — the reshard-on-restart path that
elastic scaling needs.  (At real pod scale you would write per-shard ocdbt
instead of gathering; the gather keeps this container-friendly while the
interface — save(state, step), restore(dir, like) — stays the same.)
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import numpy as np

import jax

__all__ = ["save", "save_async", "restore", "latest_step", "wait_pending"]

_PENDING: list[threading.Thread] = []


def _leaf_name(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "__".join(parts) or "leaf"


def save(ckpt_dir: str | Path, state: Any, step: int) -> Path:
    """Synchronous atomic save of a pytree; returns the final directory."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    manifest = {"step": int(step), "leaves": []}
    for path, leaf in flat:
        name = _leaf_name(path)
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"{name}.npy", arr)
        manifest["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # atomic on POSIX
    return final


def save_async(ckpt_dir: str | Path, state: Any, step: int) -> threading.Thread:
    """Background save; state is device_get'd on the caller thread first so
    the training loop can donate/overwrite buffers immediately after."""
    host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
    t = threading.Thread(target=save, args=(ckpt_dir, host_state, step),
                         daemon=True)
    t.start()
    _PENDING.append(t)
    return t


def wait_pending():
    while _PENDING:
        _PENDING.pop().join()


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(m.group(1)) for p in ckpt_dir.iterdir()
             if (m := re.fullmatch(r"step_(\d+)", p.name))]
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, like: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore a pytree saved by `save` onto the current devices.

    ``like`` provides the tree structure; ``shardings`` (optional, matching
    pytree of Shardings) re-shards every leaf for the *current* mesh —
    restoring a 512-chip checkpoint onto 8 chips (or vice versa) Just Works.
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    sflat = (jax.tree_util.tree_flatten(shardings)[0]
             if shardings is not None else [None] * len(flat))
    leaves = []
    for (path, leaf), sh in zip(flat, sflat):
        name = _leaf_name(path)
        arr = np.load(d / f"{name}.npy")
        want = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"{name}: checkpoint shape {arr.shape} != {want}")
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.device_put(arr))
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    return state, int(manifest["step"])
