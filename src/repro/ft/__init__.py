from repro.ft.driver import FTConfig, StepStats, run_training
