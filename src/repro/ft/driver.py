"""Fault-tolerant training driver: restart-on-failure, periodic (async)
checkpoints, straggler detection, and deterministic data replay.

The driver owns the outer python loop; everything inside a step is one jitted
XLA program.  On ANY exception from a step (device loss, preemption signal,
injected test fault) it:
  1. waits for pending async checkpoint writes,
  2. restores the latest valid checkpoint (elastic: onto whatever devices
     exist now),
  3. replays the data stream from the restored step (synthetic pipeline is a
     pure function of step — no iterator state to rebuild),
  4. continues, up to ``max_restarts``.

Straggler mitigation: per-step wall times feed an EWMA; a step slower than
``straggler_factor`` x EWMA is logged with its index.  On a real pod the
callback would feed the scheduler (hot-spare swap / re-shard); here it
surfaces the signal and keeps the history for tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.checkpoint import checkpoint as ckpt


@dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    async_ckpt: bool = True
    max_restarts: int = 3
    straggler_factor: float = 2.5
    ewma: float = 0.9


@dataclass
class StepStats:
    times: List[float] = field(default_factory=list)
    stragglers: List[int] = field(default_factory=list)
    restarts: int = 0


def run_training(
    *,
    state: Any,
    train_step: Callable[[Any, Any], tuple],
    batch_fn: Callable[[int], Any],
    n_steps: int,
    ft: FTConfig = FTConfig(),
    shardings: Any = None,
    on_metrics: Optional[Callable[[int, Dict], None]] = None,
    fault_injector: Optional[Callable[[int], None]] = None,
) -> tuple[Any, StepStats]:
    """Run ``n_steps`` with checkpoint/restart fault tolerance.

    ``batch_fn(step)`` must be deterministic in ``step`` (replayable).
    ``fault_injector(step)`` (tests) may raise to simulate a node failure.
    """
    stats = StepStats()
    step = int(jax.device_get(state["step"]))
    ewma_t: Optional[float] = None

    while step < n_steps:
        try:
            t0 = time.perf_counter()
            if fault_injector is not None:    # inside the timed window: an
                fault_injector(step)          # injected sleep IS a straggler
            state, metrics = train_step(state, batch_fn(step))
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            stats.times.append(dt)
            if ewma_t is not None and dt > ft.straggler_factor * ewma_t:
                stats.stragglers.append(step)
            # seed the EWMA from the SECOND measured step: the first one
            # carries XLA compile time and would mask real stragglers
            if len(stats.times) == 2:
                ewma_t = dt
            elif ewma_t is not None:
                ewma_t = ft.ewma * ewma_t + (1 - ft.ewma) * dt
            step += 1
            if on_metrics is not None:
                on_metrics(step, jax.device_get(metrics))
            if step % ft.ckpt_every == 0 or step == n_steps:
                if ft.async_ckpt:
                    ckpt.save_async(ft.ckpt_dir, state, step)
                else:
                    ckpt.save(ft.ckpt_dir, state, step)
        except (KeyboardInterrupt,):
            raise
        except Exception as e:                      # noqa: BLE001 — FT boundary
            stats.restarts += 1
            if stats.restarts > ft.max_restarts:
                raise RuntimeError(
                    f"exceeded max_restarts={ft.max_restarts}") from e
            ckpt.wait_pending()
            last = ckpt.latest_step(ft.ckpt_dir)
            if last is None:
                # nothing saved yet: restart from the initial state
                step = int(jax.device_get(state["step"]))
                continue
            state, step = ckpt.restore(ft.ckpt_dir, state, step=last,
                                       shardings=shardings)
            step = int(step)

    ckpt.wait_pending()
    return state, stats
