from repro.data.synthetic import DataConfig, synth_batch, data_iterator, random_matrix
