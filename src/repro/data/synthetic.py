"""Deterministic, shardable synthetic data pipelines.

Tokens are a pure function of (seed, step) so the pipeline is:
  * resumable — checkpoint restore replays from the stored step with zero
    state (no iterator snapshots to persist);
  * elastic    — any device count reads the same global batch;
  * cheap      — generated on-device, no host I/O on the training path.

Also provides the matrix generators used by the logdet benchmarks (normal,
scaled-SPD "spatial correlation", and the paper's §2.2 adversarial rows).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    batch: int = 8
    seq: int = 128
    kind: str = "lm"          # lm | markov


def synth_batch(cfg: ModelConfig, data: DataConfig, step) -> Dict[str, jax.Array]:
    """Global batch for `step` — jit-friendly (step may be traced)."""
    key = jax.random.fold_in(jax.random.PRNGKey(data.seed), step)
    b, t = data.batch, data.seq
    if data.kind == "markov":
        # an actually-learnable stream: tokens follow x_{t+1} = 31*x_t + noise
        k1, k2 = jax.random.split(key)
        x0 = jax.random.randint(k1, (b, 1), 0, cfg.vocab)
        noise = jax.random.randint(k2, (b, t), 0, 17)
        def body(x, n):
            nxt = (x * 31 + 7 + n) % cfg.vocab
            return nxt, nxt
        _, toks = jax.lax.scan(body, x0[:, 0], noise.T)
        tokens = toks.T
    else:
        tokens = jax.random.randint(key, (b, t), 0, cfg.vocab)
    tokens = tokens.astype(jnp.int32)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 1), (b, cfg.enc_seq, cfg.d_model),
            jnp.float32).astype(cfg.dtype)
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2), (b, cfg.n_img_tokens, cfg.d_model),
            jnp.float32).astype(cfg.dtype)
    return batch


def data_iterator(cfg: ModelConfig, data: DataConfig, start_step: int = 0
                  ) -> Iterator[Dict[str, jax.Array]]:
    step = start_step
    while True:
        yield synth_batch(cfg, data, step)
        step += 1


# ---------------------------------------------------------------------------
# matrices for the logdet core (paper §3 experiments)
# ---------------------------------------------------------------------------

def random_matrix(n: int, *, kind: str = "normal", seed: int = 0,
                  dtype=np.float64) -> np.ndarray:
    """Matrix families used by the paper + adversarial pivot cases."""
    rng = np.random.default_rng(seed)
    if kind == "normal":
        return rng.standard_normal((n, n)).astype(dtype)
    if kind == "spd":
        x = rng.standard_normal((n, n + 8))
        return ((x @ x.T) / n + 1e-3 * np.eye(n)).astype(dtype)
    if kind == "corr_scaled":
        # scaled spatial correlation matrix (paper §2.2's motivating case)
        x = rng.standard_normal((n, n + 8))
        c = (x @ x.T) / n + 1e-3 * np.eye(n)
        d = 1.0 / np.sqrt(np.diag(c))
        return (c * d[:, None] * d[None, :] * 1e-8).astype(dtype)
    if kind == "pivot_adversarial":
        # rows of {~1e-10, ~2.01}: closest-to-1 pivoting overflows (§2.2)
        a = np.where(rng.random((n, n)) < 0.5, 1e-10, 2.01)
        a += np.diag(rng.random(n) * 3.0)
        return a.astype(dtype)
    raise ValueError(kind)
