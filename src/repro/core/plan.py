"""``repro.plan()`` — the compiled plan/execute log-determinant API.

The paper's pitch is picking the *right* algorithm for the scale at hand
(condensation vs Gaussian elimination vs ScaLAPACK vs the stochastic
estimators).  This module makes that decision happen **once**, at plan
time, the way ``jax.jit`` separates trace time from run time:

    p = repro.plan((4096, 4096), method="auto", mesh=mesh)
    res = p(a)              # LogdetResult: sign, logabsdet, sem, diagnostics
    res = p(a2)             # same compiled executable — no re-trace

A `LogdetPlan` is a frozen artifact holding

  * the problem spec (shape, batch, dtype, operator structure),
  * the *resolved* method — ``method="auto"`` runs the cost model below,
  * a validated typed config (`ExactConfig` | `ChebyshevConfig` |
    `SLQConfig` — see repro.core.configs) instead of a kwargs namespace,
  * the padding / sharding strategy, and
  * a pre-jitted callable (plus a lazily-built ``value_and_grad`` twin).

Every execution path returns the same `LogdetResult`; the legacy string
API (``repro.core.slogdet`` / ``logdet_batched``) survives as deprecated
shims over plans (see repro.core.api and docs/api.md for migration).

The cost model (`select_route` / `select_method`)
-------------------------------------------------
Inputs: N (and batch), the operator's `plan_hints()` (per-column matvec
FLOPs, materializability), the mesh device count, the requested accuracy
``rtol`` — and the **measured roofline calibration table**
(repro.core.calibration: sustained GEMM FLOP/s, streaming bandwidth, and
per-collective latency/bandwidth, produced by ``python -m
benchmarks.roofline --calibrate``).  Decision tree:

  1. operator input                          -> estimator family
     (only the matrix-free estimators run through the operator
     protocol; exact condensation needs the dense array itself);
  2. ``rtol`` < 1e-3 (more digits than Monte-Carlo noise allows at sane
     probe budgets)                          -> exact family;
  3. otherwise compare *modeled seconds* (not raw FLOPs): the best exact
     engine route vs ``(probe x step budget) x matvec_flops`` priced on
     the measured GEMM roofline; cheapest wins.  Because the mesh
     communication term (per-step collective latency + payload bytes)
     does not shrink with P, both the dense<->estimator and the
     serial<->mesh crossovers move with device count;
  4. family -> concrete route: the exact family resolves to an
     `EngineConfig` *tuple* (schedule x update x backend — e.g. staged x
     rank1 for small N, staged x panel once GEMMs amortize, mesh x panel
     when collectives pay for themselves); estimators pick ``chebyshev``
     when spectral bounds are already known, else ``slq``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import time
import warnings
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs

from repro.core.calibration import (
    Calibration, estimator_cost, exact_cost, load_calibration,
)
from repro.core.configs import (
    ChebyshevConfig, ESTIMATOR_METHODS, EXACT_METHODS, ExactConfig,
    LEGACY_EXACT_ROUTES, LogdetConfig, METHODS, SLQConfig,
    config_for, filter_for_method as _filter_for_method, validate_config,
)
from repro.core.engine import EngineConfig, LEGACY_ROUTES
from repro.core.result import Diagnostics, LogdetResult

__all__ = ["plan", "LogdetPlan", "ProblemSpec", "spec_of", "select_method",
           "select_route", "clear_plan_cache"]

# probe-budget the selector assumes when none is configured yet: the SLQ
# defaults (bounds-free, the conservative estimator choice)
_DEFAULT_EST_COLS = 25 * 32
# Monte-Carlo noise floor: below this requested rtol, estimators would need
# absurd probe counts (error ~ 1/sqrt(k)); the selector goes exact
_EST_RTOL_FLOOR = 1e-3
# spectral_bounds: 2 power iterations of 32 steps + 1 closing matvec each
_BOUNDS_COLS = 2 * (32 + 1)


def _is_tracer(x) -> bool:
    try:
        return isinstance(x, jax.core.Tracer)
    except AttributeError:  # pragma: no cover - future jax relocations
        return False


def _mark_trace(trace_log: list) -> None:
    """Record one trace of a compiled forward.  Runs inside the jitted
    body, i.e. exactly once per (re)trace — the second and later marks on
    one plan are retraces, the thing a spec-stable workload must not do."""
    trace_log.append(1)
    obs.inc("plan.traces")
    if len(trace_log) > 1:
        obs.inc("plan.retraces")


# --------------------------------------------------------------------------
# problem specification
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ProblemSpec:
    """What a plan is compiled *for* — everything shape-static.

    ``kind``          "dense" (n, n) | "batched" (B, n, n) | "operator"
    ``n``             matrix side
    ``batch``         leading stack size, or None
    ``dtype``         canonical dtype string ("float64", ...)
    ``structure``     operator structure tag from `plan_hints` ("dense",
                      "kron", "toeplitz", ...) — "dense"/"batched" for
                      array inputs
    ``matvec_flops``  FLOPs one matvec column costs (cost-model input)
    ``materializable`` whether exact O(n^3) methods can run on this input
    ``device_count``  devices the operator's own matvec spans
    """
    kind: str
    n: int
    batch: Optional[int]
    dtype: str
    structure: str
    matvec_flops: float
    materializable: bool = True
    device_count: int = 1


def _dense_spec(shape: Tuple[int, ...], dtype) -> ProblemSpec:
    if len(shape) == 2 and shape[0] == shape[1]:
        n, batch, kind = int(shape[0]), None, "dense"
    elif len(shape) == 3 and shape[1] == shape[2]:
        n, batch, kind = int(shape[1]), int(shape[0]), "batched"
    else:
        raise ValueError(
            f"expected square matrix (n, n) or stack (B, n, n), got {shape}")
    dtype = jnp.dtype(dtype) if dtype is not None else jnp.result_type(float)
    dtype = jax.dtypes.canonicalize_dtype(dtype)   # f64 -> f32 sans x64
    return ProblemSpec(kind=kind, n=n, batch=batch, dtype=str(dtype),
                       structure=kind, matvec_flops=2.0 * n * n,
                       materializable=True)


def spec_of(x, dtype=None) -> ProblemSpec:
    """Coerce an input — int N, shape tuple, array, operator, or an
    existing spec — into a `ProblemSpec` for planning."""
    if isinstance(x, ProblemSpec):
        return x
    from repro.estimators.operators import is_operator
    if is_operator(x):
        hints = x.plan_hints()
        return ProblemSpec(
            kind="operator", n=int(x.shape[-1]),
            batch=getattr(x, "batch", None), dtype=str(jnp.dtype(x.dtype)),
            structure=hints.structure, matvec_flops=float(hints.matvec_flops),
            materializable=bool(hints.materializable),
            device_count=int(hints.device_count))
    if isinstance(x, int):
        return _dense_spec((x, x), dtype)
    if isinstance(x, tuple):
        return _dense_spec(x, dtype)
    arr_dtype = getattr(x, "dtype", None)
    shape = getattr(x, "shape", None)
    if shape is None:
        raise TypeError(
            f"cannot plan for {type(x).__name__}; pass a size, shape tuple, "
            "array, stack, or LinearOperator")
    return _dense_spec(tuple(shape), dtype if dtype is not None else arr_dtype)


# --------------------------------------------------------------------------
# cost model
# --------------------------------------------------------------------------

# panel updates cannot amortize their triangular-solve bookkeeping below a
# few panels' worth of rows; the selector only offers them above this.
# The panel width itself comes from the calibration-driven tile autotuner
# (repro.kernels.autotune) so the selector prices the geometry the
# kernels actually run.
_PANEL_MIN_N_FACTOR = 4
# below this modeled exact wall time there is nothing worth trading:
# Monte-Carlo noise buys ~2-3 digits, so the estimator family only wins
# when exact condensation is actually expensive
_EXACT_FREE_SECONDS = 0.05


def select_route(x, *, mesh=None, axis_name: str = "rows",
                 rtol: Optional[float] = None,
                 bounds_known: bool = False,
                 est_cols: Optional[int] = None,
                 calibration: Optional[Calibration] = None,
                 precision: Optional[str] = None,
                 ) -> Tuple[str, Optional[EngineConfig]]:
    """Resolve ``method="auto"`` to a route **tuple**.

    Returns ``(method, engine_config)``: the estimator methods carry
    ``None`` (they have no engine axes); the exact family returns
    ``("exact", EngineConfig(schedule, update, panel_k, backend))`` — the
    cheapest engine instantiation under the measured calibration table
    (`repro.core.calibration.load_calibration` unless ``calibration`` is
    given), with ``panel_k`` resolved by the tile autotuner.
    ``precision="bf16"`` prices GEMM work at the bf16 calibration rate
    and restricts the search to the exact family (the mixed-precision
    route is an engine feature; estimators have no bf16 path).  Pure and
    cheap — call it directly to ask "what would the planner do" without
    building a plan.
    """
    spec = spec_of(x)
    devices = int(mesh.shape[axis_name]) if mesh is not None \
        else spec.device_count
    est_method = "chebyshev" if bounds_known else "slq"

    if spec.kind == "operator":
        # only the matrix-free estimators run on operator inputs (plan
        # rejects exact methods for them), whatever the cost comparison
        # says — `materializable` is advisory, not a dispatch route
        return est_method, None

    cal = calibration if calibration is not None else load_calibration()
    itemsize = jnp.dtype(spec.dtype).itemsize
    route, exact_t = _best_exact_route(spec, devices, cal, itemsize,
                                       precision=precision)

    if precision == "bf16":
        # the quantized-GEMM route only exists in the exact engine
        return "exact", route
    if rtol is not None and rtol < _EST_RTOL_FLOOR:
        return "exact", route

    cols = est_cols if est_cols is not None \
        else _DEFAULT_EST_COLS + _BOUNDS_COLS
    est_t = estimator_cost(spec.n, cols, spec.matvec_flops, devices, cal,
                           itemsize=itemsize, batch=spec.batch or 1)
    # estimators trade digits (and the sign) for time: only leave the
    # exact family when exact is both slow enough to care about AND
    # modeled slower than the estimator budget
    if exact_t <= _EXACT_FREE_SECONDS or exact_t <= est_t:
        return "exact", route
    return est_method, None


def select_method(x, *, mesh=None, axis_name: str = "rows",
                  rtol: Optional[float] = None,
                  bounds_known: bool = False,
                  est_cols: Optional[int] = None,
                  calibration: Optional[Calibration] = None) -> str:
    """The method name `select_route` resolves to (family-level answer)."""
    return select_route(x, mesh=mesh, axis_name=axis_name, rtol=rtol,
                        bounds_known=bounds_known, est_cols=est_cols,
                        calibration=calibration)[0]


def _best_exact_route(spec: ProblemSpec, devices: int, cal: Calibration,
                      itemsize: int, precision: Optional[str] = None,
                      ) -> Tuple[EngineConfig, float]:
    """Cheapest exact engine instantiation under the calibration table."""
    from repro.kernels.autotune import resolved_panel_k
    n, b = spec.n, spec.batch or 1
    tuned_k = resolved_panel_k(n, itemsize=itemsize, precision=precision,
                               cal=cal)
    if spec.batch is not None:
        # stacks run one matrix per device (vmapped serial schedule)
        candidates = [("serial", "rank1", 1, False),
                      ("serial", "panel", 1, False)]
    else:
        candidates = [("staged", "rank1", 1, False),
                      ("staged", "panel", 1, False)]
        if devices > 1:
            # each mesh route is offered plain and pipelined — lookahead
            # hides broadcast latency behind the bulk update, so the
            # serial<->mesh crossover moves left when its overhead term
            # is smaller than the hidden communication
            candidates += [("mesh", "rank1", devices, False),
                           ("mesh", "panel", devices, False),
                           ("mesh", "rank1", devices, True),
                           ("mesh", "panel", devices, True)]
    if n < _PANEL_MIN_N_FACTOR * tuned_k:
        candidates = [c for c in candidates if c[1] != "panel"]

    def cost_of(c):
        schedule, update, devs, la = c
        return exact_cost(n, devs, cal, update=update,
                          panel_k=tuned_k, itemsize=itemsize,
                          batch=b, lookahead=la, precision=precision)

    best = min(candidates, key=cost_of)
    schedule, update, devs, la = best
    return EngineConfig(schedule=schedule, update=update,
                        panel_k=tuned_k, lookahead=la,
                        precision=precision), cost_of(best)


def _flops_est(method: str, spec: ProblemSpec, cfg: LogdetConfig,
               devices: int) -> Tuple[Optional[int], float]:
    """(matvec_cols, flops_est) diagnostics for the resolved path."""
    b = spec.batch or 1
    if method in EXACT_METHODS:
        return None, b * (2.0 / 3.0) * spec.n ** 3 / devices
    if isinstance(cfg, ChebyshevConfig):
        cols = cfg.degree * cfg.num_probes
        if cfg.lmin is None or cfg.lmax is None:
            cols += _BOUNDS_COLS
    else:
        cols = min(cfg.num_steps, spec.n) * cfg.num_probes
    return cols, b * cols * spec.matvec_flops / devices


# --------------------------------------------------------------------------
# execution builders
# --------------------------------------------------------------------------

def _is_mesh_exact(method: str, cfg: LogdetConfig) -> bool:
    """Does this exact method distribute one matrix over a mesh?"""
    if method in ("pge", "plu"):
        return True
    return (method == "exact" and isinstance(cfg, ExactConfig)
            and cfg.schedule == "mesh")


def _serial_exact_core(method: str, cfg: ExactConfig) -> Callable:
    from repro.core import engine as _engine
    from repro.core import gaussian as _gaussian
    from repro.core.api import pad_to_multiple
    if method == "ge":
        return _gaussian.slogdet_ge
    if method == "exact":
        ecfg = cfg.engine_config()
        fn = _engine.build_serial(ecfg)
        if ecfg.update == "panel":
            # pad so every panel is full; diag(A, I) preserves the result
            k = ecfg.panel_k
            return lambda x: fn(pad_to_multiple(x, k))
        return fn
    raise AssertionError(method)


# parallel executables are expensive to build (shard_map closure + jit);
# plans share them through this cache — the successor of the lru_cache
# that used to sit on repro.core.api._parallel_fn
_KERNEL_CACHE: dict = {}


def _parallel_kernel(method: str, cfg: ExactConfig, mesh, axis_name: str):
    if method == "exact":
        key = ("engine", cfg.engine_config(), mesh, axis_name)
    else:
        key = (method, mesh, axis_name, cfg.nb)
    fn = _KERNEL_CACHE.get(key)
    if fn is None:
        from repro.core import engine as _engine
        from repro.core import gaussian as _gaussian
        from repro.core import scalapack as _scalapack
        if method == "exact":
            fn = _engine.build_mesh(cfg.engine_config(), mesh, axis_name)
        elif method == "pge":
            fn = _gaussian.parallel_slogdet_ge(mesh, axis_name)
        elif method == "plu":
            fn = _scalapack.parallel_slogdet_lu(mesh, axis_name, nb=cfg.nb)
        else:
            raise AssertionError(method)
        _KERNEL_CACHE[key] = fn
    return fn


def _widen_bounds_for_padding(kw: dict) -> dict:
    """diag(A, I) padding adds unit eigenvalues: user-supplied Chebyshev
    bounds must be widened to bracket 1, else T_j blows up outside [-1, 1]
    on the padded directions."""
    kw = dict(kw)
    if kw.get("lmin") is not None:
        kw["lmin"] = min(float(kw["lmin"]), 1.0)
    if kw.get("lmax") is not None:
        kw["lmax"] = max(float(kw["lmax"]), 1.0)
    return kw


def _build_forward(spec: ProblemSpec, method: str, cfg: LogdetConfig,
                   mesh, axis_name: str, dtype, trace_log: list):
    """(fwd, compiled, padded_n): fwd maps execution inputs to the raw
    ``(sign, logabsdet, sem)`` triple; ``compiled`` says whether fwd is a
    pre-jitted executable (vs an eager composition of cached inner jits)."""
    from repro.core.api import pad_to_multiple

    padded_n = spec.n

    if method in EXACT_METHODS:
        from repro.estimators.grad import exact_slogdet_vjp

        if _is_mesh_exact(method, cfg):
            if mesh is None:
                raise ValueError(f"method {method!r} requires a mesh")
            p = int(mesh.shape[axis_name])
            mult = int(np.lcm(p, cfg.nb)) if method == "plu" else p
            padded_n = -(-spec.n // mult) * mult if spec.n else 0
            pfn = _parallel_kernel(method, cfg, mesh, axis_name)
            wrapped = exact_slogdet_vjp(
                lambda x: pfn(pad_to_multiple(x, mult)))

            def fwd(a, key=None, probes=None):  # eager: pfn is jitted inside
                trace_log.append(1)
                a = jnp.asarray(a, dtype)
                s, ld = wrapped(a)
                return s, ld, jnp.zeros(ld.shape, ld.dtype)

            return fwd, False, padded_n

        if method == "exact" and cfg.update == "panel":
            padded_n = -(-spec.n // cfg.k) * cfg.k if spec.n else 0
        core = _serial_exact_core(method, cfg)
        wrapped = exact_slogdet_vjp(core)
        call = jax.vmap(wrapped) if spec.batch is not None else wrapped

        def fwd(a, key=None, probes=None):
            # body runs at trace time: the span measures staging cost
            with obs.span("plan.compile", cat="trace", method=method):
                _mark_trace(trace_log)
                a = jnp.asarray(a, dtype)
                s, ld = call(a)
                return s, ld, jnp.zeros(ld.shape, ld.dtype)

        return jax.jit(fwd), True, padded_n

    # ---------------------------------------------------------- estimators
    est_kw = cfg.estimator_kwargs()

    def _merge_bounds(base_kw, lmin, lmax, widen: bool):
        """Config bounds overridden by runtime (possibly traced) bounds;
        padding still widens the runtime values to bracket 1."""
        if lmin is None and lmax is None:
            return base_kw
        kw = dict(base_kw)
        if lmin is not None:
            kw["lmin"] = jnp.minimum(jnp.asarray(lmin, dtype), 1.0) \
                if widen else lmin
        if lmax is not None:
            kw["lmax"] = jnp.maximum(jnp.asarray(lmax, dtype), 1.0) \
                if widen else lmax
        return kw

    if spec.kind == "operator":
        # eager: the operator instance carries its own (cached) inner jits;
        # estimate_logdet handles registry lookup / probe sharing / VJPs
        def fwd(op, key=None, probes=None, lmin=None, lmax=None):
            from repro import estimators as _est
            trace_log.append(1)
            kw = _merge_bounds(est_kw, lmin, lmax, widen=False)
            res = _est.estimate_logdet(op, method=method, key=key,
                                       probes=probes, **kw)
            return jnp.ones(res.est.shape, res.est.dtype), res.est, res.sem

        return fwd, False, padded_n

    if mesh is not None:
        p = int(mesh.shape[axis_name])
        padded_n = -(-spec.n // p) * p if spec.n else 0
        padded = padded_n != spec.n
        pad_kw = _widen_bounds_for_padding(est_kw) if padded else est_kw

        def fwd(a, key=None, probes=None, lmin=None, lmax=None):
            # eager: ShardedOperator construction (device_put) inside
            from repro import estimators as _est
            trace_log.append(1)
            a = jnp.asarray(a, dtype)
            op = _est.ShardedOperator(pad_to_multiple(a, p), mesh, axis_name)
            kw = _merge_bounds(pad_kw, lmin, lmax, widen=padded)
            res = _est.estimate_logdet(op, method=method, key=key,
                                       probes=probes, **kw)
            return jnp.ones(res.est.shape, res.est.dtype), res.est, res.sem

        return fwd, False, padded_n

    def fwd(a, key=None, probes=None, lmin=None, lmax=None):
        from repro import estimators as _est
        # body runs at trace time: the span measures staging cost
        with obs.span("plan.compile", cat="trace", method=method):
            _mark_trace(trace_log)
            a = jnp.asarray(a, dtype)
            kw = _merge_bounds(est_kw, lmin, lmax, widen=False)
            res = _est.estimate_logdet(a, method=method, key=key,
                                       probes=probes, **kw)
            return jnp.ones(res.est.shape, res.est.dtype), res.est, res.sem

    return jax.jit(fwd), True, padded_n


def _build_value_and_grad(spec: ProblemSpec, method: str, cfg: LogdetConfig,
                          mesh, axis_name: str, dtype, fwd):
    """vag(x, key) -> ((sign, logabsdet, sem), grad, cg_iters|None).

    The gradient of ``logabsdet`` (summed over the batch for stacks) with
    respect to the input — the dense matrix entries, or the operator's own
    parameters for structured inputs.  ``fwd`` is the plan's OWN compiled
    forward (shared, so building the backward never re-traces it).
    Estimator paths run the Hutchinson pullback explicitly (same probes as
    the forward, one transposed CG solve) so the solve's iteration count
    surfaces as a diagnostic instead of vanishing inside a custom-VJP
    rule.
    """
    from repro.core.api import pad_to_multiple

    if method in EXACT_METHODS:
        def vag(a, key=None):
            # mirror __call__'s kwarg structure so the jit cache is shared
            out = fwd(a, key=None, probes=None)
            a = jnp.asarray(a, dtype)
            if a.shape[-1] == 0:
                return out, jnp.zeros_like(a), None
            # one batched LAPACK inverse — the analytic pullback A^{-T}
            bar = jnp.swapaxes(jnp.linalg.inv(a), -1, -2).astype(a.dtype)
            return out, bar, None

        return vag

    est_kw = cfg.estimator_kwargs()
    probe_kw = {"num_probes": cfg.num_probes}
    if isinstance(cfg, ChebyshevConfig):
        probe_kw["probe_kind"] = cfg.probe_kind
    # bounds widening must mirror the forward exactly: only when the mesh
    # embedding actually padded (diag(A, I) adds unit eigenvalues)
    pad_widens = False
    if mesh is not None and spec.kind != "operator":
        pad_widens = spec.n % int(mesh.shape[axis_name]) != 0

    def vag(x, key=None):
        from repro import estimators as _est
        from repro.estimators.grad import (
            hutchinson_pullback, operator_grad_info, shared_probes,
        )
        if spec.kind != "operator":
            x = jnp.asarray(x, dtype)
            if mesh is not None:
                p = int(mesh.shape[axis_name])
                x = pad_to_multiple(x, p)
                op = _est.ShardedOperator(x, mesh, axis_name)
            else:
                op = _est.as_operator(x)
        else:
            op = x
        info = operator_grad_info(op)
        if info is None:
            raise TypeError(
                f"value_and_grad needs a grad-registered operator; "
                f"{type(op).__name__} has no registration (see "
                "repro.estimators.register_operator_grad)")
        if key is None:
            key = jax.random.PRNGKey(cfg.seed)
        probes = shared_probes(method, op, key, probe_kw)
        kw = _widen_bounds_for_padding(est_kw) if pad_widens else est_kw
        res = _est.estimate_logdet(op, method=method, key=key,
                                   probes=probes, **kw)
        g = jnp.ones(res.est.shape, res.est.dtype)
        bar, cg = hutchinson_pullback(
            op, info.params(op), probes, g, info=info,
            cg_tol=cfg.grad_cg_tol, cg_maxiter=cfg.grad_cg_maxiter)
        if mesh is not None and spec.kind != "operator":
            # d logdet(diag(A, I))/dA is exactly the A-block of the padded
            # pullback; the identity block's cotangent is discarded
            bar = bar[..., :spec.n, :spec.n]
        sign = jnp.ones(res.est.shape, res.est.dtype)
        return (sign, res.est, res.sem), bar, cg.iters

    return vag


# --------------------------------------------------------------------------
# the plan artifact
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class LogdetPlan:
    """A compiled log-determinant computation: spec + typed config +
    resolved strategy + pre-jitted executable.  Build with `repro.plan`;
    call with data; reuse freely — repeated calls with spec-matching
    inputs hit the jit cache, never re-trace.
    """
    spec: ProblemSpec
    method: str                     # resolved (never "auto")
    config: LogdetConfig
    mesh: Any = None
    axis_name: str = "rows"
    grad: bool = False
    validate: bool = True
    compiled: bool = field(default=True)
    diagnostics: Diagnostics = field(default_factory=Diagnostics)
    _fwd: Callable = field(default=None, repr=False, compare=False)
    _trace_log: list = field(default_factory=list, repr=False, compare=False)
    _cache: dict = field(default_factory=dict, repr=False, compare=False)
    _bound: Any = field(default=None, repr=False, compare=False)

    # -------------------------------------------------------------- exec

    def __call__(self, a=None, *, key=None, probes=None,
                 lmin=None, lmax=None) -> LogdetResult:
        """Execute the plan -> `LogdetResult`.

        ``a`` may be omitted when the plan was built from a concrete array
        or operator (it stays bound to the plan).  ``key``/``probes``/
        ``lmin``/``lmax`` are estimator-only runtime inputs — fresh
        randomness or traced spectral bounds without recompiling.
        """
        x = self._input(a)
        self._check(x, key, probes, lmin, lmax)
        traced = any(_is_tracer(v) for v in (x, key, probes, lmin, lmax))
        tele = not traced and obs.trace_enabled()
        if tele:
            # isolate this execution's telemetry from earlier buffered
            # streams (direct estimator calls, interleaved plans)
            obs.flush_telemetry()
            obs.drain_telemetry()
        t0 = None if traced else time.perf_counter()
        span = contextlib.nullcontext() if traced else \
            obs.span("plan.execute", method=self.method)
        with span:
            if self.method in EXACT_METHODS:
                sign, ld, sem = self._fwd(x, key=None, probes=None)
            else:
                sign, ld, sem = self._fwd(x, key=key, probes=probes,
                                          lmin=lmin, lmax=lmax)
            diags = self.diagnostics
            if not traced:
                jax.block_until_ready(ld)
                wall = time.perf_counter() - t0
                conv = None
                if tele:
                    obs.flush_telemetry()
                    conv = obs.drain_telemetry() or None
                    if conv:
                        self._cache["last_convergence"] = conv
                diags = dataclasses.replace(
                    diags, wall_time_s=wall, convergence=conv)
                obs.inc("plan.executions", method=self.method)
                if self.method in ESTIMATOR_METHODS:
                    obs.inc("estimator.probes", self.config.num_probes)
        return LogdetResult(sign=sign, logabsdet=ld, sem=sem,
                            method_used=self.method, diagnostics=diags)

    def slogdet(self, a=None, *, key=None, probes=None, lmin=None,
                lmax=None):
        """Raw ``(sign, logabsdet)`` pair — the legacy-shim entry point.

        Skips input validation and diagnostics assembly: exactly the
        compiled computation, nothing else.
        """
        x = self._input(a)
        if self.method in EXACT_METHODS:
            sign, ld, _ = self._fwd(x, key=key, probes=probes)
        else:
            sign, ld, _ = self._fwd(x, key=key, probes=probes,
                                    lmin=lmin, lmax=lmax)
        return sign, ld

    def logdet(self, a=None, *, key=None, probes=None, lmin=None,
               lmax=None) -> jax.Array:
        """``log|det|`` alone — differentiable, jit/vmap-composable."""
        return self.slogdet(a, key=key, probes=probes, lmin=lmin,
                            lmax=lmax)[1]

    def value_and_grad(self, a=None, *, key=None):
        """Execute forward AND backward -> ``(LogdetResult, grad)``.

        ``grad`` is d(sum of logabsdet)/d(input): matrix-shaped for dense
        input, parameter-shaped for structured operators (Kronecker
        factors, Toeplitz column/row, stencil bands).  Estimator plans
        report the backward CG solve's iteration count in
        ``result.diagnostics.cg_iters``.
        """
        x = self._input(a)
        self._check(x, key, None)
        traced = _is_tracer(x) or _is_tracer(key)
        tele = not traced and obs.trace_enabled()
        if tele:
            obs.flush_telemetry()
            obs.drain_telemetry()
        t0 = None if traced else time.perf_counter()
        span = contextlib.nullcontext() if traced else \
            obs.span("plan.backward", method=self.method)
        with span:
            vag = self._cache.get("vag")
            if vag is None:
                vag = _build_value_and_grad(
                    self.spec, self.method, self.config, self.mesh,
                    self.axis_name, jnp.dtype(self.spec.dtype), self._fwd)
                self._cache["vag"] = vag
            (sign, ld, sem), bar, cg_iters = vag(x, key=key)
            diags = self.diagnostics
            if not traced:
                jax.block_until_ready(bar)
                wall = time.perf_counter() - t0
                conv = None
                if tele:
                    obs.flush_telemetry()
                    conv = obs.drain_telemetry() or None
                    if conv:
                        self._cache["last_convergence"] = conv
                iters = None if cg_iters is None else int(cg_iters)
                if iters is not None:
                    obs.observe("cg.iters", iters, method=self.method)
                diags = dataclasses.replace(
                    diags, wall_time_s=wall, cg_iters=iters, convergence=conv)
        result = LogdetResult(sign=sign, logabsdet=ld, sem=sem,
                              method_used=self.method, diagnostics=diags)
        return result, bar

    # ----------------------------------------------------------- helpers

    @property
    def trace_count(self) -> int:
        """Times the forward computation has been traced (compiled plans)
        or executed (eager mesh/operator plans).  A spec-stable workload
        through a compiled plan holds this at 1."""
        return len(self._trace_log)

    def audit(self, passes=None, include_grad: bool = False):
        """Statically audit this plan's lowering -> `AuditReport`.

        Lowers a fresh forward (and, with ``include_grad``, the backward)
        at the plan's avals and runs the registered IR checker passes:
        no dense factorizations on matrix-free paths, no host callbacks
        with observability off, collective payloads within their analytic
        bounds, dtype discipline, and stage coverage.  Never executes or
        re-traces the live plan.  See docs/analysis.md.
        """
        from repro.analysis.audit import audit_plan
        return audit_plan(self, pass_ids=passes, include_grad=include_grad)

    def export(self, path: str) -> str:
        """AOT-serialize this plan's compiled forward to ``path``.

        The artifact carries a device-fingerprint header and replays
        bit-identically via `repro.load_plan` in any matching process —
        with zero traces and zero compiles at load or request time.
        Only compiled, non-operator plans are exportable; see
        repro.serve.aot for the full contract.
        """
        from repro.serve.aot import export_plan
        return export_plan(self, path)

    def explain(self) -> str:
        """Human-readable report of what this plan resolved to and what
        it has observed: route, modeled cost, trace/retrace state, and —
        after an execution under ``REPRO_OBS=trace`` — the most recent
        convergence telemetry.  Purely observational; no device work.
        """
        spec, d = self.spec, self.diagnostics
        shape = f"n={spec.n}" if spec.batch is None \
            else f"batch={spec.batch} n={spec.n}"
        lines = [
            f"LogdetPlan[{self.method}]",
            f"  spec: {spec.kind} {shape} dtype={spec.dtype} "
            f"structure={spec.structure}",
            f"  config: {self.config}",
            f"  execution: {'compiled (jit)' if self.compiled else 'eager'}"
            f", devices={d.device_count}"
            + (f", padded {spec.n} -> {d.padded_n}"
               if d.padded_n not in (None, spec.n) else ""),
            f"  traces: {self.trace_count}"
            + ("" if not self.compiled or self.trace_count <= 1
               else f"  (RETRACED {self.trace_count - 1}x — check input "
                    f"shapes/dtypes)"),
            f"  modeled cost: flops_est={d.flops_est:.3g}"
            + (f", matvec_cols={d.matvec_cols}"
               if d.matvec_cols is not None else "")
            + (f", backward cg_iters={d.cg_iters}"
               if d.cg_iters is not None else ""),
        ]
        if self.method == "exact" and isinstance(self.config, ExactConfig):
            from repro.kernels.autotune import tile_config
            prec = self.config.precision
            tiles = tile_config(spec.n,
                                itemsize=jnp.dtype(spec.dtype).itemsize,
                                precision=prec)
            lines.insert(3, f"  precision: {prec or 'native'}"
                         + (" (bf16 GEMM operands, full-precision "
                            "accumulators)" if prec == "bf16" else ""))
            lines.insert(4, f"  tiles[{tiles.source}]: "
                         f"panel_k={self.config.k} "
                         f"(autotuned {tiles.panel_k}), "
                         f"block={tiles.block_m}x{tiles.block_n}")
        conv = self._cache.get("last_convergence")
        if conv:
            lines.append("  last convergence (REPRO_OBS=trace):")
            for name, vals in sorted(conv.items()):
                finite = [v for v in vals if math.isfinite(v)]
                final = f"{finite[-1]:.3g}" if finite else "n/a"
                lines.append(
                    f"    {name}: {len(vals)} points, final {final}")
        elif obs.trace_enabled() and self.method not in EXACT_METHODS:
            lines.append("  last convergence: none recorded yet "
                         "(execute the plan first)")
        if obs.metrics_enabled():
            hits = obs.counter_value("plan.cache.hits")
            misses = obs.counter_value("plan.cache.misses")
            lines.append(f"  obs[{obs.mode()}]: plan cache "
                         f"{hits:g} hits / {misses:g} misses "
                         f"(process-wide)")
        else:
            lines.append("  obs: off (set REPRO_OBS=metrics|trace for "
                         "counters and convergence telemetry)")
        return "\n".join(lines)

    def _input(self, a):
        if a is None:
            a = self._bound
        if a is None:
            raise TypeError(
                "this plan was built from a shape spec; pass the matrix "
                "(or operator) to execute on")
        if self.spec.kind != "operator":
            shape = tuple(getattr(a, "shape", ()))
            want = ((self.spec.n, self.spec.n) if self.spec.batch is None
                    else (self.spec.batch, self.spec.n, self.spec.n))
            if shape != want:
                raise ValueError(
                    f"plan was compiled for shape {want}, got {shape}")
        return a

    def _check(self, x, key, probes, lmin=None, lmax=None):
        if self.method in EXACT_METHODS:
            if any(v is not None for v in (key, probes, lmin, lmax)):
                raise TypeError(f"exact method {self.method!r} takes no "
                                "key/probes/bounds")
            return
        if (self.validate and self.spec.kind != "operator"
                and not _is_tracer(x)):
            _validate_spd_like(x, self.method)


def _validate_spd_like(a, method: str):
    """Necessary-condition SPD screen for dense inputs routed to
    estimators: symmetry and a positive diagonal — catches the
    silent-garbage case (estimators compute tr(log A), which is
    meaningless for non-SPD input) with a clear error instead.  Runs as
    O(n^2) reductions on-device; only the three scalars cross to host."""
    x = jnp.asarray(a)
    if x.size == 0:
        return
    stats = jnp.stack([jnp.max(jnp.abs(x)),
                       jnp.max(jnp.abs(x - jnp.swapaxes(x, -1, -2))),
                       jnp.min(jnp.diagonal(x, axis1=-2, axis2=-1))])
    scale, asym, dmin = (float(v) for v in np.asarray(stats))  # ONE sync
    scale = scale or 1.0
    # sqrt(eps) * scale: far above accumulated GEMM rounding asymmetry of
    # symmetric products (~n*eps), far below any structural asymmetry
    tol = float(np.sqrt(jnp.finfo(x.dtype).eps)) * scale
    if asym > tol:
        raise ValueError(
            f"estimator method {method!r} computes tr(log A) and assumes "
            f"symmetric positive-definite input, but the matrix is not "
            f"symmetric (max |A - A^T| = {asym:.3g}). Use an exact method "
            f"('mc', 'ge', 'pmc', ...) for general matrices, pass "
            f"validate=False to repro.plan to skip this check, or "
            f"symmetrize the input.")
    if dmin <= 0:
        raise ValueError(
            f"estimator method {method!r} assumes positive-definite input, "
            f"but the diagonal has non-positive entries (min = {dmin:.3g}) "
            f"— tr(log A) is undefined. Use an exact method for indefinite "
            f"matrices, or pass validate=False to repro.plan to skip this "
            f"check.")


# --------------------------------------------------------------------------
# the factory + plan cache
# --------------------------------------------------------------------------

_PLAN_CACHE: "OrderedDict[tuple, LogdetPlan]" = OrderedDict()
_PLAN_CACHE_SIZE = 128


def clear_plan_cache():
    """Drop all cached plans and parallel kernels (test/debug hook)."""
    _PLAN_CACHE.clear()
    _KERNEL_CACHE.clear()


def plan(x, *, method: str = "auto", mesh=None, axis_name: str = "rows",
         precision=None, grad: bool = False, config: LogdetConfig = None,
         validate: bool = True, rtol: Optional[float] = None,
         **kwargs) -> LogdetPlan:
    """Compile a log-determinant plan for a problem shape.

    ``x``          what to plan for: an int N, a shape tuple ``(n, n)`` /
                   ``(B, n, n)``, a concrete array or stack, a
                   `LinearOperator`, or a `ProblemSpec`.  Concrete inputs
                   stay bound to the plan, so ``plan(a)()`` works.
    ``method``     a method name, or ``"auto"`` to run the cost model
                   (`select_method`) over N, structure, devices and
                   ``rtol``.
    ``mesh``       1-D device mesh for the distributed paths (parallel
                   condensation / row-sharded estimator matvecs).
    ``precision``  dtype override (e.g. ``"float32"``); inputs are cast.
                   ``"bf16"``/``"bfloat16"`` is different: it selects the
                   mixed-precision ENGINE route (bf16 GEMM operands,
                   full-precision accumulators — exact family only); the
                   input dtype is untouched.
    ``grad``       pre-build the ``value_and_grad`` executable now rather
                   than on first use.
    ``config``     an explicit typed config (`ExactConfig` |
                   `ChebyshevConfig` | `SLQConfig`) — mutually exclusive
                   with per-method ``**kwargs`` and with ``method="auto"``.
    ``validate``   screen dense estimator inputs for symmetry / positive
                   diagonal at call time (skipped under tracing).
    ``rtol``       requested relative accuracy — steers the auto-selector
                   (below 1e-3 only exact methods qualify).
    ``**kwargs``   per-method knobs, validated into the typed config
                   (``degree=...``, ``num_probes=...``, ``k=...``, ...).
                   With ``method="auto"`` the estimator knobs also inform
                   the cost estimate; knobs belonging to the family the
                   selector did NOT pick are dropped (exact is at least
                   as accurate), while names no method defines still
                   raise.

    Returns a `LogdetPlan`.  Plans for dense/batched specs are cached:
    equal spec + method + config + mesh reuse one compiled executable
    (this cache is what makes the deprecated string API non-retracing).
    """
    engine_precision = None
    if precision in ("bf16", "bfloat16"):
        # mixed-precision engine route, NOT a storage-dtype cast: the
        # buffer and all sign/parity/log accumulators keep the input
        # dtype; only GEMM/outer operands are quantized (docs/api.md)
        engine_precision = "bf16"
        precision = None
    spec = spec_of(x, dtype=precision)
    if (precision is not None or engine_precision is not None) \
            and spec.kind == "operator":
        raise ValueError("precision overrides apply to array inputs; "
                         "cast the operator's parameters instead")
    if precision is not None:
        spec = dataclasses.replace(spec, dtype=str(jnp.dtype(precision)))

    if mesh is not None and spec.batch is not None:
        raise TypeError(
            "mesh sharding applies to a single (n, n) matrix; batched "
            "stacks run one device per matrix — drop mesh, or map a "
            "single-matrix plan over the stack")

    if method == "auto":
        if config is not None:
            raise ValueError(
                "method='auto' with an explicit config is ambiguous — the "
                "config pins the method family; pass the method name")
        bounds_known = (kwargs.get("lmin") is not None
                        and kwargs.get("lmax") is not None)
        probes = kwargs.get("num_probes", 32)
        est_cols = (kwargs.get("degree", 64) * probes if bounds_known
                    else kwargs.get("num_steps", 25) * probes + _BOUNDS_COLS)
        method, route = select_route(spec, mesh=mesh, axis_name=axis_name,
                                     rtol=rtol, bounds_known=bounds_known,
                                     est_cols=est_cols,
                                     precision=engine_precision)
        # the resolved family keeps its own knobs; the other family's are
        # dropped (typo-only names still raise inside the filter)
        kwargs = _filter_for_method(method, kwargs)
        if route is not None:
            # the selector's engine tuple, user-supplied axes winning;
            # panel_k is the autotuned width exact_cost priced, so auto
            # RUNS the geometry it modeled
            kwargs.setdefault("schedule", route.schedule)
            kwargs.setdefault("update", route.update)
            kwargs.setdefault("k", route.panel_k)
            if route.schedule == "mesh":
                kwargs.setdefault("lookahead", route.lookahead)
    elif method in LEGACY_EXACT_ROUTES:
        schedule, update = LEGACY_ROUTES[method]
        warnings.warn(
            f"exact route string {method!r} is deprecated: it is the "
            f"engine instantiation method='exact', schedule={schedule!r}, "
            f"update={update!r} — request that directly (docs/api.md has "
            f"the route matrix)", DeprecationWarning, stacklevel=2)
        if config is not None:
            config = validate_config(method, config)
            for axis, val in (("schedule", schedule), ("update", update)):
                got = getattr(config, axis)
                if got not in (None, val):
                    raise TypeError(
                        f"route {method!r} pins {axis}={val!r} but the "
                        f"config says {got!r}; use method='exact' to "
                        f"choose engine axes freely")
            config = dataclasses.replace(config, schedule=schedule,
                                         update=update)
        else:
            for axis, val in (("schedule", schedule), ("update", update)):
                if kwargs.get(axis, val) != val:
                    raise TypeError(
                        f"route {method!r} pins {axis}={val!r}; got "
                        f"{kwargs[axis]!r} — use method='exact' to choose "
                        f"engine axes freely")
            kwargs["schedule"] = schedule
            kwargs["update"] = update
        method = "exact"
    elif method not in METHODS:
        raise ValueError(
            f"unknown method {method!r}; choose from {METHODS} or 'auto'")

    if config is not None:
        if kwargs:
            raise TypeError(
                f"pass knobs either via config= or keywords, not both "
                f"(got config and {sorted(kwargs)})")
        cfg = validate_config(method, config)
    else:
        cfg = config_for(method, kwargs)
    if engine_precision is not None:
        if method != "exact":
            raise ValueError(
                f"precision='bf16' is the condensation engine's "
                f"mixed-precision route; method {method!r} has no "
                "quantized-GEMM path (use method='exact' or 'auto')")
        got = cfg.precision
        if got not in (None, engine_precision):
            raise ValueError(
                f"precision='bf16' conflicts with config precision "
                f"{got!r}")
        cfg = dataclasses.replace(cfg, precision=engine_precision)
    if method == "exact":
        cfg = cfg.resolved(mesh_present=mesh is not None)

    if spec.kind == "operator":
        if method not in ESTIMATOR_METHODS:
            raise TypeError(
                f"method {method!r} needs a materialized matrix; operator "
                f"inputs require an estimator method "
                f"{sorted(ESTIMATOR_METHODS)}")
        if mesh is not None:
            raise TypeError("operator inputs carry their own distribution; "
                            "mesh is only accepted for dense array inputs")

    if _is_mesh_exact(method, cfg):
        if spec.batch is not None:
            raise TypeError(
                f"method {method!r} (mesh schedule) distributes ONE matrix "
                "over the mesh; batched stacks need a serial or staged "
                "schedule — map a single-matrix plan over the stack instead")
        if mesh is None:
            raise ValueError(
                "engine schedule 'mesh' requires a mesh" if method == "exact"
                else f"method {method!r} requires a mesh")

    cache_key = None
    if spec.kind != "operator":
        # validate is call-time behavior, not part of the compiled artifact
        cache_key = (spec, method, cfg, mesh, axis_name)
        cached = _PLAN_CACHE.get(cache_key)
        obs.inc("plan.cache.hits" if cached is not None
                else "plan.cache.misses")
        if cached is not None:
            _PLAN_CACHE.move_to_end(cache_key)
            if grad and "vag" not in cached._cache:
                # honor the prebuild contract on cache hits too
                cached._cache["vag"] = _build_value_and_grad(
                    spec, method, cfg, mesh, axis_name,
                    jnp.dtype(spec.dtype), cached._fwd)
            if cached.validate != validate or cached.grad != grad:
                cached = dataclasses.replace(cached, validate=validate,
                                             grad=grad)
            return _bind(cached, x)

    # diagnostics must reflect the EXECUTION: a supplied mesh only spans
    # devices for routes that actually distribute (mesh-schedule exact,
    # sharded estimator matvecs) — a serial route picked by the selector
    # despite a mesh runs on one device
    if mesh is not None and (_is_mesh_exact(method, cfg)
                             or method in ESTIMATOR_METHODS):
        devices = int(mesh.shape[axis_name])
    else:
        devices = spec.device_count
    with obs.span("plan.build", method=method, n=spec.n):
        trace_log: list = []
        dtype = jnp.dtype(spec.dtype)
        fwd, compiled, padded_n = _build_forward(
            spec, method, cfg, mesh, axis_name, dtype, trace_log)
        cols, flops = _flops_est(method, spec, cfg, devices)
        p = LogdetPlan(
            spec=spec, method=method, config=cfg, mesh=mesh,
            axis_name=axis_name, grad=grad, validate=validate,
            compiled=compiled,
            diagnostics=Diagnostics(matvec_cols=cols, flops_est=flops,
                                    padded_n=padded_n, device_count=devices),
            _fwd=fwd, _trace_log=trace_log)
        if grad:
            p._cache["vag"] = _build_value_and_grad(
                spec, method, cfg, mesh, axis_name, dtype, fwd)
    obs.set_gauge("plan.flops_est", flops, method=method)
    if cache_key is not None:
        _PLAN_CACHE[cache_key] = p
        while len(_PLAN_CACHE) > _PLAN_CACHE_SIZE:
            _PLAN_CACHE.popitem(last=False)
    return _bind(p, x)


def _bind(p: LogdetPlan, x) -> LogdetPlan:
    """Attach a concrete input to a (possibly shared) plan instance."""
    from repro.estimators.operators import is_operator
    concrete = (is_operator(x)
                or (hasattr(x, "shape") and not isinstance(x, ProblemSpec)
                    and not _is_tracer(x)))
    if not concrete:
        return p
    return dataclasses.replace(p, _bound=x)
