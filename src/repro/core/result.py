"""The unified result type every log-determinant path returns.

Before the plan API, each path returned its own shape: exact methods a
``(sign, logabsdet)`` pair, estimators a `TraceEstimate`, batched calls a
bare array.  `LogdetResult` unifies them — one container carrying the
value, its Monte-Carlo uncertainty (exactly zero for exact methods), the
method the plan actually ran (which matters when ``method="auto"``
resolved it), and execution diagnostics.

``sign`` and ``logabsdet`` follow ``numpy.linalg.slogdet`` semantics, with
a leading batch axis for stack plans.  Tuple unpacking is supported for
drop-in migration from the old pair return::

    sign, logabsdet = plan(a)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax

__all__ = ["LogdetResult", "Diagnostics"]


@dataclass(frozen=True)
class Diagnostics:
    """Where the time went and what the plan actually executed.

    ``matvec_cols``   operator matvec *columns* the forward pass consumes
                      (probes x polynomial/Lanczos steps, plus the power-
                      iteration bounds bracket) — the estimator cost unit;
                      None for exact methods, whose cost is ``flops_est``.
    ``flops_est``     dense-equivalent FLOP estimate of the path (the
                      number the auto-selector compared against).
    ``cg_iters``      inner CG iterations of the most recent gradient
                      pullback through this plan; None until a
                      ``value_and_grad`` execution runs one.
    ``wall_time_s``   host-side wall time of this execution, including
                      device sync; None when the plan ran under a trace
                      (inside jit/grad/vmap, where timing is meaningless).
    ``padded_n``      problem size after `pad_to_multiple` embedding
                      (== n when no padding was needed).
    ``device_count``  devices the execution spanned (mesh size, else 1).
    ``convergence``   convergence telemetry streams from this execution
                      (``{"slq.sem": [...], "cg.resnorm": [...]}``) —
                      populated only under ``REPRO_OBS=trace``, else
                      None.  See docs/observability.md.
    """
    matvec_cols: Optional[int] = None
    flops_est: Optional[float] = None
    cg_iters: Optional[int] = None
    wall_time_s: Optional[float] = None
    padded_n: Optional[int] = None
    device_count: int = 1
    convergence: Optional[Dict[str, List[float]]] = field(
        default=None, compare=False)


@dataclass(frozen=True)
class LogdetResult:
    """Sign, log|det|, uncertainty and provenance of one plan execution.

    ``sem`` is the standard error of the Monte-Carlo mean for estimator
    methods and exactly zero for exact methods — always present, so
    downstream code can treat every path uniformly (``est +- sem``).
    """
    sign: jax.Array
    logabsdet: jax.Array
    sem: jax.Array
    method_used: str
    diagnostics: Diagnostics

    def __iter__(self):
        """Unpack like the legacy pair: ``sign, logabsdet = result``."""
        return iter((self.sign, self.logabsdet))

    def __repr__(self):  # compact: arrays render as scalars for 0-d
        return (f"LogdetResult(sign={self.sign}, "
                f"logabsdet={self.logabsdet}, sem={self.sem}, "
                f"method_used={self.method_used!r})")
