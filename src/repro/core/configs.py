"""Typed per-method configuration for the plan API.

Each log-determinant method family gets one frozen dataclass holding every
knob it understands, validated at construction — replacing the flat
``**kwargs`` namespace the string API used to thread through dispatch.  A
config is hashable (all fields are static Python values), so it can key
the plan cache: two ``repro.plan`` calls with equal specs and equal
configs share one compiled executable.

Runtime *arrays* — PRNG ``key``, pre-drawn ``probes``, traced spectral
bounds — are deliberately NOT config fields: they are execution inputs,
passed to the plan call itself, so changing them never invalidates a
compiled plan.

  ExactConfig      mc / mc_staged / mc_blocked / ge / pmc / pmc_blocked /
                   pge / plu — panel width ``k``, block-cyclic tile ``nb``
  ChebyshevConfig  stochastic Chebyshev (Han et al.): degree, probe budget,
                   optional spectral bounds, backward-CG knobs
  SLQConfig        stochastic Lanczos quadrature (Ubaru et al.): Lanczos
                   steps, probe budget, backward-CG knobs

`config_for` maps legacy keyword soup onto the right dataclass and is the
single place the shim layer (`repro.core.api`) translates old calls.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Union

from repro.core.engine import (
    BACKENDS as _ENGINE_BACKENDS, EngineConfig, LEGACY_ROUTES,
    SCHEDULES as _ENGINE_SCHEDULES, UPDATES as _ENGINE_UPDATES,
)

__all__ = [
    "ExactConfig", "ChebyshevConfig", "SLQConfig", "LogdetConfig",
    "EngineConfig", "config_for", "config_to_dict", "config_from_dict",
    "EXACT_METHODS", "ESTIMATOR_METHODS",
    "PARALLEL_METHODS", "METHODS", "LEGACY_EXACT_ROUTES",
]

# "exact" is the unified condensation engine (schedule x update x backend,
# see repro.core.engine); the five legacy route strings are deprecated
# aliases for fixed engine tuples; ge/pge/plu are the comparison baselines
LEGACY_EXACT_ROUTES = tuple(LEGACY_ROUTES)
EXACT_METHODS = ("exact",) + LEGACY_EXACT_ROUTES + ("ge", "pge", "plu")
PARALLEL_METHODS = ("pmc", "pmc_blocked", "pge", "plu")
ESTIMATOR_METHODS = ("chebyshev", "slq")
METHODS = EXACT_METHODS + ESTIMATOR_METHODS

# every keyword the estimator family understands — used to phrase the
# "exact method got estimator keywords" error precisely
_ESTIMATOR_KW = frozenset({
    "num_probes", "degree", "num_steps", "seed", "lmin", "lmax",
    "probe_kind", "grad_cg_tol", "grad_cg_maxiter", "key", "probes",
})


def _require(cond: bool, msg: str):
    if not cond:
        raise ValueError(msg)


@dataclass(frozen=True)
class ExactConfig:
    """Knobs of the exact O(N^3) condensation / elimination family.

    The condensation engine's three axes (``method="exact"``):

    ``schedule`` — "serial" | "staged" | "mesh"; ``None`` resolves at plan
                   time ("mesh" when a mesh is supplied, else "staged").
    ``update``   — "rank1" | "panel"; ``None`` resolves to "rank1".
    ``backend``  — "auto" | "xla" | "pallas" kernel backend.
    ``k``        — panel width of the rank-K update.
    ``shrink``/``min_size`` — staged-schedule geometry.
    ``lookahead`` — mesh-only: pipeline the next pivot row / panel so its
                   broadcast overlaps the current bulk update
                   (bit-identical results; see `engine.EngineConfig`).
                   Requires ``schedule`` unset (mesh resolves when a mesh
                   is present) or explicitly ``"mesh"``.
    ``fused``    — serial/staged-only: one-pass condensation steps and a
                   composed-permutation gather for the panel swaps
                   (bit-identical results; see `engine.EngineConfig`).
    ``precision`` — ``None`` (native) or ``"bf16"``: quantize GEMM /
                   outer-product operands to bfloat16; the buffer and
                   every sign/parity/log accumulator stay in the input
                   dtype (error model in docs/api.md).

    Baseline-only knob: ``nb`` — block-cyclic tile size of the
    ScaLAPACK-style LU (``plu``).  Methods that do not use a knob ignore
    it, so one config class serves every exact method.
    """
    k: int = 32
    nb: int = 1
    schedule: Optional[str] = None
    update: Optional[str] = None
    backend: str = "auto"
    shrink: float = 0.75
    min_size: int = 64
    lookahead: bool = False
    fused: bool = False
    precision: Optional[str] = None

    def __post_init__(self):
        _require(int(self.k) >= 1, f"k must be >= 1, got {self.k}")
        _require(int(self.nb) >= 1, f"nb must be >= 1, got {self.nb}")
        _require(self.schedule is None or self.schedule in _ENGINE_SCHEDULES,
                 f"unknown schedule {self.schedule!r}; "
                 f"one of {_ENGINE_SCHEDULES}")
        _require(self.update is None or self.update in _ENGINE_UPDATES,
                 f"unknown update {self.update!r}; one of {_ENGINE_UPDATES}")
        _require(self.backend in _ENGINE_BACKENDS,
                 f"unknown backend {self.backend!r}; "
                 f"one of {_ENGINE_BACKENDS}")
        _require(0.0 < float(self.shrink) < 1.0,
                 f"shrink must be in (0, 1), got {self.shrink}")
        _require(int(self.min_size) >= 2,
                 f"min_size must be >= 2, got {self.min_size}")
        _require(not self.lookahead or self.schedule in (None, "mesh"),
                 "lookahead pipelines the mesh schedule's broadcast; it "
                 f"requires schedule='mesh' (or unset), got "
                 f"{self.schedule!r}")
        _require(not self.fused or self.schedule != "mesh",
                 "fused one-pass steps are a serial/staged optimization; "
                 "the mesh schedule pipelines via lookahead instead")
        _require(self.precision in (None, "bf16"),
                 f"unknown precision {self.precision!r}; "
                 "one of (None, 'bf16')")

    def resolved(self, *, mesh_present: bool = False) -> "ExactConfig":
        """Pin the engine axes (plan-time resolution of the defaults).

        ``backend="auto"`` is pinned to the concrete process backend here
        so the plan/kernel caches key on what was actually built — a
        later REPRO_KERNEL_BACKEND flip misses the cache instead of
        being served a stale executable.
        """
        from repro.core.engine import resolve_backend
        sched = self.schedule or ("mesh" if mesh_present else "staged")
        if self.lookahead and sched != "mesh":
            raise ValueError(
                "lookahead requires the mesh schedule: pass a mesh (or "
                f"schedule='mesh'); resolution chose {sched!r}")
        if self.fused and sched == "mesh":
            raise ValueError(
                "fused one-pass steps are a serial/staged optimization "
                "(the mesh schedule pipelines via lookahead); drop the "
                "mesh or pass schedule='serial'/'staged' explicitly")
        upd = self.update or "rank1"
        backend = resolve_backend(self.backend)
        if (sched == self.schedule and upd == self.update
                and backend == self.backend):
            return self
        return dataclasses.replace(self, schedule=sched, update=upd,
                                   backend=backend)

    def engine_config(self) -> EngineConfig:
        """The `EngineConfig` this config denotes (axes must be resolved)."""
        _require(self.schedule is not None and self.update is not None,
                 "engine axes unresolved; call .resolved() first")
        return EngineConfig(schedule=self.schedule, update=self.update,
                            panel_k=self.k, backend=self.backend,
                            shrink=self.shrink, min_size=self.min_size,
                            lookahead=self.lookahead, fused=self.fused,
                            precision=self.precision)


@dataclass(frozen=True)
class ChebyshevConfig:
    """Knobs of the stochastic Chebyshev estimator (SPD input).

    ``degree``       expansion degree — truncation bias decays ~rho^-degree
    ``num_probes``   Hutchinson probes — noise shrinks ~1/sqrt(num_probes)
    ``probe_kind``   "rademacher" (variance-minimizing) or "gaussian"
    ``seed``         default PRNG seed when no key is passed at call time
    ``lmin``/``lmax`` spectral bounds; None -> power-iteration bracket
    ``grad_cg_tol``/``grad_cg_maxiter`` backward-pass CG solve control
    """
    degree: int = 64
    num_probes: int = 32
    probe_kind: str = "rademacher"
    seed: int = 0
    lmin: Optional[float] = None
    lmax: Optional[float] = None
    grad_cg_tol: float = 1e-8
    grad_cg_maxiter: Optional[int] = None

    def __post_init__(self):
        _require(int(self.degree) >= 1,
                 f"degree must be >= 1, got {self.degree}")
        _require(int(self.num_probes) >= 1,
                 f"num_probes must be >= 1, got {self.num_probes}")
        _require(self.probe_kind in ("rademacher", "gaussian"),
                 f"unknown probe_kind {self.probe_kind!r}")
        for name in ("lmin", "lmax"):
            v = getattr(self, name)
            if v is None:
                continue
            try:
                # coerce 0-d arrays / np scalars to a hashable float —
                # configs key the plan cache
                object.__setattr__(self, name, float(v))
            except Exception:
                # traced bounds cannot be static config: they are
                # execution inputs — plan_(a, lmin=..., lmax=...)
                raise TypeError(
                    f"{name} in the config must be a static scalar; pass "
                    f"traced bounds at execution time instead "
                    f"(plan(a, {name}=...))") from None
        if self.lmin is not None and self.lmax is not None:
            _require(float(self.lmax) > float(self.lmin),
                     f"need lmax > lmin, got [{self.lmin}, {self.lmax}]")

    def estimator_kwargs(self) -> dict:
        """Keywords for `repro.estimators.estimate_logdet`."""
        kw = dict(degree=self.degree, num_probes=self.num_probes,
                  probe_kind=self.probe_kind, seed=self.seed,
                  grad_cg_tol=self.grad_cg_tol,
                  grad_cg_maxiter=self.grad_cg_maxiter)
        if self.lmin is not None:
            kw["lmin"] = self.lmin
        if self.lmax is not None:
            kw["lmax"] = self.lmax
        return kw


@dataclass(frozen=True)
class SLQConfig:
    """Knobs of the stochastic Lanczos quadrature estimator (SPD input).

    ``num_steps``    Lanczos steps — quadrature error ~exp(-4m/sqrt(cond))
    ``num_probes``   Hutchinson probes — noise shrinks ~1/sqrt(num_probes)
    ``seed``         default PRNG seed when no key is passed at call time
    ``grad_cg_tol``/``grad_cg_maxiter`` backward-pass CG solve control
    """
    num_steps: int = 25
    num_probes: int = 32
    seed: int = 0
    grad_cg_tol: float = 1e-8
    grad_cg_maxiter: Optional[int] = None

    def __post_init__(self):
        _require(int(self.num_steps) >= 1,
                 f"num_steps must be >= 1, got {self.num_steps}")
        _require(int(self.num_probes) >= 1,
                 f"num_probes must be >= 1, got {self.num_probes}")

    def estimator_kwargs(self) -> dict:
        """Keywords for `repro.estimators.estimate_logdet`."""
        return dict(num_steps=self.num_steps, num_probes=self.num_probes,
                    seed=self.seed, grad_cg_tol=self.grad_cg_tol,
                    grad_cg_maxiter=self.grad_cg_maxiter)


LogdetConfig = Union[ExactConfig, ChebyshevConfig, SLQConfig]

_CONFIG_CLS = {
    **{m: ExactConfig for m in EXACT_METHODS},
    "chebyshev": ChebyshevConfig,
    "slq": SLQConfig,
}


def config_cls_for(method: str):
    """The config dataclass governing ``method`` (ValueError if unknown)."""
    try:
        return _CONFIG_CLS[method]
    except KeyError:
        raise ValueError(
            f"unknown method {method!r}; choose from {METHODS}") from None


def config_for(method: str, kwargs: dict) -> LogdetConfig:
    """Build the typed config for ``method`` from legacy-style keywords.

    Exact methods reject estimator keywords with a TypeError (matching the
    historical string-API behavior); every family rejects keywords it does
    not define, by name, so typos fail loudly instead of being swallowed
    by a ``**kwargs`` sink.
    """
    cls = config_cls_for(method)
    names = {f.name for f in dataclasses.fields(cls)}
    extra = set(kwargs) - names
    if extra:
        if cls is ExactConfig and extra & _ESTIMATOR_KW:
            raise TypeError(f"method {method!r} takes no estimator "
                            f"keywords: {sorted(extra)}")
        raise TypeError(
            f"unknown keywords for method {method!r}: {sorted(extra)} "
            f"(valid: {sorted(names)})")
    return cls(**kwargs)


def filter_for_method(method: str, kwargs: dict) -> dict:
    """Keep the keywords the resolved method's family understands.

    Used by ``method="auto"``: the caller cannot know the family in
    advance, so knobs for the *other* family are dropped (passing
    ``num_probes`` must not crash a call the cost model resolved to exact
    condensation — exact is at least as accurate).  Keywords no family
    defines still raise, so typos fail loudly.
    """
    known = set().union(*({f.name for f in dataclasses.fields(c)}
                          for c in (ExactConfig, ChebyshevConfig,
                                    SLQConfig)))
    unknown = set(kwargs) - known
    if unknown:
        raise TypeError(
            f"unknown keywords: {sorted(unknown)} (no method understands "
            f"them; valid names: {sorted(known)})")
    names = {f.name for f in dataclasses.fields(config_cls_for(method))}
    return {k: v for k, v in kwargs.items() if k in names}


def config_to_dict(config: LogdetConfig) -> dict:
    """JSON-safe dict encoding of a typed config, tagged with its class.

    Inverse of `config_from_dict`; this is the on-disk form the AOT plan
    header (repro.serve.aot) carries, so an exported artifact records the
    exact knobs it was compiled with.
    """
    if not isinstance(config, (ExactConfig, ChebyshevConfig, SLQConfig)):
        raise TypeError(f"not a logdet config: {type(config).__name__}")
    return {"type": type(config).__name__, **dataclasses.asdict(config)}


def config_from_dict(d: dict) -> LogdetConfig:
    """Rebuild a typed config from `config_to_dict` output (validating)."""
    d = dict(d)
    name = d.pop("type", None)
    cls = {"ExactConfig": ExactConfig, "ChebyshevConfig": ChebyshevConfig,
           "SLQConfig": SLQConfig}.get(name)
    if cls is None:
        raise ValueError(f"unknown config type {name!r}")
    names = {f.name for f in dataclasses.fields(cls)}
    extra = set(d) - names
    if extra:
        raise ValueError(
            f"unknown fields for {name}: {sorted(extra)} — artifact from "
            "a newer build?")
    return cls(**d)


def validate_config(method: str, config: LogdetConfig) -> LogdetConfig:
    """Check that an explicit config instance matches ``method``'s family."""
    cls = config_cls_for(method)
    if not isinstance(config, cls):
        raise TypeError(
            f"method {method!r} needs a {cls.__name__}, "
            f"got {type(config).__name__}")
    return config
