"""Parallel Matrix Condensation (the paper's contribution) — engine routes.

The per-step body (`mc_step_fn`), the distributed phase (`mc_local_phase`)
and the shared P x P tail reduction now live in `repro.core.engine`; this
module keeps the historical entry point `parallel_slogdet_mc` as a thin
wrapper over the engine's ``(schedule="mesh", update="rank1")`` route.

Schedule (paper §2.1, Fig. 2 + pseudocode Fig. 6): block row distribution
(device ``p`` owns rows ``[p*L, (p+1)*L)``), global step ``t = i*P + p``
eliminates device ``p``'s local row ``i`` (arbitrary-pivot-row freedom,
Eq. 2), local pivoting + ONE broadcast per step (the normalized pivot row
and its column index), redundant §2.4 column swaps, and an all-gathered
P x P tail solved redundantly on every device.  Compare GE
(core/gaussian.py): argmax all-reduce + two row broadcasts per step.

Sign is tracked exactly (the paper tracks only |det|): each step
contributes ``sign(pivot) * swap_sign * (-1)^(r_pos + m - 1)`` where
``r_pos`` is the number of live rows above the pivot row (closed form
``p*(L-1-i)`` for this schedule).
"""
from __future__ import annotations

from repro.core.engine import (
    EngineConfig,
    build_mesh,
    mc_local_phase,
    mc_step_fn,
)

__all__ = ["parallel_slogdet_mc", "mc_step_fn", "mc_local_phase"]


def parallel_slogdet_mc(mesh, axis_name: str = "rows", *, update_fn=None,
                        lookahead: bool = False):
    """Parallel Matrix Condensation logdet over a 1-D device mesh.

    Engine route ``(schedule="mesh", update="rank1")``.  Returns a function
    ``f(a) -> (sign, logabsdet)`` for an ``(N, N)`` matrix with ``N``
    divisible by the mesh size.  ``update_fn`` overrides the rank-1 update
    hook (kernel injection for benchmarks/tests).  ``lookahead=True``
    pipelines the next pivot row's factorization and broadcast past the
    current bulk update (bit-identical results, overlapped collective).
    """
    cfg = EngineConfig(schedule="mesh", update="rank1", backend="xla",
                       lookahead=lookahead)
    return build_mesh(cfg, mesh, axis_name, update_fn=update_fn)
