"""Parallel Matrix Condensation (the paper's contribution), in shard_map.

Schedule (paper §2.1, Fig. 2 + pseudocode Fig. 6):

  * **Block row distribution**: device ``p`` owns the contiguous row block
    ``[p*L, (p+1)*L)`` — cheap, contiguous scatter (the paper's data-
    distribution win over GE's cyclic layout).
  * Global step ``t = i*P + p``: device ``p`` eliminates *its own* local row
    ``i``.  Arbitrary-pivot-row freedom (Eq. 2) is what makes this legal; each
    round every device retires one local row, so block layout stays balanced.
  * **Local pivoting** (§2.2–2.3): the owner picks the pivot column as
    ``argmax |row|`` over live columns, factors the pivot out of the row
    *locally*, and broadcasts the normalized row.  No global pivot search, no
    row exchange — the communication GE cannot avoid.
  * **Column swap** (§2.4): pivot column <-> last live column, applied
    redundantly by every device, keeps the live region a contiguous static-
    shape prefix (XLA-friendly analogue of the paper's cache-contiguity trick).
  * Tail (pseudocode steps 5–8): after ``(L-1)*P`` steps, each device holds one
    live row; ``all_gather`` forms the final ``P x P`` matrix, and the tail
    slogdet is computed redundantly on every device (on TPU this is cheaper
    than a real gather-to-master + scalar scatter).

Communication per step: **one** ``psum`` carrying the normalized pivot row and
its column index.  Compare GE (core/gaussian.py): argmax all-reduce + two row
broadcasts per step.

Sign is tracked exactly (paper tracks only |det|): each step contributes
``sign(pivot) * swap_sign * (-1)^(r_pos + m - 1)`` where ``r_pos`` is the
number of live rows above the pivot row (closed form ``p*(L-1-i)`` for this
schedule) and ``m-1`` is the pivot's live column position after the swap.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec

from repro._compat import (axis_size as _axis_size, pvary as _pvary,
                           shard_map as _shard_map)
from repro.core.condense import slogdet_condense

__all__ = ["parallel_slogdet_mc", "mc_step_fn", "mc_local_phase"]


def mc_step_fn(axis_name: str, *, update_fn=None):
    """Per-global-step body of parallel MC for use inside shard_map.

    ``local`` has shape (L, N) — the device's contiguous row block.  Global
    step ``t`` maps to (round ``i = t // P``, owner ``p = t % P``); the owner
    eliminates its local row ``i``.  Returns ``step(t, carry)`` with carry
    ``(local, sign, logdet)`` where sign/logdet are *per-device partial*
    contributions (combine with psum / product at the end, paper step 6).
    """

    def step(t, carry):
        local, sign, logdet = carry
        L, N = local.shape
        P = _axis_size(axis_name)
        me = lax.axis_index(axis_name)
        i = t // P                            # round = owner's local row index
        p = t % P                             # owner device
        m = N - t                             # live column count
        last = m - 1                          # post-swap pivot column
        mine = me == p

        # ---- owner: local pivot choice + row normalization (no comm) -------
        row = local[i]
        live_col = jnp.arange(N) < m
        absrow = jnp.where(live_col, jnp.abs(row), -jnp.inf)
        l = jnp.argmax(absrow)
        pv = row[l]
        # swap l <-> last inside the pivot row, normalize so pr[last] == 1
        rl, rlast = row[l], row[last]
        row = row.at[l].set(rlast).at[last].set(pv)
        safe = jnp.where(pv == 0, jnp.ones((), local.dtype), pv)
        pr = jnp.where(pv == 0, jnp.zeros_like(row), row / safe)
        pr = pr.at[last].set(jnp.where(pv == 0, pr[last], 1.0))

        # ---- broadcast: ONE collective for (normalized row, column index) ---
        pr_b, l_b = lax.psum(
            (jnp.where(mine, pr, jnp.zeros_like(pr)),
             jnp.where(mine, l, jnp.zeros_like(l))),
            axis_name,
        )

        # ---- every device: column swap l_b <-> last on its block ------------
        cl = jnp.take(local, l_b, axis=1)
        clast = jnp.take(local, last, axis=1)
        local = local.at[:, l_b].set(clast)
        local = local.at[:, last].set(cl)

        # ---- rank-1 condensation update on live rows -------------------------
        pc = jnp.take(local, last, axis=1)
        dead = i + (me <= p)                  # rows [0, dead) are retired
        pc = jnp.where(jnp.arange(L) < dead, 0.0, pc)
        if update_fn is None:
            local = local - jnp.outer(pc, pr_b)
        else:
            local = update_fn(local, pc, pr_b)

        # ---- owner accumulates its logdet/sign contribution ------------------
        r_pos = p * (L - 1 - i)               # live rows above the pivot row
        parity = jnp.where((r_pos + m - 1) % 2 == 0, 1.0, -1.0).astype(local.dtype)
        swap_sign = jnp.where(l == last, 1.0, -1.0).astype(local.dtype)
        step_sign = jnp.sign(pv) * swap_sign * parity
        sign = jnp.where(mine, sign * step_sign, sign)
        logdet = logdet + jnp.where(mine, jnp.log(jnp.abs(pv)), 0.0)
        return local, sign, logdet

    return step


def mc_local_phase(local, axis_name: str, *, t0: int = 0, n_steps: int | None = None,
                   update_fn=None):
    """Run the distributed condensation phase; local block (L, N).

    Returns (local, sign_partial, logdet_partial) after ``n_steps`` global
    steps starting at ``t0`` (default: the full ``(L-1)*P`` schedule).
    """
    L, N = local.shape
    P = _axis_size(axis_name)
    if n_steps is None:
        n_steps = (L - 1) * P - t0
    step = mc_step_fn(axis_name, update_fn=update_fn)
    sign0 = _pvary(jnp.ones((), local.dtype), axis_name)
    ld0 = _pvary(jnp.zeros((), local.dtype), axis_name)
    return lax.fori_loop(t0, t0 + n_steps, step, (local, sign0, ld0))


def _mc_kernel(axis_name: str, update_fn=None):
    def kernel(local):
        L, N = local.shape
        P = _axis_size(axis_name)
        local, sign, logdet = mc_local_phase(local, axis_name, update_fn=update_fn)

        # ---- tail: gather the P live rows (one per device) -------------------
        live = lax.dynamic_slice(local, (L - 1, 0), (1, N))[0, :]
        tail = lax.all_gather(live, axis_name)          # (P, N): device-ordered
        tail = lax.slice(tail, (0, 0), (P, P))          # live cols are prefix
        tsign, tlogdet = slogdet_condense(tail)         # redundant on all devs

        # combine per-device partials (paper step 6: reduce)
        logdet_total = lax.psum(logdet, axis_name) + tlogdet
        signs = lax.all_gather(sign, axis_name)
        sign_total = jnp.prod(signs) * tsign
        return sign_total.reshape(1), logdet_total.reshape(1)

    return kernel


def parallel_slogdet_mc(mesh, axis_name: str = "rows", *, update_fn=None):
    """Parallel Matrix Condensation logdet over a 1-D device mesh.

    Returns a function ``f(a) -> (sign, logabsdet)`` for an ``(N, N)`` matrix
    with ``N`` divisible by the mesh size.  Rows are distributed in contiguous
    blocks (the paper's preferred layout — cheap scatter, load-balanced thanks
    to the arbitrary-pivot-row schedule).
    """
    nproc = int(mesh.shape[axis_name])
    kernel = _mc_kernel(axis_name, update_fn=update_fn)

    shmapped = _shard_map(
        kernel,
        mesh=mesh,
        in_specs=(PartitionSpec(axis_name, None),),
        out_specs=(PartitionSpec(axis_name), PartitionSpec(axis_name)),
    )

    @jax.jit
    def run(a):
        n = a.shape[0]
        if n % nproc:
            raise ValueError(f"N={n} not divisible by mesh size {nproc}")
        sign, logdet = shmapped(a)
        return sign[0], logdet[0]

    return run
