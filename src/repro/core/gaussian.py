"""Gaussian elimination baselines (paper §2.5, §3).

The paper compares Matrix Condensation against a self-implemented parallel
Gaussian Elimination with partial pivoting.  GE *must* eliminate top-to-bottom,
so load balance requires a **cyclic row distribution**, and partial pivoting
requires a **global pivot search + cross-processor row exchange** each step —
the two costs MC avoids.  We reproduce both faithfully:

  * ``slogdet_ge``            — serial GE with partial pivoting (static shapes).
  * ``parallel_slogdet_ge``   — shard_map parallel GE, cyclic rows, global
                                argmax pivot search, pivot-row and displaced-row
                                broadcasts (the paper's extra communications).

Communication per step (counted in benchmarks/fig9_comm.py):
  GE:  global argmax (all-reduce) + 2 row broadcasts  (pivot row + displaced row)
  MC:  1 row broadcast                                 (see core/parallel.py)
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro._compat import (axis_size as _axis_size, pvary as _pvary,
                           shard_map as _shard_map)
# GE stays a separate algorithm family (the paper's comparison baseline),
# but the sign/permutation helpers are the engine's shared ones
from repro.core.engine import cyclic_perm, guarded_pivot, perm_parity

__all__ = ["slogdet_ge", "parallel_slogdet_ge", "ge_step_fn", "cyclic_perm", "perm_parity"]


@jax.jit
def slogdet_ge(a: jax.Array):
    """Serial Gaussian elimination with partial pivoting.

    Returns ``(sign, logabsdet)`` with `numpy.linalg.slogdet` semantics.
    Static-shape friendly: every step works on the full buffer with masking.
    """
    n = a.shape[0]
    if a.ndim != 2 or a.shape[1] != n:
        raise ValueError(f"expected square matrix, got {a.shape}")
    if n == 0:
        return jnp.ones((), a.dtype), jnp.zeros((), a.dtype)

    rows = jnp.arange(n)

    def body(t, carry):
        buf, sign, logdet = carry
        col = jnp.take(buf, t, axis=1)
        # partial pivot: global argmax of |col| among rows >= t
        cand = jnp.where(rows >= t, jnp.abs(col), -jnp.inf)
        r = jnp.argmax(cand)
        p = buf[r, t]

        # swap rows r <-> t
        row_r = buf[r]
        row_t = buf[t]
        buf = buf.at[r].set(row_t)
        buf = buf.at[t].set(row_r)
        sign = sign * jnp.where(r == t, 1.0, -1.0).astype(a.dtype)

        pr = buf[t]                                   # pivot row (unnormalized)
        safe_p = guarded_pivot(p, a.dtype)
        factor = jnp.where(rows > t, jnp.take(buf, t, axis=1) / safe_p, 0.0)
        buf = buf - factor[:, None] * pr[None, :]

        sign = sign * jnp.sign(p)
        logdet = logdet + jnp.log(jnp.abs(p))
        return buf, sign, logdet

    buf, sign, logdet = lax.fori_loop(
        0, n, body, (a, jnp.ones((), a.dtype), jnp.zeros((), a.dtype))
    )
    return sign, logdet


def ge_step_fn(axis_name: str):
    """Per-step body of parallel GE for use inside shard_map.

    Cyclic row distribution: global row ``g`` lives on device ``g % P`` at
    local index ``g // P``.  Returns ``step(t, (local, sign, ld))`` where
    ``local`` has shape (L, N).
    """

    def step(t, carry):
        local, sign, logdet = carry
        L, N = local.shape
        P = _axis_size(axis_name)
        me = lax.axis_index(axis_name)
        lrow = jnp.arange(L)
        grow = lrow * P + me                     # global index of each local row

        # ---- 1. global pivot search over column t among global rows >= t ----
        col = jnp.take(local, t, axis=1)
        cand = jnp.where(grow >= t, jnp.abs(col), -jnp.inf)
        lmax_i = jnp.argmax(cand)
        lmax_v = cand[lmax_i]
        vals = lax.all_gather(lmax_v, axis_name)           # (P,) comm #1
        grs = lax.all_gather(grow[lmax_i], axis_name)      # (P,)
        best = jnp.argmax(vals)                            # first max: determinstic
        pivot_g = grs[best]                                # global pivot row

        # ---- 2. broadcast pivot row and displaced row t ----------------------
        owner_p = pivot_g % P
        owner_t = t % P
        li_p = pivot_g // P
        li_t = t // P
        mine_p = owner_p == me
        mine_t = owner_t == me
        contrib_p = jnp.where(mine_p, local[li_p], jnp.zeros((N,), local.dtype))
        contrib_t = jnp.where(mine_t, local[li_t], jnp.zeros((N,), local.dtype))
        # two row broadcasts == GE's extra comm vs MC (psum realizes bcast)
        both = lax.psum(jnp.stack([contrib_p, contrib_t]), axis_name)  # comm #2
        pivot_row, row_t = both[0], both[1]
        p = pivot_row[t]

        # ---- 3. row exchange: owner of row t gets pivot row and vice versa --
        swapped = pivot_g != t
        new_lt = jnp.where(swapped & mine_t, pivot_row, local[li_t])
        local = local.at[li_t].set(new_lt)
        new_lp = jnp.where(swapped & mine_p, row_t, local[li_p])
        local = local.at[li_p].set(new_lp)

        # ---- 4. elimination on my rows with global index > t ----------------
        safe_p = guarded_pivot(p, local.dtype)
        factor = jnp.where(grow > t, jnp.take(local, t, axis=1) / safe_p, 0.0)
        local = local - factor[:, None] * pivot_row[None, :]

        sign = sign * jnp.where(swapped, -1.0, 1.0).astype(local.dtype)
        sign = sign * jnp.sign(p)
        logdet = logdet + jnp.log(jnp.abs(p))
        return local, sign, logdet

    return step


def parallel_slogdet_ge(mesh, axis_name: str = "rows"):
    """Parallel GE with partial pivoting over a 1-D device mesh.

    Returns a jitted function ``f(a) -> (sign, logabsdet)`` for an ``(N, N)``
    matrix with ``N`` divisible by the mesh size.  Rows are distributed
    cyclically (global row g -> device g % P), which is what load-balances GE
    (paper Fig. 1) but costs a strided scatter (benchmarked in fig9).
    """
    from jax.sharding import PartitionSpec

    step = ge_step_fn(axis_name)
    nproc = int(np.prod([mesh.shape[a] for a in ([axis_name] if isinstance(axis_name, str) else axis_name)]))

    def kernel(local):
        # local: (L, N) cyclic block, row-major as in the paper
        N = local.shape[1]
        sign0 = _pvary(jnp.ones((), local.dtype), axis_name)
        ld0 = _pvary(jnp.zeros((), local.dtype), axis_name)
        local, sign, logdet = lax.fori_loop(0, N, step, (local, sign0, ld0))
        # sign/logdet are accumulated identically on all devices.
        return sign.reshape(1), logdet.reshape(1)

    shmapped = _shard_map(
        kernel,
        mesh=mesh,
        in_specs=(PartitionSpec(axis_name, None),),
        out_specs=(PartitionSpec(axis_name), PartitionSpec(axis_name)),
    )

    import functools as _ft

    @_ft.lru_cache(maxsize=8)
    def _go(n: int):
        if n % nproc:
            raise ValueError(f"N={n} not divisible by mesh size {nproc}")
        perm = cyclic_perm(n, nproc)
        parity = perm_parity(perm)

        @jax.jit
        def go(a):
            ac = a[jnp.asarray(perm)]
            sign, logdet = shmapped(ac)
            return sign[0] * jnp.asarray(parity, a.dtype), logdet[0]

        return go

    def run(a):
        return _go(a.shape[0])(a)

    run.lower = lambda a: _go(a.shape[0]).lower(a)   # HLO introspection
    return run
