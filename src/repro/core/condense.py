"""Serial matrix condensation (paper §1–§2.4) — engine instantiations.

The step logic (pivot-column argmax §2.2, row factoring §2.3, §2.4 column
swap, sign/parity tracking) lives in ONE place: `repro.core.engine`.  This
module keeps the historical serial entry points as thin wrappers over the
engine's ``(schedule="serial"|"staged", update="rank1")`` routes:

  * `slogdet_condense`         — faithful baseline: one static buffer,
    every step updates the full buffer (dead rows/cols get harmless
    garbage; ~3x theoretical FLOPs, recorded as the §Perf baseline).
  * `slogdet_condense_staged`  — geometric re-jit over shrinking static
    shapes, slicing the live prefix between stages (possible *because of*
    the §2.4 column-swap trick).

See core/blocked.py for the rank-K panel routes and core/parallel.py for
the mesh schedule.
"""
from __future__ import annotations

from repro.core.engine import (
    combine_slogdet,
    condense_full as slogdet_condense,
    condense_steps,
    staged_full,
)

__all__ = [
    "slogdet_condense",
    "slogdet_condense_staged",
    "condense_steps",
    "combine_slogdet",
]


def slogdet_condense_staged(a, *, shrink: float = 0.75, min_size: int = 64):
    """Geometric shape-staged condensation (§Perf optimization 1).

    Engine route ``(schedule="staged", update="rank1")``: FLOP waste drops
    from ~3x (full static buffer) to ~1.5x with shrink=0.75 at the cost of
    a handful of compilations.
    """
    return staged_full(a, shrink=shrink, min_size=min_size, update="rank1")
