"""Serial matrix condensation for log-determinant (paper §1–§2.4).

Implements the Salem–Said / Haque–Maza condensation step

    det(A) = a_{k,l} * det(B*),   B*_{ij} = a_{ij} - a_{il} * (a_{kj} / a_{k,l})

with the paper's three refinements:
  * pivot column = argmax |pivot row|  (§2.2, robust partial pivoting),
  * pivot factored out of the *row*    (§2.3),
  * pivot column swapped with the last live column so the live region stays a
    contiguous prefix (§2.4 — the paper's cache-contiguity trick; here it is
    what keeps every step a static-shape prefix that XLA can slice).

Faithful baseline (`slogdet_condense`): the live region shrinks by one
row/column per step but XLA needs static shapes, so each step updates the full
static buffer (dead rows/cols receive harmless garbage).  This costs ~3x the
theoretical FLOPs and is recorded as the §Perf baseline.

`slogdet_condense_staged` re-jits on a geometric schedule of shrinking static
shapes, slicing the live prefix between stages (possible *because of* the
column-swap trick).  See core/blocked.py for the rank-K panel variant.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "slogdet_condense",
    "slogdet_condense_staged",
    "condense_steps",
    "combine_slogdet",
]


def _condense_step(buf: jax.Array, t, n_total: int, sign, logdet, *, update_fn=None):
    """One condensation step on the full static buffer.

    Live region at step ``t``: rows [t, N), cols [0, N - t).  Pivot row is row
    ``t`` (serial schedule); pivot column is the max-abs entry of the live part
    of row ``t``.  Returns the updated (buf, sign, logdet).
    """
    n = n_total
    m = n - t                       # live size (traced)
    col_ids = jnp.arange(n)
    live_col = col_ids < m

    row = buf[t]                                        # (N,)
    absrow = jnp.where(live_col, jnp.abs(row), -jnp.inf)
    l = jnp.argmax(absrow)                              # pivot column (traced)
    p = row[l]                                          # pivot value

    # --- column swap l <-> m-1 (paper §2.4) --------------------------------
    last = m - 1
    col_l = buf[:, l]
    col_last = buf[:, last]
    buf = buf.at[:, l].set(col_last)
    buf = buf.at[:, last].set(col_l)
    swap_sign = jnp.where(l == last, 1.0, -1.0).astype(buf.dtype)

    # pivot row in swapped coordinates, normalized by the pivot (§2.3).
    row = row.at[l].set(row[last])
    # row[last] still holds the pre-swap value; the true pivot now sits at
    # position `last` in the buffer.  Force it so pr[last] == 1 exactly, which
    # zeroes the pivot column for all updated rows.
    row = row.at[last].set(p)
    safe_p = jnp.where(p == 0, jnp.ones((), buf.dtype), p)
    pr = jnp.where(p == 0, jnp.zeros_like(row), row / safe_p)

    # pivot column entries; zero at the pivot row so it is left untouched.
    pc = buf[:, last]
    pc = pc.at[t].set(0.0)
    # Rows above t are dead; zero them too so the baseline buffer stays finite
    # (cosmetic — they are never read again).
    pc = jnp.where(jnp.arange(n) < t, 0.0, pc)

    if update_fn is None:
        buf = buf - jnp.outer(pc, pr)
    else:
        buf = update_fn(buf, pc, pr)

    # sign bookkeeping: pivot sign, column swap, and Laplace expansion of the
    # pivot (active row 0, active column m-1) => (-1)^(m-1).
    parity = jnp.where((m - 1) % 2 == 0, 1.0, -1.0).astype(buf.dtype)
    sign = sign * jnp.sign(p) * swap_sign * parity
    logdet = logdet + jnp.log(jnp.abs(p))
    return buf, sign, logdet


def condense_steps(buf: jax.Array, n_steps: int, *, t0: int = 0, update_fn=None):
    """Run ``n_steps`` condensation steps starting at step offset ``t0``.

    Returns (buf, sign, logdet) with sign/logdet the *contribution* of these
    steps (combine with `combine_slogdet`).
    """
    n = buf.shape[0]

    def body(t, carry):
        b, s, ld = carry
        return _condense_step(b, t, n, s, ld, update_fn=update_fn)

    # Derive the initial sign/logdet carries from `buf` so they inherit its
    # varying-manual-axes type when called inside shard_map (tail solve).
    zero = buf[0, 0] * 0
    return lax.fori_loop(t0, t0 + n_steps, body, (buf, zero + 1, zero))


def combine_slogdet(parts) -> Tuple[jax.Array, jax.Array]:
    """Combine (sign, logabsdet) contributions multiplicatively."""
    sign = functools.reduce(lambda a, b: a * b, [p[0] for p in parts])
    logdet = functools.reduce(lambda a, b: a + b, [p[1] for p in parts])
    return sign, logdet


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def slogdet_condense(a: jax.Array, *, use_kernel: bool = False):
    """Log-determinant via matrix condensation (faithful serial baseline).

    Returns ``(sign, logabsdet)`` with `numpy.linalg.slogdet` semantics.
    ``use_kernel=True`` routes the rank-1 update through the Pallas kernel
    (interpret mode on CPU).
    """
    n = a.shape[0]
    if a.ndim != 2 or a.shape[1] != n:
        raise ValueError(f"expected square matrix, got {a.shape}")
    if n == 0:
        return jnp.ones((), a.dtype), jnp.zeros((), a.dtype)
    if n == 1:
        return jnp.sign(a[0, 0]), jnp.log(jnp.abs(a[0, 0]))

    update_fn = None
    if use_kernel:
        from repro.kernels import ops as _kops
        update_fn = _kops.rank1_update

    buf, sign, logdet = condense_steps(a, n - 1, update_fn=update_fn)
    p = buf[n - 1, 0]
    return sign * jnp.sign(p), logdet + jnp.log(jnp.abs(p))


def _stage_schedule(n: int, shrink: float, min_size: int):
    """Static (size, steps) schedule: run `steps` at static size `size`."""
    sched = []
    size = n
    while size > min_size:
        nxt = max(min_size, int(math.ceil(size * shrink)))
        steps = size - nxt
        if steps <= 0:
            break
        sched.append((size, steps))
        size = nxt
    sched.append((size, size - 1))  # finish to 1x1
    return sched


@functools.partial(jax.jit, static_argnames=("steps",))
def _staged_stage(buf, steps: int):
    b, s, ld = condense_steps(buf, steps)
    n = buf.shape[0]
    live = lax.slice(b, (steps, 0), (n, n - steps))
    return live, s, ld


def slogdet_condense_staged(a: jax.Array, *, shrink: float = 0.75,
                            min_size: int = 64):
    """Geometric shape-staged condensation (§Perf optimization 1).

    Runs condensation in stages of static shape, slicing out the live prefix
    between stages.  FLOP waste drops from ~3x (full static buffer) to ~1.5x
    with shrink=0.75 (and lower with finer schedules) at the cost of a handful
    of compilations.
    """
    n = a.shape[0]
    if n <= min_size:
        return slogdet_condense(a)
    parts = []
    buf = a
    for size, steps in _stage_schedule(n, shrink, min_size):
        if buf.shape[0] != size:  # defensive; schedule and buffer must agree
            raise AssertionError((buf.shape, size))
        if size - steps <= 1:
            parts.append(slogdet_condense(buf))
            buf = None
            break
        buf, s, ld = _staged_stage(buf, steps)
        parts.append((s, ld))
    if buf is not None:
        parts.append(slogdet_condense(buf))
    return combine_slogdet(parts)
