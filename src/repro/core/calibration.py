"""Measured-roofline calibration for the method/route selector.

``select_method`` used to price methods with static FLOP constants, which
made every crossover device-count-invariant (both sides divided by P) and
wrong on any machine that is not the one the constants were guessed for.
This module replaces the constants with a **measured calibration table**:

  gemm_flops        sustained GEMM throughput per device (FLOP/s) — prices
                    panel updates and estimator matvec slabs (MXU work)
  stream_bytes      sustained streaming read+write bandwidth per device
                    (bytes/s) — prices the rank-1 update (HBM-bound)
  collective_lat    per-collective latency (s) — the fixed cost of every
                    pivot-row broadcast on the mesh schedule
  collective_bytes  collective payload bandwidth (bytes/s)

The table is produced by ``python -m benchmarks.roofline --calibrate``
(times a GEMM, a fused rank-1 update, and a shard_map psum loop at two
payload sizes, then fits latency + bandwidth) and persisted as JSON.
Search order: ``$REPRO_CALIBRATION`` (a path, or ``static`` to force the
built-in defaults), then the committed ``bench_out/roofline_calibration
.json``, then the static defaults.

The cost functions below are the single place route timings are modeled;
`repro.core.plan.select_route` consumes them.  Because the mesh terms
(latency x steps + bytes / collective bandwidth) do NOT shrink with P,
the dense<->estimator and serial<->mesh crossovers now move with device
count — the paper's own Fig. 7/8 story, priced per machine.
"""
from __future__ import annotations

import functools
import json
import math
import os
from dataclasses import dataclass
from pathlib import Path
from typing import Optional

__all__ = [
    "Calibration", "STATIC_DEFAULT", "load_calibration",
    "clear_calibration_cache", "calibration_path", "exact_cost",
    "estimator_cost",
]

_ENV_VAR = "REPRO_CALIBRATION"
_TABLE_NAME = "roofline_calibration.json"
# probes per matvec slab the estimators batch into one pass (make_probes
# default) — sets how many sequential collectives an estimator run needs
_EST_SLAB = 32


# modeled bf16:native GEMM throughput ratio when a table carries no
# measured bf16 entry — MXU parts run bf16 matmuls at ~2x the f32 rate
_BF16_GEMM_SPEEDUP = 2.0


@dataclass(frozen=True)
class Calibration:
    """Per-device roofline terms; see the module docstring.

    ``gemm_flops_bf16`` is the optional measured bf16 GEMM rate (the
    mixed-precision engine route); absent, `gemm_rate` models it as
    ``_BF16_GEMM_SPEEDUP x gemm_flops`` so the selector still prices
    bf16 and native separately.
    """
    gemm_flops: float = 4.0e10
    stream_bytes: float = 1.5e10
    collective_lat: float = 2.0e-5
    collective_bytes: float = 4.0e9
    source: str = "static-default"
    gemm_flops_bf16: Optional[float] = None

    def __post_init__(self):
        for name in ("gemm_flops", "stream_bytes", "collective_lat",
                     "collective_bytes"):
            v = float(getattr(self, name))
            if not v > 0:
                raise ValueError(f"calibration {name} must be > 0, got {v}")
        if self.gemm_flops_bf16 is not None \
                and not float(self.gemm_flops_bf16) > 0:
            raise ValueError(
                f"calibration gemm_flops_bf16 must be > 0, "
                f"got {self.gemm_flops_bf16}")

    def gemm_rate(self, precision: Optional[str] = None) -> float:
        """Sustained GEMM FLOP/s for an engine precision route."""
        if precision in (None, "f32", "f64", "native"):
            return float(self.gemm_flops)
        if precision == "bf16":
            if self.gemm_flops_bf16 is not None:
                return float(self.gemm_flops_bf16)
            return float(self.gemm_flops) * _BF16_GEMM_SPEEDUP
        raise ValueError(f"unknown precision {precision!r}")


STATIC_DEFAULT = Calibration()


def calibration_path() -> Optional[Path]:
    """Where a measured table would be loaded from (None -> static)."""
    env = os.environ.get(_ENV_VAR, "").strip()
    if env:
        if env.lower() == "static":
            return None
        return Path(env)
    committed = Path(__file__).resolve().parents[3] / "bench_out" / _TABLE_NAME
    return committed if committed.exists() else None


@functools.lru_cache(maxsize=8)
def _load(path_str: Optional[str]) -> Calibration:
    if path_str is None:
        return STATIC_DEFAULT
    try:
        raw = json.loads(Path(path_str).read_text())
    except (OSError, json.JSONDecodeError) as e:
        raise ValueError(f"cannot read calibration table {path_str}: {e}")
    bf16 = raw.get("bf16") or {}
    bf16_rate = bf16.get("gemm_flops", raw.get("gemm_flops_bf16"))
    return Calibration(
        gemm_flops=float(raw["gemm_flops"]),
        stream_bytes=float(raw["stream_bytes"]),
        collective_lat=float(raw["collective_lat"]),
        collective_bytes=float(raw["collective_bytes"]),
        source=str(raw.get("source", f"measured:{path_str}")),
        gemm_flops_bf16=None if bf16_rate is None else float(bf16_rate),
    )


def load_calibration(path=None) -> Calibration:
    """The active calibration table (measured if available)."""
    if path is not None:
        return _load(str(path))
    p = calibration_path()
    return _load(None if p is None else str(p))


def clear_calibration_cache():
    """Re-read tables on next load (test hook / after re-calibration)."""
    _load.cache_clear()


# --------------------------------------------------------------------------
# route cost model (seconds)
# --------------------------------------------------------------------------

def exact_cost(n: int, devices: int, cal: Calibration, *,
               update: str = "rank1", panel_k: Optional[int] = None,
               itemsize: int = 8, batch: int = 1,
               lookahead: bool = False,
               precision: Optional[str] = None) -> float:
    """Modeled wall time of an exact condensation route.

    ``devices == 1`` prices the serial/staged schedules; ``devices > 1``
    the mesh schedule — compute splits P ways, but every eliminated row
    (or K-row panel) still pays one broadcast, so the communication term
    is NOT divided by P.  Batched stacks run one device per matrix (no
    collectives), so ``batch`` scales the compute term only.

    ``panel_k=None`` resolves through the calibration-driven tile
    autotuner (`repro.kernels.autotune`) — the same resolution the
    kernels use, so ``method="auto"`` prices the geometry that actually
    runs.  ``precision="bf16"`` prices the GEMM term at the measured (or
    modeled) bf16 rate.

    ``lookahead`` prices the pipelined mesh schedule: the double-buffered
    broadcast overlaps the bulk trailing update, hiding up to the
    per-device compute time of the communication term, at the price of
    an extra early-apply of each step/panel to the next pivot rows
    (~``2 * width^2 * n`` FLOPs per step, width = panel_k or 1).
    """
    if n <= 1:
        return 0.0
    if panel_k is None:
        from repro.kernels.autotune import resolved_panel_k
        panel_k = resolved_panel_k(n, itemsize=itemsize,
                                   precision=precision, cal=cal)
    flops = (2.0 / 3.0) * float(n) ** 3
    if update == "panel":
        # rank-K trailing updates are GEMMs: MXU/peak-FLOP bound
        compute = flops / cal.gemm_rate(precision)
    else:
        # rank-1 updates stream the live block once per step: with staged
        # scheduling the touched area is ~1.5 x sum_m m^2 ~ n^3/2 elements,
        # read + write  =>  ~ itemsize * n^3 bytes end to end
        compute = itemsize * float(n) ** 3 / cal.stream_bytes
    cost = batch * compute / devices
    if devices > 1:
        if update == "panel":
            steps = max(1, n // panel_k)
            payload = itemsize * panel_k * n          # (K x N) panel + ls
            width = panel_k
        else:
            steps = n
            payload = itemsize * n                    # one normalized row
            width = 1
        # tree/butterfly collectives pay the latency once per hop, and the
        # hop count grows with the device fan-out: ~log2(P) depth
        lat = cal.collective_lat * max(1.0, math.log2(devices))
        comm = steps * (lat + payload / cal.collective_bytes)
        if lookahead:
            # the in-flight collective overlaps the bulk update: only the
            # part of comm that exceeds per-device compute stays exposed
            hidden = min(comm, cost)
            overhead = steps * 2.0 * width * width * n / cal.gemm_flops
            cost += (comm - hidden) + overhead
        else:
            cost += comm
    return cost


def estimator_cost(n: int, cols: int, matvec_flops: float, devices: int,
                   cal: Calibration, *, itemsize: int = 8,
                   batch: int = 1) -> float:
    """Modeled wall time of a stochastic estimator run.

    ``cols`` is the probe x step budget (total matvec columns); matvec
    slabs are GEMM-shaped, so compute prices against the measured GEMM
    roofline.  On a mesh the row-sharded matvec reduces one slab per
    sequential step.
    """
    compute = batch * cols * matvec_flops / (devices * cal.gemm_flops)
    cost = compute
    if devices > 1:
        seq = max(1, cols // _EST_SLAB)
        payload = itemsize * n * _EST_SLAB
        cost += seq * (cal.collective_lat + payload / cal.collective_bytes)
    return cost
