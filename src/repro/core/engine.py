"""The unified condensation engine: one schedule x update x backend core.

The paper's contribution is ONE step — pivot-column argmax (§2.2), row
factoring (§2.3), column swap (§2.4) — yet the repo used to reimplement it
four times (serial, staged, blocked, mesh).  This module is the single
implementation, parameterized on three orthogonal axes:

  schedule   "serial"  one static buffer, one rank-per-step fori_loop
             "staged"  geometric re-jit over shrinking static shapes
             "mesh"    round-robin block rows over a 1-D device mesh
                       (shard_map; the paper's parallel schedule)
  update     "rank1"   the faithful outer-product subtract (VPU/bandwidth)
             "panel"   rank-K panels: factorize K rows, ONE trailing GEMM
                       (MXU; the paper's "future work", blocked-LU style)
  backend    "xla"       plain jnp expressions, XLA-fused
             "pallas"    the fused Pallas kernels (repro.kernels.ops);
                         off-TPU the kernel bodies run in interpret mode
                         — never a silent fall-through to the reference
             "interpret" the kernel bodies through the Pallas interpreter
                         (deterministic CPU coverage; what CI forces via
                         REPRO_KERNEL_BACKEND=interpret)
             "auto"      resolves to the process default at plan time
                         (env override, else pallas on TPU / xla off)

Every combination shares exactly one implementation of pivot selection,
§2.4 column-swap bookkeeping, sign/parity tracking, the remainder rank-1
steps, and the P x P tail reduction (`mesh_tail`).  The legacy modules
(core/condense.py, core/blocked.py, core/parallel.py) are thin wrappers
over this engine; the Gaussian-elimination and ScaLAPACK baselines stay
separate algorithms but adopt the shared sign helpers (`perm_parity`,
`cyclic_perm`, `guarded_pivot`) and `combine_slogdet`.

Route vocabulary: a legacy route string maps to an `EngineConfig` tuple
via `LEGACY_ROUTES` —

    mc          -> (serial, rank1)      mc_staged   -> (staged, rank1)
    mc_blocked  -> (serial, panel)      pmc         -> (mesh,   rank1)
    pmc_blocked -> (mesh,   panel)

plus the combinations no legacy string ever exposed (staged x panel, any
x pallas).  New code requests ``repro.plan(..., method="exact",
schedule=..., update=..., backend=...)``.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec

from repro._compat import (axis_size as _axis_size, pvary as _pvary,
                           shard_map as _shard_map)
from repro import obs

__all__ = [
    "EngineConfig", "LEGACY_ROUTES", "SCHEDULES", "UPDATES", "BACKENDS",
    "build_serial", "build_mesh", "engine_slogdet",
    "condense_steps", "condense_full", "panel_factor", "apply_panel",
    "panel_rounds_serial", "mc_step_fn", "mc_local_phase", "mesh_tail",
    "combine_slogdet", "guarded_pivot", "cyclic_perm", "perm_parity",
    "stage_schedule",
]

SCHEDULES = ("serial", "staged", "mesh")
UPDATES = ("rank1", "panel")
# "interpret" runs the Pallas kernel bodies through the interpreter —
# the deterministic off-TPU coverage backend CI forces via
# REPRO_KERNEL_BACKEND; "pallas" off-TPU degrades to it automatically
BACKENDS = ("auto", "xla", "pallas", "interpret")


@dataclass(frozen=True)
class EngineConfig:
    """One point in the schedule x update x backend design space.

    ``panel_k``   panel width of the rank-K update (ignored for rank1).
    ``shrink``    geometric stage ratio of the staged schedule.
    ``min_size``  size at which the staged schedule stops re-jitting.
    ``lookahead`` mesh-only: pipeline the next pivot row / panel — its
                  owner factors it from an early-applied copy *before*
                  the bulk trailing update of the current one, so the
                  broadcast collective is double-buffered and overlaps
                  compute instead of serializing with it.  Bit-identical
                  results (asserted in tests/test_engine.py).
    ``fused``     serial/staged-only: run the condensation step as ONE
                  pass over the buffer — pivot argmax + §2.4 swap + the
                  rank-1 update in a single fused kernel (the swap
                  becomes a per-column select), and the panel schedule's
                  K sequential swap scatters become one composed-
                  permutation gather.  Bit-identical results (asserted
                  in tests/test_engine.py); the mesh schedule pipelines
                  via ``lookahead`` instead.
    ``precision`` ``None`` (native) or ``"bf16"``: quantize the
                  GEMM / outer-product operands to bfloat16 while the
                  buffer and all sign/parity/log accumulators stay in
                  the input dtype (the mixed-precision MXU route; error
                  model documented in docs/api.md).
    Frozen + hashable so it can ride inside `ExactConfig` and key the
    plan cache.
    """
    schedule: str = "staged"
    update: str = "rank1"
    panel_k: int = 32
    backend: str = "auto"
    shrink: float = 0.75
    min_size: int = 64
    lookahead: bool = False
    fused: bool = False
    precision: Optional[str] = None

    def __post_init__(self):
        if self.schedule not in SCHEDULES:
            raise ValueError(
                f"unknown schedule {self.schedule!r}; one of {SCHEDULES}")
        if self.update not in UPDATES:
            raise ValueError(
                f"unknown update {self.update!r}; one of {UPDATES}")
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; one of {BACKENDS}")
        if int(self.panel_k) < 1:
            raise ValueError(f"panel_k must be >= 1, got {self.panel_k}")
        if not (0.0 < float(self.shrink) < 1.0):
            raise ValueError(f"shrink must be in (0, 1), got {self.shrink}")
        if int(self.min_size) < 2:
            raise ValueError(f"min_size must be >= 2, got {self.min_size}")
        if self.lookahead and self.schedule != "mesh":
            raise ValueError(
                "lookahead pipelines the mesh schedule's broadcast; it "
                f"requires schedule='mesh', got {self.schedule!r}")
        if self.fused and self.schedule == "mesh":
            raise ValueError(
                "fused one-pass steps are a serial/staged optimization; "
                "the mesh schedule pipelines via lookahead instead")
        if self.precision not in (None, "bf16"):
            raise ValueError(
                f"unknown precision {self.precision!r}; one of "
                "(None, 'bf16')")


# legacy route string -> (schedule, update); the historical spellings all
# ran the XLA backend with default staging knobs
LEGACY_ROUTES = {
    "mc": ("serial", "rank1"),
    "mc_staged": ("staged", "rank1"),
    "mc_blocked": ("serial", "panel"),
    "pmc": ("mesh", "rank1"),
    "pmc_blocked": ("mesh", "panel"),
}


# --------------------------------------------------------------------------
# backend hooks
# --------------------------------------------------------------------------

def resolve_backend(backend: str) -> str:
    """Pin ``"auto"`` to the concrete process backend.

    The resolved value keys plan caches, so the REPRO_KERNEL_BACKEND env
    override is captured at resolution time — flipping the env var later
    builds a new executable instead of serving a stale cached one.
    """
    if backend != "auto":
        return backend
    from repro.kernels import ops as _kops
    return _kops.kernel_backend()


def _hooks(backend: str, precision: Optional[str] = None,
           ) -> Tuple[Optional[Callable], Optional[Callable]]:
    """(update_fn, gemm_fn) for the resolved backend; None == inline jnp.

    The resolved backend is passed explicitly to the kernel entry points:
    an engine built for "pallas"/"interpret" always runs the kernel
    bodies, never the jnp reference, whatever the env var says later.
    A mixed-precision route (``precision="bf16"``) always goes through
    the kernel entry points — even on the xla backend — so the operand
    quantization lives in exactly one place (kernels/ops.py).
    """
    backend = resolve_backend(backend)
    if backend == "xla" and precision is None:
        return None, None
    from repro.kernels import ops as _kops
    return (functools.partial(_kops.rank1_update, backend=backend,
                              precision=precision),
            functools.partial(_kops.panel_update, backend=backend,
                              precision=precision))


# --------------------------------------------------------------------------
# shared sign / pivot helpers (used by the engine AND the GE/LU baselines)
# --------------------------------------------------------------------------

def guarded_pivot(p, dtype):
    """A division-safe pivot: 1 where ``p == 0`` (caller masks the result)."""
    return jnp.where(p == 0, jnp.ones((), dtype), p)


def combine_slogdet(parts) -> Tuple[jax.Array, jax.Array]:
    """Combine (sign, logabsdet) contributions multiplicatively."""
    sign = functools.reduce(lambda a, b: a * b, [p[0] for p in parts])
    logdet = functools.reduce(lambda a, b: a + b, [p[1] for p in parts])
    return sign, logdet


def cyclic_perm(n: int, p: int) -> np.ndarray:
    """Permutation mapping block layout to cyclic: out[d*L + i] = i*p + d."""
    return np.arange(n).reshape(n // p, p).T.reshape(-1)


def perm_parity(perm: np.ndarray) -> float:
    """Parity (+1/-1) of a permutation via cycle decomposition (O(n))."""
    seen = np.zeros(len(perm), dtype=bool)
    parity = 1.0
    for start in range(len(perm)):
        if seen[start]:
            continue
        clen = 0
        j = start
        while not seen[j]:
            seen[j] = True
            j = int(perm[j])
            clen += 1
        if clen % 2 == 0:
            parity = -parity
    return parity


# --------------------------------------------------------------------------
# the condensation step (rank-1) — THE shared implementation
# --------------------------------------------------------------------------

def _condense_step(buf: jax.Array, t, n_total: int, sign, logdet, *,
                   update_fn=None, step_fn=None):
    """One condensation step on the full static buffer.

    Live region at step ``t``: rows [t, N), cols [0, N - t).  Pivot row is
    row ``t`` (serial schedule); pivot column is the max-abs entry of the
    live part of row ``t``.  Returns the updated (buf, sign, logdet).

    ``step_fn(buf, t) -> (buf, l, p)`` replaces the three-pass pivot /
    swap / update sequence with the fused one-pass kernel
    (`repro.kernels.ops.fused_condense_step`) — bit-identical buffers;
    the sign/parity/log bookkeeping below is shared by both paths.
    """
    n = n_total
    m = n - t                       # live size (traced)
    last = m - 1

    if step_fn is not None:
        with obs.stage("engine.fused_step"):
            buf, l, p = step_fn(buf, t)
        swap_sign = jnp.where(l == last, 1.0, -1.0).astype(buf.dtype)
    else:
        col_ids = jnp.arange(n)
        live_col = col_ids < m

        with obs.stage("engine.pivot"):
            row = buf[t]                                # (N,)
            absrow = jnp.where(live_col, jnp.abs(row), -jnp.inf)
            l = jnp.argmax(absrow)                      # pivot col (traced)
            p = row[l]                                  # pivot value

        # --- column swap l <-> m-1 (paper §2.4) ----------------------------
        with obs.stage("engine.swap"):
            col_l = buf[:, l]
            col_last = buf[:, last]
            buf = buf.at[:, l].set(col_last)
            buf = buf.at[:, last].set(col_l)
            swap_sign = jnp.where(l == last, 1.0, -1.0).astype(buf.dtype)

            # pivot row in swapped coordinates, normalized by the pivot
            # (§2.3).
            row = row.at[l].set(row[last])
            # row[last] still holds the pre-swap value; the true pivot now
            # sits at position `last` in the buffer.  Force it so
            # pr[last] == 1 exactly, which zeroes the pivot column for all
            # updated rows.
            row = row.at[last].set(p)
            safe_p = guarded_pivot(p, buf.dtype)
            pr = jnp.where(p == 0, jnp.zeros_like(row), row / safe_p)

            # pivot column entries; zero at the pivot row so it stays
            # untouched.
            pc = buf[:, last]
            pc = pc.at[t].set(0.0)
            # Rows above t are dead; zero them too so the baseline buffer
            # stays finite (cosmetic — they are never read again).
            pc = jnp.where(jnp.arange(n) < t, 0.0, pc)

        with obs.stage("engine.update"):
            if update_fn is None:
                buf = buf - jnp.outer(pc, pr)
            else:
                buf = update_fn(buf, pc, pr)

    # sign bookkeeping: pivot sign, column swap, and Laplace expansion of the
    # pivot (active row 0, active column m-1) => (-1)^(m-1).
    parity = jnp.where((m - 1) % 2 == 0, 1.0, -1.0).astype(buf.dtype)
    sign = sign * jnp.sign(p) * swap_sign * parity
    logdet = logdet + jnp.log(jnp.abs(p))
    return buf, sign, logdet


def condense_steps(buf: jax.Array, n_steps: int, *, t0: int = 0,
                   update_fn=None, step_fn=None):
    """Run ``n_steps`` condensation steps starting at step offset ``t0``.

    Returns (buf, sign, logdet) with sign/logdet the *contribution* of these
    steps (combine with `combine_slogdet`).
    """
    n = buf.shape[0]

    def body(t, carry):
        b, s, ld = carry
        return _condense_step(b, t, n, s, ld, update_fn=update_fn,
                              step_fn=step_fn)

    # Derive the initial sign/logdet carries from `buf` so they inherit its
    # varying-manual-axes type when called inside shard_map (tail solve).
    zero = buf[0, 0] * 0
    return lax.fori_loop(t0, t0 + n_steps, body, (buf, zero + 1, zero))


def _step_hooks(use_kernel, fused: bool, precision: Optional[str]):
    """(update_fn, step_fn) for the serial/staged rank-1 drivers.

    ``fused`` routes every step through the one-pass kernel entry;
    otherwise a kernel request or a mixed-precision route builds the
    classic rank-1 update hook (precision quantization lives in
    kernels/ops.py).  (None, None) == inline jnp, the historical path.
    """
    req = _kernel_request(use_kernel)
    if fused:
        from repro.kernels import ops as _kops
        return None, functools.partial(_kops.fused_condense_step,
                                       backend=req or "xla",
                                       precision=precision)
    if req is not None or precision is not None:
        from repro.kernels import ops as _kops
        return functools.partial(_kops.rank1_update, backend=req or "xla",
                                 precision=precision), None
    return None, None


@functools.partial(jax.jit,
                   static_argnames=("use_kernel", "fused", "precision"))
def condense_full(a: jax.Array, *, use_kernel=False, fused: bool = False,
                  precision: Optional[str] = None):
    """Full serial rank-1 condensation — (sign, logabsdet).

    The faithful baseline (legacy `slogdet_condense`): every step updates
    the full static buffer.  ``use_kernel=True`` forces the Pallas rank-1
    kernel body (interpret mode off-TPU) regardless of the backend probe;
    a backend string ("pallas" | "interpret") pins it exactly.
    ``fused=True`` runs each step as ONE pass over the buffer (pivot +
    swap + update, bit-identical); ``precision="bf16"`` quantizes the
    rank-1 operands only.
    """
    n = a.shape[0]
    if a.ndim != 2 or a.shape[1] != n:
        raise ValueError(f"expected square matrix, got {a.shape}")
    if n == 0:
        return jnp.ones((), a.dtype), jnp.zeros((), a.dtype)
    if n == 1:
        return jnp.sign(a[0, 0]), jnp.log(jnp.abs(a[0, 0]))

    update_fn, step_fn = _step_hooks(use_kernel, fused, precision)
    buf, sign, logdet = condense_steps(a, n - 1, update_fn=update_fn,
                                       step_fn=step_fn)
    p = buf[n - 1, 0]
    return sign * jnp.sign(p), logdet + jnp.log(jnp.abs(p))


# --------------------------------------------------------------------------
# the panel (rank-K) primitives — THE shared implementation
# --------------------------------------------------------------------------

def panel_factor(panel: jax.Array, m0, *, r_pos=0, update_fn=None):
    """Factorize a K-row condensation panel.

    Args:
      panel: (K, N) rows to eliminate (static shape; live cols are [0, m0)).
      m0:    live column count before this panel (may be traced).
      r_pos: number of live rows above the panel's rows in the global live
             ordering (0 for the serial schedule; ``p*(L-(r+1)K)`` for the
             round-robin parallel schedule) — used only for sign tracking.

    Returns ``(R, ls, sign, logdet)``:
      R:  (K, N) normalized pivot rows in the final (all-K-swaps) coordinates.
      ls: (K,) pivot column index chosen at each step, *in the coordinates
          current at that step* — consumers must replay the swaps in order.
    """
    K, N = panel.shape
    dt = panel.dtype
    cols = jnp.arange(N)

    def body(k, carry):
        buf, ls, sign, logdet = carry
        m = m0 - k                       # live cols at this step
        last = m - 1
        row = buf[k]
        absrow = jnp.where(cols < m, jnp.abs(row), -jnp.inf)
        l = jnp.argmax(absrow)
        pv = row[l]

        # swap columns l <-> last across the whole panel buffer
        cl = jnp.take(buf, l, axis=1)
        clast = jnp.take(buf, last, axis=1)
        buf = buf.at[:, l].set(clast)
        buf = buf.at[:, last].set(cl)

        # normalize the pivot row; store it back (it becomes R[k])
        row = buf[k]
        safe = guarded_pivot(pv, dt)
        pr = jnp.where(pv == 0, jnp.zeros_like(row), row / safe)
        pr = pr.at[last].set(jnp.where(pv == 0, pr[last], 1.0))
        buf = buf.at[k].set(pr)

        # rank-1 update of the remaining panel rows (k+1..K-1)
        pc = jnp.take(buf, last, axis=1)
        pc = jnp.where(jnp.arange(K) <= k, 0.0, pc)
        if update_fn is None:
            buf = buf - jnp.outer(pc, pr)
        else:
            buf = update_fn(buf, pc, pr)

        ls = ls.at[k].set(l.astype(ls.dtype))
        parity = jnp.where((r_pos + m - 1) % 2 == 0, 1.0, -1.0).astype(dt)
        swap_sign = jnp.where(l == last, 1.0, -1.0).astype(dt)
        sign = sign * jnp.sign(pv) * swap_sign * parity
        logdet = logdet + jnp.log(jnp.abs(pv))
        return buf, ls, sign, logdet

    zero = panel[0, 0] * 0
    ls0 = jnp.zeros((K,), jnp.int32) + (zero * 0).astype(jnp.int32)
    with obs.stage("engine.panel_factor"):
        R, ls, sign, logdet = lax.fori_loop(
            0, K, body, (panel, ls0, zero + 1, zero)
        )
    return R, ls, sign, logdet


def apply_panel(block: jax.Array, R: jax.Array, ls: jax.Array, m0,
                row_mask: jax.Array, *, gemm_fn=None, fused: bool = False):
    """Apply a factorized panel to a trailing row block.

    Args:
      block:    (Lb, N) trailing rows (full static width).
      R, ls:    panel factorization output (R in final coordinates).
      m0:       live columns before the panel.
      row_mask: (Lb,) 1.0 for rows that must be updated, 0.0 for dead/pivot rows.

    Returns the updated block.  ``gemm_fn(block, C, R)`` may override the
    final GEMM (Pallas kernel hook); default is ``block - C @ R``.
    ``fused=True`` replaces the K sequential swap scatters (2K passes
    over the block) with ONE composed-permutation gather — pure data
    movement, bit-identical, and the panel schedule's dominant traffic
    saving (the swaps re-stream the whole trailing block per panel).
    """
    Lb, N = block.shape
    K = R.shape[0]

    if fused:
        # compose the K swaps on an O(N) index vector, then gather once
        def perm_body(k, idx):
            l = ls[k]
            last = m0 - 1 - k
            il = idx[l]
            ilast = idx[last]
            return idx.at[l].set(ilast).at[last].set(il)

        with obs.stage("engine.panel_swap_gather"):
            idx = lax.fori_loop(0, K, perm_body, jnp.arange(N))
            block = jnp.take(block, idx, axis=1)
    else:
        # replay the K column swaps in order: swap ls[k] <-> (m0-1-k)
        def swap_body(k, blk):
            l = ls[k]
            last = m0 - 1 - k
            cl = jnp.take(blk, l, axis=1)
            clast = jnp.take(blk, last, axis=1)
            blk = blk.at[:, l].set(clast)
            blk = blk.at[:, last].set(cl)
            return blk

        block = lax.fori_loop(0, K, swap_body, block)

    # pivot-column block, reversed so column k corresponds to pivot k
    pc_cols = lax.dynamic_slice(block, (0, m0 - K), (Lb, K))   # (Lb, K)
    Pc = jnp.flip(pc_cols, axis=1)

    # T[k', k] = R[k', pos(pivot k)] — unit upper-triangular in (k', k)
    t_cols = lax.dynamic_slice(R, (0, m0 - K), (K, K))
    T = jnp.flip(t_cols, axis=1)

    # C @ T = Pc  =>  T^T C^T = Pc^T (T^T lower, unit diagonal)
    Ct = jax.scipy.linalg.solve_triangular(
        T, Pc.T, trans="T", lower=False, unit_diagonal=True
    )
    C = Ct.T * row_mask[:, None]

    with obs.stage("engine.panel_apply"):
        if gemm_fn is None:
            return block - C @ R
        return gemm_fn(block, C, R)


def _kernel_request(use_kernel) -> Optional[str]:
    """Normalize a driver's ``use_kernel`` argument to a backend request.

    ``False``/``None`` -> None (inline jnp); ``True`` -> "pallas" (the
    historical explicit-kernel spelling; off-TPU it degrades to the
    interpreter inside kernels.ops); a string passes through verbatim so
    an "interpret" config is honored even on TPU.
    """
    if not use_kernel:
        return None
    return "pallas" if use_kernel is True else use_kernel


def panel_factor_dispatch(use_kernel):
    """The panel-factorization hook for a backend choice.

    A truthy ``use_kernel`` (True or a backend string) routes full panels
    through the VMEM-resident Pallas kernel (`kernels.ops
    .panel_factor_vmem`, §Perf P0/It3 — one HBM read + write per panel
    instead of k) whenever the panel fits the VMEM budget; oversized
    panels and the XLA backend use the shared jnp implementation.  Both
    are bit-identical (asserted in test_kernels).
    """
    req = _kernel_request(use_kernel)
    if req is None:
        return lambda panel, m0, r_pos=0, update_fn=None: panel_factor(
            panel, m0, r_pos=r_pos, update_fn=update_fn)

    def factor(panel, m0, r_pos=0, update_fn=None):
        from repro.kernels import ops as _kops
        from repro.kernels.panel_factor import VMEM_BUDGET
        k, n = panel.shape
        if k * n * panel.dtype.itemsize <= VMEM_BUDGET:
            return _kops.panel_factor_vmem(panel, m0, r_pos, backend=req)
        return panel_factor(panel, m0, r_pos=r_pos, update_fn=update_fn)

    return factor


def panel_rounds_serial(buf: jax.Array, n_panels: int, k: int, *,
                        q0: int = 0, gemm_fn=None, update_fn=None,
                        factor_fn=None, fused: bool = False):
    """Run ``n_panels`` serial K-panels starting at panel offset ``q0``.

    The serial-schedule panel loop shared by the blocked driver and the
    staged x panel stages.  Returns (buf, sign, logdet) contributions.
    """
    n = buf.shape[0]
    rows = jnp.arange(n)
    if factor_fn is None:
        factor_fn = panel_factor_dispatch(False)

    def body(q, carry):
        b, sign, logdet = carry
        t0 = q * k
        m0 = n - t0
        panel = lax.dynamic_slice(b, (t0, 0), (k, n))
        R, ls, psign, plogdet = factor_fn(panel, m0, update_fn=update_fn)
        row_mask = (rows >= t0 + k).astype(b.dtype)
        b = apply_panel(b, R, ls, m0, row_mask, gemm_fn=gemm_fn,
                        fused=fused)
        # park the factorized rows back so dead region stays finite
        b = lax.dynamic_update_slice(b, R, (t0, 0))
        return b, sign * psign, logdet + plogdet

    zero = buf[0, 0] * 0
    return lax.fori_loop(q0, q0 + n_panels, body, (buf, zero + 1, zero))


def _gemm_hook(use_kernel, precision: Optional[str]):
    """The trailing-GEMM hook for the serial/staged panel drivers."""
    req = _kernel_request(use_kernel)
    if req is None and precision is None:
        return None
    from repro.kernels import ops as _kops
    return functools.partial(_kops.panel_update, backend=req or "xla",
                             precision=precision)


@functools.partial(jax.jit,
                   static_argnames=("k", "use_kernel", "fused", "precision"))
def blocked_full(a: jax.Array, *, k: int = 32, use_kernel=False,
                 fused: bool = False, precision: Optional[str] = None):
    """Serial blocked condensation: panels of ``k`` rows, rank-k GEMMs.

    Numerically equivalent to `condense_full` up to roundoff; exercises the
    exact panel/trailing structure used by the mesh x panel variant.
    """
    n = a.shape[0]
    if a.ndim != 2 or a.shape[1] != n:
        raise ValueError(f"expected square matrix, got {a.shape}")
    if n <= k:
        return condense_full(a, use_kernel=use_kernel, fused=fused,
                             precision=precision)

    gemm_fn = _gemm_hook(use_kernel, precision)
    n_panels = (n - 1) // k
    buf, sign, logdet = panel_rounds_serial(
        a, n_panels, k, gemm_fn=gemm_fn,
        factor_fn=panel_factor_dispatch(use_kernel), fused=fused)

    # remainder: rank-1 steps from t0 = n_panels*k to n-2, then the 1x1 tail
    t0 = n_panels * k
    if fused or precision is not None:
        update_fn, step_fn = _step_hooks(use_kernel, fused, precision)
    else:
        update_fn, step_fn = None, None  # historical inline-jnp remainder
    buf, rsign, rlogdet = condense_steps(buf, n - 1 - t0, t0=t0,
                                         update_fn=update_fn,
                                         step_fn=step_fn)
    p = buf[n - 1, 0]
    return (sign * rsign * jnp.sign(p),
            logdet + rlogdet + jnp.log(jnp.abs(p)))


# --------------------------------------------------------------------------
# staged schedule (geometric re-jit over shrinking static shapes)
# --------------------------------------------------------------------------

def stage_schedule(n: int, shrink: float, min_size: int):
    """Static (size, steps) schedule: run `steps` at static size `size`."""
    sched = []
    size = n
    while size > min_size:
        nxt = max(min_size, int(math.ceil(size * shrink)))
        steps = size - nxt
        if steps <= 0:
            break
        sched.append((size, steps))
        size = nxt
    sched.append((size, size - 1))  # finish to 1x1
    return sched


@functools.partial(jax.jit, static_argnames=("steps", "use_kernel", "fused",
                                             "precision"))
def _staged_stage_rank1(buf, steps: int, use_kernel=False,
                        fused: bool = False,
                        precision: Optional[str] = None):
    if fused or precision is not None:
        update_fn, step_fn = _step_hooks(use_kernel, fused, precision)
    else:
        update_fn, step_fn = None, None  # historical inline-jnp stages
    b, s, ld = condense_steps(buf, steps, update_fn=update_fn,
                              step_fn=step_fn)
    n = buf.shape[0]
    live = lax.slice(b, (steps, 0), (n, n - steps))
    return live, s, ld


@functools.partial(jax.jit, static_argnames=("steps", "k", "use_kernel",
                                             "fused", "precision"))
def _staged_stage_panel(buf, steps: int, k: int, use_kernel=False,
                        fused: bool = False,
                        precision: Optional[str] = None):
    """One staged stage eliminating ``steps`` rows via K-panels + remainder."""
    gemm_fn = _gemm_hook(use_kernel, precision)
    n = buf.shape[0]
    n_panels = steps // k
    b, s, ld = panel_rounds_serial(
        buf, n_panels, k, gemm_fn=gemm_fn,
        factor_fn=panel_factor_dispatch(use_kernel), fused=fused)
    rem = steps - n_panels * k
    if rem > 0:
        if fused or precision is not None:
            update_fn, step_fn = _step_hooks(use_kernel, fused, precision)
        else:
            update_fn, step_fn = None, None
        b, rs, rld = condense_steps(b, rem, t0=n_panels * k,
                                    update_fn=update_fn, step_fn=step_fn)
        s, ld = s * rs, ld + rld
    live = lax.slice(b, (steps, 0), (n, n - steps))
    return live, s, ld


def staged_full(a: jax.Array, *, shrink: float = 0.75, min_size: int = 64,
                update: str = "rank1", k: int = 32,
                use_kernel=False, fused: bool = False,
                precision: Optional[str] = None):
    """Geometric shape-staged condensation (§Perf optimization 1).

    Runs condensation in stages of static shape, slicing out the live prefix
    between stages.  FLOP waste drops from ~3x (full static buffer) to ~1.5x
    with shrink=0.75 at the cost of a handful of compilations.  With
    ``update="panel"`` each stage runs rank-K panels (MXU GEMMs) instead of
    rank-1 steps — the schedule x update combination no legacy route named.
    """
    n = a.shape[0]
    if n <= min_size:
        if update == "panel" and n > k:
            return blocked_full(a, k=k, use_kernel=use_kernel, fused=fused,
                                precision=precision)
        return condense_full(a, use_kernel=use_kernel, fused=fused,
                             precision=precision)
    parts = []
    buf = a
    for size, steps in stage_schedule(n, shrink, min_size):
        if buf.shape[0] != size:  # defensive; schedule and buffer must agree
            raise AssertionError((buf.shape, size))
        if size - steps <= 1:
            if update == "panel" and size > k:
                parts.append(blocked_full(buf, k=k, use_kernel=use_kernel,
                                          fused=fused, precision=precision))
            else:
                parts.append(condense_full(buf, use_kernel=use_kernel,
                                           fused=fused, precision=precision))
            buf = None
            break
        if update == "panel" and steps >= k:
            buf, s, ld = _staged_stage_panel(buf, steps, k, use_kernel,
                                             fused, precision)
        else:
            buf, s, ld = _staged_stage_rank1(buf, steps, use_kernel,
                                             fused, precision)
        parts.append((s, ld))
    if buf is not None:
        if update == "panel" and buf.shape[0] > k:
            parts.append(blocked_full(buf, k=k, use_kernel=use_kernel,
                                      fused=fused, precision=precision))
        else:
            parts.append(condense_full(buf, use_kernel=use_kernel,
                                       fused=fused, precision=precision))
    return combine_slogdet(parts)


# --------------------------------------------------------------------------
# mesh schedule (round-robin block rows, shard_map)
# --------------------------------------------------------------------------

def mc_step_fn(axis_name: str, *, update_fn=None):
    """Per-global-step body of parallel MC for use inside shard_map.

    ``local`` has shape (L, N) — the device's contiguous row block.  Global
    step ``t`` maps to (round ``i = t // P``, owner ``p = t % P``); the owner
    eliminates its local row ``i``.  Returns ``step(t, carry)`` with carry
    ``(local, sign, logdet)`` where sign/logdet are *per-device partial*
    contributions (combine with psum / product at the end, paper step 6).
    """

    def step(t, carry):
        local, sign, logdet = carry
        L, N = local.shape
        P = _axis_size(axis_name)
        me = lax.axis_index(axis_name)
        i = t // P                            # round = owner's local row index
        p = t % P                             # owner device
        m = N - t                             # live column count
        last = m - 1                          # post-swap pivot column
        mine = me == p

        # ---- owner: local pivot choice + row normalization (no comm) -------
        with obs.stage("engine.pivot"):
            row = local[i]
            live_col = jnp.arange(N) < m
            absrow = jnp.where(live_col, jnp.abs(row), -jnp.inf)
            l = jnp.argmax(absrow)
            pv = row[l]
            # swap l <-> last inside the pivot row, normalize: pr[last] == 1
            rl, rlast = row[l], row[last]
            row = row.at[l].set(rlast).at[last].set(pv)
            safe = guarded_pivot(pv, local.dtype)
            pr = jnp.where(pv == 0, jnp.zeros_like(row), row / safe)
            pr = pr.at[last].set(jnp.where(pv == 0, pr[last], 1.0))

        # ---- broadcast: ONE collective for (normalized row, column index) ---
        with obs.stage("engine.broadcast"):
            pr_b, l_b = lax.psum(
                (jnp.where(mine, pr, jnp.zeros_like(pr)),
                 jnp.where(mine, l, jnp.zeros_like(l))),
                axis_name,
            )

        # ---- every device: column swap l_b <-> last on its block ------------
        with obs.stage("engine.swap"):
            cl = jnp.take(local, l_b, axis=1)
            clast = jnp.take(local, last, axis=1)
            local = local.at[:, l_b].set(clast)
            local = local.at[:, last].set(cl)

        # ---- rank-1 condensation update on live rows -------------------------
        with obs.stage("engine.update"):
            pc = jnp.take(local, last, axis=1)
            dead = i + (me <= p)              # rows [0, dead) are retired
            pc = jnp.where(jnp.arange(L) < dead, 0.0, pc)
            if update_fn is None:
                local = local - jnp.outer(pc, pr_b)
            else:
                local = update_fn(local, pc, pr_b)

        # ---- owner accumulates its logdet/sign contribution ------------------
        r_pos = p * (L - 1 - i)               # live rows above the pivot row
        parity = jnp.where((r_pos + m - 1) % 2 == 0, 1.0, -1.0).astype(local.dtype)
        swap_sign = jnp.where(l == last, 1.0, -1.0).astype(local.dtype)
        step_sign = jnp.sign(pv) * swap_sign * parity
        sign = jnp.where(mine, sign * step_sign, sign)
        logdet = logdet + jnp.where(mine, jnp.log(jnp.abs(pv)), 0.0)
        return local, sign, logdet

    return step


def mc_local_phase(local, axis_name: str, *, t0: int = 0,
                   n_steps: int | None = None, update_fn=None):
    """Run the distributed condensation phase; local block (L, N).

    Returns (local, sign_partial, logdet_partial) after ``n_steps`` global
    steps starting at ``t0`` (default: the full ``(L-1)*P`` schedule).
    """
    L, N = local.shape
    P = _axis_size(axis_name)
    if n_steps is None:
        n_steps = (L - 1) * P - t0
    step = mc_step_fn(axis_name, update_fn=update_fn)
    sign0 = _pvary(jnp.ones((), local.dtype), axis_name)
    ld0 = _pvary(jnp.zeros((), local.dtype), axis_name)
    return lax.fori_loop(t0, t0 + n_steps, step, (local, sign0, ld0))


def mesh_tail(local, sign, logdet, axis_name: str):
    """The shared P x P tail reduction (paper pseudocode steps 5-8).

    Each device holds ONE live row (its last); ``all_gather`` forms the
    final P x P matrix, the tail slogdet runs redundantly on every device
    (cheaper than gather-to-master + scalar scatter on TPU), and the
    per-device partial (sign, logdet) contributions combine via
    psum / all_gather-product.  Returns per-device (1,) outputs for the
    shard_map out_specs.
    """
    L, N = local.shape
    P = _axis_size(axis_name)
    with obs.stage("engine.mesh_tail"):
        # slice the live columns (the [0, P) prefix) BEFORE the gather:
        # the collective moves 8*P^2 bytes, not 8*N*P — gathering full
        # rows only to discard N - P columns inflated tail traffic N/P x
        live = lax.dynamic_slice(local, (L - 1, 0), (1, N))[0, :]
        live = lax.slice(live, (0,), (P,))          # live cols are prefix
        tail = lax.all_gather(live, axis_name)      # (P, P): device-ordered
        tsign, tlogdet = condense_full(tail)        # redundant on all devs

        logdet_total = lax.psum(logdet, axis_name) + tlogdet
        signs = lax.all_gather(sign, axis_name)
        sign_total = jnp.prod(signs) * tsign
        return sign_total.reshape(1), logdet_total.reshape(1)


def _mesh_rank1_kernel(axis_name: str, update_fn=None):
    def kernel(local):
        local, sign, logdet = mc_local_phase(local, axis_name,
                                             update_fn=update_fn)
        return mesh_tail(local, sign, logdet, axis_name)

    return kernel


def _mesh_panel_kernel(axis_name: str, k: int, *, gemm_fn=None,
                       update_fn=None, factor_fn=None):
    """Round-robin K-panel mesh kernel.

    Device ``p`` factorizes panels of ``k`` of its own rows (keeping MC's
    local pivoting — still no global pivot search), broadcasts ``(R, ls)``
    once per panel, and every device applies the rank-k GEMM to its live
    rows.  Remainder rows use the rank-1 schedule; the final P x P tail is
    gathered and solved redundantly (`mesh_tail`).
    """

    if factor_fn is None:
        factor_fn = panel_factor_dispatch(False)

    def kernel(local):
        L, N = local.shape
        P = _axis_size(axis_name)
        me = lax.axis_index(axis_name)
        n_rounds = (L - 1) // k
        lrow = jnp.arange(L)
        zero = local[0, 0] * 0

        def panel_step(g, carry):
            """Global panel index g = r*P + p."""
            local, sign, logdet = carry
            r = g // P
            p = g % P
            t0 = g * k
            m0 = N - t0
            mine = me == p

            panel = lax.dynamic_slice(local, (r * k, 0), (k, N))
            r_pos = p * (L - (r + 1) * k)
            R, ls, psign, plogdet = factor_fn(panel, m0, r_pos=r_pos,
                                              update_fn=update_fn)

            R_b, ls_b = lax.psum(
                (jnp.where(mine, R, jnp.zeros_like(R)),
                 jnp.where(mine, ls, jnp.zeros_like(ls))),
                axis_name,
            )

            dead = jnp.where(me <= p, (r + 1) * k, r * k)
            row_mask = (lrow >= dead).astype(local.dtype)
            local = apply_panel(local, R_b, ls_b, m0, row_mask,
                                gemm_fn=gemm_fn)

            sign = jnp.where(mine, sign * psign, sign)
            logdet = logdet + jnp.where(mine, plogdet, zero)
            return local, sign, logdet

        carry = (local, zero + 1, zero)
        if n_rounds > 0:  # static: L, k known at trace time
            carry = lax.fori_loop(0, n_rounds * P, panel_step, carry)
        local, sign, logdet = carry

        # remainder rows: rank-1 schedule continuing at t = n_rounds*k per dev
        rem = (L - 1) - n_rounds * k
        if rem > 0:
            step = mc_step_fn(axis_name, update_fn=update_fn)
            t_start = n_rounds * k * P
            local, rsign, rlogdet = lax.fori_loop(
                t_start, t_start + rem * P, step, (local, zero + 1, zero))
            sign = sign * rsign
            logdet = logdet + rlogdet

        return mesh_tail(local, sign, logdet, axis_name)

    return kernel


# --------------------------------------------------------------------------
# lookahead mesh kernels (double-buffered broadcast, LU-style pipelining)
# --------------------------------------------------------------------------
#
# The plain mesh kernels serialize per step: factor -> broadcast -> bulk
# update, so every collective sits on the critical path between the
# owner's factorization and everyone's trailing update.  The lookahead
# kernels restructure the loop so the broadcast of step/panel g+1 is
# *issued before* the bulk update of step/panel g and only *consumed on
# the next iteration* — double buffering.  With no data dependency
# between the in-flight collective and the trailing update, XLA's
# latency-hiding scheduler overlaps them; per panel the exposed
# (non-overlapped) collective count drops from one to zero at steady
# state.
#
# The price is an early apply: before the owner of g+1 can factor its
# rows, those rows need step/panel g applied.  The early apply runs on a
# sliced COPY (k x N for panels, 1 x N for rank-1) with exactly the
# per-row arithmetic of the bulk update, so the pivots it selects — and
# therefore (sign, logabsdet) — are bit-identical to the non-lookahead
# schedule (asserted across schedule x update x P in tests).  `local`
# itself is only ever advanced by the same bulk updates as before.


def _mesh_rank1_lookahead_kernel(axis_name: str, update_fn=None):
    """Rank-1 mesh kernel with single-row lookahead.

    Carry holds the already-broadcast ``(pr, l)`` of the current step;
    each iteration early-applies the current step to the *next* pivot
    row, factors/normalizes it, issues its broadcast, and only then runs
    the bulk rank-1 update of the current step.
    """

    def select_pivot(row, m, dtype):
        """Pivot choice + §2.3/§2.4 row normalization (owner-local)."""
        N = row.shape[0]
        last = m - 1
        absrow = jnp.where(jnp.arange(N) < m, jnp.abs(row), -jnp.inf)
        l = jnp.argmax(absrow)
        pv = row[l]
        rlast = row[last]
        row = row.at[l].set(rlast).at[last].set(pv)
        safe = guarded_pivot(pv, dtype)
        pr = jnp.where(pv == 0, jnp.zeros_like(row), row / safe)
        pr = pr.at[last].set(jnp.where(pv == 0, pr[last], 1.0))
        return pr, l, pv

    def kernel(local):
        L, N = local.shape
        P = _axis_size(axis_name)
        me = lax.axis_index(axis_name)
        dt = local.dtype
        zero = local[0, 0] * 0                # device-varying scalar zero
        n_steps = (L - 1) * P
        if n_steps == 0:
            return mesh_tail(local, zero + 1, zero, axis_name)

        def bcast(pr, l, mine):
            return lax.psum(
                (jnp.where(mine, pr, jnp.zeros_like(pr)),
                 jnp.where(mine, l, jnp.zeros_like(l))),
                axis_name,
            )

        def contribution(pv, l, m, i, p, sign, logdet, mine):
            r_pos = p * (L - 1 - i)
            parity = jnp.where((r_pos + m - 1) % 2 == 0, 1.0, -1.0).astype(dt)
            swap_sign = jnp.where(l == m - 1, 1.0, -1.0).astype(dt)
            step_sign = jnp.sign(pv) * swap_sign * parity
            sign = jnp.where(mine, sign * step_sign, sign)
            logdet = logdet + jnp.where(mine, jnp.log(jnp.abs(pv)), zero)
            return sign, logdet

        # prologue: step 0's pivot row, broadcast in flight before the loop
        pr0, l0, pv0 = select_pivot(local[0], N, dt)
        sign, logdet = contribution(pv0, l0, N, 0, 0, zero + 1, zero, me == 0)
        pr_b, l_b = bcast(pr0, l0, me == 0)

        def body(t, carry):
            local, pr_b, l_b, sign, logdet = carry
            m = N - t
            last = m - 1

            # ---- lookahead: early-apply step t to the NEXT pivot row,
            # factor it, and issue its broadcast before the bulk update
            with obs.stage("engine.lookahead_factor"):
                t1 = t + 1
                i1 = t1 // P
                p1 = t1 % P
                mine1 = me == p1
                row = local[i1]
                rl, rlast = row[l_b], row[last]
                row = row.at[l_b].set(rlast).at[last].set(rl)
                pc_i = row[last]
                if update_fn is None:
                    row = (row[None, :]
                           - jnp.outer(pc_i[None], pr_b))[0]
                else:
                    row = update_fn(row[None, :], pc_i[None], pr_b)[0]
                pr1, l1, pv1 = select_pivot(row, m - 1, dt)
            with obs.stage("engine.broadcast"):
                pr_nb, l_nb = bcast(pr1, l1, mine1)

            # ---- bulk: the plain step-t swap + rank-1 update ------------
            with obs.stage("engine.swap"):
                cl = jnp.take(local, l_b, axis=1)
                clast = jnp.take(local, last, axis=1)
                local = local.at[:, l_b].set(clast)
                local = local.at[:, last].set(cl)
            with obs.stage("engine.update"):
                i = t // P
                p = t % P
                pc = jnp.take(local, last, axis=1)
                dead = i + (me <= p)
                pc = jnp.where(jnp.arange(L) < dead, 0.0, pc)
                if update_fn is None:
                    local = local - jnp.outer(pc, pr_b)
                else:
                    local = update_fn(local, pc, pr_b)

            sign, logdet = contribution(pv1, l1, m - 1, i1, p1,
                                        sign, logdet, mine1)
            return local, pr_nb, l_nb, sign, logdet

        carry = (local, pr_b, l_b, sign, logdet)
        if n_steps > 1:
            carry = lax.fori_loop(0, n_steps - 1, body, carry)
        local, pr_b, l_b, sign, logdet = carry

        # epilogue: bulk update of the final step (its broadcast is the
        # one left in the carry; no further lookahead to issue)
        t_last = n_steps - 1
        m = N - t_last
        last = m - 1
        cl = jnp.take(local, l_b, axis=1)
        clast = jnp.take(local, last, axis=1)
        local = local.at[:, l_b].set(clast)
        local = local.at[:, last].set(cl)
        pc = jnp.take(local, last, axis=1)
        dead = t_last // P + (me <= t_last % P)
        pc = jnp.where(jnp.arange(L) < dead, 0.0, pc)
        if update_fn is None:
            local = local - jnp.outer(pc, pr_b)
        else:
            local = update_fn(local, pc, pr_b)

        return mesh_tail(local, sign, logdet, axis_name)

    return kernel


def _mesh_panel_lookahead_kernel(axis_name: str, k: int, *, gemm_fn=None,
                                 update_fn=None, factor_fn=None):
    """Round-robin K-panel mesh kernel with LU-style lookahead.

    The owner of panel g+1 factors it from an early-applied (K x N) copy
    while every device still has the bulk rank-K GEMM of panel g ahead of
    it in program order; the ``(R, ls)`` broadcast of panel g+1 is issued
    between the two, double-buffered through the loop carry, so the
    collective overlaps the trailing GEMM instead of serializing with
    it.  Remainder rows and the P x P tail are shared with the plain
    kernel (bit-identical by construction).
    """

    if factor_fn is None:
        factor_fn = panel_factor_dispatch(False)

    def kernel(local):
        L, N = local.shape
        P = _axis_size(axis_name)
        me = lax.axis_index(axis_name)
        n_rounds = (L - 1) // k
        n_panels = n_rounds * P
        lrow = jnp.arange(L)
        zero = local[0, 0] * 0
        ones_k = jnp.ones((k,), local.dtype)

        def factor_at(local, g):
            """Factor global panel g from MY rows (valid on the owner)."""
            r = g // P
            p = g % P
            panel = lax.dynamic_slice(local, (r * k, 0), (k, N))
            r_pos = p * (L - (r + 1) * k)
            return factor_fn(panel, N - g * k, r_pos=r_pos,
                             update_fn=update_fn)

        def bcast(R, ls, mine):
            return lax.psum(
                (jnp.where(mine, R, jnp.zeros_like(R)),
                 jnp.where(mine, ls, jnp.zeros_like(ls))),
                axis_name,
            )

        def bulk_apply(local, R_b, ls_b, g):
            r = g // P
            p = g % P
            dead = jnp.where(me <= p, (r + 1) * k, r * k)
            row_mask = (lrow >= dead).astype(local.dtype)
            return apply_panel(local, R_b, ls_b, N - g * k, row_mask,
                               gemm_fn=gemm_fn)

        sign, logdet = zero + 1, zero
        if n_panels > 0:
            # prologue: factor + broadcast panel 0 (no trailing GEMM to
            # hide it behind yet)
            R0, ls0, psign0, plogdet0 = factor_at(local, 0)
            mine0 = me == 0
            sign = jnp.where(mine0, sign * psign0, sign)
            logdet = logdet + jnp.where(mine0, plogdet0, zero)
            R_b, ls_b = bcast(R0, ls0, mine0)

            def panel_step(g, carry):
                """Bulk-apply panel g; lookahead-factor + broadcast g+1."""
                local, R_b, ls_b, sign, logdet = carry
                g1 = g + 1
                r1 = g1 // P
                p1 = g1 % P
                mine1 = me == p1

                # ---- lookahead: early-apply panel g to MY candidate
                # rows for panel g+1 (a sliced copy — `local` is only
                # ever advanced by the bulk applies), then factor
                with obs.stage("engine.lookahead_factor"):
                    nxt = lax.dynamic_slice(local, (r1 * k, 0), (k, N))
                    nxt = apply_panel(nxt, R_b, ls_b, N - g * k, ones_k,
                                      gemm_fn=gemm_fn)
                    r_pos1 = p1 * (L - (r1 + 1) * k)
                    R1, ls1, psign1, plogdet1 = factor_fn(
                        nxt, N - g1 * k, r_pos=r_pos1, update_fn=update_fn)
                # issue the double-buffered broadcast of panel g+1 — no
                # data dependency with the bulk GEMM below, so the
                # collective can overlap it
                with obs.stage("engine.broadcast"):
                    R_nb, ls_nb = bcast(R1, ls1, mine1)

                # ---- bulk rank-K GEMM of panel g on the live rows -------
                local = bulk_apply(local, R_b, ls_b, g)

                sign = jnp.where(mine1, sign * psign1, sign)
                logdet = logdet + jnp.where(mine1, plogdet1, zero)
                return local, R_nb, ls_nb, sign, logdet

            carry = (local, R_b, ls_b, sign, logdet)
            if n_panels > 1:
                carry = lax.fori_loop(0, n_panels - 1, panel_step, carry)
            local, R_b, ls_b, sign, logdet = carry
            # epilogue: the last panel's bulk GEMM
            local = bulk_apply(local, R_b, ls_b, n_panels - 1)

        # remainder rows: rank-1 schedule continuing at t = n_rounds*k per
        # device — shared with the plain kernel, bit-identical
        rem = (L - 1) - n_rounds * k
        if rem > 0:
            step = mc_step_fn(axis_name, update_fn=update_fn)
            t_start = n_rounds * k * P
            local, rsign, rlogdet = lax.fori_loop(
                t_start, t_start + rem * P, step, (local, zero + 1, zero))
            sign = sign * rsign
            logdet = logdet + rlogdet

        return mesh_tail(local, sign, logdet, axis_name)

    return kernel


# --------------------------------------------------------------------------
# engine builders — the single entry points every route resolves to
# --------------------------------------------------------------------------

def build_serial(cfg: EngineConfig) -> Callable:
    """``a -> (sign, logabsdet)`` for the serial / staged schedules."""
    if cfg.schedule == "mesh":
        raise ValueError("mesh schedule needs build_mesh(cfg, mesh)")
    rb = resolve_backend(cfg.backend)
    # drivers take the exact backend string so "interpret" is honored
    # even on TPU (False == inline jnp, same as the xla hooks)
    use_kernel = False if rb == "xla" else rb

    if cfg.schedule == "serial":
        if cfg.update == "rank1":
            return lambda a: condense_full(a, use_kernel=use_kernel,
                                           fused=cfg.fused,
                                           precision=cfg.precision)
        k = cfg.panel_k
        return lambda a: blocked_full(a, k=k, use_kernel=use_kernel,
                                      fused=cfg.fused,
                                      precision=cfg.precision)

    # staged
    return lambda a: staged_full(
        a, shrink=cfg.shrink, min_size=cfg.min_size, update=cfg.update,
        k=cfg.panel_k, use_kernel=use_kernel, fused=cfg.fused,
        precision=cfg.precision)


def build_mesh(cfg: EngineConfig, mesh, axis_name: str = "rows", *,
               update_fn=None, gemm_fn=None) -> Callable:
    """``a -> (sign, logabsdet)`` over a 1-D device mesh.

    ``update_fn`` / ``gemm_fn`` override the backend hooks (benchmark /
    test injection); by default they resolve from ``cfg.backend``.
    """
    if cfg.schedule != "mesh":
        raise ValueError(f"build_mesh needs schedule='mesh', got {cfg.schedule!r}")
    nproc = int(mesh.shape[axis_name])
    factor_fn = None
    if update_fn is None and gemm_fn is None:
        update_fn, gemm_fn = _hooks(cfg.backend, cfg.precision)
        if gemm_fn is not None and resolve_backend(cfg.backend) != "xla":
            factor_fn = panel_factor_dispatch(resolve_backend(cfg.backend))

    if cfg.update == "rank1":
        if cfg.lookahead:
            kernel = _mesh_rank1_lookahead_kernel(axis_name,
                                                  update_fn=update_fn)
        else:
            kernel = _mesh_rank1_kernel(axis_name, update_fn=update_fn)
    elif cfg.lookahead:
        kernel = _mesh_panel_lookahead_kernel(axis_name, cfg.panel_k,
                                              gemm_fn=gemm_fn,
                                              update_fn=update_fn,
                                              factor_fn=factor_fn)
    else:
        kernel = _mesh_panel_kernel(axis_name, cfg.panel_k,
                                    gemm_fn=gemm_fn, update_fn=update_fn,
                                    factor_fn=factor_fn)

    shmapped = _shard_map(
        kernel,
        mesh=mesh,
        in_specs=(PartitionSpec(axis_name, None),),
        out_specs=(PartitionSpec(axis_name), PartitionSpec(axis_name)),
    )

    @jax.jit
    def run(a):
        n = a.shape[0]
        if n % nproc:
            raise ValueError(f"N={n} not divisible by mesh size {nproc}")
        sign, logdet = shmapped(a)
        return sign[0], logdet[0]

    return run


def engine_slogdet(a: jax.Array, cfg: EngineConfig = EngineConfig(), *,
                   mesh=None, axis_name: str = "rows"):
    """One-shot engine execution (tests / benchmarks / exploration).

    Production code should build once via `build_serial` / `build_mesh`
    (or, better, `repro.plan(..., method="exact", ...)`) and reuse.
    """
    if cfg.schedule == "mesh":
        if mesh is None:
            raise ValueError("mesh schedule requires a mesh")
        return build_mesh(cfg, mesh, axis_name)(a)
    return build_serial(cfg)(a)
