"""Blocked (rank-K panel) condensation — engine instantiations.

The panel primitives (`panel_factor`, `apply_panel`) and the drivers live
in `repro.core.engine`; this module keeps the historical names as thin
wrappers over the engine's ``update="panel"`` routes.

Why panels: the faithful rank-1 condensation has arithmetic intensity
~0.25 FLOP/byte and is HBM-bandwidth-bound.  Accumulating K pivot rows
into a panel and applying the trailing update as ONE rank-K GEMM raises
intensity to ~K/2 and moves the work onto the MXU — while keeping both of
MC's schedule freedoms (local pivot-column choice inside the panel, block
row distribution, no global pivot search).  Communication per K rows on
the mesh schedule: one (K x N + K) broadcast — K-fold fewer collectives
than rank-1 MC at the same total bytes.
"""
from __future__ import annotations

from repro.core.engine import (
    EngineConfig,
    apply_panel,
    blocked_full as slogdet_condense_blocked,
    build_mesh,
    panel_factor,
)

__all__ = [
    "panel_factor",
    "apply_panel",
    "slogdet_condense_blocked",
    "parallel_slogdet_mc_blocked",
]


def parallel_slogdet_mc_blocked(mesh, axis_name: str = "rows", *, k: int = 32,
                                gemm_fn=None, lookahead: bool = False):
    """Parallel blocked MC over a 1-D mesh: engine route (mesh, panel).

    Device ``p`` factorizes panels of ``k`` of its own rows, broadcasts
    ``(R, ls)`` once per panel, and every device applies the rank-k GEMM
    to its live rows; remainder rows use the rank-1 schedule and the
    P x P tail is gathered and solved redundantly (`engine.mesh_tail`).

    ``lookahead=True`` pipelines the schedule LU-style: the owner of panel
    g+1 factors it from an early-applied copy while the bulk rank-k GEMM
    of panel g is still pending, and the ``(R, ls)`` broadcast of panel
    g+1 is double-buffered through the loop carry so the collective
    overlaps compute instead of serializing with it.  Results are
    bit-identical to ``lookahead=False`` (asserted in tests/test_engine).
    """
    cfg = EngineConfig(schedule="mesh", update="panel", panel_k=k,
                       backend="xla", lookahead=lookahead)
    return build_mesh(cfg, mesh, axis_name, gemm_fn=gemm_fn)
