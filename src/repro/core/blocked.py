"""Blocked (rank-K panel) matrix condensation — the paper's "future work".

The faithful rank-1 condensation has arithmetic intensity ~0.25 FLOP/byte and
is HBM-bandwidth-bound on TPU.  Accumulating K pivot rows into a panel and
applying the trailing update as ONE rank-K GEMM raises intensity to ~K/2 and
moves the work onto the MXU — while keeping both of MC's schedule freedoms
(local pivot-column choice inside the panel, block row distribution, no global
pivot search).  This is the main beyond-paper optimization (§Perf).

Structure per panel (right-looking, like blocked LU but with MC pivoting):

  1. *Panel factorization* (owner rows, K x N buffer): K rank-1 condensation
     steps restricted to the panel rows; each step picks its pivot column by
     max-|.| over live columns, swaps it to the live end, normalizes.  All
     swaps are applied to the whole panel buffer so the stored rows stay in
     one consistent coordinate system; the buffer ends up holding
     ``R`` (K x N), with ``R[k]`` having 1 at its own pivot column and 0 at
     earlier pivots' columns.
  2. *Broadcast* ``(R, pivot cols)`` — ONE collective per K rows (the paper's
     per-row broadcast, amortized K-fold).
  3. *Trailing update*: apply the K column swaps, read the pivot-column block
     ``Pc`` (rows x K), solve the K x K unit-triangular system
     ``C @ T = Pc`` (T read from R's pivot columns), then ``A -= C @ R``
     — the MXU GEMM.

Communication per K rows: one (K x N + K) broadcast — K-fold fewer collectives
than rank-1 MC, K-fold larger payload (same bytes, far fewer latencies).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec

from repro._compat import axis_size as _axis_size, shard_map as _shard_map
from repro.core.condense import condense_steps, slogdet_condense
from repro.core.parallel import mc_step_fn

__all__ = [
    "panel_factor",
    "apply_panel",
    "slogdet_condense_blocked",
    "parallel_slogdet_mc_blocked",
]


def panel_factor(panel: jax.Array, m0, *, r_pos=0, update_fn=None):
    """Factorize a K-row condensation panel.

    Args:
      panel: (K, N) rows to eliminate (static shape; live cols are [0, m0)).
      m0:    live column count before this panel (may be traced).
      r_pos: number of live rows above the panel's rows in the global live
             ordering (0 for the serial schedule; ``p*(L-(r+1)K)`` for the
             round-robin parallel schedule) — used only for sign tracking.

    Returns ``(R, ls, sign, logdet)``:
      R:  (K, N) normalized pivot rows in the final (all-K-swaps) coordinates.
      ls: (K,) pivot column index chosen at each step, *in the coordinates
          current at that step* — consumers must replay the swaps in order.
    """
    K, N = panel.shape
    dt = panel.dtype
    cols = jnp.arange(N)

    def body(k, carry):
        buf, ls, sign, logdet = carry
        m = m0 - k                       # live cols at this step
        last = m - 1
        row = buf[k]
        absrow = jnp.where(cols < m, jnp.abs(row), -jnp.inf)
        l = jnp.argmax(absrow)
        pv = row[l]

        # swap columns l <-> last across the whole panel buffer
        cl = jnp.take(buf, l, axis=1)
        clast = jnp.take(buf, last, axis=1)
        buf = buf.at[:, l].set(clast)
        buf = buf.at[:, last].set(cl)

        # normalize the pivot row; store it back (it becomes R[k])
        row = buf[k]
        safe = jnp.where(pv == 0, jnp.ones((), dt), pv)
        pr = jnp.where(pv == 0, jnp.zeros_like(row), row / safe)
        pr = pr.at[last].set(jnp.where(pv == 0, pr[last], 1.0))
        buf = buf.at[k].set(pr)

        # rank-1 update of the remaining panel rows (k+1..K-1)
        pc = jnp.take(buf, last, axis=1)
        pc = jnp.where(jnp.arange(K) <= k, 0.0, pc)
        if update_fn is None:
            buf = buf - jnp.outer(pc, pr)
        else:
            buf = update_fn(buf, pc, pr)

        ls = ls.at[k].set(l.astype(ls.dtype))
        parity = jnp.where((r_pos + m - 1) % 2 == 0, 1.0, -1.0).astype(dt)
        swap_sign = jnp.where(l == last, 1.0, -1.0).astype(dt)
        sign = sign * jnp.sign(pv) * swap_sign * parity
        logdet = logdet + jnp.log(jnp.abs(pv))
        return buf, ls, sign, logdet

    zero = panel[0, 0] * 0
    ls0 = jnp.zeros((K,), jnp.int32) + (zero * 0).astype(jnp.int32)
    R, ls, sign, logdet = lax.fori_loop(
        0, K, body, (panel, ls0, zero + 1, zero)
    )
    return R, ls, sign, logdet


def apply_panel(block: jax.Array, R: jax.Array, ls: jax.Array, m0,
                row_mask: jax.Array, *, gemm_fn=None):
    """Apply a factorized panel to a trailing row block.

    Args:
      block:    (Lb, N) trailing rows (full static width).
      R, ls:    panel factorization output (R in final coordinates).
      m0:       live columns before the panel.
      row_mask: (Lb,) 1.0 for rows that must be updated, 0.0 for dead/pivot rows.

    Returns the updated block.  ``gemm_fn(block, C, R)`` may override the
    final GEMM (Pallas kernel hook); default is ``block - C @ R``.
    """
    Lb, N = block.shape
    K = R.shape[0]

    # replay the K column swaps in order: swap ls[k] <-> (m0-1-k)
    def swap_body(k, blk):
        l = ls[k]
        last = m0 - 1 - k
        cl = jnp.take(blk, l, axis=1)
        clast = jnp.take(blk, last, axis=1)
        blk = blk.at[:, l].set(clast)
        blk = blk.at[:, last].set(cl)
        return blk

    block = lax.fori_loop(0, K, swap_body, block)

    # pivot-column block, reversed so column k corresponds to pivot k
    pc_cols = lax.dynamic_slice(block, (0, m0 - K), (Lb, K))   # (Lb, K)
    Pc = jnp.flip(pc_cols, axis=1)

    # T[k', k] = R[k', pos(pivot k)] — unit upper-triangular in (k', k)
    t_cols = lax.dynamic_slice(R, (0, m0 - K), (K, K))
    T = jnp.flip(t_cols, axis=1)

    # C @ T = Pc  =>  T^T C^T = Pc^T (T^T lower, unit diagonal)
    Ct = jax.scipy.linalg.solve_triangular(
        T, Pc.T, trans="T", lower=False, unit_diagonal=True
    )
    C = Ct.T * row_mask[:, None]

    if gemm_fn is None:
        return block - C @ R
    return gemm_fn(block, C, R)


@functools.partial(jax.jit, static_argnames=("k", "use_kernel"))
def slogdet_condense_blocked(a: jax.Array, *, k: int = 32, use_kernel: bool = False):
    """Serial blocked condensation: panels of ``k`` rows, rank-k GEMM updates.

    Numerically equivalent to `slogdet_condense` up to roundoff; exercises the
    exact panel/trailing structure used by the parallel blocked variant.
    """
    n = a.shape[0]
    if a.ndim != 2 or a.shape[1] != n:
        raise ValueError(f"expected square matrix, got {a.shape}")
    if n <= k:
        return slogdet_condense(a)

    gemm_fn = None
    if use_kernel:
        from repro.kernels import ops as _kops
        gemm_fn = _kops.panel_update

    n_panels = (n - 1) // k
    rows = jnp.arange(n)

    def body(q, carry):
        buf, sign, logdet = carry
        t0 = q * k
        m0 = n - t0
        panel = lax.dynamic_slice(buf, (t0, 0), (k, n))
        R, ls, psign, plogdet = panel_factor(panel, m0)
        row_mask = (rows >= t0 + k).astype(buf.dtype)
        buf = apply_panel(buf, R, ls, m0, row_mask, gemm_fn=gemm_fn)
        # park the factorized rows back so dead region stays finite
        buf = lax.dynamic_update_slice(buf, R, (t0, 0))
        return buf, sign * psign, logdet + plogdet

    zero = a[0, 0] * 0
    buf, sign, logdet = lax.fori_loop(0, n_panels, body, (a, zero + 1, zero))

    # remainder: rank-1 steps from t0 = n_panels*k to n-2, then the 1x1 tail
    t0 = n_panels * k
    buf, rsign, rlogdet = condense_steps(buf, n - 1 - t0, t0=t0)
    p = buf[n - 1, 0]
    return (sign * rsign * jnp.sign(p),
            logdet + rlogdet + jnp.log(jnp.abs(p)))


def parallel_slogdet_mc_blocked(mesh, axis_name: str = "rows", *, k: int = 32,
                                gemm_fn=None, lookahead: bool = False):
    """Parallel blocked MC over a 1-D mesh: block rows, round-robin K-panels.

    Device ``p`` factorizes panels of ``k`` of its own rows (keeping MC's
    local pivoting — still no global pivot search), broadcasts ``(R, ls)``
    once per panel, and every device applies the rank-k GEMM to its live rows.
    Remainder rows use the rank-1 schedule; the final P x P tail is gathered
    and solved redundantly, as in `parallel_slogdet_mc`.

    ``lookahead=True`` reorders each round so the *next* panel's rows are
    updated first and factorized before the bulk GEMM of the current panel is
    issued — exposing the factorization and the big GEMM as independent ops
    that the TPU scheduler can overlap (classic LU lookahead; §Perf).
    """
    nproc = int(mesh.shape[axis_name])

    def kernel(local):
        L, N = local.shape
        P = _axis_size(axis_name)
        me = lax.axis_index(axis_name)
        n_rounds = (L - 1) // k
        lrow = jnp.arange(L)
        zero = local[0, 0] * 0

        def panel_step(g, carry):
            """Global panel index g = r*P + p."""
            local, sign, logdet = carry
            r = g // P
            p = g % P
            t0 = g * k
            m0 = N - t0
            mine = me == p

            panel = lax.dynamic_slice(local, (r * k, 0), (k, N))
            r_pos = p * (L - (r + 1) * k)
            R, ls, psign, plogdet = panel_factor(panel, m0, r_pos=r_pos)

            R_b, ls_b = lax.psum(
                (jnp.where(mine, R, jnp.zeros_like(R)),
                 jnp.where(mine, ls, jnp.zeros_like(ls))),
                axis_name,
            )

            dead = jnp.where(me <= p, (r + 1) * k, r * k)
            row_mask = (lrow >= dead).astype(local.dtype)
            local = apply_panel(local, R_b, ls_b, m0, row_mask, gemm_fn=gemm_fn)

            sign = jnp.where(mine, sign * psign, sign)
            logdet = logdet + jnp.where(mine, plogdet, zero)
            return local, sign, logdet

        carry = (local, zero + 1, zero)
        if n_rounds > 0:  # static: L, k known at trace time
            carry = lax.fori_loop(0, n_rounds * P, panel_step, carry)
        local, sign, logdet = carry

        # remainder rows: rank-1 schedule continuing at t = n_rounds*k per dev
        rem = (L - 1) - n_rounds * k
        if rem > 0:
            step = mc_step_fn(axis_name)
            t_start = n_rounds * k * P
            local, rsign, rlogdet = lax.fori_loop(
                t_start, t_start + rem * P, step, (local, zero + 1, zero))
            sign = sign * rsign
            logdet = logdet + rlogdet

        # tail: P x P gathered, solved redundantly
        live = lax.dynamic_slice(local, (L - 1, 0), (1, N))[0, :]
        tail = lax.all_gather(live, axis_name)
        tail = lax.slice(tail, (0, 0), (P, P))
        tsign, tlogdet = slogdet_condense(tail)

        logdet_total = lax.psum(logdet, axis_name) + tlogdet
        signs = lax.all_gather(sign, axis_name)
        sign_total = jnp.prod(signs) * tsign
        return sign_total.reshape(1), logdet_total.reshape(1)

    shmapped = _shard_map(
        kernel,
        mesh=mesh,
        in_specs=(PartitionSpec(axis_name, None),),
        out_specs=(PartitionSpec(axis_name), PartitionSpec(axis_name)),
    )

    @jax.jit
    def run(a):
        n = a.shape[0]
        if n % nproc:
            raise ValueError(f"N={n} not divisible by mesh size {nproc}")
        sign, logdet = shmapped(a)
        return sign[0], logdet[0]

    return run
