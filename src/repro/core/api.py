"""Public log-determinant API.

``slogdet(a, method=..., mesh=...)`` dispatches to every implementation in the
framework and transparently pads non-divisible sizes (the paper assumes
``N % P == 0``; we embed A into ``diag(A, I)`` which leaves the determinant
unchanged and keeps max-|.| pivoting stable — identity rows condense to
no-ops).

Methods:
  mc            serial matrix condensation (paper baseline)           [1 dev]
  mc_staged     geometric shape-staged condensation                   [1 dev]
  mc_blocked    serial rank-K panel condensation                      [1 dev]
  ge            serial Gaussian elimination w/ partial pivoting       [1 dev]
  pmc           parallel MC  (paper's algorithm)                      [mesh]
  pmc_blocked   parallel blocked MC (beyond-paper)                    [mesh]
  pge           parallel GE  (paper's baseline)                       [mesh]
  plu           blocked-cyclic LU ("ScaLAPACK" baseline, nb param)    [mesh]
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import blocked as _blocked
from repro.core import condense as _condense
from repro.core import gaussian as _gaussian
from repro.core import parallel as _parallel
from repro.core import scalapack as _scalapack

__all__ = ["slogdet", "logdet", "pad_to_multiple", "METHODS"]

METHODS = ("mc", "mc_staged", "mc_blocked", "ge",
           "pmc", "pmc_blocked", "pge", "plu")

_PARALLEL = {"pmc", "pmc_blocked", "pge", "plu"}


def pad_to_multiple(a: jax.Array, mult: int) -> jax.Array:
    """Embed ``a`` in ``diag(a, I_pad)`` so N becomes a multiple of ``mult``."""
    n = a.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return a
    out = jnp.zeros((n + pad, n + pad), a.dtype)
    out = out.at[:n, :n].set(a)
    idx = jnp.arange(n, n + pad)
    return out.at[idx, idx].set(1.0)


@functools.lru_cache(maxsize=64)
def _parallel_fn(method: str, mesh, axis_name: str, k: int, nb: int):
    if method == "pmc":
        return _parallel.parallel_slogdet_mc(mesh, axis_name)
    if method == "pmc_blocked":
        return _blocked.parallel_slogdet_mc_blocked(mesh, axis_name, k=k)
    if method == "pge":
        return _gaussian.parallel_slogdet_ge(mesh, axis_name)
    if method == "plu":
        return _scalapack.parallel_slogdet_lu(mesh, axis_name, nb=nb)
    raise ValueError(method)


def slogdet(a, *, method: str = "mc", mesh=None, axis_name: str = "rows",
            k: int = 32, nb: int = 1):
    """Sign and log|det| of a square matrix. numpy.linalg.slogdet semantics."""
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; choose from {METHODS}")
    a = jnp.asarray(a)
    if a.ndim != 2 or a.shape[0] != a.shape[1]:
        raise ValueError(f"expected square matrix, got {a.shape}")

    if method in _PARALLEL:
        if mesh is None:
            raise ValueError(f"method {method!r} requires a mesh")
        p = int(mesh.shape[axis_name])
        mult = int(np.lcm(p, nb)) if method == "plu" else p
        a = pad_to_multiple(a, mult)
        return _parallel_fn(method, mesh, axis_name, k, nb)(a)

    if method == "mc":
        return _condense.slogdet_condense(a)
    if method == "mc_staged":
        return _condense.slogdet_condense_staged(a)
    if method == "mc_blocked":
        return _blocked.slogdet_condense_blocked(pad_to_multiple(a, k), k=k)
    if method == "ge":
        return _gaussian.slogdet_ge(a)
    raise AssertionError


def logdet(a, **kw):
    """log|det(a)| — the paper's quantity (sign discarded)."""
    return slogdet(a, **kw)[1]
