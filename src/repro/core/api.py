"""Public log-determinant API.

``slogdet(a, method=..., mesh=...)`` dispatches to every implementation in the
framework and transparently pads non-divisible sizes (the paper assumes
``N % P == 0``; we embed A into ``diag(A, I)`` which leaves the determinant
unchanged and keeps max-|.| pivoting stable — identity rows condense to
no-ops).

Exact methods (any square matrix, O(N^3)):
  mc            serial matrix condensation (paper baseline)           [1 dev]
  mc_staged     geometric shape-staged condensation                   [1 dev]
  mc_blocked    serial rank-K panel condensation                      [1 dev]
  ge            serial Gaussian elimination w/ partial pivoting       [1 dev]
  pmc           parallel MC  (paper's algorithm)                      [mesh]
  pmc_blocked   parallel blocked MC (beyond-paper)                    [mesh]
  pge           parallel GE  (paper's baseline)                       [mesh]
  plu           blocked-cyclic LU ("ScaLAPACK" baseline, nb param)    [mesh]

Stochastic estimators (SPD matrices, O(degree * probes) matvecs — see
repro/estimators; sub-cubic, matrix-free, mesh-shardable):
  chebyshev     stochastic Chebyshev expansion (Han et al.)       [1 dev|mesh]
  slq           stochastic Lanczos quadrature (Ubaru et al.)      [1 dev|mesh]

Estimator methods also accept any ``repro.estimators.LinearOperator`` —
structured backends (`KroneckerOperator`, `ToeplitzOperator`,
`StencilOperator`, ...) reach N >> 10^4 without materializing A:

    slogdet(KroneckerOperator(a, b), method="slq")

An operator input carries its own distribution/structure, so ``mesh`` is
rejected for it (shard the dense input instead, or use `ShardedOperator`).

Choosing: exact condensation is the right call when you need all digits, a
sign, or N is small enough for O(N^3) (<~ 4k on one device); the estimators
when A is huge, implicit, or stacked and ~2-3 significant digits suffice.
Accuracy knobs: ``num_probes`` shrinks Monte-Carlo noise like 1/sqrt(k)
(tracked — `repro.estimators.estimate_logdet` returns the standard error);
``degree``/``num_steps`` shrink the spectral truncation bias geometrically
at a matvec apiece, with rate degrading as cond(A) grows.  Estimator sign
is always +1 (SPD assumption).

``logdet_batched(stack)`` maps any of mc/chebyshev/slq over a (B, N, N)
stack of SPD matrices in one vectorized call (GMM covariance workloads).

Differentiation: every method supports ``jax.grad`` (training on
log-likelihoods — the paper's motivating workload; see
examples/gmm_fit.py).  Exact methods use the analytic pullback
``d logdet/dA = A^{-T}`` (one dense inverse in the backward pass, same
O(N^3) class as the forward — the pivot control flow is never
differentiated).  Estimator methods stay matrix-free in the backward pass
too: the cotangent is the Hutchinson estimate ``(1/k) sum_c (A^{-T} z_c)
z_c^T`` on the SAME probes as the forward, realized by one batched
`cg_solve` — cost ~ one CG solve per probe set, no dense inverse — and
structured operators (Kronecker/Toeplitz/stencil) receive cotangents
shaped like their parameters, not dense (N, N) tangents.  See
`repro.estimators.grad`.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import blocked as _blocked
from repro.core import condense as _condense
from repro.core import gaussian as _gaussian
from repro.core import parallel as _parallel
from repro.core import scalapack as _scalapack

__all__ = ["slogdet", "logdet", "logdet_batched", "pad_to_multiple",
           "METHODS"]

METHODS = ("mc", "mc_staged", "mc_blocked", "ge",
           "pmc", "pmc_blocked", "pge", "plu",
           "chebyshev", "slq")

_PARALLEL = {"pmc", "pmc_blocked", "pge", "plu"}
# mirrors repro.estimators.ESTIMATOR_METHODS (kept literal here so importing
# repro.core stays light — the estimators package is imported lazily)
_ESTIMATOR = {"chebyshev", "slq"}


def pad_to_multiple(a: jax.Array, mult: int) -> jax.Array:
    """Embed ``a`` in ``diag(a, I_pad)`` so N becomes a multiple of ``mult``."""
    n = a.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return a
    out = jnp.zeros((n + pad, n + pad), a.dtype)
    out = out.at[:n, :n].set(a)
    idx = jnp.arange(n, n + pad)
    return out.at[idx, idx].set(1.0)


@functools.lru_cache(maxsize=64)
def _parallel_fn(method: str, mesh, axis_name: str, k: int, nb: int):
    if method == "pmc":
        return _parallel.parallel_slogdet_mc(mesh, axis_name)
    if method == "pmc_blocked":
        return _blocked.parallel_slogdet_mc_blocked(mesh, axis_name, k=k)
    if method == "pge":
        return _gaussian.parallel_slogdet_ge(mesh, axis_name)
    if method == "plu":
        return _scalapack.parallel_slogdet_lu(mesh, axis_name, nb=nb)
    raise ValueError(method)


def _estimator_slogdet(a, method: str, mesh, axis_name: str, **est_kw):
    from repro import estimators as _est

    if mesh is not None:
        p = int(mesh.shape[axis_name])
        padded = pad_to_multiple(a, p)
        if padded is not a:
            # diag(A, I): unit eigenvalues, logdet += 0 — but user-supplied
            # Chebyshev bounds must be widened to bracket 1, else T_j blows
            # up outside [-1, 1] on the padded directions.
            if est_kw.get("lmin") is not None:
                est_kw["lmin"] = min(float(est_kw["lmin"]), 1.0)
            if est_kw.get("lmax") is not None:
                est_kw["lmax"] = max(float(est_kw["lmax"]), 1.0)
        a = _est.ShardedOperator(padded, mesh, axis_name)
    res = _est.estimate_logdet(a, method=method, **est_kw)
    return jnp.ones((), res.est.dtype), res.est


def slogdet(a, *, method: str = "mc", mesh=None, axis_name: str = "rows",
            k: int = 32, nb: int = 1, **est_kw):
    """Sign and log|det| of a square matrix. numpy.linalg.slogdet semantics.

    Estimator methods ("chebyshev", "slq") assume SPD input, return sign 1,
    and accept the keywords of `repro.estimators.logdet_chebyshev` /
    `logdet_slq` (``num_probes``, ``degree`` / ``num_steps``, ``seed``,
    ``lmin``/``lmax``, ...).  Exact methods reject estimator keywords.

    All methods are ``jax.grad``-safe through the logdet output (custom
    VJPs — see the module docstring and `repro.estimators.grad`); the sign
    output is piecewise constant and carries zero gradient.
    """
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; choose from {METHODS}")
    from repro.estimators.operators import is_operator as _is_op
    if _is_op(a):
        # implicit operator: only the matrix-free estimator methods apply
        if method not in _ESTIMATOR:
            raise TypeError(
                f"method {method!r} needs a materialized matrix; operator "
                f"inputs require an estimator method {sorted(_ESTIMATOR)}")
        if mesh is not None:
            raise TypeError("operator inputs carry their own distribution; "
                            "mesh is only accepted for dense array inputs")
        from repro import estimators as _est
        res = _est.estimate_logdet(a, method=method, **est_kw)
        return jnp.ones((), res.est.dtype), res.est
    a_arr = a if isinstance(a, jax.Array) else jnp.asarray(a)
    shape = tuple(a_arr.shape)
    if len(shape) != 2 or shape[0] != shape[1]:
        raise ValueError(f"expected square matrix, got {shape}")

    if method in _ESTIMATOR:
        return _estimator_slogdet(a_arr, method, mesh, axis_name, **est_kw)
    if est_kw:
        raise TypeError(f"method {method!r} takes no estimator keywords: "
                        f"{sorted(est_kw)}")
    a = a_arr

    # Exact methods share one analytic VJP (bar_a = g * inv(a).T) applied at
    # the ORIGINAL matrix — padding/permutation happen inside the wrapped
    # computation and are never differentiated through, and neither is the
    # pivot control flow.  Forward behavior is unchanged outside jax.grad.
    from repro.estimators.grad import exact_slogdet_vjp as _exact_vjp

    if method in _PARALLEL:
        if mesh is None:
            raise ValueError(f"method {method!r} requires a mesh")
        p = int(mesh.shape[axis_name])
        mult = int(np.lcm(p, nb)) if method == "plu" else p
        fn = _parallel_fn(method, mesh, axis_name, k, nb)
        return _exact_vjp(lambda x: fn(pad_to_multiple(x, mult)))(a)

    if method == "mc":
        return _exact_vjp(_condense.slogdet_condense)(a)
    if method == "mc_staged":
        return _exact_vjp(_condense.slogdet_condense_staged)(a)
    if method == "mc_blocked":
        return _exact_vjp(
            lambda x: _blocked.slogdet_condense_blocked(
                pad_to_multiple(x, k), k=k))(a)
    if method == "ge":
        return _exact_vjp(_gaussian.slogdet_ge)(a)
    raise AssertionError


def logdet(a, **kw):
    """log|det(a)| — the paper's quantity (sign discarded)."""
    return slogdet(a, **kw)[1]


def logdet_batched(stack, *, method: str = "chebyshev", **kw):
    """``log|det|`` per matrix of an SPD (B, N, N) stack -> (B,).

    See `repro.estimators.logdet_batched` (re-exported here as the public
    entry point next to `slogdet`).
    """
    from repro import estimators as _est
    return _est.logdet_batched(stack, method=method, **kw)
