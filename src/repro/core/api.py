"""Legacy string-dispatch log-determinant API — deprecated shims over
``repro.plan``.

``slogdet(a, method=..., mesh=..., **kwargs)`` and ``logdet_batched`` are
kept for one release as thin wrappers that build (and cache) a
`repro.core.plan.LogdetPlan` per (spec, method, config, mesh) and execute
it, so existing callers keep identical numerics, error behavior and
gradient rules while emitting a `DeprecationWarning`.  New code should
build a plan once and call it:

    p = repro.plan((n, n), method="auto")     # or a concrete method name
    sign, logabsdet = p(a)                    # LogdetResult unpacks

See docs/api.md for the full plan lifecycle, the typed config reference
(`ExactConfig` / `ChebyshevConfig` / `SLQConfig`), the method decision
tree behind ``method="auto"``, and the migration guide from this module's
string API.

`pad_to_multiple` is not deprecated — it is the shared embedding primitive
(``A -> diag(A, I)``, determinant-preserving) that plans and the parallel
kernels both use.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.core.configs import (
    ESTIMATOR_METHODS as _EST_METHODS, METHODS, PARALLEL_METHODS,
)

__all__ = ["slogdet", "logdet", "logdet_batched", "pad_to_multiple",
           "METHODS"]

_PARALLEL = set(PARALLEL_METHODS)
_ESTIMATOR = set(_EST_METHODS)


def pad_to_multiple(a: jax.Array, mult: int) -> jax.Array:
    """Embed ``a`` in ``diag(a, I_pad)`` so N becomes a multiple of ``mult``."""
    n = a.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return a
    out = jnp.zeros((n + pad, n + pad), a.dtype)
    out = out.at[:n, :n].set(a)
    idx = jnp.arange(n, n + pad)
    # identity padding in the INPUT dtype: a Python 1.0 would weakly
    # promote integer / low-precision inputs (int32 -> f32, bf16 -> f32)
    return out.at[idx, idx].set(jnp.ones((), a.dtype))


def _warn_deprecated(name: str, repl: str):
    from repro import obs
    obs.inc("compat.deprecated", fn=name)
    warnings.warn(
        f"repro.core.{name}() is deprecated: build a plan once with "
        f"repro.plan({repl}) and call it (docs/api.md has the migration "
        f"guide)", DeprecationWarning, stacklevel=3)


def _runtime_bounds(est_kw: dict) -> dict:
    """Pop traced lmin/lmax out of the config keywords.

    Typed configs are static and hashable (they key the plan cache), so
    bounds that arrive as tracers (callers computing them under jit/grad)
    ride as execution inputs instead — same numerics as the pre-plan API,
    which threaded array kwargs through the custom VJP explicitly."""
    rt = {}
    for name in ("lmin", "lmax"):
        v = est_kw.get(name)
        try:
            traced = isinstance(v, jax.core.Tracer)
        except AttributeError:  # pragma: no cover - future jax relocations
            traced = False
        if traced:
            rt[name] = est_kw.pop(name)
    return rt


def _plan_call(a, method, mesh, axis_name, k, nb, est_kw):
    """Route one legacy call through a cached plan, preserving the string
    API's validation order and error messages."""
    from repro.core.plan import plan as _make_plan

    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; choose from {METHODS}")
    from repro.estimators.operators import is_operator as _is_op
    if _is_op(a):
        # operator inputs: only the matrix-free estimator methods apply
        if method not in _ESTIMATOR:
            raise TypeError(
                f"method {method!r} needs a materialized matrix; operator "
                f"inputs require an estimator method {sorted(_ESTIMATOR)}")
        if mesh is not None:
            raise TypeError("operator inputs carry their own distribution; "
                            "mesh is only accepted for dense array inputs")
        key = est_kw.pop("key", None)
        probes = est_kw.pop("probes", None)
        rt = _runtime_bounds(est_kw)
        p = _make_plan(a, method=method, validate=False, **est_kw)
        return p.slogdet(a, key=key, probes=probes, **rt)

    a_arr = a if isinstance(a, jax.Array) else jnp.asarray(a)
    shape = tuple(a_arr.shape)
    if len(shape) != 2 or shape[0] != shape[1]:
        raise ValueError(f"expected square matrix, got {shape}")

    if method in _ESTIMATOR:
        key = est_kw.pop("key", None)
        probes = est_kw.pop("probes", None)
        rt = _runtime_bounds(est_kw)
        p = _make_plan(a_arr, method=method, mesh=mesh,
                       axis_name=axis_name, validate=False, **est_kw)
        return p.slogdet(a_arr, key=key, probes=probes, **rt)

    kw = {"k": k, "nb": nb} if method in _PARALLEL or method == "mc_blocked" \
        else {}
    kw.update(est_kw)          # exact + estimator kwargs -> typed TypeError
    p = _make_plan(a_arr, method=method, mesh=mesh, axis_name=axis_name,
                      validate=False, **kw)
    return p.slogdet(a_arr)


def slogdet(a, *, method: str = "mc", mesh=None, axis_name: str = "rows",
            k: int = 32, nb: int = 1, **est_kw):
    """Sign and log|det| of a square matrix. numpy.linalg.slogdet semantics.

    .. deprecated:: use ``repro.plan(...)`` — this shim builds a cached
       plan per (shape, method, config, mesh) and executes it.

    Estimator methods ("chebyshev", "slq") assume SPD input, return sign 1,
    and accept the keywords of `ChebyshevConfig` / `SLQConfig` plus the
    runtime ``key``/``probes`` arrays.  Exact methods reject estimator
    keywords.  All methods are ``jax.grad``-safe through the logdet output.
    """
    _warn_deprecated("slogdet", "shape, method=...")
    return _plan_call(a, method, mesh, axis_name, k, nb, est_kw)


def logdet(a, *, method: str = "mc", mesh=None, axis_name: str = "rows",
           k: int = 32, nb: int = 1, **est_kw):
    """log|det(a)| — the paper's quantity (sign discarded).

    .. deprecated:: use ``repro.plan(...).logdet(a)``.
    """
    _warn_deprecated("logdet", "shape, method=...")
    return _plan_call(a, method, mesh, axis_name, k, nb, est_kw)[1]


def logdet_batched(stack, *, method: str = "chebyshev", **kw):
    """``log|det|`` per matrix of an SPD (B, N, N) stack -> (B,).

    .. deprecated:: use ``repro.plan(stack.shape, method=...)`` — a batched
       plan returns a `LogdetResult` whose fields carry the leading batch
       axis.
    """
    _warn_deprecated("logdet_batched", "(B, n, n), method=...")
    from repro.core.plan import plan as _make_plan
    from repro.estimators import ESTIMATOR_METHODS as _est_names
    from repro.estimators.operators import is_operator as _is_op

    if _is_op(stack):
        if getattr(stack, "batch", None) is None:
            raise ValueError(
                "logdet_batched needs a batched operator (with a .batch "
                "axis); use estimate_logdet for a single operator")
        if method not in _ESTIMATOR:
            raise TypeError(
                f"method {method!r} needs a materialized (B, n, n) stack; "
                "operator inputs require an estimator method "
                f"{_est_names}")
        key = kw.pop("key", None)
        probes = kw.pop("probes", None)
        p = _make_plan(stack, method=method, validate=False, **kw)
        return p.logdet(stack, key=key, probes=probes)

    stack = jnp.asarray(stack)
    if stack.ndim != 3 or stack.shape[1] != stack.shape[2]:
        raise ValueError(f"expected (B, n, n) stack, got {stack.shape}")
    if method not in _ESTIMATOR:
        # any exact engine route, vmapped per matrix; mesh schedules raise
        # a clear TypeError inside plan (ONE matrix per mesh)
        p = _make_plan(stack, method=method, validate=False, **kw)
        return p.logdet(stack)
    key = kw.pop("key", None)
    probes = kw.pop("probes", None)
    p = _make_plan(stack, method=method, validate=False, **kw)
    return p.logdet(stack, key=key, probes=probes)
