"""Library-style blocked right-looking LU baseline ("ScaLAPACK" row of Table 3).

The paper's third competitor is ScaLAPACK's LU with ``blocksize = 1``.  We
implement the same algorithm family natively: **block-cyclic row distribution,
right-looking blocked LU with partial pivoting** and a configurable block size
``nb``:

  * ``nb = 1``   reproduces the paper's handicapped setting (per-column global
    pivot search + row exchange + full-width update, plus the library's
    panel/solve/GEMM scaffolding overhead every step);
  * ``nb = 32+`` is the library at strength (used in §Perf as the strongest
    classical baseline against blocked MC).

Per panel: ``nb`` pivot searches (all-gather) + row exchanges (psum bcasts),
one gather of the panel rows (A12) and factor block (L11), a redundant
triangular solve for U12, and a trailing GEMM ``A22 -= L21 @ U12``.

Comparison per eliminated row (communication):
  MC            : 1 row broadcast, no search, no exchange
  GE            : 1 argmax all-reduce + 2 row broadcasts
  LU (this file): 1 argmax all-reduce + 2 row broadcasts + 1/nb panel gathers
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec

from repro._compat import (axis_size as _axis_size, pvary as _pvary,
                           shard_map as _shard_map)
from repro.core.engine import cyclic_perm, guarded_pivot, perm_parity

__all__ = ["parallel_slogdet_lu"]


def parallel_slogdet_lu(mesh, axis_name: str = "rows", *, nb: int = 1):
    """Blocked LU logdet over a 1-D mesh (cyclic rows, partial pivoting)."""
    nproc = int(mesh.shape[axis_name])

    def kernel(local):
        L, N = local.shape
        P = _axis_size(axis_name)
        me = lax.axis_index(axis_name)
        lrow = jnp.arange(L)
        grow = lrow * P + me
        cols = jnp.arange(N)
        zero = local[0, 0] * 0
        n_panels = N // nb  # N % nb == 0 enforced by caller padding

        def panel_col_step(c, carry):
            """One column of panel factorization; c is the global column."""
            local, F, sign, logdet, t0 = carry
            # ---- global pivot search on column c among rows >= c ------------
            col = jnp.take(local, c, axis=1)
            cand = jnp.where(grow >= c, jnp.abs(col), -jnp.inf)
            lmax_i = jnp.argmax(cand)
            vals = lax.all_gather(cand[lmax_i], axis_name)
            grs = lax.all_gather(grow[lmax_i], axis_name)
            pivot_g = grs[jnp.argmax(vals)]

            # ---- row exchange c <-> pivot_g (full width, like laswp) --------
            owner_p, owner_t = pivot_g % P, c % P
            li_p, li_t = pivot_g // P, c // P
            mine_p, mine_t = owner_p == me, owner_t == me
            contrib_p = jnp.where(mine_p, local[li_p], jnp.zeros((N,), local.dtype))
            contrib_t = jnp.where(mine_t, local[li_t], jnp.zeros((N,), local.dtype))
            both = lax.psum(jnp.stack([contrib_p, contrib_t]), axis_name)
            pivot_row, row_t = both[0], both[1]
            p = pivot_row[c]
            swapped = pivot_g != c
            local = local.at[li_t].set(jnp.where(swapped & mine_t, pivot_row, local[li_t]))
            local = local.at[li_p].set(jnp.where(swapped & mine_p, row_t, local[li_p]))
            # swap F rows identically (factors move with their rows)
            fp = jnp.where(mine_p, F[li_p], jnp.zeros((F.shape[1],), F.dtype))
            ft = jnp.where(mine_t, F[li_t], jnp.zeros((F.shape[1],), F.dtype))
            fboth = lax.psum(jnp.stack([fp, ft]), axis_name)
            F = F.at[li_t].set(jnp.where(swapped & mine_t, fboth[0], F[li_t]))
            F = F.at[li_p].set(jnp.where(swapped & mine_p, fboth[1], F[li_p]))

            # ---- factors + panel-restricted update ---------------------------
            safe_p = guarded_pivot(p, local.dtype)
            factor = jnp.where(grow > c, jnp.take(local, c, axis=1) / safe_p, 0.0)
            F = F.at[:, (c - t0).astype(jnp.int32)].set(factor.astype(F.dtype))
            colmask = ((cols > c) & (cols < t0 + nb)).astype(local.dtype)
            local = local - factor[:, None] * (pivot_row * colmask)[None, :]

            sign = sign * jnp.where(swapped, -1.0, 1.0).astype(local.dtype)
            sign = sign * jnp.sign(p)
            logdet = logdet + jnp.log(jnp.abs(p))
            return local, F, sign, logdet, t0

        def panel_step(q, carry):
            local, sign, logdet = carry
            t0 = q * nb
            F = jnp.zeros((L, nb), local.dtype) + zero
            local, F, sign, logdet, _ = lax.fori_loop(
                t0, t0 + nb, panel_col_step, (local, F, sign, logdet, t0))

            # ---- gather panel rows (A12) and their factor rows (L11) --------
            onehot = (grow[None, :] == (t0 + jnp.arange(nb))[:, None]).astype(local.dtype)
            A12 = lax.psum(onehot @ local, axis_name)          # (nb, N)
            L11 = lax.psum(onehot @ F, axis_name)              # (nb, nb)
            U12 = jax.scipy.linalg.solve_triangular(
                L11, A12, lower=True, unit_diagonal=True)      # redundant

            # ---- trailing GEMM (rows strictly below the panel) ---------------
            F_gemm = F * (grow >= t0 + nb).astype(F.dtype)[:, None]
            local = local - F_gemm @ U12
            return local, sign, logdet

        carry = (local, _pvary(jnp.ones((), local.dtype), axis_name),
                 _pvary(jnp.zeros((), local.dtype), axis_name))
        local, sign, logdet = lax.fori_loop(0, n_panels, panel_step, carry)
        return sign.reshape(1), logdet.reshape(1)

    shmapped = _shard_map(
        kernel,
        mesh=mesh,
        in_specs=(PartitionSpec(axis_name, None),),
        out_specs=(PartitionSpec(axis_name), PartitionSpec(axis_name)),
    )

    import functools as _ft

    @_ft.lru_cache(maxsize=8)
    def _go(n: int):
        if n % nproc:
            raise ValueError(f"N={n} not divisible by mesh size {nproc}")
        if n % nb:
            raise ValueError(f"N={n} not divisible by blocksize {nb}")
        perm = cyclic_perm(n, nproc)
        parity = perm_parity(perm)

        @jax.jit
        def go(a):
            ac = a[jnp.asarray(perm)]
            sign, logdet = shmapped(ac)
            return sign[0] * jnp.asarray(parity, a.dtype), logdet[0]

        return go

    def run(a):
        return _go(a.shape[0])(a)

    run.lower = lambda a: _go(a.shape[0]).lower(a)   # HLO introspection
    return run
