"""Core: the paper's contribution — distributed log-determinant via
parallel matrix condensation, plus the baselines it is evaluated against,
fronted by the compiled plan/execute API (`repro.plan`)."""

from repro.core.condense import (
    slogdet_condense,
    slogdet_condense_staged,
    condense_steps,
    combine_slogdet,
)
from repro.core.blocked import (
    slogdet_condense_blocked,
    parallel_slogdet_mc_blocked,
    panel_factor,
    apply_panel,
)
from repro.core.gaussian import slogdet_ge, parallel_slogdet_ge
from repro.core.parallel import parallel_slogdet_mc
from repro.core.scalapack import parallel_slogdet_lu
from repro.core.api import (
    slogdet, logdet, logdet_batched, pad_to_multiple, METHODS,
)
from repro.core.calibration import Calibration, load_calibration
from repro.core.configs import (
    ChebyshevConfig, EngineConfig, ExactConfig, SLQConfig, config_for,
)
from repro.core.engine import engine_slogdet
from repro.core.result import Diagnostics, LogdetResult
from repro.core.plan import (
    LogdetPlan, ProblemSpec, plan, select_method, select_route, spec_of,
)

__all__ = [
    "slogdet", "logdet", "logdet_batched", "pad_to_multiple", "METHODS",
    "plan", "LogdetPlan", "ProblemSpec", "select_method", "select_route",
    "spec_of",
    "EngineConfig", "engine_slogdet", "Calibration", "load_calibration",
    "ExactConfig", "ChebyshevConfig", "SLQConfig", "config_for",
    "LogdetResult", "Diagnostics",
    "slogdet_condense", "slogdet_condense_staged", "condense_steps",
    "combine_slogdet", "slogdet_condense_blocked",
    "parallel_slogdet_mc_blocked", "panel_factor", "apply_panel",
    "slogdet_ge", "parallel_slogdet_ge", "parallel_slogdet_mc",
    "parallel_slogdet_lu",
]
