"""Core: the paper's contribution — distributed log-determinant via
parallel matrix condensation, plus the baselines it is evaluated against."""

from repro.core.api import (
    slogdet, logdet, logdet_batched, pad_to_multiple, METHODS,
)
from repro.core.condense import (
    slogdet_condense,
    slogdet_condense_staged,
    condense_steps,
    combine_slogdet,
)
from repro.core.blocked import (
    slogdet_condense_blocked,
    parallel_slogdet_mc_blocked,
    panel_factor,
    apply_panel,
)
from repro.core.gaussian import slogdet_ge, parallel_slogdet_ge
from repro.core.parallel import parallel_slogdet_mc
from repro.core.scalapack import parallel_slogdet_lu

__all__ = [
    "slogdet", "logdet", "logdet_batched", "pad_to_multiple", "METHODS",
    "slogdet_condense", "slogdet_condense_staged", "condense_steps",
    "combine_slogdet", "slogdet_condense_blocked",
    "parallel_slogdet_mc_blocked", "panel_factor", "apply_panel",
    "slogdet_ge", "parallel_slogdet_ge", "parallel_slogdet_mc",
    "parallel_slogdet_lu",
]
