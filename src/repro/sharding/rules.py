"""Sharding rules: param-path -> logical axes -> mesh PartitionSpecs.

Two levels, MaxText-style:

  1. *Logical axes* per parameter, resolved from the leaf's dict-key name
     (every param name in repro.models is unique per role) and its rank —
     extra leading dims are layer-stack axes and map to None.
  2. *Rules* mapping logical axis -> mesh axis (or None), built per
     (config, mesh, mode):

       embed      ->  FSDP axis ("data" or ("pod","data")) on params
       heads/mlp/vocab/expert/inner -> "model"  (tensor/expert parallel)
       kv_heads   ->  "model" only when divisible, else None
       ...

  Activations: ``batch_spec``/``cache_spec`` build the input shardings used
  by launch/dryrun.py and train.py.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig

__all__ = ["make_rules", "param_specs", "param_shardings", "batch_spec",
           "cache_shardings", "logical_axes_for"]

# param-name -> logical axes (rightmost-aligned against the leaf rank)
_NAME_AXES: Dict[str, Tuple[str, ...]] = {
    "embed": ("vocab", "embed"),
    "head": ("vocab", "embed"),
    "wq": ("embed", "heads", "head_dim"),
    "wk": ("embed", "kv_heads", "head_dim"),
    "wv": ("embed", "kv_heads", "head_dim"),
    "wo": ("heads", "head_dim", "embed"),
    "bq": ("heads", "head_dim"),
    "bk": ("kv_heads", "head_dim"),
    "bv": ("kv_heads", "head_dim"),
    "w_gate": ("embed", "mlp"),
    "w_up": ("embed", "mlp"),
    "w_down": ("mlp", "embed"),
    "we_gate": ("expert", "embed", "mlp"),   # routed experts (EP axis)
    "we_up": ("expert", "embed", "mlp"),
    "we_down": ("expert", "mlp", "embed"),
    "router": ("embed", "expert"),
    "in_proj": ("embed", "inner"),
    "out_proj": ("inner", "embed"),
    "conv_w": ("null", "inner"),
    "conv_b": ("inner",),
    "A_log": ("null",),
    "D": ("null",),
    "dt_bias": ("null",),
    "norm": ("embed",),
    "scale": ("embed",),
    "attn_norm": ("embed",),
    "mlp_norm": ("embed",),
    "xattn_norm": ("embed",),
    "final_norm": ("embed",),
    "enc_norm": ("embed",),
    "xattn_gate": ("null",),
    "mlp_gate": ("null",),
}

def logical_axes_for(path, leaf) -> Tuple[Optional[str], ...]:
    """Logical axes for one param leaf, from its tree path + rank.

    Also resolves optimizer-state leaves: Adam moments share the param's
    path suffix (same shape, same spec); Adafactor's factored moments end in
    "vr" (last dim dropped) / "vc" (second-to-last dropped); scalars
    ("count", "step") are replicated.
    """
    name = None
    last = None
    for k in path:
        key = getattr(k, "key", None)
        last = key if key is not None else last
        if key in _NAME_AXES:
            name = key
    rank = len(leaf.shape)
    if name is None:
        if rank == 0:
            return ()
        raise ValueError(f"no sharding rule for param path {path}")
    axes: Tuple[str, ...] = _NAME_AXES[name]
    if last == "vr":                       # adafactor row stats: drop last dim
        axes = axes[:-1]
    elif last == "vc":                     # col stats: drop 2nd-to-last dim
        axes = axes[:-2] + axes[-1:]
    if rank < len(axes):
        raise ValueError(f"{path}: rank {rank} < axes {axes}")
    return (None,) * (rank - len(axes)) + tuple(axes)


def _divisible(n: int, by: int) -> bool:
    return by > 0 and n % by == 0


def make_rules(cfg: ModelConfig, mesh: Mesh, *,
               fsdp: bool = True) -> Dict[str, Optional[object]]:
    """logical axis -> mesh axis (or None), adapted to cfg divisibility.

    Deliberately serve/train-agnostic: FSDP stays on for serving too (the
    weights cannot be held model-sharded-only at scale), so there is no
    ``kind`` knob here — `batch_spec` is where train/serve/decode differ.
    """
    model_ax = "model" if "model" in mesh.axis_names else None
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    msize = mesh.shape.get("model", 1)

    # FSDP shards the embed axis of every 2D+ weight across data (+pod).
    # Enabled for serving too: at 400B scale the weights cannot be held
    # model-sharded-only; GSPMD inserts the per-layer all-gathers.
    fsdp_ax = data_axes if (fsdp and data_axes) else None

    rules: Dict[str, Optional[object]] = {
        "vocab": model_ax,
        "embed": fsdp_ax,
        "heads": model_ax if _divisible(cfg.n_heads, msize) else None,
        "kv_heads": model_ax if _divisible(cfg.n_kv_heads, msize) else None,
        "head_dim": None,
        "mlp": model_ax,
        "expert": model_ax if cfg.n_experts else None,
        "inner": model_ax,
        "conv": None,
        "null": None,
        "layer": None,
    }
    # MoE: if experts shard on model, expert-mlp dim must not also use model.
    if cfg.n_experts and rules["expert"] is not None:
        pass  # "mlp" rule only applies within expert tensors via axes order
    return rules


def _spec_from_axes(axes, rules, mesh: Mesh, shape) -> P:
    """Build a PartitionSpec; every entry must EVENLY divide its dim (pjit
    input shardings reject padding), and a mesh axis appears at most once."""
    entries = []
    used = set()
    for ax, dim in zip(axes, shape):
        m = rules.get(ax) if ax is not None else None
        if m is None:
            entries.append(None)
            continue
        ms = tuple(m) if isinstance(m, tuple) else (m,)
        ms = tuple(a for a in ms if a not in used)
        # drop trailing axes until the product divides the dimension
        while ms and (dim % int(np.prod([mesh.shape[a] for a in ms])) != 0):
            ms = ms[:-1]
        used.update(ms)
        entries.append(ms if ms else None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_specs(params_or_specs, cfg: ModelConfig, rules, mesh: Mesh) -> object:
    """Pytree of PartitionSpecs matching the params tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_or_specs)
    out = []
    for path, leaf in flat:
        axes = logical_axes_for(path, leaf)
        out.append(_spec_from_axes(axes, rules, mesh, leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)


def param_shardings(params_or_specs, cfg: ModelConfig, mesh: Mesh, **kw):
    rules = make_rules(cfg, mesh, **kw)
    specs = param_specs(params_or_specs, cfg, rules, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(cfg: ModelConfig, mesh: Mesh, *, kind: str,
               batch: int | None = None) -> Dict[str, P]:
    """PartitionSpecs for the model-input batch dict.

    ``batch`` (when known) gates the data-parallel sharding: a global batch
    that doesn't divide the data axes (long_500k: batch=1) is replicated.
    """
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if batch is not None and data_axes:
        dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
        if batch % dsize:
            data_axes = ()
    bspec = data_axes if data_axes else None
    out = {"tokens": P(bspec, None)}
    if kind == "train":
        out["targets"] = P(bspec, None)
    if cfg.family == "encdec":
        key = "memory" if kind == "decode" else "frames"
        out[key] = P(bspec, None, None)
    if cfg.family == "vlm":
        out["img_embeds"] = P(bspec, None, None)
    return out


def cache_shardings(cache_specs_tree, cfg: ModelConfig, mesh: Mesh):
    """KV/SSM cache shardings for serving.

    Attention KV (..., B, S, kvh, hd): batch on data axes; heads on model if
    divisible, else the sequence dim on model (sequence-parallel KV).
    SSM conv (..., B, W, convdim) / state (..., B, nh, hp, st): batch on data,
    inner dims on model.
    """
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsize = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1
    msize = mesh.shape.get("model", 1)
    model_ax = "model" if "model" in mesh.axis_names else None
    heads_ok = _divisible(cfg.n_kv_heads, msize)

    hd_ok = _divisible(cfg.hd if cfg.n_heads else 0, msize)

    def spec_for(path, leaf):
        shape = leaf.shape
        names = [getattr(k, "key", None) for k in path]
        is_kv = any(n in ("k", "v") for n in names)
        if is_kv:
            # (layers..., B, S, kvh, hd).  NEVER shard S when batch divides:
            # the decode write is a dynamic_update_slice at a traced position
            # along S, which GSPMD can only lower by regathering the cache.
            lead = (None,) * (len(shape) - 4)
            batch_ok = _divisible(shape[-4], dsize)
            if batch_ok:
                if heads_ok:
                    return P(*lead, data_axes or None, None, model_ax, None)
                if hd_ok:
                    return P(*lead, data_axes or None, None, None, model_ax)
                return P(*lead, data_axes or None, model_ax, None, None)
            # tiny batch (long_500k): S carries the data axes (masked-write
            # decode mode — hints.configure(kv_masked_write=True))
            if heads_ok:
                return P(*lead, None, data_axes or None, model_ax, None)
            if hd_ok:
                return P(*lead, None, data_axes or None, None, model_ax)
            seq_axes = tuple(data_axes) + ((model_ax,) if model_ax else ())
            return P(*lead, None, seq_axes or None, None, None)
        if "conv" in names:
            lead = (None,) * (len(shape) - 3)
            batch_ok = _divisible(shape[-3], dsize)
            conv_ok = _divisible(shape[-1], msize)
            return P(*lead, data_axes if batch_ok else None, None,
                     model_ax if conv_ok else None)
        if "ssm" in names:
            # (layers..., B, nh, hp, st)
            lead = (None,) * (len(shape) - 4)
            batch_ok = _divisible(shape[-4], dsize)
            nh_ok = _divisible(shape[-3], msize)
            return P(*lead, data_axes if batch_ok else None,
                     model_ax if nh_ok else None, None, None)
        raise ValueError(f"unknown cache leaf {names}")

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_specs_tree)
    specs = [spec_for(p, l) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)
