from repro.sharding.rules import (
    make_rules, param_specs, param_shardings, batch_spec, cache_shardings,
)
