"""Activation-sharding hints (GSPMD constraints) for intermediates the
propagation pass gets wrong on its own.

The big one: attention logits (B, H, Tq, Tk).  When heads divide the model
axis GSPMD shards H; when they don't (gemma3: 4 heads, llama4 GQA kv=8...)
the default is a REPLICATED (Tq, Tk) panel — 17 GiB/device at 4k train.  The
fix is sequence parallelism: shard Tq over "model".  Softmax (last dim) stays
local; the wo projection brings the sequence axis back.

Hints are configured by the launcher per (cfg, mesh) and consulted inside
model code via ``constrain(x, kind)`` — a no-op when unconfigured (smoke
tests, single device) so model code never depends on a mesh.
"""
from __future__ import annotations

import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig

_STATE = threading.local()


def configure(cfg: ModelConfig, mesh: Optional[Mesh], *,
              kv_masked_write: bool = False):
    """Install hints for cfg on mesh; pass mesh=None to clear.

    kv_masked_write: decode writes the KV cache with a one-hot masked merge
    instead of dynamic_update_slice — required when S is sharded (long_500k)
    because a traced-position slice-update on a sharded dim degenerates to a
    full regather under GSPMD.
    """
    if mesh is None:
        _STATE.hints = None
        return
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model_ax = "model" if "model" in mesh.axis_names else None
    msize = mesh.shape.get("model", 1)
    heads_div = cfg.n_heads and msize > 1 and cfg.n_heads % msize == 0
    b = data_axes or None

    # Sequence-parallel residual stream (Megatron-LM SP): the value saved by
    # remat at every block seam is (B, T, D) — sharding T over "model" cuts
    # saved-activation memory 16x.  Temporal-mixing families (ssm/hybrid)
    # keep the sequence local: SSD scans/convs over a sharded time axis
    # would shuffle every chunk.
    seq_sp = cfg.family in ("dense", "moe", "vlm", "encdec")

    hints = {}
    if model_ax and msize > 1:
        if heads_div:
            # logits (B, H, Tq, Tk): heads on model (Megatron TP)
            hints["attn_logits"] = P(b, model_ax, None, None)
            hints["qkv"] = P(b, None, model_ax, None)           # (B,T,H,hd)
            hints["attn_out"] = P(b, None, model_ax, None)
        else:
            # few heads (gemma3, GQA KV): sequence-parallel logits; heads
            # replicated; gather T again right after the attention block.
            hints["attn_logits"] = P(b, None, model_ax, None)
            hints["qkv"] = P(b, None, None, None)
            hints["attn_out"] = P(b, None, None, None)
        # Megatron mlp: hidden f on model so FSDP weight shards get
        # gathered, not activation partials all-reduced.
        hints["mlp_hidden"] = P(b, None, model_ax)               # (B,T,f)
        hints["residual"] = P(b, model_ax if seq_sp else None, None)
        hints["gathered"] = P(b, None, None)                     # (B,T,D)
        hints["ce_logits"] = P(b, None, model_ax)                # (B,tc,V)
        # (E, C, D) dispatch buffer: experts on model when they divide
        # (llama4 128/16); otherwise capacity carries the DATA axes only —
        # the expert FFN dim keeps "model", so the GLU einsums shard as
        # (e, c/data, f/model) with no axis conflict (qwen2-moe, 60 experts)
        if cfg.n_experts and cfg.n_experts % msize == 0:
            hints["moe_buf"] = P(model_ax, None, None)
        else:
            hints["moe_buf"] = P(None, b, None)
    _STATE.hints = {"mesh": mesh, "specs": hints,
                    "flags": {"kv_masked_write": kv_masked_write}}


def flag(name: str) -> bool:
    st = getattr(_STATE, "hints", None)
    return bool(st and st.get("flags", {}).get(name))


def constrain(x, kind: str):
    st = getattr(_STATE, "hints", None)
    if st is None:
        return x
    spec = st["specs"].get(kind)
    if spec is None:
        return x
    if x.ndim != len(spec):
        return x
    # divisibility guard: drop axes a dim can't shard over evenly (decode
    # T=1, tiny batches) — GSPMD would pad, wasting a full mesh slice.
    mesh = st["mesh"]
    entries = []
    for dim, e in zip(x.shape, spec):
        if e is None:
            entries.append(None)
            continue
        axes = e if isinstance(e, tuple) else (e,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        entries.append(e if dim % size == 0 and dim >= size else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))
