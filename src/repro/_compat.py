"""Version-compat shims for the jax APIs this repo straddles.

The codebase targets current jax (``jax.shard_map``, ``lax.pcast``,
``jax.sharding.AxisType``) but must also run on the 0.4.x series shipped in
CPU containers, where those names live elsewhere or don't exist yet:

  * ``shard_map``      jax.shard_map (>=0.6) vs jax.experimental.shard_map
  * ``pvary``          lax.pcast(..., to="varying") (>=0.8) vs lax.pvary
                       (0.5-0.7) vs identity (0.4.x: shard_map has no
                       varying-axes type system, so plain values are fine)
  * ``make_mesh``      axis_types kwarg exists only where AxisType does

Every module that touches these APIs imports from here instead of guessing.
"""
from __future__ import annotations

import jax
from jax import lax

__all__ = ["shard_map", "pvary", "make_mesh", "axis_size"]


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax <= 0.5: experimental home
    from jax.experimental.shard_map import shard_map as _shard_map_exp

    def shard_map(f, **kw):
        # Without pvary/pcast the 0.4.x replication checker cannot track
        # per-device partial accumulators (and has no rule for while/scan
        # carries) — disable it; the collectives are unchanged.
        kw.setdefault("check_rep", False)
        return _shard_map_exp(f, **kw)


def pvary(x, axis_name):
    """Mark a replicated value as device-varying along ``axis_name``."""
    if hasattr(lax, "pcast"):
        return lax.pcast(x, axis_name, to="varying")
    if hasattr(lax, "pvary"):
        return lax.pvary(x, axis_name)
    return x


def axis_size(axis_name):
    """Static size of a named mesh axis, inside shard_map."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    frame = jax.core.axis_frame(axis_name)  # jax 0.4.x: returns the size
    return frame if isinstance(frame, int) else frame.size


def make_mesh(axis_shapes, axis_names):
    """jax.make_mesh with explicit Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)
