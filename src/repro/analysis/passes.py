"""Checker passes over normalized IR modules.

Each pass proves one structural invariant of a lowered/compiled program —
invariants the paper's speedup claim (and three shipped bugs) hang on:

  ``no-dense-factorization``     matrix-free estimator forward/backward
                                 HLO contains no LAPACK custom calls,
                                 triangular solves, or dense inverses
  ``no-host-callback``           obs-off programs are callback-free (the
                                 telemetry gate really gates)
  ``collective-payload-budget``  every mesh-schedule collective moves at
                                 most its analytic payload — the tail
                                 all-gather is O(P^2) bytes, never O(N*P)
                                 (the PR 8 wire-bytes bug class)
  ``dtype-discipline``           no silent f32 -> f64 promotions in a
                                 sub-f64 program (the PR 4 upcast bug
                                 class; groundwork for bf16 condensation)
  ``stage-coverage``             each engine schedule's named scopes are
                                 present exactly when its flags say so
                                 (the PR 6-era inert ``lookahead=`` class)
  ``exportable-custom-calls``    AOT-exported programs carry no host
                                 function pointers (the serve/aot screen)

A pass is ``run(module, ctx) -> [Finding]`` registered under a stable id;
`run_passes` drives any subset.  Passes that need named-scope ancestry
declare ``wants="hlo"`` (scopes only print in compiled HLO text) — the
audit drivers compile when a wanting pass is selected, everything else
runs fine on lowered StableHLO.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, replace
from typing import Callable, Dict, List, Optional, Tuple

from repro.analysis.ir import Instruction, Module, parse_module
from repro.analysis.report import AuditReport, Finding

__all__ = [
    "AuditContext", "PASSES", "register_pass", "run_passes",
    "expected_engine_stages", "SAFE_CUSTOM_CALLS", "DEFAULT_PASS_IDS",
]

# custom-call targets that are safe to ship across processes (layout /
# sharding markers XLA resolves internally).  Anything else — LAPACK
# handles in particular — is a host-function pointer that does NOT
# survive a process boundary and would segfault at call time.
SAFE_CUSTOM_CALLS = frozenset({"Sharding", "SPMDFullToShardShape",
                               "SPMDShardToFullShape"})

# LAPACK/BLAS factorization + solve custom-call families, plus the HLO
# ops XLA may lower them to.  Matching is substring on the custom-call
# target (lapack_dgetrf_ffi, blas_dtrsm, cusolver_getrf, ...).
_FACTORIZATION_TARGETS = ("getrf", "getrs", "potrf", "potrs", "trsm",
                          "gesdd", "gesvd", "geev", "sytrd", "geqrf",
                          "orgqr", "gehrd")
_FACTORIZATION_OPS = ("triangular-solve", "cholesky")

_CALLBACK_MARKERS = ("callback", "py_func", "host_func")
_HOST_TRANSFER_OPS = ("infeed", "outfeed", "send", "recv")


@dataclass(frozen=True)
class AuditContext:
    """What the audited program *is* — the pass inputs.

    ``label``     context string carried onto findings ("mesh|panel fwd")
    ``method``    resolved plan method ("exact", "chebyshev", "slq", ...)
    ``kind``      "forward" | "backward" | "export"
    ``schedule``/``update``/``lookahead``/``panel_k`` engine axes (exact)
    ``fused``     the engine's one-pass condensation steps are on (the
                  per-step pivot/swap/update scopes collapse into
                  ``engine.fused_step``)
    ``precision`` the engine's mixed-precision route (``"bf16"`` -> the
                  program MUST lower bf16-operand contractions; the
                  bf16 -> f32 accumulate converts are intentional)
    ``n``/``devices``/``itemsize``  payload-budget geometry
    ``dtype``     canonical dtype string of the planned computation
    ``obs_mode``  the REPRO_OBS mode the program was lowered under
    ``matrix_free``  the program claims to never materialize/factor A
    ``expected_stages``  named-scope presence map for stage-coverage;
                  None -> derived from the engine axes via
                  `expected_engine_stages`
    """
    label: str = ""
    method: str = ""
    kind: str = "forward"
    schedule: Optional[str] = None
    update: Optional[str] = None
    lookahead: bool = False
    panel_k: int = 32
    fused: bool = False
    precision: Optional[str] = None
    n: int = 0
    devices: int = 1
    itemsize: int = 8
    dtype: str = "float64"
    obs_mode: str = "off"
    matrix_free: bool = False
    expected_stages: Optional[Dict[str, bool]] = None


@dataclass
class Pass:
    id: str
    run: Callable[[Module, AuditContext], List[Finding]]
    description: str
    wants: str = "any"          # "hlo" (needs compiled text) | "any"


PASSES: Dict[str, Pass] = {}


def register_pass(pass_id: str, description: str, wants: str = "any"):
    def deco(fn):
        PASSES[pass_id] = Pass(id=pass_id, run=fn, description=description,
                               wants=wants)
        return fn
    return deco


def run_passes(module, ctx: AuditContext,
               pass_ids: Optional[Tuple[str, ...]] = None) -> AuditReport:
    """Run the selected passes over ``module`` (a `Module` or raw text)."""
    if not isinstance(module, Module):
        module = parse_module(module)
    report = AuditReport()
    for pid in (pass_ids if pass_ids is not None else tuple(PASSES)):
        p = PASSES[pid]
        findings = [replace(f, context=f.context or ctx.label)
                    for f in p.run(module, ctx)]
        report.findings.extend(findings)
        report.passes_run.append(pid)
    if ctx.label:
        report.contexts.append(ctx.label)
    return report


def _finding(pid: str, instr: Instruction, message: str,
             severity: str = "error") -> Finding:
    return Finding(pass_id=pid, severity=severity, message=message,
                   where=instr.name, code=instr.raw)


# --------------------------------------------------------------------------
# the passes
# --------------------------------------------------------------------------

@register_pass(
    "no-dense-factorization",
    "matrix-free programs contain no LAPACK custom calls, triangular "
    "solves, or dense factorizations (Han et al. estimator contract)")
def _no_dense_factorization(mod: Module, ctx: AuditContext) -> List[Finding]:
    if not (ctx.matrix_free or ctx.method in ("chebyshev", "slq")):
        return []
    out = []
    for i in mod.instructions:
        t = (i.custom_call_target or "").lower()
        if t and any(m in t for m in _FACTORIZATION_TARGETS):
            out.append(_finding(
                "no-dense-factorization", i,
                f"dense factorization custom call {i.custom_call_target!r} "
                f"in a matrix-free {ctx.method or 'estimator'} "
                f"{ctx.kind} program"))
        elif i.opcode in _FACTORIZATION_OPS:
            out.append(_finding(
                "no-dense-factorization", i,
                f"dense {i.opcode} op in a matrix-free "
                f"{ctx.method or 'estimator'} {ctx.kind} program"))
    return out


@register_pass(
    "no-host-callback",
    "programs lowered with observability off contain no host callbacks "
    "or host transfers (telemetry must be structurally absent, not idle)")
def _no_host_callback(mod: Module, ctx: AuditContext) -> List[Finding]:
    if ctx.obs_mode not in ("off", "metrics"):
        return []           # trace mode legitimately plants callbacks
    out = []
    for i in mod.instructions:
        t = (i.custom_call_target or "").lower()
        if t and any(m in t for m in _CALLBACK_MARKERS):
            out.append(_finding(
                "no-host-callback", i,
                f"host callback {i.custom_call_target!r} in a program "
                f"lowered with obs={ctx.obs_mode!r} — trace-gated "
                "telemetry leaked into the hot path"))
        elif i.opcode in _HOST_TRANSFER_OPS:
            out.append(_finding(
                "no-host-callback", i,
                f"host transfer op {i.opcode!r} in a program lowered "
                f"with obs={ctx.obs_mode!r}"))
    return out


def _collective_budgets(ctx: AuditContext) -> Dict[str, int]:
    """Analytic per-collective payload caps for a mesh-schedule engine
    program (bytes, max(operand, result) convention).

    The loop broadcasts move one pivot row / one ``(R, ls)`` K-panel —
    O(k * N) bytes; the tail all-gather moves the (P, P) live block plus
    a (P,) column — O(P^2).  Anything bigger means a shard of dead
    columns went over the wire (the pre-PR-8 8*N*P-byte tail bug).
    64 bytes of slop covers index/sign scalars riding along.
    """
    k = ctx.panel_k if ctx.update == "panel" else 1
    p, n, isz = max(ctx.devices, 1), ctx.n, ctx.itemsize
    return {
        "all-gather": isz * (p * max(p, k) + p) + 64,
        "all-reduce": isz * k * (n + 2 * k) + 64,
        "reduce-scatter": isz * k * (n + 2 * k) + 64,
        "all-to-all": isz * k * (n + 2 * k) + 64,
        "collective-permute": isz * k * (n + 2 * k) + 64,
    }


@register_pass(
    "collective-payload-budget",
    "every mesh-schedule collective payload stays within the route's "
    "analytic bound — the tail all-gather is O(P^2) bytes, never O(N*P)")
def _collective_payload_budget(mod: Module,
                               ctx: AuditContext) -> List[Finding]:
    if ctx.schedule != "mesh" or ctx.n <= 0:
        return []
    budgets = _collective_budgets(ctx)
    tail_budget = ctx.itemsize * (ctx.devices * ctx.devices
                                  + ctx.devices) + 64
    out = []
    for i in mod.collectives():
        base = i.opcode.replace("-start", "")
        payload = max(i.result_bytes, i.operand_bytes)
        budget = budgets.get(base)
        if i.in_scope("engine.mesh_tail"):
            # inside the tail everything is (P, P)-sized — even the
            # reduce of the combined slogdet parts
            budget = tail_budget
        if budget is None or payload <= budget:
            continue
        out.append(_finding(
            "collective-payload-budget", i,
            f"{base} moves {payload} bytes, analytic bound is {budget} "
            f"(n={ctx.n}, P={ctx.devices}, k={ctx.panel_k}, "
            f"update={ctx.update}) — a live-data slice is missing "
            "before the collective"))
    return out


_32BIT = ("float32", "bfloat16", "float16")


_CONTRACTION_OPS = ("dot", "dot-general", "multiply")


@register_pass(
    "dtype-discipline",
    "no silent f32/bf16/f16 -> f64 promotions in a sub-f64 program "
    "(padding helpers and dtype-less literals are the usual culprits); "
    "with precision='bf16' the program must actually lower bf16-operand "
    "contractions (quantize-then-upcast-before-multiply is inert)")
def _dtype_discipline(mod: Module, ctx: AuditContext) -> List[Finding]:
    out: List[Finding] = []
    if ctx.precision == "bf16":
        # the mixed-precision route quantizes GEMM/outer operands to
        # bf16 and accumulates in the buffer dtype.  bf16 -> f32
        # converts are therefore INTENTIONAL here (the accumulate leg,
        # not a silent upcast) — but at least one contraction must
        # consume bf16 operands, else the quantization was optimized
        # away / upcast before the multiply and the route is inert.
        has_bf16_mul = any(
            any(s.dtype == "bf16" for s in i.operand_shapes)
            for i in mod.instructions if i.opcode in _CONTRACTION_OPS)
        if not has_bf16_mul:
            out.append(Finding(
                pass_id="dtype-discipline", severity="error",
                message="bf16-silent-upcast: precision='bf16' program "
                        "lowers no bf16-operand contraction — operands "
                        "were promoted back to full precision before "
                        "the multiply, so the mixed-precision route is "
                        "inert",
                where="precision=bf16"))
    if ctx.dtype not in _32BIT:
        return out          # an f64 plan is entitled to f64 arithmetic
    for i in mod.instructions:
        if i.opcode != "convert":
            continue
        src = {s.dtype for s in i.operand_shapes}
        dst = {s.dtype for s in i.result_shapes}
        if "f64" in dst and src & {"f32", "bf16", "f16"}:
            out.append(_finding(
                "dtype-discipline", i,
                f"silent upcast {sorted(src & {'f32', 'bf16', 'f16'})} "
                f"-> f64 in a {ctx.dtype} program — a dtype-less literal "
                "or widening helper is promoting the pipeline"))
    if not out:
        # no explicit converts: any f64-valued instruction at all still
        # means the program left its precision (weaker signal -> warning)
        for i in mod.instructions:
            if i.opcode in ("constant", "parameter", "iota"):
                continue
            if any(s.dtype == "f64" for s in i.result_shapes):
                out.append(_finding(
                    "dtype-discipline", i,
                    f"f64-valued {i.opcode} in a {ctx.dtype} program",
                    severity="warning"))
                break
    return out


def expected_engine_stages(ctx: AuditContext) -> Dict[str, bool]:
    """Which `obs.stage` scopes MUST (True) / MUST NOT (False) appear in
    a compiled engine program, given its flags and geometry.

    Derived from the engine's structure (verified against lowerings of
    every schedule x update x lookahead variant):

      * ``engine.mesh_tail`` / ``engine.broadcast``: mesh schedule only.
      * ``engine.lookahead_factor``: iff ``lookahead=True`` AND the
        pipelined loop body actually traces — the panel variant's
        prologue/loop only exists when a device owns more than one full
        panel (``(n/P - 1) // k >= 1``); with fewer rows the kernel falls
        through to the shared rank-1 remainder path and the scope is
        legitimately absent.
      * ``engine.pivot``: every schedule's step — EXCEPT the pipelined
        rank-1 mesh kernel on ONE device, where pivot selection happens
        inside the early-applied next-row factorization and is
        deliberately scoped ``engine.lookahead_factor`` (there is no
        separate pivot phase to attribute time to).  At P >= 2 the
        (P, P) tail reduction runs the serial condensation redundantly
        on every device and its step re-introduces the pivot scope.
      * ``engine.swap``/``engine.update``: every schedule's step.
      * ``fused=True`` (serial/staged only): the per-step pivot/swap/
        update scopes collapse into ``engine.fused_step`` — the one-pass
        kernel selects the pivot, swaps, and updates inside a single
        scope, so the three per-step scopes MUST be absent and
        ``engine.fused_step`` MUST be present.

    The map is exact for the supported audit geometries (panel kernels
    keep a rank-1 remainder, i.e. ``(n/P - 1) % k != 0``); degenerate
    no-remainder layouts should pass ``expected_stages`` explicitly.
    """
    mesh = ctx.schedule == "mesh"
    la_traces = False
    if mesh and ctx.lookahead:
        if ctx.update == "panel":
            local = ctx.n // max(ctx.devices, 1)
            la_traces = (local - 1) // max(ctx.panel_k, 1) >= 1
        else:
            la_traces = ctx.n >= 2
    pivot_subsumed = (bool(la_traces) and ctx.update == "rank1"
                      and ctx.devices <= 1)
    fused = bool(ctx.fused) and not mesh
    return {
        "engine.pivot": not pivot_subsumed and not fused,
        "engine.swap": not fused,
        "engine.update": not fused,
        "engine.fused_step": fused,
        "engine.mesh_tail": mesh,
        "engine.broadcast": mesh,
        "engine.lookahead_factor": bool(la_traces),
    }


@register_pass(
    "stage-coverage",
    "each engine schedule's named scopes reach the compiled program "
    "exactly when its flags say so (no inert flags, no phantom stages)",
    wants="hlo")
def _stage_coverage(mod: Module, ctx: AuditContext) -> List[Finding]:
    if (ctx.method != "exact" and ctx.expected_stages is None) or ctx.n < 2:
        return []
    expected = ctx.expected_stages
    if expected is None:
        expected = expected_engine_stages(ctx)
    present = mod.scope_names()
    # scopes can be swallowed into fusion metadata the table misses; the
    # full dotted stage name in the raw text is the robust fallback
    out = []
    for stage, want in sorted(expected.items()):
        have = stage in present or stage in mod.text
        if want and not have:
            out.append(Finding(
                pass_id="stage-coverage", severity="error",
                message=f"stage {stage!r} missing from the compiled "
                        f"program although the route's flags require it "
                        f"(schedule={ctx.schedule}, update={ctx.update}, "
                        f"lookahead={ctx.lookahead}) — the flag is inert",
                where=stage))
        elif not want and have:
            out.append(Finding(
                pass_id="stage-coverage", severity="error",
                message=f"stage {stage!r} present although the route's "
                        f"flags forbid it (schedule={ctx.schedule}, "
                        f"update={ctx.update}, lookahead={ctx.lookahead})",
                where=stage))
    return out


def _export_safe_target(target: str) -> bool:
    """Can this custom-call target survive serialization?

    Safe: the XLA-internal sharding markers, and jaxlib's FFI targets
    (``lapack_*_ffi``, ...) — those resolve BY NAME through the process's
    FFI registry at load time, and the artifact fingerprint already pins
    the jax/jaxlib version providing them.  Unsafe: python callbacks
    (pointers to THIS process's interpreter state) and legacy non-FFI
    custom calls (opaque descriptor blobs baked at compile time).
    """
    if target in SAFE_CUSTOM_CALLS:
        return True
    low = target.lower()
    if any(m in low for m in _CALLBACK_MARKERS):
        return False
    return low.endswith("_ffi")


@register_pass(
    "exportable-custom-calls",
    "AOT-exported programs reference no host function pointers — only "
    "registry-resolved custom-call targets survive serialization")
def _exportable_custom_calls(mod: Module,
                             ctx: AuditContext) -> List[Finding]:
    if ctx.kind != "export":
        return []
    bad = sorted(t for t in set(mod.custom_call_targets())
                 if not _export_safe_target(t))
    if not bad:
        return []
    return [Finding(
        pass_id="exportable-custom-calls", severity="error",
        message=f"plan lowers to XLA custom calls {bad} (host function "
                "handles that do not survive serialization across "
                "processes); only pure-XLA and registry-resolved FFI "
                "programs are AOT-exportable",
        where=bad[0])]


# the default pass set audit drivers run (export screening is opt-in —
# it only makes sense with kind="export")
DEFAULT_PASS_IDS = ("no-dense-factorization", "no-host-callback",
                    "collective-payload-budget", "dtype-discipline",
                    "stage-coverage")
