"""Audit drivers: lower a plan (or a whole plan grid, or an AOT artifact
dir) and run the registered IR passes over the result.

The split of lowering work mirrors what each pass can see:

  * lowered StableHLO (``.lower().as_text()``) is cheap and keeps every
    op visible pre-fusion — custom calls, collectives, converts live
    here, so most passes run on it;
  * compiled HLO (``.compile().as_text()``) carries the named-scope
    ancestry (``obs.stage`` -> ``metadata op_name``) that
    ``stage-coverage`` needs, at the price of an XLA compile — the
    drivers only pay it when a ``wants="hlo"`` pass is selected and the
    plan is an exact-engine route.

`audit_plan` is the core; `LogdetPlan.audit()` delegates here.  The CLI
(`python -m repro.analysis`) wraps `audit_grid` / `audit_aot_dir` /
`repro.analysis.lint.lint_paths`.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.passes import (
    PASSES, AuditContext, DEFAULT_PASS_IDS, run_passes,
)
from repro.analysis.report import AuditReport, Finding

__all__ = ["PlanAuditError", "audit_plan", "audit_grid", "default_grid",
           "audit_artifact", "audit_aot_dir", "context_for",
           "backward_label"]


class PlanAuditError(ValueError):
    """The plan has no statically-analyzable lowering."""


# --------------------------------------------------------------------------
# plan -> AuditContext
# --------------------------------------------------------------------------

def context_for(plan, kind: str = "forward") -> AuditContext:
    """Derive the pass inputs from a live `LogdetPlan`."""
    import jax.numpy as jnp
    from repro.core.configs import ESTIMATOR_METHODS, ExactConfig
    from repro import obs

    spec, cfg = plan.spec, plan.config
    schedule = update = None
    lookahead, panel_k = False, 32
    fused, precision = False, None
    if isinstance(cfg, ExactConfig):
        ecfg = cfg.engine_config()
        schedule, update = ecfg.schedule, ecfg.update
        lookahead, panel_k = ecfg.lookahead, ecfg.panel_k
        fused, precision = ecfg.fused, ecfg.precision
    n = plan.diagnostics.padded_n or spec.n
    label = plan.method if schedule is None else \
        (f"{plan.method}:{schedule}/{update}"
         + ("/la" if lookahead else "")
         + ("/fused" if fused else "")
         + (f"/{precision}" if precision else ""))
    if kind != "forward":
        label = f"{label} {kind}"
    return AuditContext(
        label=label, method=plan.method, kind=kind,
        schedule=schedule, update=update, lookahead=lookahead,
        panel_k=panel_k, fused=fused, precision=precision, n=n,
        devices=plan.diagnostics.device_count or 1,
        itemsize=jnp.dtype(spec.dtype).itemsize, dtype=spec.dtype,
        obs_mode=obs.mode(),
        matrix_free=plan.method in ESTIMATOR_METHODS)


def backward_label(plan) -> str:
    return context_for(plan, kind="backward").label


# --------------------------------------------------------------------------
# plan -> lowerings
# --------------------------------------------------------------------------

def _avals(plan):
    import jax
    import jax.numpy as jnp
    spec = plan.spec
    dtype = jnp.dtype(spec.dtype)
    shape = ((spec.n, spec.n) if spec.batch is None
             else (spec.batch, spec.n, spec.n))
    return jax.ShapeDtypeStruct(shape, dtype)


def _forward_lowered(plan):
    """A fresh ``jax.Lowered`` of the plan's forward at its avals.

    Mirrors serve/aot.export_plan: lower a rebuilt forward with a scratch
    trace log so auditing never marks a retrace on the live plan."""
    import jax
    from repro.core.configs import ESTIMATOR_METHODS
    from repro.core.plan import _build_forward, _is_mesh_exact, \
        _parallel_kernel

    spec, method, cfg = plan.spec, plan.method, plan.config
    if spec.kind == "operator":
        raise PlanAuditError(
            "operator plans compose the operator's own executables and "
            "have no single lowering to audit; audit a dense plan of the "
            "materialized matrix instead")

    if _is_mesh_exact(method, cfg):
        import jax.numpy as jnp
        pn = plan.diagnostics.padded_n or spec.n
        aval = jax.ShapeDtypeStruct((pn, pn), jnp.dtype(spec.dtype))
        kernel = _parallel_kernel(method, cfg, plan.mesh, plan.axis_name)
        return kernel.lower(aval)

    if not plan.compiled:
        raise PlanAuditError(
            f"plan (method={plan.method!r}, mesh={plan.mesh is not None}) "
            "composes eager executables at run time and has no single "
            "lowering to audit")

    a_aval = _avals(plan)
    dtype = a_aval.dtype
    fwd, _, _ = _build_forward(spec, method, cfg, None, plan.axis_name,
                               dtype, trace_log=[])
    if method in ESTIMATOR_METHODS:
        k0 = np.asarray(jax.random.PRNGKey(getattr(cfg, "seed", 0)))
        k_aval = jax.ShapeDtypeStruct(k0.shape, k0.dtype)
        return jax.jit(lambda a, key: fwd(a, key=key)).lower(a_aval, k_aval)
    return jax.jit(lambda a: fwd(a)).lower(a_aval)


def _backward_lowered(plan):
    """Lower the plan's gradient: d logabsdet / d A at the plan avals."""
    import jax
    a_aval = _avals(plan)

    def loss(a):
        return plan.slogdet(a)[1]

    return jax.jit(jax.grad(loss)).lower(a_aval)


def _needs_hlo(plan, pass_ids: Sequence[str]) -> bool:
    """Compile (to recover named scopes) only when it can matter."""
    wants_hlo = any(PASSES[p].wants == "hlo" for p in pass_ids)
    return wants_hlo and plan.method == "exact"


# --------------------------------------------------------------------------
# core driver
# --------------------------------------------------------------------------

def audit_plan(plan, pass_ids: Optional[Sequence[str]] = None,
               include_grad: bool = False) -> AuditReport:
    """Statically audit a `LogdetPlan` -> `AuditReport`.

    Lowers a fresh forward (and, with ``include_grad``, the backward) at
    the plan's avals and runs the selected passes (default:
    `DEFAULT_PASS_IDS`).  Raises `PlanAuditError` for plans with no
    static lowering (operator inputs, sharded-estimator composites).
    """
    ids = tuple(pass_ids) if pass_ids is not None else DEFAULT_PASS_IDS
    report = AuditReport()
    lowerings: List[Tuple[str, object]] = [("forward", _forward_lowered(plan))]
    if include_grad:
        lowerings.append(("backward", _backward_lowered(plan)))

    for kind, lowered in lowerings:
        ctx = context_for(plan, kind=kind)
        any_ids = tuple(p for p in ids if PASSES[p].wants != "hlo")
        hlo_ids = tuple(p for p in ids if PASSES[p].wants == "hlo")
        if any_ids:
            report.extend(run_passes(lowered.as_text(), ctx, any_ids))
        if hlo_ids and kind == "forward" and _needs_hlo(plan, hlo_ids):
            report.extend(run_passes(lowered.compile().as_text(), ctx,
                                     hlo_ids))
        elif hlo_ids:
            # keep passes_run honest: selected but structurally inapplicable
            for p in hlo_ids:
                if p not in report.passes_run:
                    report.passes_run.append(p)
    report.meta.setdefault("plans", []).append(context_for(plan).label)
    return report


# --------------------------------------------------------------------------
# grid driver (the CLI's --grid / --all)
# --------------------------------------------------------------------------

def default_grid(n: int = 32, panel_k: int = 8) -> List[dict]:
    """The audit matrix from the CI contract: every engine route
    (serial|staged|mesh x rank1|panel x lookahead on/off), the fused
    one-pass and bf16 mixed-precision engine variants, plus the
    estimator methods with their backward passes."""
    entries = []
    for schedule in ("serial", "staged", "mesh"):
        for update in ("rank1", "panel"):
            for la in ((False, True) if schedule == "mesh" else (False,)):
                entries.append(dict(method="exact", schedule=schedule,
                                    update=update, lookahead=la, n=n,
                                    k=panel_k))
    # the PR-10 engine variants: one-pass fused steps (serial/staged
    # only) and the quantized-GEMM route, alone and combined
    entries.append(dict(method="exact", schedule="staged", update="rank1",
                        n=n, k=panel_k, fused=True))
    entries.append(dict(method="exact", schedule="staged", update="panel",
                        n=n, k=panel_k, fused=True, precision="bf16"))
    entries.append(dict(method="exact", schedule="staged", update="panel",
                        n=n, k=panel_k, precision="bf16"))
    for method in ("chebyshev", "slq"):
        entries.append(dict(method=method, n=n, grad=True,
                            num_probes=4, seed=0))
    return entries


def _grid_mesh():
    import jax
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()), ("rows",))


def audit_grid(entries: Optional[List[dict]] = None,
               pass_ids: Optional[Sequence[str]] = None,
               n: int = 32) -> AuditReport:
    """Plan and audit every grid entry; one merged `AuditReport`."""
    from repro.core.plan import plan as make_plan

    entries = entries if entries is not None else default_grid(n=n)
    mesh = None
    report = AuditReport()
    for entry in entries:
        entry = dict(entry)
        grad = entry.pop("grad", False)
        size = entry.pop("n", n)
        method = entry.pop("method")
        if entry.get("schedule") == "mesh" and mesh is None:
            mesh = _grid_mesh()
        kw = {}
        if entry.get("schedule") == "mesh":
            kw["mesh"] = mesh
        if method in ("chebyshev",):
            entry.setdefault("degree", 8)
        if method in ("slq",):
            entry.setdefault("num_steps", 8)
        p = make_plan((size, size), method=method, **kw, **entry)
        report.extend(audit_plan(p, pass_ids=pass_ids, include_grad=grad))
    return report


# --------------------------------------------------------------------------
# AOT artifact audit (the CLI's --aot)
# --------------------------------------------------------------------------

def audit_artifact(path, pass_ids: Optional[Sequence[str]] = None
                   ) -> AuditReport:
    """Audit one exported plan artifact.

    The artifact stores a compiled XLA executable; its disassembly is
    post-fusion HLO, so scope-sensitive passes apply but per-op converts
    may already be fused away.  A device-fingerprint mismatch is reported
    as a finding (the executable cannot be safely deserialized here), not
    an exception — an audit sweep over a mixed artifact dir should keep
    going."""
    import jax.numpy as jnp
    from repro.core.configs import ESTIMATOR_METHODS
    from repro.serve.aot import (
        PlanFingerprintError, check_fingerprint, read_header,
    )
    from jax.experimental.serialize_executable import deserialize_and_load
    import pickle

    path = str(path)
    header = read_header(path)
    spec = header["spec"]
    method = header["method"]
    ecfg = header.get("config", {})
    label = f"aot:{method}:n{spec['n']}"
    ctx = AuditContext(
        label=label, method=method, kind="export",
        schedule=ecfg.get("schedule"), update=ecfg.get("update"),
        lookahead=bool(ecfg.get("lookahead")),
        panel_k=int(ecfg.get("k") or 32),
        fused=bool(ecfg.get("fused")), precision=ecfg.get("precision"),
        n=int(header.get("padded_n") or spec["n"]),
        itemsize=jnp.dtype(spec["dtype"]).itemsize, dtype=spec["dtype"],
        obs_mode="off",     # exported programs must be telemetry-free
        matrix_free=method in ESTIMATOR_METHODS)

    report = AuditReport(contexts=[label])
    try:
        check_fingerprint(header, path)
    except PlanFingerprintError as exc:
        report.findings.append(Finding(
            pass_id="aot-fingerprint", severity="warning", context=label,
            message=str(exc), where=path))
        return report

    from repro.serve.aot import _read
    _, blob = _read(path)
    payload, in_tree, out_tree = pickle.loads(blob)
    executable = deserialize_and_load(payload, in_tree, out_tree)
    text = executable.as_text()

    ids = tuple(pass_ids) if pass_ids is not None else \
        DEFAULT_PASS_IDS + ("exportable-custom-calls",)
    # post-fusion text: stage-coverage would mis-read fused scopes of
    # estimator programs; only structural-presence passes apply
    ids = tuple(p for p in ids if p != "stage-coverage")
    report.extend(run_passes(text, ctx, ids))
    return report


def audit_aot_dir(dirpath, pass_ids: Optional[Sequence[str]] = None
                  ) -> AuditReport:
    """Audit every ``*.reproplan`` (or any magic-tagged file) in a dir."""
    from pathlib import Path
    from repro.serve.aot import _MAGIC

    report = AuditReport()
    found = 0
    for f in sorted(Path(dirpath).iterdir()):
        if not f.is_file():
            continue
        with open(f, "rb") as fh:
            if fh.read(len(_MAGIC)) != _MAGIC:
                continue
        found += 1
        report.extend(audit_artifact(f, pass_ids=pass_ids))
    report.meta["artifacts"] = found
    if not found:
        report.findings.append(Finding(
            pass_id="aot-scan", severity="warning", context="aot",
            message=f"no plan artifacts found under {dirpath}",
            where=str(dirpath)))
    return report
