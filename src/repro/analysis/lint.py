"""Repo-grounded AST lint over ``src/`` — the bug classes the last three
PRs shipped, as static rules:

  ``unused-config-kwarg``   a function accepts a keyword with a default
                            and never reads it (the inert ``lookahead=``
                            flag class: accepted, documented, ignored)
  ``implicit-dtype``        ``jnp.ones``/``jnp.zeros``/``jnp.full``/
                            ``jnp.eye`` without an explicit ``dtype=`` in
                            promotion-sensitive library code (the PR 4
                            silent-f64-upcast class; ``*_like`` variants
                            and arrays built from an existing ``.dtype``
                            are fine)
  ``timing-no-block``       a function brackets work between two
                            ``time.perf_counter()``/``time.time()`` calls
                            without any ``block_until_ready`` in sight —
                            it times dispatch, not device work (the PR 6
                            span class)
  ``deprecated-route``      internal code passing one of the legacy exact
                            route strings (mc/mc_staged/mc_blocked/pmc/
                            pmc_blocked) as a ``method=`` — those are
                            one-release DeprecationWarning shims and must
                            not be load-bearing inside the library

Each rule reports `Finding`s (pass_id == rule id) with ``where`` set to
``path:line`` so the shared allowlist machinery (fnmatch on ``where``,
substring on ``code``) waives the residue with a recorded reason.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, List, Optional

from repro.analysis.report import AuditReport, Finding

__all__ = ["lint_source", "lint_paths", "lint_tree", "LINT_RULES"]

LINT_RULES = ("unused-config-kwarg", "implicit-dtype", "timing-no-block",
              "deprecated-route")

_LEGACY_ROUTES = {"mc", "mc_staged", "mc_blocked", "pmc", "pmc_blocked"}
# modules that legitimately *mention* the legacy strings: the definitions,
# the shim layer itself, and the plan dispatcher that resolves them
_ROUTE_DEFINERS = ("core/engine.py", "core/configs.py", "core/api.py",
                   "core/plan.py")

_ARRAY_CTORS = {"ones", "zeros", "full", "eye", "empty"}


def _names_loaded(node: ast.AST) -> set:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)
            and isinstance(n.ctx, ast.Load)}


def _is_stub(fn: ast.FunctionDef) -> bool:
    """Protocol/ABC bodies: docstring + pass/.../raise only."""
    body = fn.body
    if body and isinstance(body[0], ast.Expr) \
            and isinstance(body[0].value, ast.Constant) \
            and isinstance(body[0].value.value, str):
        body = body[1:]
    return all(isinstance(s, (ast.Pass, ast.Raise)) or
               (isinstance(s, ast.Expr) and isinstance(s.value, ast.Constant))
               for s in body)


def _finding(rule: str, path: str, node: ast.AST, message: str,
             code: str = "") -> Finding:
    return Finding(pass_id=rule, severity="error", message=message,
                   where=f"{path}:{getattr(node, 'lineno', 0)}",
                   context="lint", code=code)


# --------------------------------------------------------------------------
# rules
# --------------------------------------------------------------------------

def _rule_unused_config_kwarg(tree: ast.AST, path: str) -> List[Finding]:
    out = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if _is_stub(fn):
            continue
        # keyword-accepting params: positional-with-default + kw-only
        args = fn.args
        defaulted = args.args[len(args.args) - len(args.defaults):]
        candidates = list(defaulted) + list(args.kwonlyargs)
        if not candidates:
            continue
        loaded = _names_loaded(ast.Module(body=fn.body, type_ignores=[]))
        for a in candidates:
            name = a.arg
            if name.startswith("_") or name in ("self", "cls"):
                continue
            if name not in loaded:
                out.append(_finding(
                    "unused-config-kwarg", path, a,
                    f"{fn.name}() accepts {name}= and never reads it — "
                    "an inert knob callers believe is doing something",
                    code=f"{fn.name}({name}=...)"))
    return out


def _call_name(call: ast.Call) -> Optional[str]:
    f = call.func
    if isinstance(f, ast.Attribute):
        base = f.value
        base_name = base.id if isinstance(base, ast.Name) else None
        return f"{base_name}.{f.attr}" if base_name else f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def _has_dtype_arg(call: ast.Call) -> bool:
    if any(kw.arg == "dtype" for kw in call.keywords):
        return True
    # jnp.ones(shape, dtype) positional second arg
    ctor = (_call_name(call) or "").rsplit(".", 1)[-1]
    pos_dtype_index = {"ones": 1, "zeros": 1, "empty": 1, "eye": 3,
                       "full": 2}
    idx = pos_dtype_index.get(ctor)
    return idx is not None and len(call.args) > idx


def _rule_implicit_dtype(tree: ast.AST, path: str) -> List[Finding]:
    out = []
    for call in ast.walk(tree):
        if not isinstance(call, ast.Call):
            continue
        name = _call_name(call)
        if name is None:
            continue
        mod, _, fn = name.rpartition(".")
        if mod not in ("jnp", "jax.numpy") or fn not in _ARRAY_CTORS:
            continue
        if _has_dtype_arg(call):
            continue
        out.append(_finding(
            "implicit-dtype", path, call,
            f"jnp.{fn}(...) without an explicit dtype= — under x64 this "
            "materializes f64 and silently promotes everything it "
            "touches; pass dtype= (usually the input's)",
            code=f"jnp.{fn}"))
    return out


_TIMER_CALLS = {"time.perf_counter", "time.time", "perf_counter",
                "time.monotonic", "monotonic"}


def _rule_timing_no_block(tree: ast.AST, path: str) -> List[Finding]:
    out = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        timers = [c for c in ast.walk(fn) if isinstance(c, ast.Call)
                  and _call_name(c) in _TIMER_CALLS]
        if len(timers) < 2:
            continue
        synced = any(
            (isinstance(n, ast.Attribute)
             and n.attr == "block_until_ready")
            or (isinstance(n, ast.Name) and n.id == "block_until_ready")
            for n in ast.walk(fn))
        if not synced:
            out.append(_finding(
                "timing-no-block", path, timers[0],
                f"{fn.name}() walls-clocks between perf counters with no "
                "block_until_ready — it times dispatch, not device work",
                code=fn.name))
    return out


def _rule_deprecated_route(tree: ast.AST, path: str) -> List[Finding]:
    if path.replace("\\", "/").endswith(_ROUTE_DEFINERS):
        return []
    out = []
    for call in ast.walk(tree):
        if not isinstance(call, ast.Call):
            continue
        for kw in call.keywords:
            if kw.arg == "method" and isinstance(kw.value, ast.Constant) \
                    and kw.value.value in _LEGACY_ROUTES:
                out.append(_finding(
                    "deprecated-route", path, call,
                    f"internal call passes deprecated route string "
                    f"method={kw.value.value!r} — use method='exact' with "
                    "schedule=/update= (the shims are one release from "
                    "removal)", code=f"method={kw.value.value!r}"))
    return out


_RULE_FNS = {
    "unused-config-kwarg": _rule_unused_config_kwarg,
    "implicit-dtype": _rule_implicit_dtype,
    "timing-no-block": _rule_timing_no_block,
    "deprecated-route": _rule_deprecated_route,
}


# --------------------------------------------------------------------------
# drivers
# --------------------------------------------------------------------------

def lint_tree(tree: ast.AST, path: str,
              rules: Iterable[str] = LINT_RULES) -> List[Finding]:
    findings = []
    for rule in rules:
        findings.extend(_RULE_FNS[rule](tree, path))
    return findings


def lint_source(source: str, path: str = "<string>",
                rules: Iterable[str] = LINT_RULES) -> List[Finding]:
    return lint_tree(ast.parse(source), path, rules)


def lint_paths(paths: Iterable, root: Optional[Path] = None,
               rules: Iterable[str] = LINT_RULES) -> AuditReport:
    """Lint every ``.py`` file under ``paths`` -> `AuditReport`.

    ``where`` locations are recorded relative to ``root`` (default: the
    common parent) so allowlist globs stay machine-independent."""
    report = AuditReport(passes_run=list(rules), contexts=["lint"])
    for p in paths:
        p = Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            rel = str(f.relative_to(root)) if root else str(f)
            try:
                tree = ast.parse(f.read_text())
            except SyntaxError as exc:
                report.findings.append(Finding(
                    pass_id="lint", severity="error", context="lint",
                    message=f"unparseable source: {exc}", where=rel))
                continue
            report.findings.extend(lint_tree(tree, rel, rules))
    return report
