"""Findings, audit reports, and the allowlist that waives them.

Every checker pass (IR passes in `repro.analysis.passes`, AST lint rules
in `repro.analysis.lint`) reports `Finding`s; an `AuditReport` is the
machine-readable bundle a whole audit run produces — the thing
``LogdetPlan.audit()`` returns, ``python -m repro.analysis --json``
writes, and ``benchmarks.check_regression --audit`` diffs against the
committed baseline.

Severities:
  ``error``    the invariant is broken — audits exit non-zero
  ``warning``  suspicious but not proof (promote with ``--strict``)
  ``info``     context / waived findings (never fails anything)

The allowlist (``src/repro/analysis/allowlist.toml``) records *accepted*
findings with a one-line justification each.  A waived finding is kept in
the report (downgraded to ``info`` and flagged ``waived``) so the JSON
artifact still shows what was accepted and why.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

__all__ = ["Finding", "AuditReport", "SEVERITIES", "load_allowlist",
           "apply_allowlist"]

SEVERITIES = ("error", "warning", "info")


@dataclass(frozen=True)
class Finding:
    """One checker result.

    ``pass_id``   which pass/rule produced it (e.g. "no-host-callback")
    ``severity``  "error" | "warning" | "info"
    ``message``   human-readable statement of the defect
    ``where``     location — "path.py:12", an instruction name, or ""
    ``context``   what was audited — "mesh|panel|lookahead fwd", "lint"
    ``code``      the offending fragment (instruction text / source line),
                  used by the allowlist's substring matcher
    ``waived``    True once an allowlist entry accepted it
    """
    pass_id: str
    severity: str
    message: str
    where: str = ""
    context: str = ""
    code: str = ""
    waived: bool = False

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity {self.severity!r} not in "
                             f"{SEVERITIES}")

    @property
    def ident(self) -> str:
        """Stable identity for baseline diffs: pass + context + where —
        message wording and volatile numbers excluded on purpose."""
        return f"{self.pass_id}::{self.context}::{_stable_where(self.where)}"

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["ident"] = self.ident
        return d


def _stable_where(where: str) -> str:
    """Line numbers churn with unrelated edits; keep the file, drop the
    line, so a finding only counts as *new* when it moves files or the
    pass/context changes."""
    return re.sub(r":\d+$", "", where)


@dataclass
class AuditReport:
    """The machine-readable outcome of one audit run."""
    findings: List[Finding] = field(default_factory=list)
    passes_run: List[str] = field(default_factory=list)
    contexts: List[str] = field(default_factory=list)
    meta: Dict[str, object] = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.errors

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "warning"]

    def extend(self, other: "AuditReport") -> "AuditReport":
        self.findings.extend(other.findings)
        for p in other.passes_run:
            if p not in self.passes_run:
                self.passes_run.append(p)
        for c in other.contexts:
            if c not in self.contexts:
                self.contexts.append(c)
        return self

    def to_json(self, indent: int = 2) -> str:
        return json.dumps({
            "findings": [f.to_dict() for f in self.findings],
            "passes_run": self.passes_run,
            "contexts": self.contexts,
            "meta": self.meta,
            "ok": self.ok,
        }, indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "AuditReport":
        d = json.loads(text)
        findings = [Finding(**{k: v for k, v in f.items() if k != "ident"})
                    for f in d.get("findings", [])]
        return cls(findings=findings, passes_run=d.get("passes_run", []),
                   contexts=d.get("contexts", []), meta=d.get("meta", {}))

    def summary(self) -> str:
        lines = [f"audit: {len(self.findings)} finding(s) over "
                 f"{len(self.contexts)} context(s), "
                 f"{len(self.passes_run)} pass(es)"]
        for f in self.findings:
            tag = f"[{f.severity}{'/waived' if f.waived else ''}]"
            loc = f" @ {f.where}" if f.where else ""
            ctx = f" ({f.context})" if f.context else ""
            lines.append(f"  {tag:17s} {f.pass_id}{ctx}{loc}: {f.message}")
        if not self.findings:
            lines.append("  clean")
        return "\n".join(lines)


# --------------------------------------------------------------------------
# allowlist — TOML array-of-tables, parsed with a vendored subset reader
# (python 3.10 containers have no tomllib; the allowlist grammar is just
# [[pass-id]] tables of string keys, so a full TOML dependency is not
# worth gating the audit on)
# --------------------------------------------------------------------------

_TABLE_RE = re.compile(r"^\[\[([\w\-./]+)\]\]\s*$")
_KV_RE = re.compile(r'^([\w\-]+)\s*=\s*"((?:[^"\\]|\\.)*)"\s*$')


def load_allowlist(path) -> Dict[str, List[dict]]:
    """Parse the allowlist file -> {pass_id: [entry, ...]}.

    Grammar (a strict TOML subset): ``[[<pass-id>]]`` array-of-table
    headers, each followed by ``key = "string"`` pairs.  Every entry must
    carry a ``reason``; matchers are ``where`` (fnmatch glob against the
    finding's location), ``context`` (fnmatch) and ``code`` (substring of
    the offending fragment).  Unparseable lines raise — a typo must not
    silently widen the waiver."""
    entries: Dict[str, List[dict]] = {}
    current: Optional[dict] = None
    try:
        text = open(path).read()
    except FileNotFoundError:
        return entries
    for ln, line in enumerate(text.splitlines(), start=1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        tm = _TABLE_RE.match(line)
        if tm:
            current = {}
            entries.setdefault(tm.group(1), []).append(current)
            continue
        kv = _KV_RE.match(line)
        if kv and current is not None:
            current[kv.group(1)] = kv.group(2).replace('\\"', '"')
            continue
        raise ValueError(
            f"{path}:{ln}: unparseable allowlist line {line!r} — entries "
            'are [[pass-id]] headers and key = "value" string pairs')
    for pid, group in entries.items():
        for e in group:
            if not e.get("reason"):
                raise ValueError(
                    f"{path}: allowlist entry for {pid!r} has no reason= "
                    "— every waiver must say why")
    return entries


def _entry_matches(entry: dict, f: Finding) -> bool:
    if "where" in entry and not fnmatch.fnmatch(f.where, entry["where"]):
        return False
    if "context" in entry and not fnmatch.fnmatch(f.context,
                                                  entry["context"]):
        return False
    if "code" in entry and entry["code"] not in f.code:
        return False
    return True


def apply_allowlist(report: AuditReport,
                    allowlist: Dict[str, List[dict]]) -> AuditReport:
    """Downgrade allowlisted findings to waived ``info`` entries."""
    out = []
    for f in report.findings:
        for entry in allowlist.get(f.pass_id, []):
            if _entry_matches(entry, f):
                f = dataclasses.replace(
                    f, severity="info", waived=True,
                    message=f"{f.message} [waived: {entry['reason']}]")
                break
        out.append(f)
    report.findings = out
    return report
