"""``python -m repro.analysis`` — audit plans and source from the shell.

Modes (combinable; ``--all`` = ``--grid --lint``):

  --grid          plan + audit the full route grid (serial|staged|mesh x
                  rank1|panel x lookahead on/off, estimators incl. grad)
  --lint          AST lint over --src (default: the repro package dir)
  --aot DIR       audit every exported plan artifact in DIR

Findings pass through the committed allowlist
(``src/repro/analysis/allowlist.toml`` unless ``--allowlist`` overrides);
waived findings stay in the report as ``info``.  Exit status: 1 when any
error-severity finding survives (``--strict`` also promotes warnings),
else 0 — the CI contract.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static IR/AST audit of repro plans and source")
    ap.add_argument("--all", action="store_true",
                    help="run the plan grid and the AST lint")
    ap.add_argument("--grid", action="store_true",
                    help="audit the engine/estimator plan grid")
    ap.add_argument("--lint", action="store_true",
                    help="run the AST lint over --src")
    ap.add_argument("--aot", metavar="DIR",
                    help="audit exported plan artifacts in DIR")
    ap.add_argument("--n", type=int, default=32,
                    help="matrix side for the plan grid (default 32)")
    ap.add_argument("--src", action="append", default=None, metavar="PATH",
                    help="source roots for --lint (repeatable; default: "
                         "the installed repro package)")
    ap.add_argument("--passes", default=None,
                    help="comma-separated pass ids (default: all defaults)")
    ap.add_argument("--allowlist", default=None, metavar="TOML",
                    help="waiver file (default: the committed allowlist)")
    ap.add_argument("--no-allowlist", action="store_true",
                    help="ignore every allowlist entry")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full AuditReport as JSON ('-' = stdout)")
    ap.add_argument("--strict", action="store_true",
                    help="treat warnings as errors for the exit status")
    args = ap.parse_args(argv)

    if args.all:
        args.grid = args.lint = True
    if not (args.grid or args.lint or args.aot):
        ap.error("nothing to do: pass --all, --grid, --lint, and/or --aot")

    from repro import analysis

    pass_ids = tuple(args.passes.split(",")) if args.passes else None
    if pass_ids:
        unknown = [p for p in pass_ids if p not in analysis.PASSES]
        if unknown:
            ap.error(f"unknown pass id(s) {unknown}; have "
                     f"{sorted(analysis.PASSES)}")

    report = analysis.AuditReport()
    if args.grid:
        report.extend(analysis.audit_grid(pass_ids=pass_ids, n=args.n))
    if args.lint:
        roots = [Path(p) for p in args.src] if args.src else \
            [Path(analysis.__file__).resolve().parents[1]]
        root = roots[0].parent if len(roots) == 1 else None
        report.extend(analysis.lint_paths(roots, root=root))
    if args.aot:
        report.extend(analysis.audit_aot_dir(args.aot, pass_ids=pass_ids))

    if not args.no_allowlist:
        allowlist_path = args.allowlist or analysis.DEFAULT_ALLOWLIST
        report = analysis.apply_allowlist(
            report, analysis.load_allowlist(allowlist_path))

    if args.json:
        payload = report.to_json()
        if args.json == "-":
            print(payload)
        else:
            Path(args.json).write_text(payload + "\n")

    if args.json != "-":
        print(report.summary())

    failed = bool(report.errors) or (args.strict and report.warnings)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
