"""repro.analysis — static IR/AST audits that prove plan invariants
before execution.

Layers (each usable on its own):

  `repro.analysis.ir`      StableHLO/HLO text -> normalized instruction
                           table (opcode, shapes, dtypes, named-scope
                           ancestry, collective payload bytes)
  `repro.analysis.passes`  registered checker passes over parsed modules
                           (`run_passes`, `PASSES`, `AuditContext`)
  `repro.analysis.lint`    AST lint rules over repo source
  `repro.analysis.audit`   drivers: lower a `LogdetPlan` / plan grid /
                           AOT artifact dir and run the passes
  `repro.analysis.report`  `Finding` / `AuditReport` / allowlist

Entry points: ``plan.audit()``, ``python -m repro.analysis --all``, and
the export screen inside `repro.serve.aot`.  See docs/analysis.md.
"""
from repro.analysis.ir import (
    CollectiveStats, Instruction, Module, Shape, collective_bytes,
    parse_module, roofline,
)
from repro.analysis.passes import (
    AuditContext, DEFAULT_PASS_IDS, PASSES, SAFE_CUSTOM_CALLS,
    expected_engine_stages, register_pass, run_passes,
)
from repro.analysis.report import (
    AuditReport, Finding, apply_allowlist, load_allowlist,
)
from repro.analysis.lint import LINT_RULES, lint_paths, lint_source
from repro.analysis.audit import (
    PlanAuditError, audit_aot_dir, audit_artifact, audit_grid, audit_plan,
    default_grid,
)

__all__ = [
    "Shape", "Instruction", "Module", "parse_module", "collective_bytes",
    "CollectiveStats", "roofline",
    "AuditContext", "PASSES", "DEFAULT_PASS_IDS", "SAFE_CUSTOM_CALLS",
    "register_pass", "run_passes", "expected_engine_stages",
    "Finding", "AuditReport", "load_allowlist", "apply_allowlist",
    "LINT_RULES", "lint_source", "lint_paths",
    "PlanAuditError", "audit_plan", "audit_grid", "default_grid",
    "audit_artifact", "audit_aot_dir", "DEFAULT_ALLOWLIST",
]

from pathlib import Path as _Path

# the committed waiver file next to this package; CLI and gates use it
# unless --allowlist points elsewhere
DEFAULT_ALLOWLIST = _Path(__file__).with_name("allowlist.toml")
