"""Normalized IR tables over lowered/compiled XLA programs.

The checker passes in `repro.analysis.passes` prove structural claims
about a program — "the tail all-gather moves O(P^2) bytes", "the
estimator backward is factorization-free" — and those claims live at the
instruction level.  This module parses the two text forms jax hands us
into ONE normalized instruction table:

  * **StableHLO MLIR** (``lowered.as_text()``): pre-optimization, every
    op the trace emitted survives, but no scope metadata is printed.
  * **HLO text** (``lowered.compile().as_text()``): post-optimization,
    ops carry ``metadata={op_name="jit(f)/.../engine.mesh_tail/..."}`` —
    the named-scope ancestry `obs.stage` planted — at the cost of fusion
    having swallowed some instructions.

Each `Instruction` records opcode (normalized to HLO spelling:
``all-gather``, not ``stablehlo.all_gather``), result/operand shapes with
dtypes, named-scope ancestry, and the custom-call target when present;
`Module` is the queryable table.  `collective_bytes` / `roofline` (the
dry-run cost model this parser grew out of — repro.launch.hlo_analysis
re-exports them for compatibility) are implemented on top.

Wire-byte conventions (ring algorithms, per device):
  all-reduce         2 x operand bytes   (reduce-scatter + all-gather phases)
  all-gather         output bytes - operand bytes (received shards)
  reduce-scatter     operand bytes - output bytes
  all-to-all         operand bytes       (each device re-sends its shard)
  collective-permute operand bytes
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Shape", "Instruction", "Module", "parse_module", "shape_bytes",
    "collective_bytes", "roofline", "HW", "CollectiveStats",
    "COLLECTIVE_OPS", "collective_payload_bytes",
]

# TPU v5e hardware constants (per chip)
HW = {
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # B/s
    "ici_bw": 49.5e9,              # B/s per link direction (~50 GB/s)
}

# HLO dtype -> bytes.  Sub-byte types (u1/s1/u2/s2/u4/s4) occupy one byte
# each in unpacked HLO buffers; token/opaque carry no payload.
_DTYPE_BYTES = {
    "pred": 1, "s1": 1, "u1": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1, "f8e8m0fnu": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

# MLIR element type -> HLO dtype name
_MLIR_DTYPE = {
    "i1": "pred", "i2": "s2", "i4": "s4", "i8": "s8", "i16": "s16",
    "i32": "s32", "i64": "s64",
    "ui1": "u1", "ui2": "u2", "ui4": "u4", "ui8": "u8", "ui16": "u16",
    "ui32": "u32", "ui64": "u64",
    "si8": "s8", "si16": "s16", "si32": "s32", "si64": "s64",
    "bf16": "bf16", "f16": "f16", "f32": "f32", "f64": "f64",
    "f8E4M3FN": "f8e4m3fn", "f8E5M2": "f8e5m2",
    "complex<f32>": "c64", "complex<f64>": "c128",
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

# dtype[dims]: covers f32[4,4]{1,0}, u1[8], token[] and bare scalars f32[]
_HLO_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
# instruction definition:  [ROOT] [%]name = <shape or (tuple)> opcode(...)
_HLO_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\((?:[^()]|\([^)]*\))*\))|(?:[a-z][a-z0-9]*\[[\d,]*\]\S*))\s+"
    r"([\w\-]+)")
_HLO_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_HLO_OP_NAME_RE = re.compile(r'op_name="([^"]*)"')
_HLO_TARGET_RE = re.compile(r'custom_call_target="([^"]*)"')

# tensor<4x4xf32>, tensor<f32>, tensor<8x!quant...> (unknown kept raw),
# tensor<2x?xf32> (dynamic dims -> 0), !stablehlo.token
_MLIR_TENSOR_RE = re.compile(r"tensor<([^<>]*(?:<[^<>]*>)?[^<>]*)>")
_MLIR_DEF_RE = re.compile(r"^\s*(%[\w#.\-]+(?::\d+)?)\s*=\s*"
                          r'(?:"([\w.]+)"|([\w.]+))')
_MLIR_TARGET_RE = re.compile(r"custom_call\s+@([\w.\-]+)|@([\w.\-]+)\s*\(")
_MLIR_SCOPE_RE = re.compile(r'loc\("([^"]*)"')


@dataclass(frozen=True)
class Shape:
    """One result/operand aval: dtype (HLO spelling) + static dims."""
    dtype: str
    dims: Tuple[int, ...] = ()

    @property
    def bytes(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n * _DTYPE_BYTES.get(self.dtype, 0)


def shape_bytes(shapes: Iterable[Shape]) -> int:
    """Total byte size of a (possibly nested, already flattened) result."""
    return sum(s.bytes for s in shapes)


@dataclass(frozen=True)
class Instruction:
    """One normalized instruction row.

    ``opcode`` uses HLO spelling (``all-gather``); StableHLO ops are
    mapped (``stablehlo.all_gather`` -> ``all-gather``).  ``scopes`` is
    the named-scope ancestry from ``metadata={op_name=...}`` (compiled
    HLO) — empty in the StableHLO dialect, which does not print it.
    Tuple results arrive flattened into ``result_shapes`` (nested tuples
    too — the parser unnests ``((f32[4], u1[2]), token[])``).
    """
    name: str
    opcode: str
    result_shapes: Tuple[Shape, ...] = ()
    operand_shapes: Tuple[Shape, ...] = ()
    operands: Tuple[str, ...] = ()
    scopes: Tuple[str, ...] = ()
    custom_call_target: Optional[str] = None
    line_no: int = 0
    raw: str = ""

    @property
    def result_bytes(self) -> int:
        return shape_bytes(self.result_shapes)

    @property
    def operand_bytes(self) -> int:
        return shape_bytes(self.operand_shapes)

    def in_scope(self, name: str) -> bool:
        return any(name == s or s.endswith("/" + name) for s in self.scopes)


@dataclass
class Module:
    """Normalized instruction table for one lowered/compiled program."""
    dialect: str                       # "hlo" | "stablehlo"
    instructions: List[Instruction] = field(default_factory=list)
    text: str = ""

    def find(self, opcode_prefix: str) -> List[Instruction]:
        """Instructions whose opcode starts with ``opcode_prefix`` (the
        async ``-start`` forms match their base opcode)."""
        return [i for i in self.instructions
                if i.opcode.startswith(opcode_prefix)]

    def collectives(self) -> List[Instruction]:
        """Cross-device collectives, async pairs counted once (``-start``
        kept, ``-done`` dropped)."""
        out = []
        for i in self.instructions:
            base = _collective_base(i.opcode)
            if base is not None and not i.opcode.endswith("-done"):
                out.append(i)
        return out

    def custom_call_targets(self) -> Dict[str, int]:
        targets: Dict[str, int] = {}
        for i in self.instructions:
            if i.custom_call_target:
                targets[i.custom_call_target] = \
                    targets.get(i.custom_call_target, 0) + 1
        return targets

    def scope_names(self) -> set:
        names = set()
        for i in self.instructions:
            names.update(i.scopes)
        return names

    def dump(self) -> str:
        """Stable normalized text form (round-trip/debug aid): one line
        per instruction — name, opcode, result shapes, scopes, target."""
        rows = []
        for i in self.instructions:
            shapes = ",".join(
                f"{s.dtype}[{'x'.join(map(str, s.dims))}]"
                for s in i.result_shapes)
            rows.append("\t".join([
                i.name, i.opcode, shapes or "-",
                "/".join(i.scopes) or "-", i.custom_call_target or "-"]))
        return "\n".join(rows)


def _collective_base(opcode: str) -> Optional[str]:
    base = opcode
    for suffix in ("-start", "-done"):
        if base.endswith(suffix):
            base = base[: -len(suffix)]
    return base if base in COLLECTIVE_OPS else None


# --------------------------------------------------------------------------
# HLO text dialect
# --------------------------------------------------------------------------

def _parse_hlo_shapes(text: str) -> Tuple[Shape, ...]:
    """Every dtype[dims] occurrence in ``text`` — tuples (and tuples of
    tuples) flatten naturally since each leaf prints its own shape."""
    shapes = []
    for dt, dims in _HLO_SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        shapes.append(Shape(dt, tuple(int(d) for d in dims.split(",") if d)))
    return tuple(shapes)


def _scopes_of(op_name: str) -> Tuple[str, ...]:
    """Named-scope components of a jax op_name path.

    ``jit(f)/jit(main)/while/body/engine.broadcast/psum`` — the jit(...) /
    transform wrappers and the trailing primitive name are structure, the
    dotted components in between are user `jax.named_scope` frames."""
    parts = [p for p in op_name.split("/") if p]
    out = []
    for p in parts[:-1] if len(parts) > 1 else parts:
        if p.startswith(("jit(", "vmap(", "pmap(", "transpose(", "jvp(",
                         "pjit(", "custom_vjp(", "custom_jvp(", "remat(")):
            continue
        if p in ("while", "body", "cond"):
            continue
        out.append(p)
    return tuple(out)


def _parse_hlo(text: str) -> Module:
    mod = Module(dialect="hlo", text=text)
    for ln, line in enumerate(text.splitlines(), start=1):
        m = _HLO_DEF_RE.match(line)
        if not m:
            continue
        name, out_txt, op = m.group(1), m.group(2), m.group(3).lower()
        rest = line[m.end():]
        paren = rest.find("(")
        operand_txt = ""
        if paren >= 0:
            depth, j = 0, paren
            for j in range(paren, len(rest)):
                if rest[j] == "(":
                    depth += 1
                elif rest[j] == ")":
                    depth -= 1
                    if depth == 0:
                        break
            operand_txt = rest[paren + 1:j]
        meta = _HLO_OP_NAME_RE.search(line)
        target = _HLO_TARGET_RE.search(line)
        mod.instructions.append(Instruction(
            name=name, opcode=op,
            result_shapes=_parse_hlo_shapes(out_txt),
            operand_shapes=_parse_hlo_shapes(operand_txt),
            operands=tuple(_HLO_OPERAND_RE.findall(operand_txt)),
            scopes=_scopes_of(meta.group(1)) if meta else (),
            custom_call_target=target.group(1) if target else None,
            line_no=ln, raw=line.strip()))
    return mod


# --------------------------------------------------------------------------
# StableHLO MLIR dialect
# --------------------------------------------------------------------------

def _mlir_shape(spec: str) -> Optional[Shape]:
    """``4x4xf32`` / ``f32`` / ``2x?xbf16`` -> Shape (dynamic dims -> 0)."""
    spec = spec.strip()
    parts = spec.split("x")
    # element type may itself contain 'x' only for complex<...> (handled
    # as the joined tail)
    for split in range(len(parts)):
        elem = "x".join(parts[split:])
        dtype = _MLIR_DTYPE.get(elem)
        if dtype is None:
            continue
        dims = []
        ok = True
        for d in parts[:split]:
            if d == "?":
                dims.append(0)
            elif d.isdigit():
                dims.append(int(d))
            else:
                ok = False
                break
        if ok:
            return Shape(dtype, tuple(dims))
    if spec in ("!stablehlo.token", "token"):
        return Shape("token")
    return None


def _parse_mlir_types(text: str) -> Tuple[Shape, ...]:
    shapes = []
    for spec in _MLIR_TENSOR_RE.findall(text):
        s = _mlir_shape(spec)
        if s is not None:
            shapes.append(s)
    for _ in re.findall(r"!stablehlo\.token", text):
        shapes.append(Shape("token"))
    return tuple(shapes)


def _normalize_mlir_op(op: str) -> str:
    op = op.split(".")[-1]            # stablehlo.all_gather -> all_gather
    return op.replace("_", "-")


def _parse_stablehlo(text: str) -> Module:
    mod = Module(dialect="stablehlo", text=text)
    for ln, line in enumerate(text.splitlines(), start=1):
        m = _MLIR_DEF_RE.match(line)
        if not m:
            continue
        name = m.group(1).split(":")[0].lstrip("%")
        op = _normalize_mlir_op(m.group(2) or m.group(3))
        # the type annotation after ':' — `(operands) -> results` for the
        # generic form, a bare type for the pretty form
        res_txt, opnd_txt = line, ""
        sig = re.search(r":\s*\(([^:]*)\)\s*->\s*(.*)$", line)
        if sig:
            opnd_txt, res_txt = sig.group(1), sig.group(2)
        else:
            bare = re.search(r":\s*(tensor<[^:]*|!stablehlo\.token\s*$)",
                             line)
            res_txt = bare.group(1) if bare else ""
        target = None
        if "custom_call" in line or "@" in line:
            tm = _MLIR_TARGET_RE.search(line)
            if tm:
                target = tm.group(1) or tm.group(2)
        scope = _MLIR_SCOPE_RE.search(line)
        mod.instructions.append(Instruction(
            name=name, opcode=op,
            result_shapes=_parse_mlir_types(res_txt),
            operand_shapes=_parse_mlir_types(opnd_txt),
            operands=tuple(re.findall(r"%([\w#.\-]+)",
                                      line[m.end():sig.start() if sig
                                           else len(line)])),
            scopes=_scopes_of(scope.group(1)) if scope else (),
            custom_call_target=target if op == "custom-call" else None,
            line_no=ln, raw=line.strip()))
    return mod


# --------------------------------------------------------------------------
# entry point + collective accounting
# --------------------------------------------------------------------------

def parse_module(text: str) -> Module:
    """Parse HLO text or StableHLO MLIR into a normalized `Module`.

    Dialect is auto-detected: MLIR programs open with ``module @`` /
    contain ``stablehlo.`` ops; everything else parses as HLO text."""
    head = text[:4096]
    if ("stablehlo." in text or "mhlo." in head
            or head.lstrip().startswith(("module @", "module attributes",
                                         "func.func"))):
        return _parse_stablehlo(text)
    return _parse_hlo(text)


def collective_payload_bytes(instr: Instruction,
                             sizes: Optional[Dict[str, int]] = None
                             ) -> float:
    """Per-device wire bytes of one collective (ring conventions)."""
    base = _collective_base(instr.opcode)
    out_bytes = instr.result_bytes
    in_bytes = instr.operand_bytes
    if in_bytes == 0 and sizes:
        in_bytes = sum(sizes.get(o, 0) for o in instr.operands)
    if base == "all-reduce":
        return 2 * in_bytes
    if base == "all-gather":
        return max(out_bytes - in_bytes, out_bytes // 2)
    if base == "reduce-scatter":
        return max(in_bytes - out_bytes, in_bytes // 2)
    return max(in_bytes, out_bytes)     # all-to-all, collective-permute


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    wire_bytes: float = 0.0          # per device
    by_op: Dict[str, float] = field(default_factory=dict)


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Parse per-device wire bytes from (post-SPMD) HLO text.

    Operands are printed by NAME in optimized HLO dumps; a first pass
    builds the name -> result-bytes symbol table so payloads resolve.

    NOTE on while loops: collectives inside a while body are counted once
    (same undercount as cost_analysis); the dry-run lowers with unrolled
    layer stacks so per-step traffic is exact for the roofline table.
    """
    mod = parse_module(hlo_text)
    sizes = {i.name: i.result_bytes for i in mod.instructions}
    stats = CollectiveStats()
    for instr in mod.collectives():
        base = _collective_base(instr.opcode)
        wire = collective_payload_bytes(instr, sizes)
        stats.counts[base] = stats.counts.get(base, 0) + 1
        stats.by_op[base] = stats.by_op.get(base, 0.0) + wire
        stats.wire_bytes += wire
    return stats


def roofline(*, flops: float, hbm_bytes: float, wire_bytes_per_chip: float,
             chips: int, hw: Dict[str, float] = HW) -> Dict[str, float]:
    """Three-term roofline (seconds) + bottleneck."""
    terms = {
        "compute_s": flops / (chips * hw["peak_flops_bf16"]),
        "memory_s": hbm_bytes / (chips * hw["hbm_bw"]),
        "collective_s": wire_bytes_per_chip / hw["ici_bw"],
    }
    terms["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["step_s_lower_bound"] = max(
        terms["compute_s"], terms["memory_s"], terms["collective_s"])
    return terms
