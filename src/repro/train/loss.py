"""Losses: token cross-entropy + the framework-level integration point for
the paper's technique — an optional log-determinant decorrelation auxiliary
on hidden-state covariance, computed with the condensation core.

The logdet-reg term maximizes ``logdet(Cov(h) + eps I) - tr(Cov(h))``
(a soft-whitening / decorrelation objective from the representation-learning
literature): it is the place a *training framework* genuinely computes a
large log-determinant every step — the paper's motivating use-case
(log-likelihood of Gaussian models) expressed as a first-class feature that
every arch config can enable (TrainConfig.logdet_reg > 0).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.condense import slogdet_condense
from repro.models.common import ModelConfig


def cross_entropy(logits, targets, *, z_loss: float = 1e-4):
    """Mean token NLL (+ z-loss for logit drift control, MaxText-style)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    nll = (lse - ll).mean()
    if z_loss:
        nll = nll + z_loss * (lse ** 2).mean()
    return nll


def chunked_cross_entropy(hidden, embed_or_head, targets, *,
                          softcap: float = 0.0, z_loss: float = 1e-4,
                          chunk: int = 512, unroll: bool = False):
    """CE computed seq-chunk-wise so (B, T, V) f32 logits never materialize.

    For a 262k vocab at (256, 4096) the full logits tensor is 1.1 PB global;
    chunking bounds the live slab to (B, chunk, V) — with the vocab sharded
    over "model" that is ~0.5 GiB/device.  jax.checkpoint on the chunk body
    keeps the backward pass at the same bound (logits are recomputed).
    """
    from repro.sharding import hints
    b, t, d = hidden.shape
    chunk = min(chunk, t)
    n_chunks = t // chunk
    rem = t - n_chunks * chunk
    table = embed_or_head.astype(jnp.float32)
    # gather a seq-sharded residual before chunking along T
    hidden = hints.constrain(hidden, "gathered")

    @jax.checkpoint
    def chunk_loss(h, y):
        logits = jnp.einsum("btd,vd->btv", h.astype(jnp.float32), table)
        logits = hints.constrain(logits, "ce_logits")
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, y[..., None].astype(jnp.int32),
                                 axis=-1)[..., 0]
        out = (lse - ll).sum()
        if z_loss:
            out = out + z_loss * (lse ** 2).sum()
        return out

    hc = hidden[:, :n_chunks * chunk].reshape(b, n_chunks, chunk, d)
    yc = targets[:, :n_chunks * chunk].reshape(b, n_chunks, chunk)

    def body(acc, inp):
        h, y = inp
        return acc + chunk_loss(h, y), None

    total, _ = jax.lax.scan(
        body, jnp.zeros((), jnp.float32),
        (hc.swapaxes(0, 1), yc.swapaxes(0, 1)),
        unroll=True if unroll else 1)
    if rem:
        total = total + chunk_loss(hidden[:, -rem:], targets[:, -rem:])
    return total / (b * t)


def logdet_decorrelation(h, *, eps: float = 1e-3):
    """-logdet(Cov(h)+eps I) + tr(Cov) soft-whitening aux on features h.

    h: (..., d) activations; covariance over all leading axes.  The logdet
    runs through the condensation core (differentiable: every op in
    slogdet_condense is jnp).
    """
    d = h.shape[-1]
    flat = h.reshape(-1, d).astype(jnp.float32)
    mu = flat.mean(0)
    xc = flat - mu
    cov = xc.T @ xc / flat.shape[0] + eps * jnp.eye(d, dtype=jnp.float32)
    _, ld = slogdet_condense(cov)
    return jnp.trace(cov) / d - ld / d
