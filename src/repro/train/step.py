"""Training step factory: grad accumulation (microbatching), global-norm
clipping, optional bf16 gradient compression, optional logdet-reg aux, and
optimizer update — all inside ONE jittable function so the whole step lowers
to a single XLA program (collectives scheduled/overlapped by the compiler).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import model as M
from repro.models.common import ModelConfig
from repro.optim.optimizers import (
    OptConfig, clip_by_global_norm, get_optimizer,
)
from repro.train.loss import (
    chunked_cross_entropy, cross_entropy, logdet_decorrelation,
)


@dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    microbatches: int = 1          # grad accumulation steps per train step
    moe_aux_weight: float = 0.01
    logdet_reg: float = 0.0        # weight of the condensation-core aux loss
    grad_compression: bool = False # cast grads to bf16 before the all-reduce
    ce_chunk: int = 512            # seq chunk for the fused unembed+CE
    accum_dtype: Any = jnp.float32 # grad-accumulation buffer dtype (bf16 at
                                   # 400B scale: halves the accum footprint)
    cast_params_bf16: bool = False # cast 2D+ params to bf16 BEFORE use: the
                                   # FSDP all-gathers then move bf16, not f32
                                   # (f32 master stays in the opt state)


def make_loss_fn(cfg: ModelConfig, tcfg: TrainConfig):
    def loss_fn(params, batch):
        if tcfg.cast_params_bf16:
            # shard-local cast precedes the FSDP gather -> bf16 on the wire;
            # grads w.r.t. the f32 leaves flow through the convert
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if p.ndim >= 2 and p.dtype == jnp.float32 else p, params)
        hidden, aux = M.forward_hidden(params, batch, cfg)
        table = params.get("head", params["embed"])
        loss = chunked_cross_entropy(hidden, table, batch["targets"],
                                     softcap=cfg.logits_softcap,
                                     chunk=tcfg.ce_chunk,
                                     unroll=not cfg.scan_layers)
        metrics = {"nll": loss}
        for k, v in aux.items():
            loss = loss + tcfg.moe_aux_weight * v
            metrics[k] = v
        if tcfg.logdet_reg:
            # decorrelation on the mean-pooled last hidden state — the
            # framework-level use of the paper's logdet core
            emb = M.embed_lookup(params["embed"], batch["tokens"], cfg.dtype)
            pooled = emb.mean(axis=1)
            reg = logdet_decorrelation(pooled)
            loss = loss + tcfg.logdet_reg * reg
            metrics["logdet_reg"] = reg
        metrics["loss"] = loss
        return loss, metrics
    return loss_fn


def init_train_state(key, cfg: ModelConfig, tcfg: TrainConfig):
    params = M.init_model(key, cfg)
    opt_init, _ = get_optimizer(tcfg.opt)
    return {"params": params, "opt": opt_init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics)."""
    loss_fn = make_loss_fn(cfg, tcfg)
    _, opt_update = get_optimizer(tcfg.opt)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compress(g):
        if not tcfg.grad_compression:
            return g
        return jax.tree.map(
            lambda x: x.astype(jnp.bfloat16).astype(jnp.float32)
            if x.dtype == jnp.float32 else x, g)

    def one_micro(params, mb):
        (loss, metrics), grads = grad_fn(params, mb)
        return compress(grads), metrics

    def train_step(state, batch):
        params = state["params"]
        if tcfg.microbatches > 1:
            def split(x):
                b = x.shape[0]
                mb = tcfg.microbatches
                return x.reshape(mb, b // mb, *x.shape[1:])
            mbs = jax.tree.map(split, batch)
            adt = tcfg.accum_dtype

            def body(acc, mb):
                g, m = one_micro(params, mb)
                acc_g, acc_m = acc
                acc_g = jax.tree.map(
                    lambda a, x: a + x.astype(adt), acc_g, g)
                acc_m = jax.tree.map(jnp.add, acc_m, m)
                return (acc_g, acc_m), None

            g0, m0 = one_micro(params, jax.tree.map(lambda x: x[0], mbs))
            g0 = jax.tree.map(lambda x: x.astype(adt), g0)
            (grads, metrics), _ = lax.scan(
                body, (g0, m0), jax.tree.map(lambda x: x[1:], mbs))
            inv = 1.0 / tcfg.microbatches
            # keep grads in accum_dtype: clip + optimizer cast PER LEAF, so
            # no full-tree f32 copy (6.25 GB/chip at 400B) is materialized
            grads = jax.tree.map(lambda x: x * jnp.asarray(inv, x.dtype),
                                 grads)
            metrics = jax.tree.map(lambda x: x * inv, metrics)
        else:
            grads, metrics = one_micro(params, batch)

        grads, gnorm = clip_by_global_norm(grads, tcfg.opt.clip_norm)
        new_params, new_opt = opt_update(grads, state["opt"], params)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1}, metrics)

    return train_step
