from repro.train.step import TrainConfig, make_train_step, init_train_state, make_loss_fn
from repro.train.loss import cross_entropy, logdet_decorrelation
