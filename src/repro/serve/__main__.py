"""``python -m repro.serve`` — run the logdet service, or pre-export plans.

Subcommands::

    serve          start the HTTP service (default when no subcommand)
        --host/--port        bind address (port 0 picks a free port)
        --buckets 64,128,256 bucket ladder
        --max-batch/--max-wait-ms/--cache-capacity
        --method             default method ('auto' resolves per bucket)
        --plan-dir DIR       load AOT artifacts from DIR instead of
                             compiling at warmup
        --no-warmup          skip startup warmup (first requests compile)
        --metrics-port       repro.obs scrape endpoint (shared flag with
                             repro.launch.serve)

    export         AOT-compile and serialize every plan the ladder needs
        --out DIR            artifact directory (feed back as --plan-dir)
        same ladder/batch/method flags as serve

On startup the serve subcommand prints exactly one ready line::

    serving on http://HOST:PORT

(after warmup, so a supervisor that waits for the line gets a service
that never compiles at request time).
"""
from __future__ import annotations

import argparse
import sys

from repro import obs


def _parse_buckets(text: str):
    try:
        return tuple(int(tok) for tok in text.split(",") if tok.strip())
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"buckets must be comma-separated ints, got {text!r}")


def _add_ladder_flags(ap: argparse.ArgumentParser) -> None:
    from repro.serve.bucket import DEFAULT_BUCKETS
    ap.add_argument("--buckets", type=_parse_buckets,
                    default=DEFAULT_BUCKETS, metavar="N,N,...",
                    help="bucket ladder (default "
                         + ",".join(map(str, DEFAULT_BUCKETS)) + ")")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--method", default="auto",
                    help="default method for requests that name none")
    ap.add_argument("--dtype", default="float64",
                    choices=["float32", "float64"])
    ap.add_argument("--seed", type=int, default=0)


def _config_from_args(args):
    from repro.serve.service import ServeConfig
    return ServeConfig(
        buckets=args.buckets, max_batch=args.max_batch,
        max_wait_ms=getattr(args, "max_wait_ms", 2.0),
        cache_capacity=getattr(args, "cache_capacity", 64),
        plan_dir=getattr(args, "plan_dir", None),
        default_method=args.method, dtype=args.dtype, seed=args.seed)


def _cmd_serve(args) -> int:
    from repro.serve.http import serve_http
    from repro.serve.service import LogdetService

    metrics_server = obs.start_metrics_from_args(args)
    service = LogdetService(_config_from_args(args))
    if not args.no_warmup:
        dt = service.warmup()
        print(f"warmup: {len(service.plans)} plans ready in {dt:.1f}s",
              file=sys.stderr)
    server = serve_http(service, args.host, args.port)
    host, port = server.server_address[:2]
    print(f"serving on http://{host}:{port}", flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.shutdown()
        service.close()
        if metrics_server is not None:
            metrics_server.shutdown()
    return 0


def _cmd_export(args) -> int:
    import os

    import repro
    from repro.serve.bucket import BucketLadder
    from repro.serve.service import plan_filename

    os.makedirs(args.out, exist_ok=True)
    ladder = BucketLadder(args.buckets)
    batches, b = [], 1
    while b < args.max_batch:
        batches.append(b)
        b *= 2
    batches.append(args.max_batch)
    for bucket in ladder.buckets:
        if args.method == "auto":
            method = repro.select_method((bucket, bucket))
        else:
            method = args.method
        for batch in dict.fromkeys(batches):
            shape = ((bucket, bucket) if batch == 1
                     else (batch, bucket, bucket))
            plan = repro.plan(shape, method=method, precision=args.dtype,
                              validate=False)
            path = os.path.join(
                args.out, plan_filename(method, bucket, batch, args.dtype))
            plan.export(path)
            print(f"exported {path}")
    return 0


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0].startswith("-"):
        argv = ["serve", *argv]   # bare invocation serves
    ap = argparse.ArgumentParser(prog="python -m repro.serve",
                                 description=__doc__.split("\n", 1)[0])
    sub = ap.add_subparsers(dest="cmd")

    serve = sub.add_parser("serve", help="run the HTTP logdet service")
    serve.add_argument("--host", default="127.0.0.1")
    serve.add_argument("--port", type=int, default=8080,
                       help="0 picks a free port (printed on the ready line)")
    _add_ladder_flags(serve)
    serve.add_argument("--max-wait-ms", type=float, default=2.0)
    serve.add_argument("--cache-capacity", type=int, default=64)
    serve.add_argument("--plan-dir", default=None, metavar="DIR",
                       help="load AOT plan artifacts from DIR")
    serve.add_argument("--no-warmup", action="store_true")
    obs.add_metrics_cli(serve)

    export = sub.add_parser(
        "export", help="AOT-export every plan the ladder needs")
    export.add_argument("--out", required=True, metavar="DIR")
    _add_ladder_flags(export)

    args = ap.parse_args(argv)
    if args.dtype == "float64":
        import jax
        jax.config.update("jax_enable_x64", True)
    if args.cmd == "serve":
        return _cmd_serve(args)
    return _cmd_export(args)


if __name__ == "__main__":
    sys.exit(main())
