"""AOT plan export/import — compile once, serve forever.

A serving process must never pay trace or compile time at request time.
`export_plan` takes a *compiled* `LogdetPlan`, lowers a fresh forward at
the plan's exact avals, compiles it, and serializes the XLA executable
(``jax.experimental.serialize_executable``) together with a JSON header
describing what the artifact is for.  `load_plan` reverses it: the
returned `LogdetPlan` wraps the deserialized executable directly — its
``trace_count`` stays 0 forever and the ``plan.traces`` metric never
moves, which is the property tests/test_serve.py asserts.

File layout (single file, magic-tagged)::

    REPROPLAN\\x00 | u32 header_len | header JSON | pickle(payload, trees)

The header carries a format version, the problem spec, the typed config,
and a **device fingerprint** (platform, device kind, device count, jax
version, x64 state).  XLA executables are only valid on the hardware and
runtime they were compiled for; `load_plan` refuses a mismatch with a
field-by-field error instead of letting XLA segfault on a stale binary.

What can be exported: any plan with ``plan.compiled`` — the serial/staged
exact engine routes (single or batched) and the single-device dense
estimators.  Mesh-schedule and operator plans compose eagerly over cached
inner executables and raise `PlanExportError`.  Exported programs are
additionally screened through the `repro.analysis` pass framework
(``exportable-custom-calls`` + ``no-host-callback``): LAPACK handles and
host callbacks do not survive process boundaries; the repro engine and
estimators lower to pure XLA ops, so this screen only trips on foreign
code or telemetry left enabled.

AOT-loaded plans are execute-only: they cannot be traced into an outer
``jit``/``grad`` (the executable is a binary, not a jaxpr) and
``value_and_grad`` raises — re-plan locally when you need gradients.
"""
from __future__ import annotations

import dataclasses
import json
import pickle
import struct
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.core.configs import (
    ESTIMATOR_METHODS, config_from_dict, config_to_dict,
)
from repro.core.result import Diagnostics

__all__ = [
    "PLAN_FORMAT", "PlanExportError", "PlanFingerprintError",
    "device_fingerprint", "export_plan", "load_plan", "read_header",
]

PLAN_FORMAT = 1
_MAGIC = b"REPROPLAN\x00"

class PlanExportError(ValueError):
    """The plan cannot be exported as an AOT artifact."""


class PlanFingerprintError(ValueError):
    """The artifact was compiled for a different device/runtime."""


def device_fingerprint() -> Dict[str, Any]:
    """What an XLA executable is pinned to in this process."""
    dev = jax.devices()[0]
    return {
        "platform": dev.platform,
        "device_kind": dev.device_kind,
        "device_count": jax.device_count(),
        "jax_version": jax.__version__,
        "x64": bool(jax.config.jax_enable_x64),
    }


def _screen_export(lowered, plan) -> None:
    """Refuse programs whose executables cannot cross a process boundary.

    Runs the shared `repro.analysis` pass framework over the lowering
    with ``kind="export"``: custom-call targets outside
    `repro.analysis.passes.SAFE_CUSTOM_CALLS` (LAPACK handles are host
    function pointers that do not survive serialization) and leaked host
    callbacks both block the export.
    """
    from repro.analysis.audit import context_for
    from repro.analysis.passes import run_passes

    ctx = dataclasses.replace(context_for(plan, kind="export"),
                              obs_mode="off")
    report = run_passes(lowered.as_text(), ctx,
                        ("exportable-custom-calls", "no-host-callback"))
    if not report.ok:
        raise PlanExportError("; ".join(
            f.message for f in report.errors))


def export_plan(plan, path: str) -> str:
    """Serialize ``plan``'s compiled forward to ``path``; returns ``path``.

    The artifact replays bit-identically in any process whose device
    fingerprint matches (`load_plan` enforces this).  The live plan's
    trace counters are untouched — export lowers a fresh forward from the
    plan's spec/config rather than re-tracing the plan's own executable.
    """
    # imported here, not at module top: core.plan lazily imports THIS
    # module for LogdetPlan.export
    from repro.core.plan import _build_forward
    from jax.experimental.serialize_executable import serialize

    if plan.spec.kind == "operator":
        raise PlanExportError(
            "operator plans compose the operator's own executables and "
            "cannot be exported; export a dense/batched plan instead")
    if not plan.compiled:
        raise PlanExportError(
            "only compiled plans are exportable; mesh-schedule and "
            "sharded-estimator plans compose eager shard_map executables "
            f"(plan: method={plan.method!r}, mesh={plan.mesh is not None})")

    spec, method, cfg = plan.spec, plan.method, plan.config
    dtype = jnp.dtype(spec.dtype)
    shape = ((spec.n, spec.n) if spec.batch is None
             else (spec.batch, spec.n, spec.n))
    a_aval = jax.ShapeDtypeStruct(shape, dtype)

    # a fresh forward with a scratch trace log: exporting must not mark a
    # retrace on the live plan
    fwd, compiled_flag, _padded_n = _build_forward(
        spec, method, cfg, None, plan.axis_name, dtype, trace_log=[])
    assert compiled_flag, "compiled plan rebuilt as eager"

    key_info: Optional[Dict[str, Any]] = None
    with obs.span("serve.aot.export", method=method, n=spec.n):
        if method in ESTIMATOR_METHODS:
            k0 = np.asarray(jax.random.PRNGKey(getattr(cfg, "seed", 0)))
            key_info = {"shape": list(k0.shape), "dtype": str(k0.dtype)}
            k_aval = jax.ShapeDtypeStruct(k0.shape, k0.dtype)
            lowered = jax.jit(lambda a, key: fwd(a, key=key)) \
                .lower(a_aval, k_aval)
        else:
            lowered = jax.jit(lambda a: fwd(a)).lower(a_aval)
        _screen_export(lowered, plan)
        payload, in_tree, out_tree = serialize(lowered.compile())

    header = {
        "format": PLAN_FORMAT,
        "method": method,
        "spec": dataclasses.asdict(spec),
        "config": config_to_dict(cfg),
        "key": key_info,
        "padded_n": plan.diagnostics.padded_n,
        "fingerprint": device_fingerprint(),
        "created_unix": time.time(),
    }
    head = json.dumps(header, sort_keys=True).encode()
    with open(path, "wb") as f:
        f.write(_MAGIC)
        f.write(struct.pack("<I", len(head)))
        f.write(head)
        pickle.dump((payload, in_tree, out_tree), f)
    obs.inc("serve.aot.exports", method=method)
    return path


def _read(path: str) -> Tuple[Dict[str, Any], bytes]:
    with open(path, "rb") as f:
        magic = f.read(len(_MAGIC))
        if magic != _MAGIC:
            raise PlanExportError(
                f"{path}: not a repro plan artifact (bad magic)")
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen))
        blob = f.read()
    if header.get("format") != PLAN_FORMAT:
        raise PlanExportError(
            f"{path}: plan format {header.get('format')!r} not supported "
            f"(this build reads format {PLAN_FORMAT})")
    return header, blob


def read_header(path: str) -> Dict[str, Any]:
    """Parse and return the JSON header only (no executable load)."""
    return _read(path)[0]


def check_fingerprint(header: Dict[str, Any], path: str) -> None:
    want, have = header["fingerprint"], device_fingerprint()
    bad = [f"{k}: artifact={want.get(k)!r} process={have.get(k)!r}"
           for k in sorted(set(want) | set(have))
           if want.get(k) != have.get(k)]
    if bad:
        raise PlanFingerprintError(
            f"{path}: plan was compiled for a different device/runtime — "
            + "; ".join(bad)
            + ". Re-export on this host (plan.export) or serve on the "
            "hardware the artifact was built for.")


def _is_tracer(x) -> bool:
    try:
        return isinstance(x, jax.core.Tracer)
    except AttributeError:  # pragma: no cover
        return False


def load_plan(path: str, *, validate: bool = True,
              check_device: bool = True):
    """Load an exported plan — zero traces, zero compiles, ever.

    Returns a `LogdetPlan` whose forward is the deserialized executable.
    ``check_device=False`` skips the fingerprint check (only for tests
    that tamper with headers; a real mismatch can crash the process).
    """
    from repro.core.plan import LogdetPlan, ProblemSpec, _flops_est
    from jax.experimental.serialize_executable import deserialize_and_load

    header, blob = _read(path)
    if check_device:
        check_fingerprint(header, path)

    spec = ProblemSpec(**header["spec"])
    try:
        cfg = config_from_dict(header["config"])
    except ValueError as exc:
        raise PlanExportError(f"{path}: {exc}") from None
    method = header["method"]

    with obs.span("serve.aot.load", method=method, n=spec.n):
        payload, in_tree, out_tree = pickle.loads(blob)
        executable = deserialize_and_load(payload, in_tree, out_tree)

    dtype = jnp.dtype(spec.dtype)
    estimator = method in ESTIMATOR_METHODS
    if estimator:
        default_key = np.asarray(
            jax.random.PRNGKey(getattr(cfg, "seed", 0)))

    def fwd(a, key=None, probes=None, lmin=None, lmax=None):
        if any(_is_tracer(v) for v in (a, key, probes, lmin, lmax)):
            raise TypeError(
                "AOT-loaded plans are execute-only: the forward is a "
                "deserialized XLA binary and cannot be traced into jit/"
                "grad/vmap — build a local plan with repro.plan instead")
        if probes is not None or lmin is not None or lmax is not None:
            raise TypeError(
                "AOT-loaded plans accept `key` only; probes and spectral "
                "bounds were baked in (or resolved) at export time")
        if getattr(a, "dtype", None) != dtype:
            a = jnp.asarray(a, dtype)
        if not estimator:
            if key is not None:
                raise TypeError(
                    f"exact method {method!r} takes no key")
            return executable(a)
        k = default_key if key is None else key
        return executable(a, k)

    cols, flops = _flops_est(method, spec, cfg, 1)
    plan = LogdetPlan(
        spec=spec, method=method, config=cfg, mesh=None, grad=False,
        validate=validate, compiled=True,
        diagnostics=Diagnostics(matvec_cols=cols, flops_est=flops,
                                padded_n=header.get("padded_n", spec.n),
                                device_count=1),
        _fwd=fwd, _trace_log=[])
    plan._cache["aot_path"] = path
    plan._cache["vag"] = _vag_unavailable
    obs.inc("serve.aot.loads", method=method)
    return plan


def _vag_unavailable(x, key=None):
    raise NotImplementedError(
        "AOT-loaded plans are execute-only; gradients need a locally "
        "built plan (repro.plan(..., grad=True))")
