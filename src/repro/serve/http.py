"""Stdlib HTTP front end for `LogdetService`.

Endpoints (JSON in, JSON out)::

    POST /v1/logdet    {"matrix": [[...]], "method": "auto", "rtol": null}
                       or {"matrices": [[[...]], ...], ...} for several
                       independent requests in one call (each is admitted
                       separately; the server may batch them with other
                       traffic).
    GET  /healthz      {"status": "ok", ...}
    GET  /stats        LogdetService.stats() snapshot
    GET  /metrics      Prometheus text (same registry as repro.obs)

The handler threads only do admission + JSON; every matrix still flows
through the service's single drain thread, so HTTP concurrency feeds the
batcher instead of racing it.  Malformed requests get a 400 with an
``{"error": ...}`` body; execution failures surface as 500 with the
exception text.
"""
from __future__ import annotations

import json
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict

import numpy as np

from repro import obs
from repro.serve.service import LogdetService

__all__ = ["serve_http", "make_handler"]

_MAX_BODY = 512 * 1024 * 1024  # refuse absurd request bodies outright


def _result_json(res) -> Dict[str, Any]:
    d = res.diagnostics
    return {
        "sign": float(res.sign),
        "logabsdet": float(res.logabsdet),
        "sem": None if res.sem is None or not np.isfinite(res.sem)
        else float(res.sem),
        "method": res.method_used,
        "bucket": d.padded_n,
    }


def make_handler(service: LogdetService):
    """Build the request-handler class bound to one service instance."""

    class Handler(BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"

        # ------------------------------------------------------ plumbing
        def _send(self, code: int, payload: Dict[str, Any]) -> None:
            body = json.dumps(payload).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):      # keep stdout for the operator
            pass

        # ------------------------------------------------------- routes
        def do_GET(self):  # noqa: N802 (stdlib casing)
            path = self.path.split("?", 1)[0].rstrip("/") or "/"
            if path in ("/", "/healthz"):
                self._send(200, {"status": "ok",
                                 "buckets": list(service.ladder.buckets),
                                 "dtype": service.config.dtype})
            elif path == "/stats":
                self._send(200, service.stats())
            elif path == "/metrics":
                body = obs.prometheus_text().encode()
                self.send_response(200)
                self.send_header(
                    "Content-Type",
                    "text/plain; version=0.0.4; charset=utf-8")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)
            else:
                self._send(404, {"error": f"no such path {self.path!r}"})

        def do_POST(self):  # noqa: N802
            path = self.path.split("?", 1)[0].rstrip("/")
            if path != "/v1/logdet":
                self._send(404, {"error": f"no such path {self.path!r}"})
                return
            try:
                length = int(self.headers.get("Content-Length", 0))
                if not 0 < length <= _MAX_BODY:
                    raise ValueError(
                        f"Content-Length must be in (0, {_MAX_BODY}]")
                req = json.loads(self.rfile.read(length))
                if "matrix" in req:
                    mats, single = [req["matrix"]], True
                elif "matrices" in req:
                    mats, single = list(req["matrices"]), False
                    if not mats:
                        raise ValueError("'matrices' is empty")
                else:
                    raise ValueError(
                        "body must contain 'matrix' or 'matrices'")
                method = req.get("method")
                rtol = req.get("rtol")
                # admit everything before waiting on anything, so one
                # HTTP call's matrices can share a drain batch
                futures = [service.submit(m, method=method, rtol=rtol)
                           for m in mats]
            except (ValueError, TypeError, json.JSONDecodeError) as exc:
                self._send(400, {"error": str(exc)})
                return
            try:
                results = [_result_json(f.result()) for f in futures]
            except Exception as exc:       # noqa: BLE001 — report upstream
                self._send(500, {"error": f"{type(exc).__name__}: {exc}"})
                return
            self._send(200, results[0] if single
                       else {"results": results})

    return Handler


def serve_http(service: LogdetService, host: str = "127.0.0.1",
               port: int = 8080) -> ThreadingHTTPServer:
    """Bind and return the server (caller runs ``serve_forever``)."""
    return ThreadingHTTPServer((host, port), make_handler(service))
