"""Request coalescing: heterogeneous queue -> homogeneous stacks.

The drain loop pulls whatever requests are pending and must turn a mixed
bag of ``(A, method, rtol)`` into as few executable dispatches as
possible.  `coalesce` is the pure core of that: it groups requests by
``(bucket, method, rtol)`` — everything that can legally share one
``(B, b, b)`` stack — preserving FIFO admission order both across groups
(a group is ordered by its oldest member) and within a group (results
are split back positionally, so per-request ordering never depends on
how the batch was packed).

Groups larger than ``max_batch`` are split into consecutive chunks; the
batch *executable* size is then bucketed separately (`bucket_batch`) so
a 5-request chunk runs through the warm ``B=8`` stack with identity
filler rather than compiling a ``B=5`` one.
"""
from __future__ import annotations

import itertools
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.bucket import BucketLadder

__all__ = ["Request", "BatchGroup", "coalesce"]

_ids = itertools.count()


@dataclass
class Request:
    """One admitted logdet request, waiting in the queue."""
    a: np.ndarray                      # validated square (n, n), host-side
    n: int
    bucket: int
    method: str                        # as requested ("auto" allowed)
    rtol: Optional[float]
    future: Future = field(default_factory=Future)
    id: int = field(default_factory=lambda: next(_ids))
    t_submit: float = field(default_factory=time.perf_counter)


@dataclass
class BatchGroup:
    """Requests that share one padded stack and one plan."""
    bucket: int
    method: str
    rtol: Optional[float]
    requests: List[Request]

    @property
    def oldest(self) -> float:
        return min(r.t_submit for r in self.requests)


def coalesce(requests: Sequence[Request],
             max_batch: int) -> List[BatchGroup]:
    """Group pending requests into homogeneous, FIFO-ordered batches.

    Returns groups sorted by their oldest member's submit time, each at
    most ``max_batch`` long, members in admission order.
    """
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    by_key = {}
    for r in sorted(requests, key=lambda r: r.id):
        by_key.setdefault((r.bucket, r.method, r.rtol), []).append(r)
    groups: List[BatchGroup] = []
    for (bucket, method, rtol), members in by_key.items():
        for i in range(0, len(members), max_batch):
            groups.append(BatchGroup(bucket=bucket, method=method,
                                     rtol=rtol,
                                     requests=members[i:i + max_batch]))
    groups.sort(key=lambda g: g.oldest)
    return groups


def admit(a, ladder: BucketLadder, *, method: str,
          rtol: Optional[float], dtype) -> Request:
    """Validate one raw input into a `Request` (raises on bad input)."""
    arr = np.asarray(a, dtype)
    if arr.ndim != 2 or arr.shape[0] != arr.shape[1]:
        raise ValueError(
            f"expected one square (n, n) matrix per request, got shape "
            f"{arr.shape}; submit stacks as individual requests and let "
            "the server batch them")
    if not np.all(np.isfinite(arr)):
        raise ValueError("matrix contains non-finite entries")
    n = arr.shape[0]
    return Request(a=arr, n=n, bucket=ladder.bucket_for(n),
                   method=method, rtol=rtol)
