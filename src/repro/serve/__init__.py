"""repro.serve — logdet-as-a-service on top of `LogdetPlan`.

Layers (each usable on its own):

==============  ========================================================
``aot``         AOT plan export/import: `export_plan` / `load_plan`
                serialize a compiled plan's XLA executable with a
                device-fingerprint header — the serving process never
                traces or compiles at request time
``bucket``      the pad-to-bucket policy (`BucketLadder`,
                `pad_to_bucket`, `stack_to_bucket`) and the warm-plan
                LRU (`PlanCache`)
``batching``    request admission and coalescing of heterogeneous
                ``(A, method, rtol)`` traffic into homogeneous stacks
``service``     `LogdetService` — submit() -> Future[LogdetResult],
                one continuous-batching drain thread
``http``        stdlib JSON front end (``POST /v1/logdet`` ...)
==============  ========================================================

``python -m repro.serve`` runs the HTTP service; see docs/serving.md.
"""
from repro.serve.aot import (
    PLAN_FORMAT, PlanExportError, PlanFingerprintError, device_fingerprint,
    export_plan, load_plan, read_header,
)
from repro.serve.batching import BatchGroup, Request, coalesce
from repro.serve.bucket import (
    DEFAULT_BUCKETS, BucketLadder, PlanCache, bucket_batch, pad_to_bucket,
    stack_to_bucket,
)
from repro.serve.service import (
    LogdetService, ServeConfig, ServiceClosed, plan_filename,
)

__all__ = [
    "PLAN_FORMAT", "PlanExportError", "PlanFingerprintError",
    "device_fingerprint", "export_plan", "load_plan", "read_header",
    "BatchGroup", "Request", "coalesce",
    "DEFAULT_BUCKETS", "BucketLadder", "PlanCache", "bucket_batch",
    "pad_to_bucket", "stack_to_bucket",
    "LogdetService", "ServeConfig", "ServiceClosed", "plan_filename",
]
