"""`LogdetService` — the warm, continuously-batching logdet engine.

Ties the pieces together: admission (`batching.admit`), the bucket
ladder, the warm `PlanCache`, AOT plan preloading, and a single drain
thread that coalesces whatever is pending into homogeneous padded
stacks and runs each through one warm executable::

    with LogdetService(ServeConfig(buckets=(64, 128, 256))) as svc:
        svc.warmup()
        fut = svc.submit(a, method="auto")      # returns a Future
        result = fut.result()                   # per-request LogdetResult

Throughput comes from never compiling at request time: every request is
padded up to a bucket rung and drained through a plan that was warmed at
startup (or AOT-loaded from ``plan_dir`` — see repro.serve.aot).  The
drain is one thread by design: requests queue while a batch executes and
are coalesced when it finishes — continuous batching, no locks on the
hot path, strict FIFO fairness.

Ordering guarantees: admission order is request order (`submit` is the
serialization point); the drain preserves FIFO across groups (oldest
request first) and within a group (results are split back by position).
Completion order across *different* buckets is not guaranteed — a small
matrix behind a large one may finish first; per-request futures make
that safe.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from repro import obs
from repro.core.configs import ESTIMATOR_METHODS, METHODS
from repro.core.result import LogdetResult
from repro.serve.batching import BatchGroup, Request, admit, coalesce
from repro.serve.bucket import (
    DEFAULT_BUCKETS, BucketLadder, PlanCache, bucket_batch, stack_to_bucket,
)

__all__ = ["ServeConfig", "LogdetService", "ServiceClosed", "plan_filename"]


class ServiceClosed(RuntimeError):
    """The service is closed.

    Raised by `LogdetService.submit` after `close()`, and set on the
    futures of requests that were still queued when the drain thread
    stopped — a queued request must fail loudly, never hang its client.
    """


@dataclass(frozen=True)
class ServeConfig:
    """Serving knobs — everything the deployment tunes.

    ``buckets``        the shape ladder (requests above the top rung are
                       rejected at admission)
    ``max_batch``      largest stack one drain dispatch runs
    ``max_wait_ms``    how long the drain lingers for a batch to fill
                       once at least one request is pending (0 = drain
                       immediately; latency-vs-throughput dial)
    ``cache_capacity`` warm executables kept before LRU eviction
    ``plan_dir``       directory of AOT-exported plans to load instead
                       of compiling (see ``python -m repro.serve export``)
    ``default_method`` method used when a request does not name one
    ``dtype``          serving dtype; requests are cast on admission
    ``seed``           base of the per-batch estimator key sequence
    """
    buckets: Tuple[int, ...] = DEFAULT_BUCKETS
    max_batch: int = 16
    max_wait_ms: float = 2.0
    cache_capacity: int = 32
    plan_dir: Optional[str] = None
    default_method: str = "auto"
    dtype: str = "float64"
    seed: int = 0

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_wait_ms < 0:
            raise ValueError(
                f"max_wait_ms must be >= 0, got {self.max_wait_ms}")
        if self.default_method != "auto" and self.default_method not in METHODS:
            raise ValueError(
                f"unknown default_method {self.default_method!r}")
        object.__setattr__(self, "buckets",
                           BucketLadder(self.buckets).buckets)


def plan_filename(method: str, bucket: int, batch: int, dtype: str) -> str:
    """Canonical artifact name `python -m repro.serve export` writes and
    the service looks for inside ``plan_dir``."""
    return f"{method}-n{bucket}-B{batch}-{dtype}.repro-plan"


class LogdetService:
    """Bucketed, continuously-batching log-determinant service."""

    def __init__(self, config: ServeConfig = ServeConfig()):
        self.config = config
        self.ladder = BucketLadder(config.buckets)
        self.plans = PlanCache(capacity=config.cache_capacity)
        self._np_dtype = np.dtype(config.dtype)
        self._cond = threading.Condition()
        self._pending: list = []
        self._thread: Optional[threading.Thread] = None
        self._closed = False
        self._auto: Dict[tuple, str] = {}   # (bucket, rtol) -> method
        self._key_counter = int(config.seed)
        self._key_lock = threading.Lock()

    # ------------------------------------------------------------ admission

    def submit(self, a, *, method: Optional[str] = None,
               rtol: Optional[float] = None):
        """Admit one ``(n, n)`` matrix; returns a Future[LogdetResult].

        Raises immediately (not via the future) on malformed input:
        non-square, non-finite, or larger than the top bucket rung.
        """
        if self._closed:
            raise ServiceClosed("service is closed")
        m = method or self.config.default_method
        if m != "auto" and m not in METHODS:
            raise ValueError(f"unknown method {m!r}; one of {METHODS} "
                             "or 'auto'")
        req = admit(a, self.ladder, method=m, rtol=rtol,
                    dtype=self._np_dtype)
        obs.inc("serve.requests", method=m)
        obs.observe("serve.request_n", req.n)
        with self._cond:
            if self._closed:
                raise ServiceClosed("service is closed")
            self._ensure_thread()
            self._pending.append(req)
            self._cond.notify()
        return req.future

    def logdet(self, a, *, method: Optional[str] = None,
               rtol: Optional[float] = None,
               timeout: Optional[float] = None) -> LogdetResult:
        """Synchronous convenience wrapper over `submit`."""
        return self.submit(a, method=method, rtol=rtol).result(timeout)

    # ---------------------------------------------------------------- plans

    def _resolve(self, method: str, bucket: int,
                 rtol: Optional[float]) -> str:
        """Pin ``method="auto"`` per (bucket, rtol) — resolved once, on
        the single-matrix spec, so batching never changes the answer."""
        if method != "auto":
            return method
        key = (bucket, rtol)
        got = self._auto.get(key)
        if got is None:
            from repro.core.plan import select_method
            got = select_method((bucket, bucket), rtol=rtol)
            self._auto[key] = got
        return got

    def _plan_for(self, method: str, bucket: int, batch: int):
        key = (method, bucket, batch, self.config.dtype)
        return self.plans.get(key, lambda: self._build_plan(*key))

    def _build_plan(self, method: str, bucket: int, batch: int,
                    dtype: str):
        path = None
        if self.config.plan_dir:
            cand = os.path.join(self.config.plan_dir,
                                plan_filename(method, bucket, batch, dtype))
            if os.path.exists(cand):
                path = cand
        if path is not None:
            from repro.serve.aot import load_plan
            return load_plan(path, validate=False)
        import repro
        shape = (bucket, bucket) if batch == 1 else (batch, bucket, bucket)
        return repro.plan(shape, method=method, precision=dtype,
                          validate=False)

    def warmup(self, methods: Optional[Sequence[str]] = None,
               batches: Optional[Sequence[int]] = None,
               buckets: Optional[Sequence[int]] = None) -> float:
        """Build (or AOT-load) and execute every plan the drain can need,
        so no request ever pays a compile.  Returns wall seconds spent.

        Defaults: the configured ``default_method``, every bucket rung,
        and the full batch ladder 1, 2, 4, ... ``max_batch``.
        """
        t0 = time.perf_counter()
        methods = list(methods or [self.config.default_method])
        if batches is None:
            batches, b = [], 1
            while b < self.config.max_batch:
                batches.append(b)
                b *= 2
            batches.append(self.config.max_batch)
        with obs.span("serve.warmup"):
            for bucket in (buckets or self.ladder.buckets):
                for m in methods:
                    method = self._resolve(m, bucket, None)
                    for batch in dict.fromkeys(batches):
                        plan = self._plan_for(method, bucket, batch)
                        eye = stack_to_bucket([], bucket, batch,
                                              self._np_dtype)
                        x = eye if batch > 1 else eye[0]
                        if method in ESTIMATOR_METHODS:
                            plan(x, key=self._next_key()).logabsdet\
                                .block_until_ready()
                        else:
                            plan(x).logabsdet.block_until_ready()
        dt = time.perf_counter() - t0
        obs.set_gauge("serve.warmup_s", dt)
        return dt

    def _next_key(self) -> np.ndarray:
        """Fresh PRNG key per batch, derived host-side (no jax dispatch:
        this is exactly the (hi, lo) split an int seed becomes)."""
        with self._key_lock:
            c = self._key_counter
            self._key_counter += 1
        return np.array([c >> 32, c & 0xFFFFFFFF], np.uint32)

    # ---------------------------------------------------------------- drain

    def _ensure_thread(self):
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._drain_loop, name="repro-serve-drain",
                daemon=True)
            self._thread.start()

    def _drain_loop(self):
        wait_s = self.config.max_wait_ms / 1e3
        batch: list = []
        try:
            while True:
                with self._cond:
                    while not self._pending and not self._closed:
                        self._cond.wait()
                    if wait_s > 0 and not self._closed \
                            and len(self._pending) < self.config.max_batch:
                        deadline = time.perf_counter() + wait_s
                        while (len(self._pending) < self.config.max_batch
                               and not self._closed):
                            rem = deadline - time.perf_counter()
                            if rem <= 0:
                                break
                            self._cond.wait(rem)
                    popped, self._pending = self._pending, []
                    done = self._closed and not popped
                if done:
                    # keep `batch` pointing at the last popped work so the
                    # exit cleanup below can still fail anything _run_group
                    # left unresolved (e.g. it was wedged past close())
                    return
                batch = popped
                for group in coalesce(batch, self.config.max_batch):
                    self._run_group(group)
        finally:
            # the drain is stopping — normally (close) or by a crash
            # outside _run_group's guard (e.g. coalesce).  Whatever is
            # still queued, or popped but unprocessed, must fail loudly
            # instead of leaving forever-pending futures.
            self._fail_queued(batch)

    def _fail_queued(self, extra: Sequence = ()) -> None:
        """Fail every queued (and ``extra``) request with `ServiceClosed`."""
        with self._cond:
            leftovers, self._pending = self._pending, []
        exc = ServiceClosed(
            "service closed before this request was served")
        for r in list(extra) + leftovers:
            if not r.future.done():
                obs.inc("serve.responses", status="closed")
                r.future.set_exception(exc)

    def _run_group(self, g: BatchGroup) -> None:
        try:
            method = self._resolve(g.method, g.bucket, g.rtol)
            m = len(g.requests)
            batch = bucket_batch(m, self.config.max_batch)
            plan = self._plan_for(method, g.bucket, batch)
            stack = stack_to_bucket([r.a for r in g.requests],
                                    g.bucket, batch, self._np_dtype)
            x = stack if batch > 1 else stack[0]
            now = time.perf_counter()
            with obs.span("serve.batch", method=method, bucket=g.bucket,
                          size=m):
                if method in ESTIMATOR_METHODS:
                    res = plan(x, key=self._next_key())
                else:
                    res = plan(x)
            exec_ms = (time.perf_counter() - now) * 1e3
            signs = np.atleast_1d(np.asarray(res.sign))
            lds = np.atleast_1d(np.asarray(res.logabsdet))
            sems = np.atleast_1d(np.asarray(res.sem))
            for i, r in enumerate(g.requests):
                diags = dataclasses.replace(
                    res.diagnostics, padded_n=g.bucket)
                if r.future.done():      # already failed by close()
                    continue
                r.future.set_result(LogdetResult(
                    sign=signs[i], logabsdet=lds[i], sem=sems[i],
                    method_used=res.method_used, diagnostics=diags))
                obs.observe("serve.queue_wait_ms",
                            (now - r.t_submit) * 1e3)
                obs.observe("serve.pad_ratio", g.bucket / r.n)
            obs.inc("serve.batches", method=method, bucket=g.bucket)
            obs.inc("serve.responses", m, status="ok")
            obs.observe("serve.batch_size", m)
            obs.observe("serve.batch_fill", m / batch)
            obs.observe("serve.exec_ms", exec_ms, bucket=g.bucket)
        except Exception as exc:           # noqa: BLE001 — fail the futures
            obs.inc("serve.responses", len(g.requests), status="error")
            for r in g.requests:
                if not r.future.done():
                    r.future.set_exception(exc)

    # ------------------------------------------------------------ lifecycle

    def close(self, timeout: Optional[float] = 30.0) -> None:
        """Drain remaining requests, then stop the drain thread.

        Requests still queued when the drain stops — it crashed earlier,
        or ``timeout`` expired with it wedged — get `ServiceClosed` set
        on their futures; `submit` raises `ServiceClosed` from now on.
        """
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        # normally the drain already failed its own leftovers on exit;
        # this covers a wedged or previously-crashed thread
        self._fail_queued()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ---------------------------------------------------------------- intro

    def trace_count(self) -> int:
        """Total traces across every warm plan — a warm, spec-stable
        service holds this constant between calls (the zero-recompile
        property serve_bench and tests assert)."""
        return sum(p.trace_count for p in
                   (self.plans.get(k) for k in self.plans.keys())
                   if p is not None)

    def stats(self) -> dict:
        """JSON-friendly operational snapshot (served at ``GET /stats``)."""
        snap = obs.snapshot()
        serve_counters = {k: v for k, v in snap["counters"].items()
                          if k.startswith("serve.")}
        return {
            "buckets": list(self.ladder.buckets),
            "max_batch": self.config.max_batch,
            "max_wait_ms": self.config.max_wait_ms,
            "dtype": self.config.dtype,
            "plans_cached": len(self.plans),
            "plan_keys": ["|".join(map(str, k)) for k in self.plans.keys()],
            "auto_resolution": {f"n{b}" + (f"@rtol={r}" if r else ""): m
                                for (b, r), m in sorted(self._auto.items())},
            "trace_count": self.trace_count(),
            "pending": len(self._pending),
            "counters": serve_counters,
            "quantiles": {
                name: {"p50": obs.quantile(name, 0.5),
                       "p99": obs.quantile(name, 0.99)}
                for name in ("serve.queue_wait_ms", "serve.batch_size")
            },
        }
