"""Shape bucketing: the pad-to-bucket policy and the warm plan cache.

A serving process cannot afford one executable per request shape — with
sizes drawn from [64, 512] nearly every request would pay a fresh trace
and compile.  Requests are instead padded up to a **bucket ladder**: a
short ascending list of sizes, each served by a handful of warm
executables.  Padding embeds ``A`` as ``diag(A, I)``, which preserves
``slogdet`` exactly (the identity block contributes sign ``+1`` and
``log|det| = 0``), and adds only unit eigenvalues — harmless to the SPD
estimators too.

Batch sizes are bucketed the same way (1, 2, 4, ... ``max_batch``) so a
drain of 5 requests reuses the ``B=8`` executable with identity filler
matrices instead of compiling a ``B=5`` one.

`PlanCache` is the LRU of warm plans, keyed by whatever tuple the caller
chooses (the service uses ``(method, bucket, batch, dtype)``).  Eviction
drops the oldest-touched plan; hits, misses and evictions are counted in
`repro.obs` as ``serve.plan_cache.*``.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence, Tuple

import numpy as np

from repro import obs

__all__ = ["DEFAULT_BUCKETS", "BucketLadder", "PlanCache",
           "bucket_batch", "pad_to_bucket", "stack_to_bucket"]

# covers the mixed-request regime the benchmarks exercise (N in 64..512)
# with one rung of headroom; tune per deployment via ServeConfig.buckets
DEFAULT_BUCKETS = (64, 96, 128, 192, 256, 384, 512, 768, 1024)


@dataclass(frozen=True)
class BucketLadder:
    """Ascending rung sizes; every request is padded up to its rung."""
    buckets: Tuple[int, ...] = DEFAULT_BUCKETS

    def __post_init__(self):
        rungs = tuple(sorted({int(b) for b in self.buckets}))
        if not rungs or rungs[0] < 1:
            raise ValueError(f"need at least one positive bucket size, "
                             f"got {self.buckets!r}")
        object.__setattr__(self, "buckets", rungs)

    @property
    def max(self) -> int:
        return self.buckets[-1]

    def bucket_for(self, n: int) -> int:
        """Smallest rung >= n.  Raises for n above the top rung — a
        serving deployment must size its ladder for its traffic rather
        than silently compile unbounded executables."""
        if n < 1:
            raise ValueError(f"matrix size must be >= 1, got {n}")
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(
            f"matrix size {n} exceeds the top bucket {self.max}; add a "
            f"rung to the ladder (buckets={self.buckets})")


def bucket_batch(m: int, max_batch: int) -> int:
    """Smallest power-of-two batch >= m, capped at ``max_batch``."""
    if m < 1:
        raise ValueError(f"batch must be >= 1, got {m}")
    if m >= max_batch:
        return max_batch
    b = 1
    while b < m:
        b *= 2
    return min(b, max_batch)


def pad_to_bucket(a: np.ndarray, bucket: int,
                  dtype=np.float64) -> np.ndarray:
    """Embed one ``(n, n)`` matrix as ``diag(a, I)`` of size bucket."""
    n = a.shape[-1]
    if n > bucket:
        raise ValueError(f"matrix size {n} exceeds bucket {bucket}")
    out = np.zeros((bucket, bucket), dtype)
    out[:n, :n] = a
    if n < bucket:
        idx = np.arange(n, bucket)
        out[idx, idx] = 1.0
    return out


def stack_to_bucket(mats: Sequence[np.ndarray], bucket: int, batch: int,
                    dtype=np.float64) -> np.ndarray:
    """Pad each matrix to ``bucket`` and stack to ``(batch, b, b)``.

    Unused slots (``len(mats) < batch``) are identity matrices — their
    log-determinants are exactly 0 and are discarded on the way out.
    """
    if len(mats) > batch:
        raise ValueError(f"{len(mats)} matrices exceed batch {batch}")
    out = np.zeros((batch, bucket, bucket), dtype)
    idx = np.arange(bucket)
    out[:, idx, idx] = 1.0
    for i, a in enumerate(mats):
        n = a.shape[-1]
        out[i] = 0.0
        out[i, :n, :n] = a
        if n < bucket:
            tail = np.arange(n, bucket)
            out[i, tail, tail] = 1.0
    return out


@dataclass
class PlanCache:
    """LRU cache of warm plans (or any expensive keyed artifact).

    ``get(key, builder)`` returns the cached value, or builds, inserts
    and possibly evicts.  Thread-safe; the builder runs outside the lock
    is NOT guaranteed — the serve drain is single-threaded, and double
    builds are merely wasteful, never incorrect.
    """
    capacity: int = 32
    _lru: "OrderedDict[tuple, object]" = field(default_factory=OrderedDict)
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")

    def __len__(self) -> int:
        with self._lock:
            return len(self._lru)

    def keys(self):
        with self._lock:
            return list(self._lru)

    def get(self, key: tuple, builder: Optional[Callable] = None):
        with self._lock:
            if key in self._lru:
                self._lru.move_to_end(key)
                obs.inc("serve.plan_cache.hits")
                return self._lru[key]
        obs.inc("serve.plan_cache.misses")
        if builder is None:
            return None
        value = builder()
        self.put(key, value)
        return value

    def put(self, key: tuple, value) -> None:
        with self._lock:
            self._lru[key] = value
            self._lru.move_to_end(key)
            while len(self._lru) > self.capacity:
                old_key, _ = self._lru.popitem(last=False)
                obs.inc("serve.plan_cache.evictions")
                obs.set_gauge("serve.plan_cache.size", len(self._lru))
            obs.set_gauge("serve.plan_cache.size", len(self._lru))
