"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before the
first jax call, and smoke tests must keep seeing the single real device.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 chips per pod ("data", "model"); 2 pods add a leading "pod"."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_rows_mesh(n: int | None = None, axis_name: str = "rows") -> Mesh:
    """1-D mesh for the logdet core (paper's P processors)."""
    n = n or jax.device_count()
    return jax.make_mesh((n,), (axis_name,), axis_types=(AxisType.Auto,))


def make_mesh_like(spec: str) -> Mesh:
    """'16x16' / '2x16x16' / '8' -> mesh (for CLI flags)."""
    dims = tuple(int(x) for x in spec.lower().split("x"))
    if len(dims) == 1:
        return make_rows_mesh(dims[0])
    if len(dims) == 2:
        return jax.make_mesh(dims, ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
    if len(dims) == 3:
        return jax.make_mesh(dims, ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,) * 3)
    raise ValueError(spec)
