"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before the
first jax call, and smoke tests must keep seeing the single real device.
Mesh creation goes through `repro._compat.make_mesh` so the same code runs
on jax versions with and without ``jax.sharding.AxisType``.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro._compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 chips per pod ("data", "model"); 2 pods add a leading "pod"."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_rows_mesh(n: int | None = None, axis_name: str = "rows") -> Mesh:
    """1-D mesh for the logdet core (paper's P processors)."""
    n = n or jax.device_count()
    return make_mesh((n,), (axis_name,))


def make_mesh_like(spec: str) -> Mesh:
    """'16x16' / '2x16x16' / '8' -> mesh (for CLI flags)."""
    dims = tuple(int(x) for x in spec.lower().split("x"))
    if len(dims) == 1:
        return make_rows_mesh(dims[0])
    if len(dims) == 2:
        return make_mesh(dims, ("data", "model"))
    if len(dims) == 3:
        return make_mesh(dims, ("pod", "data", "model"))
    raise ValueError(spec)
