"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

THIS FILE MUST SET XLA_FLAGS BEFORE ANY OTHER IMPORT — jax locks the device
count on first init.  512 placeholder host devices cover both the 16x16
single-pod mesh and the 2x16x16 multi-pod mesh.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402  (the two lines above are load-bearing)
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCHS, batch_specs, get_config, skip_shapes
from repro.configs.shapes import SHAPES
from repro.launch.hlo_analysis import HW, collective_bytes, roofline
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.common import ModelConfig
from repro.optim.optimizers import OptConfig
from repro.sharding import hints
from repro.sharding.rules import (
    batch_spec as batch_pspec, cache_shardings, make_rules, param_shardings,
)
from repro.train.step import TrainConfig, init_train_state, make_train_step


def _tcfg_for(cfg: ModelConfig, *, cost_pass: bool = False) -> TrainConfig:
    # 400B MoE: AdamW's 8 bytes/param of moments cannot fit 256 chips;
    # Adafactor's factored second moment can (DESIGN.md memory budget);
    # 8-way microbatching + bf16 accumulation bound the activation slab.
    import jax.numpy as jnp
    big = cfg.n_experts >= 64
    return TrainConfig(
        opt=OptConfig(name="adafactor" if big else "adamw"),
        microbatches=1 if cost_pass else (16 if big else 1),
        accum_dtype=jnp.bfloat16 if big else jnp.float32,
    )


def _serving_cfg(cfg: ModelConfig) -> ModelConfig:
    # 32k prefill with materialized (T x T) logits would be ~4 TB/device;
    # serving paths always use the chunked (flash-style) attention.
    return cfg.replace(attn_impl="chunked", remat=False)


def lower_cell(arch: str, shape_name: str, mesh, *, smoke: bool = False,
               scan_layers: bool = True):
    """Build + lower one cell; returns (lowered, n_model_params, cfg).

    scan_layers=True  -> deployment form: lax.scan over layers (fast compile,
                         realistic memory_analysis).
    scan_layers=False -> unrolled: cost_analysis counts while bodies ONCE, so
                         the roofline pass lowers unrolled for exact per-step
                         FLOPs / bytes / collective traffic.
    """
    cfg = get_config(arch, smoke=smoke)
    cfg = cfg.replace(scan_layers=scan_layers)
    shape = SHAPES[shape_name]
    specs = batch_specs(cfg, shape.global_batch, shape.seq_len, kind=shape.kind)
    bspecs = batch_pspec(cfg, mesh, kind=shape.kind, batch=shape.global_batch)
    bshard = {k: NamedSharding(mesh, bspecs[k]) for k in specs}

    # long_500k (global_batch < data axes): the KV cache is sequence-sharded
    # and decode must use the masked-write path (see sharding/hints.py)
    data_axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    dsize = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1
    masked = shape.kind == "decode" and shape.global_batch % dsize != 0
    hints.configure(cfg, mesh, kv_masked_write=masked)
    key = jax.random.PRNGKey(0)

    if shape.kind == "train":
        # cost pass (unrolled): microbatches=1 — the accumulation loop is a
        # scan (counted once by cost_analysis) and per-step FLOPs/collective
        # totals are microbatch-invariant; memory truth comes from the scan
        # pass which uses the real microbatched config.
        tcfg = _tcfg_for(cfg, cost_pass=not scan_layers)
        state_shapes = jax.eval_shape(
            lambda k: init_train_state(k, cfg, tcfg), key)
        state_shardings = {
            "params": param_shardings(state_shapes["params"], cfg, mesh),
            "opt": param_shardings(state_shapes["opt"], cfg, mesh),
            "step": NamedSharding(mesh, P()),
        }
        step_fn = make_train_step(cfg, tcfg)
        jitted = jax.jit(step_fn,
                         in_shardings=(state_shardings, bshard),
                         out_shardings=(state_shardings, None),
                         donate_argnums=(0,))
        with mesh:
            lowered = jitted.lower(state_shapes, specs)

    elif shape.kind == "prefill":
        scfg = _serving_cfg(cfg)
        params_shapes = jax.eval_shape(lambda k: M.init_model(k, scfg), key)
        pshard = param_shardings(params_shapes, scfg, mesh)
        cspec = M.cache_specs(scfg, shape.global_batch, shape.seq_len)
        cshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                              cache_shardings(cspec, scfg, mesh),
                              is_leaf=lambda x: isinstance(x, P))

        def prefill_fn(params, batch):
            return M.prefill(params, batch, scfg, shape.seq_len)

        jitted = jax.jit(prefill_fn, in_shardings=(pshard, bshard),
                         out_shardings=(None, cshard))
        with mesh:
            lowered = jitted.lower(params_shapes, specs)

    elif shape.kind == "decode":
        scfg = _serving_cfg(cfg)
        params_shapes = jax.eval_shape(lambda k: M.init_model(k, scfg), key)
        pshard = param_shardings(params_shapes, scfg, mesh)
        cspec = M.cache_specs(scfg, shape.global_batch, shape.seq_len)
        cshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                              cache_shardings(cspec, scfg, mesh),
                              is_leaf=lambda x: isinstance(x, P))
        pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
        tok_spec = specs.pop("tokens")
        extras = specs or None
        eshard = {k: bshard[k] for k in (extras or {})} or None

        def decode_fn(params, tokens, caches, pos, extras_):
            return M.decode_step(params, tokens, caches, pos, scfg,
                                 batch_extras=extras_)

        jitted = jax.jit(
            decode_fn,
            in_shardings=(pshard, bshard["tokens"], cshard,
                          NamedSharding(mesh, P()), eshard),
            out_shardings=(None, cshard),
            donate_argnums=(2,))
        with mesh:
            lowered = jitted.lower(params_shapes, tok_spec, cspec,
                                   pos_spec, extras)
    else:
        raise ValueError(shape.kind)

    n_active = M.count_params(cfg, active_only=True)
    return lowered, n_active, cfg


def analyze_compiled(lowered, compiled, *, chips: int, cfg, shape, n_active):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax 0.4.x: one dict per computation
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    stats = collective_bytes(compiled.as_text())
    n_tok = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    model_fl = 2 * n_active * n_tok * (3 if shape.kind == "train" else 1)
    # SSD/conv inner scans stay rolled even with unrolled layers (8k chunk
    # trips at 500k) -> cost_analysis undercounts those cells; take the max
    # of compiled and analytic FLOPs for the compute term (documented).
    flops_global = max(flops_dev * chips, float(model_fl))
    terms = roofline(flops=flops_global, hbm_bytes=bytes_dev * chips,
                     wire_bytes_per_chip=stats.wire_bytes, chips=chips)
    rec = {
        "arch": cfg.name, "shape": shape.name, "kind": shape.kind,
        "chips": chips,
        "hlo_flops_global": flops_dev * chips,
        "hlo_bytes_global": bytes_dev * chips,
        "wire_bytes_per_chip": stats.wire_bytes,
        "collective_counts": stats.counts,
        "collective_bytes_by_op": stats.by_op,
        "model_flops": model_fl,
        "useful_flops_frac": model_fl / max(flops_dev * chips, 1.0),
        **{k: terms[k] for k in
           ("compute_s", "memory_s", "collective_s", "bottleneck",
            "step_s_lower_bound")},
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes),
        },
    }
    return rec


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, smoke: bool = False,
             verbose: bool = True, fast: bool = False):
    """Two lowerings per cell: scan (memory truth) + unrolled (cost truth)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))

    # pass 1: deployment form — the compile that must succeed + memory proof
    t0 = time.time()
    lowered, n_active, cfg = lower_cell(arch, shape_name, mesh, smoke=smoke,
                                        scan_layers=True)
    compiled = lowered.compile()
    t1 = time.time()
    rec = analyze_compiled(lowered, compiled, chips=chips, cfg=cfg,
                           shape=SHAPES[shape_name], n_active=n_active)
    rec["scan_compile_s"] = round(t1 - t0, 1)

    # pass 2: unrolled — exact per-step FLOPs / bytes / collectives
    if not fast:
        t2 = time.time()
        lowered_u, _, _ = lower_cell(arch, shape_name, mesh, smoke=smoke,
                                     scan_layers=False)
        compiled_u = lowered_u.compile()
        t3 = time.time()
        rec_u = analyze_compiled(lowered_u, compiled_u, chips=chips, cfg=cfg,
                                 shape=SHAPES[shape_name], n_active=n_active)
        rec_u["memory_unrolled_temp_bytes"] = \
            rec_u["memory"]["temp_bytes_per_device"]
        rec_u["memory"] = rec["memory"]   # memory truth: deployment form
        rec_u["scan_compile_s"] = round(t1 - t0, 1)
        rec_u["unrolled_compile_s"] = round(t3 - t2, 1)
        rec = rec_u

    rec["mesh"] = "2x16x16" if multi_pod else "16x16"
    if verbose:
        m = rec["memory"]
        print(f"[{rec['mesh']}] {arch} x {shape_name}: "
              f"args={m['argument_bytes_per_device']/2**30:.2f}GiB "
              f"temp={m['temp_bytes_per_device']/2**30:.2f}GiB "
              f"flops/dev={rec['hlo_flops_global']/chips:.3e} "
              f"wire/dev={rec['wire_bytes_per_chip']:.3e}B "
              f"bottleneck={rec['bottleneck']} "
              f"(compiles {rec.get('scan_compile_s')}s scan"
              + (f", {rec.get('unrolled_compile_s')}s unrolled)" if not fast
                 else ")"))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--smoke", action="store_true",
                    help="use reduced configs (CI sanity)")
    ap.add_argument("--fast", action="store_true",
                    help="skip the unrolled cost pass (scan costs only)")
    ap.add_argument("--out", default="dryrun_results.jsonl")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    archs = ARCHS if args.arch == "all" else [args.arch]
    shape_names = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    out_path = Path(args.out)
    done = set()
    if args.skip_existing and out_path.exists():
        for line in out_path.read_text().splitlines():
            try:
                r = json.loads(line)
                done.add((r["arch"], r["shape"], r["mesh"]))
            except Exception:
                pass

    failures = []
    with out_path.open("a") as f:
        for arch in archs:
            skips = skip_shapes(arch)
            for shape_name in shape_names:
                for multi_pod in meshes:
                    mesh_name = "2x16x16" if multi_pod else "16x16"
                    cfg_name = get_config(arch).name
                    if (cfg_name, shape_name, mesh_name) in done:
                        continue
                    if shape_name in skips:
                        rec = {"arch": cfg_name, "shape": shape_name,
                               "mesh": mesh_name, "skipped": True,
                               "reason": "full-attention arch: long_500k "
                                         "needs sub-quadratic attention"}
                        print(f"[{mesh_name}] {arch} x {shape_name}: SKIP")
                        f.write(json.dumps(rec) + "\n")
                        f.flush()
                        continue
                    try:
                        # unrolled cost pass: single-pod only (the roofline
                        # table is single-pod; multi-pod proves sharding).
                        rec = run_cell(arch, shape_name, multi_pod=multi_pod,
                                       smoke=args.smoke,
                                       fast=(multi_pod or args.fast))
                        f.write(json.dumps(rec) + "\n")
                        f.flush()
                    except Exception as e:  # noqa: BLE001 — report & continue
                        failures.append((arch, shape_name, mesh_name, repr(e)))
                        traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for fll in failures:
            print("  ", *fll[:3], fll[3][:200])
        sys.exit(1)
    print("\nALL CELLS PASSED")


if __name__ == "__main__":
    main()
