"""Serving launcher: batched prefill + decode loop.

``python -m repro.launch.serve --arch <id> --batch 4 --prompt-len 32 --gen 16``

Runs a real token-generation loop on the smoke configs (greedy or top-k
sampling), with the same prefill/decode step functions the dry-run lowers at
production shapes.

``--metrics-port N`` exposes the `repro.obs` metrics registry over HTTP
(``GET /metrics``, Prometheus text format) for the duration of the run —
the first concrete piece of the ROADMAP serving direction.  Request and
token counters are recorded regardless of ``REPRO_OBS`` *mode* only when
metrics are enabled; run with ``REPRO_OBS=metrics`` (or ``trace``) to see
non-empty output.  ``--metrics-hold S`` keeps the process (and endpoint)
alive S seconds after generation so a scraper can collect.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs.registry import ARCHS, get_config
from repro.models import model as M


def generate(params, cfg, prompt, *, max_len: int, gen: int, temperature=0.0,
             extras=None, key=None):
    """prompt (B, T0) -> tokens (B, T0+gen); greedy if temperature == 0."""
    b, t0 = prompt.shape
    batch = dict(extras or {})
    batch["tokens"] = prompt
    logits, caches = M.prefill(params, batch, cfg, max_len)

    @jax.jit
    def step(tok, caches, pos, key):
        lg, caches = M.decode_step(params, tok, caches, pos, cfg,
                                   batch_extras=extras)
        lg = lg[:, -1]
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, lg / temperature, axis=-1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        return nxt[:, None].astype(jnp.int32), caches, key

    key = key if key is not None else jax.random.PRNGKey(0)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [prompt, tok]
    for pos in range(t0, t0 + gen - 1):
        tok, caches, key = step(tok, caches, jnp.asarray(pos, jnp.int32), key)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=list(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    obs.add_metrics_cli(ap)
    args = ap.parse_args(argv)

    server = obs.start_metrics_from_args(args)

    cfg = get_config(args.arch, smoke=True).replace(remat=False)
    rng = np.random.default_rng(0)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    extras = None
    if cfg.family == "encdec":
        frames = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.enc_seq, cfg.d_model)), cfg.dtype)
        extras = {"memory": M._encode(params, {"frames": frames}, cfg)}
    elif cfg.family == "vlm":
        extras = {"img_embeds": jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_img_tokens, cfg.d_model)), cfg.dtype)}

    max_len = args.prompt_len + args.gen
    t0 = time.time()
    with obs.span("serve.generate", arch=args.arch, batch=args.batch,
                  gen=args.gen):
        toks = generate(params, cfg, prompt, max_len=max_len, gen=args.gen,
                        temperature=args.temperature, extras=extras)
        toks.block_until_ready()
    dt = time.time() - t0
    obs.inc("serve.requests", arch=args.arch)
    obs.inc("serve.tokens", args.batch * args.gen, arch=args.arch)
    obs.set_gauge("serve.tok_per_s", args.batch * args.gen / dt,
                  arch=args.arch)
    print(f"{args.arch}: generated {args.batch}x{args.gen} tokens "
          f"in {dt:.2f}s ({args.batch * args.gen / dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(toks[0, args.prompt_len:]))
    if server is not None and args.metrics_hold > 0:
        time.sleep(args.metrics_hold)
    return toks


if __name__ == "__main__":
    main()
