"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

End-to-end: config -> mesh -> sharded init -> fault-tolerant train loop
(checkpoint/restart, straggler monitor) -> metrics log.  On this container it
runs smoke-size configs on 1..8 fake devices; the same entry point scales to
the production mesh (the step function is mesh-agnostic).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCHS, get_config
from repro.data.synthetic import DataConfig, synth_batch
from repro.ft.driver import FTConfig, run_training
from repro.launch.mesh import make_mesh_like, make_rows_mesh
from repro.optim.optimizers import OptConfig
from repro.sharding import hints
from repro.sharding.rules import batch_spec, param_shardings
from repro.train.step import TrainConfig, init_train_state, make_train_step


def build(arch: str, *, smoke: bool, mesh, tcfg: TrainConfig, seed: int = 0,
          batch: int = 8, seq: int = 128):
    cfg = get_config(arch, smoke=smoke)
    hints.configure(cfg, mesh)
    data = DataConfig(seed=seed, batch=batch, seq=seq, kind="markov")

    state_shapes = jax.eval_shape(
        lambda k: init_train_state(k, cfg, tcfg), jax.random.PRNGKey(seed))
    state_shardings = {
        "params": param_shardings(state_shapes["params"], cfg, mesh),
        "opt": param_shardings(state_shapes["opt"], cfg, mesh),
        "step": NamedSharding(mesh, P()),
    }
    bspecs = batch_spec(cfg, mesh, kind="train", batch=batch)

    with mesh:
        state = jax.jit(
            lambda k: init_train_state(k, cfg, tcfg),
            out_shardings=state_shardings)(jax.random.PRNGKey(seed))

    step_fn = jax.jit(make_train_step(cfg, tcfg),
                      in_shardings=(state_shardings, None),
                      out_shardings=(state_shardings, None),
                      donate_argnums=(0,))

    def batch_fn(step: int):
        b = synth_batch(cfg, data, step)
        return jax.device_put(
            b, {k: NamedSharding(mesh, bspecs[k]) for k in b})

    return cfg, state, step_fn, batch_fn, state_shardings


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")  # validated by registry
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--mesh", default="", help="e.g. 2x4 / 16x16; default 1-dev")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--logdet-reg", type=float, default=0.0)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    if args.mesh:
        mesh = make_mesh_like(args.mesh)
    else:
        n = jax.device_count()
        mesh = make_mesh_like(f"{n}x1" if n > 1 else "1x1")

    tcfg = TrainConfig(
        opt=OptConfig(name=args.optimizer, lr=args.lr,
                      decay_steps=max(args.steps, 2)),
        microbatches=args.microbatches,
        logdet_reg=args.logdet_reg,
        grad_compression=args.grad_compression,
    )
    cfg, state, step_fn, batch_fn, shardings = build(
        args.arch, smoke=args.smoke, mesh=mesh, tcfg=tcfg,
        batch=args.batch, seq=args.seq)

    losses = []

    def on_metrics(step, m):
        losses.append(float(m["loss"]))
        if step % args.log_every == 0:
            print(f"step {step:5d}  loss {m['loss']:.4f}  "
                  f"nll {m['nll']:.4f}  gnorm {m['grad_norm']:.3f}")

    ft = FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    t0 = time.time()
    with mesh:
        state, stats = run_training(
            state=state, train_step=step_fn, batch_fn=batch_fn,
            n_steps=args.steps, ft=ft, shardings=shardings,
            on_metrics=on_metrics)
    dt = time.time() - t0
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({1000 * dt / max(len(stats.times), 1):.0f} ms/step median-ish); "
          f"restarts={stats.restarts} stragglers={stats.stragglers[:5]}")
    print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f}")
    return state


if __name__ == "__main__":
    main()
