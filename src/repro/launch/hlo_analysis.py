"""HLO-level analysis: collective-bytes extraction + roofline terms.

``cost_analysis()`` gives FLOPs and HBM bytes but NOT collective traffic;
we parse the post-SPMD (per-device) HLO text and sum the payloads of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Wire-byte conventions (ring algorithms, per device):
  all-reduce         2 x operand bytes   (reduce-scatter + all-gather phases)
  all-gather         output bytes - operand bytes (received shards)
  reduce-scatter     operand bytes - output bytes
  all-to-all         operand bytes       (each device re-sends its shard)
  collective-permute operand bytes

Roofline terms (TPU v5e defaults):
  compute    = HLO_FLOPs            / (chips * 197e12 FLOP/s)
  memory     = HLO_bytes            / (chips * 819e9  B/s)
  collective = wire_bytes_per_chip  /          49.5e9 B/s  (per ICI link)
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List

__all__ = ["collective_bytes", "roofline", "HW", "CollectiveStats"]

# TPU v5e hardware constants (per chip)
HW = {
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # B/s
    "ici_bw": 49.5e9,              # B/s per link direction (~50 GB/s)
}

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# instruction definition:  [%]name = <shape or (tuple)> opcode(...operands)
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[\d,]*\]\S*))\s+"
    r"([\w\-]+)")
_OPERAND_RE = re.compile(r"%?([\w.\-]+)")
_COLLECTIVE_BASE = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    wire_bytes: float = 0.0          # per device
    by_op: Dict[str, float] = field(default_factory=dict)


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Parse per-device wire bytes from (post-SPMD) HLO text.

    Two passes: (1) symbol table name -> result bytes (operands are printed
    by NAME in optimized HLO dumps), (2) per collective instruction, resolve
    operand bytes through the table.

    NOTE on while loops: collectives inside a while body are counted once
    (same undercount as cost_analysis); the dry-run lowers with unrolled
    layer stacks so per-step traffic is exact for the roofline table.
    """
    sizes: Dict[str, int] = {}
    instrs = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, out_txt, op = m.group(1), m.group(2), m.group(3).lower()
        sizes[name] = _shape_bytes(out_txt)
        base = op
        for suffix in ("-start", "-done"):
            if base.endswith(suffix):
                base = base[: -len(suffix)]
        if base in _COLLECTIVE_BASE and not op.endswith("-done"):
            paren = line.find("(", m.end())
            operand_txt = line[paren + 1:line.find(")", paren)] if paren >= 0 else ""
            instrs.append((base, name, out_txt, operand_txt))

    stats = CollectiveStats()
    for base, name, out_txt, operand_txt in instrs:
        out_bytes = _shape_bytes(out_txt)
        in_bytes = _shape_bytes(operand_txt)
        if in_bytes == 0:              # operands printed by name: look up
            in_bytes = sum(sizes.get(o, 0)
                           for o in _OPERAND_RE.findall(operand_txt))
        if base == "all-reduce":
            wire = 2 * in_bytes
        elif base == "all-gather":
            wire = max(out_bytes - in_bytes, out_bytes // 2)
        elif base == "reduce-scatter":
            wire = max(in_bytes - out_bytes, in_bytes // 2)
        else:                          # all-to-all, collective-permute
            wire = max(in_bytes, out_bytes)
        stats.counts[base] = stats.counts.get(base, 0) + 1
        stats.by_op[base] = stats.by_op.get(base, 0.0) + wire
        stats.wire_bytes += wire
    return stats


def roofline(*, flops: float, hbm_bytes: float, wire_bytes_per_chip: float,
             chips: int, hw: Dict[str, float] = HW) -> Dict[str, float]:
    """Three-term roofline (seconds) + bottleneck."""
    terms = {
        "compute_s": flops / (chips * hw["peak_flops_bf16"]),
        "memory_s": hbm_bytes / (chips * hw["hbm_bw"]),
        "collective_s": wire_bytes_per_chip / hw["ici_bw"],
    }
    terms["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["step_s_lower_bound"] = max(
        terms["compute_s"], terms["memory_s"], terms["collective_s"])
    return terms
