"""Compatibility shim — the HLO parser moved to `repro.analysis.ir`.

This module used to hold the post-SPMD HLO text parser (collective wire
bytes + roofline terms).  That parser was promoted into the
`repro.analysis` subsystem, normalized into a full instruction table
(opcode, shapes, dtypes, named-scope ancestry), and grew the checker
passes described in docs/analysis.md.  The public surface re-exported
here is unchanged; new code should import from `repro.analysis` (or
`repro.analysis.ir`) directly.
"""
from __future__ import annotations

from repro.analysis.ir import (  # noqa: F401
    HW, CollectiveStats, collective_bytes, roofline,
)

__all__ = ["collective_bytes", "roofline", "HW", "CollectiveStats"]
