"""Pure-jnp oracles for every Pallas kernel (numerical ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rank1_update_ref", "panel_update_ref", "matvec_ref",
           "stencil_mv_ref"]


def rank1_update_ref(a: jax.Array, pc: jax.Array, pr: jax.Array) -> jax.Array:
    """a (M, N) - outer(pc, pr)."""
    return a - jnp.outer(pc, pr)


def panel_update_ref(a: jax.Array, c: jax.Array, r: jax.Array) -> jax.Array:
    """a (M, N) - c (M, K) @ r (K, N)."""
    return a - c @ r


def matvec_ref(a: jax.Array, x: jax.Array) -> jax.Array:
    """a (M, N) @ x (N,) or (N, K)."""
    return a @ x.astype(a.dtype)


def stencil_mv_ref(bands: jax.Array, x: jax.Array, *,
                   offsets: tuple) -> jax.Array:
    """y[i] = sum_d bands[d, i] * x[i + offsets[d]], zero outside [0, n)."""
    vec = x.ndim == 1
    x2 = (x[:, None] if vec else x).astype(bands.dtype)
    n = x2.shape[0]
    lo = min(min(offsets), 0)
    hi = max(max(offsets), 0)
    xp = jnp.pad(x2, ((-lo, hi), (0, 0)))
    y = jnp.zeros_like(x2)
    for d, off in enumerate(offsets):
        start = off - lo
        y = y + bands[d][:, None] * xp[start:start + n]
    return y[:, 0] if vec else y
