"""Pure-jnp oracles for every Pallas kernel (numerical ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rank1_update_ref", "panel_update_ref", "matvec_ref",
           "stencil_mv_ref", "fused_step_ref", "cheb_step_ref",
           "cg_step_ref"]


def rank1_update_ref(a: jax.Array, pc: jax.Array, pr: jax.Array) -> jax.Array:
    """a (M, N) - outer(pc, pr)."""
    return a - jnp.outer(pc, pr)


def panel_update_ref(a: jax.Array, c: jax.Array, r: jax.Array) -> jax.Array:
    """a (M, N) - c (M, K) @ r (K, N)."""
    return a - c @ r


def fused_step_ref(a: jax.Array, l, last, pc: jax.Array, pr: jax.Array,
                   col_l: jax.Array, col_last: jax.Array) -> jax.Array:
    """Fused column swap (l <-> last) + rank-1 update, one select pass.

    Expresses the engine's scatter-swap + outer-subtract sequence as a
    single elementwise pass: bit-identical (the swap is pure data
    movement; the multiply-subtract is the same arithmetic).  ``pc`` /
    ``pr`` may be lower precision (bf16 operands); the product is
    accumulated back into the buffer dtype.
    """
    cols = jnp.arange(a.shape[1])
    sw = jnp.where(cols[None, :] == l, col_last[:, None],
                   jnp.where(cols[None, :] == last, col_l[:, None], a))
    return sw - (pc[:, None] * pr[None, :]).astype(a.dtype)


def cheb_step_ref(a: jax.Array, w: jax.Array, w_prev: jax.Array,
                  v: jax.Array, center, width):
    """One Chebyshev three-term step; returns (w_next, probe dots).

    Op-for-op the loop body of `estimators.chebyshev.logdet_chebyshev`
    (shifted matvec, axpy, probe dot) so f32 results are bit-identical.
    """
    mv = (2.0 * (a @ w) - center * w) / width
    w_next = 2.0 * mv - w_prev
    return w_next, (v * w_next).sum(-2)


def cg_step_ref(a: jax.Array, p: jax.Array, x: jax.Array, r: jax.Array,
                rz: jax.Array):
    """One CG matvec+axpy chain; returns (x_new, r_new).

    Op-for-op the hot half of `operators.solve.cg_solve`'s loop body,
    including the guarded 0/0 -> 0 alpha of converged columns.
    """
    ap = a @ p
    den = (p * ap).sum(-2)
    tiny = jnp.finfo(den.dtype).tiny
    safe = jnp.where(jnp.abs(den) > tiny, den, 1.0)
    alpha = jnp.where(jnp.abs(den) > tiny, rz / safe,
                      jnp.zeros_like(rz))[..., None, :]
    return x + alpha * p, r - alpha * ap


def matvec_ref(a: jax.Array, x: jax.Array) -> jax.Array:
    """a (M, N) @ x (N,) or (N, K)."""
    return a @ x.astype(a.dtype)


def stencil_mv_ref(bands: jax.Array, x: jax.Array, *,
                   offsets: tuple) -> jax.Array:
    """y[i] = sum_d bands[d, i] * x[i + offsets[d]], zero outside [0, n)."""
    vec = x.ndim == 1
    x2 = (x[:, None] if vec else x).astype(bands.dtype)
    n = x2.shape[0]
    lo = min(min(offsets), 0)
    hi = max(max(offsets), 0)
    xp = jnp.pad(x2, ((-lo, hi), (0, 0)))
    y = jnp.zeros_like(x2)
    for d, off in enumerate(offsets):
        start = off - lo
        y = y + bands[d][:, None] * xp[start:start + n]
    return y[:, 0] if vec else y
