"""Pure-jnp oracles for every Pallas kernel (numerical ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rank1_update_ref", "panel_update_ref", "matvec_ref"]


def rank1_update_ref(a: jax.Array, pc: jax.Array, pr: jax.Array) -> jax.Array:
    """a (M, N) - outer(pc, pr)."""
    return a - jnp.outer(pc, pr)


def panel_update_ref(a: jax.Array, c: jax.Array, r: jax.Array) -> jax.Array:
    """a (M, N) - c (M, K) @ r (K, N)."""
    return a - c @ r


def matvec_ref(a: jax.Array, x: jax.Array) -> jax.Array:
    """a (M, N) @ x (N,) or (N, K)."""
    return a @ x.astype(a.dtype)
