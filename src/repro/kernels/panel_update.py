"""Pallas TPU kernel: rank-K panel (trailing) update — the MXU path.

The beyond-paper blocked condensation (core/blocked.py) turns K rank-1
condensation steps into one trailing update

    A -= C @ R        C: (M, K) coefficients, R: (K, N) pivot panel

with arithmetic intensity ~K/2 FLOP/byte — a real matmul that belongs on
the MXU.  The kernel fuses the GEMM with the subtraction so the trailing
matrix is read and written exactly once (no A' = C@R temporary in HBM).

Tiling: grid (M/bm, N/bn); each program reads
  a tile (bm, bn), c slab (bm, K), r slab (K, bn)
and issues a single (bm x K) @ (K x bn) MXU contraction with f32
accumulation.  bm = bn = 256 and K <= 256 keeps the footprint
(256*256 + 2*256*K) * 4B < 1.3 MiB — far under VMEM; K and the block
dims should be multiples of 128 for full MXU occupancy (the blocked
algorithm's panel width IS this K, so the config plumbs straight into
BlockSpec).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["panel_update_kernel", "panel_update_pallas"]

DEFAULT_BM = 256
DEFAULT_BN = 256


def panel_update_kernel(a_ref, c_ref, r_ref, o_ref):
    """o = a - c @ r with f32 MXU accumulation."""
    a = a_ref[...]
    c = c_ref[...]              # (bm, K)
    r = r_ref[...]              # (K, bn)
    acc = jax.lax.dot_general(
        c, r, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32 if a.dtype != jnp.float64 else jnp.float64,
    )
    o_ref[...] = a - acc.astype(a.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def panel_update_pallas(a: jax.Array, c: jax.Array, r: jax.Array, *,
                        bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                        interpret: bool = False) -> jax.Array:
    """a (M, N) - c (M, K) @ r (K, N) via a tiled Pallas kernel."""
    m, n = a.shape
    k = c.shape[1]
    if r.shape != (k, n) or c.shape != (m, k):
        raise ValueError(f"shape mismatch: a={a.shape} c={c.shape} r={r.shape}")
    bm = min(bm, m)
    bn = min(bn, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        panel_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, c, r)
