"""Pallas TPU kernel: fused rank-1 condensation update.

The hot loop of the faithful algorithm (paper pseudocode step 4.11):

    local_A[row, col] -= pivot_column[row] * pivot_row[col]

Arithmetic intensity is ~0.25 FLOP/byte (2 FLOPs per 8-byte f32
read+write pair), so the kernel is HBM-bandwidth-bound and runs on the
VPU.  The kernel's job is to guarantee exactly ONE pass over the buffer
per step: read the (bm, bn) tile, fuse multiply-subtract, write back —
no separate outer-product materialization (which a naive
``a - jnp.outer(pc, pr)`` could do under a fusion-hostile scheduler).

Tiling: grid (M/bm, N/bn); each program reads
  a  tile (bm, bn)   from VMEM
  pc slab (bm, 1)
  pr slab (1, bn)
VMEM footprint per program: bm*bn + bm + bn floats.  Default 256x512 f32
= 512 KiB + eps, well under the ~16 MiB v5e VMEM budget, and both dims
are multiples of the (8, 128) f32 VREG tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rank1_update_kernel", "rank1_update_pallas"]

DEFAULT_BM = 256
DEFAULT_BN = 512


def rank1_update_kernel(a_ref, pc_ref, pr_ref, o_ref):
    """o = a - pc * pr  (pc broadcast over cols, pr over rows)."""
    a = a_ref[...]
    pc = pc_ref[...]            # (bm, 1)
    pr = pr_ref[...]            # (1, bn)
    o_ref[...] = a - pc * pr


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def rank1_update_pallas(a: jax.Array, pc: jax.Array, pr: jax.Array, *,
                        bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                        interpret: bool = False) -> jax.Array:
    """a (M, N) - outer(pc (M,), pr (N,)) via a tiled Pallas kernel."""
    m, n = a.shape
    bm = min(bm, m)
    bn = min(bn, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        rank1_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, pc[:, None], pr[None, :])
