"""Pallas TPU kernel: tiled dense matvec / multi-vector matvec.

The stochastic estimators (repro/estimators) reduce every log-determinant to
a stream of products ``A @ V`` where ``V`` stacks a handful of probe vectors
(K ~ 8..64 columns).  With K << 128 the MXU runs far from peak, so — like the
rank-1 condensation update — the product is HBM-bandwidth-bound: every f32
element of ``A`` is read exactly once for ~2K FLOPs.  The kernel's job is to
guarantee that single pass: each (bm, bn) tile of ``A`` is loaded into VMEM
once, multiplied against the resident (bn, K) slab of ``V``, and accumulated
into the (bm, K) output tile across the reduction grid axis.

Grid: ``(M/bm, N/bn)`` with the reduction axis ``j`` innermost, so the output
tile for row-block ``i`` stays resident in VMEM while ``j`` sweeps — the
standard Pallas accumulate-in-place pattern (init at j==0, += after).

VMEM per program: ``bm*bn + bn*K + bm*K`` floats; the default 256x512 f32
tile with K=64 is ~0.7 MiB, well under the ~16 MiB budget, and (bm, bn) are
multiples of the (8, 128) f32 VREG tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["matvec_kernel", "matvec_pallas"]

DEFAULT_BM = 256
DEFAULT_BN = 512


def matvec_kernel(a_ref, x_ref, o_ref):
    """o[i] += a[i, j] @ x[j]; o initialized on the first reduction step."""
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], x_ref[...],
                          preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def matvec_pallas(a: jax.Array, x: jax.Array, *,
                  bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                  interpret: bool = False) -> jax.Array:
    """``a (M, N) @ x (N,) or (N, K)`` via a tiled Pallas kernel."""
    vec = x.ndim == 1
    x2 = x[:, None] if vec else x
    m, n = a.shape
    k = x2.shape[1]
    bm = min(bm, m)
    bn = min(bn, n)
    # Partial tiles along the reduction axis would fold padding garbage into
    # the accumulator (unlike the output axes, where it is just discarded) —
    # zero-pad N up front so every j-tile is full.
    n_pad = (-n) % bn
    if n_pad:
        a = jnp.pad(a, ((0, 0), (0, n_pad)))
        x2 = jnp.pad(x2, ((0, n_pad), (0, 0)))
        n += n_pad
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    out = pl.pallas_call(
        matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), a.dtype),
        interpret=interpret,
    )(a, x2.astype(a.dtype))
    return out[:, 0] if vec else out
