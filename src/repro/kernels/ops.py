"""Jitted kernel entry points with explicit backend dispatch.

Three backends:

  ``pallas``     the Pallas kernels compiled natively (TPU).
  ``interpret``  the same Pallas kernel bodies run in ``interpret=True``
                 mode — Python-slow, but byte-for-byte the kernel logic,
                 which is what CPU CI wants for deterministic coverage.
  ``xla``        the pure-jnp references (kernels/ref.py) or, for the
                 panel factorization, the engine's jnp implementation —
                 the fast fallback on non-TPU backends.

Resolution order (most specific wins): an explicit ``backend=`` request
from the caller (the engine passes its resolved backend; ``"pallas"``
off-TPU degrades to ``"interpret"`` — the kernel body still runs, never
a silent fall-through to the reference), else the
``REPRO_KERNEL_BACKEND`` environment variable (re-read at every trace,
so a test/CI job can force any backend deterministically — the old
``lru_cache``d TPU probe pinned the choice for the whole process), else
``pallas`` on TPU and ``xla`` elsewhere.

The algorithm code (core/engine.py) calls these via its backend hooks.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels import autotune as _autotune
from repro.kernels import ref as _ref
from repro.kernels.condense_step import rank1_update_pallas
from repro.kernels.fused_est import (VMEM_BUDGET as _EST_VMEM_BUDGET,
                                     cg_step_pallas, cheb_step_pallas)
from repro.kernels.fused_step import fused_step_pallas
from repro.kernels.matvec import matvec_pallas
from repro.kernels.panel_factor import panel_factor_pallas
from repro.kernels.panel_update import panel_update_pallas
from repro.kernels.stencil_mv import stencil_mv_pallas

__all__ = ["rank1_update", "panel_update", "panel_factor_vmem", "matvec",
           "stencil_mv", "fused_condense_step", "fused_cheb_step",
           "fused_cg_step", "kernel_backend", "on_tpu", "KERNEL_BACKENDS"]

KERNEL_BACKENDS = ("xla", "pallas", "interpret")
_ENV_VAR = "REPRO_KERNEL_BACKEND"


@functools.lru_cache(maxsize=1)
def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _dispatch(requested: Optional[str]) -> str:
    """Resolve a backend: explicit request > env override > platform."""
    src = "backend argument"
    if requested is None:
        requested = os.environ.get(_ENV_VAR, "").strip().lower() or None
        src = _ENV_VAR
    if requested is not None and requested not in KERNEL_BACKENDS:
        raise ValueError(
            f"{src}={requested!r}: choose one of {KERNEL_BACKENDS}")
    if requested == "pallas" and not on_tpu():
        return "interpret"          # run the kernel BODY, not the reference
    if requested is not None:
        return requested
    return "pallas" if on_tpu() else "xla"


def kernel_backend() -> str:
    """The process-default kernel backend: env override, else platform."""
    env = os.environ.get(_ENV_VAR, "").strip().lower()
    if env:
        if env not in KERNEL_BACKENDS:
            raise ValueError(
                f"{_ENV_VAR}={env!r}: choose one of {KERNEL_BACKENDS}")
        return env
    return "pallas" if on_tpu() else "xla"


def _quantize(precision: Optional[str], *operands):
    """Cast GEMM/outer-product operands for a mixed-precision route.

    ``precision="bf16"`` quantizes the multiply operands to bfloat16;
    products accumulate back into the buffer dtype downstream (the
    kernels and references all ``astype`` the contraction result), so
    sign / parity / log accumulators never leave full precision.
    """
    if precision is None:
        return operands
    if precision != "bf16":
        raise ValueError(f"unknown precision {precision!r}; "
                         "one of (None, 'bf16')")
    return tuple(o.astype(jnp.bfloat16) for o in operands)


def rank1_update(a: jax.Array, pc: jax.Array, pr: jax.Array, *,
                 backend: Optional[str] = None,
                 precision: Optional[str] = None, **kw) -> jax.Array:
    """Fused a -= outer(pc, pr); backend per `_dispatch`."""
    b = _dispatch(backend)
    obs.inc("kernel.dispatch", op="rank1_update", backend=b)
    pc, pr = _quantize(precision, pc, pr)
    with obs.stage("kernel.rank1_update", backend=b):
        if b == "xla":
            return _ref.rank1_update_ref(a, pc, pr).astype(a.dtype)
        return rank1_update_pallas(a, pc, pr, interpret=b == "interpret",
                                   **kw)


def panel_update(a: jax.Array, c: jax.Array, r: jax.Array, *,
                 backend: Optional[str] = None,
                 precision: Optional[str] = None, **kw) -> jax.Array:
    """Fused a -= c @ r; backend per `_dispatch`."""
    b = _dispatch(backend)
    obs.inc("kernel.dispatch", op="panel_update", backend=b)
    c, r = _quantize(precision, c, r)
    with obs.stage("kernel.panel_update", backend=b):
        if b == "xla":
            return _ref.panel_update_ref(a, c, r).astype(a.dtype)
        return panel_update_pallas(a, c, r, interpret=b == "interpret", **kw)


def fused_condense_step(buf: jax.Array, t, *,
                        backend: Optional[str] = None,
                        precision: Optional[str] = None):
    """One-pass condensation step at pivot row ``t``.

    Fuses pivot argmax (§2.2), the §2.4 column-swap bookkeeping, and the
    rank-1 update into a single pass over the buffer, replacing the
    engine's three-pass scatter-swap + outer-subtract sequence.  Returns
    ``(buf', l, p)`` — the updated buffer plus the chosen pivot column
    and pivot value for the caller's sign/parity/log bookkeeping (which
    stays in the buffer dtype; ``precision="bf16"`` quantizes only the
    rank-1 operands).

    The O(n) pivot-row bookkeeping (argmax, normalization) runs inline —
    it touches one row; the O(n^2) swap+update is the fused pass
    (`kernels.fused_step` or the bit-identical jnp select reference).
    """
    b = _dispatch(backend)
    obs.inc("kernel.dispatch", op="fused_condense_step", backend=b)
    n = buf.shape[0]
    cols = jnp.arange(n)
    m = n - t                       # live size (t may be traced)
    last = m - 1
    row = buf[t]
    absrow = jnp.where(cols < m, jnp.abs(row), -jnp.inf)
    l = jnp.argmax(absrow)
    p = row[l]
    col_l = buf[:, l]
    col_last = buf[:, last]
    # pivot row in swapped coordinates, normalized so pr[last] == 1
    row = row.at[l].set(row[last])
    row = row.at[last].set(p)
    safe_p = jnp.where(p == 0, jnp.ones((), buf.dtype), p)
    pr = jnp.where(p == 0, jnp.zeros_like(row), row / safe_p)
    # pivot column, zeroed at the pivot row and the dead rows above it
    pc = col_l.at[t].set(0.0)
    pc = jnp.where(cols < t, 0.0, pc)
    pc, pr = _quantize(precision, pc, pr)
    with obs.stage("kernel.fused_step", backend=b):
        if b == "xla":
            out = _ref.fused_step_ref(buf, l, last, pc, pr, col_l, col_last)
        else:
            tiles = _autotune.tile_config(
                n, itemsize=buf.dtype.itemsize, precision=precision)
            out = fused_step_pallas(buf, l, last, pc, pr, col_l, col_last,
                                    bm=tiles.block_m, bn=tiles.block_n,
                                    interpret=b == "interpret")
    return out, l, p


def _est_fits_vmem(a: jax.Array, k: int) -> bool:
    n = a.shape[-1]
    return (n * n + 4 * n * k) * a.dtype.itemsize <= _EST_VMEM_BUDGET


def fused_cheb_step(a: jax.Array, w: jax.Array, w_prev: jax.Array,
                    v: jax.Array, center, width, *,
                    backend: Optional[str] = None):
    """Fused Chebyshev three-term step: one pass over ``a`` per degree.

    Returns ``(w_next, dots)`` with ``w_next = 2 * (2 a w - c w)/width -
    w_prev`` and ``dots = (v * w_next).sum(-2)`` — op-for-op the unfused
    loop body, so f32 results are bit-identical.  Oversized operands
    (A + slabs beyond the VMEM budget) fall back to the identical jnp
    reference rather than a partial kernel.
    """
    b = _dispatch(backend)
    if b != "xla" and (a.ndim != 2 or not _est_fits_vmem(a, w.shape[-1])):
        b = "xla"
    obs.inc("kernel.dispatch", op="fused_cheb_step", backend=b)
    with obs.stage("kernel.fused_cheb_step", backend=b):
        if b == "xla":
            return _ref.cheb_step_ref(a, w, w_prev, v, center, width)
        return cheb_step_pallas(a, w, w_prev, v, center, width,
                                interpret=b == "interpret")


def fused_cg_step(a: jax.Array, p: jax.Array, x: jax.Array, r: jax.Array,
                  rz: jax.Array, *, backend: Optional[str] = None):
    """Fused CG matvec+axpy+dot chain: one pass over ``a`` per iteration.

    Returns ``(x_new, r_new)`` for ``ap = a p; alpha = rz / (p . ap)``
    (guarded 0/0 -> 0), ``x += alpha p; r -= alpha ap`` — op-for-op the
    unfused `operators.solve` loop body.  Oversized operands fall back
    to the identical jnp reference.
    """
    b = _dispatch(backend)
    if b != "xla" and (a.ndim != 2 or not _est_fits_vmem(a, p.shape[-1])):
        b = "xla"
    obs.inc("kernel.dispatch", op="fused_cg_step", backend=b)
    with obs.stage("kernel.fused_cg_step", backend=b):
        if b == "xla":
            return _ref.cg_step_ref(a, p, x, r, rz)
        return cg_step_pallas(a, p, x, r, rz, interpret=b == "interpret")


def matvec(a: jax.Array, x: jax.Array, *, backend: Optional[str] = None,
           **kw) -> jax.Array:
    """Tiled a @ x (vector or multi-vector).

    Unlike the update kernels (whose interpret mode is fast enough for
    validation-sized inputs), the estimators issue thousands of matvecs —
    only an explicit ``interpret`` request opts into the Python
    interpreter here; otherwise non-TPU backends use the XLA-fused
    reference (``pallas`` off-TPU degrades to interpret via `_dispatch`).
    """
    b = _dispatch(backend)
    obs.inc("kernel.dispatch", op="matvec", backend=b)
    with obs.stage("kernel.matvec", backend=b):
        if b == "pallas":
            return matvec_pallas(a, x, **kw)
        if b == "interpret":
            return matvec_pallas(a, x, interpret=True, **kw)
        return _ref.matvec_ref(a, x)


def stencil_mv(bands: jax.Array, x: jax.Array, *, offsets: tuple,
               backend: Optional[str] = None, **kw) -> jax.Array:
    """Banded stencil matvec; same dispatch policy as `matvec`."""
    b = _dispatch(backend)
    obs.inc("kernel.dispatch", op="stencil_mv", backend=b)
    with obs.stage("kernel.stencil_mv", backend=b):
        if b == "pallas":
            return stencil_mv_pallas(bands, x, offsets=offsets, **kw)
        if b == "interpret":
            return stencil_mv_pallas(bands, x, offsets=offsets,
                                     interpret=True, **kw)
        return _ref.stencil_mv_ref(bands, x, offsets=offsets)


def panel_factor_vmem(panel: jax.Array, m0, r_pos=0, *,
                      backend: Optional[str] = None):
    """VMEM-resident k-step panel factorization (§Perf P0/It3).

    On the ``xla`` backend falls through to the engine's jnp panel
    factorization (same numerics, XLA-fused) instead of the interpreter.
    """
    b = _dispatch(backend)
    obs.inc("kernel.dispatch", op="panel_factor_vmem", backend=b)
    with obs.stage("kernel.panel_factor_vmem", backend=b):
        if b == "xla":
            from repro.core.engine import panel_factor
            return panel_factor(panel, m0, r_pos=r_pos)
        return panel_factor_pallas(panel, m0, r_pos,
                                   interpret=b == "interpret")
