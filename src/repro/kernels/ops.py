"""Jitted kernel entry points with explicit backend dispatch.

Three backends:

  ``pallas``     the Pallas kernels compiled natively (TPU).
  ``interpret``  the same Pallas kernel bodies run in ``interpret=True``
                 mode — Python-slow, but byte-for-byte the kernel logic,
                 which is what CPU CI wants for deterministic coverage.
  ``xla``        the pure-jnp references (kernels/ref.py) or, for the
                 panel factorization, the engine's jnp implementation —
                 the fast fallback on non-TPU backends.

Resolution order (most specific wins): an explicit ``backend=`` request
from the caller (the engine passes its resolved backend; ``"pallas"``
off-TPU degrades to ``"interpret"`` — the kernel body still runs, never
a silent fall-through to the reference), else the
``REPRO_KERNEL_BACKEND`` environment variable (re-read at every trace,
so a test/CI job can force any backend deterministically — the old
``lru_cache``d TPU probe pinned the choice for the whole process), else
``pallas`` on TPU and ``xla`` elsewhere.

The algorithm code (core/engine.py) calls these via its backend hooks.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax

from repro import obs
from repro.kernels import ref as _ref
from repro.kernels.condense_step import rank1_update_pallas
from repro.kernels.matvec import matvec_pallas
from repro.kernels.panel_factor import panel_factor_pallas
from repro.kernels.panel_update import panel_update_pallas
from repro.kernels.stencil_mv import stencil_mv_pallas

__all__ = ["rank1_update", "panel_update", "panel_factor_vmem", "matvec",
           "stencil_mv", "kernel_backend", "on_tpu", "KERNEL_BACKENDS"]

KERNEL_BACKENDS = ("xla", "pallas", "interpret")
_ENV_VAR = "REPRO_KERNEL_BACKEND"


@functools.lru_cache(maxsize=1)
def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _dispatch(requested: Optional[str]) -> str:
    """Resolve a backend: explicit request > env override > platform."""
    src = "backend argument"
    if requested is None:
        requested = os.environ.get(_ENV_VAR, "").strip().lower() or None
        src = _ENV_VAR
    if requested is not None and requested not in KERNEL_BACKENDS:
        raise ValueError(
            f"{src}={requested!r}: choose one of {KERNEL_BACKENDS}")
    if requested == "pallas" and not on_tpu():
        return "interpret"          # run the kernel BODY, not the reference
    if requested is not None:
        return requested
    return "pallas" if on_tpu() else "xla"


def kernel_backend() -> str:
    """The process-default kernel backend: env override, else platform."""
    env = os.environ.get(_ENV_VAR, "").strip().lower()
    if env:
        if env not in KERNEL_BACKENDS:
            raise ValueError(
                f"{_ENV_VAR}={env!r}: choose one of {KERNEL_BACKENDS}")
        return env
    return "pallas" if on_tpu() else "xla"


def rank1_update(a: jax.Array, pc: jax.Array, pr: jax.Array, *,
                 backend: Optional[str] = None, **kw) -> jax.Array:
    """Fused a -= outer(pc, pr); backend per `_dispatch`."""
    b = _dispatch(backend)
    obs.inc("kernel.dispatch", op="rank1_update", backend=b)
    with obs.stage("kernel.rank1_update", backend=b):
        if b == "xla":
            return _ref.rank1_update_ref(a, pc, pr)
        return rank1_update_pallas(a, pc, pr, interpret=b == "interpret",
                                   **kw)


def panel_update(a: jax.Array, c: jax.Array, r: jax.Array, *,
                 backend: Optional[str] = None, **kw) -> jax.Array:
    """Fused a -= c @ r; backend per `_dispatch`."""
    b = _dispatch(backend)
    obs.inc("kernel.dispatch", op="panel_update", backend=b)
    with obs.stage("kernel.panel_update", backend=b):
        if b == "xla":
            return _ref.panel_update_ref(a, c, r)
        return panel_update_pallas(a, c, r, interpret=b == "interpret", **kw)


def matvec(a: jax.Array, x: jax.Array, *, backend: Optional[str] = None,
           **kw) -> jax.Array:
    """Tiled a @ x (vector or multi-vector).

    Unlike the update kernels (whose interpret mode is fast enough for
    validation-sized inputs), the estimators issue thousands of matvecs —
    only an explicit ``interpret`` request opts into the Python
    interpreter here; otherwise non-TPU backends use the XLA-fused
    reference (``pallas`` off-TPU degrades to interpret via `_dispatch`).
    """
    b = _dispatch(backend)
    obs.inc("kernel.dispatch", op="matvec", backend=b)
    with obs.stage("kernel.matvec", backend=b):
        if b == "pallas":
            return matvec_pallas(a, x, **kw)
        if b == "interpret":
            return matvec_pallas(a, x, interpret=True, **kw)
        return _ref.matvec_ref(a, x)


def stencil_mv(bands: jax.Array, x: jax.Array, *, offsets: tuple,
               backend: Optional[str] = None, **kw) -> jax.Array:
    """Banded stencil matvec; same dispatch policy as `matvec`."""
    b = _dispatch(backend)
    obs.inc("kernel.dispatch", op="stencil_mv", backend=b)
    with obs.stage("kernel.stencil_mv", backend=b):
        if b == "pallas":
            return stencil_mv_pallas(bands, x, offsets=offsets, **kw)
        if b == "interpret":
            return stencil_mv_pallas(bands, x, offsets=offsets,
                                     interpret=True, **kw)
        return _ref.stencil_mv_ref(bands, x, offsets=offsets)


def panel_factor_vmem(panel: jax.Array, m0, r_pos=0, *,
                      backend: Optional[str] = None):
    """VMEM-resident k-step panel factorization (§Perf P0/It3).

    On the ``xla`` backend falls through to the engine's jnp panel
    factorization (same numerics, XLA-fused) instead of the interpreter.
    """
    b = _dispatch(backend)
    obs.inc("kernel.dispatch", op="panel_factor_vmem", backend=b)
    with obs.stage("kernel.panel_factor_vmem", backend=b):
        if b == "xla":
            from repro.core.engine import panel_factor
            return panel_factor(panel, m0, r_pos=r_pos)
        return panel_factor_pallas(panel, m0, r_pos,
                                   interpret=b == "interpret")
