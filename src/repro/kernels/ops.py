"""Jitted kernel entry points with automatic backend dispatch.

On TPU the Pallas kernels compile natively; on CPU (this container) they run
in ``interpret=True`` mode, which executes the kernel body in Python for
correctness validation against ref.py.  The algorithm code (core/*.py) calls
these via the ``update_fn`` / ``gemm_fn`` hooks.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import ref as _ref
from repro.kernels.condense_step import rank1_update_pallas
from repro.kernels.matvec import matvec_pallas
from repro.kernels.panel_factor import panel_factor_pallas
from repro.kernels.panel_update import panel_update_pallas
from repro.kernels.stencil_mv import stencil_mv_pallas

__all__ = ["rank1_update", "panel_update", "panel_factor_vmem", "matvec",
           "stencil_mv", "on_tpu"]


@functools.lru_cache(maxsize=1)
def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def rank1_update(a: jax.Array, pc: jax.Array, pr: jax.Array, **kw) -> jax.Array:
    """Fused a -= outer(pc, pr); Pallas on TPU, interpret elsewhere."""
    return rank1_update_pallas(a, pc, pr, interpret=not on_tpu(), **kw)


def panel_update(a: jax.Array, c: jax.Array, r: jax.Array, **kw) -> jax.Array:
    """Fused a -= c @ r; Pallas on TPU, interpret elsewhere."""
    return panel_update_pallas(a, c, r, interpret=not on_tpu(), **kw)


def matvec(a: jax.Array, x: jax.Array, **kw) -> jax.Array:
    """Tiled a @ x (vector or multi-vector); Pallas on TPU, jnp elsewhere.

    Unlike the update kernels (whose interpret mode is fast enough for
    validation-sized inputs), the estimators issue thousands of matvecs — on
    non-TPU backends we fall through to the XLA-fused reference instead of
    the Python interpreter.
    """
    if on_tpu():
        return matvec_pallas(a, x, **kw)
    return _ref.matvec_ref(a, x)


def stencil_mv(bands: jax.Array, x: jax.Array, *, offsets: tuple,
               **kw) -> jax.Array:
    """Banded stencil matvec; Pallas on TPU, jnp reference elsewhere.

    Like `matvec`, the estimators drive this thousands of times — on non-TPU
    backends fall through to the XLA-fused reference rather than the Python
    interpreter.
    """
    if on_tpu():
        return stencil_mv_pallas(bands, x, offsets=offsets, **kw)
    return _ref.stencil_mv_ref(bands, x, offsets=offsets)


def panel_factor_vmem(panel: jax.Array, m0, r_pos=0):
    """VMEM-resident k-step panel factorization (§Perf P0/It3)."""
    return panel_factor_pallas(panel, m0, r_pos, interpret=not on_tpu())
