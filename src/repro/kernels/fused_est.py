"""Pallas TPU kernels: fused estimator recurrences (Chebyshev, CG).

The stochastic Chebyshev estimator's three-term recurrence used to make
two passes over ``A`` worth of traffic per degree: the shifted matvec
``(2 A w - c w) / width`` materializes ``A w`` to HBM, then the axpy
``2 mv - w_prev`` and the probe dot ``(v * w).sum`` stream the slab
again.  Same story for CG's hot chain ``ap = A p; alpha = rz / p.ap;
x += alpha p; r -= alpha ap``.  Both are one-matvec-plus-epilogue
shapes: the epilogue is O(n k) next to the O(n^2 k) matvec, so the win
is keeping the slab VMEM-resident — read ``A`` once, finish the
recurrence before anything round-trips HBM.

Single-block kernels (grid=()): ``A`` plus the probe slabs must fit the
VMEM budget (checked by the dispatch layer in `repro.kernels.ops`,
which falls back to the identical unfused jnp reference for oversized
operands).  The arithmetic is ordered exactly as the unfused reference
in `repro.kernels.ref` so f32 results are bit-identical (asserted in
tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "cheb_step_kernel", "cheb_step_pallas",
    "cg_step_kernel", "cg_step_pallas", "VMEM_BUDGET",
]

VMEM_BUDGET = 8 * 1024 * 1024  # bytes; A + slabs must fit


def _pref(dt):
    return jnp.float64 if dt == jnp.float64 else jnp.float32


def cheb_step_kernel(a_ref, w_ref, wprev_ref, v_ref, center_ref,
                     width_ref, wnext_ref, dots_ref):
    """w_next = 2*(2 A w - c w)/width - w_prev; dots = (v * w_next).sum(0)."""
    a = a_ref[...]
    w = w_ref[...]
    center = center_ref[0]
    width = width_ref[0]
    aw = jnp.dot(a, w, preferred_element_type=_pref(a.dtype)).astype(a.dtype)
    mv = (2.0 * aw - center * w) / width
    w_next = 2.0 * mv - wprev_ref[...]
    wnext_ref[...] = w_next
    dots_ref[...] = (v_ref[...] * w_next).sum(0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cheb_step_pallas(a: jax.Array, w: jax.Array, w_prev: jax.Array,
                     v: jax.Array, center, width, *,
                     interpret: bool = False):
    """Fused Chebyshev three-term step; returns (w_next, dots)."""
    n, k = w.shape
    center = jnp.asarray(center, a.dtype).reshape(1)
    width = jnp.asarray(width, a.dtype).reshape(1)
    w_next, dots = pl.pallas_call(
        cheb_step_kernel,
        in_specs=[
            pl.BlockSpec((n, n), lambda: (0, 0)),   # A, VMEM-resident
            pl.BlockSpec((n, k), lambda: (0, 0)),   # w
            pl.BlockSpec((n, k), lambda: (0, 0)),   # w_prev
            pl.BlockSpec((n, k), lambda: (0, 0)),   # v
            pl.BlockSpec((1,), lambda: (0,)),       # center
            pl.BlockSpec((1,), lambda: (0,)),       # width
        ],
        out_specs=[
            pl.BlockSpec((n, k), lambda: (0, 0)),
            pl.BlockSpec((k,), lambda: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k), a.dtype),
            jax.ShapeDtypeStruct((k,), a.dtype),
        ],
        interpret=interpret,
    )(a, w, w_prev, v, center, width)
    return w_next, dots


def cg_step_kernel(a_ref, p_ref, x_ref, r_ref, rz_ref, x_out, r_out):
    """ap = A p; alpha = rz / p.ap (0/0 -> 0); x += alpha p; r -= alpha ap."""
    a = a_ref[...]
    p = p_ref[...]
    ap = jnp.dot(a, p, preferred_element_type=_pref(a.dtype)).astype(a.dtype)
    den = (p * ap).sum(0)
    rz = rz_ref[...]
    # same guarded division as operators.solve._safe_div: converged
    # columns have vanishing denominators and must take exact no-ops
    tiny = jnp.finfo(den.dtype).tiny
    safe = jnp.where(jnp.abs(den) > tiny, den, 1.0)
    alpha = jnp.where(jnp.abs(den) > tiny, rz / safe,
                      jnp.zeros_like(rz))[None, :]
    x_out[...] = x_ref[...] + alpha * p
    r_out[...] = r_ref[...] - alpha * ap


@functools.partial(jax.jit, static_argnames=("interpret",))
def cg_step_pallas(a: jax.Array, p: jax.Array, x: jax.Array, r: jax.Array,
                   rz: jax.Array, *, interpret: bool = False):
    """Fused CG matvec+axpy+dot chain; returns (x_new, r_new)."""
    n, k = p.shape
    x_new, r_new = pl.pallas_call(
        cg_step_kernel,
        in_specs=[
            pl.BlockSpec((n, n), lambda: (0, 0)),   # A, VMEM-resident
            pl.BlockSpec((n, k), lambda: (0, 0)),   # p
            pl.BlockSpec((n, k), lambda: (0, 0)),   # x
            pl.BlockSpec((n, k), lambda: (0, 0)),   # r
            pl.BlockSpec((k,), lambda: (0,)),       # rz
        ],
        out_specs=[
            pl.BlockSpec((n, k), lambda: (0, 0)),
            pl.BlockSpec((n, k), lambda: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k), a.dtype),
            jax.ShapeDtypeStruct((n, k), a.dtype),
        ],
        interpret=interpret,
    )(a, p, x, r, rz)
    return x_new, r_new
