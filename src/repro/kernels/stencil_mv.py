"""Pallas TPU kernel: banded / sparse-stencil matvec.

A stencil operator applies ``y[i] = sum_d bands[d, i] * x[i + offsets[d]]``
— a handful of diagonals instead of a dense matrix.  The arithmetic
intensity is tiny (one multiply-add per band element), so the product is
purely bandwidth-bound: the kernel's job is one pass over the (nb, n) band
table with the probe slab resident in VMEM.

Grid: ``(M/bm,)`` over row tiles.  Per program: the (nb, bm) band tile for
its rows, the whole zero-padded slab ``xp (m_pad + span, k)`` (estimator
slabs are k ~ 8..64 columns — a few hundred KiB, far under the ~16 MiB
VMEM budget for any n this kernel targets), and the (bm, k) output tile.
Each band contributes a ``pl.ds``-shifted (bm, k) window of ``xp`` scaled
by its coefficient column; offsets are static Python ints so the band loop
unrolls at trace time.

Zero padding (``-lo`` rows above, ``hi + tile remainder`` below) realizes
the Dirichlet boundary — rows whose stencil pokes outside [0, n) read
zeros — and keeps every window in range, so no masking is needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["stencil_mv_kernel", "stencil_mv_pallas"]

DEFAULT_BM = 256


def stencil_mv_kernel(bands_ref, xp_ref, o_ref, *, offsets, lo, bm):
    """o[i] = sum_d bands[d, i] * xp[i + offsets[d] - lo] for the row tile."""
    i = pl.program_id(0)
    acc = jnp.zeros(o_ref.shape, o_ref.dtype)
    for d, off in enumerate(offsets):
        window = xp_ref[pl.ds(i * bm + (off - lo), bm), :]
        acc += bands_ref[d, :][:, None] * window
    o_ref[...] = acc


@functools.partial(jax.jit,
                   static_argnames=("offsets", "bm", "interpret"))
def stencil_mv_pallas(bands: jax.Array, x: jax.Array, *, offsets: tuple,
                      bm: int = DEFAULT_BM,
                      interpret: bool = False) -> jax.Array:
    """Banded matvec ``y[i] = sum_d bands[d, i] * x[i + offsets[d]]``.

    ``bands (nb, n)`` holds one coefficient row per diagonal offset;
    ``x (n,) or (n, k)``; out-of-range reads are zero (Dirichlet).
    """
    vec = x.ndim == 1
    x2 = (x[:, None] if vec else x).astype(bands.dtype)
    n, k = x2.shape
    lo = min(min(offsets), 0)
    hi = max(max(offsets), 0)
    bm = min(bm, n)
    m_pad = -(-n // bm) * bm
    span = hi - lo
    xp = jnp.pad(x2, ((-lo, hi + (m_pad - n)), (0, 0)))
    bands_p = jnp.pad(bands, ((0, 0), (0, m_pad - n)))
    out = pl.pallas_call(
        functools.partial(stencil_mv_kernel, offsets=offsets, lo=lo, bm=bm),
        grid=(m_pad // bm,),
        in_specs=[
            pl.BlockSpec((bands.shape[0], bm), lambda i: (0, i)),
            pl.BlockSpec((m_pad + span, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, k), bands.dtype),
        interpret=interpret,
    )(bands_p, xp)
    out = out[:n]
    return out[:, 0] if vec else out
