"""Calibration-driven tile autotuner for the condensation kernels.

The engine used to hard-code ``panel_k = 32`` (and each Pallas kernel its
own block sizes) — a geometry guessed for one machine.  This module
derives the panel width and kernel tile sizes from the **measured**
roofline table (`repro.core.calibration`): the balance point between the
GEMM term (total trailing-update FLOPs at ``gemm_flops``) and the
streaming terms (per-panel one-pass swap+update traffic ~``n^3/k`` and
panel-factorization traffic ~``k * n^2``, both at ``stream_bytes``)
moves with the machine's FLOP/byte ratio, so the tuned ``k`` does too
(k* ~ sqrt(n/2) on a balanced part, larger when streaming is cheap
relative to GEMMs).

The cost model is intentionally the same family of terms
`core.calibration.exact_cost` prices routes with — which is the point:
``exact_cost`` resolves its default panel width HERE, so ``method="auto"``
prices exactly the geometry the kernels then run.

Results are cached per (device fingerprint, dtype, n-bucket, calibration
source).  ``REPRO_AUTOTUNE`` overrides:

  REPRO_AUTOTUNE=off                      pin the legacy fixed geometry
  REPRO_AUTOTUNE=panel_k=64               pin the panel width
  REPRO_AUTOTUNE=panel_k=64,block_m=128,block_n=256
                                          pin panel width and kernel tiles
"""
from __future__ import annotations

import functools
import math
import os
from dataclasses import dataclass

__all__ = [
    "TileConfig", "tile_config", "resolved_panel_k", "device_fingerprint",
    "clear_autotune_cache", "DEFAULT_PANEL_K", "PANEL_K_CANDIDATES",
]

_ENV_VAR = "REPRO_AUTOTUNE"

# the legacy fixed geometry (pre-autotuner); REPRO_AUTOTUNE=off pins it
DEFAULT_PANEL_K = 32
PANEL_K_CANDIDATES = (8, 16, 32, 64, 128)

# kernel block sizes by itemsize: both dims multiples of the TPU VREG
# tile ((8, 128) f32, (16, 128) bf16) and small enough that a
# (bm, bn) + slab footprint stays well under the ~16 MiB VMEM budget
_BLOCKS_BY_ITEMSIZE = {8: (256, 256), 4: (256, 512), 2: (512, 512)}
_DEFAULT_BLOCKS = (256, 512)


@dataclass(frozen=True)
class TileConfig:
    """A resolved kernel geometry.

    ``panel_k``  rank-K panel width (engine ``panel`` update / exact_cost).
    ``block_m`` / ``block_n``  Pallas grid tile of the fused update kernels.
    ``source``   provenance: "model:<cal-source>", "env", or "off".
    """
    panel_k: int = DEFAULT_PANEL_K
    block_m: int = _DEFAULT_BLOCKS[0]
    block_n: int = _DEFAULT_BLOCKS[1]
    source: str = "off"

    def __post_init__(self):
        for name in ("panel_k", "block_m", "block_n"):
            if int(getattr(self, name)) < 1:
                raise ValueError(f"{name} must be >= 1, "
                                 f"got {getattr(self, name)}")


@functools.lru_cache(maxsize=1)
def device_fingerprint() -> str:
    """Stable id of the accelerator the tuned geometry was derived for."""
    import jax
    devs = jax.devices()
    d0 = devs[0]
    kind = getattr(d0, "device_kind", d0.platform)
    return f"{d0.platform}:{kind}:{len(devs)}"


def _parse_override(env: str):
    """Parse a REPRO_AUTOTUNE override; None means "run the model"."""
    env = env.strip()
    if not env:
        return None
    if env.lower() == "off":
        return TileConfig(source="off")
    fields = {}
    for part in env.split(","):
        if "=" not in part:
            raise ValueError(
                f"bad {_ENV_VAR} entry {part!r}; expected 'off' or "
                "comma-separated key=int pairs "
                "(panel_k=..., block_m=..., block_n=...)")
        key, _, val = part.partition("=")
        key = key.strip()
        if key not in ("panel_k", "block_m", "block_n"):
            raise ValueError(f"unknown {_ENV_VAR} key {key!r}; one of "
                             "panel_k, block_m, block_n")
        fields[key] = int(val)
    return TileConfig(source="env", **{
        "block_m": _DEFAULT_BLOCKS[0], "block_n": _DEFAULT_BLOCKS[1],
        **fields})


def _gemm_rate(cal, precision):
    """Per-dtype sustained GEMM rate from the calibration table."""
    rate_fn = getattr(cal, "gemm_rate", None)
    if rate_fn is not None:
        return float(rate_fn(precision))
    return float(cal.gemm_flops)


def _model_cost(k: int, n: int, itemsize: int, gemm: float,
                stream: float) -> float:
    """Modeled seconds for one n x n condensation at panel width k.

    gemm term    (2/3) n^3 trailing-update FLOPs at the measured rate
    stream terms one fused swap+update pass over the trailing block per
                 panel (~n^2 elements x n/k panels) plus the k serial
                 rank-1 passes of each panel factorization (k x n panel
                 re-streamed k times => k * n^2 total elements)
    """
    panels = max(1.0, n / k)
    gemm_t = (2.0 / 3.0) * float(n) ** 3 / gemm
    byte_t = itemsize / stream
    sweep_t = panels * 0.5 * float(n) ** 2 * 2.0 * byte_t
    factor_t = float(k) * float(n) ** 2 * byte_t
    return gemm_t + sweep_t + factor_t


@functools.lru_cache(maxsize=64)
def _tuned(fingerprint: str, n_bucket: int, itemsize: int,
           precision, cal_key: str) -> TileConfig:
    from repro.core.calibration import load_calibration
    cal = load_calibration()
    gemm = _gemm_rate(cal, precision)
    stream = float(cal.stream_bytes)
    cap = max(PANEL_K_CANDIDATES[0], n_bucket // 4)
    cands = [k for k in PANEL_K_CANDIDATES if k <= cap] \
        or [PANEL_K_CANDIDATES[0]]
    best = min(cands, key=lambda k: _model_cost(k, n_bucket, itemsize,
                                                gemm, stream))
    bm, bn = _BLOCKS_BY_ITEMSIZE.get(itemsize, _DEFAULT_BLOCKS)
    return TileConfig(panel_k=best, block_m=bm, block_n=bn,
                      source=f"model:{cal.source}")


def tile_config(n: int, *, itemsize: int = 4, precision=None,
                cal=None) -> TileConfig:
    """The tuned geometry for an ``n x n`` problem on this device.

    ``itemsize`` is the buffer dtype's width in bytes; ``precision`` is
    the engine's mixed-precision route (``"bf16"`` prices GEMM operands
    at the bf16 rate).  ``cal`` overrides the loaded calibration table
    (tests); the override bypasses the cache.
    """
    override = _parse_override(os.environ.get(_ENV_VAR, ""))
    if override is not None:
        return override
    n_bucket = 1 << max(3, int(math.ceil(math.log2(max(2, int(n))))))
    if cal is not None:
        gemm = _gemm_rate(cal, precision)
        stream = float(cal.stream_bytes)
        cap = max(PANEL_K_CANDIDATES[0], n_bucket // 4)
        cands = [k for k in PANEL_K_CANDIDATES if k <= cap] \
            or [PANEL_K_CANDIDATES[0]]
        best = min(cands, key=lambda k: _model_cost(k, n_bucket, itemsize,
                                                    gemm, stream))
        bm, bn = _BLOCKS_BY_ITEMSIZE.get(itemsize, _DEFAULT_BLOCKS)
        return TileConfig(panel_k=best, block_m=bm, block_n=bn,
                          source=f"model:{cal.source}")
    from repro.core.calibration import load_calibration
    cal_key = load_calibration().source
    return _tuned(device_fingerprint(), n_bucket, int(itemsize),
                  precision, cal_key)


def resolved_panel_k(n: int, *, itemsize: int = 4, precision=None,
                     cal=None) -> int:
    """The tuned panel width (what replaced the hard-coded 32)."""
    return tile_config(n, itemsize=itemsize, precision=precision,
                       cal=cal).panel_k


def clear_autotune_cache():
    """Re-run the model on next call (test hook / after recalibration)."""
    _tuned.cache_clear()
    device_fingerprint.cache_clear()
