"""Pallas TPU kernel: fused one-pass condensation step.

The engine's rank-1 step used to make three passes over the trailing
buffer: the §2.4 column swap (two scatters), then the rank-1 update
(read + write).  But the swap and the update commute into ONE
elementwise pass once the swap is expressed as a per-column select —
column ``l`` takes the old column ``last``, column ``last`` takes the
old column ``l``, everything else passes through — fused with the
multiply-subtract:

    out[:, j] = select_swap(a, j) - pc * pr[j]

Bit-identical to the scatter+outer sequence (pure data movement plus the
same multiply-subtract, asserted in tests/test_kernels.py) and the
buffer is read and written exactly once per step instead of three times.

The O(n) pivot bookkeeping — argmax over the live pivot row, pivot-row
normalization, sign/parity tracking — stays outside the kernel (it
touches one row, not the O(n^2) buffer) in `repro.kernels.ops
.fused_condense_step`, which is the dispatch entry the engine calls.

Tiling: grid (M/bm, N/bn); each program reads
  a tile (bm, bn), the two swap columns + pivot column as (bm, 1) slabs,
  the pivot row as a (1, bn) slab, and the scalar column ids l / last.
Default tiles come from the calibration-driven autotuner
(`repro.kernels.autotune`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["fused_step_kernel", "fused_step_pallas"]


def fused_step_kernel(l_ref, last_ref, a_ref, cl_ref, clast_ref,
                      pc_ref, pr_ref, o_ref, *, bn: int):
    """o = swap_select(a; l<->last) - pc * pr, one pass over the tile."""
    j0 = pl.program_id(1) * bn
    cols = j0 + lax.broadcasted_iota(jnp.int32, (1, bn), 1)
    l = l_ref[0]
    last = last_ref[0]
    a = a_ref[...]
    sw = jnp.where(cols == l, clast_ref[...],
                   jnp.where(cols == last, cl_ref[...], a))
    # pc/pr may ride in at a lower precision (bf16 operands); the product
    # is accumulated back into the buffer dtype
    o_ref[...] = sw - (pc_ref[...] * pr_ref[...]).astype(a.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def fused_step_pallas(a: jax.Array, l, last, pc: jax.Array, pr: jax.Array,
                      col_l: jax.Array, col_last: jax.Array, *,
                      bm: int = 256, bn: int = 512,
                      interpret: bool = False) -> jax.Array:
    """Fused swap(l<->last) + rank-1 update via a tiled Pallas kernel.

    ``a (M, N)``; ``l`` / ``last`` scalar column ids; ``pc (M,)`` pivot
    column (zeroed at dead/pivot rows); ``pr (N,)`` normalized pivot row;
    ``col_l`` / ``col_last (M,)`` the two pre-swap columns.
    """
    m, n = a.shape
    bm = min(bm, m)
    bn = min(bn, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    l = jnp.asarray(l, jnp.int32).reshape(1)
    last = jnp.asarray(last, jnp.int32).reshape(1)
    return pl.pallas_call(
        functools.partial(fused_step_kernel, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),       # l
            pl.BlockSpec((1,), lambda i, j: (0,)),       # last
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),  # a tile
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),   # col_l slab
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),   # col_last slab
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),   # pc slab
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),   # pr slab
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(l, last, a, col_l[:, None], col_last[:, None], pc[:, None],
      pr[None, :])
