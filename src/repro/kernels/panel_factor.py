"""Pallas TPU kernel: VMEM-resident panel factorization (§Perf P0/It3).

The blocked algorithm's panel factorization runs k rank-1 condensation steps
over a (k, N) panel.  Done with jnp ops, each step re-reads and re-writes the
panel through HBM: 8*k^2*N bytes per panel — at k ~ L this costs as much
traffic as the whole baseline.  But a (k, N) f32 panel at k=16..32, N<=64k is
2..8 MiB — it FITS IN VMEM.  This kernel keeps the panel resident for all k
steps: HBM traffic drops to one read + one write (8*k*N), a k-fold cut —
the TPU-native realization of the paper's §2.4 cache-contiguity insight.

Single-block kernel (grid=()): panel must satisfy k*N*4B <= ~8 MiB.
Scalars (live column count m0, sign parity offset r_pos) ride in as (1,)
int32 inputs.  Outputs: factorized panel R (k,N) in final swapped
coordinates, chosen pivot columns ls (k,), and the panel's (sign, logdet)
contribution — bit-identical semantics to core.blocked.panel_factor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["panel_factor_kernel", "panel_factor_pallas"]

VMEM_BUDGET = 8 * 1024 * 1024  # bytes; panel must fit


def panel_factor_kernel(m0_ref, rpos_ref, panel_ref, r_ref, ls_ref,
                        sign_ref, logdet_ref):
    k, n = panel_ref.shape
    m0 = m0_ref[0]
    r_pos = rpos_ref[0]
    cols = lax.broadcasted_iota(jnp.int32, (n,), 0)

    def body(j, carry):
        buf, ls, sign, logdet = carry
        m = m0 - j
        last = m - 1
        row = buf[j]
        absrow = jnp.where(cols < m, jnp.abs(row), -jnp.inf)
        l = jnp.argmax(absrow).astype(jnp.int32)
        pv = row[l]

        cl = jnp.take(buf, l, axis=1)
        clast = jnp.take(buf, last, axis=1)
        buf = buf.at[:, l].set(clast)
        buf = buf.at[:, last].set(cl)

        row = buf[j]
        safe = jnp.where(pv == 0, jnp.ones((), buf.dtype), pv)
        pr = jnp.where(pv == 0, jnp.zeros_like(row), row / safe)
        pr = pr.at[last].set(jnp.where(pv == 0, pr[last], 1.0))
        buf = buf.at[j].set(pr)

        pc = jnp.take(buf, last, axis=1)
        pc = jnp.where(lax.broadcasted_iota(jnp.int32, (k,), 0) <= j, 0.0, pc)
        buf = buf - pc[:, None] * pr[None, :]
        # the pivot row was overwritten by the update of itself with pc=0;
        # (pc[j]==0 so row j is untouched — already pr)

        ls = ls.at[j].set(l)
        parity = jnp.where((r_pos + m - 1) % 2 == 0, 1.0, -1.0).astype(buf.dtype)
        swap_sign = jnp.where(l == last, 1.0, -1.0).astype(buf.dtype)
        sign = sign * jnp.sign(pv) * swap_sign * parity
        logdet = logdet + jnp.log(jnp.abs(pv))
        return buf, ls, sign, logdet

    buf0 = panel_ref[...]
    ls0 = jnp.zeros((k,), jnp.int32)
    one = jnp.ones((), buf0.dtype)
    zero = jnp.zeros((), buf0.dtype)
    buf, ls, sign, logdet = lax.fori_loop(0, k, body, (buf0, ls0, one, zero))
    r_ref[...] = buf
    ls_ref[...] = ls
    sign_ref[0] = sign
    logdet_ref[0] = logdet


@functools.partial(jax.jit, static_argnames=("interpret",))
def panel_factor_pallas(panel: jax.Array, m0, r_pos=0, *,
                        interpret: bool = False):
    """VMEM-resident panel factorization; returns (R, ls, sign, logdet)."""
    k, n = panel.shape
    if k * n * panel.dtype.itemsize > VMEM_BUDGET:
        raise ValueError(f"panel {panel.shape} exceeds VMEM budget")
    m0 = jnp.asarray(m0, jnp.int32).reshape(1)
    r_pos = jnp.asarray(r_pos, jnp.int32).reshape(1)
    r, ls, sign, logdet = pl.pallas_call(
        panel_factor_kernel,
        in_specs=[
            pl.BlockSpec((1,), lambda: (0,)),      # m0   (SMEM-able scalar)
            pl.BlockSpec((1,), lambda: (0,)),      # r_pos
            pl.BlockSpec((k, n), lambda: (0, 0)),  # the VMEM-resident panel
        ],
        out_specs=[
            pl.BlockSpec((k, n), lambda: (0, 0)),
            pl.BlockSpec((k,), lambda: (0,)),
            pl.BlockSpec((1,), lambda: (0,)),
            pl.BlockSpec((1,), lambda: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, n), panel.dtype),
            jax.ShapeDtypeStruct((k,), jnp.int32),
            jax.ShapeDtypeStruct((1,), panel.dtype),
            jax.ShapeDtypeStruct((1,), panel.dtype),
        ],
        interpret=interpret,
    )(m0, r_pos, panel)
    return r, ls, sign[0], logdet[0]
