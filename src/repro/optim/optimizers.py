"""Optimizers (AdamW, Adafactor) + schedules + gradient utilities.

Self-contained pytree optimizers (no optax dependency):
  * adamw      — fp32 moments; the default.
  * adafactor  — factored second moment: the memory-feasible choice for the
                 400B llama4 cell (see DESIGN.md memory budget).
Gradient utilities: global-norm clipping and bf16 gradient COMPRESSION for
cross-pod all-reduce (cast-to-bf16 before psum, error tolerated by Adam's
normalization; enabled via TrainConfig.grad_compression).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | adafactor | sgd
    lr: float = 3e-4
    warmup: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0


def lr_at(cfg: OptConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = cfg.lr * jnp.minimum(1.0, (step + 1) / max(cfg.warmup, 1))
    prog = jnp.clip((step - cfg.warmup) / max(cfg.decay_steps - cfg.warmup, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup, warm, cfg.lr * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        grads), g


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: OptConfig, grads, state, params):
    c = state["count"] + 1
    cf = c.astype(jnp.float32)
    lr = lr_at(cfg, state["count"])
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** cf)
        vh = v / (1 - b2 ** cf)
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:                      # decoupled decay on matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"m": new_m, "v": new_v, "count": c}


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; no first moment by default)
# ---------------------------------------------------------------------------

def adafactor_init(params):
    def factored(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"f": jax.tree.map(factored, params,
                              is_leaf=lambda x: hasattr(x, "shape")),
            "count": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: OptConfig, grads, state, params):
    c = state["count"] + 1
    lr = lr_at(cfg, state["count"])
    decay = 1.0 - (c.astype(jnp.float32)) ** -0.8

    def upd(f, g, p):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if p.ndim >= 2:
            vr = decay * f["vr"] + (1 - decay) * g2.mean(axis=-1)
            vc = decay * f["vc"] + (1 - decay) * g2.mean(axis=-2)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(vr.mean(axis=-1)[..., None, None], 1e-30))
            step = g * jax.lax.rsqrt(denom + 1e-30)
            nf = {"vr": vr, "vc": vc}
        else:
            v = decay * f["v"] + (1 - decay) * g2
            step = g * jax.lax.rsqrt(v + 1e-30)
            nf = {"v": v}
        # update clipping (Adafactor's RMS-1 rule)
        rms = jnp.sqrt(jnp.mean(step * step) + 1e-30)
        step = step / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), nf

    # f nodes ({"vr","vc"} / {"v"}) are treated as leaves of the FIRST tree
    is_f = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
    out = jax.tree.map(upd, state["f"], grads, params, is_leaf=is_f)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_f = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"f": new_f, "count": c}


# ---------------------------------------------------------------------------
# SGD (tests/toys)
# ---------------------------------------------------------------------------

def sgd_init(params):
    return {"count": jnp.zeros((), jnp.int32)}


def sgd_update(cfg: OptConfig, grads, state, params):
    lr = lr_at(cfg, state["count"])
    new_p = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)
                      ).astype(p.dtype), params, grads)
    return new_p, {"count": state["count"] + 1}


OPTIMIZERS = {
    "adamw": (adamw_init, adamw_update),
    "adafactor": (adafactor_init, adafactor_update),
    "sgd": (sgd_init, sgd_update),
}


def get_optimizer(cfg: OptConfig):
    init, update = OPTIMIZERS[cfg.name]
    return init, functools.partial(update, cfg)
