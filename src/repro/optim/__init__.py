from repro.optim.optimizers import (
    OptConfig, get_optimizer, clip_by_global_norm, global_norm, lr_at,
)
