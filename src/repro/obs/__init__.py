"""repro.obs — tracing, metrics, and convergence telemetry.

Zero-dependency instrumentation for the plan/engine/estimator stack.
Three modes via ``REPRO_OBS=off|metrics|trace`` (default ``off``):

==========  ==========================================================
``off``     no-ops everywhere; no host callbacks staged into jitted
            code (the lowered HLO is byte-identical to uninstrumented)
``metrics`` counters / gauges / histograms (plan-cache hits, retraces,
            probes used, CG iterations, ...)
``trace``   metrics + wall-time spans + convergence telemetry streamed
            off device via ``jax.debug.callback``; artifacts written
            to ``REPRO_OBS_DIR`` (default ``obs_out/``) at exit
==========  ==========================================================

See docs/observability.md for the full tour.  Public surface::

    with obs.span("plan.build"):          # host wall-time span
        ...
    with obs.stage("engine.pivot"):       # jax.named_scope + trace span
        ...
    obs.inc("plan.cache.hits")            # metrics
    obs.emit_curve("slq.sem", curve)      # telemetry (inside traced code)
    obs.export_chrome_trace("trace.json") # Perfetto-loadable
"""
from repro.obs.config import (
    ENV_DIR, ENV_VAR, MODES, configure, metrics_enabled, mode, out_dir,
    trace_enabled,
)
from repro.obs.export import (
    add_metrics_cli, chrome_trace, export_chrome_trace, export_jsonl,
    export_metrics, install_atexit, start_metrics_from_args,
    start_metrics_server, validate_chrome_trace, write_all,
)
from repro.obs.metrics import (
    counter_value, inc, observe, prometheus_text, quantile, set_gauge,
    snapshot,
)
from repro.obs.telemetry import (
    drain as drain_telemetry, emit_curve, emit_point, flush as flush_telemetry,
    running_sem,
)
from repro.obs.trace import dropped_events, events, span, stage

__all__ = [
    "configure", "mode", "out_dir", "metrics_enabled", "trace_enabled",
    "MODES", "ENV_VAR", "ENV_DIR",
    "span", "stage", "events", "dropped_events",
    "inc", "set_gauge", "observe", "counter_value", "snapshot",
    "prometheus_text", "quantile",
    "emit_curve", "emit_point", "running_sem", "drain_telemetry",
    "flush_telemetry",
    "chrome_trace", "export_chrome_trace", "export_jsonl", "export_metrics",
    "validate_chrome_trace", "write_all", "start_metrics_server",
    "add_metrics_cli", "start_metrics_from_args",
    "install_atexit", "reset",
]


def reset() -> None:
    """Clear spans, metrics, and telemetry buffers (test hook)."""
    from repro.obs import metrics as _m, telemetry as _t, trace as _tr
    _tr.reset()
    _m.reset()
    _t.reset()


# REPRO_OBS set in the environment -> dump artifacts at interpreter exit.
if mode() != "off":
    install_atexit()
